//! End-to-end driver (ARCHITECTURE.md walks this flow): the full paper
//! pipeline on a real workload, proving all three layers compose.
//!
//! 1. Profile the ARM platform (simulated substrate) into a dataset.
//! 2. Train the NN2 performance model by driving the AOT `train_step`
//!    HLO artifact (L2+L1: JAX MLP over Pallas dense kernels) via PJRT.
//! 3. Predict per-primitive costs for every GoogLeNet layer in one
//!    batched PJRT call, plus the DLT edge costs.
//! 4. PBQP-select the optimal primitive per layer.
//! 5. Report model-vs-profiled selection quality, and validate against
//!    *real measured* Pallas kernel executions on this host.
//!
//! Run: `cargo run --release --example quickstart`

use primsel::experiments::Workbench;
use primsel::networks;
use primsel::perfmodel::model::model_table;
use primsel::primitives::{catalog, Family};
use primsel::profiler;
use primsel::report::Table;
use primsel::runtime::Runtime;
use primsel::selection;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open_default()?;
    let mut wb = Workbench::new(rt);

    // ---- steps 1+2: profile (simulated ARM) + train NN2 over PJRT ----
    println!("[1/5] profiling ARM (simulated) + training NN2 via AOT train_step...");
    let t0 = Instant::now();
    let inputs = wb.xla_model_inputs("arm")?;
    println!("      ready in {:.1?} (cached under artifacts/trained/)", t0.elapsed());

    // ---- step 3: batched prediction for all GoogLeNet layers ----
    let net = networks::googlenet();
    let sim = wb.platform("arm")?.sim.clone();
    let model = inputs.build(&wb.rt)?;
    let _warm = model_table(&net, &model)?;
    let t0 = Instant::now();
    let source = model_table(&net, &model)?;
    let predict_ms = t0.elapsed().as_secs_f64() * 1e3;
    println!(
        "[2/5] predicted {} layer cost rows + DLT edges in {predict_ms:.1} ms (batched PJRT)",
        net.n_layers()
    );

    // ---- step 4: PBQP selection ----
    let t0 = Instant::now();
    let sel_model = selection::select(&net, &source)?;
    let pbqp_ms = t0.elapsed().as_secs_f64() * 1e3;
    println!("[3/5] PBQP selection in {pbqp_ms:.2} ms");

    // ---- step 5a: quality vs profiled-optimal + single-family baselines ----
    let sel_prof = selection::select(&net, &sim)?;
    let t_model = selection::evaluate(&net, &sel_model, &sim)?;
    let t_prof = selection::evaluate(&net, &sel_prof, &sim)?;
    let mut t = Table::new(
        "GoogLeNet on ARM: network inference time by strategy",
        &["strategy", "time (ms)", "vs profiled-optimal"],
    );
    t.row(vec![
        "profiled-optimal (paper [1])".into(),
        format!("{t_prof:.2}"),
        "1.000x".into(),
    ]);
    t.row(vec![
        "perf-model selection (ours)".into(),
        format!("{t_model:.2}"),
        format!("{:.4}x", t_model / t_prof),
    ]);
    for fam in [Family::Im2, Family::Kn2, Family::Direct] {
        let base = selection::single_family_baseline(&net, &sim, fam)?;
        t.row(vec![
            format!("all-{} baseline", fam.name()),
            format!("{:.2}", base.estimated_ms),
            format!("{:.3}x", base.estimated_ms / t_prof),
        ]);
    }
    println!("{}", t.render());
    println!(
        "[4/5] inference-time increase from using the model: {:.3}% (paper: <= 1.1%)",
        (t_model / t_prof - 1.0) * 100.0
    );

    // ---- step 5b: ground a sample with REAL kernel executions ----
    println!("[5/5] validating primitive rankings with real Pallas kernels on this host...");
    let measurements = profiler::profile_grid(&wb.rt, 7)?;
    let mut by_cfg: std::collections::BTreeMap<(u32, u32, u32, u32, u32), Vec<(String, f64)>> =
        Default::default();
    for m in &measurements {
        by_cfg
            .entry((m.c, m.im, m.k, m.f, m.s))
            .or_default()
            .push((m.kernel.clone(), m.median_ms));
    }
    let mut t = Table::new(
        "real measured kernel times (median, this host)",
        &["config (c,im,k,f,s)", "fastest kernel", "ms", "slowest kernel", "ms"],
    );
    for (cfg, mut v) in by_cfg {
        v.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        let (fast, slow) = (v.first().unwrap().clone(), v.last().unwrap().clone());
        t.row(vec![
            format!("{cfg:?}"),
            fast.0,
            format!("{:.3}", fast.1),
            slow.0,
            format!("{:.3}", slow.1),
        ]);
    }
    println!("{}", t.render());
    println!("quickstart complete: selected primitives for {} layers;", net.n_layers());
    println!(
        "  example selections: layer 0 -> {}, layer 10 -> {}, layer 56 -> {}",
        catalog()[sel_model.primitive[0]].name,
        catalog()[sel_model.primitive[10]].name,
        catalog()[sel_model.primitive[56]].name,
    );
    Ok(())
}

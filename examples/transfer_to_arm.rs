//! Transfer-learning walkthrough (paper §4.4 / Figure 9): pre-train on
//! Intel, then adapt to ARM three ways — direct, factor-corrected, and
//! fine-tuned on 1% of ARM data — and compare against native training.
//!
//! Run: `cargo run --release --example transfer_to_arm`

use primsel::dataset;
use primsel::experiments::Workbench;
use primsel::perfmodel::metrics::mdrae_all;
use primsel::perfmodel::transfer::factor_correction;
use primsel::perfmodel::Predictor;
use primsel::report::Table;
use primsel::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open_default()?;
    let mut wb = Workbench::new(rt);
    wb.max_epochs = 120; // walkthrough speed

    println!("pre-training the Intel NN2 model (cached if already trained)...");
    let intel = wb.nn2_params("intel")?;

    let (xs, targets, _, _) = wb.prim_test_data("arm")?;
    let (isx, isy) = wb.prim_standardizers("intel")?;

    // 1) direct application
    let direct = Predictor::new(&wb.rt, "nn2", intel.clone(), isx.clone(), isy.clone())?;
    let md_direct = mdrae_all(&direct.predict_raw(&xs)?, &targets);

    // 2) factor correction from 1% of ARM profiles
    let factors = {
        let pd = wb.platform("arm")?;
        let idx = dataset::fraction(&pd.prim_split.train, 0.01, 7);
        let cal = pd.prim.subset(&idx);
        let cxs: Vec<Vec<f64>> = cal.features().iter().map(|f| f.to_vec()).collect();
        let ctargets = cal.targets.clone();
        let pred = Predictor::new(&wb.rt, "nn2", intel.clone(), isx.clone(), isy.clone())?;
        factor_correction(&pred, &cxs, &ctargets)?
    };
    let mut corrected =
        Predictor::new(&wb.rt, "nn2", intel.clone(), isx.clone(), isy.clone())?;
    corrected.factors = factors;
    let md_factor = mdrae_all(&corrected.predict_raw(&xs)?, &targets);

    // 3) fine-tune on 1% of ARM data (lr/10, same AOT artifacts)
    println!("fine-tuning on 1% of ARM profiles...");
    let idx = {
        let pd = wb.platform("arm")?;
        dataset::fraction(&pd.prim_split.train, 0.01, 7)
    };
    let tuned = wb.finetune(intel.clone(), "arm", &idx)?;
    let (asx, asy) = wb.prim_standardizers("arm")?;
    let tuned_pred = Predictor::new(&wb.rt, "nn2", tuned, asx.clone(), asy.clone())?;
    let md_tuned = mdrae_all(&tuned_pred.predict_raw(&xs)?, &targets);

    // 4) native full-data reference
    println!("training native ARM model for reference...");
    let native = wb.nn2_params("arm")?;
    let native_pred = Predictor::new(&wb.rt, "nn2", native, asx, asy)?;
    let md_native = mdrae_all(&native_pred.predict_raw(&xs)?, &targets);

    let mut t = Table::new(
        "Intel -> ARM transfer: MdRAE on the ARM test set",
        &["approach", "target data used", "MdRAE"],
    );
    t.row(vec!["Intel model, direct".into(), "none".into(), format!("{:.0}%", md_direct * 100.0)]);
    t.row(vec![
        "Intel + factor correction".into(),
        "1% (scale only)".into(),
        format!("{:.0}%", md_factor * 100.0),
    ]);
    t.row(vec![
        "Intel + fine-tune (lr/10)".into(),
        "1%".into(),
        format!("{:.1}%", md_tuned * 100.0),
    ]);
    t.row(vec![
        "native ARM (all data)".into(),
        "100%".into(),
        format!("{:.1}%", md_native * 100.0),
    ]);
    println!("{}", t.render());
    println!("expected shape (paper fig 8/9): direct >> factor > fine-tune > native");
    Ok(())
}

//! Transfer-learning walkthrough (paper §4.4 / Figure 9): pre-train on
//! Intel, then adapt to ARM with a small calibration sample — entirely
//! through the [`CostModel`] layer.
//!
//! Part 1 runs offline (no PJRT): a pure-Rust `LinCostModel` is trained
//! on Intel simulator data, factor-corrected to ARM from ~1% of samples,
//! and onboarded into a `Coordinator` as a served platform with
//! validation against profiled-optimal selections.
//!
//! Part 2 needs `make artifacts`: the Intel NN2 model is applied to ARM
//! directly, factor-corrected, fine-tuned on 1% of ARM data (lr/10), and
//! compared against native training — the paper's Figure 8/9 shape.
//!
//! Run: `cargo run --release --example transfer_to_arm`

use primsel::coordinator::{Coordinator, OnboardSpec};
use primsel::dataset;
use primsel::experiments::Workbench;
use primsel::networks;
use primsel::perfmodel::metrics::mdrae_all;
use primsel::perfmodel::model::CostModel;
use primsel::perfmodel::transfer::prim_factors;
use primsel::perfmodel::LinCostModel;
use primsel::report::Table;
use primsel::runtime::Runtime;
use primsel::selection::CostSource;
use primsel::simulator::{machine, Simulator};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    offline_lin_transfer()?;
    match Runtime::open_default() {
        Ok(rt) => nn2_transfer(rt)?,
        Err(e) => {
            println!("\nskipping the NN2 (PJRT) transfer walkthrough: {e}");
            println!("run `make artifacts` to enable it");
        }
    }
    Ok(())
}

/// Part 1 — the serving story, fully offline: Lin source model on Intel,
/// §4.4 factor correction to ARM, coordinator onboarding + validation.
fn offline_lin_transfer() -> anyhow::Result<()> {
    println!("[offline] training LinCostModel on Intel simulator data...");
    let intel = Simulator::new(machine::intel_i9_9900k());
    let (prim, dlt) = dataset::calibration_sample(&intel, 0.80, 1);
    let source_model: Arc<dyn CostModel + Send + Sync> =
        Arc::new(LinCostModel::fit(&prim, &dlt, "intel")?);

    let arm: Arc<dyn CostSource> = Arc::new(Simulator::new(machine::arm_cortex_a73()));
    let coord = Coordinator::new();
    println!("[offline] onboarding \"arm-lin\" from 1% ARM calibration samples...");
    let report = coord.onboard_platform(
        "arm-lin",
        OnboardSpec::transfer(Arc::clone(&arm), source_model, 0.01, 7)
            .with_validation(networks::selection_networks()),
    )?;

    let mut t = Table::new(
        &format!(
            "onboarded {} ({}, {} calib samples) — predicted vs simulated",
            report.platform, report.model_kind, report.calib_samples
        ),
        &["network", "predicted ms", "simulated ms", "profiled ms", "increase", "agreement"],
    );
    for v in &report.validation {
        t.row(vec![
            v.network.clone(),
            format!("{:.2}", v.predicted_ms),
            format!("{:.2}", v.simulated_ms),
            format!("{:.2}", v.profiled_ms),
            format!("{:.2}%", v.increase * 100.0),
            format!("{:.0}%", v.agreement * 100.0),
        ]);
    }
    println!("{}", t.render());

    let path = coord.persist_table("arm-lin", &networks::selection_networks())?;
    println!("[offline] dense serving table persisted to {}", path.display());
    Ok(())
}

/// Part 2 — the paper's NN2 figure-8/9 comparison over PJRT.
fn nn2_transfer(rt: Runtime) -> anyhow::Result<()> {
    let mut wb = Workbench::new(rt);
    wb.max_epochs = 120; // walkthrough speed

    println!("\npre-training the Intel NN2 model (cached if already trained)...");
    let intel = wb.nn2_params("intel")?;
    let (cfgs, targets) = wb.prim_test_set("arm")?;
    let cal = {
        let pd = wb.platform("arm")?;
        let idx = dataset::fraction(&pd.prim_split.train, 0.01, 7);
        pd.prim.subset(&idx)
    };

    // 1+2) direct application, then factor correction from 1% of ARM
    // profiles — one built model serves both evaluations
    let (md_direct, md_factor) = {
        let inputs = wb.xla_model_inputs_from(intel.clone(), "intel", "arm")?;
        let model = inputs.build(&wb.rt)?;
        let md_direct = mdrae_all(&model.predict_prim(&cfgs)?, &targets);
        let factors = prim_factors(&model, &cal)?;
        let model = model.with_prim_factors(factors, cal.len());
        (md_direct, mdrae_all(&model.predict_prim(&cfgs)?, &targets))
    };

    // 3) fine-tune on 1% of ARM data (lr/10, same AOT artifacts)
    println!("fine-tuning on 1% of ARM profiles...");
    let idx = {
        let pd = wb.platform("arm")?;
        dataset::fraction(&pd.prim_split.train, 0.01, 7)
    };
    let tuned = wb.finetune(intel.clone(), "arm", &idx)?;
    let md_tuned = {
        let inputs = wb.xla_model_inputs_from(tuned, "arm", "arm")?;
        let model = inputs.build(&wb.rt)?;
        mdrae_all(&model.predict_prim(&cfgs)?, &targets)
    };

    // 4) native full-data reference
    println!("training native ARM model for reference...");
    let inputs = wb.xla_model_inputs("arm")?;
    let native = inputs.build(&wb.rt)?;
    let md_native = mdrae_all(&native.predict_prim(&cfgs)?, &targets);

    let mut t = Table::new(
        "Intel -> ARM transfer: MdRAE on the ARM test set",
        &["approach", "target data used", "MdRAE"],
    );
    t.row(vec!["Intel model, direct".into(), "none".into(), format!("{:.0}%", md_direct * 100.0)]);
    t.row(vec![
        "Intel + factor correction".into(),
        "1% (scale only)".into(),
        format!("{:.0}%", md_factor * 100.0),
    ]);
    t.row(vec![
        "Intel + fine-tune (lr/10)".into(),
        "1%".into(),
        format!("{:.1}%", md_tuned * 100.0),
    ]);
    t.row(vec![
        "native ARM (all data)".into(),
        "100%".into(),
        format!("{:.1}%", md_native * 100.0),
    ]);
    println!("{}", t.render());
    println!("expected shape (paper fig 8/9): direct >> factor > fine-tune > native");
    Ok(())
}

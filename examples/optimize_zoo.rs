//! Optimise every network in the zoo on one platform (Table-4 style
//! sweep): model-driven selection time vs simulated profiling time, plus
//! the achieved speedup over naive single-family baselines.
//!
//! Run: `cargo run --release --example optimize_zoo [-- platform]`

use primsel::experiments::Workbench;
use primsel::networks;
use primsel::perfmodel::model::model_table;
use primsel::primitives::Family;
use primsel::report::{fmt_time_ms, Table};
use primsel::runtime::Runtime;
use primsel::selection::{self, CostCache};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let platform = std::env::args().nth(1).unwrap_or_else(|| "intel".into());
    let rt = Runtime::open_default()?;
    let mut wb = Workbench::new(rt);

    let inputs = wb.xla_model_inputs(&platform)?;
    let sim = wb.platform(&platform)?.sim.clone();
    let model = inputs.build(&wb.rt)?;

    let mut t = Table::new(
        &format!("zoo optimisation on {platform}"),
        &["network", "layers", "model+PBQP", "profiling (sim)", "speedup", "vs all-im2"],
    );
    // one cost cache across the whole zoo: repeated layer shapes are
    // profiled once, and evaluation reuses the profiling sweep's rows
    let measured = CostCache::new(&sim);
    for net in networks::zoo() {
        let _ = model_table(&net, &model)?; // warm executables
        let t0 = Instant::now();
        let source = model_table(&net, &model)?;
        let sel = selection::select(&net, &source)?;
        let opt_ms = t0.elapsed().as_secs_f64() * 1e3;

        let profiling_ms = measured.network_profiling_wallclock_ms(&net);
        let t_sel = selection::evaluate(&net, &sel, &measured)?;
        let base = selection::single_family_baseline(&net, &measured, Family::Im2)?;
        t.row(vec![
            net.name.clone(),
            net.n_layers().to_string(),
            fmt_time_ms(opt_ms),
            fmt_time_ms(profiling_ms),
            format!("{:.0}x", profiling_ms / opt_ms),
            format!("{:.2}x faster", base.estimated_ms / t_sel),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

//! Drive the admission-controlled selection service: one shared
//! [`Coordinator`], a bounded admission queue, a deficit-weighted fair
//! scheduler and a persistent worker pool, serving two tenants of
//! *unequal weight* concurrently:
//!
//! * `batch-sweep` (weight 1) floods the whole zoo x three platforms —
//!   plus a few TASO-style memory-budget requests — through
//!   non-blocking admission, so queue-full rejections show up as
//!   backpressure instead of unbounded buffering;
//! * `interactive` (weight 4) submits a small latency-sensitive batch
//!   through blocking admission and gets its reports while the sweep's
//!   backlog is still queued — the fairness guarantee, visible.
//!
//! Runs entirely on the simulator substrate — no AOT artifacts needed —
//! and ends with the full `ServiceStats` printout: per-tenant
//! admitted/rejected/served, p50/p95 wait and service latency, and the
//! per-platform cache hit rates that make the second pass of the same
//! traffic nearly free.
//!
//! Run: `cargo run --release --example serve_zoo`

use primsel::coordinator::{Coordinator, Objective, SelectionRequest};
use primsel::networks;
use primsel::report::{fmt_time_ms, Table};
use primsel::service::{Service, ServiceConfig, SubmitError, Ticket};

fn main() -> anyhow::Result<()> {
    let platforms = ["intel", "amd", "arm"];
    let service = Service::new(
        Coordinator::shared(),
        // a deliberately small admission queue so the sweep's flood can
        // actually bounce off it
        ServiceConfig::default().with_capacity(12),
    );
    service.register_tenant("batch-sweep", 1.0, 4)?;
    service.register_tenant("interactive", 4.0, 4)?;

    // the flood: every selection network on every platform, plus one
    // memory-constrained VGG-16 request per platform
    let mut sweep_reqs = Vec::new();
    for net in networks::selection_networks() {
        for p in platforms {
            sweep_reqs.push(SelectionRequest::new(net.clone(), p));
        }
    }
    for p in platforms {
        sweep_reqs.push(SelectionRequest::new(networks::vgg(16), p).with_objective(
            Objective::MinTimeWithMemoryBudget {
                budget_bytes: 8.0 * 1024.0 * 1024.0,
                lambda_ms_per_mb: 5.0,
            },
        ));
    }

    // non-blocking admission: whatever bounces (QueueFull) is retried
    // once with blocking admission afterwards — nothing is lost, but the
    // rejections are real and show up in the stats
    let mut sweep_tickets: Vec<Ticket> = Vec::new();
    let mut retry = Vec::new();
    for req in sweep_reqs {
        match service.try_submit("batch-sweep", req.clone()) {
            Ok(t) => sweep_tickets.push(t),
            Err(SubmitError::QueueFull) => retry.push(req),
            Err(e) => return Err(anyhow::anyhow!("sweep admission failed: {e}")),
        }
    }

    // the interactive tenant arrives while the sweep backlog is queued
    let interactive: Vec<Ticket> = ["alexnet", "vgg11", "googlenet", "resnet18"]
        .iter()
        .filter_map(|name| networks::by_name(name))
        .map(|net| service.submit("interactive", SelectionRequest::new(net, "intel")))
        .collect::<Result<_, _>>()
        .map_err(|e| anyhow::anyhow!("interactive admission failed: {e}"))?;

    let mut t = Table::new(
        "interactive tenant — served ahead of the batch-sweep backlog (4x weight)",
        &["network", "platform", "est time", "peak ws (MiB)", "request wall"],
    );
    for ticket in interactive {
        let r = ticket.wait()?;
        t.row(vec![
            r.network.clone(),
            r.platform.clone(),
            fmt_time_ms(r.evaluated_ms),
            format!("{:.1}", r.peak_workspace_bytes / (1024.0 * 1024.0)),
            fmt_time_ms(r.wall_ms),
        ]);
    }
    println!("{}", t.render());
    let mid = service.stats();
    let sweep_row = mid.tenants.iter().find(|t| t.tenant == "batch-sweep");
    println!(
        "interactive done; batch-sweep at that moment: {} queued, {} rejected so far\n",
        sweep_row.map_or(0, |t| t.queued),
        sweep_row.map_or(0, |t| t.rejected),
    );

    // retry the bounced sweep requests with blocking admission, then
    // drain the whole sweep
    for req in retry {
        sweep_tickets.push(
            service
                .submit("batch-sweep", req)
                .map_err(|e| anyhow::anyhow!("sweep retry failed: {e}"))?,
        );
    }
    let mut sweep_total_ms = 0.0;
    let n_sweep = sweep_tickets.len();
    for ticket in sweep_tickets {
        sweep_total_ms += ticket.wait()?.evaluated_ms;
    }
    println!(
        "batch-sweep drained: {n_sweep} requests, {:.1} ms total estimated network time\n",
        sweep_total_ms
    );

    // the instruments: rejected counts, p50/p95 wait & service latency,
    // per-platform cache hit rates
    println!("{}", service.stats().render());
    service.shutdown();
    Ok(())
}

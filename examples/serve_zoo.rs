//! Drive the admission-controlled selection service: one shared
//! [`Coordinator`], a bounded admission queue, a deficit-weighted fair
//! scheduler and a persistent worker pool, serving two tenants of
//! *unequal weight* concurrently:
//!
//! * `batch-sweep` (weight 1) floods the whole zoo x three platforms —
//!   plus a few TASO-style memory-budget requests — through
//!   non-blocking admission, so queue-full rejections show up as
//!   backpressure instead of unbounded buffering;
//! * `interactive` (weight 4) submits a small latency-sensitive batch
//!   through blocking admission and gets its reports while the sweep's
//!   backlog is still queued — the fairness guarantee, visible.
//!
//! Runs entirely on the simulator substrate — no AOT artifacts needed —
//! then demos budget queries answered from the cached time×space Pareto
//! front (`FastestUnderBytes` / `SmallestWithinPct`), and ends with the
//! full `ServiceStats` printout: per-tenant admitted/rejected/served,
//! p50/p95 wait and service latency, and the per-platform cache hit
//! rates that make the second pass of the same traffic nearly free.
//!
//! Run: `cargo run --release --example serve_zoo`
//!
//! With `--metrics`, serves a small mixed workload and dumps the
//! unified telemetry (Prometheus exposition + JSON snapshot, delimited
//! by `=== metrics: ... ===` markers) — the mode
//! `python/tools/check_metrics.py` validates in CI.
//!
//! With `--inject-faults`, runs the self-healing demo instead: a
//! transfer-onboarded platform over a seeded [`FaultySource`] is driven
//! through drift → automatic recalibration → repeated recalibration
//! failure → quarantine (typed refusals) → cool-down probe readmission,
//! ending with the health section of the `ServiceStats` printout.
//!
//! Two ops-plane flags compose with either mode: `--dashboard` brings
//! up the background sampler + burn-rate SLOs and prints the rolling
//! time-series report (sparklines, alert table, recorder drop counts);
//! `--timeline <path>` exports the flight recorder as Chrome
//! trace-event JSON — load it in Perfetto or `chrome://tracing`.
//! `python/tools/check_timeline.py` validates
//! `--inject-faults --dashboard --timeline results/timeline.json` in CI.

use primsel::coordinator::{Coordinator, Objective, OnboardSpec, SelectionRequest};
use primsel::dataset::calibration_sample;
use primsel::health::{HealthPolicy, HealthState, PlatformHealth, QuarantinedError};
use primsel::networks::{self, Network};
use primsel::obs::SloSpec;
use primsel::perfmodel::{CostModel, LinCostModel};
use primsel::report::{fmt_time_ms, Table};
use primsel::selection::{CostSource, FaultySource};
use primsel::service::{Service, ServiceConfig, SubmitError, Ticket};
use primsel::simulator::{machine, Simulator};
use std::sync::Arc;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let dashboard = args.iter().any(|a| a == "--dashboard");
    let timeline = args
        .iter()
        .position(|a| a == "--timeline")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let result = if args.iter().any(|a| a == "--metrics") {
        metrics_demo()
    } else if args.iter().any(|a| a == "--inject-faults") {
        inject_faults_demo(dashboard)
    } else {
        serve_demo(dashboard)
    };
    result?;
    if let Some(path) = timeline {
        primsel::obs::write_chrome_trace(
            primsel::obs::flight_recorder(),
            std::path::Path::new(&path),
        )?;
        println!("chrome trace written to {path} (load in Perfetto / chrome://tracing)");
    }
    Ok(())
}

/// The demo SLO suite: a latency objective on the end-to-end stage, an
/// admission error budget, queue pressure, and (when `platform` is
/// monitored) a drift band whose Critical alerts nudge the platform's
/// health monitor into shadow-sampling early. Windows are shrunk far
/// below the production defaults so burn rates move within a demo run.
fn demo_slos(config: ServiceConfig, drift_platform: Option<&str>) -> ServiceConfig {
    let windows = |s: SloSpec| s.with_windows(Duration::from_millis(200), Duration::from_secs(2));
    let mut config = config
        .with_sampling(Duration::from_millis(25))
        .with_slo(windows(SloSpec::latency_p95("e2e-latency", "e2e", 50.0)))
        .with_slo(windows(SloSpec::error_rate("admission-errors", 0.05)))
        .with_slo(windows(SloSpec::queue_depth("queue-pressure", 0.8)));
    if let Some(p) = drift_platform {
        config = config
            .with_slo(windows(SloSpec::drift(&format!("{p}-drift"), p, 0.75)).with_nudge(16));
    }
    config
}

/// With `--dashboard`: force a final sampler tick and print the rolling
/// ops report — series sparklines, SLO alert states, recorder counts.
fn print_dashboard(service: &Service) {
    service.ops_tick();
    if let Some(report) = service.ops_report() {
        println!("{}", report.render());
    }
}

/// `--metrics`: serve a small mixed-tenant workload, then dump the
/// unified telemetry — the Prometheus exposition and the JSON snapshot
/// of the process metrics registry, delimited by `=== metrics: ... ===`
/// markers so `python/tools/check_metrics.py` can split and validate
/// them — followed by the flight recorder's tables. The ops plane runs
/// here too, so the SLO / series / drop-count metric families are part
/// of the validated exposition.
fn metrics_demo() -> anyhow::Result<()> {
    let coord = Coordinator::shared();
    // monitor one platform so the health gauges have a row to publish
    let target: Arc<dyn CostSource> =
        Arc::new(Simulator::new(machine::intel_i9_9900k()));
    coord.monitor_platform("intel", target, HealthPolicy::default().with_sampling(0.25, 11))?;
    let service = Service::new(
        Arc::clone(&coord),
        demo_slos(
            ServiceConfig::default().with_capacity(16).with_workers(2),
            Some("intel"),
        ),
    );
    service.register_tenant("interactive", 4.0, 2)?;
    service.register_tenant("batch", 1.0, 2)?;

    let nets = networks::selection_networks();
    let platforms = ["intel", "arm"];
    let mut tickets = Vec::new();
    for i in 0..12 {
        let tenant = if i % 2 == 0 { "interactive" } else { "batch" };
        let req =
            SelectionRequest::new(nets[i % nets.len()].clone(), platforms[i % platforms.len()]);
        tickets.push(
            service
                .submit(tenant, req)
                .map_err(|e| anyhow::anyhow!("admission failed: {e}"))?,
        );
    }
    for t in tickets {
        t.wait()?;
    }
    // one budget query so the Pareto-front cache has traffic too
    let req = SelectionRequest::new(networks::vgg(16), "intel").with_objective(
        Objective::FastestUnderBytes { budget_bytes: 8.0 * 1024.0 * 1024.0 },
    );
    coord.submit(&req)?;

    // one forced tick publishes the SLO / series families into the
    // registry before the exposition is rendered
    service.ops_tick();
    let reg = service.metrics();
    println!("=== metrics: prometheus ===");
    print!("{}", reg.render_prometheus());
    println!("=== metrics: json ===");
    println!("{}", reg.snapshot_json().dump());
    println!("=== metrics: end ===");
    println!("\n{}", primsel::obs::flight_recorder().render());
    service.shutdown();
    Ok(())
}

/// Serve requests at `platform` until `done(health)` holds. Refused
/// tickets still resolve (typed errors) — expected while quarantined.
fn drive_until(
    service: &Service,
    platform: &str,
    net: &Network,
    done: impl Fn(&PlatformHealth) -> bool,
) -> anyhow::Result<u32> {
    for n in 1..=80 {
        let ticket = service
            .submit("ops", SelectionRequest::new(net.clone(), platform))
            .map_err(|e| anyhow::anyhow!("admission failed: {e}"))?;
        let _ = ticket.wait();
        let health = service
            .coordinator()
            .platform_health_of(platform)
            .ok_or_else(|| anyhow::anyhow!("{platform} is not monitored"))?;
        if done(&health) {
            return Ok(n);
        }
    }
    anyhow::bail!("demo did not reach the expected health state within 80 requests")
}

fn inject_faults_demo(dashboard: bool) -> anyhow::Result<()> {
    // the "live device": an ARM simulator wrapped in seeded fault
    // injection, serving as both calibration target and replay target
    let faulty = Arc::new(FaultySource::new(
        Arc::new(Simulator::new(machine::arm_cortex_a73())),
        42,
    ));
    let target: Arc<dyn CostSource> = Arc::clone(&faulty);

    let coord = Coordinator::shared();
    let intel = Simulator::new(machine::intel_i9_9900k());
    let (prim, dlt) = calibration_sample(&intel, 0.1, 3);
    let source: Arc<dyn CostModel + Send + Sync> =
        Arc::new(LinCostModel::fit(&prim, &dlt, "intel")?);
    coord.onboard_platform(
        "arm-live",
        OnboardSpec::transfer(Arc::clone(&target), source, 0.02, 5),
    )?;
    coord.monitor_platform(
        "arm-live",
        target,
        HealthPolicy::default()
            .with_sampling(1.0, 7)
            .with_window(24, 8)
            .with_drift_band(0.75)
            .with_quarantine(2, Duration::ZERO, Duration::from_millis(100)),
    )?;
    let mut config = ServiceConfig::default().with_workers(2);
    if dashboard {
        // drift SLO over the same 0.75 band as the health policy: the
        // injected 3x / 9x drifts burn it Critical, and the nudge pulls
        // the monitor's shadow sampling forward
        config = demo_slos(config, Some("arm-live"));
    }
    let service = Service::new(Arc::clone(&coord), config);
    let net = networks::alexnet();

    // phase 1 — healthy traffic: live replays agree with the served model
    for _ in 0..3 {
        let ticket = service
            .submit("ops", SelectionRequest::new(net.clone(), "arm-live"))
            .map_err(|e| anyhow::anyhow!("admission failed: {e}"))?;
        ticket.wait()?;
    }
    let h = coord.platform_health_of("arm-live").unwrap();
    println!("phase 1 — healthy: state {}, drift {:.3}\n", h.state, h.drift);

    // phase 2 — the device drifts 3x: detection, then automatic repair
    faulty.set_drift(3.0);
    let n = drive_until(&service, "arm-live", &net, |h| h.state == HealthState::Drifting)?;
    println!("phase 2 — drift 3.0 injected: Drifting after {n} requests");
    let n = drive_until(&service, "arm-live", &net, |h| h.recalibrations >= 1)?;
    let h = coord.platform_health_of("arm-live").unwrap();
    println!(
        "          auto-recalibrated after {n} more: state {}, drift {:.3}\n",
        h.state, h.drift
    );

    // phase 3 — drift again, but now every target query panics:
    // recalibration attempts burn out and the platform quarantines
    faulty.set_drift(9.0);
    drive_until(&service, "arm-live", &net, |h| h.state == HealthState::Drifting)?;
    faulty.set_error_rate(1.0);
    drive_until(&service, "arm-live", &net, |h| h.state == HealthState::Quarantined)?;
    let refused = service
        .submit("ops", SelectionRequest::new(net.clone(), "arm-live"))
        .map_err(|e| anyhow::anyhow!("admission failed: {e}"))?;
    match refused.wait() {
        Err(e) => {
            let q = e
                .downcast_ref::<QuarantinedError>()
                .ok_or_else(|| anyhow::anyhow!("refusal was not the typed error: {e}"))?;
            println!("phase 3 — errors injected: quarantined, tickets refuse with:");
            println!("          {q}\n");
        }
        Ok(_) => anyhow::bail!("expected a quarantined refusal"),
    }

    // phase 4 — fault cleared: after the cool-down the next admission
    // probes a recalibration and the platform readmits
    faulty.set_error_rate(0.0);
    std::thread::sleep(Duration::from_millis(150));
    let ticket = service
        .submit("ops", SelectionRequest::new(net, "arm-live"))
        .map_err(|e| anyhow::anyhow!("admission failed: {e}"))?;
    let report = ticket.wait()?;
    let h = coord.platform_health_of("arm-live").unwrap();
    println!(
        "phase 4 — probe readmission: served {} in {}, state {}\n",
        report.network,
        fmt_time_ms(report.wall_ms),
        h.state
    );

    // the instruments, health table included
    println!("{}", service.stats().render());
    // the same story as structured telemetry: every health transition
    // and recalibration outcome the demo drove, straight from the
    // flight recorder, plus the health gauges the registry publishes
    println!("{}", primsel::obs::flight_recorder().render());
    service.metrics();
    print!("{}", primsel::obs::registry().render_prometheus());
    if dashboard {
        print_dashboard(&service);
    }
    service.shutdown();
    Ok(())
}

fn serve_demo(dashboard: bool) -> anyhow::Result<()> {
    let platforms = ["intel", "amd", "arm"];
    // a deliberately small admission queue so the sweep's flood can
    // actually bounce off it
    let mut config = ServiceConfig::default().with_capacity(12);
    if dashboard {
        config = demo_slos(config, None);
    }
    let service = Service::new(Coordinator::shared(), config);
    service.register_tenant("batch-sweep", 1.0, 4)?;
    service.register_tenant("interactive", 4.0, 4)?;

    // the flood: every selection network on every platform, plus one
    // memory-constrained VGG-16 request per platform
    let mut sweep_reqs = Vec::new();
    for net in networks::selection_networks() {
        for p in platforms {
            sweep_reqs.push(SelectionRequest::new(net.clone(), p));
        }
    }
    for p in platforms {
        sweep_reqs.push(SelectionRequest::new(networks::vgg(16), p).with_objective(
            Objective::MinTimeWithMemoryBudget {
                budget_bytes: 8.0 * 1024.0 * 1024.0,
                lambda_ms_per_mb: 5.0,
            },
        ));
    }

    // non-blocking admission: whatever bounces (QueueFull) is retried
    // once with blocking admission afterwards — nothing is lost, but the
    // rejections are real and show up in the stats
    let mut sweep_tickets: Vec<Ticket> = Vec::new();
    let mut retry = Vec::new();
    for req in sweep_reqs {
        match service.try_submit("batch-sweep", req.clone()) {
            Ok(t) => sweep_tickets.push(t),
            Err(SubmitError::QueueFull) => retry.push(req),
            Err(e) => return Err(anyhow::anyhow!("sweep admission failed: {e}")),
        }
    }

    // the interactive tenant arrives while the sweep backlog is queued
    let interactive: Vec<Ticket> = ["alexnet", "vgg11", "googlenet", "resnet18"]
        .iter()
        .filter_map(|name| networks::by_name(name))
        .map(|net| service.submit("interactive", SelectionRequest::new(net, "intel")))
        .collect::<Result<_, _>>()
        .map_err(|e| anyhow::anyhow!("interactive admission failed: {e}"))?;

    let mut t = Table::new(
        "interactive tenant — served ahead of the batch-sweep backlog (4x weight)",
        &["network", "platform", "est time", "peak ws (MiB)", "request wall"],
    );
    for ticket in interactive {
        let r = ticket.wait()?;
        t.row(vec![
            r.network.clone(),
            r.platform.clone(),
            fmt_time_ms(r.evaluated_ms),
            format!("{:.1}", r.peak_workspace_bytes / (1024.0 * 1024.0)),
            fmt_time_ms(r.wall_ms),
        ]);
    }
    println!("{}", t.render());
    let mid = service.stats();
    let sweep_row = mid.tenants.iter().find(|t| t.tenant == "batch-sweep");
    println!(
        "interactive done; batch-sweep at that moment: {} queued, {} rejected so far\n",
        sweep_row.map_or(0, |t| t.queued),
        sweep_row.map_or(0, |t| t.rejected),
    );

    // retry the bounced sweep requests with blocking admission, then
    // drain the whole sweep
    for req in retry {
        sweep_tickets.push(
            service
                .submit("batch-sweep", req)
                .map_err(|e| anyhow::anyhow!("sweep retry failed: {e}"))?,
        );
    }
    let mut sweep_total_ms = 0.0;
    let n_sweep = sweep_tickets.len();
    for ticket in sweep_tickets {
        sweep_total_ms += ticket.wait()?.evaluated_ms;
    }
    println!(
        "batch-sweep drained: {n_sweep} requests, {:.1} ms total estimated network time\n",
        sweep_total_ms
    );

    // budget queries ride the cached time×space Pareto front: the first
    // one sweeps and caches the (vgg16, intel) front, the rest are pure
    // lookups — zero PBQP solves, visible in the "front cached" column
    let coord = service.coordinator();
    let mut t = Table::new(
        "vgg16 on intel — budget queries answered from the Pareto front",
        &["objective", "peak ws (MiB)", "true time", "front cached"],
    );
    for mib in [1.0, 4.0, 16.0] {
        let req = SelectionRequest::new(networks::vgg(16), "intel").with_objective(
            Objective::FastestUnderBytes { budget_bytes: mib * 1024.0 * 1024.0 },
        );
        let f = coord.submit(&req)?.front.expect("front-served objective");
        t.row(vec![
            format!("fastest under {mib:.0} MiB"),
            format!("{:.1}", f.peak_workspace_bytes / (1024.0 * 1024.0)),
            fmt_time_ms(f.true_time_ms),
            format!("{}", f.cache_hit),
        ]);
    }
    let req = SelectionRequest::new(networks::vgg(16), "intel")
        .with_objective(Objective::SmallestWithinPct { pct_of_optimal_time: 5.0 });
    let f = coord.submit(&req)?.front.expect("front-served objective");
    t.row(vec![
        "smallest within +5% of optimal".into(),
        format!("{:.1}", f.peak_workspace_bytes / (1024.0 * 1024.0)),
        fmt_time_ms(f.true_time_ms),
        format!("{}", f.cache_hit),
    ]);
    println!("{}", t.render());

    // the instruments: rejected counts, p50/p95 wait & service latency,
    // per-platform cache hit rates
    println!("{}", service.stats().render());
    if dashboard {
        print_dashboard(&service);
    }
    service.shutdown();
    Ok(())
}

//! Drive the multi-tenant selection service: one [`Coordinator`], three
//! platforms, a batch of concurrent mixed-network requests (plus a few
//! memory-constrained tenants) served from shared warm cost caches.
//!
//! Runs entirely on the simulator substrate — no AOT artifacts needed —
//! and prints the cold-vs-warm batch wall-clock next to the per-platform
//! cache hit rates, which is the whole economic argument for sharding
//! the cache: the second batch of the same traffic is nearly free.
//!
//! Run: `cargo run --release --example serve_zoo`

use primsel::coordinator::{Coordinator, Objective, SelectionRequest};
use primsel::networks;
use primsel::report::{fmt_pct, fmt_time_ms, Table};

fn main() -> anyhow::Result<()> {
    let platforms = ["intel", "amd", "arm"];
    let coord = Coordinator::new();

    // the traffic: every selection network on every platform, plus one
    // memory-constrained VGG-16 tenant per platform riding the same batch
    let mut reqs = Vec::new();
    for net in networks::selection_networks() {
        for p in platforms {
            reqs.push(SelectionRequest::new(net.clone(), p));
        }
    }
    for p in platforms {
        reqs.push(SelectionRequest::new(networks::vgg(16), p).with_objective(
            Objective::MinTimeWithMemoryBudget {
                budget_bytes: 8.0 * 1024.0 * 1024.0,
                lambda_ms_per_mb: 5.0,
            },
        ));
    }

    let cold = coord.submit_batch(&reqs)?;
    let warm = coord.submit_batch(&reqs)?;

    let mut t = Table::new(
        "serve_zoo — one warm-batch report per request",
        &["network", "platform", "objective", "est time", "peak ws (MiB)", "request wall"],
    );
    for r in &warm.reports {
        t.row(vec![
            r.network.clone(),
            r.platform.clone(),
            r.objective.tag(),
            fmt_time_ms(r.evaluated_ms),
            format!("{:.1}", r.peak_workspace_bytes / (1024.0 * 1024.0)),
            fmt_time_ms(r.wall_ms),
        ]);
    }
    println!("{}", t.render());

    let mut s = Table::new(
        "cache trajectory — cold batch vs warm batch",
        &["platform", "cold hit rate", "cold misses", "warm hit rate", "warm misses"],
    );
    for ((p, c), (_, w)) in cold.stats.iter().zip(&warm.stats) {
        s.row(vec![
            p.clone(),
            fmt_pct(c.hit_rate()),
            c.misses().to_string(),
            fmt_pct(w.hit_rate()),
            w.misses().to_string(),
        ]);
    }
    println!("{}", s.render());
    println!(
        "batch wall-clock: cold {} -> warm {} ({} requests, {} platforms)",
        fmt_time_ms(cold.wall_ms),
        fmt_time_ms(warm.wall_ms),
        reqs.len(),
        platforms.len(),
    );
    Ok(())
}

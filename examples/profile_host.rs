//! Profile the real Pallas primitive kernels on this host via PJRT
//! (median of 25 runs, paper §4.1.1) and check that the *measured*
//! family ranking agrees qualitatively with the simulator's cost model
//! (the grounding argument of ARCHITECTURE.md).
//!
//! Run: `cargo run --release --example profile_host [-- runs]`

use primsel::layers::ConvConfig;
use primsel::primitives::catalog;
use primsel::profiler;
use primsel::report::Table;
use primsel::runtime::Runtime;
use primsel::simulator::{machine, Simulator};

fn main() -> anyhow::Result<()> {
    let runs: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(25);
    let rt = Runtime::open_default()?;
    println!(
        "profiling {} kernels x {} runs (real execution, interpret-mode Pallas on CPU)...",
        rt.manifest.prim_grid.len(),
        runs
    );
    let ms = profiler::profile_grid(&rt, runs)?;

    let mut t = Table::new(
        "host kernel profile",
        &["kernel", "config (c,im,k,f,s)", "median ms", "min..max", "GFLOP/s"],
    );
    for m in &ms {
        t.row(vec![
            m.kernel.clone(),
            format!("({},{},{},{},{})", m.c, m.im, m.k, m.f, m.s),
            format!("{:.3}", m.median_ms),
            format!("{:.3}..{:.3}", m.min_ms, m.max_ms),
            format!("{:.2}", m.gflops()),
        ]);
    }
    println!("{}", t.render());

    // rank agreement: for each config with >= 4 measured kernels, compare
    // the measured fastest family against the simulator's fastest family
    let sim = Simulator::noiseless(machine::intel_i9_9900k());
    let mut agree = 0;
    let mut total = 0;
    let mut by_cfg: std::collections::BTreeMap<(u32, u32, u32, u32, u32), Vec<&profiler::Measurement>> =
        Default::default();
    for m in &ms {
        by_cfg.entry((m.c, m.im, m.k, m.f, m.s)).or_default().push(m);
    }
    for ((c, im, k, f, s), group) in by_cfg {
        if group.len() < 4 {
            continue;
        }
        let cfg = ConvConfig::new(k, c, im, s, f);
        let measured_best = &group
            .iter()
            .min_by(|a, b| a.median_ms.partial_cmp(&b.median_ms).unwrap())
            .unwrap()
            .kernel;
        let sim_row = sim.profile_layer(&cfg);
        let sim_best = sim_row
            .iter()
            .enumerate()
            .filter_map(|(i, t)| t.map(|t| (i, t)))
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .map(|(i, _)| catalog()[i].kernel_id.to_string())
            .unwrap_or_default();
        total += 1;
        // agreement at kernel-family granularity
        let fam = |k: &str| k.split('_').next().unwrap_or(k).to_string();
        if fam(measured_best) == fam(&sim_best) {
            agree += 1;
        }
        println!(
            "cfg ({c},{im},{k},{f},{s}): measured-best {measured_best}, simulator-best {sim_best}"
        );
    }
    if total > 0 {
        println!("\nfamily-rank agreement: {agree}/{total}");
    }
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/host_profile.csv", t.to_csv())?;
    Ok(())
}

//! PJRT runtime benchmarks: the paper's Table 4 "Perf. Model Inf." column
//! lives or dies on predict latency; train_step throughput bounds the
//! experiment-suite wall-clock. Requires `make artifacts`.

mod harness;

use harness::Bench;
use primsel::perfmodel::params::init_params;
use primsel::runtime::{literal_f32, scalar_f32, Runtime};

fn main() {
    let Ok(rt) = Runtime::open_default() else {
        eprintln!("artifacts missing — run `make artifacts` first");
        return;
    };
    let mut b = Bench::new();

    for kind in ["nn1", "nn2"] {
        let spec = rt.manifest.models[kind].clone();
        let params = init_params(&rt, &spec, 1).unwrap();

        for bsz in [rt.manifest.predict_batches.0, rt.manifest.predict_batches.1] {
            let exe = rt.load(&spec.files[&format!("predict_b{bsz}")]).unwrap();
            let x = literal_f32(
                &vec![0.1f32; bsz * spec.in_dim],
                &[bsz as i64, spec.in_dim as i64],
            )
            .unwrap();
            let mut inputs = Vec::new();
            params.push_literals(&mut inputs).unwrap();
            inputs.push(x);
            b.run(&format!("runtime/predict_{kind}_b{bsz}"), 3, 50, || {
                let _ = rt.execute(&exe, &inputs).unwrap();
            });
        }

        // one Adam step at the training batch size
        let exe = rt.load(&spec.files["train_step"]).unwrap();
        let bsz = spec.train_batch;
        let mut inputs = Vec::new();
        params.push_literals(&mut inputs).unwrap();
        let zeros = primsel::perfmodel::ParamStore::zeros_like(&spec);
        zeros.push_literals(&mut inputs).unwrap();
        zeros.push_literals(&mut inputs).unwrap();
        inputs.push(scalar_f32(0.0));
        inputs.push(
            literal_f32(&vec![0.1f32; bsz * spec.in_dim], &[bsz as i64, spec.in_dim as i64])
                .unwrap(),
        );
        inputs.push(
            literal_f32(&vec![0.0f32; bsz * spec.out_dim], &[bsz as i64, spec.out_dim as i64])
                .unwrap(),
        );
        inputs.push(
            literal_f32(&vec![1.0f32; bsz * spec.out_dim], &[bsz as i64, spec.out_dim as i64])
                .unwrap(),
        );
        inputs.push(scalar_f32(1e-3));
        inputs.push(scalar_f32(0.0));
        b.run(&format!("runtime/train_step_{kind}_b{bsz}"), 2, 20, || {
            let _ = rt.execute(&exe, &inputs).unwrap();
        });
    }

    // artifact compile cost (cold load): parse + compile one kernel module
    if let Some(e) = rt.manifest.prim_grid.first().cloned() {
        b.run("runtime/compile_kernel_artifact", 0, 5, || {
            let fresh = Runtime::open_default().unwrap();
            let _ = fresh.load(&e.file).unwrap();
        });
    }

    b.finish("runtime");
}

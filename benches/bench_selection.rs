//! End-to-end selection benchmarks (Table 4's headline comparison): the
//! full model-driven pipeline — batched PJRT prediction + PBQP — per
//! network, against the simulated profiling wall-clock it replaces.
//! Requires `make artifacts` and trained models (runs training on first
//! use; cached under artifacts/trained/).

mod harness;

use harness::Bench;
use primsel::experiments::{model_source, Workbench};
use primsel::networks;
use primsel::perfmodel::predictor::DltPredictor;
use primsel::perfmodel::Predictor;
use primsel::runtime::Runtime;
use primsel::selection;

fn main() {
    let Ok(rt) = Runtime::open_default() else {
        eprintln!("artifacts missing — run `make artifacts` first");
        return;
    };
    let mut wb = Workbench::new(rt);
    wb.max_epochs = 60; // enough for a usable model if not cached yet

    let nn2 = wb.nn2_params("intel").unwrap();
    let dltp = wb.dlt_nn2_params("intel").unwrap();
    let (sx, sy) = wb.prim_standardizers("intel").unwrap();
    let (dx, dy) = wb.dlt_standardizers("intel").unwrap();
    let sim = wb.platform("intel").unwrap().sim.clone();
    let prim = Predictor::new(&wb.rt, "nn2", nn2, sx, sy).unwrap();
    let dlt = DltPredictor::new(&wb.rt, "dlt_nn2", dltp, dx, dy).unwrap();

    let mut b = Bench::new();
    for net in networks::selection_networks() {
        let _ = model_source(&net, &prim, &dlt).unwrap(); // warm executables
        b.run(&format!("selection/model_pipeline_{}", net.name), 1, 10, || {
            let source = model_source(&net, &prim, &dlt).unwrap();
            let _ = selection::select(&net, &source).unwrap();
        });
        b.run(&format!("selection/profiled_{}", net.name), 1, 10, || {
            let _ = selection::select(&net, &sim).unwrap();
        });
        // the thing the model replaces: exhaustive profiling wall-clock
        let profiling_ms: f64 = net
            .layers
            .iter()
            .map(|cfg| sim.profiling_wallclock_ms(cfg))
            .sum();
        println!(
            "selection/simulated_profiling_{:<24} would take {profiling_ms:>12.1} ms on-device",
            net.name
        );
    }
    b.finish("selection");
}

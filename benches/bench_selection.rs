//! End-to-end selection benchmarks (Table 4's headline comparison).
//!
//! Two tiers:
//! * `profiled_*` rows need only the simulator and always run — they are
//!   the cost-query-engine trajectory (`select()` cold, `select()` over a
//!   shared cache, `select()` over a precomputed dense table).
//! * `model_pipeline_*` rows drive batched PJRT prediction + PBQP and
//!   require `make artifacts` plus trained models (training runs on first
//!   use; cached under artifacts/trained/).

mod harness;

use harness::Bench;
use primsel::coordinator::{Coordinator, SelectionRequest};
use primsel::experiments::{model_source, Workbench};
use primsel::networks;
use primsel::par;
use primsel::perfmodel::predictor::DltPredictor;
use primsel::perfmodel::Predictor;
use primsel::runtime::Runtime;
use primsel::selection::{self, CostCache};
use primsel::simulator::{machine, Simulator};

fn main() {
    let mut b = Bench::new();
    let sim = Simulator::new(machine::intel_i9_9900k());
    let nets = networks::selection_networks();

    // --- simulator-backed tier (always runs) ---
    for net in &nets {
        // cold: every call profiles the network from scratch (through a
        // fresh per-call cache) and solves
        b.run(&format!("selection/profiled_{}", net.name), 1, 10, || {
            let _ = selection::select(net, &sim).unwrap();
        });
    }
    // end-to-end select() over the whole six-network zoo, cold cache
    b.run("selection/profiled_zoo_total", 1, 10, || {
        for net in &nets {
            let _ = selection::select(net, &sim).unwrap();
        }
    });
    // warm: one cost cache shared across the zoo (the deployment shape —
    // profile once, re-select per deployment)
    b.run("selection/profiled_zoo_total_shared_cache", 1, 10, || {
        let cache = CostCache::new(&sim);
        for net in &nets {
            let _ = selection::select(net, &cache).unwrap();
        }
    });
    // steady state: dense per-network tables precomputed, select() is
    // pure table lookups + PBQP
    {
        let cache = CostCache::new(&sim);
        let tables: Vec<_> = nets.iter().map(|n| cache.table_for(n)).collect();
        b.run("selection/table_zoo_total", 2, 20, || {
            for (net, table) in nets.iter().zip(&tables) {
                let _ = selection::select(net, table).unwrap();
            }
        });
    }
    // multi-tenant serving shape: one warm shared cache. Uncontended =
    // one thread re-selecting the zoo; contended = every worker doing
    // that same zoo sweep concurrently against the same cache, so the
    // delta between the rows is pure lock/sharing overhead per tenant.
    {
        let cache = CostCache::new(&sim);
        for net in &nets {
            let _ = selection::select(net, &cache).unwrap(); // warm rows
        }
        b.run("selection/shared_cache_uncontended", 1, 10, || {
            for net in &nets {
                let _ = selection::select(net, &cache).unwrap();
            }
        });
        let tenants: Vec<usize> = (0..par::workers().clamp(2, 8)).collect();
        println!("selection/shared_cache_contended: {} concurrent tenants", tenants.len());
        b.run("selection/shared_cache_contended", 1, 10, || {
            par::par_map_coarse(&tenants, |_| {
                for net in &nets {
                    let _ = selection::select(net, &cache).unwrap();
                }
            });
        });
    }
    // the coordinator end-to-end: a mixed three-platform zoo batch
    {
        let coord = Coordinator::new();
        let reqs: Vec<SelectionRequest> = ["intel", "amd", "arm"]
            .iter()
            .flat_map(|p| nets.iter().map(|n| SelectionRequest::new(n.clone(), p)))
            .collect();
        let _ = coord.submit_batch(&reqs).unwrap(); // warm all three caches
        println!("selection/coordinator_batch: {} mixed requests", reqs.len());
        b.run("selection/coordinator_batch", 1, 10, || {
            let _ = coord.submit_batch(&reqs).unwrap();
        });
    }
    // the thing the model replaces: exhaustive profiling wall-clock
    {
        let cache = CostCache::new(&sim);
        for net in &nets {
            let profiling_ms = cache.network_profiling_wallclock_ms(net);
            println!(
                "selection/simulated_profiling_{:<24} would take {profiling_ms:>12.1} ms on-device",
                net.name
            );
        }
    }

    // --- PJRT-backed tier (skipped without artifacts; a failure here
    // must not discard the simulator-tier rows above) ---
    if let Err(e) = model_pipeline_tier(&mut b, &nets) {
        eprintln!("skipping model_pipeline benches ({e}) — run `make artifacts` first");
    }

    b.finish("selection");
}

fn model_pipeline_tier(
    b: &mut Bench,
    nets: &[networks::Network],
) -> Result<(), Box<dyn std::error::Error>> {
    let rt = Runtime::open_default().map_err(|e| e.to_string())?;
    let mut wb = Workbench::new(rt);
    wb.max_epochs = 60; // enough for a usable model if not cached yet

    let nn2 = wb.nn2_params("intel").map_err(|e| e.to_string())?;
    let dltp = wb.dlt_nn2_params("intel").map_err(|e| e.to_string())?;
    let (sx, sy) = wb.prim_standardizers("intel").map_err(|e| e.to_string())?;
    let (dx, dy) = wb.dlt_standardizers("intel").map_err(|e| e.to_string())?;
    let prim = Predictor::new(&wb.rt, "nn2", nn2, sx, sy).map_err(|e| e.to_string())?;
    let dlt = DltPredictor::new(&wb.rt, "dlt_nn2", dltp, dx, dy).map_err(|e| e.to_string())?;

    for net in nets {
        let _ = model_source(net, &prim, &dlt).map_err(|e| e.to_string())?; // warm executables
        b.run(&format!("selection/model_pipeline_{}", net.name), 1, 10, || {
            let source = model_source(net, &prim, &dlt).unwrap();
            let _ = selection::select(net, &source).unwrap();
        });
    }
    Ok(())
}

//! End-to-end selection benchmarks (Table 4's headline comparison).
//!
//! Two tiers:
//! * `profiled_*` rows need only the simulator and always run — they are
//!   the cost-query-engine trajectory (`select()` cold, `select()` over a
//!   shared cache, `select()` over a precomputed dense table).
//! * `model_pipeline_*` rows drive batched PJRT prediction + PBQP and
//!   require `make artifacts` plus trained models (training runs on first
//!   use; cached under artifacts/trained/).

mod harness;

use harness::Bench;
use primsel::coordinator::{Coordinator, Objective, OnboardSpec, ReportDetail, SelectionRequest};
use primsel::dataset;
use primsel::experiments::Workbench;
use primsel::networks;
use primsel::obs::{self, Sampler, SamplerConfig, SystemClock};
use primsel::par;
use primsel::perfmodel::model::model_table;
use primsel::perfmodel::LinCostModel;
use primsel::runtime::Runtime;
use primsel::selection::pareto::DEFAULT_LAMBDA_MS_PER_MB;
use primsel::selection::{self, CostCache, CostSource, ModeledSource, ParetoFront};
use primsel::service::{Service, ServiceConfig};
use primsel::simulator::{machine, Simulator};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

fn main() {
    let mut b = Bench::new();
    let sim = Simulator::new(machine::intel_i9_9900k());
    let nets = networks::selection_networks();

    // --- simulator-backed tier (always runs) ---
    for net in &nets {
        // cold: every call profiles the network from scratch (through a
        // fresh per-call cache) and solves
        b.run(&format!("selection/profiled_{}", net.name), 1, 10, || {
            let _ = selection::select(net, &sim).unwrap();
        });
    }
    // end-to-end select() over the whole six-network zoo, cold cache
    b.run("selection/profiled_zoo_total", 1, 10, || {
        for net in &nets {
            let _ = selection::select(net, &sim).unwrap();
        }
    });
    // warm: one cost cache shared across the zoo (the deployment shape —
    // profile once, re-select per deployment)
    b.run("selection/profiled_zoo_total_shared_cache", 1, 10, || {
        let cache = CostCache::new(&sim);
        for net in &nets {
            let _ = selection::select(net, &cache).unwrap();
        }
    });
    // steady state: dense per-network tables precomputed, select() is
    // pure table lookups + PBQP
    {
        let cache = CostCache::new(&sim);
        let tables: Vec<_> = nets.iter().map(|n| cache.table_for(n)).collect();
        b.run("selection/table_zoo_total", 2, 20, || {
            for (net, table) in nets.iter().zip(&tables) {
                let _ = selection::select(net, table).unwrap();
            }
        });
    }
    // multi-tenant serving shape: one warm shared cache. Uncontended =
    // one thread re-selecting the zoo; contended = every worker doing
    // that same zoo sweep concurrently against the same cache, so the
    // delta between the rows is pure lock/sharing overhead per tenant.
    {
        let cache = CostCache::new(&sim);
        for net in &nets {
            let _ = selection::select(net, &cache).unwrap(); // warm rows
        }
        b.run("selection/shared_cache_uncontended", 1, 10, || {
            for net in &nets {
                let _ = selection::select(net, &cache).unwrap();
            }
        });
        let tenants: Vec<usize> = (0..par::workers().clamp(2, 8)).collect();
        println!("selection/shared_cache_contended: {} concurrent tenants", tenants.len());
        b.run("selection/shared_cache_contended", 1, 10, || {
            par::par_map_coarse(&tenants, |_| {
                for net in &nets {
                    let _ = selection::select(net, &cache).unwrap();
                }
            });
        });
    }
    // the Pareto tentpole: one full budget sweep over a warm cache —
    // the acceptance pair (vgg16, intel) — exercising the reused PBQP
    // arena across every distinct workspace level
    {
        let cache = CostCache::new(&sim);
        let net = networks::vgg(16);
        let _ = selection::select(&net, &cache).unwrap(); // warm rows
        b.run("selection/pareto_front_sweep", 1, 10, || {
            let _ = ParetoFront::compute(&net, &cache, DEFAULT_LAMBDA_MS_PER_MB).unwrap();
        });
    }
    // warm front serving: budget queries answered from the coordinator's
    // cached front — zero PBQP solves per request, so this row is pure
    // lookup + report-assembly overhead
    {
        let coord = Coordinator::new();
        let req = SelectionRequest::new(networks::vgg(16), "intel").with_objective(
            Objective::FastestUnderBytes { budget_bytes: f64::INFINITY },
        );
        let _ = coord.submit(&req).unwrap(); // compute + cache the front
        b.run("selection/pareto_warm_lookup", 10, 100, || {
            let _ = coord.submit(&req).unwrap();
        });
    }
    // the compiled-plan tentpole pair: `cold` re-builds the PBQP graph
    // and elimination template from the (already warm) cost cache every
    // call — the per-request price before plans; `warm_plan` answers the
    // same request through the coordinator's plan cache with Minimal
    // detail — one flat arena solve, zero construction, zero cache
    // lookups, zero steady-state allocation. The gate prints the
    // warm/cold ratio (acceptance: >= 5x).
    {
        let coord = Coordinator::new();
        let net = networks::vgg(16);
        let req = SelectionRequest::new(net.clone(), "intel")
            .with_detail(ReportDetail::Minimal);
        let _ = coord.select_one(&req).unwrap(); // compile + cache the plan
        b.run("selection/select_one_warm_plan", 10, 100, || {
            let _ = coord.select_one(&req).unwrap();
        });
        // the same warm solve with full telemetry live: a per-request
        // trace, stage-histogram records, and a flight-recorder capture.
        // The gate fails if this row drifts more than 5% off warm_plan —
        // observability must stay effectively free
        let traced = req.clone().with_trace();
        let _ = coord.select_one(&traced).unwrap();
        b.run("selection/select_one_warm_instrumented", 10, 100, || {
            let _ = coord.select_one(&traced).unwrap();
        });
        // the instrumented row again, but with the ops-plane sampler
        // live: a background thread snapshotting the whole registry into
        // its series rings at ~1 ms cadence (40x the production 25 ms
        // demo cadence) while the traced selects run. The gate holds
        // this row to the same 5% envelope around warm_plan — the
        // time-series layer must not tax the hot path
        {
            let sampler = Arc::new(Sampler::new(SamplerConfig::default().with_capacity(256)));
            let clock = Arc::new(SystemClock::new());
            sampler.sample(obs::registry(), &*clock); // prime the rings
            let stop = Arc::new(AtomicBool::new(false));
            let thread = {
                let (sampler, clock, stop) =
                    (Arc::clone(&sampler), Arc::clone(&clock), Arc::clone(&stop));
                std::thread::spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        sampler.sample(obs::registry(), &*clock);
                        std::thread::sleep(std::time::Duration::from_millis(1));
                    }
                })
            };
            b.run("selection/select_one_warm_sampled", 10, 100, || {
                let _ = coord.select_one(&traced).unwrap();
            });
            stop.store(true, Ordering::Relaxed);
            thread.join().unwrap();
            println!(
                "selection/select_one_warm_sampled: {} sampler ticks during row",
                sampler.ticks()
            );
        }
        let cache = coord.cache("intel").unwrap();
        b.run("selection/select_one_cold", 1, 10, || {
            let _ = selection::select(&net, cache.as_ref()).unwrap();
        });
    }
    // the coordinator end-to-end: a mixed three-platform zoo batch
    {
        let coord = Coordinator::new();
        let reqs: Vec<SelectionRequest> = ["intel", "amd", "arm"]
            .iter()
            .flat_map(|p| nets.iter().map(|n| SelectionRequest::new(n.clone(), p)))
            .collect();
        let _ = coord.submit_batch(&reqs).unwrap(); // warm all three caches
        println!("selection/coordinator_batch: {} mixed requests", reqs.len());
        b.run("selection/coordinator_batch", 1, 10, || {
            let _ = coord.submit_batch(&reqs).unwrap();
        });
    }
    // the admission-controlled service end-to-end: the same mixed
    // three-platform zoo batch as coordinator_batch, but through the
    // bounded queue + fair scheduler + persistent worker pool — the
    // delta between the rows is the serving layer's overhead
    {
        let service = Service::new(
            Coordinator::shared(),
            ServiceConfig::default()
                .with_capacity(1024)
                .with_workers(par::workers().clamp(2, 8)),
        );
        service.register_tenant("bench", 1.0, usize::MAX).unwrap();
        let reqs: Vec<SelectionRequest> = ["intel", "amd", "arm"]
            .iter()
            .flat_map(|p| nets.iter().map(|n| SelectionRequest::new(n.clone(), p)))
            .collect();
        let submit_all = |tenant: &str, reqs: &[SelectionRequest]| {
            let tickets: Vec<_> = reqs
                .iter()
                .map(|r| service.submit(tenant, r.clone()).unwrap())
                .collect();
            for t in tickets {
                let _ = t.wait().unwrap();
            }
        };
        submit_all("bench", &reqs); // warm the caches
        b.run("selection/service_throughput", 1, 10, || submit_all("bench", &reqs));

        // fairness shape: a weight-1 flood plus a weight-8 interactive
        // tenant riding the same queue — the row tracks the *combined*
        // drain time, so a scheduler regression that serialises tenants
        // (or starves one) moves it
        service.register_tenant("bench-heavy", 1.0, usize::MAX).unwrap();
        service.register_tenant("bench-light", 8.0, usize::MAX).unwrap();
        let light_reqs: Vec<SelectionRequest> = (0..6)
            .map(|_| SelectionRequest::new(networks::alexnet(), "intel"))
            .collect();
        b.run("selection/service_fairness", 1, 10, || {
            let heavy: Vec<_> = reqs
                .iter()
                .map(|r| service.submit("bench-heavy", r.clone()).unwrap())
                .collect();
            let light: Vec<_> = light_reqs
                .iter()
                .map(|r| service.submit("bench-light", r.clone()).unwrap())
                .collect();
            for t in light {
                let _ = t.wait().unwrap();
            }
            for t in heavy {
                let _ = t.wait().unwrap();
            }
        });
        service.shutdown();
    }
    // model-served selection, no PJRT: a Lin model trained offline on
    // intel simulator data answers through ModeledSource (per-call cache
    // wraps it), vs the profiled_zoo_total row above — the modeled-vs-
    // simulated sweep comparison
    {
        let (prim, dlt) = dataset::calibration_sample(&sim, 0.10, 17);
        let lin = LinCostModel::fit(&prim, &dlt, "intel").unwrap();
        let modeled = ModeledSource::new(Arc::new(lin));
        b.run("selection/modeled_source_zoo", 1, 10, || {
            for net in &nets {
                let _ = selection::select(net, &modeled).unwrap();
            }
        });
    }
    // cold platform onboarding: calibration draw + Lin fit + register
    // (no validation) — the "new device shows up" hot path
    {
        let coord = Coordinator::new();
        let target: Arc<dyn CostSource> =
            Arc::new(Simulator::new(machine::arm_cortex_a73()));
        b.run("selection/onboard_platform_cold", 1, 10, || {
            let spec = OnboardSpec::fresh_lin(Arc::clone(&target), 0.02, 7);
            let _ = coord.onboard_platform("arm-lin-bench", spec).unwrap();
        });
    }
    // the thing the model replaces: exhaustive profiling wall-clock
    {
        let cache = CostCache::new(&sim);
        for net in &nets {
            let profiling_ms = cache.network_profiling_wallclock_ms(net);
            println!(
                "selection/simulated_profiling_{:<24} would take {profiling_ms:>12.1} ms on-device",
                net.name
            );
        }
    }

    // --- PJRT-backed tier (skipped without artifacts; a failure here
    // must not discard the simulator-tier rows above) ---
    if let Err(e) = model_pipeline_tier(&mut b, &nets) {
        eprintln!("skipping model_pipeline benches ({e}) — run `make artifacts` first");
    }

    b.finish("selection");
}

fn model_pipeline_tier(
    b: &mut Bench,
    nets: &[networks::Network],
) -> Result<(), Box<dyn std::error::Error>> {
    let rt = Runtime::open_default().map_err(|e| e.to_string())?;
    let mut wb = Workbench::new(rt);
    wb.max_epochs = 60; // enough for a usable model if not cached yet

    let inputs = wb.xla_model_inputs("intel").map_err(|e| e.to_string())?;
    let model = inputs.build(&wb.rt).map_err(|e| e.to_string())?;

    for net in nets {
        let _ = model_table(net, &model).map_err(|e| e.to_string())?; // warm executables
        b.run(&format!("selection/model_pipeline_{}", net.name), 1, 10, || {
            let source = model_table(net, &model).unwrap();
            let _ = selection::select(net, &source).unwrap();
        });
    }
    Ok(())
}

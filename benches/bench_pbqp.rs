//! PBQP solver benchmarks — the paper's claim that the solver stage is
//! sub-second even for large networks (§2.1, Table 4 includes it).

mod harness;

use harness::Bench;
use primsel::networks;
use primsel::pbqp;
use primsel::selection;
use primsel::simulator::{machine, Simulator};

fn main() {
    let mut b = Bench::new();
    let sim = Simulator::new(machine::intel_i9_9900k());

    // synthetic chains (VGG-like) of growing length
    for n in [8usize, 64, 256, 1024] {
        let mut rng = primsel::simulator::noise::SplitMix64::new(n as u64);
        let node_costs: Vec<Vec<f64>> =
            (0..n).map(|_| (0..31).map(|_| rng.next_f64() * 10.0).collect()).collect();
        let mut g = pbqp::Graph::new(node_costs);
        for u in 0..n - 1 {
            let cost: Vec<f64> = (0..31 * 31).map(|_| rng.next_f64()).collect();
            g.add_edge(u, u + 1, cost);
        }
        b.run(&format!("pbqp/chain_{n}x31"), 2, 10, || {
            let _ = pbqp::solve(&g);
        });
    }

    // the six selection networks (real graph shapes incl. inception fan-out)
    for net in networks::selection_networks() {
        let prob = selection::build_problem(&net, &sim).unwrap();
        b.run(&format!("pbqp/{}", net.name), 2, 20, || {
            let _ = pbqp::solve(&prob.graph);
        });
    }

    // densenet201: the highest-degree graph in the zoo
    let net = networks::densenet(201);
    let prob = selection::build_problem(&net, &sim).unwrap();
    b.run("pbqp/densenet201", 1, 10, || {
        let _ = pbqp::solve(&prob.graph);
    });

    b.finish("pbqp");
}

//! Platform-simulator benchmarks: the substrate must be fast enough that
//! "profiling" three platforms over ~6k configurations is interactive
//! (it stands in for hours of device time — Table 4's right columns).

mod harness;

use harness::Bench;
use primsel::dataset;
use primsel::layers::ConvConfig;
use primsel::selection::CostCache;
use primsel::simulator::{machine, Simulator};

fn main() {
    let mut b = Bench::new();
    let sims: Vec<Simulator> = machine::all().into_iter().map(Simulator::new).collect();
    let cfg = ConvConfig::new(256, 256, 28, 1, 3);

    for sim in &sims {
        b.run(&format!("simulator/layer_row_{}", sim.name()), 10, 200, || {
            let _ = sim.profile_layer(&cfg);
        });
    }

    // the cost-query engine's steady state: repeat queries are hash hits
    {
        let cache = CostCache::new(&sims[0]);
        let _ = cache.row(&cfg);
        b.run("simulator/layer_row_cached_intel", 10, 200, || {
            let _ = cache.row(&cfg);
        });
        let _ = cache.matrix(256, 28);
        b.run("simulator/dlt_matrix_cached_intel", 10, 200, || {
            let _ = cache.matrix(256, 28);
        });
    }

    let configs = dataset::enumerate_configs(dataset::MAX_CONFIGS, 1);
    b.run("simulator/enumerate_configs", 1, 10, || {
        let _ = dataset::enumerate_configs(dataset::MAX_CONFIGS, 1);
    });
    b.run(
        &format!("simulator/full_dataset_{}_configs", configs.len()),
        1,
        5,
        || {
            let _ = dataset::profile_prim_dataset(&sims[0], &configs);
        },
    );

    let pairs = dataset::dlt_pairs(&configs);
    b.run(&format!("simulator/dlt_dataset_{}_pairs", pairs.len()), 1, 10, || {
        let _ = dataset::profile_dlt_dataset(&sims[0], &pairs);
    });

    b.finish("simulator");
}

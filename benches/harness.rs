//! Tiny bench harness (criterion is not available offline): warms up,
//! runs timed iterations, prints median/mean/min like criterion's summary
//! line, and writes a CSV row per benchmark to results/bench.csv.

use std::time::Instant;

pub struct Bench {
    rows: Vec<(String, f64, f64, f64)>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Self {
        Self { rows: Vec::new() }
    }

    /// Time `f` for `iters` iterations after `warmup` runs; report ms.
    pub fn run<F: FnMut()>(&mut self, name: &str, warmup: usize, iters: usize, mut f: F) {
        for _ in 0..warmup {
            f();
        }
        let mut times = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = times[times.len() / 2];
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let min = times[0];
        println!("{name:<44} median {median:>10.4} ms  mean {mean:>10.4} ms  min {min:>10.4} ms  ({iters} iters)");
        self.rows.push((name.to_string(), median, mean, min));
    }

    /// Append results to results/bench.csv.
    pub fn finish(&self, suite: &str) {
        std::fs::create_dir_all("results").ok();
        let mut out = String::from("suite,name,median_ms,mean_ms,min_ms\n");
        for (name, med, mean, min) in &self.rows {
            out.push_str(&format!("{suite},{name},{med},{mean},{min}\n"));
        }
        let path = format!("results/bench_{suite}.csv");
        std::fs::write(path, out).ok();
    }
}

//! Tiny bench harness (criterion is not available offline): warms up,
//! runs timed iterations, prints median/mean/min like criterion's summary
//! line, and writes the results to `results/bench_<suite>.csv` plus a
//! machine-readable `results/bench_<suite>.json` so the BENCH_* perf
//! trajectory can be tracked across PRs.

use primsel::config::Json;
use std::collections::BTreeMap;
use std::time::Instant;

pub struct Bench {
    rows: Vec<(String, f64, f64, f64)>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Self {
        Self { rows: Vec::new() }
    }

    /// Time `f` for `iters` iterations after `warmup` runs; report ms.
    pub fn run<F: FnMut()>(&mut self, name: &str, warmup: usize, iters: usize, mut f: F) {
        for _ in 0..warmup {
            f();
        }
        let mut times = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = times[times.len() / 2];
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let min = times[0];
        println!("{name:<44} median {median:>10.4} ms  mean {mean:>10.4} ms  min {min:>10.4} ms  ({iters} iters)");
        self.rows.push((name.to_string(), median, mean, min));
    }

    /// Write results/bench_<suite>.csv and results/bench_<suite>.json.
    pub fn finish(&self, suite: &str) {
        std::fs::create_dir_all("results").ok();

        let mut out = String::from("suite,name,median_ms,mean_ms,min_ms\n");
        for (name, med, mean, min) in &self.rows {
            out.push_str(&format!("{suite},{name},{med},{mean},{min}\n"));
        }
        std::fs::write(format!("results/bench_{suite}.csv"), out).ok();

        let benches: Vec<Json> = self
            .rows
            .iter()
            .map(|(name, med, mean, min)| {
                let mut m = BTreeMap::new();
                m.insert("name".to_string(), Json::Str(name.clone()));
                m.insert("median_ms".to_string(), Json::Num(*med));
                m.insert("mean_ms".to_string(), Json::Num(*mean));
                m.insert("min_ms".to_string(), Json::Num(*min));
                Json::Obj(m)
            })
            .collect();
        let mut root = BTreeMap::new();
        root.insert("suite".to_string(), Json::Str(suite.to_string()));
        root.insert("benches".to_string(), Json::Arr(benches));
        std::fs::write(format!("results/bench_{suite}.json"), Json::Obj(root).dump()).ok();
    }
}

//! Offline in-tree stub of the `xla_extension` PJRT bindings.
//!
//! The build environment has no registry and no libxla, so this crate
//! mirrors the API surface `primsel::runtime` uses. [`Literal`] is a real
//! host-side tensor container (so literal construction, reshape and
//! round-trips work and their tests pass); everything PJRT-backed —
//! [`PjRtClient::cpu`] onward — returns [`Error::BackendUnavailable`],
//! which `Runtime::open_default().ok()` turns into a graceful skip in
//! every artifact-dependent test, bench and experiment.
//!
//! Swap back to the real bindings with
//! `xla = { package = "xla_extension", version = "0.5.1" }`.

use std::fmt;
use std::path::PathBuf;

/// Error type matching the real crate's role (Display-able, wrapped by
/// `primsel::runtime::wrap` into anyhow).
#[derive(Debug)]
pub enum Error {
    BackendUnavailable(&'static str),
    ShapeMismatch { expected: usize, got: usize },
    NotATuple,
    WrongElementType,
    Io(PathBuf),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::BackendUnavailable(what) => write!(
                f,
                "{what}: PJRT backend unavailable (offline xla stub; link xla_extension for real execution)"
            ),
            Error::ShapeMismatch { expected, got } => {
                write!(f, "shape mismatch: expected {expected} elements, got {got}")
            }
            Error::NotATuple => write!(f, "literal is not a tuple"),
            Error::WrongElementType => write!(f, "literal element type mismatch"),
            Error::Io(p) => write!(f, "cannot read {p:?}"),
        }
    }
}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types a [`Literal`] can hold (subset used by primsel).
pub trait NativeType: Copy + Sized {
    fn wrap(data: Vec<Self>) -> Elements;
    fn unwrap(e: &Elements) -> Result<Vec<Self>>;
}

#[derive(Debug, Clone)]
pub enum Elements {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

impl NativeType for f32 {
    fn wrap(data: Vec<Self>) -> Elements {
        Elements::F32(data)
    }
    fn unwrap(e: &Elements) -> Result<Vec<Self>> {
        match e {
            Elements::F32(v) => Ok(v.clone()),
            _ => Err(Error::WrongElementType),
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<Self>) -> Elements {
        Elements::I32(data)
    }
    fn unwrap(e: &Elements) -> Result<Vec<Self>> {
        match e {
            Elements::I32(v) => Ok(v.clone()),
            _ => Err(Error::WrongElementType),
        }
    }
}

/// A host tensor literal (fully functional in the stub).
#[derive(Debug, Clone)]
pub struct Literal {
    pub dims: Vec<i64>,
    pub elements: Elements,
}

impl Literal {
    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal { dims: vec![], elements: T::wrap(vec![v]) }
    }

    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { dims: vec![data.len() as i64], elements: T::wrap(data.to_vec()) }
    }

    /// Reshape, checking the element count.
    pub fn reshape(self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        let len = self.element_count();
        if n as usize != len {
            return Err(Error::ShapeMismatch { expected: n as usize, got: len });
        }
        Ok(Literal { dims: dims.to_vec(), elements: self.elements })
    }

    pub fn element_count(&self) -> usize {
        match &self.elements {
            Elements::F32(v) => v.len(),
            Elements::I32(v) => v.len(),
            Elements::Tuple(v) => v.len(),
        }
    }

    /// Flatten back to a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.elements)
    }

    /// Decompose a tuple literal.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.elements {
            Elements::Tuple(v) => Ok(v),
            _ => Err(Error::NotATuple),
        }
    }
}

impl AsRef<Literal> for Literal {
    fn as_ref(&self) -> &Literal {
        self
    }
}

/// Parsed HLO module (stub: retains the path only).
pub struct HloModuleProto {
    pub path: String,
}

impl HloModuleProto {
    /// The real binding parses HLO text; the stub only checks readability
    /// so missing-artifact setups fail the same way they would online.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        if std::path::Path::new(path).exists() {
            Ok(HloModuleProto { path: path.to_string() })
        } else {
            Err(Error::Io(PathBuf::from(path)))
        }
    }
}

/// An XLA computation handle.
pub struct XlaComputation {
    pub path: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { path: proto.path.clone() }
    }
}

/// PJRT client handle. `cpu()` always fails in the stub — every caller
/// treats that as "artifacts/backend absent" and skips.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::BackendUnavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::BackendUnavailable("PjRtClient::compile"))
    }
}

/// A compiled executable (unreachable in the stub: no client can exist).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: AsRef<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::BackendUnavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer (unreachable in the stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::BackendUnavailable("PjRtBuffer::to_literal_sync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_reshape_round_trip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(Literal::vec1(&[1.0f32]).reshape(&[3]).is_err());
    }

    #[test]
    fn scalar_types() {
        assert_eq!(Literal::scalar(5i32).to_vec::<i32>().unwrap(), vec![5]);
        assert!(Literal::scalar(5i32).to_vec::<f32>().is_err());
    }

    #[test]
    fn client_is_unavailable() {
        assert!(PjRtClient::cpu().is_err());
    }
}

//! Offline in-tree shim for the `anyhow` crate.
//!
//! The build environment has no crate registry, so this vendored stand-in
//! provides the exact subset primsel uses: [`Error`], [`Result`], the
//! [`anyhow!`] / [`bail!`] / [`ensure!`] macros and the [`Context`]
//! extension trait for `Result` and `Option`. Semantics follow the real
//! crate closely enough to swap back (`anyhow = "1"`) unchanged: `Error`
//! deliberately does *not* implement `std::error::Error`, which is what
//! lets the blanket `From<E: std::error::Error>` conversion exist.

use std::fmt;

/// A dynamic error: a message plus the chain of causes beneath it.
pub struct Error {
    msg: String,
    /// Outermost-context-first chain of underlying causes (strings; the
    /// shim does not retain live source objects).
    chain: Vec<String>,
    /// The original typed error, when the `Error` came from a
    /// `std::error::Error` value — what makes [`Error::downcast_ref`]
    /// work like the real crate's (typed errors such as the serving
    /// layer's `QuarantinedError` survive the anyhow boundary).
    typed: Option<Box<dyn std::any::Any + Send + Sync>>,
}

impl Error {
    /// Construct from anything displayable (the `anyhow!` entry point).
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string(), chain: Vec::new(), typed: None }
    }

    /// Wrap with an outer context message (used by [`Context`]).
    pub fn context<C: fmt::Display>(mut self, c: C) -> Self {
        self.chain.insert(0, std::mem::replace(&mut self.msg, c.to_string()));
        self
    }

    /// Borrow the original typed error, if this `Error` was converted
    /// from a value of type `E` (via `?` or `From`). Mirrors the real
    /// crate's `downcast_ref`, including surviving added [`Context`].
    pub fn downcast_ref<E>(&self) -> Option<&E>
    where
        E: fmt::Display + fmt::Debug + Send + Sync + 'static,
    {
        self.typed.as_ref()?.downcast_ref::<E>()
    }

    /// The cause chain, outermost first (message, then wrapped causes).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        std::iter::once(self.msg.as_str()).chain(self.chain.iter().map(String::as_str))
    }

    /// Root cause message (innermost).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or(&self.msg)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        for cause in &self.chain {
            write!(f, "\n\nCaused by:\n    {cause}")?;
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let msg = e.to_string();
        let mut chain = Vec::new();
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { msg, chain, typed: Some(Box::new(e)) }
    }
}

/// `anyhow::Result<T>` — `Result` defaulting the error to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond))
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*)
        }
    };
}

mod ext {
    use super::Error;
    use std::fmt::Display;

    /// Private dispatch trait: anything convertible to [`Error`] with an
    /// added context message. Implemented for std errors and for `Error`
    /// itself (which is why `Error` must not implement
    /// `std::error::Error`).
    pub trait StdError {
        fn ext_context<C: Display>(self, c: C) -> Error;
    }

    impl<E> StdError for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn ext_context<C: Display>(self, c: C) -> Error {
            Error::from(self).context(c)
        }
    }

    impl StdError for Error {
        fn ext_context<C: Display>(self, c: C) -> Error {
            self.context(c)
        }
    }
}

/// Context-attachment extension for `Result` and `Option`.
pub trait Context<T>: Sized {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: ext::StdError> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T> {
        self.map_err(|e| e.ext_context(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.ext_context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fail() -> Result<()> {
        bail!("boom {}", 42)
    }

    #[test]
    fn macros_and_display() {
        let e = fail().unwrap_err();
        assert_eq!(e.to_string(), "boom 42");
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
    }

    #[test]
    fn ensure_forms() {
        fn check(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert!(check(1).is_ok());
        assert_eq!(check(-1).unwrap_err().to_string(), "x must be positive, got -1");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("7").unwrap(), 7);
        assert!(parse("x").is_err());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::Other, "inner"));
        let e = r.with_context(|| "outer").unwrap_err();
        assert_eq!(e.to_string(), "outer");
        assert_eq!(e.root_cause(), "inner");

        let o: Option<i32> = None;
        assert_eq!(o.context("missing").unwrap_err().to_string(), "missing");
    }

    #[test]
    fn context_on_anyhow_result() {
        let r: Result<()> = Err(anyhow!("inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer");
        assert!(format!("{e:?}").contains("inner"));
    }

    #[derive(Debug, PartialEq)]
    struct Typed(u32);

    impl fmt::Display for Typed {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(f, "typed error {}", self.0)
        }
    }

    impl std::error::Error for Typed {}

    #[test]
    fn downcast_ref_recovers_typed_errors() {
        let e: Error = Typed(7).into();
        assert_eq!(e.downcast_ref::<Typed>(), Some(&Typed(7)));
        // the typed value survives added context (like the real crate)
        let e = e.context("outer");
        assert_eq!(e.to_string(), "outer");
        assert_eq!(e.downcast_ref::<Typed>(), Some(&Typed(7)));
        // message-only errors downcast to nothing
        assert!(anyhow!("plain").downcast_ref::<Typed>().is_none());
    }
}

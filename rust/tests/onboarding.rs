//! End-to-end model-served platform onboarding (the paper's §4.4 flow as
//! a service operation), fully offline — no PJRT anywhere:
//!
//! * fresh-Lin onboarding from ≤ 2% calibration samples must yield zoo
//!   selections whose simulated wallclock is within 10% of
//!   profiled-source selections (the acceptance bound);
//! * an Intel-trained `LinCostModel`, factor-corrected to ARM from ~1%
//!   of samples, must transfer with the same quality and report
//!   chosen-primitive agreement;
//! * an onboarded platform's dense table survives a process "restart"
//!   (persist → reload → register) bit-identically.

use primsel::coordinator::{
    Coordinator, CostProvenance, OnboardSpec, SelectionRequest,
};
use primsel::dataset;
use primsel::networks;
use primsel::perfmodel::model::CostModel;
use primsel::perfmodel::LinCostModel;
use primsel::selection::{CostSource, TableSource};
use primsel::simulator::{machine, Simulator};
use std::sync::Arc;

fn arm_target() -> Arc<dyn CostSource> {
    Arc::new(Simulator::new(machine::arm_cortex_a73()))
}

/// Shared assertions over an onboard report's validation block: the
/// acceptance bound on the zoo aggregate, a looser per-network backstop,
/// and a sanity floor on reported primitive agreement.
fn assert_validation_quality(report: &primsel::coordinator::OnboardReport) {
    assert_eq!(report.validation.len(), networks::selection_networks().len());
    let mut total_model = 0.0;
    let mut total_prof = 0.0;
    let mut agreement_sum = 0.0;
    for v in &report.validation {
        assert!(v.predicted_ms > 0.0, "{}: non-positive prediction", v.network);
        assert!(v.simulated_ms > 0.0 && v.profiled_ms > 0.0, "{}: bad wallclocks", v.network);
        assert!(
            v.increase < 0.25,
            "{}: modeled selection {:.1}% worse than profiled",
            v.network,
            v.increase * 100.0
        );
        assert!((0.0..=1.0).contains(&v.agreement), "{}: bad agreement", v.network);
        total_model += v.simulated_ms;
        total_prof += v.profiled_ms;
        agreement_sum += v.agreement;
    }
    // the acceptance bound: zoo-aggregate simulated wallclock of modeled
    // selections within 10% of the profiled-source selections
    let zoo_increase = total_model / total_prof - 1.0;
    assert!(
        zoo_increase < 0.10,
        "zoo selections {:.2}% worse than profiled (bound: 10%)",
        zoo_increase * 100.0
    );
    // agreement is genuinely reported (not stuck at zero)
    assert!(agreement_sum / report.validation.len() as f64 > 0.1);
}

#[test]
fn fresh_lin_onboarding_serves_zoo_within_10pct() {
    let coord = Coordinator::new();
    let report = coord
        .onboard_platform(
            "arm-lin",
            OnboardSpec::fresh_lin(arm_target(), 0.02, 42)
                .with_validation(networks::selection_networks()),
        )
        .unwrap();

    assert_eq!(report.model_kind, "lin");
    // ≤ 2% of the canonical universe
    let universe = dataset::enumerate_configs(dataset::MAX_CONFIGS, dataset::DATASET_SEED).len();
    assert!(report.calib_samples * 50 <= universe + 50, "{}", report.calib_samples);
    assert_validation_quality(&report);

    // the onboarded platform serves requests with predicted provenance
    let rep = coord
        .submit(&SelectionRequest::new(networks::googlenet(), "arm-lin"))
        .unwrap();
    assert!(matches!(rep.provenance, CostProvenance::Predicted { .. }));
    assert!(rep.evaluated_ms > 0.0);
}

#[test]
fn intel_lin_transfers_to_arm_with_one_percent_calibration() {
    // source model: Lin trained on (a large sample of) Intel simulator
    // data — the "factory-profiled platform" of §4.4
    let intel = Simulator::new(machine::intel_i9_9900k());
    let (prim, dlt) = dataset::calibration_sample(&intel, 0.5, 3);
    let source: Arc<dyn CostModel + Send + Sync> =
        Arc::new(LinCostModel::fit(&prim, &dlt, "intel").unwrap());

    let coord = Coordinator::new();
    let report = coord
        .onboard_platform(
            "arm-transfer",
            OnboardSpec::transfer(arm_target(), source, 0.01, 9)
                .with_validation(networks::selection_networks()),
        )
        .unwrap();

    assert_eq!(report.model_kind, "lin+factor");
    assert!(matches!(
        &report.provenance,
        CostProvenance::Predicted { model_kind, .. } if model_kind == "lin+factor"
    ));
    assert_validation_quality(&report);

    // agreement is surfaced per network (the satellite's reporting
    // requirement): print the table a CI log can eyeball
    for v in &report.validation {
        println!(
            "{:<16} simulated {:>9.2} ms  profiled {:>9.2} ms  (+{:.2}%)  agreement {:.0}%",
            v.network,
            v.simulated_ms,
            v.profiled_ms,
            v.increase * 100.0,
            v.agreement * 100.0
        );
    }
}

#[test]
fn onboarded_table_survives_restart_via_persisted_json() {
    let zoo = networks::selection_networks();
    let coord = Coordinator::new();
    coord
        .onboard_platform("arm-lin", OnboardSpec::fresh_lin(arm_target(), 0.02, 42))
        .unwrap();
    let before: Vec<_> = zoo
        .iter()
        .map(|n| coord.submit(&SelectionRequest::new(n.clone(), "arm-lin")).unwrap())
        .collect();

    // persist under a temp dir so parallel test runs don't collide on
    // artifacts/tables/
    let dir = std::env::temp_dir().join(format!("primsel_tables_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("arm-lin.json");
    coord.persist_table_to("arm-lin", &zoo, &path).unwrap();

    // "restart": a fresh coordinator serving the reloaded table, with
    // the original platform's provenance restored alongside the values
    let provenance = coord.provenance("arm-lin").unwrap();
    let reloaded = TableSource::load_json(&path).unwrap();
    let coord2 = Coordinator::new();
    coord2.register_with_provenance("arm-lin", Arc::new(reloaded), provenance);
    for (net, old) in zoo.iter().zip(&before) {
        let new = coord2.submit(&SelectionRequest::new(net.clone(), "arm-lin")).unwrap();
        assert_eq!(new.selection.primitive, old.selection.primitive);
        assert_eq!(new.selection.estimated_ms, old.selection.estimated_ms);
        assert_eq!(new.evaluated_ms, old.evaluated_ms);
        // the reloaded platform still reports model-predicted costs
        assert_eq!(new.provenance, old.provenance);
        assert!(matches!(new.provenance, CostProvenance::Predicted { .. }));
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn persist_table_writes_the_artifact_path() {
    let coord = Coordinator::new();
    coord
        .onboard_platform("arm-lin-persist", OnboardSpec::fresh_lin(arm_target(), 0.01, 4))
        .unwrap();
    let net = networks::alexnet();
    let path = coord.persist_table("arm-lin-persist", std::slice::from_ref(&net)).unwrap();
    assert_eq!(path, dataset::table_artifact_path("arm-lin-persist"));
    assert!(path.exists());
    let table = TableSource::load_json(&path).unwrap();
    // the reloaded table answers exactly what the served cache answers
    let cache = coord.cache("arm-lin-persist").unwrap();
    for cfg in &net.layers {
        assert_eq!(table.layer_costs(cfg).as_ref(), cache.row(cfg).as_ref());
    }
    std::fs::remove_file(&path).ok();
}

//! End-to-end observability: traces ride real served requests in stage
//! order, the registry stays exact under concurrent hammering, the
//! flight recorder's keep-slowest retention holds under contention, and
//! a forced quarantine is visible as registry gauges *and* structured
//! flight-recorder events — the full telemetry path the serving stack
//! promises, driven through the public surface only.

use primsel::config::Json;
use primsel::coordinator::{Coordinator, OnboardSpec, SelectionRequest};
use primsel::dataset::calibration_sample;
use primsel::health::{HealthPolicy, HealthState};
use primsel::networks;
use primsel::obs::{self, FlightRecorder, RecordKind, Registry, Stage, Trace};
use primsel::perfmodel::model::CostModel;
use primsel::perfmodel::LinCostModel;
use primsel::selection::{CostSource, FaultySource};
use primsel::service::{Service, ServiceConfig};
use primsel::simulator::{machine, Simulator};
use std::sync::Arc;
use std::time::Duration;

#[test]
fn service_reports_carry_ordered_traces() {
    let service = Service::new(
        Coordinator::shared(),
        ServiceConfig::default().with_capacity(8).with_workers(2),
    );
    let mut tickets = Vec::new();
    for i in 0..6 {
        let net = if i % 2 == 0 { networks::alexnet() } else { networks::vgg(11) };
        tickets.push(service.submit("trace-test", SelectionRequest::new(net, "intel")).unwrap());
    }
    // every served report carries a trace with the full stage ladder,
    // monotone in pipeline order
    let order = [
        Stage::Admit,
        Stage::Dispatch,
        Stage::SolveStart,
        Stage::PlanReady,
        Stage::Solved,
        Stage::SolveEnd,
        Stage::Done,
    ];
    for ticket in tickets {
        let report = ticket.wait().unwrap();
        let trace = report.trace.expect("service-served reports carry a trace");
        let mut prev = 0u64;
        for stage in order {
            let ns = trace
                .stage_ns(stage)
                .unwrap_or_else(|| panic!("stage {stage:?} was never marked"));
            assert!(ns >= prev, "stage {stage:?} at {ns} ns precedes its predecessor at {prev}");
            prev = ns;
        }
        let admit = trace.stage_ns(Stage::Admit).unwrap();
        let done = trace.stage_ns(Stage::Done).unwrap();
        assert_eq!(trace.total_ns(), done - admit);
    }
    // the worker path fed the per-stage histograms and the recorder
    let text = service.metrics().render_prometheus();
    for stage in ["queue", "solve", "e2e"] {
        assert!(
            text.contains(&format!("primsel_trace_stage_ms_count{{stage=\"{stage}\"}}")),
            "missing stage={stage} histogram in:\n{text}"
        );
    }
    assert!(obs::flight_recorder().requests_recorded() >= 6);
    service.shutdown();
}

#[test]
fn registry_counts_exactly_under_concurrent_hammering() {
    let reg = Registry::new();
    let shared = reg.counter("obs.test.shared", &[]);
    let hist = reg.histogram("obs.test.ms", &[]);
    std::thread::scope(|s| {
        for t in 0..8u64 {
            let shared = shared.clone();
            let hist = hist.clone();
            let reg = &reg;
            s.spawn(move || {
                // per-thread registration races the other threads' reads
                let label = t.to_string();
                let own = reg.counter("obs.test.per_thread", &[("t", label.as_str())]);
                for i in 0..10_000u64 {
                    shared.inc();
                    own.inc();
                    if i % 100 == 0 {
                        hist.record_ns((i + 1) * 1_000);
                    }
                }
            });
        }
    });
    assert_eq!(shared.get(), 80_000);
    for t in 0..8u64 {
        let label = t.to_string();
        assert_eq!(reg.counter("obs.test.per_thread", &[("t", label.as_str())]).get(), 10_000);
    }
    assert_eq!(hist.snapshot().count, 800);
    // 1 shared + 8 per-thread counters; the snapshot is valid JSON
    let parsed = Json::parse(&reg.snapshot_json().dump()).unwrap();
    assert_eq!(parsed.get("counters").unwrap().as_arr().unwrap().len(), 9);
    assert_eq!(parsed.get("histograms").unwrap().as_arr().unwrap().len(), 1);
}

#[test]
fn flight_recorder_keeps_the_slowest_under_contention() {
    let rec = FlightRecorder::new(64, 8, 16);
    rec.set_slow_threshold(Duration::ZERO);
    // 4 writers × 200 records with distinct totals 1µs..800µs
    std::thread::scope(|s| {
        for t in 0..4u64 {
            let rec = &rec;
            s.spawn(move || {
                for i in 0..200u64 {
                    let tr = Trace::begin();
                    tr.mark_at_ns(Stage::Admit, 0);
                    tr.mark_at_ns(Stage::Done, (t * 200 + i + 1) * 1_000);
                    rec.record_request(&tr, "p", "n", "lane");
                }
            });
        }
    });
    assert_eq!(rec.requests_recorded(), 800);
    assert_eq!(rec.slow_captured(), 800);
    // replace-the-minimum retention keeps exactly the global top 8,
    // regardless of arrival interleaving
    let slow: Vec<u64> = rec.slow_snapshot().iter().map(|r| r.total_ns).collect();
    let want: Vec<u64> = (793..=800).rev().map(|us| us * 1_000).collect();
    assert_eq!(slow, want);
    // concurrent seqlock writes never yield torn records
    for r in rec.snapshot() {
        assert_eq!((r.platform.as_str(), r.network.as_str()), ("p", "n"));
        assert_eq!(r.tenant, "lane");
        assert!(r.total_ns >= 1_000 && r.total_ns <= 800_000);
    }
}

/// An Intel-trained Lin source model for transfer onboarding (same
/// recipe as `rust/tests/health.rs`).
fn intel_lin() -> Arc<dyn CostModel + Send + Sync> {
    let intel = Simulator::new(machine::intel_i9_9900k());
    let (prim, dlt) = calibration_sample(&intel, 0.1, 3);
    Arc::new(LinCostModel::fit(&prim, &dlt, "intel").unwrap())
}

#[test]
fn quarantine_is_visible_in_registry_and_flight_recorder() {
    let faulty = Arc::new(FaultySource::new(
        Arc::new(Simulator::new(machine::arm_cortex_a73())),
        42,
    ));
    let target: Arc<dyn CostSource> = Arc::clone(&faulty) as Arc<dyn CostSource>;
    let coord = Coordinator::shared();
    coord
        .onboard_platform(
            "obs-arm-live",
            OnboardSpec::transfer(Arc::clone(&target), intel_lin(), 0.02, 5),
        )
        .unwrap();
    coord
        .monitor_platform(
            "obs-arm-live",
            target,
            HealthPolicy::default()
                .with_sampling(1.0, 7)
                .with_window(16, 4)
                .with_drift_band(0.5)
                .with_quarantine(2, Duration::ZERO, Duration::from_millis(40)),
        )
        .unwrap();
    let service = Service::new(Arc::clone(&coord), ServiceConfig::default().with_workers(2));
    let net = networks::alexnet();

    let drive_until = |done: &dyn Fn(HealthState) -> bool| {
        for _ in 0..80 {
            let ticket = service
                .submit("ops", SelectionRequest::new(net.clone(), "obs-arm-live"))
                .unwrap();
            let _ = ticket.wait(); // quarantined refusals are expected
            let state = coord.platform_health_of("obs-arm-live").unwrap().state;
            if done(state) {
                return;
            }
        }
        panic!("health state not reached within 80 requests");
    };

    // drift past the band, then make every recalibration attempt fail:
    // the platform burns its failure budget and quarantines
    faulty.set_drift(9.0);
    drive_until(&|s| s == HealthState::Drifting);
    faulty.set_error_rate(1.0);
    drive_until(&|s| s == HealthState::Quarantined);

    // visible as a registry gauge (code 3 = quarantined, with drift)...
    let reg = service.metrics();
    assert_eq!(reg.gauge(obs::names::HEALTH_STATE, &[("platform", "obs-arm-live")]).get(), 3.0);
    assert!(reg.gauge(obs::names::HEALTH_DRIFT, &[("platform", "obs-arm-live")]).get() > 0.5);
    let text = reg.render_prometheus();
    assert!(
        text.contains("primsel_health_state{platform=\"obs-arm-live\"} 3"),
        "quarantine gauge missing from:\n{text}"
    );

    // ...and as structured flight-recorder events: the transition into
    // quarantine plus the failed recalibration attempts that caused it
    let events: Vec<_> = obs::flight_recorder()
        .events_snapshot()
        .into_iter()
        .filter(|e| e.platform == "obs-arm-live")
        .collect();
    assert!(
        events
            .iter()
            .any(|e| e.kind == RecordKind::Transition && e.tenant == "quarantined"),
        "no transition-to-quarantined event in {events:?}"
    );
    assert!(
        events
            .iter()
            .any(|e| e.kind == RecordKind::Recalibration && e.network == "failed"),
        "no failed-recalibration event in {events:?}"
    );
    service.shutdown();
}

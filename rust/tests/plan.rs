//! Differential suite for the compiled-plan warm path: every answer a
//! warm (plan-cache-hit) request produces must be **bit-identical** to
//! the cold path — a fresh cache and a fresh graph build — for every
//! zoo network, every built-in platform, and every objective kind. On
//! top of identity, the suite pins the *mechanism*: warm hits re-build
//! zero PBQP templates (thread-local build counter), plans expire on
//! explicit and health-loop recalibration, and eight threads
//! interleaving warm solves over shared plans stay bit-identical to
//! sequential.

use primsel::coordinator::{Coordinator, Objective, ReportDetail, SelectionRequest};
use primsel::health::HealthPolicy;
use primsel::networks::{self, Network};
use primsel::pbqp;
use primsel::selection::{self, memory, CostCache, CostSource, FaultySource};
use primsel::simulator::{machine, Simulator};
use std::sync::Arc;
use std::time::Duration;

const PLATFORMS: [&str; 3] = ["intel", "amd", "arm"];

fn sim_for(platform: &str) -> Simulator {
    Simulator::new(machine::by_name(platform).unwrap())
}

/// Every objective kind exercised per (network, platform): the two
/// solve-served ones answer through the plan cache, the two
/// front-served ones through the front cache — all four must keep
/// agreeing with cold ground truth after the caches warm up.
fn objectives(free_peak: f64) -> Vec<Objective> {
    vec![
        Objective::MinTime,
        Objective::MinTimeWithMemoryBudget {
            budget_bytes: free_peak * 0.3,
            lambda_ms_per_mb: 50.0,
        },
        Objective::FastestUnderBytes { budget_bytes: f64::INFINITY },
        Objective::SmallestWithinPct { pct_of_optimal_time: 0.0 },
    ]
}

#[test]
fn warm_requests_are_bit_identical_to_cold_ground_truth() {
    let coord = Coordinator::new();
    for platform in PLATFORMS {
        let sim = sim_for(platform);
        for net in networks::selection_networks() {
            // cold ground truth from a fresh single-use cache
            let fresh = CostCache::new(&sim);
            let free = selection::select(&net, &fresh).unwrap();
            let free_peak = memory::peak_workspace(&net, &free);

            for objective in objectives(free_peak) {
                let req = SelectionRequest::new(net.clone(), platform)
                    .with_objective(objective);
                let cold = coord.select_one(&req).unwrap();
                // second pass: plan (or front) cache hit
                let warm = coord.select_one(&req).unwrap();
                assert_eq!(
                    warm.selection.primitive, cold.selection.primitive,
                    "{platform}/{}/{objective:?}", net.name
                );
                assert_eq!(warm.selection.objective_ms, cold.selection.objective_ms);
                assert_eq!(warm.selection.estimated_ms, cold.selection.estimated_ms);
                assert_eq!(warm.evaluated_ms, cold.evaluated_ms);
                assert_eq!(warm.peak_workspace_bytes, cold.peak_workspace_bytes);

                // and both agree with the cold-path ground truth
                let expected = match objective {
                    Objective::MinTime
                    | Objective::FastestUnderBytes { .. }
                    | Objective::SmallestWithinPct { .. } => free.clone(),
                    Objective::MinTimeWithMemoryBudget { budget_bytes, lambda_ms_per_mb } => {
                        memory::select_with_budget(&net, &fresh, budget_bytes, lambda_ms_per_mb)
                            .unwrap()
                    }
                };
                assert_eq!(
                    warm.selection.primitive, expected.primitive,
                    "{platform}/{}/{objective:?}", net.name
                );
                assert_eq!(warm.selection.estimated_ms, expected.estimated_ms);
                assert_eq!(
                    warm.evaluated_ms,
                    selection::evaluate(&net, &expected, &fresh).unwrap()
                );
                assert_eq!(
                    warm.peak_workspace_bytes,
                    memory::peak_workspace(&net, &expected)
                );
            }
        }
    }
    // every (platform, network) pair compiled its plan exactly once:
    // the solve-served repeats were all hits
    let (hits, misses) = coord.plan_cache_stats();
    assert_eq!(misses as usize, PLATFORMS.len() * networks::selection_networks().len());
    assert!(hits >= misses, "repeat solve-served requests must hit: {hits} vs {misses}");
}

#[test]
fn warm_hits_build_zero_pbqp_templates() {
    // single-threaded on purpose: the build counter is thread-local, so
    // this test stays exact under a parallel test harness
    let coord = Coordinator::new();
    let net = networks::vgg(11);
    let req = SelectionRequest::new(net.clone(), "intel");
    let cold = coord.select_one(&req).unwrap();
    assert!(pbqp::template_builds_on_thread() >= 1, "the cold pass compiled a template");

    let before = pbqp::template_builds_on_thread();
    let solves_before = pbqp::solves_on_thread();
    for _ in 0..5 {
        let warm = coord.select_one(&req).unwrap();
        assert_eq!(warm.selection.primitive, cold.selection.primitive);
        // the budgeted objective reuses the very same plan
        let b = coord
            .select_one(&req.clone().with_objective(Objective::MinTimeWithMemoryBudget {
                budget_bytes: cold.peak_workspace_bytes * 0.5,
                lambda_ms_per_mb: 50.0,
            }))
            .unwrap();
        assert!(b.evaluated_ms >= cold.evaluated_ms);
    }
    assert_eq!(
        pbqp::template_builds_on_thread(),
        before,
        "warm plan hits must re-build nothing"
    );
    // ... while still actually solving (one arena-reusing solve each)
    assert_eq!(pbqp::solves_on_thread(), solves_before + 10);
}

#[test]
fn explicit_recalibration_drops_the_plan_and_the_new_one_serves_the_new_cache() {
    let coord = Coordinator::new();
    let target: Arc<dyn CostSource> = Arc::new(Simulator::new(machine::arm_cortex_a73()));
    coord
        .onboard_platform(
            "arm-lin",
            primsel::coordinator::OnboardSpec::fresh_lin(target, 0.02, 7),
        )
        .unwrap();
    let net = networks::alexnet();
    let req = SelectionRequest::new(net.clone(), "arm-lin");
    assert!(coord.select_one(&req).unwrap().evaluated_ms > 0.0);
    let old_plan = coord.selection_plan("arm-lin", &net).unwrap();

    coord.recalibrate_platform("arm-lin", 0.03, 99).unwrap();
    let new_plan = coord.selection_plan("arm-lin", &net).unwrap();
    assert!(
        !Arc::ptr_eq(&old_plan, &new_plan),
        "recalibration must expire the compiled plan"
    );
    // the fresh plan answers exactly like a cold solve over the
    // *recalibrated* serving cache
    let after = coord.select_one(&req).unwrap();
    let direct = selection::select(&net, coord.cache("arm-lin").unwrap().as_ref()).unwrap();
    assert_eq!(after.selection.primitive, direct.primitive);
    assert_eq!(after.selection.estimated_ms, direct.estimated_ms);
}

#[test]
fn health_auto_recalibration_drops_the_plan() {
    // a drifting live device triggers the health loop's auto-repair;
    // the repair swaps the serving cache, which must expire the plan
    let faulty = Arc::new(FaultySource::new(
        Arc::new(Simulator::new(machine::arm_cortex_a73())),
        42,
    ));
    let target: Arc<dyn CostSource> = Arc::clone(&faulty) as Arc<dyn CostSource>;
    let coord = Coordinator::new();
    coord
        .onboard_platform(
            "arm-live",
            primsel::coordinator::OnboardSpec::fresh_lin(Arc::clone(&target), 0.02, 5),
        )
        .unwrap();
    coord
        .monitor_platform(
            "arm-live",
            target,
            HealthPolicy::default()
                .with_sampling(1.0, 11)
                .with_window(24, 8)
                .with_drift_band(0.75)
                .with_auto_recalibrate(true, 0.02)
                .with_quarantine(3, Duration::ZERO, Duration::from_millis(200)),
        )
        .unwrap();
    let net = networks::alexnet();
    let req = SelectionRequest::new(net.clone(), "arm-live");
    coord.select_one(&req).unwrap();
    let old_plan = coord.selection_plan("arm-live", &net).unwrap();

    faulty.set_drift(3.0);
    for _ in 0..60 {
        let _ = coord.select_one(&req);
        if coord.platform_health_of("arm-live").unwrap().recalibrations >= 1 {
            break;
        }
    }
    assert!(
        coord.platform_health_of("arm-live").unwrap().recalibrations >= 1,
        "the drifted platform must auto-recalibrate"
    );
    let new_plan = coord.selection_plan("arm-live", &net).unwrap();
    assert!(
        !Arc::ptr_eq(&old_plan, &new_plan),
        "auto-recalibration must expire the compiled plan"
    );
    // serving continues over the new plan
    assert!(coord.select_one(&req).unwrap().evaluated_ms > 0.0);
}

#[test]
fn eight_threads_interleaving_warm_solves_match_sequential() {
    const THREADS: usize = 8;
    let coord = Coordinator::new();
    let nets: Vec<Network> = networks::selection_networks();

    // sequential ground truth with fresh caches
    let expected: Vec<(Vec<usize>, f64, Vec<usize>)> = nets
        .iter()
        .map(|net| {
            let sim = sim_for("intel");
            let fresh = CostCache::new(&sim);
            let free = selection::select(net, &fresh).unwrap();
            let peak = memory::peak_workspace(net, &free);
            let tight =
                memory::select_with_budget(net, &fresh, peak * 0.3, 50.0).unwrap();
            (free.primitive, free.estimated_ms, tight.primitive)
        })
        .collect();

    // prime every plan once so the hammer below is all warm traffic
    for net in &nets {
        coord.select_one(&SelectionRequest::new(net.clone(), "intel")).unwrap();
    }
    let (_, misses_after_prime) = coord.plan_cache_stats();

    std::thread::scope(|s| {
        for t in 0..THREADS {
            let coord = &coord;
            let nets = &nets;
            let expected = &expected;
            s.spawn(move || {
                for round in 0..3 {
                    for i in 0..nets.len() {
                        // stagger so threads collide on different plans
                        let n = (i + t + round) % nets.len();
                        let (exp_free, exp_ms, exp_tight) = &expected[n];
                        let req = SelectionRequest::new(nets[n].clone(), "intel")
                            .with_detail(ReportDetail::Minimal);
                        let rep = coord.select_one(&req).unwrap();
                        assert_eq!(&rep.selection.primitive, exp_free, "{}", nets[n].name);
                        assert_eq!(rep.selection.estimated_ms, *exp_ms);
                        assert_eq!(rep.evaluated_ms, *exp_ms);
                        let peak = rep.peak_workspace_bytes;
                        let tight = coord
                            .select_one(&req.clone().with_objective(
                                Objective::MinTimeWithMemoryBudget {
                                    budget_bytes: peak * 0.3,
                                    lambda_ms_per_mb: 50.0,
                                },
                            ))
                            .unwrap();
                        assert_eq!(&tight.selection.primitive, exp_tight);
                    }
                }
            });
        }
    });
    // the hammer compiled nothing new: every plan came from the cache
    let (_, misses) = coord.plan_cache_stats();
    assert_eq!(misses, misses_after_prime, "warm hammer must not recompile plans");
}

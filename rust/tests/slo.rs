//! Property tests for the ops plane's SLO burn-rate engine, and an
//! end-to-end check that a `Service` with sampling enabled actually
//! publishes the alert/series families.
//!
//! The engine's contract is that it is a *pure function* of the
//! `(t_ns, SloInputs)` sequence — no wall clocks, no randomness — so
//! two engines fed the same sequence must agree **bit for bit** on
//! every burn rate and every transition. That purity is what makes the
//! [`ManualClock`] tests here (and any postmortem replay of recorded
//! inputs) trustworthy.

use primsel::obs::{AlertState, Clock, ManualClock, SloEngine, SloInputs, SloSpec};
use std::time::Duration;

const SEC: u64 = 1_000_000_000;

/// Deterministic 64-bit generator (SplitMix64) — good enough statistical
/// spread for fuzzing input sequences, fully reproducible.
struct SplitMix64(u64);

impl SplitMix64 {
    fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    fn unit(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }
}

fn fuzz_specs() -> Vec<SloSpec> {
    vec![
        SloSpec::queue_depth("queue", 0.5)
            .with_windows(Duration::from_secs(3), Duration::from_secs(12)),
        SloSpec::error_rate("errors", 0.1)
            .with_windows(Duration::from_secs(2), Duration::from_secs(8))
            .with_burns(1.0, 3.0)
            .with_hysteresis(0.25),
        SloSpec::drift("drift", "arm", 0.75)
            .with_windows(Duration::from_secs(1), Duration::from_secs(6))
            .with_nudge(16),
        SloSpec::latency_p95("lat", "e2e", 25.0)
            .with_windows(Duration::from_secs(4), Duration::from_secs(10)),
    ]
}

fn fuzz_inputs(rng: &mut SplitMix64) -> SloInputs {
    let mut inputs = SloInputs {
        error_rate: rng.unit() * 0.4,
        queue_frac: rng.unit() * 1.5,
        ..SloInputs::default()
    };
    inputs.latency_p95_ms.push(("e2e".to_string(), rng.unit() * 80.0));
    // drift is present only ~half the ticks, so the skip path is
    // exercised inside the fuzzed sequence too
    if rng.next_u64() % 2 == 0 {
        inputs.drift.push(("arm".to_string(), rng.unit() * 3.0));
    }
    inputs
}

/// Everything observable about one evaluation, with burns as raw bits
/// so "equal" means bit-equal, not approximately equal.
type TickDigest = (Vec<(String, u8, u8, u64, u64, Option<u64>)>, Vec<(String, u8, u64, u64)>);

fn digest(eng: &mut SloEngine, t_ns: u64, inputs: &SloInputs) -> TickDigest {
    let transitions = eng
        .evaluate(t_ns, inputs)
        .into_iter()
        .map(|tr| {
            (
                tr.slo,
                tr.from as u8,
                tr.to as u8,
                tr.burn_fast.to_bits(),
                tr.burn_slow.to_bits(),
                tr.nudge,
            )
        })
        .collect();
    let alerts = eng
        .alerts()
        .into_iter()
        .map(|a| (a.slo, a.state as u8, a.burn_fast.to_bits(), a.burn_slow.to_bits()))
        .collect();
    (transitions, alerts)
}

/// Two engines, two manual clocks, one fuzzed input sequence: every
/// tick's transitions and burn rates must match bit for bit.
#[test]
fn engine_is_bit_deterministic_under_manual_clock() {
    let mut a = SloEngine::new(fuzz_specs()).unwrap();
    let mut b = SloEngine::new(fuzz_specs()).unwrap();
    let clock_a = ManualClock::new(0);
    let clock_b = ManualClock::new(0);
    let mut rng_a = SplitMix64(0xD1CE);
    let mut rng_b = SplitMix64(0xD1CE);
    let mut transitions_seen = 0usize;
    for _ in 0..500 {
        // irregular tick spacing, derived from the same stream
        let dt = SEC / 4 + rng_a.next_u64() % (2 * SEC);
        assert_eq!(dt, SEC / 4 + rng_b.next_u64() % (2 * SEC));
        clock_a.advance(dt);
        clock_b.advance(dt);
        let (ia, ib) = (fuzz_inputs(&mut rng_a), fuzz_inputs(&mut rng_b));
        let da = digest(&mut a, clock_a.now_ns(), &ia);
        let db = digest(&mut b, clock_b.now_ns(), &ib);
        assert_eq!(da, db, "engines diverged on an identical input sequence");
        transitions_seen += da.0.len();
    }
    // the fuzzed thresholds must actually get exercised, or the
    // determinism claim is vacuous
    assert!(transitions_seen > 4, "fuzz sequence produced almost no transitions");
}

/// Replaying the same recorded sequence later (fresh engine, fresh
/// clock) reproduces the same final alert standing — the postmortem
/// replay property.
#[test]
fn replay_from_scratch_reaches_identical_standing() {
    let mut rng = SplitMix64(7);
    let sequence: Vec<(u64, SloInputs)> = (1..=200)
        .map(|i| (i * SEC + (rng.next_u64() % SEC), fuzz_inputs(&mut rng)))
        .collect();
    let run = |seq: &[(u64, SloInputs)]| {
        let mut eng = SloEngine::new(fuzz_specs()).unwrap();
        let mut log = Vec::new();
        for (t, inputs) in seq {
            log.push(digest(&mut eng, *t, inputs));
        }
        log
    };
    assert_eq!(run(&sequence), run(&sequence));
}

/// Hysteresis pins the alert once it fires: burn oscillating just
/// below the Warning threshold (but above the clear margin) must not
/// flap the state, and the alert clears only once burn drops below
/// `warn × (1 - hysteresis)`.
#[test]
fn boundary_riding_burn_does_not_flap() {
    // target 0.5, warn 1.0, hysteresis 0.2 → fires at burn ≥ 1.0,
    // clears only below 0.8
    let spec = SloSpec::queue_depth("q", 0.5)
        .with_windows(Duration::from_secs(1), Duration::from_secs(1))
        .with_hysteresis(0.2);
    let mut eng = SloEngine::new(vec![spec]).unwrap();
    let clock = ManualClock::new(0);
    let tick = |eng: &mut SloEngine, clock: &ManualClock, frac: f64| {
        clock.advance(SEC);
        let inputs = SloInputs { queue_frac: frac, ..SloInputs::default() };
        eng.evaluate(clock.now_ns(), &inputs)
    };

    // enter Warning (burn 1.1 in both windows)
    let tr = tick(&mut eng, &clock, 0.55);
    assert_eq!(tr.len(), 1);
    assert_eq!((tr[0].from, tr[0].to), (AlertState::Ok, AlertState::Warning));

    // ride the boundary: burns 0.9–0.96, below warn but above clear
    for i in 0..12 {
        let frac = if i % 2 == 0 { 0.45 } else { 0.48 };
        let tr = tick(&mut eng, &clock, frac);
        assert!(tr.is_empty(), "boundary riding flapped the alert: {tr:?}");
        assert_eq!(eng.alerts()[0].state, AlertState::Warning);
    }

    // a real recovery clears it — exactly one transition, to Ok
    let mut cleared = Vec::new();
    for _ in 0..3 {
        cleared.extend(tick(&mut eng, &clock, 0.05));
    }
    assert_eq!(cleared.len(), 1, "clear must happen exactly once: {cleared:?}");
    assert_eq!((cleared[0].from, cleared[0].to), (AlertState::Warning, AlertState::Ok));
}

/// The full ladder: sustained heat escalates Ok → Critical directly
/// (both windows hot past crit), and recovery steps down one level per
/// evaluation — Critical → Warning → Ok, never Critical → Ok.
#[test]
fn recovery_from_critical_passes_through_warning() {
    let spec = SloSpec::latency_p95("lat", "e2e", 10.0)
        .with_windows(Duration::from_secs(1), Duration::from_secs(3));
    let mut eng = SloEngine::new(vec![spec]).unwrap();
    fn lat(ms: f64) -> SloInputs {
        SloInputs { latency_p95_ms: vec![("e2e".to_string(), ms)], ..SloInputs::default() }
    }
    let clock = ManualClock::new(0);
    let mut ladder = Vec::new();
    for _ in 0..5 {
        clock.advance(SEC);
        ladder.extend(eng.evaluate(clock.now_ns(), &lat(50.0)));
    }
    for _ in 0..8 {
        clock.advance(SEC);
        ladder.extend(eng.evaluate(clock.now_ns(), &lat(0.0)));
    }
    let steps: Vec<(AlertState, AlertState)> = ladder.iter().map(|t| (t.from, t.to)).collect();
    assert_eq!(
        steps,
        vec![
            (AlertState::Ok, AlertState::Critical),
            (AlertState::Critical, AlertState::Warning),
            (AlertState::Warning, AlertState::Ok),
        ]
    );
    for (from, to) in steps {
        assert!(
            !(from == AlertState::Critical && to == AlertState::Ok),
            "Critical must never clear straight to Ok"
        );
    }
}

/// End to end: a service with the ops plane enabled runs its sampler,
/// evaluates its SLOs, and publishes the alert/series metric families —
/// and `ops_report` hands all of it back.
#[test]
fn service_ops_plane_publishes_alerts_and_series() {
    use primsel::coordinator::{Coordinator, SelectionRequest};
    use primsel::networks;
    use primsel::service::{Service, ServiceConfig};

    let service = Service::new(
        Coordinator::shared(),
        ServiceConfig::default()
            .with_capacity(8)
            .with_workers(2)
            // long cadence: the background thread ticks once at spawn,
            // then the test drives further ticks by hand
            .with_sampling(Duration::from_secs(3600))
            .with_slo(SloSpec::queue_depth("ops-queue", 0.9))
            .with_slo(SloSpec::latency_p95("ops-latency", "e2e", 1e9)),
    );
    let t = service
        .submit("ops", SelectionRequest::new(networks::alexnet(), "intel"))
        .expect("admission");
    t.wait().expect("served");
    service.ops_tick();
    service.ops_tick();

    let report = service.ops_report().expect("ops plane is enabled");
    assert!(report.ticks >= 2, "sampler must have ticked, got {}", report.ticks);
    assert!(!report.series.is_empty(), "series rings must have content");
    let names: Vec<&str> = report.alerts.iter().map(|a| a.slo.as_str()).collect();
    assert_eq!(names, vec!["ops-queue", "ops-latency"], "alerts in spec order");
    for a in &report.alerts {
        assert_eq!(a.state, AlertState::Ok, "nothing should be burning here");
    }
    let rendered = report.render();
    assert!(rendered.contains("ops report"), "report: {rendered}");
    assert!(rendered.contains("slo alerts"), "report: {rendered}");

    let text = primsel::obs::registry().render_prometheus();
    for family in [
        "primsel_slo_state{slo=\"ops-queue\"}",
        "primsel_slo_state{slo=\"ops-latency\"}",
        "primsel_slo_burn_fast{",
        "primsel_slo_burn_slow{",
        "primsel_series_ticks",
        "primsel_recorder_requests_dropped",
        "primsel_recorder_events_dropped",
    ] {
        assert!(text.contains(family), "missing {family} in exposition");
    }
    service.shutdown();
}

//! Cross-module integration tests: AOT artifacts -> PJRT training ->
//! prediction -> PBQP selection. These need `make artifacts` (they are
//! skipped gracefully when artifacts are absent).

use primsel::dataset::{self, Standardizer};
use primsel::layers::ConvConfig;
use primsel::networks;
use primsel::perfmodel::{hparams_for, ParamStore, Predictor, TrainOpts, Trainer};
use primsel::runtime::Runtime;
use primsel::selection;
use primsel::simulator::{machine, Simulator};

fn runtime() -> Option<Runtime> {
    Runtime::open_default().ok()
}

/// NN1 (tiny MLP) must fit a small simulated dataset: loss decreases by
/// an order of magnitude within a few epochs.
#[test]
fn training_reduces_loss_via_pjrt() {
    let Some(rt) = runtime() else { return };
    let sim = Simulator::new(machine::intel_i9_9900k());
    let configs = dataset::enumerate_configs(512, 3);
    let ds = dataset::profile_prim_dataset(&sim, &configs);
    let xs: Vec<Vec<f64>> = ds.features().iter().map(|f| f.to_vec()).collect();
    // single-column dataset for the nn1 artifact (direct-sum2d, col 0)
    let ys: Vec<Vec<Option<f64>>> = ds.targets.iter().map(|r| vec![r[0]]).collect();
    let sx = Standardizer::fit(&xs, true);
    let sy = Standardizer::fit_masked(&ys, true);
    let b = dataset::make_batches(&xs, &ys, &sx, &sy, 1024);

    let trainer = Trainer::new(&rt, "nn1").unwrap();
    let mut hp = hparams_for("nn1");
    hp.max_epochs = 40;
    let res = trainer
        .train(trainer.init(5).unwrap(), &b, &b, TrainOpts { hp, verbose_every: 0 })
        .unwrap();
    let first = res.history.first().unwrap().1;
    assert!(
        res.best_val_loss < first * 0.25,
        "loss {first} -> {} after {} epochs",
        res.best_val_loss,
        res.epochs_run
    );
}

/// A trained-enough NN1 predictor must beat a constant-mean predictor
/// on held-out data, and its denormalised outputs must be positive ms.
#[test]
fn predictor_denormalises_sensibly() {
    let Some(rt) = runtime() else { return };
    let sim = Simulator::new(machine::amd_a10_7850k());
    let configs = dataset::enumerate_configs(768, 9);
    let ds = dataset::profile_prim_dataset(&sim, &configs);
    let split = dataset::split(ds.len(), 1);
    let train = ds.subset(&split.train);
    let test = ds.subset(&split.test);
    let xs: Vec<Vec<f64>> = train.features().iter().map(|f| f.to_vec()).collect();
    let ys: Vec<Vec<Option<f64>>> = train.targets.iter().map(|r| vec![r[0]]).collect();
    let sx = Standardizer::fit(&xs, true);
    let sy = Standardizer::fit_masked(&ys, true);
    let b = dataset::make_batches(&xs, &ys, &sx, &sy, 1024);
    let trainer = Trainer::new(&rt, "nn1").unwrap();
    let mut hp = hparams_for("nn1");
    hp.max_epochs = 60;
    let res = trainer
        .train(trainer.init(2).unwrap(), &b, &b, TrainOpts { hp, verbose_every: 0 })
        .unwrap();

    let pred = Predictor::new(&rt, "nn1", res.params, sx, sy).unwrap();
    let txs: Vec<Vec<f64>> = test.features().iter().map(|f| f.to_vec()).collect();
    let preds = pred.predict_raw(&txs).unwrap();
    let pairs: Vec<(f64, f64)> = preds
        .iter()
        .zip(&test.targets)
        .filter_map(|(p, t)| t[0].map(|a| (p[0], a)))
        .collect();
    let md = primsel::perfmodel::mdrae(&pairs);
    assert!(md < 0.30, "NN1 MdRAE too high: {md}");
    for (p, _) in &pairs {
        assert!(*p > 0.0, "negative predicted time");
    }
}

/// Selection with a *predicted* cost table must produce a network time
/// within a few percent of the profiled-optimal selection (paper fig 7
/// allows 1.1%; we allow slack for the lightly-trained test model).
#[test]
fn predicted_selection_close_to_profiled() {
    let Some(rt) = runtime() else { return };
    // use a cached fully-trained model when available, else skip
    let path = std::path::Path::new("artifacts/trained/intel_nn2.bin");
    if !path.exists() {
        return;
    }
    let params = ParamStore::load(path).unwrap();
    let sim = Simulator::new(machine::intel_i9_9900k());
    let configs = dataset::enumerate_configs(dataset::MAX_CONFIGS, 20200612);
    let ds = dataset::profile_prim_dataset(&sim, &configs);
    let split = dataset::split(ds.len(), 42);
    let train = ds.subset(&split.train);
    let xs: Vec<Vec<f64>> = train.features().iter().map(|f| f.to_vec()).collect();
    let sx = Standardizer::fit(&xs, true);
    let sy = Standardizer::fit_masked(&train.targets, true);
    let pred = Predictor::new(&rt, "nn2", params, sx, sy).unwrap();

    let net = networks::vgg(11);
    let rows = pred.predict_configs(&net.layers).unwrap();
    let mut keys: Vec<(u32, u32)> = net
        .edges
        .iter()
        .map(|&(u, v)| (net.layers[u].k, net.layers[v].im))
        .collect();
    keys.sort();
    keys.dedup();
    let mats: Vec<[[f64; 3]; 3]> =
        keys.iter().map(|&(c, im)| sim.dlt_matrix(c, im)).collect();
    let source = selection::TableSource::new(net.layers.clone(), rows, keys, mats);
    let sel_model = selection::select(&net, &source).unwrap();
    let sel_prof = selection::select(&net, &sim).unwrap();
    let t_model = selection::evaluate(&net, &sel_model, &sim).unwrap();
    let t_prof = selection::evaluate(&net, &sel_prof, &sim).unwrap();
    let inc = t_model / t_prof - 1.0;
    assert!(inc < 0.10, "predicted selection {:.2}% worse", inc * 100.0);
    assert!(inc >= -1e-9);
}

/// The measured-grid profiler must return sane numbers for real kernels.
#[test]
fn host_profiler_smoke() {
    let Some(mut rt) = runtime() else { return };
    if rt.manifest.prim_grid.is_empty() {
        return;
    }
    rt.manifest.prim_grid.truncate(3);
    let ms = primsel::profiler::profile_grid(&rt, 3).unwrap();
    assert_eq!(ms.len(), 3);
    for m in ms {
        assert!(m.median_ms > 0.0 && m.median_ms < 60_000.0);
    }
}

/// Layout contract: every primitive's in/out layout matches its kernel's
/// manifest output layout for grid entries.
#[test]
fn manifest_layouts_match_catalog() {
    let Some(rt) = runtime() else { return };
    for e in &rt.manifest.prim_grid {
        let cfg = ConvConfig::new(e.k, e.c, e.im, e.s, e.f);
        // at least one catalog primitive uses this kernel and applies here
        let found = primsel::primitives::catalog()
            .iter()
            .any(|p| p.kernel_id == e.kernel && p.applicable(&cfg));
        assert!(found, "orphan grid entry {e:?}");
    }
}

//! Zero-allocation pin for the warm plan solve core: a counting
//! `#[global_allocator]` proves that, after a priming round, repeated
//! [`SelectionPlan::min_time_into`] / [`SelectionPlan::with_budget_into`]
//! solves on a retained [`PlanScratch`] perform **zero** heap
//! allocations — including when one scratch is interleaved across
//! differently-shaped plans (every buffer grows to the high-water mark
//! during priming and is only ever reused after).
//!
//! The measured window also runs fully instrumented — a live
//! [`Trace`], stage-histogram records and flight-recorder captures on
//! every round, **and** a busy ops-plane sampler thread snapshotting
//! the whole registry into its series rings the entire time — pinning
//! the observability layer's zero-allocation claim (the sampler's
//! steady state included) alongside the solver's.
//!
//! The binary holds exactly one `#[test]` on purpose: the counter is
//! process-global, and a sibling test allocating concurrently would
//! make the "zero since the snapshot" assertion racy. The sampler
//! thread is that rule's one deliberate exception: it is *supposed* to
//! run inside the measured window, and the assertion is exactly that it
//! contributes nothing to the count.

use primsel::networks;
use primsel::obs::{self, ManualClock, Sampler, SamplerConfig, Stage, Trace};
use primsel::selection::{PlanScratch, SelectionPlan};
use primsel::simulator::{machine, Simulator};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

/// System allocator plus a count of every allocation-path call
/// (`alloc`, `alloc_zeroed`, `realloc`). Deallocations are free to
/// happen (dropping is not allocating), so they are not counted.
struct CountingAlloc;

static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn alloc_calls() -> u64 {
    ALLOC_CALLS.load(Ordering::SeqCst)
}

#[test]
fn warm_plan_solves_allocate_nothing_in_steady_state() {
    let sim = Simulator::new(machine::intel_i9_9900k());
    // two differently shaped networks so the interleaving exercises the
    // scratch's re-shaping path, not just same-size reuse
    let nets = [networks::alexnet(), networks::vgg(11)];
    let plans: Vec<SelectionPlan> =
        nets.iter().map(|n| SelectionPlan::compile(n, &sim).unwrap()).collect();
    let mut scratch = PlanScratch::default();

    // ground truth + budgets captured before the measured window
    let budgets: Vec<f64> =
        plans.iter().map(|p| p.min_time_into(&mut scratch).peak_workspace_bytes * 0.3).collect();
    let truth: Vec<(Vec<usize>, f64, Vec<usize>)> = plans
        .iter()
        .zip(&budgets)
        .map(|(p, &b)| {
            let free = p.min_time_into(&mut scratch);
            let (fp, fe) = (free.primitive.to_vec(), free.estimated_ms);
            let tight = p.with_budget_into(b, 50.0, &mut scratch);
            (fp, fe, tight.primitive.to_vec())
        })
        .collect();

    // sanity: the counter counts (compiling above certainly allocated)
    assert!(alloc_calls() > 0, "counting allocator must be live");

    // observability pre-resolution: registry handles are looked up once
    // (that allocates; so does registering), the recorder keeps every
    // request (threshold zero) but its rings and slow buffer are
    // pre-sized — so the measured window's marks, records and captures
    // must all be pure atomic writes
    let solve_ms = obs::registry().histogram(obs::names::STAGE_MS, &[("stage", "solve")]);
    let recorder = obs::FlightRecorder::with_defaults();
    recorder.set_slow_threshold(std::time::Duration::ZERO);
    let trace = Trace::begin();

    // priming pass: every buffer reaches its high-water mark
    for _ in 0..2 {
        for (p, &b) in plans.iter().zip(&budgets) {
            let _ = p.min_time_into(&mut scratch);
            let _ = p.with_budget_into(b, 50.0, &mut scratch);
        }
    }

    // ops-plane sampler over the process registry: two priming samples
    // allocate the per-series rings (first sight of each series), after
    // which sampling is pure ring writes. The thread then busy-samples
    // through the whole measured window on a hand-cranked clock.
    let sampler = Arc::new(Sampler::new(SamplerConfig::default().with_capacity(64)));
    let clock = Arc::new(ManualClock::new(0));
    for _ in 0..2 {
        clock.advance(1_000_000);
        sampler.sample(obs::registry(), &*clock);
    }
    let stop = Arc::new(AtomicBool::new(false));
    let sampler_thread = {
        let (sampler, clock, stop) = (Arc::clone(&sampler), Arc::clone(&clock), Arc::clone(&stop));
        std::thread::spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                clock.advance(1_000_000);
                sampler.sample(obs::registry(), &*clock);
            }
        })
    };

    // the measured window: interleaved warm solves, fully instrumented,
    // zero allocations — the live sampler thread included
    let before = alloc_calls();
    for _ in 0..50 {
        for ((p, &b), (fp, fe, tp)) in plans.iter().zip(&budgets).zip(&truth) {
            trace.mark(Stage::SolveStart);
            let free = p.min_time_into(&mut scratch);
            assert_eq!(free.primitive, &fp[..]);
            assert_eq!(free.estimated_ms, *fe);
            let tight = p.with_budget_into(b, 50.0, &mut scratch);
            assert_eq!(tight.primitive, &tp[..]);
            trace.mark(Stage::SolveEnd);
            if let Some(ns) = trace.span_ns(Stage::SolveStart, Stage::SolveEnd) {
                solve_ms.record_ns(ns);
            }
            recorder.record_request(&trace, "intel", "alexnet", "alloc-test");
        }
    }
    let delta = alloc_calls() - before;
    stop.store(true, Ordering::Relaxed);
    sampler_thread.join().unwrap();
    assert_eq!(
        delta, 0,
        "instrumented warm plan solves must not allocate: {delta} allocation calls \
         in the steady state (sampler thread live)"
    );
    assert_eq!(recorder.requests_recorded(), 100);
    assert_eq!(solve_ms.snapshot().count, 100);
    // the sampler really ran concurrently with the measured window
    assert!(sampler.ticks() >= 2, "sampler must have ticked");
}

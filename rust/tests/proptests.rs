//! Randomised property tests (proptest is unavailable offline; these use
//! the in-tree SplitMix64 with fixed seeds, so failures are reproducible).

use primsel::dataset::{self, Standardizer};
use primsel::layers::ConvConfig;
use primsel::networks::Network;
use primsel::pbqp::{self, Graph};
use primsel::perfmodel::metrics;
use primsel::primitives::{catalog, Layout};
use primsel::selection::memory::{peak_workspace, select_with_budget, workspace_bytes};
use primsel::selection::pareto::{ParetoFront, DEFAULT_LAMBDA_MS_PER_MB};
use primsel::selection::{self, CostCache, CostSource, Selection};
use primsel::simulator::noise::SplitMix64;
use primsel::simulator::{machine, Simulator};

const CASES: usize = 60;

fn rand_cfg(rng: &mut SplitMix64) -> ConvConfig {
    let k = 1 + (rng.next_u64() % 512) as u32;
    let c = 1 + (rng.next_u64() % 512) as u32;
    let im = 7 + (rng.next_u64() % 220) as u32;
    let s = [1u32, 2, 4][(rng.next_u64() % 3) as usize];
    let f = [1u32, 3, 5, 7, 9, 11][(rng.next_u64() % 6) as usize];
    ConvConfig::new(k, c, im, s, f)
}

/// PBQP never reports a cost below the true optimum, and is exact on
/// chain-reducible graphs.
#[test]
fn prop_pbqp_sound_and_chain_exact() {
    let mut rng = SplitMix64::new(0xFACADE);
    for case in 0..CASES {
        let n = 2 + (rng.next_u64() % 5) as usize;
        let chain = case % 2 == 0;
        let node_costs: Vec<Vec<f64>> = (0..n)
            .map(|_| {
                let ch = 2 + (rng.next_u64() % 3) as usize;
                (0..ch).map(|_| rng.next_f64() * 9.0).collect()
            })
            .collect();
        let mut g = Graph::new(node_costs);
        for u in 0..n {
            for v in (u + 1)..n {
                let connect = if chain { v == u + 1 } else { rng.next_f64() < 0.45 };
                if connect {
                    let len = g.node_costs[u].len() * g.node_costs[v].len();
                    g.add_edge(u, v, (0..len).map(|_| rng.next_f64() * 4.0).collect());
                }
            }
        }
        let sol = pbqp::solve(&g);
        let exact = g.brute_force();
        assert!(sol.cost >= exact.cost - 1e-9, "solver under-reports");
        assert!((g.cost_of(&sol.choice) - sol.cost).abs() < 1e-9, "inconsistent");
        if chain {
            assert!(
                (sol.cost - exact.cost).abs() < 1e-9,
                "case {case}: chain must be exact ({} vs {})",
                sol.cost,
                exact.cost
            );
        }
    }
}

/// The rewritten work-graph (flat edge arena + degree buckets) must match
/// brute force exactly on randomized R0–RII-reducible graphs: chains,
/// trees and cycles, with parallel edges and ragged choice counts thrown
/// in (parallel edges merge; a cycle reduces via RII onto an existing
/// edge).
#[test]
fn prop_pbqp_workgraph_exact_on_reducible_graphs() {
    let mut rng = SplitMix64::new(0xBEEFCAFE);
    for case in 0..CASES {
        let n = 3 + (rng.next_u64() % 5) as usize;
        let node_costs: Vec<Vec<f64>> = (0..n)
            .map(|_| {
                let ch = 1 + (rng.next_u64() % 4) as usize;
                (0..ch).map(|_| rng.next_f64() * 9.0).collect()
            })
            .collect();
        let mut g = Graph::new(node_costs);
        let shape = case % 3;
        let mut pairs: Vec<(usize, usize)> = Vec::new();
        match shape {
            0 => pairs.extend((0..n - 1).map(|u| (u, u + 1))), // chain
            1 => {
                // random tree
                for v in 1..n {
                    pairs.push(((rng.next_u64() as usize) % v, v));
                }
            }
            _ => {
                // single cycle: still fully RII-reducible
                pairs.extend((0..n - 1).map(|u| (u, u + 1)));
                pairs.push((0, n - 1));
            }
        }
        for &(u, v) in &pairs {
            let len = g.node_costs[u].len() * g.node_costs[v].len();
            g.add_edge(u, v, (0..len).map(|_| rng.next_f64() * 5.0).collect());
            if rng.next_f64() < 0.3 {
                // parallel duplicate, sometimes flipped orientation
                let (a, b) = if rng.next_f64() < 0.5 { (u, v) } else { (v, u) };
                let len = g.node_costs[a].len() * g.node_costs[b].len();
                g.add_edge(a, b, (0..len).map(|_| rng.next_f64() * 2.0).collect());
            }
        }
        let sol = pbqp::solve(&g);
        let exact = g.brute_force();
        assert!(
            (sol.cost - exact.cost).abs() < 1e-9,
            "case {case} (shape {shape}): {} vs {}",
            sol.cost,
            exact.cost
        );
        assert!((g.cost_of(&sol.choice) - sol.cost).abs() < 1e-9);
    }
}

/// Cached and uncached simulator costs are bit-identical: the cost-query
/// engine memoizes, it never re-derives.
#[test]
fn prop_cost_cache_bit_identical() {
    let mut rng = SplitMix64::new(0xCACE);
    for sim in machine::all().into_iter().map(Simulator::new) {
        let cache = CostCache::new(&sim);
        let mut cfgs = Vec::new();
        for _ in 0..CASES {
            cfgs.push(rand_cfg(&mut rng));
        }
        // query twice (cold then hot) interleaved with direct queries
        for pass in 0..2 {
            for cfg in &cfgs {
                assert_eq!(
                    cache.row(cfg).as_ref(),
                    sim.profile_layer(cfg).as_slice(),
                    "pass {pass}: cached row must equal direct profile"
                );
                assert_eq!(cache.layer_costs(cfg).as_ref(), sim.profile_layer(cfg).as_slice());
            }
            for cfg in &cfgs {
                let (c, im) = (cfg.c, cfg.im);
                assert_eq!(cache.matrix(c, im), sim.dlt_matrix(c, im));
                for src in Layout::ALL {
                    for dst in Layout::ALL {
                        assert_eq!(
                            cache.dlt_cost(c, im, src, dst),
                            sim.profile_dlt(c, im, src, dst)
                        );
                    }
                }
            }
        }
        assert!(cache.rows_cached() <= cfgs.len());
    }
}

/// Dense per-network tables answer exactly like the live simulator, and
/// selection through cache or table matches direct selection bit for bit.
#[test]
fn prop_table_source_matches_simulator() {
    let sim = Simulator::new(machine::amd_a10_7850k());
    let nets = primsel::networks::selection_networks();
    for net in &nets {
        let cache = CostCache::new(&sim);
        let table = cache.table_for(net);
        for cfg in &net.layers {
            assert_eq!(table.layer_costs(cfg).as_ref(), sim.profile_layer(cfg).as_slice());
        }
        let direct = selection::select(net, &sim).unwrap();
        let cached = selection::select(net, &cache).unwrap();
        let tabled = selection::select(net, &table).unwrap();
        assert_eq!(direct.primitive, cached.primitive, "{}", net.name);
        assert_eq!(direct.primitive, tabled.primitive, "{}", net.name);
        assert_eq!(direct.estimated_ms, cached.estimated_ms);
        assert_eq!(direct.estimated_ms, tabled.estimated_ms);
        assert_eq!(
            selection::evaluate(net, &direct, &table).unwrap(),
            selection::evaluate(net, &direct, &sim).unwrap()
        );
    }
}

/// Splits partition the index set for arbitrary sizes and seeds.
#[test]
fn prop_split_partitions() {
    let mut rng = SplitMix64::new(3);
    for _ in 0..CASES {
        let n = 1 + (rng.next_u64() % 3000) as usize;
        let seed = rng.next_u64();
        let s = dataset::split(n, seed);
        let mut all: Vec<usize> =
            s.train.iter().chain(&s.val).chain(&s.test).copied().collect();
        all.sort();
        all.dedup();
        assert_eq!(all.len(), n, "split must cover all {n} indices");
        assert!(s.train.len() >= s.val.len());
    }
}

/// Log-standardisation round-trips arbitrary positive data.
#[test]
fn prop_standardizer_round_trip() {
    let mut rng = SplitMix64::new(17);
    for _ in 0..CASES {
        let n = 2 + (rng.next_u64() % 40) as usize;
        let rows: Vec<Vec<f64>> = (0..n)
            .map(|_| vec![(rng.next_f64() * 8.0 - 4.0).exp()])
            .collect();
        let s = Standardizer::fit(&rows, true);
        for r in &rows {
            let back = s.inverse(&s.forward(r));
            assert!((back[0] - r[0]).abs() / r[0] < 1e-9);
        }
    }
}

/// Simulator invariants on random configs: defined costs are positive
/// and finite; inapplicability matches the catalog predicate; more MACs
/// with all else fixed never makes a primitive faster.
#[test]
fn prop_simulator_sanity() {
    let mut rng = SplitMix64::new(23);
    for sim in machine::all().into_iter().map(Simulator::noiseless) {
        for _ in 0..CASES {
            let cfg = rand_cfg(&mut rng);
            let row = sim.profile_layer(&cfg);
            for (p, t) in row.iter().enumerate() {
                assert_eq!(t.is_some(), catalog()[p].applicable(&cfg));
                if let Some(t) = t {
                    assert!(t.is_finite() && *t > 0.0);
                }
            }
            // doubling k must not make a primitive meaningfully faster
            // (tiny gemms are latency-bound: equal time is physical), and
            // scaling the whole problem 4x must strictly slow it down.
            if cfg.k <= 1024 {
                let big = ConvConfig { k: cfg.k * 2, ..cfg };
                for (a, b) in row.iter().zip(sim.profile_layer(&big)) {
                    if let (Some(a), Some(b)) = (a, b) {
                        assert!(b > *a * 0.7, "k doubling sped up {a} -> {b}");
                    }
                }
            }
            if cfg.k <= 512 && cfg.c <= 512 {
                let big = ConvConfig { k: cfg.k * 4, c: cfg.c * 4, ..cfg };
                for (a, b) in row.iter().zip(sim.profile_layer(&big)) {
                    if let (Some(a), Some(b)) = (a, b) {
                        assert!(b > *a, "16x MACs must cost more");
                    }
                }
            }
        }
    }
}

/// DLT costs are a symmetric-support matrix with zero diagonal and obey
/// a loose triangle-style bound through the middle layout.
#[test]
fn prop_dlt_matrix_structure() {
    let mut rng = SplitMix64::new(29);
    let sim = Simulator::noiseless(machine::arm_cortex_a73());
    for _ in 0..CASES {
        let c = 1 + (rng.next_u64() % 512) as u32;
        let im = 7 + (rng.next_u64() % 200) as u32;
        let m = sim.dlt_matrix(c, im);
        for (i, _) in Layout::ALL.iter().enumerate() {
            assert_eq!(m[i][i], 0.0);
            for j in 0..3 {
                if i != j {
                    assert!(m[i][j] > 0.0);
                    // going via a third layout can't be free
                    let k = 3 - i - j;
                    assert!(m[i][k] + m[k][j] > 0.5 * m[i][j]);
                }
            }
        }
    }
}

/// evaluate() equals the PBQP objective for the solver's own choice on
/// random subgraphs of the zoo.
#[test]
fn prop_selection_objective_consistency() {
    let mut rng = SplitMix64::new(31);
    let sim = Simulator::new(machine::amd_a10_7850k());
    let nets = primsel::networks::zoo();
    for _ in 0..12 {
        let net = &nets[(rng.next_u64() as usize) % nets.len()];
        let sel = selection::select(net, &sim).unwrap();
        let ev = selection::evaluate(net, &sel, &sim).unwrap();
        assert!(
            (ev - sel.estimated_ms).abs() / ev.max(1e-9) < 1e-9,
            "{}: {} vs {}",
            net.name,
            ev,
            sel.estimated_ms
        );
    }
}

/// MdRAE is scale-invariant and zero iff predictions are exact.
#[test]
fn prop_mdrae_properties() {
    let mut rng = SplitMix64::new(37);
    for _ in 0..CASES {
        let n = 1 + (rng.next_u64() % 50) as usize;
        let pairs: Vec<(f64, f64)> = (0..n)
            .map(|_| {
                let a = rng.next_f64() * 10.0 + 0.1;
                (a * (1.0 + rng.next_normal() * 0.1), a)
            })
            .collect();
        let m = metrics::mdrae(&pairs);
        assert!(m >= 0.0);
        let scaled: Vec<(f64, f64)> =
            pairs.iter().map(|&(p, a)| (p * 7.0, a * 7.0)).collect();
        assert!((metrics::mdrae(&scaled) - m).abs() < 1e-12);
        let exact: Vec<(f64, f64)> = pairs.iter().map(|&(_, a)| (a, a)).collect();
        assert_eq!(metrics::mdrae(&exact), 0.0);
    }
}

/// The workspace model is total over the config space: every
/// (primitive, config) pair — applicable or not — yields a finite,
/// non-negative byte count.
#[test]
fn prop_workspace_model_sane() {
    let mut rng = SplitMix64::new(43);
    for _ in 0..CASES {
        let cfg = rand_cfg(&mut rng);
        for prim in catalog() {
            let w = workspace_bytes(prim, &cfg);
            assert!(w.is_finite() && w >= 0.0, "{}: workspace {w}", prim.name);
        }
    }
}

/// Peak workspace is a per-layer maximum, so jointly permuting the
/// (layer, primitive) pairs must not move it by a single bit.
#[test]
fn prop_peak_workspace_permutation_stable() {
    let mut rng = SplitMix64::new(47);
    let cat = catalog();
    for case in 0..CASES {
        let n = 2 + (rng.next_u64() % 10) as usize;
        let mut layers: Vec<ConvConfig> = Vec::with_capacity(n);
        let mut primitive: Vec<usize> = Vec::with_capacity(n);
        while layers.len() < n {
            let cfg = rand_cfg(&mut rng);
            let apps: Vec<usize> =
                (0..cat.len()).filter(|&p| cat[p].applicable(&cfg)).collect();
            if apps.is_empty() {
                continue; // degenerate config (e.g. filter larger than image)
            }
            primitive.push(apps[(rng.next_u64() as usize) % apps.len()]);
            layers.push(cfg);
        }
        let net =
            Network { name: format!("perm-{case}"), layers: layers.clone(), edges: vec![] };
        let sel =
            Selection { primitive: primitive.clone(), objective_ms: 0.0, estimated_ms: 0.0 };
        let peak = peak_workspace(&net, &sel);
        assert!(peak.is_finite() && peak >= 0.0);

        // joint Fisher–Yates shuffle of the (layer, primitive) pairs
        let mut pairs: Vec<(ConvConfig, usize)> = layers.into_iter().zip(primitive).collect();
        for i in (1..pairs.len()).rev() {
            let j = (rng.next_u64() as usize) % (i + 1);
            pairs.swap(i, j);
        }
        let (layers2, primitive2): (Vec<_>, Vec<_>) = pairs.into_iter().unzip();
        let net2 = Network { name: "shuffled".into(), layers: layers2, edges: vec![] };
        let sel2 = Selection { primitive: primitive2, objective_ms: 0.0, estimated_ms: 0.0 };
        assert_eq!(peak, peak_workspace(&net2, &sel2), "case {case}: peak moved");
    }
}

/// With no effective budget constraint, both the Pareto front's fastest
/// endpoint and an infinite-budget point query recover the
/// unconstrained `selection::select` answer bit for bit.
#[test]
fn prop_infinite_budget_front_endpoints_match_unconstrained_select() {
    let sim = Simulator::new(machine::intel_i9_9900k());
    for net in [primsel::networks::alexnet(), primsel::networks::vgg(11)] {
        let free = selection::select(&net, &sim).unwrap();
        let front = ParetoFront::compute(&net, &sim, DEFAULT_LAMBDA_MS_PER_MB).unwrap();
        let fastest = front.fastest_under(f64::INFINITY).unwrap();
        assert_eq!(fastest.selection.primitive, free.primitive, "{}", net.name);
        assert_eq!(fastest.true_time_ms, free.estimated_ms);
        let inf =
            select_with_budget(&net, &sim, f64::INFINITY, DEFAULT_LAMBDA_MS_PER_MB).unwrap();
        assert_eq!(inf.primitive, free.primitive);
        assert_eq!(inf.estimated_ms, free.estimated_ms);
        assert_eq!(inf.objective_ms, inf.estimated_ms, "no penalty at infinite budget");
    }
}

/// Fractions sample without replacement and respect requested sizes.
#[test]
fn prop_fraction_sampling() {
    let mut rng = SplitMix64::new(41);
    for _ in 0..CASES {
        let n = 100 + (rng.next_u64() % 5000) as usize;
        let train: Vec<usize> = (0..n).collect();
        let frac = [0.001, 0.01, 0.1, 0.25][(rng.next_u64() % 4) as usize];
        let idx = dataset::fraction(&train, frac, rng.next_u64());
        let expect = ((n as f64 * frac).round() as usize).max(1);
        assert_eq!(idx.len(), expect);
        let mut sorted = idx.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), idx.len(), "no duplicates");
    }
}

//! Integration tests for the admission-controlled serving layer
//! (`primsel::service`), pinning its three contracts:
//!
//! * **transparency** — served reports are bit-identical to the
//!   synchronous `Coordinator::submit_batch` for the same requests;
//! * **backpressure** — `try_submit` fails with `QueueFull` at
//!   capacity, a deadline submit times out while full, and a blocked
//!   `submit` wakes as workers drain;
//! * **fairness** — a weighted light tenant's small batch completes
//!   while a heavy tenant's earlier flood is still queued, and clean
//!   shutdown drains every admitted ticket.
//!
//! Timing-sensitive tests slow the cost source down (a wrapper that
//! sleeps per *cold* layer query) and give every request a unique layer
//! config so the platform cache cannot absorb the slowness — making
//! "the worker is busy for ~100 ms" a property of the request, not of
//! the host's scheduler mood.

use primsel::coordinator::{Coordinator, Objective, SelectionRequest};
use primsel::layers::ConvConfig;
use primsel::networks::{self, Network};
use primsel::primitives::Layout;
use primsel::selection::CostSource;
use primsel::service::{Service, ServiceConfig, SubmitError};
use primsel::simulator::{machine, Simulator};
use std::borrow::Cow;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A cost source that takes real wall-clock per layer query — the
/// stand-in for an actual on-device profile.
struct SlowSource {
    inner: Simulator,
    delay: Duration,
}

impl SlowSource {
    fn new(delay_ms: u64) -> Self {
        Self {
            inner: Simulator::new(machine::arm_cortex_a73()),
            delay: Duration::from_millis(delay_ms),
        }
    }
}

impl CostSource for SlowSource {
    fn layer_costs(&self, cfg: &ConvConfig) -> Cow<'_, [Option<f64>]> {
        std::thread::sleep(self.delay);
        self.inner.layer_costs(cfg)
    }

    fn dlt_cost(&self, c: u32, im: u32, src: Layout, dst: Layout) -> f64 {
        self.inner.dlt_cost(c, im, src, dst)
    }

    fn dlt_matrix3(&self, c: u32, im: u32) -> [[f64; 3]; 3] {
        self.inner.dlt_matrix3(c, im)
    }
}

/// A small chain network whose layer configs are unique per `tag`, so
/// every request against a caching platform is a cold one.
fn unique_net(tag: u32, n_layers: u32) -> Network {
    let layers: Vec<ConvConfig> = (0..n_layers)
        // im varies with the tag: no two nets share a config, and all
        // configs stay inside the paper's valid ranges
        .map(|i| ConvConfig::new(16 + i, 16, 28 + (tag % 64), 1, 3))
        .collect();
    let edges = (0..n_layers as usize - 1).map(|u| (u, u + 1)).collect();
    Network { name: format!("chain-{tag}"), layers, edges }
}

fn slow_service(delay_ms: u64, capacity: usize, workers: usize) -> Service {
    let coord = Coordinator::shared();
    coord.register("slow", Arc::new(SlowSource::new(delay_ms)));
    Service::new(coord, ServiceConfig::default().with_capacity(capacity).with_workers(workers))
}

fn wait_until(deadline: Duration, mut ok: impl FnMut() -> bool) -> bool {
    let t0 = Instant::now();
    while t0.elapsed() < deadline {
        if ok() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    ok()
}

fn tenant_row(service: &Service, name: &str) -> primsel::service::TenantStats {
    service
        .stats()
        .tenants
        .into_iter()
        .find(|t| t.tenant == name)
        .expect("tenant registered")
}

#[test]
fn service_results_bit_identical_to_synchronous_batch() {
    let coord = Coordinator::shared();
    let mut reqs = Vec::new();
    for (i, net) in networks::selection_networks().into_iter().enumerate() {
        for p in ["intel", "amd", "arm"] {
            let mut req = SelectionRequest::new(net.clone(), p);
            if i % 2 == 0 {
                req = req.with_objective(Objective::MinTimeWithMemoryBudget {
                    budget_bytes: 8.0 * 1024.0 * 1024.0,
                    lambda_ms_per_mb: 5.0,
                });
            }
            reqs.push(req);
        }
    }
    let sync = coord.submit_batch(&reqs).unwrap();

    let service =
        Service::new(Arc::clone(&coord), ServiceConfig::default().with_workers(4));
    let tenants = ["a", "b", "c"];
    let tickets: Vec<_> = reqs
        .iter()
        .enumerate()
        .map(|(i, r)| service.submit(tenants[i % tenants.len()], r.clone()).unwrap())
        .collect();
    for (ticket, expected) in tickets.into_iter().zip(&sync.reports) {
        let served = ticket.wait().unwrap();
        assert_eq!(served.network, expected.network);
        assert_eq!(served.platform, expected.platform);
        assert_eq!(served.selection.primitive, expected.selection.primitive);
        assert_eq!(served.selection.estimated_ms, expected.selection.estimated_ms);
        assert_eq!(served.evaluated_ms, expected.evaluated_ms);
        assert_eq!(served.peak_workspace_bytes, expected.peak_workspace_bytes);
        assert_eq!(served.provenance, expected.provenance);
    }
    let stats = service.stats();
    assert_eq!(stats.tenants.iter().map(|t| t.served).sum::<u64>(), reqs.len() as u64);
    assert_eq!(stats.wait.count, reqs.len() as u64);
    assert_eq!(stats.service.count, reqs.len() as u64);
    service.shutdown();
}

#[test]
fn backpressure_queue_full_then_blocked_submit_wakes_on_drain() {
    // one worker chewing a ~200 ms request, capacity 2: the queue can
    // actually fill
    let service = slow_service(25, 2, 1);
    let req = |tag| SelectionRequest::new(unique_net(tag, 8), "slow");

    let first = service.submit("t", req(0)).unwrap();
    assert!(
        wait_until(Duration::from_secs(5), || tenant_row(&service, "t").inflight == 1),
        "first request must be dispatched"
    );

    // fill the queue to capacity behind the busy worker
    let second = service.submit("t", req(1)).unwrap();
    let third = service.submit("t", req(2)).unwrap();
    assert_eq!(service.stats().queue_depth, 2);

    // non-blocking admission refuses *now*
    match service.try_submit("t", req(3)) {
        Err(SubmitError::QueueFull) => {}
        other => panic!("expected QueueFull, got {other:?}"),
    }
    assert_eq!(tenant_row(&service, "t").rejected, 1);

    // a deadline shorter than the worker's current request times out
    let t0 = Instant::now();
    match service.submit_deadline("t", req(4), Duration::from_millis(30)) {
        Err(SubmitError::Timeout) => assert!(t0.elapsed() >= Duration::from_millis(30)),
        other => panic!("expected Timeout, got {other:?}"),
    }
    assert_eq!(tenant_row(&service, "t").rejected, 2);

    // a blocked submit parks until the worker drains a slot, then admits
    let admit_t0 = Instant::now();
    let fourth = service.submit("t", req(5)).unwrap();
    let blocked_for = admit_t0.elapsed();
    // it can only have been admitted after a pop freed a queue slot,
    // i.e. after the worker finished the ~200 ms first request
    assert!(
        blocked_for >= Duration::from_millis(20),
        "submit returned after {blocked_for:?}, queue never blocked it"
    );

    for t in [first, second, third, fourth] {
        assert!(t.wait().is_ok());
    }
    service.shutdown();
}

#[test]
fn weighted_light_tenant_finishes_while_heavy_backlog_queued() {
    // single worker, ~120 ms per unique request: dispatch order is the
    // whole story
    let service = slow_service(20, 64, 1);
    service.register_tenant("heavy", 1.0, 1).unwrap();
    service.register_tenant("light", 8.0, 1).unwrap();

    // the heavy flood goes in first — under FIFO it would starve
    // everything behind it
    let heavy_n = 8u32;
    let heavy_tickets: Vec<_> = (0..heavy_n)
        .map(|i| {
            service
                .submit("heavy", SelectionRequest::new(unique_net(100 + i, 6), "slow"))
                .unwrap()
        })
        .collect();
    let light_tickets: Vec<_> = (0..3u32)
        .map(|i| {
            service
                .submit("light", SelectionRequest::new(unique_net(200 + i, 6), "slow"))
                .unwrap()
        })
        .collect();

    for t in light_tickets {
        assert!(t.wait().is_ok());
    }
    // the instant the light tenant is fully served, the heavy backlog
    // must still be deep: DRR with 8x weight dispatches at most a
    // couple of heavy requests before the light lane drains
    let heavy = tenant_row(&service, "heavy");
    assert!(
        heavy.queued >= 4,
        "heavy backlog should still be queued, got {heavy:?}"
    );
    assert!(
        heavy.served <= 3,
        "heavy tenant served too much before light finished: {heavy:?}"
    );

    for t in heavy_tickets {
        assert!(t.wait().is_ok());
    }
    let heavy = tenant_row(&service, "heavy");
    assert_eq!(heavy.served, heavy_n as u64);
    assert_eq!(heavy.queued, 0);
    service.shutdown();
}

#[test]
fn clean_shutdown_drains_admitted_tickets() {
    let coord = Coordinator::shared();
    let service =
        Service::new(coord, ServiceConfig::default().with_capacity(64).with_workers(2));
    let tickets: Vec<_> = (0..12)
        .map(|i| {
            let net = networks::selection_networks()[i % 6].clone();
            service.submit("t", SelectionRequest::new(net, "intel")).unwrap()
        })
        .collect();
    // shut down immediately: everything admitted must still be served
    service.shutdown();
    for t in tickets {
        assert!(t.poll(), "shutdown returned before draining");
        assert!(t.wait().is_ok());
    }
}

#[test]
fn errors_flow_through_tickets_and_coordinator_outlives_service() {
    let coord = Coordinator::shared();
    let service = Service::new(Arc::clone(&coord), ServiceConfig::default().with_workers(2));

    // a request for an unknown platform is admitted; the error comes
    // back through the ticket, not the worker's stack
    let bad = service
        .submit("t", SelectionRequest::new(networks::alexnet(), "riscv"))
        .unwrap();
    assert!(bad.wait().is_err());

    let ok = service
        .submit("t", SelectionRequest::new(networks::alexnet(), "intel"))
        .unwrap();
    assert!(ok.wait().is_ok());

    let stats = service.stats();
    assert_eq!(stats.capacity, ServiceConfig::default().capacity);
    assert!(stats.platforms.iter().any(|(p, s)| p == "intel" && s.lookups() > 0));

    service.shutdown();
    // the coordinator (shared handle) survives service shutdown
    assert!(coord
        .submit(&SelectionRequest::new(networks::alexnet(), "intel"))
        .is_ok());
}

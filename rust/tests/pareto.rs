//! Differential, concurrency, and invalidation tests for time×space
//! Pareto-front serving.
//!
//! The front is a combinatorial artifact built by a *reused* solver
//! arena, so every claim here is checked against an independent path:
//!
//! * every front point must be **bit-identical** to a fresh
//!   `select_with_budget` exact solve at that point's budget — on
//!   alexnet and vgg(11), and on the acceptance pair (vgg16, intel)
//!   across all (≥ 8) swept budget levels;
//! * the front itself must be strictly non-dominated and monotone;
//! * `FastestUnderBytes` / `SmallestWithinPct` answers must be
//!   bit-identical cold vs cached, across coordinators, and across
//!   thread interleavings (the concurrency-hammer pattern from
//!   `rust/tests/concurrency.rs`) — and a warm lookup must run **zero**
//!   PBQP solves (pinned via `pbqp::solves_on_thread`);
//! * recalibration — explicit or driven by the health loop's
//!   `FaultySource` machinery — must drop cached fronts: no stale-front
//!   serving.

use primsel::coordinator::{Coordinator, Objective, OnboardSpec, SelectionRequest};
use primsel::dataset::calibration_sample;
use primsel::health::{HealthPolicy, HealthState};
use primsel::networks::{self, Network};
use primsel::pbqp;
use primsel::perfmodel::model::CostModel;
use primsel::perfmodel::LinCostModel;
use primsel::selection::memory::{peak_workspace, select_with_budget};
use primsel::selection::pareto::DEFAULT_LAMBDA_MS_PER_MB;
use primsel::selection::{self, CostSource, FaultySource, ParetoFront};
use primsel::service::{Service, ServiceConfig};
use primsel::simulator::{machine, Simulator};
use std::sync::Arc;
use std::time::Duration;

const THREADS: usize = 8;

fn intel() -> Simulator {
    Simulator::new(machine::intel_i9_9900k())
}

fn front_req(net: &Network, platform: &str, objective: Objective) -> SelectionRequest {
    SelectionRequest::new(net.clone(), platform).with_objective(objective)
}

/// Every front point re-solved from scratch at its own budget must come
/// back bit-identical: same primitives, same penalised objective, same
/// true time, same peak.
fn assert_front_matches_fresh_solves(net: &Network, sim: &Simulator, front: &ParetoFront) {
    for p in &front.points {
        let fresh =
            select_with_budget(net, sim, p.budget_bytes, front.lambda_ms_per_mb).unwrap();
        assert_eq!(
            p.selection.primitive, fresh.primitive,
            "{}: front point at budget {} diverged from the exact solve",
            net.name, p.budget_bytes
        );
        assert_eq!(p.selection.objective_ms, fresh.objective_ms);
        assert_eq!(p.selection.estimated_ms, fresh.estimated_ms);
        assert_eq!(p.true_time_ms, fresh.estimated_ms);
        assert_eq!(p.peak_workspace_bytes, peak_workspace(net, &fresh));
    }
}

#[test]
fn front_points_match_fresh_exact_solves_on_small_nets() {
    let sim = intel();
    for net in [networks::alexnet(), networks::vgg(11)] {
        let front = ParetoFront::compute(&net, &sim, DEFAULT_LAMBDA_MS_PER_MB).unwrap();
        assert!(!front.is_empty());
        assert_front_matches_fresh_solves(&net, &sim, &front);
    }
}

#[test]
fn vgg16_sweep_is_point_identical_to_per_budget_solves_across_levels() {
    // the acceptance pair: (vgg16, intel_i9_9900k)
    let sim = intel();
    let net = networks::vgg(16);
    let front = ParetoFront::compute(&net, &sim, DEFAULT_LAMBDA_MS_PER_MB).unwrap();
    assert!(
        front.swept_budgets.len() >= 8,
        "expected >= 8 distinct budget levels, got {}",
        front.swept_budgets.len()
    );

    // every surviving front point is bit-identical to the exact solve
    assert_front_matches_fresh_solves(&net, &sim, &front);

    // and across >= 8 quantile-sampled swept levels, the exact solve at
    // each level is (weakly) dominated by the front — the sweep solved
    // those levels through the same code path, so a fresh solve can
    // never beat the curve
    let n = front.swept_budgets.len();
    let mut checked = 0;
    for i in 0..8 {
        let b = front.swept_budgets[i * (n - 1) / 7];
        let fresh = select_with_budget(&net, &sim, b, front.lambda_ms_per_mb).unwrap();
        let fresh_peak = peak_workspace(&net, &fresh);
        assert!(
            front.points.iter().any(|p| p.peak_workspace_bytes <= fresh_peak
                && p.true_time_ms <= fresh.estimated_ms),
            "exact solve at budget {b} ({} bytes, {} ms) beats the front",
            fresh_peak,
            fresh.estimated_ms
        );
        checked += 1;
    }
    assert_eq!(checked, 8);
}

#[test]
fn front_is_strictly_nondominated_and_monotone() {
    let sim = intel();
    for net in [networks::alexnet(), networks::vgg(11), networks::vgg(16)] {
        let front = ParetoFront::compute(&net, &sim, DEFAULT_LAMBDA_MS_PER_MB).unwrap();
        for w in front.points.windows(2) {
            assert!(
                w[0].peak_workspace_bytes < w[1].peak_workspace_bytes,
                "{}: peaks must strictly increase",
                net.name
            );
            assert!(
                w[0].true_time_ms > w[1].true_time_ms,
                "{}: times must strictly decrease",
                net.name
            );
        }
        // the fastest point is the unconstrained optimum, bit for bit
        let free = selection::select(&net, &sim).unwrap();
        let fastest = front.fastest_under(f64::INFINITY).unwrap();
        assert_eq!(fastest.selection.primitive, free.primitive);
        assert_eq!(front.optimal_time_ms(), free.estimated_ms);
    }
}

#[test]
fn warm_front_lookup_is_bit_identical_and_runs_zero_pbqp_solves() {
    let net = networks::vgg(16);
    let coord = Coordinator::new();
    let unbounded = Objective::FastestUnderBytes { budget_bytes: f64::INFINITY };

    // cold: computes the front
    let cold = coord.submit(&front_req(&net, "intel", unbounded)).unwrap();
    assert!(!cold.front.as_ref().unwrap().cache_hit);

    // warm: answers from the cached front with ZERO PBQP solves
    let solves_before = pbqp::solves_on_thread();
    let warm = coord.submit(&front_req(&net, "intel", unbounded)).unwrap();
    assert_eq!(
        pbqp::solves_on_thread(),
        solves_before,
        "a warm front lookup must not solve anything"
    );
    let look = warm.front.as_ref().unwrap();
    assert!(look.cache_hit);
    assert_eq!(warm.selection.primitive, cold.selection.primitive);
    assert_eq!(warm.selection.estimated_ms, cold.selection.estimated_ms);
    assert_eq!(warm.evaluated_ms, cold.evaluated_ms);
    assert_eq!(warm.peak_workspace_bytes, cold.peak_workspace_bytes);
    let (hits, misses) = coord.front_cache_stats();
    assert_eq!((hits, misses), (1, 1));

    // a second, cold coordinator answers bit-identically
    let other = Coordinator::new();
    let twin = other.submit(&front_req(&net, "intel", unbounded)).unwrap();
    assert_eq!(twin.selection.primitive, cold.selection.primitive);
    assert_eq!(twin.evaluated_ms, cold.evaluated_ms);
}

#[test]
fn front_answers_are_stable_across_thread_interleavings() {
    // the concurrency-hammer pattern: one shared coordinator, THREADS
    // threads firing mixed front objectives, every answer compared to a
    // single-threaded reference
    let net = networks::vgg(11);
    let coord = Coordinator::shared();
    let front = coord.pareto_front("intel", &net).unwrap();

    // reference objectives: one hard budget pinning each front point,
    // plus the unbounded query and a pct-slack query
    let mut objectives: Vec<Objective> = front
        .points
        .iter()
        .map(|p| Objective::FastestUnderBytes { budget_bytes: p.peak_workspace_bytes })
        .collect();
    objectives.push(Objective::FastestUnderBytes { budget_bytes: f64::INFINITY });
    objectives.push(Objective::SmallestWithinPct { pct_of_optimal_time: 0.0 });
    objectives.push(Objective::SmallestWithinPct { pct_of_optimal_time: 1e9 });
    let reference: Vec<_> = objectives
        .iter()
        .map(|&o| coord.submit(&front_req(&net, "intel", o)).unwrap())
        .collect();

    std::thread::scope(|s| {
        for t in 0..THREADS {
            let coord = Arc::clone(&coord);
            let net = &net;
            let objectives = &objectives;
            let reference = &reference;
            s.spawn(move || {
                // each thread walks the objective list from a different
                // offset so lookups interleave differently every run
                for k in 0..objectives.len() * 2 {
                    let i = (t + k) % objectives.len();
                    let rep = coord.submit(&front_req(net, "intel", objectives[i])).unwrap();
                    assert_eq!(rep.selection.primitive, reference[i].selection.primitive);
                    assert_eq!(rep.selection.estimated_ms, reference[i].selection.estimated_ms);
                    assert_eq!(rep.evaluated_ms, reference[i].evaluated_ms);
                    assert_eq!(rep.peak_workspace_bytes, reference[i].peak_workspace_bytes);
                    assert!(rep.front.unwrap().cache_hit, "front was warmed up front");
                }
            });
        }
    });
}

#[test]
fn explicit_recalibration_drops_the_cached_front() {
    let coord = Coordinator::new();
    let target: Arc<dyn CostSource> = Arc::new(Simulator::new(machine::arm_cortex_a73()));
    coord.onboard_platform("arm-lin", OnboardSpec::fresh_lin(target, 0.02, 7)).unwrap();
    let net = networks::alexnet();

    let first = coord.pareto_front("arm-lin", &net).unwrap();
    let warm = coord.pareto_front("arm-lin", &net).unwrap();
    assert!(Arc::ptr_eq(&first, &warm), "repeat lookups serve the same cached front");

    coord.recalibrate_platform("arm-lin", 0.04, 99).unwrap();

    // the first post-recal front query recomputes — cache_hit says so
    let rep = coord
        .submit(&front_req(
            &net,
            "arm-lin",
            Objective::FastestUnderBytes { budget_bytes: f64::INFINITY },
        ))
        .unwrap();
    assert!(!rep.front.unwrap().cache_hit, "recalibration must drop the cached front");

    // and the recomputed front is exactly what the refreshed serving
    // cache yields
    let fresh = coord.pareto_front("arm-lin", &net).unwrap();
    assert!(!Arc::ptr_eq(&first, &fresh));
    let direct = ParetoFront::compute(
        &net,
        coord.cache("arm-lin").unwrap().as_ref(),
        DEFAULT_LAMBDA_MS_PER_MB,
    )
    .unwrap();
    assert_eq!(fresh.points.len(), direct.points.len());
    for (a, b) in fresh.points.iter().zip(&direct.points) {
        assert_eq!(a.selection.primitive, b.selection.primitive);
        assert_eq!(a.true_time_ms, b.true_time_ms);
        assert_eq!(a.peak_workspace_bytes, b.peak_workspace_bytes);
    }
}

#[test]
fn health_loop_auto_recalibration_drops_the_cached_front() {
    // the fault-injection machinery from rust/tests/health.rs: a
    // transfer-onboarded platform whose live device drifts, monitored
    // with a tight policy so the auto-recal fires within a few requests
    let faulty = Arc::new(FaultySource::new(
        Arc::new(Simulator::new(machine::arm_cortex_a73())),
        101,
    ));
    let target: Arc<dyn CostSource> = Arc::clone(&faulty) as Arc<dyn CostSource>;
    let intel_sim = intel();
    let (prim, dlt) = calibration_sample(&intel_sim, 0.1, 3);
    let source: Arc<dyn CostModel + Send + Sync> =
        Arc::new(LinCostModel::fit(&prim, &dlt, "intel").unwrap());

    let coord = Coordinator::new();
    coord
        .onboard_platform("arm-live", OnboardSpec::transfer(Arc::clone(&target), source, 0.02, 5))
        .unwrap();
    coord
        .monitor_platform(
            "arm-live",
            target,
            HealthPolicy::default()
                .with_sampling(1.0, 11)
                .with_window(24, 8)
                .with_drift_band(0.75)
                .with_auto_recalibrate(true, 0.02)
                .with_quarantine(3, Duration::ZERO, Duration::from_millis(200)),
        )
        .unwrap();
    let net = networks::alexnet();

    let before = coord.pareto_front("arm-live", &net).unwrap();

    // drift the device and drive traffic until the health loop repairs
    faulty.set_drift(3.0);
    let mut recalibrated = false;
    for _ in 0..50 {
        let _ = coord.submit(&SelectionRequest::new(net.clone(), "arm-live"));
        let h = coord.platform_health_of("arm-live").unwrap();
        if h.recalibrations >= 1 {
            recalibrated = true;
            break;
        }
    }
    assert!(recalibrated, "auto-recalibration never fired");
    assert_eq!(coord.platform_health_of("arm-live").unwrap().state, HealthState::Healthy);

    // the auto-recal swapped the serving cache, so the cached front is
    // gone: the next lookup recomputes over the healed model
    let rep = coord
        .submit(&front_req(
            &net,
            "arm-live",
            Objective::FastestUnderBytes { budget_bytes: f64::INFINITY },
        ))
        .unwrap();
    assert!(!rep.front.unwrap().cache_hit, "auto-recal must drop the cached front");
    let after = coord.pareto_front("arm-live", &net).unwrap();
    assert!(!Arc::ptr_eq(&before, &after));
    let (_, misses) = coord.front_cache_stats();
    assert!(misses >= 2, "both generations were computed, got {misses} misses");
}

#[test]
fn front_objectives_through_service_tickets_match_direct_submits() {
    let net = networks::vgg(11);
    let coord = Coordinator::shared();
    let objectives = [
        Objective::FastestUnderBytes { budget_bytes: f64::INFINITY },
        Objective::SmallestWithinPct { pct_of_optimal_time: 5.0 },
    ];
    let direct: Vec<_> = objectives
        .iter()
        .map(|&o| coord.submit(&front_req(&net, "intel", o)).unwrap())
        .collect();

    let service = Service::new(
        Arc::clone(&coord),
        ServiceConfig::default().with_capacity(16).with_workers(2),
    );
    for (o, d) in objectives.iter().zip(&direct) {
        let ticket = service.submit("tenant", front_req(&net, "intel", *o)).unwrap();
        let rep = ticket.wait().unwrap();
        assert_eq!(rep.selection.primitive, d.selection.primitive);
        assert_eq!(rep.evaluated_ms, d.evaluated_ms);
        assert_eq!(rep.peak_workspace_bytes, d.peak_workspace_bytes);
        // the direct submits warmed the front, so tickets hit the cache
        assert!(rep.front.unwrap().cache_hit);
    }
    service.shutdown();
}

//! Concurrency tests for the shared cost cache and the coordinator
//! (loom-free: plain `std::thread` hammering with deterministic inputs).
//! The invariant under test everywhere: sharing one warm cache across
//! threads changes *nothing* about the answers — rows, matrices,
//! selections and objectives are bit-identical to a fresh
//! single-threaded cache.

use primsel::coordinator::{Coordinator, Objective, SelectionRequest};
use primsel::layers::ConvConfig;
use primsel::networks;
use primsel::selection::{self, memory, CostCache, CostSource};
use primsel::simulator::noise::SplitMix64;
use primsel::simulator::{machine, Simulator};
use std::sync::Arc;

const THREADS: usize = 8;

fn rand_cfg(rng: &mut SplitMix64) -> ConvConfig {
    let k = 1 + (rng.next_u64() % 512) as u32;
    let c = 1 + (rng.next_u64() % 512) as u32;
    let im = 7 + (rng.next_u64() % 220) as u32;
    let s = [1u32, 2, 4][(rng.next_u64() % 3) as usize];
    let f = [1u32, 3, 5, 7, 9, 11][(rng.next_u64() % 6) as usize];
    ConvConfig::new(k, c, im, s, f)
}

/// Many threads hammer one shared cache — overlapping key sets, every
/// thread interleaving row and matrix queries in its own order — and
/// every answer must equal what a fresh single-threaded cache returns.
#[test]
fn shared_cache_hammer_is_bit_identical_to_single_threaded() {
    let sim = Simulator::new(machine::intel_i9_9900k());
    let shared = CostCache::new(&sim);

    // a pool of configs with deliberate duplicates so threads collide on
    // hot keys as well as racing on cold ones
    let mut rng = SplitMix64::new(0xC0FFEE);
    let mut pool: Vec<ConvConfig> = (0..96).map(|_| rand_cfg(&mut rng)).collect();
    let dups = pool[..32].to_vec();
    pool.extend(dups);

    std::thread::scope(|s| {
        for t in 0..THREADS {
            let shared = &shared;
            let sim = &sim;
            let pool = &pool;
            s.spawn(move || {
                // per-thread visit order, seeded differently per thread
                let mut rng = SplitMix64::new(0xAB + t as u64);
                for _ in 0..3 {
                    for _ in 0..pool.len() {
                        let cfg = &pool[(rng.next_u64() as usize) % pool.len()];
                        assert_eq!(
                            shared.row(cfg).as_ref(),
                            sim.profile_layer(cfg).as_slice(),
                            "shared row must equal direct profile"
                        );
                        assert_eq!(shared.matrix(cfg.c, cfg.im), sim.dlt_matrix(cfg.c, cfg.im));
                    }
                }
            });
        }
    });

    // post-conditions: the shared cache holds exactly what a fresh
    // single-threaded cache would, key for key and bit for bit
    let fresh = CostCache::new(&sim);
    for cfg in &pool {
        assert_eq!(shared.row(cfg).as_ref(), fresh.row(cfg).as_ref());
        assert_eq!(shared.layer_costs(cfg), fresh.layer_costs(cfg));
        assert_eq!(shared.matrix(cfg.c, cfg.im), fresh.matrix(cfg.c, cfg.im));
    }
    let distinct = {
        let mut v = pool.clone();
        v.sort_by_key(|c| (c.k, c.c, c.im, c.s, c.f));
        v.dedup();
        v.len()
    };
    assert_eq!(shared.rows_cached(), distinct);
    let stats = shared.stats();
    // every lookup was counted, and the overwhelming majority were hits
    assert!(stats.lookups() >= (THREADS * 3 * pool.len()) as u64);
    // even in the pathological schedule where every thread double-misses
    // every cold key, hits still dominate (bounds: ≥ 3072 row lookups,
    // ≤ THREADS × distinct = 768 misses)
    assert!(stats.row_hits > stats.row_misses * 2, "{stats:?}");
}

/// Concurrent *selection* through one shared cache: every thread's
/// result must be bit-identical to the sequential fresh-cache result,
/// for both the plain and the memory-budgeted objectives.
#[test]
fn concurrent_selection_matches_single_threaded() {
    let sim = Simulator::new(machine::amd_a10_7850k());
    let nets = networks::selection_networks();

    // sequential ground truth, one fresh cache per network
    let expected: Vec<_> = nets
        .iter()
        .map(|net| {
            let cache = CostCache::new(&sim);
            let sel = selection::select(net, &cache).unwrap();
            let ev = selection::evaluate(net, &sel, &cache).unwrap();
            let budgeted =
                memory::select_with_budget(net, &cache, 4.0 * 1024.0 * 1024.0, 10.0).unwrap();
            (sel, ev, budgeted)
        })
        .collect();

    let shared = CostCache::new(&sim);
    std::thread::scope(|s| {
        for t in 0..THREADS {
            let shared = &shared;
            let nets = &nets;
            let expected = &expected;
            s.spawn(move || {
                // stagger starting points so threads hit different
                // networks (and so different cache keys) simultaneously
                for i in 0..nets.len() {
                    let n = (i + t) % nets.len();
                    let (exp_sel, exp_ev, exp_budgeted) = &expected[n];
                    let sel = selection::select(&nets[n], shared).unwrap();
                    assert_eq!(sel.primitive, exp_sel.primitive, "{}", nets[n].name);
                    assert_eq!(sel.estimated_ms, exp_sel.estimated_ms);
                    let ev = selection::evaluate(&nets[n], &sel, shared).unwrap();
                    assert_eq!(ev, *exp_ev);
                    let budgeted = memory::select_with_budget(
                        &nets[n],
                        shared,
                        4.0 * 1024.0 * 1024.0,
                        10.0,
                    )
                    .unwrap();
                    assert_eq!(budgeted.primitive, exp_budgeted.primitive);
                    assert_eq!(budgeted.estimated_ms, exp_budgeted.estimated_ms);
                }
            });
        }
    });
}

/// An owned-source shared cache (`new_shared`, the coordinator's shape)
/// behaves exactly like the borrowed one under the same hammer.
#[test]
fn shared_arc_cache_matches_borrowed() {
    let sim = Simulator::new(machine::arm_cortex_a73());
    let owned = Arc::new(CostCache::new_shared(Arc::new(sim.clone())));
    let net = networks::googlenet();

    let handles: Vec<_> = (0..THREADS)
        .map(|_| {
            let cache = Arc::clone(&owned);
            let net = net.clone();
            std::thread::spawn(move || selection::select(&net, cache.as_ref()).unwrap())
        })
        .collect();
    let expected = selection::select(&net, &CostCache::new(&sim)).unwrap();
    for h in handles {
        let sel = h.join().unwrap();
        assert_eq!(sel.primitive, expected.primitive);
        assert_eq!(sel.estimated_ms, expected.estimated_ms);
    }
}

/// Coordinator batch over mixed networks/platforms/objectives: reports
/// come back in request order and match sequential per-request
/// selection with fresh caches.
#[test]
fn coordinator_batch_matches_sequential_selection() {
    let coord = Coordinator::new();
    let platforms = ["intel", "amd", "arm"];
    let nets = networks::selection_networks();

    let mut reqs = Vec::new();
    for net in &nets {
        for p in platforms {
            reqs.push(SelectionRequest::new(net.clone(), p));
        }
    }
    // memory-budgeted tenants in the same batch
    for p in platforms {
        reqs.push(SelectionRequest::new(networks::vgg(16), p).with_objective(
            Objective::MinTimeWithMemoryBudget {
                budget_bytes: 8.0 * 1024.0 * 1024.0,
                lambda_ms_per_mb: 5.0,
            },
        ));
    }

    let batch = coord.submit_batch(&reqs).unwrap();
    assert_eq!(batch.reports.len(), reqs.len());
    assert_eq!(batch.stats.len(), platforms.len());

    for (req, rep) in reqs.iter().zip(&batch.reports) {
        assert_eq!(rep.network, req.network.name);
        assert_eq!(rep.platform, req.platform);

        let sim = Simulator::new(machine::by_name(&req.platform).unwrap());
        let fresh = CostCache::new(&sim);
        let expected = match req.objective {
            Objective::MinTime => selection::select(&req.network, &fresh).unwrap(),
            Objective::MinTimeWithMemoryBudget { budget_bytes, lambda_ms_per_mb } => {
                memory::select_with_budget(&req.network, &fresh, budget_bytes, lambda_ms_per_mb)
                    .unwrap()
            }
            other => unreachable!("this batch contains no front objectives: {other:?}"),
        };
        assert_eq!(rep.selection.primitive, expected.primitive, "{}/{}", rep.network, rep.platform);
        assert_eq!(rep.selection.estimated_ms, expected.estimated_ms);
        assert_eq!(
            rep.evaluated_ms,
            selection::evaluate(&req.network, &expected, &fresh).unwrap()
        );
        assert_eq!(rep.peak_workspace_bytes, memory::peak_workspace(&req.network, &expected));
    }

    // a second identical batch is served from the compiled plans: zero
    // cache traffic of any kind (no re-profiling, no re-reads — the
    // plans froze the rows), identical reports
    let warm = coord.submit_batch(&reqs).unwrap();
    for (_, s) in &warm.stats {
        assert_eq!(s.lookups(), 0, "warm batch is plan-served: {s:?}");
    }
    for (a, b) in batch.reports.iter().zip(&warm.reports) {
        assert_eq!(a.selection.primitive, b.selection.primitive);
        assert_eq!(a.selection.estimated_ms, b.selection.estimated_ms);
        assert_eq!(a.evaluated_ms, b.evaluated_ms);
    }
}

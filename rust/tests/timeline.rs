//! Golden tests for the Chrome trace-event timeline export: the file
//! `write_chrome_trace` produces must parse as JSON, carry the fields
//! the Chrome tracing UI / Perfetto require (`ph`, `ts`, `dur`, `pid`,
//! `tid`), keep `ts` monotone per `(pid, tid)` in array order, and name
//! every process it references — the same contract CI's
//! `check_timeline.py` enforces on a real `serve_zoo` run.

use primsel::config::Json;
use primsel::coordinator::{Coordinator, SelectionRequest};
use primsel::networks;
use primsel::obs::{self, chrome_trace, write_chrome_trace, FlightRecorder, Stage, Trace};
use primsel::service::{Service, ServiceConfig};
use std::collections::{BTreeMap, BTreeSet};

fn field<'a>(e: &'a Json, key: &str) -> &'a str {
    e.get(key).unwrap().as_str().unwrap()
}

fn num(e: &Json, key: &str) -> f64 {
    e.get(key).unwrap().as_f64().unwrap()
}

/// The shared golden checks: Chrome-required fields on every event,
/// non-negative durations, per-(pid, tid) ts monotonicity in array
/// order, and process_name metadata covering every referenced pid.
fn assert_loadable(trace: &Json) {
    let events = trace.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty(), "timeline must contain events");
    assert_eq!(trace.get("displayTimeUnit").unwrap().as_str().unwrap(), "ms");

    let mut named_pids = BTreeSet::new();
    let mut seen_pids = BTreeSet::new();
    let mut last_ts: BTreeMap<(u64, u64), f64> = BTreeMap::new();
    for e in events {
        let ph = field(e, "ph");
        let pid = num(e, "pid") as u64;
        seen_pids.insert(pid);
        match ph {
            "X" => {
                assert!(!field(e, "name").is_empty());
                assert!(num(e, "dur") >= 0.0, "negative span duration");
                let key = (pid, num(e, "tid") as u64);
                let ts = num(e, "ts");
                if let Some(&prev) = last_ts.get(&key) {
                    assert!(ts >= prev, "ts regressed on pid/tid {key:?}");
                }
                last_ts.insert(key, ts);
            }
            "i" => {
                assert_eq!(field(e, "s"), "g", "instants must be global-scoped");
                assert!(e.get("ts").is_ok());
            }
            "M" => {
                if field(e, "name") == "process_name" {
                    named_pids.insert(pid);
                }
            }
            other => panic!("unexpected event phase {other:?}"),
        }
    }
    for pid in &seen_pids {
        assert!(named_pids.contains(pid), "pid {pid} has no process_name metadata");
    }
}

/// A deterministic ladder through a private recorder, written to disk
/// and read back — the full export path, no service involved.
#[test]
fn written_timeline_round_trips_through_disk() {
    let rec = FlightRecorder::new(8, 4, 8);
    for (i, net) in ["alexnet", "vgg11", "googlenet"].iter().enumerate() {
        let t = Trace::begin();
        let base = i as u64 * 50_000;
        t.mark_at_ns(Stage::Admit, base);
        t.mark_at_ns(Stage::Dispatch, base + 10_000);
        t.mark_at_ns(Stage::SolveStart, base + 20_000);
        t.mark_at_ns(Stage::SolveEnd, base + 30_000);
        t.mark_at_ns(Stage::Done, base + 40_000);
        rec.record_request(&t, if i % 2 == 0 { "intel" } else { "arm" }, net, "golden");
    }
    rec.record_transition("intel", "healthy", "drifting", 1.5);
    rec.record_alert("queue-pressure", "ok", "warning", 1.2);

    let path = std::env::temp_dir().join(format!("primsel_timeline_{}.json", std::process::id()));
    write_chrome_trace(&rec, &path).expect("export writes");
    let text = std::fs::read_to_string(&path).expect("file exists");
    std::fs::remove_file(&path).ok();
    let trace = Json::parse(&text).expect("timeline must be valid JSON");
    assert_loadable(&trace);

    let events = trace.get("traceEvents").unwrap().as_arr().unwrap();
    let names: Vec<&str> = events.iter().map(|e| field(e, "name")).collect();
    assert!(names.contains(&"alexnet"), "umbrella span per request");
    assert!(
        names.iter().any(|n| n.contains("->") && !n.contains(": ")),
        "adjacent stage pairs become spans: {names:?}"
    );
    assert!(names.contains(&"transition: healthy->drifting"));
    assert!(names.contains(&"alert: ok->warning"));
    // both platforms became processes, alerts ride the ops pid 0
    let alert = events.iter().find(|e| field(e, "name").starts_with("alert:")).unwrap();
    assert_eq!(num(alert, "pid"), 0.0, "alerts belong to the ops process");
}

/// Real traffic: a service workload fills the process recorder, and the
/// export of *that* passes the same golden checks — what
/// `serve_zoo --timeline` ships.
#[test]
fn service_workload_exports_a_loadable_timeline() {
    let service = Service::new(
        Coordinator::shared(),
        ServiceConfig::default().with_capacity(8).with_workers(2),
    );
    let tickets: Vec<_> = (0..6)
        .map(|i| {
            let net = if i % 2 == 0 { networks::alexnet() } else { networks::vgg(11) };
            service
                .submit("timeline", SelectionRequest::new(net, "intel"))
                .expect("admission")
        })
        .collect();
    for t in tickets {
        t.wait().expect("served");
    }
    obs::flight_recorder().record_transition("intel", "healthy", "drifting", 0.5);

    let trace = chrome_trace(obs::flight_recorder());
    assert_loadable(&trace);
    let events = trace.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(
        events.iter().any(|e| field(e, "ph") == "X" && field(e, "cat") == "request"),
        "served requests must appear as umbrella spans"
    );
    assert!(
        events.iter().any(|e| field(e, "ph") == "i"),
        "health events must appear as instants"
    );
    service.shutdown();
}

//! End-to-end self-healing: the drift → recalibrate → quarantine loop
//! driven entirely through the public serving surface, with every fault
//! injected deterministically by [`FaultySource`] under a fixed seed.
//!
//! * a transfer-onboarded platform whose device drifts 3x walks
//!   Healthy → Drifting → (auto) Recalibrating → Healthy, and the healed
//!   model's zoo selections stay within the onboarding acceptance bound
//!   (10%) of profiled-optimal **on the drifted device**;
//! * when recalibration itself keeps failing (error injection), the
//!   platform quarantines and every `Service::submit` ticket resolves —
//!   never hangs — with a typed [`QuarantinedError`], while other
//!   platforms keep serving;
//! * clearing the fault and waiting out the cool-down lets the next
//!   admission probe-recalibrate and readmit the platform;
//! * the same loop heals fresh-Lin-onboarded platforms (full refit path);
//! * a monitor at sampling fraction 0 is free: selections are
//!   bit-identical to an unmonitored twin and the live target sees zero
//!   shadow queries.

use primsel::coordinator::{Coordinator, CostProvenance, OnboardSpec, SelectionRequest};
use primsel::dataset::calibration_sample;
use primsel::health::{HealthPolicy, HealthState, QuarantinedError};
use primsel::networks::{self, Network};
use primsel::perfmodel::model::CostModel;
use primsel::perfmodel::LinCostModel;
use primsel::selection::{self, CostSource, FaultySource};
use primsel::service::{Service, ServiceConfig};
use primsel::simulator::{machine, Simulator};
use std::sync::Arc;
use std::time::Duration;

/// A faulty live ARM device: the simulator wrapped in seeded fault
/// injection, handed out both as the concrete handle (to flip faults)
/// and as the `CostSource` the coordinator sees.
fn faulty_arm(seed: u64) -> (Arc<FaultySource>, Arc<dyn CostSource>) {
    let f = Arc::new(FaultySource::new(
        Arc::new(Simulator::new(machine::arm_cortex_a73())),
        seed,
    ));
    (Arc::clone(&f), f as Arc<dyn CostSource>)
}

/// An Intel-trained Lin source model (the §4.4 "factory" platform).
fn intel_lin() -> Arc<dyn CostModel + Send + Sync> {
    let intel = Simulator::new(machine::intel_i9_9900k());
    let (prim, dlt) = calibration_sample(&intel, 0.1, 3);
    Arc::new(LinCostModel::fit(&prim, &dlt, "intel").unwrap())
}

/// Tight monitor policy: replay everything, small window, no backoff —
/// transitions happen within a handful of requests, deterministically.
fn tight(seed: u64, band: f64, max_failures: u32, cool_down: Duration) -> HealthPolicy {
    HealthPolicy::default()
        .with_sampling(1.0, seed)
        .with_window(24, 8)
        .with_drift_band(band)
        .with_auto_recalibrate(true, 0.02)
        .with_quarantine(max_failures, Duration::ZERO, cool_down)
}

/// Drive requests at `platform` until `done(health)` holds (or panic
/// after `max` requests). Submission errors are tolerated — a
/// quarantined platform refuses, which some callers drive *toward*.
fn drive(
    coord: &Coordinator,
    platform: &str,
    net: &Network,
    max: usize,
    done: impl Fn(&primsel::health::PlatformHealth) -> bool,
) {
    for _ in 0..max {
        let _ = coord.submit(&SelectionRequest::new(net.clone(), platform));
        let h = coord.platform_health_of(platform).expect("platform is monitored");
        if done(&h) {
            return;
        }
    }
    let h = coord.platform_health_of(platform).unwrap();
    panic!("condition not reached after {max} requests; last health: {h:?}");
}

#[test]
fn drifted_platform_heals_itself_and_serves_the_zoo_within_tolerance() {
    let (faulty, target) = faulty_arm(101);
    let coord = Coordinator::new();
    coord
        .onboard_platform("arm-live", OnboardSpec::transfer(target.clone(), intel_lin(), 0.02, 5))
        .unwrap();
    coord
        .monitor_platform("arm-live", target, tight(11, 0.75, 3, Duration::from_millis(200)))
        .unwrap();
    let net = networks::alexnet();

    // pre-drift traffic: the monitor sees agreement and stays Healthy
    for _ in 0..3 {
        coord.submit(&SelectionRequest::new(net.clone(), "arm-live")).unwrap();
    }
    let h = coord.platform_health_of("arm-live").unwrap();
    assert_eq!(h.state, HealthState::Healthy);
    assert_eq!(h.sampled, h.observed, "fraction 1.0 replays every request");
    assert!(h.sampled >= 3);

    // the device drifts 3x (column-spread): evidence accumulates past
    // the band, a later request detects it, the next one auto-repairs
    faulty.set_drift(3.0);
    drive(&coord, "arm-live", &net, 40, |h| h.state == HealthState::Drifting);
    assert_eq!(coord.platform_health_of("arm-live").unwrap().recalibrations, 0);
    drive(&coord, "arm-live", &net, 10, |h| h.recalibrations >= 1);

    let healed = coord.platform_health_of("arm-live").unwrap();
    assert_eq!(healed.state, HealthState::Healthy, "{healed:?}");
    assert_eq!(healed.consecutive_failures, 0);
    assert_eq!(healed.quarantines, 0);

    // the healed model serves the zoo within the onboarding acceptance
    // bound, measured against the *drifted* device
    let mut total_model = 0.0;
    let mut total_prof = 0.0;
    for zoo_net in networks::selection_networks() {
        let rep = coord.submit(&SelectionRequest::new(zoo_net.clone(), "arm-live")).unwrap();
        let live: &dyn CostSource = faulty.as_ref();
        let profiled = selection::select(&zoo_net, live).unwrap();
        total_model += selection::evaluate(&zoo_net, &rep.selection, live).unwrap();
        total_prof += selection::evaluate(&zoo_net, &profiled, live).unwrap();
    }
    let increase = total_model / total_prof - 1.0;
    assert!(
        increase < 0.10,
        "healed zoo selections {:.2}% worse than profiled-on-drifted (bound: 10%)",
        increase * 100.0
    );
    // the monitor agrees the healed model fits the drifted device
    assert_eq!(coord.platform_health_of("arm-live").unwrap().state, HealthState::Healthy);
}

#[test]
fn failing_recalibration_quarantines_and_tickets_resolve_with_typed_errors() {
    let (faulty, target) = faulty_arm(202);
    let coord = Coordinator::shared();
    coord
        .onboard_platform("arm-sick", OnboardSpec::transfer(target.clone(), intel_lin(), 0.02, 7))
        .unwrap();
    coord
        .monitor_platform("arm-sick", target, tight(13, 0.75, 2, Duration::from_millis(150)))
        .unwrap();
    let net = networks::alexnet();

    // drift hard, then make every target query panic: detection already
    // happened, so each later request burns one recalibration attempt
    faulty.set_drift(9.0);
    drive(&coord, "arm-sick", &net, 40, |h| h.state == HealthState::Drifting);
    faulty.set_error_rate(1.0);
    drive(&coord, "arm-sick", &net, 10, |h| h.state == HealthState::Quarantined);

    let sick = coord.platform_health_of("arm-sick").unwrap();
    assert_eq!(sick.quarantines, 1);
    assert!(sick.recal_failures >= 2);
    assert!(!sick.state.is_serving());

    // a direct submit refuses with the typed error (not a string match)
    let err = coord.submit(&SelectionRequest::new(net.clone(), "arm-sick")).unwrap_err();
    let q = err.downcast_ref::<QuarantinedError>().expect("typed quarantine error");
    assert_eq!(q.platform, "arm-sick");
    assert!(q.consecutive_failures >= 2);

    // through the service: every quarantined ticket RESOLVES (no hangs)
    // with the same typed error, while another platform keeps serving
    let service = Service::new(
        Arc::clone(&coord),
        ServiceConfig::default().with_capacity(32).with_workers(2),
    );
    let mut sick_tickets = Vec::new();
    for _ in 0..6 {
        let req = SelectionRequest::new(net.clone(), "arm-sick");
        sick_tickets.push(service.submit("tenant-a", req).unwrap());
    }
    let ok_ticket =
        service.submit("tenant-b", SelectionRequest::new(net.clone(), "intel")).unwrap();
    for t in sick_tickets {
        let resolved = t
            .wait_timeout(Duration::from_secs(30))
            .unwrap_or_else(|_| panic!("quarantined ticket must resolve, not hang"));
        let err = resolved.unwrap_err();
        assert!(err.downcast_ref::<QuarantinedError>().is_some(), "{err}");
    }
    assert_eq!(ok_ticket.wait().unwrap().platform, "intel");
    let stats = service.stats();
    assert_eq!(stats.health.len(), 1);
    assert_eq!(stats.health[0].platform, "arm-sick");
    let rendered = stats.render();
    assert!(rendered.contains("platform health") && rendered.contains("quarantined"));
    service.shutdown();

    // clear the fault and wait out the cool-down: the next admission
    // probes, the probe-recalibration succeeds, and the platform serves
    // again — healed against the drifted device it now matches
    faulty.set_error_rate(0.0);
    std::thread::sleep(Duration::from_millis(200));
    let rep = coord.submit(&SelectionRequest::new(net.clone(), "arm-sick")).unwrap();
    assert!(rep.evaluated_ms > 0.0);
    let healed = coord.platform_health_of("arm-sick").unwrap();
    assert_eq!(healed.state, HealthState::Healthy);
    assert!(healed.recalibrations >= 1);
    assert_eq!(healed.consecutive_failures, 0);
}

#[test]
fn fresh_lin_platform_heals_via_full_refit() {
    let (faulty, target) = faulty_arm(303);
    let coord = Coordinator::new();
    coord
        .onboard_platform("lin-live", OnboardSpec::fresh_lin(target.clone(), 0.02, 21))
        .unwrap();
    coord
        .monitor_platform("lin-live", target, tight(17, 0.8, 3, Duration::from_millis(200)))
        .unwrap();
    let net = networks::vgg(11);

    for _ in 0..3 {
        coord.submit(&SelectionRequest::new(net.clone(), "lin-live")).unwrap();
    }
    assert_eq!(coord.platform_health_of("lin-live").unwrap().state, HealthState::Healthy);

    faulty.set_drift(4.0);
    drive(&coord, "lin-live", &net, 40, |h| h.recalibrations >= 1);
    let healed = coord.platform_health_of("lin-live").unwrap();
    assert_eq!(healed.state, HealthState::Healthy, "{healed:?}");

    // the refit path kept the platform model-served under the same kind
    match coord.provenance("lin-live").unwrap() {
        CostProvenance::Predicted { model_kind, .. } => assert_eq!(model_kind, "lin"),
        other => panic!("expected predicted provenance, got {other:?}"),
    }
    assert!(coord.submit(&SelectionRequest::new(net, "lin-live")).unwrap().evaluated_ms > 0.0);
}

#[test]
fn monitor_at_fraction_zero_is_bit_identical_and_query_free() {
    // twin coordinators over identically-seeded faulty targets: one
    // monitored at sampling fraction 0, one not monitored at all
    let (faulty_a, target_a) = faulty_arm(404);
    let (faulty_b, target_b) = faulty_arm(404);
    let monitored = Coordinator::new();
    let plain = Coordinator::new();
    monitored
        .onboard_platform("arm-twin", OnboardSpec::fresh_lin(target_a.clone(), 0.02, 9))
        .unwrap();
    plain.onboard_platform("arm-twin", OnboardSpec::fresh_lin(target_b, 0.02, 9)).unwrap();
    monitored
        .monitor_platform(
            "arm-twin",
            target_a,
            tight(19, 0.75, 3, Duration::from_millis(200)).with_sampling(0.0, 19),
        )
        .unwrap();
    assert_eq!(faulty_a.queries(), faulty_b.queries(), "identical onboarding draws");
    let after_onboard = faulty_a.queries();

    let reqs: Vec<SelectionRequest> = networks::selection_networks()
        .into_iter()
        .flat_map(|n| {
            vec![
                SelectionRequest::new(n.clone(), "arm-twin"),
                SelectionRequest::new(n, "arm-twin"),
            ]
        })
        .collect();
    let a = monitored.submit_batch(&reqs).unwrap();
    let b = plain.submit_batch(&reqs).unwrap();
    for (ra, rb) in a.reports.iter().zip(&b.reports) {
        assert_eq!(ra.selection.primitive, rb.selection.primitive);
        assert_eq!(ra.selection.estimated_ms, rb.selection.estimated_ms);
        assert_eq!(ra.evaluated_ms, rb.evaluated_ms);
    }

    // the fraction-0 monitor saw the traffic but replayed none of it:
    // zero extra queries ever reached the live target
    let h = monitored.platform_health_of("arm-twin").unwrap();
    assert_eq!(h.observed, reqs.len() as u64);
    assert_eq!(h.sampled, 0);
    assert_eq!(faulty_a.queries(), after_onboard, "warm path must add no shadow traffic");
    assert_eq!(h.state, HealthState::Healthy);
}

//! Minimal dense linear algebra: just enough for the paper's linear
//! regression baseline (ordinary least squares via normal equations and
//! Cholesky) — no external BLAS.

/// Column-major-free, row-major dense matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Self { rows: r, cols: c, data }
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] = v;
    }

    /// self^T * self (Gram matrix), k x k for an n x k input.
    pub fn gram(&self) -> Matrix {
        let k = self.cols;
        let mut g = Matrix::zeros(k, k);
        for row in self.data.chunks(k) {
            for i in 0..k {
                let ri = row[i];
                if ri == 0.0 {
                    continue;
                }
                for j in i..k {
                    g.data[i * k + j] += ri * row[j];
                }
            }
        }
        for i in 0..k {
            for j in 0..i {
                g.data[i * k + j] = g.data[j * k + i];
            }
        }
        g
    }

    /// self^T * y for a length-n vector y.
    pub fn t_mul_vec(&self, y: &[f64]) -> Vec<f64> {
        assert_eq!(y.len(), self.rows);
        let k = self.cols;
        let mut out = vec![0.0; k];
        for (row, &yi) in self.data.chunks(k).zip(y) {
            for j in 0..k {
                out[j] += row[j] * yi;
            }
        }
        out
    }

    /// self * x for a length-cols vector x.
    pub fn mul_vec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        self.data
            .chunks(self.cols)
            .map(|row| row.iter().zip(x).map(|(a, b)| a * b).sum())
            .collect()
    }
}

/// Cholesky factorisation of a symmetric positive-definite matrix
/// (in-place lower triangle). Returns None if not SPD.
pub fn cholesky(a: &Matrix) -> Option<Matrix> {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.get(i, j);
            for k in 0..j {
                sum -= l.get(i, k) * l.get(j, k);
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                l.set(i, j, sum.sqrt());
            } else {
                l.set(i, j, sum / l.get(j, j));
            }
        }
    }
    Some(l)
}

/// Solve L L^T x = b given the Cholesky factor L.
pub fn cholesky_solve(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    assert_eq!(b.len(), n);
    // forward: L y = b
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut sum = b[i];
        for k in 0..i {
            sum -= l.get(i, k) * y[k];
        }
        y[i] = sum / l.get(i, i);
    }
    // backward: L^T x = y
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut sum = y[i];
        for k in (i + 1)..n {
            sum -= l.get(k, i) * x[k];
        }
        x[i] = sum / l.get(i, i);
    }
    x
}

/// Ordinary least squares with ridge damping: argmin |X w - y|^2 + λ|w|^2.
/// Returns the weight vector (length = X.cols).
pub fn least_squares(x: &Matrix, y: &[f64], ridge: f64) -> Option<Vec<f64>> {
    let mut g = x.gram();
    for i in 0..g.rows {
        let v = g.get(i, i) + ridge;
        g.set(i, i, v);
    }
    let l = cholesky(&g)?;
    Some(cholesky_solve(&l, &x.t_mul_vec(y)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cholesky_known() {
        let a = Matrix::from_rows(&[vec![4.0, 2.0], vec![2.0, 3.0]]);
        let l = cholesky(&a).unwrap();
        assert!((l.get(0, 0) - 2.0).abs() < 1e-12);
        assert!((l.get(1, 0) - 1.0).abs() < 1e-12);
        assert!((l.get(1, 1) - 2f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]);
        assert!(cholesky(&a).is_none());
    }

    #[test]
    fn solve_round_trip() {
        let a = Matrix::from_rows(&[
            vec![6.0, 2.0, 1.0],
            vec![2.0, 5.0, 2.0],
            vec![1.0, 2.0, 4.0],
        ]);
        let l = cholesky(&a).unwrap();
        let x = cholesky_solve(&l, &[1.0, 2.0, 3.0]);
        let b = a.mul_vec(&x);
        for (bi, want) in b.iter().zip([1.0, 2.0, 3.0]) {
            assert!((bi - want).abs() < 1e-10);
        }
    }

    #[test]
    fn least_squares_recovers_exact_fit() {
        // y = 3 x0 - 2 x1 + 1
        let rows: Vec<Vec<f64>> = (0..20)
            .map(|i| {
                let x0 = i as f64;
                let x1 = (i * i % 7) as f64;
                vec![x0, x1, 1.0]
            })
            .collect();
        let y: Vec<f64> = rows.iter().map(|r| 3.0 * r[0] - 2.0 * r[1] + 1.0).collect();
        let x = Matrix::from_rows(&rows);
        let w = least_squares(&x, &y, 1e-9).unwrap();
        assert!((w[0] - 3.0).abs() < 1e-6);
        assert!((w[1] + 2.0).abs() < 1e-6);
        assert!((w[2] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn gram_is_symmetric() {
        let x = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
        let g = x.gram();
        assert_eq!(g.get(0, 1), g.get(1, 0));
        assert!((g.get(0, 0) - 35.0).abs() < 1e-12);
    }
}

//! Convolutional layer configurations and the paper's parameter ranges
//! (Table 1): `k` #kernels, `c` #channels, `im` square input size,
//! `s` stride, `f` (odd) kernel size.


/// One convolutional layer configuration `(k, c, im, s, f)`.
///
/// The paper assumes square inputs (`im = w = h`) and VALID padding; the
/// output spatial size is `(im - f) / s + 1`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ConvConfig {
    /// Number of kernels (output channels), 1..=2048.
    pub k: u32,
    /// Number of input channels, 1..=2048.
    pub c: u32,
    /// Input width/height, 7..=299.
    pub im: u32,
    /// Stride, one of {1, 2, 4}.
    pub s: u32,
    /// Kernel size, odd, 1..=11.
    pub f: u32,
}

/// Paper Table 1 common parameter ranges.
pub mod ranges {
    pub const K: (u32, u32) = (1, 2048);
    pub const C: (u32, u32) = (1, 2048);
    pub const IM: (u32, u32) = (7, 299);
    pub const STRIDES: [u32; 3] = [1, 2, 4];
    pub const KERNEL_SIZES: [u32; 6] = [1, 3, 5, 7, 9, 11];
}

impl ConvConfig {
    pub fn new(k: u32, c: u32, im: u32, s: u32, f: u32) -> Self {
        Self { k, c, im, s, f }
    }

    /// VALID-padding output spatial size; `None` if `f > im`.
    pub fn out_size(&self) -> Option<u32> {
        if self.f > self.im {
            return None;
        }
        Some((self.im - self.f) / self.s + 1)
    }

    /// Whether this configuration is possible at all (paper filters f > im).
    pub fn is_valid(&self) -> bool {
        self.f <= self.im && self.s >= 1 && self.k >= 1 && self.c >= 1
    }

    /// Whether every field lies in the paper's Table 1 common ranges.
    pub fn in_common_ranges(&self) -> bool {
        use ranges::*;
        self.is_valid()
            && (K.0..=K.1).contains(&self.k)
            && (C.0..=C.1).contains(&self.c)
            && (IM.0..=IM.1).contains(&self.im)
            && STRIDES.contains(&self.s)
            && KERNEL_SIZES.contains(&self.f)
    }

    /// MACs needed for direct computation of this layer (2x for FLOPs).
    pub fn macs(&self) -> f64 {
        let o = self.out_size().unwrap_or(0) as f64;
        self.k as f64 * self.c as f64 * (self.f as f64).powi(2) * o * o
    }

    /// Input tensor element count (c * im * im).
    pub fn input_elems(&self) -> u64 {
        self.c as u64 * self.im as u64 * self.im as u64
    }

    /// Output tensor element count (k * o * o).
    pub fn output_elems(&self) -> u64 {
        let o = self.out_size().unwrap_or(0) as u64;
        self.k as u64 * o * o
    }

    /// Weight element count (k * c * f * f).
    pub fn weight_elems(&self) -> u64 {
        self.k as u64 * self.c as u64 * (self.f as u64).pow(2)
    }

    /// The `(c, k, im)` triplet the paper crosses with (f, s) pairs.
    pub fn triplet(&self) -> (u32, u32, u32) {
        (self.c, self.k, self.im)
    }

    /// Model input features `[k, c, im, s, f]` (order fixed; must match
    /// python/compile and the dataset writer).
    pub fn features(&self) -> [f64; 5] {
        [
            self.k as f64,
            self.c as f64,
            self.im as f64,
            self.s as f64,
            self.f as f64,
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn out_size_valid_padding() {
        assert_eq!(ConvConfig::new(1, 1, 7, 1, 3).out_size(), Some(5));
        assert_eq!(ConvConfig::new(1, 1, 7, 2, 3).out_size(), Some(3));
        assert_eq!(ConvConfig::new(1, 1, 224, 2, 7).out_size(), Some(109));
        assert_eq!(ConvConfig::new(1, 1, 3, 1, 5).out_size(), None);
    }

    #[test]
    fn validity() {
        assert!(ConvConfig::new(64, 64, 56, 1, 3).in_common_ranges());
        assert!(!ConvConfig::new(64, 64, 56, 3, 3).in_common_ranges()); // stride 3
        assert!(!ConvConfig::new(64, 64, 56, 1, 4).in_common_ranges()); // even f
        assert!(!ConvConfig::new(64, 64, 5, 1, 7).is_valid()); // f > im
    }

    #[test]
    fn macs_match_formula() {
        let c = ConvConfig::new(2, 3, 8, 1, 3);
        // o = 6; macs = 2*3*9*36
        assert_eq!(c.macs(), 2.0 * 3.0 * 9.0 * 36.0);
    }

    #[test]
    fn features_order_is_kcimsf() {
        let c = ConvConfig::new(1, 2, 3, 4, 3);
        assert_eq!(c.features(), [1.0, 2.0, 3.0, 4.0, 3.0]);
    }
}

//! Dataset + table persistence: CSV save/load for profiled datasets and
//! JSON save/load for predicted dense cost tables, so both the paper's
//! "factory profiling once" story and an onboarded platform's serving
//! table survive process restarts and ship between machines.

use super::{DltDataset, PrimDataset};
use crate::config::Json;
use crate::layers::ConvConfig;
use crate::primitives::catalog;
use crate::selection::TableSource;
use anyhow::{bail, ensure, Context, Result};
use std::path::{Path, PathBuf};

impl PrimDataset {
    /// CSV: header `k,c,im,s,f,<primitive names...>`; undefined = empty.
    pub fn save_csv(&self, path: &Path) -> Result<()> {
        let mut out = String::from("k,c,im,s,f");
        for p in catalog() {
            out.push(',');
            out.push_str(p.name);
        }
        out.push('\n');
        for (cfg, row) in self.configs.iter().zip(&self.targets) {
            out.push_str(&format!("{},{},{},{},{}", cfg.k, cfg.c, cfg.im, cfg.s, cfg.f));
            for t in row {
                out.push(',');
                if let Some(t) = t {
                    out.push_str(&format!("{t:.9e}"));
                }
            }
            out.push('\n');
        }
        std::fs::write(path, out).with_context(|| format!("writing {path:?}"))
    }

    pub fn load_csv(path: &Path) -> Result<PrimDataset> {
        let text = std::fs::read_to_string(path)?;
        let mut lines = text.lines();
        let header = lines.next().context("empty csv")?;
        let cols: Vec<&str> = header.split(',').collect();
        ensure!(cols.len() == 5 + catalog().len(), "column count mismatch");
        for (c, p) in cols[5..].iter().zip(catalog()) {
            ensure!(*c == p.name, "catalog order changed: {c} != {}", p.name);
        }
        let mut configs = Vec::new();
        let mut targets = Vec::new();
        for (ln, line) in lines.enumerate() {
            if line.is_empty() {
                continue;
            }
            let f: Vec<&str> = line.split(',').collect();
            if f.len() != cols.len() {
                bail!("row {ln}: {} fields", f.len());
            }
            configs.push(ConvConfig::new(
                f[0].parse()?,
                f[1].parse()?,
                f[2].parse()?,
                f[3].parse()?,
                f[4].parse()?,
            ));
            targets.push(
                f[5..]
                    .iter()
                    .map(|s| if s.is_empty() { Ok(None) } else { s.parse().map(Some) })
                    .collect::<std::result::Result<Vec<Option<f64>>, _>>()?,
            );
        }
        Ok(PrimDataset { configs, targets })
    }
}

impl DltDataset {
    /// CSV: `c,im,<9 directed costs row-major>` (identity entries 0).
    pub fn save_csv(&self, path: &Path) -> Result<()> {
        let mut out = String::from("c,im");
        for src in crate::primitives::Layout::ALL {
            for dst in crate::primitives::Layout::ALL {
                out.push_str(&format!(",{}_{}", src.name(), dst.name()));
            }
        }
        out.push('\n');
        for (&(c, im), m) in self.pairs.iter().zip(&self.targets) {
            out.push_str(&format!("{c},{im}"));
            for row in m {
                for v in row {
                    out.push_str(&format!(",{v:.9e}"));
                }
            }
            out.push('\n');
        }
        std::fs::write(path, out).with_context(|| format!("writing {path:?}"))
    }

    pub fn load_csv(path: &Path) -> Result<DltDataset> {
        let text = std::fs::read_to_string(path)?;
        let mut pairs = Vec::new();
        let mut targets = Vec::new();
        for line in text.lines().skip(1) {
            if line.is_empty() {
                continue;
            }
            let f: Vec<&str> = line.split(',').collect();
            ensure!(f.len() == 11, "bad dlt row");
            pairs.push((f[0].parse()?, f[1].parse()?));
            let mut m = [[0.0; 3]; 3];
            for i in 0..3 {
                for j in 0..3 {
                    m[i][j] = f[2 + i * 3 + j].parse()?;
                }
            }
            targets.push(m);
        }
        Ok(DltDataset { pairs, targets })
    }
}

/// Canonical location for a platform's persisted serving table.
pub fn table_artifact_path(platform: &str) -> PathBuf {
    PathBuf::from("artifacts/tables").join(format!("{platform}.json"))
}

impl TableSource {
    /// Serialise the dense table to JSON:
    /// `{"configs": [[k,c,im,s,f],...], "rows": [[ms|null,...],...],
    ///   "dlt": [[c, im, m00..m22],...]}`.
    /// Parent directories are created as needed.
    pub fn save_json(&self, path: &Path) -> Result<()> {
        let mut out = String::from("{\"configs\":[");
        let configs = self.configs();
        for (i, c) in configs.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("[{},{},{},{},{}]", c.k, c.c, c.im, c.s, c.f));
        }
        out.push_str("],\"rows\":[");
        for (i, c) in configs.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push('[');
            let row = self.row(c).expect("table covers its own configs");
            for (j, t) in row.iter().enumerate() {
                if j > 0 {
                    out.push(',');
                }
                match t {
                    Some(v) => {
                        ensure!(v.is_finite(), "non-finite cost in table row");
                        out.push_str(&format!("{v}"));
                    }
                    None => out.push_str("null"),
                }
            }
            out.push(']');
        }
        out.push_str("],\"dlt\":[");
        for (i, &((c, im), m)) in self.dlt_entries().iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("[{c},{im}"));
            for row in &m {
                for v in row {
                    ensure!(v.is_finite(), "non-finite cost in DLT matrix");
                    out.push_str(&format!(",{v}"));
                }
            }
            out.push(']');
        }
        out.push_str("]}");
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).ok();
        }
        std::fs::write(path, out).with_context(|| format!("writing {path:?}"))
    }

    /// Load a table previously written by [`Self::save_json`]. Parsing
    /// goes through [`crate::config::Json`] (the same reader the
    /// artifact manifest uses).
    pub fn load_json(path: &Path) -> Result<TableSource> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path:?}"))?;
        let root = Json::parse(&text)?;

        let mut configs = Vec::new();
        for c in root.get("configs")?.as_arr()? {
            let f = c.as_arr()?;
            ensure!(f.len() == 5, "config needs 5 fields");
            configs.push(ConvConfig::new(
                f[0].as_f64()? as u32,
                f[1].as_f64()? as u32,
                f[2].as_f64()? as u32,
                f[3].as_f64()? as u32,
                f[4].as_f64()? as u32,
            ));
        }

        let mut rows = Vec::new();
        for r in root.get("rows")?.as_arr()? {
            let cells = r.as_arr()?;
            ensure!(cells.len() == catalog().len(), "row length != catalog size");
            rows.push(
                cells
                    .iter()
                    .map(|v| match v {
                        Json::Null => Ok(None),
                        other => other.as_f64().map(Some),
                    })
                    .collect::<Result<Vec<Option<f64>>>>()?,
            );
        }
        ensure!(rows.len() == configs.len(), "row count != config count");

        let mut keys = Vec::new();
        let mut mats = Vec::new();
        for e in root.get("dlt")?.as_arr()? {
            let f = e.as_arr()?;
            ensure!(f.len() == 11, "dlt entry needs c, im + 9 costs");
            keys.push((f[0].as_f64()? as u32, f[1].as_f64()? as u32));
            let mut m = [[0.0; 3]; 3];
            for (i, v) in f[2..].iter().enumerate() {
                m[i / 3][i % 3] = v.as_f64()?;
            }
            mats.push(m);
        }
        Ok(TableSource::new(configs, rows, keys, mats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset;
    use crate::simulator::{machine, Simulator};

    #[test]
    fn prim_round_trip() {
        let sim = Simulator::new(machine::intel_i9_9900k());
        let configs = dataset::enumerate_configs(40, 5);
        let ds = dataset::profile_prim_dataset(&sim, &configs);
        let path = std::env::temp_dir().join("primsel_prim.csv");
        ds.save_csv(&path).unwrap();
        let back = PrimDataset::load_csv(&path).unwrap();
        assert_eq!(back.configs, ds.configs);
        for (a, b) in back.targets.iter().zip(&ds.targets) {
            for (x, y) in a.iter().zip(b) {
                match (x, y) {
                    (Some(x), Some(y)) => assert!((x - y).abs() < 1e-9 * y.abs()),
                    (None, None) => {}
                    _ => panic!("mask mismatch"),
                }
            }
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn dlt_round_trip() {
        let sim = Simulator::new(machine::arm_cortex_a73());
        let ds = dataset::profile_dlt_dataset(&sim, &[(8, 14), (64, 28)]);
        let path = std::env::temp_dir().join("primsel_dlt.csv");
        ds.save_csv(&path).unwrap();
        let back = DltDataset::load_csv(&path).unwrap();
        assert_eq!(back.pairs, ds.pairs);
        let (a, b) = (back.targets[1][0][2], ds.targets[1][0][2]);
        assert!((a - b).abs() < 1e-8 * b.abs(), "{a} vs {b}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn table_source_json_round_trip() {
        // bake a dense table (Some/None cells + DLT matrices), persist,
        // reload: bit-exact (f64 Display round-trips)
        let sim = Simulator::new(machine::intel_i9_9900k());
        let cache = crate::selection::CostCache::new(&sim);
        let net = crate::networks::alexnet();
        let table = cache.table_for(&net);
        let path = std::env::temp_dir().join("primsel_table_rt.json");
        table.save_json(&path).unwrap();
        let back = TableSource::load_json(&path).unwrap();
        assert_eq!(back.configs(), table.configs());
        for cfg in table.configs() {
            assert_eq!(back.row(cfg), table.row(cfg));
        }
        assert_eq!(back.dlt_entries(), table.dlt_entries());
        // the reloaded table serves selection identically
        let a = crate::selection::select(&net, &table).unwrap();
        let b = crate::selection::select(&net, &back).unwrap();
        assert_eq!(a.primitive, b.primitive);
        assert_eq!(a.estimated_ms, b.estimated_ms);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn table_json_rejects_garbage() {
        let path = std::env::temp_dir().join("primsel_table_bad.json");
        std::fs::write(&path, "{\"configs\":[[1,2]]}").unwrap();
        assert!(TableSource::load_json(&path).is_err());
        std::fs::write(&path, "not json").unwrap();
        assert!(TableSource::load_json(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn load_rejects_reordered_catalog() {
        let path = std::env::temp_dir().join("primsel_bad.csv");
        std::fs::write(&path, "k,c,im,s,f,wrong-name\n").unwrap();
        assert!(PrimDataset::load_csv(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}

//! Dataset persistence: CSV save/load so profiled datasets (simulated or
//! real-device) can be shipped between machines — the paper's "factory
//! profiling once" deployment story needs the dataset to be an artifact.

use super::{DltDataset, PrimDataset};
use crate::layers::ConvConfig;
use crate::primitives::catalog;
use anyhow::{bail, ensure, Context, Result};
use std::path::Path;

impl PrimDataset {
    /// CSV: header `k,c,im,s,f,<primitive names...>`; undefined = empty.
    pub fn save_csv(&self, path: &Path) -> Result<()> {
        let mut out = String::from("k,c,im,s,f");
        for p in catalog() {
            out.push(',');
            out.push_str(p.name);
        }
        out.push('\n');
        for (cfg, row) in self.configs.iter().zip(&self.targets) {
            out.push_str(&format!("{},{},{},{},{}", cfg.k, cfg.c, cfg.im, cfg.s, cfg.f));
            for t in row {
                out.push(',');
                if let Some(t) = t {
                    out.push_str(&format!("{t:.9e}"));
                }
            }
            out.push('\n');
        }
        std::fs::write(path, out).with_context(|| format!("writing {path:?}"))
    }

    pub fn load_csv(path: &Path) -> Result<PrimDataset> {
        let text = std::fs::read_to_string(path)?;
        let mut lines = text.lines();
        let header = lines.next().context("empty csv")?;
        let cols: Vec<&str> = header.split(',').collect();
        ensure!(cols.len() == 5 + catalog().len(), "column count mismatch");
        for (c, p) in cols[5..].iter().zip(catalog()) {
            ensure!(*c == p.name, "catalog order changed: {c} != {}", p.name);
        }
        let mut configs = Vec::new();
        let mut targets = Vec::new();
        for (ln, line) in lines.enumerate() {
            if line.is_empty() {
                continue;
            }
            let f: Vec<&str> = line.split(',').collect();
            if f.len() != cols.len() {
                bail!("row {ln}: {} fields", f.len());
            }
            configs.push(ConvConfig::new(
                f[0].parse()?,
                f[1].parse()?,
                f[2].parse()?,
                f[3].parse()?,
                f[4].parse()?,
            ));
            targets.push(
                f[5..]
                    .iter()
                    .map(|s| if s.is_empty() { Ok(None) } else { s.parse().map(Some) })
                    .collect::<std::result::Result<Vec<Option<f64>>, _>>()?,
            );
        }
        Ok(PrimDataset { configs, targets })
    }
}

impl DltDataset {
    /// CSV: `c,im,<9 directed costs row-major>` (identity entries 0).
    pub fn save_csv(&self, path: &Path) -> Result<()> {
        let mut out = String::from("c,im");
        for src in crate::primitives::Layout::ALL {
            for dst in crate::primitives::Layout::ALL {
                out.push_str(&format!(",{}_{}", src.name(), dst.name()));
            }
        }
        out.push('\n');
        for (&(c, im), m) in self.pairs.iter().zip(&self.targets) {
            out.push_str(&format!("{c},{im}"));
            for row in m {
                for v in row {
                    out.push_str(&format!(",{v:.9e}"));
                }
            }
            out.push('\n');
        }
        std::fs::write(path, out).with_context(|| format!("writing {path:?}"))
    }

    pub fn load_csv(path: &Path) -> Result<DltDataset> {
        let text = std::fs::read_to_string(path)?;
        let mut pairs = Vec::new();
        let mut targets = Vec::new();
        for line in text.lines().skip(1) {
            if line.is_empty() {
                continue;
            }
            let f: Vec<&str> = line.split(',').collect();
            ensure!(f.len() == 11, "bad dlt row");
            pairs.push((f[0].parse()?, f[1].parse()?));
            let mut m = [[0.0; 3]; 3];
            for i in 0..3 {
                for j in 0..3 {
                    m[i][j] = f[2 + i * 3 + j].parse()?;
                }
            }
            targets.push(m);
        }
        Ok(DltDataset { pairs, targets })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset;
    use crate::simulator::{machine, Simulator};

    #[test]
    fn prim_round_trip() {
        let sim = Simulator::new(machine::intel_i9_9900k());
        let configs = dataset::enumerate_configs(40, 5);
        let ds = dataset::profile_prim_dataset(&sim, &configs);
        let path = std::env::temp_dir().join("primsel_prim.csv");
        ds.save_csv(&path).unwrap();
        let back = PrimDataset::load_csv(&path).unwrap();
        assert_eq!(back.configs, ds.configs);
        for (a, b) in back.targets.iter().zip(&ds.targets) {
            for (x, y) in a.iter().zip(b) {
                match (x, y) {
                    (Some(x), Some(y)) => assert!((x - y).abs() < 1e-9 * y.abs()),
                    (None, None) => {}
                    _ => panic!("mask mismatch"),
                }
            }
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn dlt_round_trip() {
        let sim = Simulator::new(machine::arm_cortex_a73());
        let ds = dataset::profile_dlt_dataset(&sim, &[(8, 14), (64, 28)]);
        let path = std::env::temp_dir().join("primsel_dlt.csv");
        ds.save_csv(&path).unwrap();
        let back = DltDataset::load_csv(&path).unwrap();
        assert_eq!(back.pairs, ds.pairs);
        let (a, b) = (back.targets[1][0][2], ds.targets[1][0][2]);
        assert!((a - b).abs() < 1e-8 * b.abs(), "{a} vs {b}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn load_rejects_reordered_catalog() {
        let path = std::env::temp_dir().join("primsel_bad.csv");
        std::fs::write(&path, "k,c,im,s,f,wrong-name\n").unwrap();
        assert!(PrimDataset::load_csv(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}

//! Log-standardisation (paper §3.3): `x̃ = (log x − mean) / std`,
//! fitted per column, applied to both model inputs and targets.

/// Per-column (log-)standardiser.
#[derive(Debug, Clone)]
pub struct Standardizer {
    pub log: bool,
    pub mean: Vec<f64>,
    pub std: Vec<f64>,
}

impl Standardizer {
    pub fn dim(&self) -> usize {
        self.mean.len()
    }

    /// Fit on dense rows. `log` applies the paper's log transform first.
    pub fn fit(rows: &[Vec<f64>], log: bool) -> Self {
        let dim = rows.first().map_or(0, |r| r.len());
        let masked: Vec<Vec<Option<f64>>> = rows
            .iter()
            .map(|r| r.iter().map(|&v| Some(v)).collect())
            .collect();
        Self::fit_masked_dim(&masked, log, dim)
    }

    /// Fit on rows with undefined entries (ignored in the statistics).
    pub fn fit_masked(rows: &[Vec<Option<f64>>], log: bool) -> Self {
        let dim = rows.first().map_or(0, |r| r.len());
        Self::fit_masked_dim(rows, log, dim)
    }

    fn fit_masked_dim(rows: &[Vec<Option<f64>>], log: bool, dim: usize) -> Self {
        let mut sum = vec![0.0; dim];
        let mut sum2 = vec![0.0; dim];
        let mut count = vec![0usize; dim];
        for row in rows {
            for (j, v) in row.iter().enumerate() {
                if let Some(v) = v {
                    let z = if log { v.max(1e-12).ln() } else { *v };
                    sum[j] += z;
                    sum2[j] += z * z;
                    count[j] += 1;
                }
            }
        }
        let mean: Vec<f64> = (0..dim)
            .map(|j| if count[j] > 0 { sum[j] / count[j] as f64 } else { 0.0 })
            .collect();
        let std: Vec<f64> = (0..dim)
            .map(|j| {
                if count[j] > 1 {
                    let var = sum2[j] / count[j] as f64 - mean[j] * mean[j];
                    var.max(1e-12).sqrt()
                } else {
                    1.0
                }
            })
            .collect();
        Self { log, mean, std }
    }

    pub fn forward_one(&self, j: usize, v: f64) -> f64 {
        let z = if self.log { v.max(1e-12).ln() } else { v };
        (z - self.mean[j]) / self.std[j]
    }

    pub fn inverse_one(&self, j: usize, t: f64) -> f64 {
        let z = t * self.std[j] + self.mean[j];
        if self.log {
            z.exp()
        } else {
            z
        }
    }

    pub fn forward(&self, row: &[f64]) -> Vec<f64> {
        row.iter().enumerate().map(|(j, &v)| self.forward_one(j, v)).collect()
    }

    pub fn inverse(&self, row: &[f64]) -> Vec<f64> {
        row.iter().enumerate().map(|(j, &t)| self.inverse_one(j, t)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let rows = vec![vec![1.0, 10.0], vec![2.0, 100.0], vec![3.0, 1000.0]];
        for log in [false, true] {
            let s = Standardizer::fit(&rows, log);
            for row in &rows {
                let t = s.forward(row);
                let back = s.inverse(&t);
                for (a, b) in back.iter().zip(row) {
                    assert!((a - b).abs() < 1e-9 * b.abs().max(1.0), "{a} {b}");
                }
            }
        }
    }

    #[test]
    fn standardised_moments() {
        let rows: Vec<Vec<f64>> = (1..=100).map(|i| vec![i as f64]).collect();
        let s = Standardizer::fit(&rows, true);
        let ts: Vec<f64> = rows.iter().map(|r| s.forward(r)[0]).collect();
        let mean = ts.iter().sum::<f64>() / ts.len() as f64;
        let var = ts.iter().map(|t| (t - mean).powi(2)).sum::<f64>() / ts.len() as f64;
        assert!(mean.abs() < 1e-10);
        assert!((var - 1.0).abs() < 1e-6);
    }

    #[test]
    fn masked_fit_ignores_undefined() {
        let rows = vec![
            vec![Some(1.0), None],
            vec![Some(3.0), Some(5.0)],
            vec![None, Some(5.0)],
        ];
        let s = Standardizer::fit_masked(&rows, false);
        assert!((s.mean[0] - 2.0).abs() < 1e-12);
        assert!((s.mean[1] - 5.0).abs() < 1e-12);
    }

    #[test]
    fn log_compresses_magnitude() {
        // the paper's motivation: wide-magnitude times become comparable
        let rows = vec![vec![1e-3], vec![1.0], vec![1e3]];
        let s = Standardizer::fit(&rows, true);
        let t: Vec<f64> = rows.iter().map(|r| s.forward(r)[0]).collect();
        assert!((t[0] + t[2]).abs() < 1e-9); // symmetric in log space
        assert!(t[1].abs() < 1e-9);
    }
}

//! Profiler datasets (paper §3.2): layer-configuration enumeration from
//! the network zoo, simulated/measured profiling into training data,
//! log-standardisation, deterministic splits and fixed-shape batching for
//! the AOT training artifacts.

mod persist;
mod standardize;

pub use persist::table_artifact_path;
pub use standardize::Standardizer;

use crate::layers::{ranges, ConvConfig};
use crate::networks;
use crate::primitives::{catalog, Layout};
use crate::simulator::noise::SplitMix64;
use crate::simulator::Simulator;
use std::collections::BTreeSet;

/// Maximum dataset size: 80% of this fits the 7-batch AOT train_epoch
/// artifact exactly (7 * 1024 / 0.8).
pub const MAX_CONFIGS: usize = 8960;

/// Canonical seed for the enumerated config universe — the paper's
/// dataset date. Every platform profiles the *same* config set, which is
/// what makes cross-platform calibration and transfer comparable.
pub const DATASET_SEED: u64 = 20200612;

/// The primitive running-time dataset: `(k,c,im,s,f) -> (R_1..R_N)`.
#[derive(Debug, Clone)]
pub struct PrimDataset {
    pub configs: Vec<ConvConfig>,
    /// targets[i][p] = median execution time in ms; None = undefined.
    pub targets: Vec<Vec<Option<f64>>>,
}

/// The DLT dataset: `(c, im) -> R_{3x3}` (ms; diagonal zero).
#[derive(Debug, Clone)]
pub struct DltDataset {
    pub pairs: Vec<(u32, u32)>,
    pub targets: Vec<[[f64; 3]; 3]>,
}

/// Index split (deterministic, seeded): 80/10/10 train/val/test.
#[derive(Debug, Clone)]
pub struct Split {
    pub train: Vec<usize>,
    pub val: Vec<usize>,
    pub test: Vec<usize>,
}

/// Extract the unique `(c, k, im)` triplets from the zoo (paper: 475).
pub fn zoo_triplets() -> Vec<(u32, u32, u32)> {
    let mut set = BTreeSet::new();
    for n in networks::zoo() {
        set.extend(n.triplets());
    }
    set.into_iter().collect()
}

/// Cross triplets with all (f, s) pairs, filter impossible configs
/// (f > im), and cap at `max_n` via seeded subsampling (paper §3.2.1).
pub fn enumerate_configs(max_n: usize, seed: u64) -> Vec<ConvConfig> {
    let mut configs = Vec::new();
    for (c, k, im) in zoo_triplets() {
        for &f in &ranges::KERNEL_SIZES {
            for &s in &ranges::STRIDES {
                let cfg = ConvConfig::new(k, c, im, s, f);
                if cfg.is_valid() {
                    configs.push(cfg);
                }
            }
        }
    }
    let mut rng = SplitMix64::new(seed);
    rng.shuffle(&mut configs);
    configs.truncate(max_n);
    configs
}

/// Profile all configs on a simulator into a primitive dataset. Rows are
/// independent, so the sweep fans out across cores (order-preserving).
pub fn profile_prim_dataset(sim: &Simulator, configs: &[ConvConfig]) -> PrimDataset {
    let targets = crate::par::par_map(configs, |cfg| sim.profile_layer(cfg));
    PrimDataset { configs: configs.to_vec(), targets }
}

/// Unique (c, im) pairs occurring in the config set, for the DLT dataset.
pub fn dlt_pairs(configs: &[ConvConfig]) -> Vec<(u32, u32)> {
    let set: BTreeSet<(u32, u32)> = configs.iter().map(|c| (c.c, c.im)).collect();
    set.into_iter().collect()
}

/// Profile the DLT dataset on a simulator (parallel, order-preserving).
pub fn profile_dlt_dataset(sim: &Simulator, pairs: &[(u32, u32)]) -> DltDataset {
    let targets = crate::par::par_map(pairs, |&(c, im)| sim.dlt_matrix(c, im));
    DltDataset { pairs: pairs.to_vec(), targets }
}

/// Draw a small calibration set from a target cost source: a seeded
/// `fraction` of the canonical config universe, profiled through
/// `source` into a primitive dataset plus the DLT dataset of the
/// sample's distinct edge tensors.
///
/// This is the "measure a handful of points on the new device" step of
/// platform onboarding (paper §4.4): the coordinator feeds the result to
/// [`LinCostModel::fit`](crate::perfmodel::LinCostModel::fit) or
/// [`FactorCorrected::fit`](crate::perfmodel::FactorCorrected::fit).
/// The source is queried through the same `CostSource` interface the
/// selection engine uses, so any target works — a simulator stand-in, a
/// real profiler, even another model.
pub fn calibration_sample(
    source: &dyn crate::selection::CostSource,
    fraction: f64,
    seed: u64,
) -> (PrimDataset, DltDataset) {
    let mut configs = enumerate_configs(MAX_CONFIGS, DATASET_SEED);
    let mut rng = SplitMix64::new(seed);
    rng.shuffle(&mut configs);
    let n = ((configs.len() as f64 * fraction).round() as usize).clamp(1, configs.len());
    configs.truncate(n);
    let targets =
        crate::par::par_map(&configs, |cfg| source.layer_costs(cfg).into_owned());
    let pairs = dlt_pairs(&configs);
    let dlt_targets = crate::par::par_map(&pairs, |&(c, im)| source.dlt_matrix3(c, im));
    (PrimDataset { configs, targets }, DltDataset { pairs, targets: dlt_targets })
}

impl PrimDataset {
    pub fn len(&self) -> usize {
        self.configs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.configs.is_empty()
    }

    /// Count of defined data points per primitive (paper Table 2).
    pub fn points_per_primitive(&self) -> Vec<usize> {
        let n_prims = catalog().len();
        let mut counts = vec![0usize; n_prims];
        for row in &self.targets {
            for (p, t) in row.iter().enumerate() {
                if t.is_some() {
                    counts[p] += 1;
                }
            }
        }
        counts
    }

    /// Feature matrix rows: raw (k, c, im, s, f).
    pub fn features(&self) -> Vec<[f64; 5]> {
        self.configs.iter().map(|c| c.features()).collect()
    }

    /// Select a subset by indices.
    pub fn subset(&self, idx: &[usize]) -> PrimDataset {
        PrimDataset {
            configs: idx.iter().map(|&i| self.configs[i]).collect(),
            targets: idx.iter().map(|&i| self.targets[i].clone()).collect(),
        }
    }
}

impl DltDataset {
    pub fn len(&self) -> usize {
        self.pairs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pairs.is_empty()
    }

    /// Flatten targets to 9 outputs per row (row-major src x dst),
    /// identity entries marked undefined (they are skipped at runtime).
    pub fn flat_targets(&self) -> Vec<Vec<Option<f64>>> {
        self.targets
            .iter()
            .map(|m| {
                let mut row = Vec::with_capacity(9);
                for src in Layout::ALL {
                    for dst in Layout::ALL {
                        let v = m[src.index()][dst.index()];
                        row.push(if src == dst { None } else { Some(v) });
                    }
                }
                row
            })
            .collect()
    }

    pub fn features(&self) -> Vec<[f64; 2]> {
        self.pairs.iter().map(|&(c, im)| [c as f64, im as f64]).collect()
    }

    pub fn subset(&self, idx: &[usize]) -> DltDataset {
        DltDataset {
            pairs: idx.iter().map(|&i| self.pairs[i]).collect(),
            targets: idx.iter().map(|&i| self.targets[i]).collect(),
        }
    }
}

/// Deterministic 80/10/10 split of `n` indices.
pub fn split(n: usize, seed: u64) -> Split {
    let mut idx: Vec<usize> = (0..n).collect();
    let mut rng = SplitMix64::new(seed);
    rng.shuffle(&mut idx);
    let n_train = n * 8 / 10;
    let n_val = n / 10;
    Split {
        train: idx[..n_train].to_vec(),
        val: idx[n_train..n_train + n_val].to_vec(),
        test: idx[n_train + n_val..].to_vec(),
    }
}

/// A fraction of the training indices (paper §4.4 transfer experiments),
/// sampled uniformly at random with `seed`.
pub fn fraction(train: &[usize], frac: f64, seed: u64) -> Vec<usize> {
    let mut idx = train.to_vec();
    let mut rng = SplitMix64::new(seed);
    rng.shuffle(&mut idx);
    let n = ((idx.len() as f64 * frac).round() as usize).max(1);
    idx.truncate(n);
    idx
}

/// Fixed-shape f32 batches with per-element masks for the AOT trainer.
///
/// `xs`: normalised features, `ys`: normalised targets with None =
/// undefined. Rows are padded to a multiple of `batch` with zero masks.
pub struct Batches {
    pub n_batches: usize,
    pub batch: usize,
    pub in_dim: usize,
    pub out_dim: usize,
    /// (n_batches * batch * in_dim) row-major.
    pub x: Vec<f32>,
    /// (n_batches * batch * out_dim).
    pub y: Vec<f32>,
    pub mask: Vec<f32>,
}

pub fn make_batches(
    xs: &[Vec<f64>],
    ys: &[Vec<Option<f64>>],
    std_x: &Standardizer,
    std_y: &Standardizer,
    batch: usize,
) -> Batches {
    assert_eq!(xs.len(), ys.len());
    let mut b = make_inference_batches(xs, std_x, std_y.dim(), batch);
    for (i, row) in ys.iter().enumerate() {
        for (j, t) in row.iter().enumerate() {
            if let Some(v) = t {
                b.y[i * b.out_dim + j] = std_y.forward_one(j, *v) as f32;
                b.mask[i * b.out_dim + j] = 1.0;
            }
        }
    }
    b
}

/// Inference-only fixed-shape batches: normalised features, zero targets
/// and masks. `make_batches` is this plus a target/mask overlay, so the
/// layouts cannot drift apart — the predictor's hot path reads only `x`
/// and the shape fields and skips the dummy target matrix entirely.
pub fn make_inference_batches(
    xs: &[Vec<f64>],
    std_x: &Standardizer,
    out_dim: usize,
    batch: usize,
) -> Batches {
    let in_dim = std_x.dim();
    let n = xs.len();
    let n_batches = n.div_ceil(batch).max(1);
    let total = n_batches * batch;
    let mut x = vec![0.0f32; total * in_dim];
    for (i, row) in xs.iter().enumerate() {
        let xf = std_x.forward(row);
        for (j, v) in xf.iter().enumerate() {
            x[i * in_dim + j] = *v as f32;
        }
    }
    Batches {
        n_batches,
        batch,
        in_dim,
        out_dim,
        x,
        y: vec![0.0f32; total * out_dim],
        mask: vec![0.0f32; total * out_dim],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::machine;

    #[test]
    fn triplets_scale_like_paper() {
        let t = zoo_triplets();
        // paper: 475 unique triplets; our zoo should land in the hundreds
        assert!(t.len() >= 300 && t.len() <= 1200, "{}", t.len());
    }

    #[test]
    fn enumerate_filters_invalid() {
        let configs = enumerate_configs(MAX_CONFIGS, 1);
        assert!(!configs.is_empty());
        assert!(configs.len() <= MAX_CONFIGS);
        for c in &configs {
            assert!(c.f <= c.im);
        }
    }

    #[test]
    fn enumerate_is_deterministic() {
        assert_eq!(enumerate_configs(100, 7), enumerate_configs(100, 7));
        assert_ne!(enumerate_configs(100, 7), enumerate_configs(100, 8));
    }

    #[test]
    fn split_proportions_and_disjoint() {
        let s = split(1000, 3);
        assert_eq!(s.train.len(), 800);
        assert_eq!(s.val.len(), 100);
        assert_eq!(s.test.len(), 100);
        let mut all: Vec<usize> =
            s.train.iter().chain(&s.val).chain(&s.test).copied().collect();
        all.sort();
        assert_eq!(all, (0..1000).collect::<Vec<_>>());
    }

    #[test]
    fn fraction_sizes() {
        let train: Vec<usize> = (0..2500).collect();
        assert_eq!(fraction(&train, 0.01, 1).len(), 25);
        assert_eq!(fraction(&train, 0.001, 1).len(), 3);
        assert!(fraction(&train, 0.0001, 1).len() >= 1);
    }

    #[test]
    fn profiled_dataset_shapes() {
        let sim = Simulator::new(machine::intel_i9_9900k());
        let configs = enumerate_configs(50, 2);
        let ds = profile_prim_dataset(&sim, &configs);
        assert_eq!(ds.len(), 50);
        assert_eq!(ds.targets[0].len(), catalog().len());
        let counts = ds.points_per_primitive();
        // direct/im2/mec defined everywhere
        assert_eq!(counts[0], 50);
    }

    #[test]
    fn table2_structure() {
        // always-applicable families have more points than stride-1-only,
        // which have more than the f-specific families (paper Table 2)
        let sim = Simulator::new(machine::intel_i9_9900k());
        let configs = enumerate_configs(800, 4);
        let ds = profile_prim_dataset(&sim, &configs);
        let counts = ds.points_per_primitive();
        let idx = |name: &str| crate::primitives::index_of(name).unwrap();
        let direct = counts[idx("direct-sum2d")];
        let kn2 = counts[idx("kn2row")];
        let wino3 = counts[idx("winograd-2x2-3x3")];
        let wino5 = counts[idx("winograd-2x2-5x5")];
        assert!(direct > kn2, "{direct} {kn2}");
        assert!(kn2 > wino3, "{kn2} {wino3}");
        assert!(wino3 > 0 && wino5 > 0);
    }

    #[test]
    fn batches_pad_with_zero_mask() {
        let xs = vec![vec![1.0, 2.0]; 5];
        let ys: Vec<Vec<Option<f64>>> =
            vec![vec![Some(1.0), None]; 5];
        let sx = Standardizer::fit(&xs, false);
        let sy = Standardizer::fit_masked(&ys, true);
        let b = make_batches(&xs, &ys, &sx, &sy, 4);
        assert_eq!(b.n_batches, 2);
        // rows 5..8 fully masked
        for i in 5..8 {
            for j in 0..2 {
                assert_eq!(b.mask[i * 2 + j], 0.0);
            }
        }
        // col 1 masked everywhere (row 0: indices 0 and 1)
        assert_eq!(b.mask[1], 0.0);
        assert_eq!(b.mask[0], 1.0);
    }

    #[test]
    fn inference_batches_match_fully_masked_make_batches() {
        // the inference-only constructor must be bit-identical to the old
        // dummy-target flow it replaces
        let xs: Vec<Vec<f64>> =
            (1..=5).map(|i| vec![i as f64, (i * i) as f64]).collect();
        let ys: Vec<Vec<Option<f64>>> = vec![vec![None; 3]; 5];
        let sx = Standardizer::fit(&xs, true);
        let sy = Standardizer::fit_masked(&ys, true);
        let a = make_batches(&xs, &ys, &sx, &sy, 4);
        let b = make_inference_batches(&xs, &sx, 3, 4);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
        assert_eq!(a.mask, b.mask);
        assert_eq!(a.n_batches, b.n_batches);
        assert_eq!((a.in_dim, a.out_dim, a.batch), (b.in_dim, b.out_dim, b.batch));
    }

    #[test]
    fn parallel_profiling_matches_sequential() {
        // par_map sweep must be order- and value-identical to a plain map
        let sim = Simulator::new(machine::arm_cortex_a73());
        let configs = enumerate_configs(200, 11);
        let ds = profile_prim_dataset(&sim, &configs);
        for (cfg, row) in ds.configs.iter().zip(&ds.targets) {
            assert_eq!(*row, sim.profile_layer(cfg));
        }
        let pairs = dlt_pairs(&configs);
        let dlt = profile_dlt_dataset(&sim, &pairs);
        for (&(c, im), m) in dlt.pairs.iter().zip(&dlt.targets) {
            assert_eq!(*m, sim.dlt_matrix(c, im));
        }
    }

    #[test]
    fn calibration_sample_matches_source_and_seed() {
        let sim = Simulator::new(machine::arm_cortex_a73());
        let (prim, dlt) = calibration_sample(&sim, 0.01, 5);
        let universe = enumerate_configs(MAX_CONFIGS, DATASET_SEED).len();
        let n = ((universe as f64 * 0.01).round() as usize).clamp(1, universe);
        assert_eq!(prim.len(), n);
        // rows are exactly what the source returns
        for (cfg, row) in prim.configs.iter().zip(&prim.targets) {
            assert_eq!(*row, sim.profile_layer(cfg));
        }
        // dlt pairs cover exactly the sample's distinct (c, im) tensors
        assert_eq!(dlt.pairs, dlt_pairs(&prim.configs));
        for (&(c, im), m) in dlt.pairs.iter().zip(&dlt.targets) {
            assert_eq!(*m, sim.dlt_matrix(c, im));
        }
        // deterministic in the seed, different across seeds
        let (again, _) = calibration_sample(&sim, 0.01, 5);
        assert_eq!(again.configs, prim.configs);
        let (other, _) = calibration_sample(&sim, 0.01, 6);
        assert_ne!(other.configs, prim.configs);
    }

    #[test]
    fn dlt_dataset_flat_targets() {
        let sim = Simulator::new(machine::amd_a10_7850k());
        let ds = profile_dlt_dataset(&sim, &[(16, 28), (64, 56)]);
        let flat = ds.flat_targets();
        assert_eq!(flat[0].len(), 9);
        // diagonal (0, 4, 8) undefined
        assert!(flat[0][0].is_none() && flat[0][4].is_none() && flat[0][8].is_none());
        assert!(flat[0][1].is_some());
    }
}

//! Host profiler: times *real* executions of the Pallas primitive
//! kernels (the AOT prim_grid artifacts) on this machine's CPU via PJRT —
//! the measured counterpart that grounds the simulator substitution
//! (see `ARCHITECTURE.md`). Median of 25 runs, as in the paper (§4.1.1).

use crate::runtime::{literal_f32, Runtime};
use crate::simulator::noise::SplitMix64;
use anyhow::Result;
use std::time::Instant;

/// One measured grid point.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub kernel: String,
    pub c: u32,
    pub im: u32,
    pub k: u32,
    pub f: u32,
    pub s: u32,
    /// Median wall-clock per execution, ms.
    pub median_ms: f64,
    /// Spread: (min, max) over the runs.
    pub min_ms: f64,
    pub max_ms: f64,
    pub flops: f64,
}

impl Measurement {
    /// Achieved GFLOP/s of this kernel execution.
    pub fn gflops(&self) -> f64 {
        self.flops / (self.median_ms / 1e3) / 1e9
    }
}

/// Profile every prim_grid artifact. `runs` = measurements per kernel
/// (paper: 25); inputs are drawn from a normal distribution (paper §4.1.1).
pub fn profile_grid(rt: &Runtime, runs: usize) -> Result<Vec<Measurement>> {
    let mut out = Vec::new();
    let entries = rt.manifest.prim_grid.clone();
    for e in &entries {
        let exe = rt.load(&e.file)?;
        let mut rng = SplitMix64::new(
            crate::simulator::noise::fnv1a(e.file.as_bytes()),
        );
        let x: Vec<f32> = (0..(e.c * e.im * e.im) as usize)
            .map(|_| rng.next_normal() as f32)
            .collect();
        let w: Vec<f32> = (0..(e.k * e.c * e.f * e.f) as usize)
            .map(|_| rng.next_normal() as f32)
            .collect();
        let xl = literal_f32(&x, &[e.c as i64, e.im as i64, e.im as i64])?;
        let wl = literal_f32(&w, &[e.k as i64, e.c as i64, e.f as i64, e.f as i64])?;

        // warm-up
        rt.execute(&exe, &[xl.clone().into(), wl.clone().into()])
            .map(|_| ())
            .unwrap_or(());

        let mut times = Vec::with_capacity(runs);
        for _ in 0..runs {
            let t0 = Instant::now();
            let _ = rt.execute(&exe, &[xl.clone().into(), wl.clone().into()])?;
            times.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        out.push(Measurement {
            kernel: e.kernel.clone(),
            c: e.c,
            im: e.im,
            k: e.k,
            f: e.f,
            s: e.s,
            median_ms: times[times.len() / 2],
            min_ms: times[0],
            max_ms: times[times.len() - 1],
            flops: e.flops,
        });
    }
    Ok(out)
}

/// Profile the DLT artifacts (same protocol).
pub fn profile_dlt_grid(rt: &Runtime, runs: usize) -> Result<Vec<(String, String, u32, u32, f64)>> {
    let mut out = Vec::new();
    let entries = rt.manifest.dlt_grid.clone();
    for e in &entries {
        let exe = rt.load(&e.file)?;
        let shape: Vec<i64> = match e.src.as_str() {
            "chw" => vec![e.c as i64, e.im as i64, e.im as i64],
            "hcw" => vec![e.im as i64, e.c as i64, e.im as i64],
            "hwc" => vec![e.im as i64, e.im as i64, e.c as i64],
            other => anyhow::bail!("unknown layout {other}"),
        };
        let n: i64 = shape.iter().product();
        let x: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let xl = literal_f32(&x, &shape)?;
        let _ = rt.execute(&exe, &[xl.clone().into()])?; // warm-up
        let mut times = Vec::with_capacity(runs);
        for _ in 0..runs {
            let t0 = Instant::now();
            let _ = rt.execute(&exe, &[xl.clone().into()])?;
            times.push(t0.elapsed().as_secs_f64() * 1e3);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        out.push((e.src.clone(), e.dst.clone(), e.c, e.im, times[times.len() / 2]));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_a_subset_when_artifacts_exist() {
        let Ok(rt) = Runtime::open_default() else { return };
        if rt.manifest.prim_grid.is_empty() {
            return;
        }
        // keep the test fast: 3 runs over the first entries only
        let mut small = rt.manifest.prim_grid.clone();
        small.truncate(2);
        // inline a tiny version of profile_grid over the truncated list
        let m = {
            let mut rt2 = rt;
            rt2.manifest.prim_grid = small;
            profile_grid(&rt2, 3).unwrap()
        };
        assert_eq!(m.len(), 2);
        for meas in &m {
            assert!(meas.median_ms > 0.0);
            assert!(meas.min_ms <= meas.median_ms && meas.median_ms <= meas.max_ms);
            assert!(meas.gflops() > 0.0);
        }
    }
}

//! AlexNet and the VGG family — the classic chain CNNs.

use super::{Builder, Network};

/// AlexNet (Krizhevsky et al. 2012), torchvision layout: 5 conv layers.
pub fn alexnet() -> Network {
    let mut b = Builder::new("alexnet", 224, 3);
    b.conv(64, 11, 4); // 224 -> 56 grid (pool to 27 below)
    b.pool(2); // 28 -> pools land at 27-ish; nominal halving
    b.conv(192, 5, 1);
    b.pool(2);
    b.conv(384, 3, 1);
    b.conv(256, 3, 1);
    b.conv(256, 3, 1);
    b.build()
}

/// VGG-n for n in {11, 13, 16, 19} (Simonyan & Zisserman 2014).
/// All convs 3x3 stride 1; five stages separated by 2x2 max pools.
pub fn vgg(n: u32) -> Network {
    // convs per stage
    let per_stage: [usize; 5] = match n {
        11 => [1, 1, 2, 2, 2],
        13 => [2, 2, 2, 2, 2],
        16 => [2, 2, 3, 3, 3],
        19 => [2, 2, 4, 4, 4],
        _ => panic!("unknown VGG depth {n}"),
    };
    let widths = [64u32, 128, 256, 512, 512];
    let mut b = Builder::new(&format!("vgg{n}"), 224, 3);
    for (stage, &count) in per_stage.iter().enumerate() {
        for _ in 0..count {
            b.conv(widths[stage], 3, 1);
        }
        if stage < 4 {
            b.pool(2);
        }
    }
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg_depths() {
        assert_eq!(vgg(11).n_layers(), 8);
        assert_eq!(vgg(13).n_layers(), 10);
        assert_eq!(vgg(16).n_layers(), 13);
        assert_eq!(vgg(19).n_layers(), 16);
    }

    #[test]
    fn vgg_channel_flow() {
        let v = vgg(11);
        assert_eq!(v.layers[0].c, 3);
        assert_eq!(v.layers[0].k, 64);
        assert_eq!(v.layers[1].c, 64);
        assert_eq!(v.layers[1].k, 128);
        // final stage at 14x14, 512 channels
        let last = v.layers.last().unwrap();
        assert_eq!(last.k, 512);
        assert_eq!(last.im, 14);
    }

    #[test]
    fn alexnet_first_layer() {
        let a = alexnet();
        assert_eq!(a.layers[0].f, 11);
        assert_eq!(a.layers[0].s, 4);
        assert_eq!(a.layers[0].c, 3);
    }

    #[test]
    #[should_panic]
    fn vgg_rejects_unknown_depth() {
        vgg(12);
    }
}

//! ResNet and ResNeXt families, with residual-shortcut edges in the
//! selection graph (the add requires consistent layouts, so the shortcut
//! carries a DLT edge cost).

use super::{Builder, Network};

/// ResNet-n for n in {18, 34, 50, 101, 152} (He et al. 2016).
pub fn resnet(n: u32) -> Network {
    let (blocks, bottleneck): ([usize; 4], bool) = match n {
        18 => ([2, 2, 2, 2], false),
        34 => ([3, 4, 6, 3], false),
        50 => ([3, 4, 6, 3], true),
        101 => ([3, 4, 23, 3], true),
        152 => ([3, 8, 36, 3], true),
        _ => panic!("unknown ResNet depth {n}"),
    };
    build_resnet(&format!("resnet{n}"), blocks, bottleneck, 64, 1)
}

/// ResNeXt (Xie et al. 2016): 50 => 32x4d, 101 => 32x8d.
/// Grouped 3x3 convs are modelled at their full width (the group count
/// affects cost, which the simulator folds into the channel dimensions).
pub fn resnext(n: u32) -> Network {
    let (blocks, width_mult) = match n {
        50 => ([3usize, 4, 6, 3], 2),  // 32 groups x 4d = width 128 at stage 1
        101 => ([3, 4, 23, 3], 4),     // 32 groups x 8d = width 256
        _ => panic!("unknown ResNeXt depth {n}"),
    };
    build_resnet(&format!("resnext{n}"), blocks, true, 64, width_mult)
}

fn build_resnet(
    name: &str,
    blocks: [usize; 4],
    bottleneck: bool,
    base: u32,
    width_mult: u32,
) -> Network {
    let mut b = Builder::new(name, 224, 3);
    b.conv(base, 7, 2); // 112
    b.pool(2); // 56
    let expansion = if bottleneck { 4 } else { 1 };
    let mut in_ch = base;
    for (stage, &count) in blocks.iter().enumerate() {
        let width = base << stage; // 64, 128, 256, 512
        let out_ch = width * expansion;
        for block in 0..count {
            let stride = if stage > 0 && block == 0 { 2 } else { 1 };
            let block_in = b.last();
            let block_im = b.im();
            let block_out;
            if bottleneck {
                let mid = width * width_mult;
                b.conv(mid, 1, 1);
                b.conv(mid, 3, stride);
                block_out = b.conv(out_ch, 1, 1);
            } else {
                b.conv(width, 3, stride);
                block_out = b.conv(width, 3, 1);
            }
            if in_ch != out_ch || stride != 1 {
                // 1x1 projection shortcut: a real conv layer on the side
                // branch, feeding the residual add at block_out
                b.side_conv(block_in, block_out, out_ch, in_ch, block_im, 1, stride);
            } else if let Some(src) = block_in {
                // identity shortcut: layouts must agree across the add
                b.skip(src, block_out);
            }
            in_ch = out_ch;
        }
    }
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet18_structure() {
        let r = resnet(18);
        
        assert_eq!(r.n_layers(), 20); // 1 stem + 16 + 3 projections
        assert_eq!(r.layers[0].f, 7);
        // stage widths double
        assert!(r.layers.iter().any(|l| l.k == 512));
    }

    #[test]
    fn resnet50_is_bottleneck() {
        let r = resnet(50);
        assert_eq!(r.n_layers(), 1 + 16 * 3 + 4); // stem + bottlenecks + 4 projections
        // bottleneck expansion: some layer outputs 2048 channels
        assert!(r.layers.iter().any(|l| l.k == 2048));
    }

    #[test]
    fn resnext_wider_3x3() {
        let x = resnext(50);
        let r = resnet(50);
        let max_3x3_x = x.layers.iter().filter(|l| l.f == 3).map(|l| l.k).max();
        let max_3x3_r = r.layers.iter().filter(|l| l.f == 3).map(|l| l.k).max();
        assert!(max_3x3_x > max_3x3_r);
    }

    #[test]
    fn skip_edges_present() {
        let r = resnet(34);
        let chain_edges = r.n_layers() - 1;
        assert!(r.edges.len() > chain_edges);
    }

    #[test]
    fn strides_flow_spatial() {
        let r = resnet(18);
        // first stage at 56, last at 7
        assert!(r.layers.iter().any(|l| l.im == 56));
        assert!(r.layers.iter().any(|l| l.im == 7));
    }
}

//! DenseNet family. Dense connectivity is bounded to the next two layers
//! in the selection graph (full dense fan-out would charge the same DLT
//! many times over; two hops preserves the high-degree structure that
//! exercises the PBQP RN heuristic without distorting total edge cost).

use super::{Builder, Network};

/// DenseNet-n for n in {121, 161, 169, 201} (Huang et al. 2017).
pub fn densenet(n: u32) -> Network {
    let (blocks, growth, init): ([usize; 4], u32, u32) = match n {
        121 => ([6, 12, 24, 16], 32, 64),
        161 => ([6, 12, 36, 24], 48, 96),
        169 => ([6, 12, 32, 32], 32, 64),
        201 => ([6, 12, 48, 32], 32, 64),
        _ => panic!("unknown DenseNet depth {n}"),
    };
    let mut b = Builder::new(&format!("densenet{n}"), 224, 3);
    b.conv(init, 7, 2); // 112
    b.pool(2); // 56
    let mut channels = init;
    for (stage, &count) in blocks.iter().enumerate() {
        for _ in 0..count {
            // dense layer: 1x1 bottleneck (4*growth) then 3x3 growth
            let before = b.last();
            set_channels(&mut b, channels);
            b.conv(4 * growth, 1, 1);
            let out = b.conv(growth, 3, 1);
            // dense connectivity: concat feeds later layers; bound to 2 hops
            if let Some(src) = before {
                if out >= 2 {
                    b.skip(src, out);
                }
            }
            channels += growth;
        }
        if stage < 3 {
            // transition: 1x1 halving + 2x2 pool
            set_channels(&mut b, channels);
            channels /= 2;
            b.conv(channels, 1, 1);
            b.pool(2);
        }
    }
    b.build()
}

/// The concat of a dense block means the next conv consumes the
/// accumulated channel count, not just the previous layer's k.
/// Capped at the paper's Table 1 common range (c <= 2048): DenseNet-161's
/// deepest concats exceed it, and the paper's triplet pool excludes such
/// outliers by construction.
fn set_channels(b: &mut Builder, channels: u32) {
    b.force_channels(channels.min(2048));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn densenet121_layers() {
        let d = densenet(121);
        // 1 stem + (6+12+24+16) dense layers x 2 convs + 3 transitions
        assert_eq!(d.n_layers(), 1 + 58 * 2 + 3);
    }

    #[test]
    fn channel_growth() {
        let d = densenet(121);
        // inside block 1, input channels grow by 32 per dense layer
        assert_eq!(d.layers[1].c, 64);
        assert_eq!(d.layers[3].c, 96);
        assert_eq!(d.layers[5].c, 128);
    }

    #[test]
    fn densenet161_wider() {
        let d = densenet(161);
        assert!(d.layers.iter().any(|l| l.k == 192)); // 4 * growth 48
    }

    #[test]
    fn transitions_halve() {
        let d = densenet(121);
        // after block 1 (6 layers): 64 + 6*32 = 256 -> transition to 128
        let trans = d.layers.iter().find(|l| l.c == 256 && l.f == 1).unwrap();
        assert_eq!(trans.k, 128);
    }
}

//! The network zoo (paper Table 7): conv-layer graphs for all the
//! architectures the paper extracts its `(c, k, im)` triplets from, plus
//! the six networks used in the selection experiments (§4.3).
//!
//! A [`Network`] is a DAG over convolutional layers only (the paper
//! optimises conv layers, which take >90% of inference time [27]); edges
//! carry data-layout-transformation costs in the PBQP graph. Non-conv ops
//! (pooling, concat, residual add) are modelled by their effect on the
//! spatial size / channel count and by the dataflow edges they induce.

mod classic;
mod dense;
mod inception;
mod mobile;
mod resnet;

use crate::layers::ConvConfig;
use std::collections::BTreeSet;

pub use classic::{alexnet, vgg};
pub use dense::densenet;
pub use inception::{googlenet, inception_v3};
pub use mobile::{mobilenet_v1, shufflenet_v2, squeezenet};
pub use resnet::{resnet, resnext};

/// A convolutional network as a DAG of conv layers.
#[derive(Debug, Clone)]
pub struct Network {
    pub name: String,
    pub layers: Vec<ConvConfig>,
    /// Dataflow edges (producer, consumer), producer < consumer.
    pub edges: Vec<(usize, usize)>,
}

impl Network {
    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// All `(c, k, im)` triplets occurring in this network.
    pub fn triplets(&self) -> BTreeSet<(u32, u32, u32)> {
        self.layers.iter().map(|l| l.triplet()).collect()
    }

    /// Total MACs of the network's conv layers.
    pub fn total_macs(&self) -> f64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    /// Degree of each node in the (undirected) selection graph.
    pub fn degrees(&self) -> Vec<usize> {
        let mut d = vec![0usize; self.layers.len()];
        for &(a, b) in &self.edges {
            d[a] += 1;
            d[b] += 1;
        }
        d
    }

    fn validate(self) -> Self {
        for l in &self.layers {
            debug_assert!(l.is_valid(), "{}: invalid layer {l:?}", self.name);
        }
        for &(a, b) in &self.edges {
            debug_assert!(a < b && b < self.layers.len(), "{}: bad edge", self.name);
        }
        self
    }
}

/// Incremental graph builder tracking spatial size and channel flow.
pub(crate) struct Builder {
    name: String,
    layers: Vec<ConvConfig>,
    edges: Vec<(usize, usize)>,
    /// Nodes whose outputs feed the next added layer.
    frontier: Vec<usize>,
    /// Current spatial size (input resolution of the next layer).
    im: u32,
    /// Current channel count.
    c: u32,
}

impl Builder {
    pub fn new(name: &str, input_im: u32, input_c: u32) -> Self {
        Self {
            name: name.to_string(),
            layers: Vec::new(),
            edges: Vec::new(),
            frontier: Vec::new(),
            im: input_im,
            c: input_c,
        }
    }

    pub fn im(&self) -> u32 {
        self.im
    }

    #[allow(dead_code)] // symmetric accessor kept for builder completeness
    pub fn channels(&self) -> u32 {
        self.c
    }

    pub fn last(&self) -> Option<usize> {
        self.frontier.last().copied()
    }

    /// Add a conv layer consuming the current frontier.
    /// SAME-padding flow: the next layer sees `ceil(im / s)`.
    pub fn conv(&mut self, k: u32, f: u32, s: u32) -> usize {
        let id = self.layers.len();
        self.layers.push(ConvConfig::new(k, self.c, self.im, s, f));
        for &p in &self.frontier {
            self.edges.push((p, id));
        }
        self.frontier = vec![id];
        self.c = k;
        self.im = self.im.div_ceil(s);
        id
    }

    /// Depthwise conv modelled as a conv with c = k = current channels.
    pub fn dwconv(&mut self, f: u32, s: u32) -> usize {
        let k = self.c;
        self.conv(k, f, s)
    }

    /// Pooling: spatial reduction only.
    pub fn pool(&mut self, s: u32) {
        self.im = self.im.div_ceil(s);
    }

    /// Override the channel count seen by the next layer (dense-block
    /// concatenation accumulates channels beyond the previous layer's k).
    pub fn force_channels(&mut self, c: u32) {
        self.c = c;
    }

    /// Explicit extra dataflow edge (e.g. residual shortcut).
    pub fn skip(&mut self, from: usize, to: usize) {
        if from < to {
            self.edges.push((from, to));
        }
    }

    /// A conv layer on a side branch (e.g. a ResNet projection shortcut):
    /// explicit config, fed from `from`, joining the dataflow at `join`.
    /// Does not change the main-path frontier/channel state.
    pub fn side_conv(
        &mut self,
        from: Option<usize>,
        join: usize,
        k: u32,
        c: u32,
        im: u32,
        f: u32,
        s: u32,
    ) -> usize {
        let id = self.layers.len();
        self.layers.push(ConvConfig::new(k, c, im, s, f));
        if let Some(src) = from {
            self.edges.push((src, id));
        }
        // the join node consumes the side branch's output
        if join < id {
            self.edges.push((join, id));
        }
        id
    }

    /// Run `branches` in parallel from the current frontier and concat.
    /// Each branch is a list of (k, f, s) convs. Returns ending channel sum.
    pub fn parallel(&mut self, branches: &[&[(u32, u32, u32)]]) -> u32 {
        let entry_frontier = self.frontier.clone();
        let entry_c = self.c;
        let entry_im = self.im;
        let mut ends = Vec::new();
        let mut out_c = 0;
        let mut out_im = entry_im;
        for branch in branches {
            self.frontier = entry_frontier.clone();
            self.c = entry_c;
            self.im = entry_im;
            for &(k, f, s) in *branch {
                self.conv(k, f, s);
            }
            if let Some(&e) = self.frontier.last() {
                ends.push(e);
            }
            out_c += self.c;
            out_im = self.im;
        }
        self.frontier = ends;
        self.c = out_c;
        self.im = out_im;
        out_c
    }

    pub fn build(self) -> Network {
        Network { name: self.name, layers: self.layers, edges: self.edges }.validate()
    }
}

/// The full zoo used for triplet extraction (paper Table 7).
pub fn zoo() -> Vec<Network> {
    let mut nets = vec![
        alexnet(),
        vgg(11),
        vgg(13),
        vgg(16),
        vgg(19),
        googlenet(),
        inception_v3(),
        squeezenet(true),
        squeezenet(false),
        mobilenet_v1(),
    ];
    for n in [18, 34, 50, 101, 152] {
        nets.push(resnet(n));
    }
    for n in [121, 161, 169, 201] {
        nets.push(densenet(n));
    }
    nets.push(resnext(50));
    nets.push(resnext(101));
    for scale in ["0_5", "1_0", "1_5", "2_0"] {
        nets.push(shufflenet_v2(scale));
    }
    nets
}

/// The six networks of the selection experiments (paper §4.3).
pub fn selection_networks() -> Vec<Network> {
    vec![alexnet(), vgg(11), vgg(19), googlenet(), resnet(18), resnet(34)]
}

/// Look up a network by name (CLI entry point).
pub fn by_name(name: &str) -> Option<Network> {
    zoo().into_iter().find(|n| n.name.eq_ignore_ascii_case(name))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_is_large() {
        let z = zoo();
        assert!(z.len() >= 20, "zoo has {} networks", z.len());
        for n in &z {
            assert!(n.n_layers() >= 5, "{} too small", n.name);
            assert!(!n.edges.is_empty(), "{} has no edges", n.name);
        }
    }

    #[test]
    fn triplet_pool_is_diverse() {
        let mut triplets = BTreeSet::new();
        for n in zoo() {
            triplets.extend(n.triplets());
        }
        // paper: 475 unique triplets across the pool
        assert!(
            triplets.len() >= 300,
            "only {} unique triplets",
            triplets.len()
        );
    }

    #[test]
    fn selection_networks_present() {
        let names: Vec<_> = selection_networks().iter().map(|n| n.name.clone()).collect();
        assert_eq!(
            names,
            ["alexnet", "vgg11", "vgg19", "googlenet", "resnet18", "resnet34"]
        );
    }

    #[test]
    fn layer_counts_plausible() {
        assert_eq!(alexnet().n_layers(), 5);
        assert_eq!(vgg(11).n_layers(), 8);
        assert_eq!(vgg(19).n_layers(), 16);
        assert!(googlenet().n_layers() >= 55); // 57 convs
        assert_eq!(resnet(18).n_layers(), 20); // stem + 16 convs + 3 projections
        assert!(resnet(50).n_layers() >= 50);
        assert!(densenet(121).n_layers() >= 115);
    }

    #[test]
    fn googlenet_has_branchy_nodes() {
        let g = googlenet();
        let max_deg = g.degrees().into_iter().max().unwrap();
        assert!(max_deg >= 4, "inception fan-out should give degree >= 4");
    }

    #[test]
    fn resnet_has_skip_edges() {
        let r = resnet(18);
        // more edges than a pure chain
        assert!(r.edges.len() > r.n_layers() - 1);
    }

    #[test]
    fn by_name_lookup() {
        assert!(by_name("googlenet").is_some());
        assert!(by_name("GoogLeNet").is_some());
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn all_layers_in_paper_ranges() {
        // the zoo is the *source* of the paper's Table 1 ranges
        for n in zoo() {
            for l in &n.layers {
                assert!(l.k <= 2048 && l.c <= 2048, "{}: {l:?}", n.name);
                assert!(l.im <= 299, "{}: {l:?}", n.name);
                assert!(l.f <= 11 && l.f % 2 == 1, "{}: {l:?}", n.name);
                assert!([1, 2, 4].contains(&l.s), "{}: {l:?}", n.name);
            }
        }
    }
}

//! GoogLeNet (Inception v1) and Inception v3 — the branchy networks that
//! exercise the PBQP solver's higher-degree reductions.

use super::{Builder, Network};

/// GoogLeNet (Szegedy et al. 2015): stem + 9 inception modules, 57 convs.
pub fn googlenet() -> Network {
    let mut b = Builder::new("googlenet", 224, 3);
    b.conv(64, 7, 2); // 224 -> 112
    b.pool(2); // 56
    b.conv(64, 1, 1);
    b.conv(192, 3, 1);
    b.pool(2); // 28

    // (b1_1x1, b2_reduce, b2_3x3, b3_reduce, b3_5x5, b4_poolproj)
    let modules_3: [(u32, u32, u32, u32, u32, u32); 2] =
        [(64, 96, 128, 16, 32, 32), (128, 128, 192, 32, 96, 64)];
    for m in modules_3 {
        inception_module(&mut b, m);
    }
    b.pool(2); // 14
    let modules_4: [(u32, u32, u32, u32, u32, u32); 5] = [
        (192, 96, 208, 16, 48, 64),
        (160, 112, 224, 24, 64, 64),
        (128, 128, 256, 24, 64, 64),
        (112, 144, 288, 32, 64, 64),
        (256, 160, 320, 32, 128, 128),
    ];
    for m in modules_4 {
        inception_module(&mut b, m);
    }
    b.pool(2); // 7
    let modules_5: [(u32, u32, u32, u32, u32, u32); 2] =
        [(256, 160, 320, 32, 128, 128), (384, 192, 384, 48, 128, 128)];
    for m in modules_5 {
        inception_module(&mut b, m);
    }
    b.build()
}

fn inception_module(b: &mut Builder, (b1, r3, b3, r5, b5, pp): (u32, u32, u32, u32, u32, u32)) {
    b.parallel(&[
        &[(b1, 1, 1)],
        &[(r3, 1, 1), (b3, 3, 1)],
        &[(r5, 1, 1), (b5, 5, 1)],
        &[(pp, 1, 1)], // pool-projection branch (pool is layout-neutral)
    ]);
}

/// Inception v3 (Szegedy et al. 2016), 299x299 input.
///
/// Factorised 7x7 convs are modelled at f=7 where the original uses
/// asymmetric 1x7/7x1 pairs — the paper's triplet extraction only records
/// square kernels (Table 1: f odd, up to 11), and the (c, k, im) pool this
/// feeds is what matters here.
pub fn inception_v3() -> Network {
    let mut b = Builder::new("inception_v3", 299, 3);
    // stem
    b.conv(32, 3, 2); // 150
    b.conv(32, 3, 1);
    b.conv(64, 3, 1);
    b.pool(2); // 75
    b.conv(80, 1, 1);
    b.conv(192, 3, 1);
    b.pool(2); // 38 -> nominal 35 grid
    // 3x inception-A at 35 (use the 38 grid the SAME-flow gives us)
    for pool_proj in [32, 64, 64] {
        b.parallel(&[
            &[(64, 1, 1)],
            &[(48, 1, 1), (64, 5, 1)],
            &[(64, 1, 1), (96, 3, 1), (96, 3, 1)],
            &[(pool_proj, 1, 1)],
        ]);
    }
    // reduction-A
    b.parallel(&[
        &[(384, 3, 2)],
        &[(64, 1, 1), (96, 3, 1), (96, 3, 2)],
    ]);
    // 4x inception-B at 17 (1x7/7x1 pairs modelled as f=7)
    for w in [128u32, 160, 160, 192] {
        b.parallel(&[
            &[(192, 1, 1)],
            &[(w, 1, 1), (192, 7, 1)],
            &[(w, 1, 1), (w, 7, 1), (192, 7, 1)],
            &[(192, 1, 1)],
        ]);
    }
    // reduction-B
    b.parallel(&[
        &[(192, 1, 1), (320, 3, 2)],
        &[(192, 1, 1), (192, 7, 1), (192, 3, 2)],
    ]);
    // 2x inception-C at 8
    for _ in 0..2 {
        b.parallel(&[
            &[(320, 1, 1)],
            &[(384, 1, 1), (384, 3, 1)],
            &[(448, 1, 1), (384, 3, 1), (384, 3, 1)],
            &[(192, 1, 1)],
        ]);
    }
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn googlenet_layer_count() {
        // 3 stem convs + 9 modules x 6 convs = 57
        assert_eq!(googlenet().n_layers(), 57);
    }

    #[test]
    fn googlenet_channel_concat() {
        let g = googlenet();
        // first inception module consumes 192 channels
        assert_eq!(g.layers[3].c, 192);
        // 3a output = 64+128+32+32 = 256 feeds 3b
        assert_eq!(g.layers[9].c, 256);
    }

    #[test]
    fn inception_v3_starts_at_299() {
        let n = inception_v3();
        assert_eq!(n.layers[0].im, 299);
        assert!(n.n_layers() > 40);
    }

    #[test]
    fn branch_fanout_edges() {
        let g = googlenet();
        // the conv feeding module 3a (stem conv 192) must have >= 4 consumers
        let consumers = g.edges.iter().filter(|(a, _)| *a == 2).count();
        assert!(consumers >= 4, "got {consumers}");
    }
}

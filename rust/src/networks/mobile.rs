//! The mobile-oriented architectures of Table 7: SqueezeNet, MobileNet v1
//! and ShuffleNet v2 — sources of small-channel / depthwise triplets.

use super::{Builder, Network};

/// SqueezeNet (Iandola et al. 2017). `v1_0` selects 1.0 vs 1.1.
pub fn squeezenet(v1_0: bool) -> Network {
    let name = if v1_0 { "squeezenet1_0" } else { "squeezenet1_1" };
    let mut b = Builder::new(name, 224, 3);
    if v1_0 {
        b.conv(96, 7, 2); // 112
    } else {
        b.conv(64, 3, 2);
    }
    b.pool(2); // 56
    // fire modules: (squeeze, expand1x1, expand3x3)
    let fires: [(u32, u32, u32); 8] = [
        (16, 64, 64),
        (16, 64, 64),
        (32, 128, 128),
        (32, 128, 128),
        (48, 192, 192),
        (48, 192, 192),
        (64, 256, 256),
        (64, 256, 256),
    ];
    for (i, &(sq, e1, e3)) in fires.iter().enumerate() {
        b.conv(sq, 1, 1);
        b.parallel(&[&[(e1, 1, 1)], &[(e3, 3, 1)]]);
        // pools at different places for 1.0 vs 1.1
        let pool_after = if v1_0 { [2usize, 6].contains(&i) } else { [0usize, 2].contains(&i) };
        if pool_after {
            b.pool(2);
        }
    }
    b.conv(1000, 1, 1); // classifier conv
    b.build()
}

/// MobileNet v1 (Howard et al. 2017): depthwise-separable chain.
pub fn mobilenet_v1() -> Network {
    let mut b = Builder::new("mobilenet", 224, 3);
    b.conv(32, 3, 2); // 112
    // (pointwise-out, stride of the depthwise)
    let blocks: [(u32, u32); 13] = [
        (64, 1),
        (128, 2),
        (128, 1),
        (256, 2),
        (256, 1),
        (512, 2),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (1024, 2),
        (1024, 1),
    ];
    for (k, s) in blocks {
        b.dwconv(3, s); // depthwise 3x3 (modelled c = k)
        b.conv(k, 1, 1); // pointwise
    }
    b.build()
}

/// ShuffleNet v2 (Zhang et al. 2017) at scales 0_5, 1_0, 1_5, 2_0.
pub fn shufflenet_v2(scale: &str) -> Network {
    let (stages, final_k): ([u32; 3], u32) = match scale {
        "0_5" => ([48, 96, 192], 1024),
        "1_0" => ([116, 232, 464], 1024),
        "1_5" => ([176, 352, 704], 1024),
        "2_0" => ([244, 488, 976], 2048),
        _ => panic!("unknown shufflenet scale {scale}"),
    };
    let repeats = [4usize, 8, 4];
    let mut b = Builder::new(&format!("shufflenet_v2_x{scale}"), 224, 3);
    b.conv(24, 3, 2); // 112
    b.pool(2); // 56
    for (stage, (&width, &count)) in stages.iter().zip(&repeats).enumerate() {
        let _ = stage;
        for unit in 0..count {
            let s = if unit == 0 { 2 } else { 1 };
            // shuffle unit main branch: 1x1 -> dw3x3 -> 1x1 (half width each
            // branch; modelled at branch width)
            let half = width / 2;
            b.conv(half, 1, 1);
            b.dwconv(3, s);
            b.conv(half, 1, 1);
        }
        b.force_channels(width);
    }
    b.conv(final_k, 1, 1);
    b.build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn squeezenet_variants_differ() {
        let a = squeezenet(true);
        let c = squeezenet(false);
        assert_ne!(a.layers[0].k, c.layers[0].k);
        assert!(a.n_layers() >= 25);
    }

    #[test]
    fn fire_module_branches() {
        let s = squeezenet(true);
        // squeeze layer (k=16) fans out to two expands
        let sq_idx = s.layers.iter().position(|l| l.k == 16).unwrap();
        let consumers = s.edges.iter().filter(|(a, _)| *a == sq_idx).count();
        assert_eq!(consumers, 2);
    }

    #[test]
    fn mobilenet_depthwise_modelling() {
        let m = mobilenet_v1();
        // depthwise layers have c == k
        let dw: Vec<_> = m.layers.iter().filter(|l| l.f == 3 && l.c == l.k).collect();
        assert!(dw.len() >= 13);
        assert!(m.layers.iter().any(|l| l.k == 1024));
    }

    #[test]
    fn shufflenet_scales() {
        assert!(shufflenet_v2("0_5").layers.iter().any(|l| l.k == 24));
        assert!(shufflenet_v2("2_0").layers.iter().any(|l| l.k == 2048));
    }

    #[test]
    #[should_panic]
    fn shufflenet_bad_scale() {
        shufflenet_v2("9_9");
    }
}

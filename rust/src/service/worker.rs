//! The persistent worker pool: long-lived threads (built on
//! [`par::Pool`](crate::par::Pool), not per-batch spawns) that drain
//! the fair scheduler into the coordinator's
//! [`select_one`](crate::coordinator::Coordinator::select_one) unit of
//! work and fulfil each request's [`Ticket`](super::Ticket).
//!
//! A worker's life is one loop: pop (blocks until the scheduler yields
//! an eligible request), record the queued-wait latency, run the
//! selection, record the service latency, fulfil the ticket, return the
//! tenant's inflight slot. When the queue reports closed-and-drained
//! the loop ends and the thread exits — shutdown is just "close, then
//! join".
//!
//! Workers ask the coordinator for [`ReportDetail::Minimal`] reports —
//! the warm plan-served fast path then allocates nothing for the name
//! strings — and render the names back in *after* the service-latency
//! clock stops, so tenants still see fully-populated reports.

use super::ticket::Fulfiller;
use super::ServiceShared;
use crate::coordinator::{ReportDetail, SelectionRequest};
use crate::health;
use crate::obs::{self, Stage};
use crate::par;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

/// One admitted request in flight through the queue.
pub(crate) struct Job {
    pub(crate) req: SelectionRequest,
    /// When admission succeeded — the wait histogram measures from here
    /// to dispatch.
    pub(crate) admitted_at: Instant,
    /// Fulfilment half of the caller's [`Ticket`](super::Ticket). If the
    /// job is dropped unserved (queue torn down, worker lost), its
    /// `Drop` resolves the ticket with an "abandoned" error — waiters
    /// never hang.
    pub(crate) cell: Fulfiller,
}

/// One worker's drain loop; returns when the queue is closed and empty.
pub(crate) fn run(shared: &ServiceShared) {
    while let Some((tenant, mut job)) = shared.queue.pop() {
        shared.wait.record(job.admitted_at.elapsed());
        if let Some(t) = &job.req.trace {
            t.mark(Stage::Dispatch);
            if let Some(ns) = t.span_ns(Stage::Admit, Stage::Dispatch) {
                shared.obs.queue_ms.record_ns(ns);
            }
        }
        // solve with deferred name strings: the warm fast path stays
        // allocation-free, and render() restores them below — outside
        // the service-latency window — so tickets look identical to a
        // Full-detail solve
        job.req.detail = ReportDetail::Minimal;
        let t0 = Instant::now();
        // errors (unknown platform, solver failure) — and panics from a
        // user-registered cost source — travel through the ticket: a bad
        // request must never take the worker down, hang its ticket, or
        // leak the tenant's inflight slot
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            shared.coord.select_one(&job.req)
        }))
        .unwrap_or_else(|payload| {
            Err(anyhow::anyhow!("selection panicked: {}", health::panic_message(payload)))
        });
        shared.service.record(t0.elapsed());
        let meta = shared.tenant_meta(tenant);
        meta.counters.served.fetch_add(1, Ordering::Relaxed);
        if let Some(t) = &job.req.trace {
            t.mark(Stage::Done);
            if let Some(ns) = t.span_ns(Stage::Admit, Stage::Done) {
                shared.obs.e2e_ms.record_ns(ns);
            }
            obs::flight_recorder().record_request(
                t,
                &job.req.platform,
                &job.req.network.name,
                meta.name(),
            );
        }
        job.cell.fulfil(result.map(|mut r| {
            // re-clone after the Done mark so the caller's report carries
            // the complete span set, not the copy select_one detached
            r.trace = job.req.trace.clone();
            r.render(&job.req)
        }));
        shared.queue.complete(tenant);
    }
}

/// Spawn the persistent pool: `n` named threads running [`run`] until
/// shutdown.
pub(crate) fn spawn(shared: &Arc<ServiceShared>, n: usize) -> par::Pool {
    let shared = Arc::clone(shared);
    par::Pool::spawn(n, "primsel-serve", move |_| run(&shared))
}

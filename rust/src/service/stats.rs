//! Serving observability: per-tenant admission counters, wait/service
//! latency histograms, and the [`ServiceStats`] snapshot a serving
//! process prints — the instruments that make a fairness regression or
//! a backpressure storm visible without a debugger.

use crate::health::PlatformHealth;
use crate::report::Table;
use crate::selection::CacheStats;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Log-spaced latency buckets: 4 per doubling from 1 µs, covering
/// ~1 µs to ~10 min with ≤ ~19% bucket resolution — plenty for p50/p95
/// of a serving path whose requests span µs (warm table hits) to
/// seconds (cold profiling sweeps).
pub const N_BUCKETS: usize = 120;
const BUCKETS_PER_DOUBLING: f64 = 4.0;

/// A lock-free, fixed-bucket latency histogram (relaxed atomics, like
/// [`CacheStats`]'s counters: approximate under concurrency, exact
/// enough for reporting).
pub struct LatencyHistogram {
    buckets: [AtomicU64; N_BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    fn bucket_of(us: u64) -> usize {
        // floor(log2(us) * 4): sub-µs measurements land in bucket 0
        let idx = ((us.max(1) as f64).log2() * BUCKETS_PER_DOUBLING).floor() as usize;
        idx.min(N_BUCKETS - 1)
    }

    /// Log-interpolated point within bucket `idx`, `frac` of the way
    /// through it (0.5 = the geometric midpoint), in milliseconds.
    fn bucket_point_ms(idx: usize, frac: f64) -> f64 {
        let lo = (idx as f64 / BUCKETS_PER_DOUBLING).exp2();
        let hi = ((idx + 1) as f64 / BUCKETS_PER_DOUBLING).exp2();
        lo * (hi / lo).powf(frac.clamp(0.0, 1.0)) / 1e3
    }

    /// Record one latency sample.
    pub fn record(&self, d: Duration) {
        let us = d.as_micros().min(u64::MAX as u128) as u64;
        self.buckets[Self::bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    /// A consistent-enough copy of the counters for reporting.
    pub fn snapshot(&self) -> HistogramSnapshot {
        self.snapshot_inline()
    }

    /// [`Self::snapshot`] without touching the heap: bucket counts are
    /// copied into a stack array, so the series sampler can quantile
    /// every registry histogram on its cadence without allocating in
    /// steady state (pinned by `rust/tests/alloc_counter.rs` with the
    /// sampler thread live).
    pub fn snapshot_inline(&self) -> HistogramSnapshot {
        let mut buckets = [0u64; N_BUCKETS];
        for (dst, src) in buckets.iter_mut().zip(self.buckets.iter()) {
            *dst = src.load(Ordering::Relaxed);
        }
        let count: u64 = buckets.iter().sum();
        let quantile = |q: f64| -> f64 {
            if count == 0 {
                return 0.0;
            }
            let rank = ((q * count as f64).ceil() as u64).clamp(1, count);
            let mut seen = 0u64;
            for (i, &b) in buckets.iter().enumerate() {
                if b > 0 && seen + b >= rank {
                    // Interpolate within the matched bucket, treating its
                    // b samples as spread evenly through it in log space
                    // (resolving to the bucket midpoint instead biases
                    // quantiles by up to the ~19% bucket width).
                    let frac = ((rank - seen) as f64 - 0.5) / b as f64;
                    return Self::bucket_point_ms(i, frac);
                }
                seen += b;
            }
            Self::bucket_point_ms(N_BUCKETS - 1, 0.5)
        };
        let (p50_ms, p95_ms) = (quantile(0.50), quantile(0.95));
        let sum_us = self.sum_us.load(Ordering::Relaxed);
        HistogramSnapshot {
            count,
            mean_ms: if count == 0 { 0.0 } else { sum_us as f64 / count as f64 / 1e3 },
            p50_ms,
            p95_ms,
            max_ms: self.max_us.load(Ordering::Relaxed) as f64 / 1e3,
        }
    }
}

/// Point-in-time summary of one [`LatencyHistogram`]. Quantiles are
/// log-interpolated within the matched bucket, so their error is a
/// fraction of the ~19% bucket width rather than the full width.
#[derive(Debug, Clone, Copy, Default)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub max_ms: f64,
}

/// Monotonic per-tenant admission counters (worker/submitter side).
#[derive(Default)]
pub(crate) struct TenantCounters {
    pub(crate) admitted: AtomicU64,
    pub(crate) rejected: AtomicU64,
    pub(crate) served: AtomicU64,
}

/// One tenant's row in a [`ServiceStats`] snapshot.
#[derive(Debug, Clone)]
pub struct TenantStats {
    pub tenant: String,
    pub weight: f64,
    /// Requests that passed admission control (lifetime).
    pub admitted: u64,
    /// Requests bounced by backpressure — `QueueFull` or a blown
    /// admission deadline (lifetime).
    pub rejected: u64,
    /// Requests fully served (lifetime).
    pub served: u64,
    /// Currently queued (admitted, not yet dispatched).
    pub queued: usize,
    /// Currently being served by workers.
    pub inflight: usize,
}

/// What [`Service::stats`](super::Service::stats) returns: the live
/// queue/tenant picture, latency summaries, and per-platform cache
/// deltas accumulated since the service started.
#[derive(Debug, Clone)]
pub struct ServiceStats {
    /// Admitted-but-undispatched requests right now, across tenants.
    pub queue_depth: usize,
    /// The admission bound `queue_depth` is capped at.
    pub capacity: usize,
    /// Worker threads draining the scheduler.
    pub workers: usize,
    pub tenants: Vec<TenantStats>,
    /// Admission → dispatch latency (time spent queued).
    pub wait: HistogramSnapshot,
    /// Dispatch → fulfilment latency (time inside a worker).
    pub service: HistogramSnapshot,
    /// Per-platform cache hit/miss deltas over the service's lifetime,
    /// sorted by platform name (merged across all tenants' traffic —
    /// and any direct coordinator traffic sharing those caches).
    pub platforms: Vec<(String, CacheStats)>,
    /// Compiled-plan cache (hits, misses) totals at snapshot time.
    pub plan_cache: (u64, u64),
    /// Pareto-front cache (hits, misses) totals at snapshot time.
    pub front_cache: (u64, u64),
    /// Health snapshots for every monitored platform
    /// ([`Coordinator::monitor_platform`](crate::coordinator::Coordinator::monitor_platform)),
    /// sorted by platform name; empty when nothing is monitored.
    pub health: Vec<PlatformHealth>,
}

impl ServiceStats {
    /// Render the snapshot as ASCII tables (what the `serve` subcommand
    /// and `serve_zoo` example print).
    pub fn render(&self) -> String {
        let mut t = Table::new(
            &format!(
                "service stats — queue {}/{} ({} workers)",
                self.queue_depth, self.capacity, self.workers
            ),
            &["tenant", "weight", "admitted", "rejected", "served", "queued", "inflight"],
        );
        for ts in &self.tenants {
            t.row(vec![
                ts.tenant.clone(),
                format!("{:.1}", ts.weight),
                ts.admitted.to_string(),
                ts.rejected.to_string(),
                ts.served.to_string(),
                ts.queued.to_string(),
                ts.inflight.to_string(),
            ]);
        }
        let mut lat = Table::new(
            "latency (ms)",
            &["phase", "count", "mean", "p50", "p95", "max"],
        );
        for (name, h) in [("wait", &self.wait), ("service", &self.service)] {
            lat.row(vec![
                name.to_string(),
                h.count.to_string(),
                format!("{:.3}", h.mean_ms),
                format!("{:.3}", h.p50_ms),
                format!("{:.3}", h.p95_ms),
                format!("{:.3}", h.max_ms),
            ]);
        }
        let mut cache = Table::new(
            "per-platform cache deltas (service lifetime)",
            &["platform", "hits", "misses", "hit ratio"],
        );
        for (p, s) in &self.platforms {
            cache.row(vec![
                p.clone(),
                s.hits().to_string(),
                s.misses().to_string(),
                crate::report::fmt_pct(s.hit_ratio()),
            ]);
        }
        let mut sel = Table::new(
            "selection caches (coordinator lifetime)",
            &["cache", "hits", "misses", "hit ratio"],
        );
        for (name, (hits, misses)) in [("plan", self.plan_cache), ("front", self.front_cache)] {
            let total = hits + misses;
            let ratio = if total == 0 { 0.0 } else { hits as f64 / total as f64 };
            sel.row(vec![
                name.to_string(),
                hits.to_string(),
                misses.to_string(),
                crate::report::fmt_pct(ratio),
            ]);
        }
        let mut out = format!(
            "{}\n{}\n{}\n{}",
            t.render(),
            lat.render(),
            cache.render(),
            sel.render()
        );
        if !self.health.is_empty() {
            let mut ht = Table::new(
                "platform health (monitored platforms)",
                &[
                    "platform", "state", "drift", "window", "sampled/observed", "recals",
                    "consec fail", "quarantines",
                ],
            );
            for h in &self.health {
                ht.row(vec![
                    h.platform.clone(),
                    h.state.to_string(),
                    format!("{:.3}", h.drift),
                    h.window.to_string(),
                    format!("{}/{}", h.sampled, h.observed),
                    format!("{}+{}f", h.recalibrations, h.recal_failures),
                    h.consecutive_failures.to_string(),
                    h.quarantines.to_string(),
                ]);
            }
            out.push('\n');
            out.push_str(&ht.render());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram_snapshots_to_zeros() {
        let h = LatencyHistogram::new();
        let s = h.snapshot();
        assert_eq!(s.count, 0);
        assert_eq!(s.p50_ms, 0.0);
        assert_eq!(s.p95_ms, 0.0);
        assert_eq!(s.mean_ms, 0.0);
        assert_eq!(s.max_ms, 0.0);
    }

    #[test]
    fn quantiles_track_recorded_latencies() {
        let h = LatencyHistogram::new();
        // 95 fast samples at ~1 ms, 5 slow at ~100 ms
        for _ in 0..95 {
            h.record(Duration::from_millis(1));
        }
        for _ in 0..5 {
            h.record(Duration::from_millis(100));
        }
        let s = h.snapshot();
        assert_eq!(s.count, 100);
        // bucket resolution is ~19%, so compare with generous factors
        assert!(s.p50_ms > 0.5 && s.p50_ms < 2.0, "p50 {}", s.p50_ms);
        assert!(s.p95_ms > 0.5 && s.p95_ms < 2.0, "p95 {} (95th is still fast)", s.p95_ms);
        assert!(s.max_ms >= 99.0, "max {}", s.max_ms);
        assert!(s.mean_ms > 4.0 && s.mean_ms < 8.0, "mean {}", s.mean_ms);
        // one more slow sample pushes p95 into the slow mode
        for _ in 0..10 {
            h.record(Duration::from_millis(100));
        }
        let s = h.snapshot();
        assert!(s.p95_ms > 50.0, "p95 {}", s.p95_ms);
    }

    #[test]
    fn quantiles_interpolate_within_the_matched_bucket() {
        // uniform 1..=1000 µs: exact p50 = 0.5 ms, p95 = 0.95 ms. The
        // pre-interpolation midpoint estimate was off by up to the full
        // ~19% bucket width; interpolated estimates land much closer.
        let h = LatencyHistogram::new();
        for us in 1..=1000u64 {
            h.record(Duration::from_micros(us));
        }
        let s = h.snapshot();
        assert!((s.p50_ms - 0.5).abs() / 0.5 < 0.05, "p50 {}", s.p50_ms);
        assert!((s.p95_ms - 0.95).abs() / 0.95 < 0.05, "p95 {}", s.p95_ms);

        // a single sample resolves near itself, not a bucket boundary
        let h = LatencyHistogram::new();
        h.record(Duration::from_micros(100));
        let s = h.snapshot();
        assert!((s.p50_ms - 0.1).abs() / 0.1 < 0.05, "p50 {}", s.p50_ms);

        // identical samples: quantiles stay ordered and inside the bucket
        let h = LatencyHistogram::new();
        for _ in 0..64 {
            h.record(Duration::from_micros(400));
        }
        let s = h.snapshot();
        assert!(s.p50_ms <= s.p95_ms);
        assert!((s.p50_ms - 0.4).abs() / 0.4 < 0.19, "p50 {}", s.p50_ms);
        assert!((s.p95_ms - 0.4).abs() / 0.4 < 0.19, "p95 {}", s.p95_ms);
    }

    #[test]
    fn bucket_mapping_is_monotonic_and_bounded() {
        let mut last = 0;
        for us in [0u64, 1, 2, 3, 7, 100, 1_000, 1_000_000, u64::MAX] {
            let b = LatencyHistogram::bucket_of(us);
            assert!(b >= last, "bucket({us}) regressed");
            assert!(b < N_BUCKETS);
            last = b;
        }
    }

    #[test]
    fn render_includes_every_section() {
        let stats = ServiceStats {
            queue_depth: 1,
            capacity: 8,
            workers: 2,
            tenants: vec![TenantStats {
                tenant: "t0".into(),
                weight: 2.0,
                admitted: 5,
                rejected: 1,
                served: 4,
                queued: 1,
                inflight: 0,
            }],
            wait: HistogramSnapshot::default(),
            service: HistogramSnapshot::default(),
            platforms: vec![("intel".into(), CacheStats::default())],
            plan_cache: (3, 1),
            front_cache: (0, 0),
            health: vec![],
        };
        let out = stats.render();
        assert!(out.contains("t0") && out.contains("rejected"));
        assert!(out.contains("p95") && out.contains("intel"));
        // selection-cache hit ratios render as percentages
        assert!(out.contains("selection caches"), "{out}");
        assert!(out.contains("75.00%") && out.contains("0.00%"), "{out}");
        // no monitors → no health table
        assert!(!out.contains("platform health"));

        let mut stats = stats;
        stats.health.push(PlatformHealth {
            platform: "arm-x".into(),
            state: crate::health::HealthState::Drifting,
            drift: 1.25,
            window: 16,
            observed: 40,
            sampled: 40,
            probe_failures: 0,
            recalibrations: 2,
            recal_failures: 1,
            consecutive_failures: 1,
            quarantines: 0,
        });
        let out = stats.render();
        assert!(out.contains("platform health"), "{out}");
        assert!(out.contains("arm-x") && out.contains("drifting"), "{out}");
        assert!(out.contains("1.250") && out.contains("40/40"), "{out}");
    }
}

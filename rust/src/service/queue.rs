//! The bounded MPMC admission queue: per-tenant lanes behind one
//! `Mutex` + two `Condvar`s, with a *global* capacity bound over all
//! queued (admitted, not yet dispatched) items.
//!
//! This is the mechanism half of admission control — locks, lanes,
//! blocking and backpressure; *which* lane a worker serves next is
//! delegated to a [`Scheduler`](super::sched::Scheduler) consulted under
//! the same lock, so admission, scheduling and inflight accounting can
//! never race each other.
//!
//! Semantics:
//!
//! * [`AdmissionQueue::try_push`] never blocks: at capacity it returns
//!   [`SubmitError::QueueFull`] — the backpressure signal a tenant can
//!   react to (shed load, retry later, route elsewhere).
//! * [`AdmissionQueue::push`] blocks while full (optionally up to a
//!   deadline, then [`SubmitError::Timeout`]), waking when a worker pop
//!   frees a slot.
//! * [`AdmissionQueue::pop`] blocks until the scheduler yields an
//!   eligible item, and returns `None` only when the queue is closed
//!   *and* fully drained — so closing is graceful by construction:
//!   admission stops immediately, workers finish everything already
//!   admitted.
//! * [`AdmissionQueue::complete`] returns a tenant's inflight slot and
//!   wakes poppers (a freed slot can make a capped tenant eligible
//!   again).
//!
//! Liveness: every backlogged lane is eligible once its inflight count
//! is under the cap, and caps are floored at 1 — so "queued but nobody
//! eligible" implies some request is inflight, whose completion will
//! wake the waiters. There is no state where items are queued and no
//! wake-up is pending.

use super::sched::Scheduler;
use crate::sync;
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Why an admission attempt did not enqueue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The queue is at capacity (only from non-blocking admission).
    QueueFull,
    /// The queue stayed at capacity past the caller's deadline.
    Timeout,
    /// The service is shutting down; no new work is admitted.
    Closed,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull => write!(f, "admission queue full"),
            SubmitError::Timeout => write!(f, "admission deadline exceeded while queue full"),
            SubmitError::Closed => write!(f, "service is shut down"),
        }
    }
}

impl std::error::Error for SubmitError {}

struct Inner<T, S> {
    /// One FIFO lane per tenant, indexed by tenant id.
    lanes: Vec<VecDeque<T>>,
    /// Total queued items across lanes (≤ capacity).
    len: usize,
    closed: bool,
    sched: S,
    /// Scratch for the per-pick backlog snapshot, reused across pops so
    /// the hot path never allocates under the queue lock.
    backlog: Vec<usize>,
}

/// Bounded multi-tenant MPMC queue; see the module docs for semantics.
pub struct AdmissionQueue<T, S: Scheduler> {
    inner: Mutex<Inner<T, S>>,
    /// Producers wait here while at capacity.
    not_full: Condvar,
    /// Workers wait here while nothing is eligible.
    not_empty: Condvar,
    capacity: usize,
}

impl<T, S: Scheduler> AdmissionQueue<T, S> {
    pub fn new(capacity: usize, sched: S) -> Self {
        assert!(capacity >= 1, "admission queue capacity must be >= 1");
        Self {
            inner: Mutex::new(Inner {
                lanes: Vec::new(),
                len: 0,
                closed: false,
                sched,
                backlog: Vec::new(),
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            capacity,
        }
    }

    fn lock(&self) -> MutexGuard<'_, Inner<T, S>> {
        sync::lock(&self.inner)
    }

    /// Register the next tenant lane; returns its id. Lane ids are dense
    /// and stable (lanes are never removed).
    pub fn add_tenant(&self, weight: f64, max_inflight: usize) -> usize {
        let mut inner = self.lock();
        inner.lanes.push(VecDeque::new());
        inner.sched.add_tenant(weight, max_inflight);
        inner.lanes.len() - 1
    }

    /// Total queued (admitted, not yet dispatched) items — the
    /// admission-control bound.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Current queue depth across all lanes.
    pub fn depth(&self) -> usize {
        self.lock().len
    }

    /// Per-tenant `(queued, inflight)` snapshot, indexed by tenant id.
    pub fn lane_snapshot(&self) -> Vec<(usize, usize)> {
        let inner = self.lock();
        (0..inner.lanes.len())
            .map(|i| (inner.lanes[i].len(), inner.sched.inflight(i)))
            .collect()
    }

    /// Non-blocking admission: enqueue or fail *now*.
    pub fn try_push(&self, tenant: usize, item: T) -> Result<(), SubmitError> {
        let mut inner = self.lock();
        if inner.closed {
            return Err(SubmitError::Closed);
        }
        if inner.len >= self.capacity {
            return Err(SubmitError::QueueFull);
        }
        inner.lanes[tenant].push_back(item);
        inner.len += 1;
        drop(inner);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking admission: wait while at capacity, up to `deadline` from
    /// now if given (then [`SubmitError::Timeout`]).
    pub fn push(
        &self,
        tenant: usize,
        item: T,
        deadline: Option<Duration>,
    ) -> Result<(), SubmitError> {
        let start = Instant::now();
        let mut inner = self.lock();
        loop {
            if inner.closed {
                return Err(SubmitError::Closed);
            }
            if inner.len < self.capacity {
                inner.lanes[tenant].push_back(item);
                inner.len += 1;
                drop(inner);
                self.not_empty.notify_one();
                return Ok(());
            }
            inner = match deadline {
                None => sync::wait(&self.not_full, inner),
                Some(d) => {
                    let elapsed = start.elapsed();
                    if elapsed >= d {
                        return Err(SubmitError::Timeout);
                    }
                    // re-check on every wake: a wait_timeout that reports
                    // timed_out may still find a freed slot (and spurious
                    // wakes may not)
                    sync::wait_timeout(&self.not_full, inner, d - elapsed).0
                }
            };
        }
    }

    /// Worker side: block until the scheduler yields an eligible item,
    /// mark it dispatched (pair with [`Self::complete`]), and return it
    /// with its tenant id. Returns `None` once the queue is closed and
    /// every lane is drained.
    pub fn pop(&self) -> Option<(usize, T)> {
        let mut guard = self.lock();
        loop {
            {
                // split the guard once so the scratch buffer and the
                // scheduler can be borrowed as disjoint fields
                let inner = &mut *guard;
                inner.backlog.clear();
                for lane in &inner.lanes {
                    inner.backlog.push(lane.len());
                }
                if let Some(t) = inner.sched.pick(&inner.backlog) {
                    let item =
                        inner.lanes[t].pop_front().expect("scheduler picked an empty lane");
                    inner.len -= 1;
                    inner.sched.on_dispatch(t);
                    drop(guard);
                    self.not_full.notify_one();
                    return Some((t, item));
                }
                if inner.closed && inner.len == 0 {
                    return None;
                }
            }
            guard = sync::wait(&self.not_empty, guard);
        }
    }

    /// A dispatched item finished; frees the tenant's inflight slot.
    pub fn complete(&self, tenant: usize) {
        let mut inner = self.lock();
        inner.sched.on_complete(tenant);
        drop(inner);
        // a freed slot can make a capped tenant schedulable again, and
        // several workers may be waiting on different lanes
        self.not_empty.notify_all();
    }

    /// Stop admitting; pending pushes fail with [`SubmitError::Closed`],
    /// workers drain what was already admitted and then see `None`.
    pub fn close(&self) {
        self.lock().closed = true;
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }

    /// Whether [`Self::close`] has been called.
    pub fn is_closed(&self) -> bool {
        self.lock().closed
    }
}

#[cfg(test)]
mod tests {
    use super::super::sched::DrrScheduler;
    use super::*;
    use std::sync::Arc;

    fn queue(capacity: usize, tenants: usize) -> Arc<AdmissionQueue<u32, DrrScheduler>> {
        let q = Arc::new(AdmissionQueue::new(capacity, DrrScheduler::new()));
        for _ in 0..tenants {
            q.add_tenant(1.0, usize::MAX);
        }
        q
    }

    #[test]
    fn try_push_full_then_pop_frees_a_slot() {
        let q = queue(2, 1);
        q.try_push(0, 1).unwrap();
        q.try_push(0, 2).unwrap();
        assert_eq!(q.try_push(0, 3), Err(SubmitError::QueueFull));
        assert_eq!(q.depth(), 2);
        assert_eq!(q.pop(), Some((0, 1)));
        q.try_push(0, 3).unwrap();
        assert_eq!(q.pop(), Some((0, 2)));
        assert_eq!(q.pop(), Some((0, 3)));
        assert_eq!(q.lane_snapshot(), vec![(0, 3)]); // three never completed
        q.complete(0);
        q.complete(0);
        q.complete(0);
        assert_eq!(q.lane_snapshot(), vec![(0, 0)]);
    }

    #[test]
    fn blocking_push_wakes_on_drain() {
        let q = queue(1, 1);
        q.try_push(0, 1).unwrap();
        let q2 = Arc::clone(&q);
        let pusher = std::thread::spawn(move || q2.push(0, 2, None));
        // let the pusher reach its wait, then free the slot
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(q.depth(), 1, "pusher must be blocked, not queued");
        assert_eq!(q.pop(), Some((0, 1)));
        pusher.join().unwrap().unwrap();
        assert_eq!(q.pop(), Some((0, 2)));
    }

    #[test]
    fn push_deadline_times_out_while_full() {
        let q = queue(1, 1);
        q.try_push(0, 1).unwrap();
        let t0 = Instant::now();
        assert_eq!(
            q.push(0, 2, Some(Duration::from_millis(40))),
            Err(SubmitError::Timeout)
        );
        assert!(t0.elapsed() >= Duration::from_millis(40));
    }

    #[test]
    fn close_rejects_pushes_and_drains_pops() {
        let q = queue(4, 2);
        q.try_push(0, 10).unwrap();
        q.try_push(1, 20).unwrap();
        q.close();
        assert!(q.is_closed());
        assert_eq!(q.try_push(0, 30), Err(SubmitError::Closed));
        assert_eq!(q.push(0, 30, None), Err(SubmitError::Closed));
        // both queued items still drain, then None
        let mut drained: Vec<u32> = Vec::new();
        while let Some((t, v)) = q.pop() {
            drained.push(v);
            q.complete(t);
        }
        drained.sort_unstable();
        assert_eq!(drained, vec![10, 20]);
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn pop_blocks_until_inflight_slot_frees() {
        // cap the single tenant at 1 inflight
        let q = Arc::new(AdmissionQueue::new(4, DrrScheduler::new()));
        q.add_tenant(1.0, 1);
        q.try_push(0, 1).unwrap();
        q.try_push(0, 2).unwrap();
        let (t, v) = q.pop().unwrap();
        assert_eq!((t, v), (0, 1));
        // second pop must wait for complete(0)
        let q2 = Arc::clone(&q);
        let popper = std::thread::spawn(move || q2.pop());
        std::thread::sleep(Duration::from_millis(30));
        q.complete(0);
        assert_eq!(popper.join().unwrap(), Some((0, 2)));
        q.complete(0);
    }

    #[test]
    fn concurrent_producers_and_workers_preserve_items() {
        let q = queue(8, 4);
        let total = 400u32;
        let workers: Vec<_> = (0..3)
            .map(|_| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    while let Some((t, v)) = q.pop() {
                        got.push(v);
                        q.complete(t);
                    }
                    got
                })
            })
            .collect();
        let producers: Vec<_> = (0..4u32)
            .map(|p| {
                let q = Arc::clone(&q);
                std::thread::spawn(move || {
                    for i in 0..total / 4 {
                        q.push(p as usize, p * 1000 + i, None).unwrap();
                    }
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all: Vec<u32> = workers
            .into_iter()
            .flat_map(|w| w.join().unwrap())
            .collect();
        assert_eq!(all.len(), total as usize);
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), total as usize, "every item delivered exactly once");
    }
}

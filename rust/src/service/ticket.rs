//! Per-request result handles: a [`Ticket`] is the caller's half of one
//! admitted request, fulfilled by whichever worker serves it.

use crate::coordinator::SelectionReport;
use crate::sync;
use anyhow::{anyhow, Result};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Shared slot between a [`Ticket`] and the worker that will fulfil it.
pub(crate) struct TicketCell {
    slot: Mutex<Option<Result<SelectionReport>>>,
    done: Condvar,
}

impl TicketCell {
    fn fulfil(&self, result: Result<SelectionReport>) {
        let mut slot = sync::lock(&self.slot);
        debug_assert!(slot.is_none(), "ticket fulfilled twice");
        *slot = Some(result);
        drop(slot);
        self.done.notify_all();
    }
}

/// The serving side's obligation to resolve one [`Ticket`], enforced by
/// the type system: either [`Fulfiller::fulfil`] runs with a real
/// result, or the `Drop` impl resolves the ticket with an "abandoned"
/// error. Whatever path drops an admitted job — a worker panic between
/// catch points, a queue torn down with items still laned, a future
/// refactor that forgets a code path — the caller's `wait` returns an
/// error instead of hanging forever.
pub(crate) struct Fulfiller {
    cell: Arc<TicketCell>,
    fulfilled: bool,
}

impl Fulfiller {
    /// Resolve the ticket with the served result (consumes the
    /// obligation).
    pub(crate) fn fulfil(mut self, result: Result<SelectionReport>) {
        self.fulfilled = true;
        self.cell.fulfil(result);
    }
}

impl Drop for Fulfiller {
    fn drop(&mut self) {
        if !self.fulfilled {
            self.cell.fulfil(Err(anyhow!(
                "request abandoned: the serving side dropped it before a worker \
                 produced a result"
            )));
        }
    }
}

/// The caller's handle to one admitted request.
///
/// A ticket is always eventually fulfilled: workers fulfil served
/// requests (with the report, or the error the selection produced), a
/// clean shutdown drains every admitted request before the workers
/// exit, and a request dropped unserved resolves with an "abandoned"
/// error via [`Fulfiller`]'s `Drop` — so [`Ticket::wait`] cannot hang.
pub struct Ticket {
    cell: Arc<TicketCell>,
}

impl Ticket {
    /// A fresh pending ticket plus the worker-side fulfilment
    /// obligation.
    pub(crate) fn pending() -> (Ticket, Fulfiller) {
        let cell = Arc::new(TicketCell { slot: Mutex::new(None), done: Condvar::new() });
        (Ticket { cell: Arc::clone(&cell) }, Fulfiller { cell, fulfilled: false })
    }

    /// Non-blocking readiness check: has the report landed?
    pub fn poll(&self) -> bool {
        sync::lock(&self.cell.slot).is_some()
    }

    /// Block until the request is served and take its result.
    pub fn wait(self) -> Result<SelectionReport> {
        let mut slot = sync::lock(&self.cell.slot);
        loop {
            if let Some(r) = slot.take() {
                return r;
            }
            slot = sync::wait(&self.cell.done, slot);
        }
    }

    /// [`Self::wait`] with a timeout: `Err(self)` gives the ticket back
    /// if the result hasn't landed within `d`.
    pub fn wait_timeout(
        self,
        d: Duration,
    ) -> std::result::Result<Result<SelectionReport>, Ticket> {
        let deadline = std::time::Instant::now() + d;
        {
            let mut slot = sync::lock(&self.cell.slot);
            loop {
                if let Some(r) = slot.take() {
                    return Ok(r);
                }
                let now = std::time::Instant::now();
                if now >= deadline {
                    break;
                }
                slot = sync::wait_timeout(&self.cell.done, slot, deadline - now).0;
            }
        }
        Err(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> SelectionReport {
        SelectionReport {
            network: "net".into(),
            platform: "p".into(),
            objective: crate::coordinator::Objective::MinTime,
            provenance: crate::coordinator::CostProvenance::Measured,
            selection: crate::selection::Selection {
                primitive: vec![0],
                objective_ms: 1.0,
                estimated_ms: 1.0,
            },
            evaluated_ms: 1.0,
            peak_workspace_bytes: 0.0,
            front: None,
            wall_ms: 0.0,
            trace: None,
        }
    }

    #[test]
    fn fulfil_then_wait() {
        let (ticket, fulfiller) = Ticket::pending();
        assert!(!ticket.poll());
        fulfiller.fulfil(Ok(report()));
        assert!(ticket.poll());
        assert_eq!(ticket.wait().unwrap().network, "net");
    }

    #[test]
    fn wait_blocks_until_fulfilled_across_threads() {
        let (ticket, fulfiller) = Ticket::pending();
        let t = std::thread::spawn(move || ticket.wait().unwrap().network);
        std::thread::sleep(Duration::from_millis(20));
        fulfiller.fulfil(Ok(report()));
        assert_eq!(t.join().unwrap(), "net");
    }

    #[test]
    fn wait_timeout_returns_the_ticket() {
        let (ticket, fulfiller) = Ticket::pending();
        let ticket = match ticket.wait_timeout(Duration::from_millis(10)) {
            Err(t) => t,
            Ok(_) => panic!("nothing was fulfilled yet"),
        };
        fulfiller.fulfil(Err(anyhow::anyhow!("boom")));
        match ticket.wait_timeout(Duration::from_secs(5)) {
            Ok(r) => assert!(r.is_err()),
            Err(_) => panic!("fulfilled ticket must resolve"),
        }
    }

    #[test]
    fn dropped_fulfiller_resolves_the_ticket_with_abandoned() {
        let (ticket, fulfiller) = Ticket::pending();
        drop(fulfiller);
        let err = ticket.wait().unwrap_err();
        assert!(err.to_string().contains("abandoned"), "{err}");
    }

    #[test]
    fn abandonment_wakes_a_blocked_waiter() {
        let (ticket, fulfiller) = Ticket::pending();
        let t = std::thread::spawn(move || ticket.wait());
        std::thread::sleep(Duration::from_millis(20));
        drop(fulfiller); // e.g. the queue was torn down with the job laned
        let err = t.join().unwrap().unwrap_err();
        assert!(err.to_string().contains("abandoned"), "{err}");
    }
}

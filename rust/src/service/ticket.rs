//! Per-request result handles: a [`Ticket`] is the caller's half of one
//! admitted request, fulfilled by whichever worker serves it.

use crate::coordinator::SelectionReport;
use anyhow::Result;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Shared slot between a [`Ticket`] and the worker that will fulfil it.
pub(crate) struct TicketCell {
    slot: Mutex<Option<Result<SelectionReport>>>,
    done: Condvar,
}

impl TicketCell {
    pub(crate) fn fulfil(&self, result: Result<SelectionReport>) {
        let mut slot = self.slot.lock().expect("ticket poisoned");
        debug_assert!(slot.is_none(), "ticket fulfilled twice");
        *slot = Some(result);
        drop(slot);
        self.done.notify_all();
    }
}

/// The caller's handle to one admitted request.
///
/// A ticket is always eventually fulfilled: workers fulfil served
/// requests (with the report, or the error the selection produced), and
/// a clean shutdown drains every admitted request before the workers
/// exit — so [`Ticket::wait`] cannot hang on a live-or-cleanly-stopped
/// service.
pub struct Ticket {
    cell: Arc<TicketCell>,
}

impl Ticket {
    /// A fresh pending ticket plus the worker-side fulfilment handle.
    pub(crate) fn pending() -> (Ticket, Arc<TicketCell>) {
        let cell = Arc::new(TicketCell { slot: Mutex::new(None), done: Condvar::new() });
        (Ticket { cell: Arc::clone(&cell) }, cell)
    }

    /// Non-blocking readiness check: has the report landed?
    pub fn poll(&self) -> bool {
        self.cell.slot.lock().expect("ticket poisoned").is_some()
    }

    /// Block until the request is served and take its result.
    pub fn wait(self) -> Result<SelectionReport> {
        let mut slot = self.cell.slot.lock().expect("ticket poisoned");
        loop {
            if let Some(r) = slot.take() {
                return r;
            }
            slot = self.cell.done.wait(slot).expect("ticket poisoned");
        }
    }

    /// [`Self::wait`] with a timeout: `Err(self)` gives the ticket back
    /// if the result hasn't landed within `d`.
    pub fn wait_timeout(
        self,
        d: Duration,
    ) -> std::result::Result<Result<SelectionReport>, Ticket> {
        let deadline = std::time::Instant::now() + d;
        {
            let mut slot = self.cell.slot.lock().expect("ticket poisoned");
            loop {
                if let Some(r) = slot.take() {
                    return Ok(r);
                }
                let now = std::time::Instant::now();
                if now >= deadline {
                    break;
                }
                slot = self
                    .cell
                    .done
                    .wait_timeout(slot, deadline - now)
                    .expect("ticket poisoned")
                    .0;
            }
        }
        Err(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> SelectionReport {
        SelectionReport {
            network: "net".into(),
            platform: "p".into(),
            objective: crate::coordinator::Objective::MinTime,
            provenance: crate::coordinator::CostProvenance::Measured,
            selection: crate::selection::Selection { primitive: vec![0], estimated_ms: 1.0 },
            evaluated_ms: 1.0,
            peak_workspace_bytes: 0.0,
            wall_ms: 0.0,
        }
    }

    #[test]
    fn fulfil_then_wait() {
        let (ticket, cell) = Ticket::pending();
        assert!(!ticket.poll());
        cell.fulfil(Ok(report()));
        assert!(ticket.poll());
        assert_eq!(ticket.wait().unwrap().network, "net");
    }

    #[test]
    fn wait_blocks_until_fulfilled_across_threads() {
        let (ticket, cell) = Ticket::pending();
        let t = std::thread::spawn(move || ticket.wait().unwrap().network);
        std::thread::sleep(Duration::from_millis(20));
        cell.fulfil(Ok(report()));
        assert_eq!(t.join().unwrap(), "net");
    }

    #[test]
    fn wait_timeout_returns_the_ticket() {
        let (ticket, cell) = Ticket::pending();
        let ticket = match ticket.wait_timeout(Duration::from_millis(10)) {
            Err(t) => t,
            Ok(_) => panic!("nothing was fulfilled yet"),
        };
        cell.fulfil(Err(anyhow::anyhow!("boom")));
        match ticket.wait_timeout(Duration::from_secs(5)) {
            Ok(r) => assert!(r.is_err()),
            Err(_) => panic!("fulfilled ticket must resolve"),
        }
    }
}

//! Per-tenant fair scheduling policy: deficit-weighted round-robin.
//!
//! The policy is deliberately split from the queue mechanism
//! ([`queue::AdmissionQueue`](super::queue::AdmissionQueue) owns the
//! lanes, locks and condvars; the scheduler only decides *which lane to
//! serve next*), so fairness is testable as pure arithmetic: feed
//! backlogs in, count picks out.
//!
//! ## The algorithm
//!
//! Classic deficit round robin over unit-cost items (every selection
//! request costs one scheduling credit), weighted:
//!
//! * each tenant carries a `deficit` (spendable credit) and a `weight`;
//! * serving a tenant costs `1.0` credit;
//! * when no *eligible* tenant (backlogged and under its max-inflight
//!   cap) has a full credit, every eligible tenant is refilled by
//!   `weight / max_eligible_weight` — the heaviest eligible tenant gains
//!   exactly one credit, so a refill always unblocks someone and
//!   deficits stay bounded (< 2.0);
//! * a tenant whose lane drains forfeits its remaining credit (standard
//!   DRR: you cannot bank priority while idle).
//!
//! Long-run, backlogged tenants are served in proportion to their
//! weights — a weight-4 tenant gets four dispatches for every one a
//! weight-1 tenant gets — and a flood from one tenant can delay another
//! by at most the in-service request plus its own weighted share,
//! never the whole backlog. The `max_inflight` cap bounds how many
//! workers one tenant can occupy at once regardless of backlog.

/// The scheduling policy the admission queue consults under its lock.
///
/// `pick` may mutate internal credit state; the queue guarantees that a
/// `Some(t)` pick is immediately followed by `on_dispatch(t)` and a
/// matching `on_complete(t)` when the request finishes.
pub trait Scheduler: Send {
    /// Register the next tenant lane; lanes are indexed in registration
    /// order, matching the queue's lane indices.
    fn add_tenant(&mut self, weight: f64, max_inflight: usize);

    /// Choose the next lane to serve, given per-lane backlog sizes.
    /// Returns `None` when nothing is eligible: backlog is empty, or
    /// every backlogged tenant is at its max-inflight cap.
    fn pick(&mut self, backlog: &[usize]) -> Option<usize>;

    /// A request from lane `tenant` was handed to a worker.
    fn on_dispatch(&mut self, tenant: usize);

    /// A dispatched request from lane `tenant` finished.
    fn on_complete(&mut self, tenant: usize);

    /// Requests from lane `tenant` currently being served.
    fn inflight(&self, tenant: usize) -> usize;
}

struct TenantSched {
    weight: f64,
    deficit: f64,
    inflight: usize,
    max_inflight: usize,
}

impl TenantSched {
    fn eligible(&self, backlog: usize) -> bool {
        backlog > 0 && self.inflight < self.max_inflight
    }
}

/// Deficit-weighted round robin (see the module docs for the
/// algorithm).
#[derive(Default)]
pub struct DrrScheduler {
    tenants: Vec<TenantSched>,
    /// Lane the last pick landed on; scans resume *after* it, so fresh
    /// credit rotates to the next tenant instead of letting the
    /// last-served lane double-dip straight after a refill. A tenant
    /// with banked credit (a weight above the refill's unit grant) is
    /// still reached within the same pass and spends it.
    cursor: usize,
}

impl DrrScheduler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Refill every eligible tenant proportionally to weight, scaled so
    /// the heaviest eligible tenant gains exactly one credit.
    fn refill(&mut self, backlog: &[usize]) {
        let w_max = self
            .tenants
            .iter()
            .zip(backlog)
            .filter(|(t, &b)| t.eligible(b))
            .map(|(t, _)| t.weight)
            .fold(0.0f64, f64::max);
        if w_max <= 0.0 {
            return;
        }
        for (t, &b) in self.tenants.iter_mut().zip(backlog) {
            if t.eligible(b) {
                t.deficit += t.weight / w_max;
            }
        }
    }
}

impl Scheduler for DrrScheduler {
    fn add_tenant(&mut self, weight: f64, max_inflight: usize) {
        assert!(
            weight.is_finite() && weight > 0.0,
            "tenant weight must be positive, got {weight}"
        );
        self.tenants.push(TenantSched {
            weight,
            deficit: 0.0,
            inflight: 0,
            // a zero cap would deadlock the lane (backlogged, never
            // eligible, nothing inflight to complete); floor at one
            max_inflight: max_inflight.max(1),
        });
    }

    fn pick(&mut self, backlog: &[usize]) -> Option<usize> {
        let n = self.tenants.len();
        debug_assert_eq!(n, backlog.len());
        // a drained lane forfeits its banked credit (standard DRR)
        for (t, &b) in self.tenants.iter_mut().zip(backlog) {
            if b == 0 {
                t.deficit = 0.0;
            }
        }
        if !self.tenants.iter().zip(backlog).any(|(t, &b)| t.eligible(b)) {
            return None;
        }
        // two passes at most: one spending existing credit, and — since a
        // refill gives the heaviest eligible tenant a full credit — one
        // that is guaranteed to find a spender after the refill
        for _ in 0..2 {
            for k in 0..n {
                let i = (self.cursor + 1 + k) % n;
                let t = &mut self.tenants[i];
                if t.eligible(backlog[i]) && t.deficit >= 1.0 {
                    t.deficit -= 1.0;
                    self.cursor = i;
                    return Some(i);
                }
            }
            self.refill(backlog);
        }
        unreachable!("refill always grants a full credit to an eligible tenant")
    }

    fn on_dispatch(&mut self, tenant: usize) {
        self.tenants[tenant].inflight += 1;
    }

    fn on_complete(&mut self, tenant: usize) {
        let t = &mut self.tenants[tenant];
        debug_assert!(t.inflight > 0, "complete without dispatch");
        t.inflight = t.inflight.saturating_sub(1);
    }

    fn inflight(&self, tenant: usize) -> usize {
        self.tenants[tenant].inflight
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive the scheduler like the queue does: pick, dispatch,
    /// complete immediately (single-worker shape), draining `backlog`.
    fn serve_sequence(sched: &mut DrrScheduler, mut backlog: Vec<usize>, n: usize) -> Vec<usize> {
        let mut order = Vec::with_capacity(n);
        for _ in 0..n {
            let t = match sched.pick(&backlog) {
                Some(t) => t,
                None => break,
            };
            sched.on_dispatch(t);
            backlog[t] -= 1;
            order.push(t);
            sched.on_complete(t);
        }
        order
    }

    #[test]
    fn equal_weights_round_robin() {
        let mut s = DrrScheduler::new();
        s.add_tenant(1.0, usize::MAX);
        s.add_tenant(1.0, usize::MAX);
        let order = serve_sequence(&mut s, vec![10, 10], 20);
        let a = order.iter().filter(|&&t| t == 0).count();
        assert_eq!(a, 10);
        // never more than one consecutive serve of the same tenant once
        // both are backlogged and equally weighted
        for w in order.windows(2) {
            assert_ne!(w[0], w[1], "{order:?}");
        }
    }

    #[test]
    fn weighted_shares_are_proportional() {
        let mut s = DrrScheduler::new();
        s.add_tenant(1.0, usize::MAX); // heavy backlog, light weight
        s.add_tenant(4.0, usize::MAX);
        let order = serve_sequence(&mut s, vec![100, 100], 50);
        let heavy = order.iter().filter(|&&t| t == 0).count();
        let light = order.len() - heavy;
        // 4:1 weights → the weight-4 tenant gets ~4x the dispatches
        assert!(light >= 3 * heavy, "light {light} vs heavy {heavy}: {order:?}");
        assert!(heavy >= 5, "weight-1 tenant must not starve: {order:?}");
    }

    #[test]
    fn light_tenant_served_ahead_of_deep_backlog() {
        let mut s = DrrScheduler::new();
        s.add_tenant(1.0, usize::MAX); // 50 queued
        s.add_tenant(8.0, usize::MAX); // 3 queued, 8x weight
        let order = serve_sequence(&mut s, vec![50, 3], 10);
        let light_done_at = order
            .iter()
            .enumerate()
            .filter(|(_, &t)| t == 1)
            .nth(2)
            .map(|(i, _)| i)
            .expect("light tenant fully served");
        assert!(light_done_at <= 4, "light tenant finished at dispatch {light_done_at}: {order:?}");
    }

    #[test]
    fn empty_lane_forfeits_credit() {
        let mut s = DrrScheduler::new();
        s.add_tenant(8.0, usize::MAX);
        s.add_tenant(1.0, usize::MAX);
        // the light tenant is served 10 times while the heavy-weight
        // tenant's lane is empty; idling must not bank credit (an idle
        // refill would), and must not distort shares once it backlogs
        let idle = serve_sequence(&mut s, vec![0, 10], 10);
        assert_eq!(idle, vec![1; 10], "{idle:?}");
        let order = serve_sequence(&mut s, vec![5, 5], 12);
        assert_eq!(order.len(), 10, "both lanes fully drained: {order:?}");
        assert_eq!(order[0], 0, "8x weight leads once backlogged: {order:?}");
        assert!(order.contains(&1), "{order:?}");
    }

    #[test]
    fn inflight_cap_skips_saturated_tenant() {
        let mut s = DrrScheduler::new();
        s.add_tenant(1.0, 1);
        s.add_tenant(1.0, usize::MAX);
        let backlog = vec![5, 5];
        // dispatch tenant 0 once without completing: its lane saturates
        let first = loop {
            let t = s.pick(&backlog).unwrap();
            s.on_dispatch(t);
            if t == 0 {
                break t;
            }
            s.on_complete(t);
        };
        assert_eq!(s.inflight(0), 1);
        // with tenant 0 at its cap, every further pick lands on tenant 1
        for _ in 0..4 {
            let t = s.pick(&backlog).unwrap();
            assert_eq!(t, 1);
            s.on_dispatch(t);
            s.on_complete(t);
        }
        s.on_complete(first);
        assert_eq!(s.inflight(0), 0);
        assert_eq!(s.inflight(1), 0);
        // the freed slot makes tenant 0 schedulable again
        let seen0 = (0..4).any(|_| {
            let t = s.pick(&backlog).unwrap();
            s.on_dispatch(t);
            s.on_complete(t);
            t == 0
        });
        assert!(seen0);
    }

    #[test]
    fn nothing_eligible_returns_none() {
        let mut s = DrrScheduler::new();
        s.add_tenant(1.0, 1);
        assert_eq!(s.pick(&[0]), None); // empty backlog
        let t = s.pick(&[3]).unwrap();
        s.on_dispatch(t);
        assert_eq!(s.pick(&[2]), None); // backlogged but at the cap
        s.on_complete(t);
        assert_eq!(s.pick(&[2]), Some(0));
    }

    #[test]
    fn zero_max_inflight_is_floored_to_one() {
        let mut s = DrrScheduler::new();
        s.add_tenant(1.0, 0);
        assert_eq!(s.pick(&[1]), Some(0));
    }

    #[test]
    #[should_panic(expected = "weight must be positive")]
    fn rejects_non_positive_weight() {
        DrrScheduler::new().add_tenant(0.0, 1);
    }
}

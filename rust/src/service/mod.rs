//! # The admission-controlled serving layer
//!
//! [`Coordinator::submit_batch`](crate::coordinator::Coordinator::submit_batch)
//! is a synchronous fan-out: the caller owns the batch, the batch owns
//! the threads, and one tenant's thousand-request sweep monopolises the
//! process while everyone else waits. This module is the traffic-shaped
//! alternative — a [`Service`] in front of the coordinator that admits,
//! schedules and serves requests from *many* tenants concurrently and
//! continuously:
//!
//! ```text
//!   tenants ── submit / try_submit ──► AdmissionQueue (bounded, per-
//!      ▲        (Ticket out,            tenant lanes; QueueFull /
//!      │         QueueFull back)        blocking + deadline)
//!      │                                      │ DrrScheduler picks
//!      │                                      ▼ (weights, inflight caps)
//!   Ticket::wait/poll ◄── fulfil ── worker pool (par::Pool, persistent)
//!                                             │ Coordinator::select_one
//!                                             ▼
//!                               per-platform shared CostCaches
//! ```
//!
//! The module split mirrors the pipeline: [`queue`] is the bounded
//! MPMC admission mechanism, [`sched`] the deficit-weighted round-robin
//! fairness policy, [`worker`] the persistent drain loop, [`stats`] the
//! instruments ([`ServiceStats`]). Three properties the test suite
//! (`rust/tests/service.rs`) pins:
//!
//! * **Transparency** — served reports are bit-identical to calling
//!   `submit_batch` with the same requests: the service reshapes *when*
//!   work runs, never *what* it computes. That covers the front-served
//!   objectives (`FastestUnderBytes` / `SmallestWithinPct`) too: workers
//!   call `select_one`, so tickets answer from the coordinator's cached
//!   Pareto fronts exactly like direct submissions do.
//! * **Backpressure** — at capacity, [`Service::try_submit`] refuses
//!   with [`SubmitError::QueueFull`] instead of buffering without
//!   bound; blocked [`Service::submit`] calls wake as workers drain.
//! * **Fairness** — a flood from one tenant cannot starve another:
//!   dispatch order follows tenant weights (deficit round robin), so a
//!   weighted interactive tenant's requests complete while a batch
//!   tenant's backlog is still queued.

pub mod queue;
pub mod sched;
pub mod stats;
mod ticket;
pub mod worker;

pub use queue::SubmitError;
pub use stats::{HistogramSnapshot, LatencyHistogram, ServiceStats, TenantStats};
pub use ticket::Ticket;

use crate::coordinator::{Coordinator, SelectionRequest};
use crate::obs;
use crate::obs::clock::Clock;
use crate::par;
use crate::selection::CacheStats;
use crate::sync;
use queue::AdmissionQueue;
use sched::DrrScheduler;
use stats::TenantCounters;
use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};
use worker::Job;

/// How a [`Service`] is shaped: admission bound, pool size, the
/// defaults for tenants that are not explicitly registered, and the
/// optional ops plane (series sampler + SLO engine).
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Max admitted-but-undispatched requests across all tenants; at
    /// this bound `try_submit` rejects and `submit` blocks.
    pub capacity: usize,
    /// Persistent worker threads draining the scheduler.
    pub workers: usize,
    /// Scheduling weight for tenants first seen via `submit`.
    pub default_weight: f64,
    /// Max concurrently-served requests for tenants first seen via
    /// `submit` (caps how much of the pool one tenant can occupy).
    pub default_max_inflight: usize,
    /// When set, the service owns a `primsel-sampler` thread that ticks
    /// the ops plane at this sampler's cadence: publish metrics, take a
    /// series sample, evaluate the SLOs.
    pub sampling: Option<obs::SamplerConfig>,
    /// SLOs the ops tick evaluates (ignored without `sampling`).
    pub slos: Vec<obs::SloSpec>,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            capacity: 256,
            workers: par::workers().clamp(2, 8),
            default_weight: 1.0,
            default_max_inflight: usize::MAX,
            sampling: None,
            slos: Vec::new(),
        }
    }
}

impl ServiceConfig {
    /// Override the admission capacity (builder style).
    pub fn with_capacity(mut self, capacity: usize) -> Self {
        self.capacity = capacity;
        self
    }

    /// Override the worker-pool size (builder style).
    pub fn with_workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    /// Override the defaults applied to auto-registered tenants
    /// (builder style).
    pub fn with_tenant_defaults(mut self, weight: f64, max_inflight: usize) -> Self {
        self.default_weight = weight;
        self.default_max_inflight = max_inflight;
        self
    }

    /// Enable the ops plane with the default sampler ring capacity at
    /// `cadence` (builder style).
    pub fn with_sampling(self, cadence: Duration) -> Self {
        self.with_sampler(obs::SamplerConfig::every(cadence))
    }

    /// Enable the ops plane with an explicit sampler shape (builder
    /// style).
    pub fn with_sampler(mut self, cfg: obs::SamplerConfig) -> Self {
        self.sampling = Some(cfg);
        self
    }

    /// Add one SLO for the ops tick to evaluate (builder style).
    pub fn with_slo(mut self, spec: obs::SloSpec) -> Self {
        self.slos.push(spec);
        self
    }
}

/// One tenant's identity + counters, shared between submitters, workers
/// and stats readers.
pub(crate) struct TenantMeta {
    name: String,
    weight: f64,
    pub(crate) counters: TenantCounters,
}

impl TenantMeta {
    /// Tenant lane name (tagged onto flight-recorder request entries).
    pub(crate) fn name(&self) -> &str {
        &self.name
    }
}

#[derive(Default)]
struct TenantTable {
    metas: Vec<Arc<TenantMeta>>,
    by_name: HashMap<String, usize>,
}

/// Everything the worker pool shares with the service front door.
pub(crate) struct ServiceShared {
    pub(crate) queue: AdmissionQueue<Job, DrrScheduler>,
    pub(crate) coord: Arc<Coordinator>,
    tenants: RwLock<TenantTable>,
    workers: usize,
    pub(crate) wait: LatencyHistogram,
    pub(crate) service: LatencyHistogram,
    /// Per-platform cache counters at service start; stats() reports
    /// deltas against this.
    baseline: Vec<(String, CacheStats)>,
    /// Registry handles the workers record into on the hot path.
    pub(crate) obs: ServiceObs,
}

/// Pre-resolved handles into the process [`obs::Registry`]: looked up
/// once at service construction so the worker hot path is a pure
/// atomic-increment (no name hashing, no registry lock).
pub(crate) struct ServiceObs {
    /// `primsel.trace.stage_ms{stage="queue"}` — admit → dispatch.
    pub(crate) queue_ms: obs::Histogram,
    /// `primsel.trace.stage_ms{stage="e2e"}` — admit → done.
    pub(crate) e2e_ms: obs::Histogram,
}

impl ServiceObs {
    fn resolve() -> ServiceObs {
        let reg = obs::registry();
        ServiceObs {
            queue_ms: reg.histogram(obs::names::STAGE_MS, &[("stage", "queue")]),
            e2e_ms: reg.histogram(obs::names::STAGE_MS, &[("stage", "e2e")]),
        }
    }
}

impl ServiceShared {
    pub(crate) fn tenant_meta(&self, id: usize) -> Arc<TenantMeta> {
        Arc::clone(&sync::read(&self.tenants).metas[id])
    }

    /// A point-in-time [`ServiceStats`] snapshot. Lives on the shared
    /// state so the `primsel-sampler` thread can take one per tick
    /// without holding a `Service` reference.
    fn stats(&self) -> ServiceStats {
        let lanes = self.queue.lane_snapshot();
        let table = sync::read(&self.tenants);
        let tenants = table
            .metas
            .iter()
            .enumerate()
            .map(|(i, m)| {
                let (queued, inflight) = lanes.get(i).copied().unwrap_or((0, 0));
                TenantStats {
                    tenant: m.name.clone(),
                    weight: m.weight,
                    admitted: m.counters.admitted.load(Ordering::Relaxed),
                    rejected: m.counters.rejected.load(Ordering::Relaxed),
                    served: m.counters.served.load(Ordering::Relaxed),
                    queued,
                    inflight,
                }
            })
            .collect();
        drop(table);
        let platforms = self
            .coord
            .cache_stats()
            .into_iter()
            .map(|(name, s)| {
                let before = self
                    .baseline
                    .iter()
                    .find(|(n, _)| *n == name)
                    .map(|(_, b)| *b)
                    .unwrap_or_default();
                (name, s.since(&before))
            })
            .collect();
        ServiceStats {
            queue_depth: self.queue.depth(),
            capacity: self.queue.capacity(),
            workers: self.workers,
            tenants,
            wait: self.wait.snapshot(),
            service: self.service.snapshot(),
            platforms,
            plan_cache: self.coord.plan_cache_stats(),
            front_cache: self.coord.front_cache_stats(),
            health: self.coord.platform_health(),
        }
    }
}

/// Ops-plane state owned by the service and shared with its
/// `primsel-sampler` thread: the series sampler, the SLO engine, the
/// production clock they tick on, and the shutdown latch.
struct OpsState {
    sampler: obs::Sampler,
    engine: Mutex<obs::SloEngine>,
    clock: obs::SystemClock,
    stop: Mutex<bool>,
    wake: Condvar,
}

/// The admission-controlled serving layer over a shared
/// [`Coordinator`]. See the module docs for the architecture.
///
/// Dropping the service performs a clean shutdown: admission closes,
/// workers drain every already-admitted request (fulfilling its
/// [`Ticket`]), and the pool is joined. Use [`Service::shutdown`] to do
/// this explicitly.
///
/// ```
/// use primsel::coordinator::{Coordinator, SelectionRequest};
/// use primsel::service::{Service, ServiceConfig};
/// use primsel::networks;
///
/// let service = Service::new(
///     Coordinator::shared(),
///     ServiceConfig::default().with_capacity(16).with_workers(2),
/// );
/// // two tenants submit concurrently-served requests and get tickets
/// let a = service
///     .submit("interactive", SelectionRequest::new(networks::alexnet(), "intel"))
///     .unwrap();
/// let b = service
///     .submit("batch", SelectionRequest::new(networks::vgg(11), "arm"))
///     .unwrap();
/// let report = a.wait().unwrap();
/// assert_eq!(report.network, "alexnet");
/// assert!(b.wait().unwrap().evaluated_ms > 0.0);
/// let stats = service.stats();
/// assert_eq!(stats.tenants.len(), 2);
/// assert_eq!(stats.tenants.iter().map(|t| t.served).sum::<u64>(), 2);
/// service.shutdown();
/// ```
pub struct Service {
    shared: Arc<ServiceShared>,
    pool: Option<par::Pool>,
    default_weight: f64,
    default_max_inflight: usize,
    /// Present when the config enabled sampling.
    ops: Option<Arc<OpsState>>,
    sampler_thread: Option<std::thread::JoinHandle<()>>,
}

impl Service {
    /// Start a service over `coord`: build the admission queue and spawn
    /// the persistent worker pool. The coordinator handle is shared —
    /// synchronous `submit_batch` callers and the service can coexist on
    /// the same platform caches, and the coordinator outlives service
    /// shutdown.
    pub fn new(coord: Arc<Coordinator>, config: ServiceConfig) -> Service {
        assert!(config.workers >= 1, "a service needs at least one worker");
        // validate the auto-registration defaults now: failing later,
        // inside the first submit's tenant registration, would poison
        // the tenant table instead of pointing at the bad config
        assert!(
            config.default_weight.is_finite() && config.default_weight > 0.0,
            "default tenant weight must be positive, got {}",
            config.default_weight
        );
        let shared = Arc::new(ServiceShared {
            queue: AdmissionQueue::new(config.capacity, DrrScheduler::new()),
            baseline: coord.cache_stats(),
            coord,
            tenants: RwLock::new(TenantTable::default()),
            workers: config.workers,
            wait: LatencyHistogram::new(),
            service: LatencyHistogram::new(),
            obs: ServiceObs::resolve(),
        });
        let pool = worker::spawn(&shared, config.workers);
        let (ops, sampler_thread) = match config.sampling {
            Some(sampler_cfg) => {
                let engine = obs::SloEngine::new(config.slos)
                    .unwrap_or_else(|e| panic!("invalid SLO config: {e}"));
                let ops = Arc::new(OpsState {
                    sampler: obs::Sampler::new(sampler_cfg),
                    engine: Mutex::new(engine),
                    clock: obs::SystemClock::new(),
                    stop: Mutex::new(false),
                    wake: Condvar::new(),
                });
                let thread = {
                    let shared = Arc::clone(&shared);
                    let ops = Arc::clone(&ops);
                    std::thread::Builder::new()
                        .name("primsel-sampler".to_string())
                        .spawn(move || loop {
                            ops_tick(&shared, &ops);
                            let cadence = ops.sampler.cadence();
                            let guard = sync::lock(&ops.stop);
                            if *guard {
                                break;
                            }
                            let (guard, _) = sync::wait_timeout(&ops.wake, guard, cadence);
                            if *guard {
                                break;
                            }
                        })
                        .expect("spawning primsel-sampler")
                };
                (Some(ops), Some(thread))
            }
            None => (None, None),
        };
        Service {
            shared,
            pool: Some(pool),
            default_weight: config.default_weight,
            default_max_inflight: config.default_max_inflight,
            ops,
            sampler_thread,
        }
    }

    /// Register `name` with an explicit scheduling weight and
    /// max-inflight cap. Errors if the tenant already exists (weights
    /// are fixed at registration — re-weighting live lanes would make
    /// past fairness unauditable).
    pub fn register_tenant(
        &self,
        name: &str,
        weight: f64,
        max_inflight: usize,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            weight.is_finite() && weight > 0.0,
            "tenant weight must be positive, got {weight}"
        );
        let mut table = sync::write(&self.shared.tenants);
        anyhow::ensure!(
            !table.by_name.contains_key(name),
            "tenant {name:?} is already registered"
        );
        self.insert_tenant(&mut table, name, weight, max_inflight);
        Ok(())
    }

    /// The one place a tenant lane comes into being: keeps the dense-id
    /// invariant (queue lane index == metas index == by_name value) in a
    /// single code path. Caller holds the table write lock.
    fn insert_tenant(
        &self,
        table: &mut TenantTable,
        name: &str,
        weight: f64,
        max_inflight: usize,
    ) -> usize {
        let id = self.shared.queue.add_tenant(weight, max_inflight);
        debug_assert_eq!(id, table.metas.len());
        table.metas.push(Arc::new(TenantMeta {
            name: name.to_string(),
            weight,
            counters: TenantCounters::default(),
        }));
        table.by_name.insert(name.to_string(), id);
        id
    }

    /// Resolve (or auto-register with the config defaults) a tenant id.
    fn tenant_id(&self, name: &str) -> usize {
        if let Some(&id) = sync::read(&self.shared.tenants).by_name.get(name) {
            return id;
        }
        let mut table = sync::write(&self.shared.tenants);
        if let Some(&id) = table.by_name.get(name) {
            return id; // raced another registrar; keep the winner
        }
        self.insert_tenant(&mut table, name, self.default_weight, self.default_max_inflight)
    }

    fn admit(
        &self,
        tenant: &str,
        req: SelectionRequest,
        mode: AdmitMode,
    ) -> Result<Ticket, SubmitError> {
        let id = self.tenant_id(tenant);
        let meta = self.shared.tenant_meta(id);
        let (ticket, cell) = Ticket::pending();
        let mut req = req;
        req.trace.get_or_insert_with(obs::Trace::begin).mark(obs::Stage::Admit);
        let job = Job { req, admitted_at: Instant::now(), cell };
        let outcome = match mode {
            AdmitMode::Try => self.shared.queue.try_push(id, job),
            AdmitMode::Block => self.shared.queue.push(id, job, None),
            AdmitMode::Deadline(d) => self.shared.queue.push(id, job, Some(d)),
        };
        match outcome {
            Ok(()) => {
                meta.counters.admitted.fetch_add(1, Ordering::Relaxed);
                Ok(ticket)
            }
            Err(e) => {
                // only backpressure counts as rejected (that's what the
                // counter documents); Closed is lifecycle, not load
                if matches!(e, SubmitError::QueueFull | SubmitError::Timeout) {
                    meta.counters.rejected.fetch_add(1, Ordering::Relaxed);
                }
                Err(e)
            }
        }
    }

    /// Admit one request, blocking while the queue is at capacity.
    /// Returns the request's [`Ticket`]; a request whose platform is
    /// unknown (or whose selection fails) is still admitted and served —
    /// the error comes back through [`Ticket::wait`].
    pub fn submit(&self, tenant: &str, req: SelectionRequest) -> Result<Ticket, SubmitError> {
        self.admit(tenant, req, AdmitMode::Block)
    }

    /// [`Self::submit`] with an admission deadline: blocks at most
    /// `deadline`, then fails with [`SubmitError::Timeout`].
    pub fn submit_deadline(
        &self,
        tenant: &str,
        req: SelectionRequest,
        deadline: Duration,
    ) -> Result<Ticket, SubmitError> {
        self.admit(tenant, req, AdmitMode::Deadline(deadline))
    }

    /// Non-blocking admission: at capacity, fail *now* with
    /// [`SubmitError::QueueFull`] — the backpressure signal.
    pub fn try_submit(&self, tenant: &str, req: SelectionRequest) -> Result<Ticket, SubmitError> {
        self.admit(tenant, req, AdmitMode::Try)
    }

    /// The coordinator this service serves from.
    pub fn coordinator(&self) -> &Arc<Coordinator> {
        &self.shared.coord
    }

    /// A point-in-time [`ServiceStats`] snapshot.
    pub fn stats(&self) -> ServiceStats {
        self.shared.stats()
    }

    /// Publish a scrape-time snapshot of the service's state into the
    /// process-wide [`obs::Registry`] and return it. Stage latencies
    /// (`primsel.trace.stage_ms{stage=queue|solve|e2e}`) accumulate
    /// live on the hot path; everything else — queue gauges, tenant
    /// counters, cache hit ratios, platform health, flight-recorder
    /// totals — is published here as absolute values, so calling this
    /// right before [`obs::Registry::render_prometheus`] or
    /// [`obs::Registry::snapshot_json`] yields a coherent exposition.
    pub fn metrics(&self) -> &'static obs::Registry {
        publish_metrics(&self.stats())
    }

    /// Run one ops tick by hand: publish metrics, take a series sample,
    /// evaluate the SLOs. The `primsel-sampler` thread calls the same
    /// path on its cadence; this gives tests and CLI dumps a
    /// deterministic "one more tick right now". No-op when the config
    /// did not enable sampling.
    pub fn ops_tick(&self) {
        if let Some(ops) = &self.ops {
            ops_tick(&self.shared, ops);
        }
    }

    /// The ops-plane digest: drained series, SLO alert states, and
    /// flight-recorder coverage. `None` when the config did not enable
    /// sampling.
    pub fn ops_report(&self) -> Option<obs::OpsReport> {
        let ops = self.ops.as_ref()?;
        let rec = obs::flight_recorder();
        Some(obs::OpsReport {
            at_ns: ops.clock.now_ns(),
            ticks: ops.sampler.ticks(),
            series: ops.sampler.snapshot(),
            alerts: sync::lock(&ops.engine).alerts(),
            recorder: obs::RecorderCounts {
                requests: rec.requests_recorded(),
                events: rec.events_recorded(),
                slow: rec.slow_captured(),
                requests_dropped: rec.requests_dropped(),
                events_dropped: rec.events_dropped(),
            },
        })
    }

    /// Clean shutdown: stop the sampler thread, close admission, drain
    /// every already-admitted request (each ticket is fulfilled), join
    /// the pool. Idempotent with the `Drop` impl.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        if let Some(ops) = &self.ops {
            *sync::lock(&ops.stop) = true;
            ops.wake.notify_all();
        }
        if let Some(thread) = self.sampler_thread.take() {
            let _ = thread.join();
        }
        self.shared.queue.close();
        if let Some(pool) = self.pool.take() {
            pool.join();
        }
    }
}

/// Publish a scrape-time snapshot of `stats` into the process-wide
/// [`obs::Registry`] and return it (see [`Service::metrics`]). Shared
/// between scrape calls and the ops tick.
fn publish_metrics(stats: &ServiceStats) -> &'static obs::Registry {
    let reg = obs::registry();
    reg.gauge(obs::names::QUEUE_DEPTH, &[]).set(stats.queue_depth as f64);
    reg.gauge(obs::names::QUEUE_CAPACITY, &[]).set(stats.capacity as f64);
    reg.gauge(obs::names::WORKERS, &[]).set(stats.workers as f64);
    for t in &stats.tenants {
        let lbl: &[(&str, &str)] = &[("tenant", t.tenant.as_str())];
        reg.counter(obs::names::TENANT_ADMITTED, lbl).store(t.admitted);
        reg.counter(obs::names::TENANT_REJECTED, lbl).store(t.rejected);
        reg.counter(obs::names::TENANT_SERVED, lbl).store(t.served);
    }
    for (platform, s) in &stats.platforms {
        let lbl: &[(&str, &str)] = &[("platform", platform.as_str())];
        reg.counter(obs::names::COST_HITS, lbl).store(s.hits());
        reg.counter(obs::names::COST_MISSES, lbl).store(s.misses());
        reg.gauge(obs::names::COST_HIT_RATIO, lbl).set(s.hit_ratio());
    }
    let ratio = |h: u64, m: u64| if h + m == 0 { 0.0 } else { h as f64 / (h + m) as f64 };
    let (ph, pm) = stats.plan_cache;
    reg.counter(obs::names::PLAN_HITS, &[]).store(ph);
    reg.counter(obs::names::PLAN_MISSES, &[]).store(pm);
    reg.gauge(obs::names::PLAN_HIT_RATIO, &[]).set(ratio(ph, pm));
    let (fh, fm) = stats.front_cache;
    reg.counter(obs::names::FRONT_HITS, &[]).store(fh);
    reg.counter(obs::names::FRONT_MISSES, &[]).store(fm);
    reg.gauge(obs::names::FRONT_HIT_RATIO, &[]).set(ratio(fh, fm));
    for h in &stats.health {
        let lbl: &[(&str, &str)] = &[("platform", h.platform.as_str())];
        reg.gauge(obs::names::HEALTH_STATE, lbl).set(h.state.code() as f64);
        reg.gauge(obs::names::HEALTH_DRIFT, lbl).set(h.drift);
    }
    let rec = obs::flight_recorder();
    reg.counter(obs::names::RECORDER_REQUESTS, &[]).store(rec.requests_recorded());
    reg.counter(obs::names::RECORDER_EVENTS, &[]).store(rec.events_recorded());
    reg.counter(obs::names::RECORDER_SLOW, &[]).store(rec.slow_captured());
    reg.counter(obs::names::RECORDER_REQUESTS_DROPPED, &[]).store(rec.requests_dropped());
    reg.counter(obs::names::RECORDER_EVENTS_DROPPED, &[]).store(rec.events_dropped());
    reg
}

/// One ops-plane tick: publish the service's state into the registry,
/// evaluate the SLOs against it (recording transitions in the flight
/// recorder, publishing alert gauges, and nudging the health monitor on
/// Critical drift/latency alerts), then take a series sample so the
/// rings see the freshly published values.
fn ops_tick(shared: &ServiceShared, ops: &OpsState) {
    let stats = shared.stats();
    let reg = publish_metrics(&stats);

    let mut inputs = obs::SloInputs {
        error_rate: {
            let (adm, rej) = stats
                .tenants
                .iter()
                .fold((0u64, 0u64), |(a, r), t| (a + t.admitted, r + t.rejected));
            if adm + rej == 0 { 0.0 } else { rej as f64 / (adm + rej) as f64 }
        },
        queue_frac: if stats.capacity == 0 {
            0.0
        } else {
            stats.queue_depth as f64 / stats.capacity as f64
        },
        ..obs::SloInputs::default()
    };
    inputs.latency_p95_ms.push(("wait".to_string(), stats.wait.p95_ms));
    inputs.latency_p95_ms.push(("service".to_string(), stats.service.p95_ms));
    inputs
        .latency_p95_ms
        .push(("e2e".to_string(), shared.obs.e2e_ms.snapshot().p95_ms));
    for h in &stats.health {
        inputs.drift.push((h.platform.clone(), h.drift));
    }

    let t_ns = ops.clock.now_ns();
    let transitions = sync::lock(&ops.engine).evaluate(t_ns, &inputs);
    let rec = obs::flight_recorder();
    for tr in &transitions {
        rec.record_alert(&tr.slo, tr.from.name(), tr.to.name(), tr.burn_fast);
        if tr.to == obs::AlertState::Critical {
            if let Some(n) = tr.nudge {
                // close the obs→health loop: a Critical drift alert
                // pulls that platform's shadow sampling forward; a
                // Critical latency alert pulls every monitored platform
                match &tr.sli {
                    obs::Sli::Drift { platform } => {
                        shared.coord.boost_shadow_sampling(platform, n);
                    }
                    obs::Sli::LatencyP95 { .. } => {
                        shared.coord.boost_all_shadow_sampling(n);
                    }
                    _ => {}
                }
            }
        }
    }
    for a in sync::lock(&ops.engine).alerts() {
        let lbl: &[(&str, &str)] = &[("slo", a.slo.as_str())];
        reg.gauge(obs::names::SLO_STATE, lbl).set(a.state.code());
        reg.gauge(obs::names::SLO_BURN_FAST, lbl).set(a.burn_fast);
        reg.gauge(obs::names::SLO_BURN_SLOW, lbl).set(a.burn_slow);
    }
    ops.sampler.sample(obs::registry(), &ops.clock);
    reg.counter(obs::names::SERIES_TICKS, &[]).store(ops.sampler.ticks());
}

impl Drop for Service {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

enum AdmitMode {
    Try,
    Block,
    Deadline(Duration),
}

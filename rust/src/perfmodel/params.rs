//! ParamStore: flat model parameters (W0, b0, ..., W4, b4) with binary
//! save/load so trained models persist across runs (and benches reuse
//! pre-trained weights).

use crate::runtime::{literal_f32, to_f32_vec, ModelSpec, Runtime};
use anyhow::{ensure, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"PRIMSEL1";

/// Flat parameter tensors in the manifest's fixed order.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamStore {
    pub shapes: Vec<Vec<usize>>,
    pub tensors: Vec<Vec<f32>>,
}

impl ParamStore {
    pub fn new(shapes: Vec<Vec<usize>>, tensors: Vec<Vec<f32>>) -> Self {
        assert_eq!(shapes.len(), tensors.len());
        for (s, t) in shapes.iter().zip(&tensors) {
            assert_eq!(s.iter().product::<usize>(), t.len());
        }
        Self { shapes, tensors }
    }

    /// Zero-initialised parameters for a model spec (Adam m/v state).
    pub fn zeros_like(spec: &ModelSpec) -> Self {
        let shapes = spec.param_shapes.clone();
        let tensors = shapes
            .iter()
            .map(|s| vec![0.0f32; s.iter().product()])
            .collect();
        Self { shapes, tensors }
    }

    /// From PJRT output literals.
    pub fn from_literals(spec: &ModelSpec, lits: &[xla::Literal]) -> Result<Self> {
        ensure!(lits.len() == spec.param_shapes.len(), "literal count");
        let tensors = lits.iter().map(to_f32_vec).collect::<Result<Vec<_>>>()?;
        Ok(Self::new(spec.param_shapes.clone(), tensors))
    }

    /// To PJRT input literals (appends to `out`).
    pub fn push_literals(&self, out: &mut Vec<xla::Literal>) -> Result<()> {
        for (shape, data) in self.shapes.iter().zip(&self.tensors) {
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            out.push(literal_f32(data, &dims)?);
        }
        Ok(())
    }

    pub fn n_values(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// Binary save: magic, tensor count, then (ndim, dims..., data) each.
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut f = std::fs::File::create(path)?;
        f.write_all(MAGIC)?;
        f.write_all(&(self.tensors.len() as u32).to_le_bytes())?;
        for (shape, data) in self.shapes.iter().zip(&self.tensors) {
            f.write_all(&(shape.len() as u32).to_le_bytes())?;
            for &d in shape {
                f.write_all(&(d as u32).to_le_bytes())?;
            }
            for &v in data {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let mut f = std::fs::File::open(path)?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        ensure!(&magic == MAGIC, "bad param file magic");
        let count = read_u32(&mut f)? as usize;
        let mut shapes = Vec::with_capacity(count);
        let mut tensors = Vec::with_capacity(count);
        for _ in 0..count {
            let ndim = read_u32(&mut f)? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(read_u32(&mut f)? as usize);
            }
            let n: usize = shape.iter().product();
            let mut buf = vec![0u8; n * 4];
            f.read_exact(&mut buf)?;
            let data: Vec<f32> = buf
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            shapes.push(shape);
            tensors.push(data);
        }
        Ok(Self::new(shapes, tensors))
    }
}

fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

/// Initialise parameters by running the model's `init` artifact.
pub fn init_params(rt: &Runtime, spec: &ModelSpec, seed: i32) -> Result<ParamStore> {
    let exe = rt.load(&spec.files["init"])?;
    let out = rt.execute(&exe, &[crate::runtime::scalar_i32(seed)])?;
    ParamStore::from_literals(spec, &out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_load_round_trip() {
        let p = ParamStore::new(
            vec![vec![2, 3], vec![3]],
            vec![vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], vec![0.1, 0.2, 0.3]],
        );
        let dir = std::env::temp_dir().join("primsel_test_params.bin");
        p.save(&dir).unwrap();
        let q = ParamStore::load(&dir).unwrap();
        assert_eq!(p, q);
        std::fs::remove_file(dir).ok();
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = std::env::temp_dir().join("primsel_test_garbage.bin");
        std::fs::write(&dir, b"not a param file").unwrap();
        assert!(ParamStore::load(&dir).is_err());
        std::fs::remove_file(dir).ok();
    }

    #[test]
    #[should_panic]
    fn shape_data_mismatch_panics() {
        ParamStore::new(vec![vec![2, 2]], vec![vec![1.0]]);
    }
}

//! Evaluation metrics: the paper's MdRAE (median relative absolute error,
//! §3.3) plus helpers used across the experiment suite.

/// Relative absolute error |ŷ - y| / y.
pub fn rae(pred: f64, actual: f64) -> f64 {
    (pred - actual).abs() / actual.abs().max(1e-12)
}

/// Median of a slice (copies; n log n).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// MdRAE over (pred, actual) pairs.
pub fn mdrae(pairs: &[(f64, f64)]) -> f64 {
    let raes: Vec<f64> = pairs.iter().map(|&(p, a)| rae(p, a)).collect();
    median(&raes)
}

/// Per-output-column MdRAE for masked prediction matrices.
/// `preds[i][j]`, `actuals[i][j]` with None = undefined. Columns with no
/// defined points yield NaN.
pub fn mdrae_per_column(
    preds: &[Vec<f64>],
    actuals: &[Vec<Option<f64>>],
) -> Vec<f64> {
    let cols = actuals.first().map_or(0, |r| r.len());
    let mut out = Vec::with_capacity(cols);
    for j in 0..cols {
        let pairs: Vec<(f64, f64)> = preds
            .iter()
            .zip(actuals)
            .filter_map(|(p, a)| a[j].map(|av| (p[j], av)))
            .collect();
        out.push(mdrae(&pairs));
    }
    out
}

/// Overall MdRAE across all defined cells.
pub fn mdrae_all(preds: &[Vec<f64>], actuals: &[Vec<Option<f64>>]) -> f64 {
    let mut pairs = Vec::new();
    for (p, a) in preds.iter().zip(actuals) {
        for (j, av) in a.iter().enumerate() {
            if let Some(av) = av {
                pairs.push((p[j], *av));
            }
        }
    }
    mdrae(&pairs)
}

/// Geometric mean (for speedup summaries).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.max(1e-300).ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rae_basics() {
        assert_eq!(rae(1.1, 1.0), 0.10000000000000009);
        assert_eq!(rae(0.9, 1.0), 0.09999999999999998);
        assert_eq!(rae(2.0, 2.0), 0.0);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert!(median(&[]).is_nan());
    }

    #[test]
    fn mdrae_is_robust_to_outliers() {
        // one terrible prediction must not dominate the median
        let pairs = [(1.0, 1.0), (2.0, 2.0), (100.0, 1.0), (3.0, 3.0), (4.0, 4.0)];
        assert_eq!(mdrae(&pairs), 0.0);
    }

    #[test]
    fn per_column_masks() {
        let preds = vec![vec![1.0, 5.0], vec![2.0, 7.0]];
        let actuals = vec![
            vec![Some(1.0), None],
            vec![Some(4.0), Some(7.0)],
        ];
        let m = mdrae_per_column(&preds, &actuals);
        assert!((m[0] - 0.25).abs() < 1e-12); // median of {0, 0.5}
        assert_eq!(m[1], 0.0);
    }

    #[test]
    fn geomean_matches_hand() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
    }
}

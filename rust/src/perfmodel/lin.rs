//! The paper's linear-regression baseline ("Lin"): ordinary least squares
//! per output column on the same log-standardised features/targets as the
//! neural models, fitted in closed form (normal equations + Cholesky) —
//! no PJRT involvement.

use crate::dataset::Standardizer;
use crate::linalg::{least_squares, Matrix};
use anyhow::{ensure, Result};

/// Per-output linear model on log-standardised features (+ bias).
#[derive(Debug, Clone)]
pub struct LinModel {
    pub std_x: Standardizer,
    pub std_y: Standardizer,
    /// weights[j] has in_dim + 1 coefficients (bias last).
    pub weights: Vec<Vec<f64>>,
}

impl LinModel {
    /// Fit on raw features and masked raw targets.
    pub fn fit(
        xs: &[Vec<f64>],
        ys: &[Vec<Option<f64>>],
        std_x: Standardizer,
        std_y: Standardizer,
    ) -> Result<LinModel> {
        ensure!(!xs.is_empty(), "empty training set");
        let out_dim = ys[0].len();
        let in_dim = xs[0].len();
        let xn: Vec<Vec<f64>> = xs.iter().map(|x| std_x.forward(x)).collect();
        let mut weights = Vec::with_capacity(out_dim);
        for j in 0..out_dim {
            let mut rows = Vec::new();
            let mut targets = Vec::new();
            for (x, y) in xn.iter().zip(ys) {
                if let Some(v) = y[j] {
                    let mut r = x.clone();
                    r.push(1.0); // bias
                    rows.push(r);
                    targets.push(std_y.forward_one(j, v));
                }
            }
            if rows.is_empty() {
                weights.push(vec![0.0; in_dim + 1]);
                continue;
            }
            let m = Matrix::from_rows(&rows);
            let w = least_squares(&m, &targets, 1e-8)
                .ok_or_else(|| anyhow::anyhow!("singular normal equations"))?;
            weights.push(w);
        }
        Ok(LinModel { std_x, std_y, weights })
    }

    /// Predict denormalised outputs (ms) for raw feature rows.
    pub fn predict_raw(&self, xs: &[Vec<f64>]) -> Vec<Vec<f64>> {
        xs.iter()
            .map(|x| {
                let xn = self.std_x.forward(x);
                self.weights
                    .iter()
                    .enumerate()
                    .map(|(j, w)| {
                        let mut t = w[w.len() - 1];
                        for (xi, wi) in xn.iter().zip(w) {
                            t += xi * wi;
                        }
                        self.std_y.inverse_one(j, t)
                    })
                    .collect()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Lin must fit a pure power law exactly: t = k^2 * c / im is linear in
    /// log space.
    #[test]
    fn fits_power_laws_exactly() {
        let mut xs = Vec::new();
        let mut ys: Vec<Vec<Option<f64>>> = Vec::new();
        for k in [1.0f64, 2.0, 4.0, 8.0] {
            for c in [1.0f64, 3.0, 9.0] {
                for im in [2.0f64, 4.0] {
                    xs.push(vec![k, c, im]);
                    ys.push(vec![Some(k * k * c / im)]);
                }
            }
        }
        let sx = Standardizer::fit(&xs, true);
        let sy = Standardizer::fit_masked(&ys, true);
        let m = LinModel::fit(&xs, &ys, sx, sy).unwrap();
        let preds = m.predict_raw(&xs);
        for (p, y) in preds.iter().zip(&ys) {
            let actual = y[0].unwrap();
            assert!((p[0] - actual).abs() / actual < 1e-6, "{} vs {actual}", p[0]);
        }
    }

    /// ... and must fail to fit a non-multiplicative law (the paper's
    /// motivation for neural models): cache-knee-style piecewise behaviour.
    #[test]
    fn cannot_fit_piecewise_behaviour() {
        let mut xs = Vec::new();
        let mut ys: Vec<Vec<Option<f64>>> = Vec::new();
        for i in 1..=40 {
            let k = i as f64;
            xs.push(vec![k]);
            // knee at k = 20: slope changes 10x
            let t = if k <= 20.0 { k } else { 20.0 + (k - 20.0) * 10.0 };
            ys.push(vec![Some(t)]);
        }
        let sx = Standardizer::fit(&xs, true);
        let sy = Standardizer::fit_masked(&ys, true);
        let m = LinModel::fit(&xs, &ys, sx, sy).unwrap();
        let preds = m.predict_raw(&xs);
        let pairs: Vec<(f64, f64)> = preds
            .iter()
            .zip(&ys)
            .map(|(p, y)| (p[0], y[0].unwrap()))
            .collect();
        let err = super::super::metrics::mdrae(&pairs);
        assert!(err > 0.05, "linear model should struggle: MdRAE {err}");
    }

    #[test]
    fn masked_columns_do_not_break_fit() {
        let xs = vec![vec![1.0], vec![2.0], vec![4.0]];
        let ys = vec![
            vec![Some(2.0), None],
            vec![Some(4.0), None],
            vec![Some(8.0), Some(1.0)],
        ];
        let sx = Standardizer::fit(&xs, true);
        let sy = Standardizer::fit_masked(&ys, true);
        let m = LinModel::fit(&xs, &ys, sx, sy).unwrap();
        let p = m.predict_raw(&xs);
        assert!((p[0][0] - 2.0).abs() < 1e-6);
        assert!(p[0][1].is_finite());
    }
}

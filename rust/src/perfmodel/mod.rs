//! The paper's performance models on the rust side: training loops that
//! drive the AOT `train_step`/`train_epoch` artifacts over PJRT, batched
//! predictors over the `predict` artifacts, the linear-regression
//! baseline, evaluation metrics (MdRAE), transfer learning (factor
//! correction + fine-tuning) — and the runtime-agnostic [`model`] layer
//! ([`CostModel`]) that presents any of them to the serving stack as one
//! interface.

pub mod lin;
pub mod metrics;
pub mod model;
pub mod params;
pub mod predictor;
pub mod trainer;
pub mod transfer;

pub use lin::LinModel;
pub use metrics::mdrae;
pub use model::{
    CostModel, FactorCorrected, LinCostModel, ModelProvenance, XlaCostModel, XlaModelInputs,
};
pub use params::ParamStore;
pub use predictor::Predictor;
pub use trainer::{TrainOpts, TrainResult, Trainer};

/// Hyper-parameters (paper Table 3).
#[derive(Debug, Clone, Copy)]
pub struct HParams {
    pub lr: f64,
    pub weight_decay: f64,
    pub batch: usize,
    /// Early stopping: halt when validation loss hasn't improved for this
    /// many epochs.
    pub patience: usize,
    pub max_epochs: usize,
}

/// Table 3 values for a model kind ("nn1", "nn2", "dlt_nn1", "dlt_nn2").
pub fn hparams_for(kind: &str) -> HParams {
    match kind {
        "nn1" | "dlt_nn1" => HParams {
            lr: 0.003,
            weight_decay: 0.0,
            batch: 1024,
            patience: 12,
            max_epochs: 300,
        },
        "nn2" | "dlt_nn2" => HParams {
            lr: 0.001,
            weight_decay: 1e-5,
            batch: 1024,
            patience: 12,
            max_epochs: 300,
        },
        _ => panic!("unknown model kind {kind}"),
    }
}

/// Fine-tuning lowers the learning rate by 10x (paper Table 3 caption).
pub fn finetune_hparams(kind: &str) -> HParams {
    let mut h = hparams_for(kind);
    h.lr /= 10.0;
    h.max_epochs = 150;
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_values() {
        assert_eq!(hparams_for("nn1").lr, 0.003);
        assert_eq!(hparams_for("nn2").lr, 0.001);
        assert_eq!(hparams_for("nn2").weight_decay, 1e-5);
        assert_eq!(hparams_for("nn1").weight_decay, 0.0);
        assert_eq!(hparams_for("nn2").batch, 1024);
    }

    #[test]
    fn finetune_lowers_lr_10x() {
        assert!((finetune_hparams("nn2").lr - 0.0001).abs() < 1e-12);
    }
}

//! The training loop: rust drives the AOT `train_step` / `train_epoch`
//! artifacts over PJRT. Early stopping monitors validation loss (paper
//! §4.2); the learning rate and weight decay are runtime scalars, so the
//! same artifacts serve both from-scratch training and fine-tuning.

use super::params::ParamStore;
use super::HParams;
use crate::dataset::Batches;
use crate::runtime::{literal_f32, scalar_f32, to_f32_vec, ModelSpec, Runtime};
use anyhow::{ensure, Result};

/// Training options.
#[derive(Debug, Clone, Copy)]
pub struct TrainOpts {
    pub hp: HParams,
    /// Log every n epochs (0 = silent).
    pub verbose_every: usize,
}

/// Outcome of a training run.
#[derive(Debug, Clone)]
pub struct TrainResult {
    pub params: ParamStore,
    pub epochs_run: usize,
    pub final_train_loss: f64,
    pub best_val_loss: f64,
    /// (epoch, train_loss, val_loss) log.
    pub history: Vec<(usize, f64, f64)>,
}

/// Drives one model kind's artifacts.
pub struct Trainer<'rt> {
    rt: &'rt Runtime,
    spec: ModelSpec,
}

impl<'rt> Trainer<'rt> {
    pub fn new(rt: &'rt Runtime, kind: &str) -> Result<Self> {
        let spec = rt
            .manifest
            .models
            .get(kind)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("unknown model kind {kind}"))?;
        Ok(Self { rt, spec })
    }

    pub fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    /// Fresh parameters from the `init` artifact.
    pub fn init(&self, seed: i32) -> Result<ParamStore> {
        super::params::init_params(self.rt, &self.spec, seed)
    }

    /// Train from `start` params on `train` batches with early stopping on
    /// `val` loss. Uses the scanned `train_epoch` artifact when the batch
    /// count matches its baked size, per-batch `train_step` otherwise.
    pub fn train(
        &self,
        start: ParamStore,
        train: &Batches,
        val: &Batches,
        opts: TrainOpts,
    ) -> Result<TrainResult> {
        ensure!(train.in_dim == self.spec.in_dim, "in_dim mismatch");
        ensure!(train.out_dim == self.spec.out_dim, "out_dim mismatch");
        ensure!(train.batch == self.spec.train_batch, "batch mismatch");

        let mut state = TrainState::fresh(&self.spec, start);
        let mut best_val = f64::INFINITY;
        let mut best_params = state.params.clone();
        let mut since_best = 0usize;
        let mut history = Vec::new();
        let mut last_train_loss = f64::NAN;
        let mut epochs_run = 0;

        let use_epoch_artifact = train.n_batches == self.spec.epoch_batches
            && self.spec.files.contains_key("train_epoch");

        for epoch in 0..opts.hp.max_epochs {
            last_train_loss = if use_epoch_artifact {
                self.run_epoch_scanned(&mut state, train, &opts.hp)?
            } else {
                self.run_epoch_stepped(&mut state, train, &opts.hp)?
            };
            let val_loss = self.eval_loss(&state.params, val)?;
            history.push((epoch, last_train_loss, val_loss));
            epochs_run = epoch + 1;
            if opts.verbose_every > 0 && epoch % opts.verbose_every == 0 {
                eprintln!("epoch {epoch}: train {last_train_loss:.5} val {val_loss:.5}");
            }
            if val_loss < best_val - 1e-6 {
                best_val = val_loss;
                best_params = state.params.clone();
                since_best = 0;
            } else {
                since_best += 1;
                if since_best >= opts.hp.patience {
                    break;
                }
            }
        }

        Ok(TrainResult {
            params: best_params,
            epochs_run,
            final_train_loss: last_train_loss,
            best_val_loss: best_val,
            history,
        })
    }

    fn run_epoch_stepped(
        &self,
        state: &mut TrainState,
        b: &Batches,
        hp: &HParams,
    ) -> Result<f64> {
        let exe = self.rt.load(&self.spec.files["train_step"])?;
        let (bsz, idim, odim) = (b.batch, b.in_dim, b.out_dim);
        let mut loss_sum = 0.0;
        for bi in 0..b.n_batches {
            let mut inputs = Vec::with_capacity(3 * state.params.tensors.len() + 6);
            state.params.push_literals(&mut inputs)?;
            state.m.push_literals(&mut inputs)?;
            state.v.push_literals(&mut inputs)?;
            inputs.push(scalar_f32(state.t));
            let xr = &b.x[bi * bsz * idim..(bi + 1) * bsz * idim];
            let yr = &b.y[bi * bsz * odim..(bi + 1) * bsz * odim];
            let mr = &b.mask[bi * bsz * odim..(bi + 1) * bsz * odim];
            inputs.push(literal_f32(xr, &[bsz as i64, idim as i64])?);
            inputs.push(literal_f32(yr, &[bsz as i64, odim as i64])?);
            inputs.push(literal_f32(mr, &[bsz as i64, odim as i64])?);
            inputs.push(scalar_f32(hp.lr as f32));
            inputs.push(scalar_f32(hp.weight_decay as f32));
            let out = self.rt.execute(&exe, &inputs)?;
            loss_sum += state.absorb(&self.spec, &out)?;
        }
        Ok(loss_sum / b.n_batches as f64)
    }

    fn run_epoch_scanned(
        &self,
        state: &mut TrainState,
        b: &Batches,
        hp: &HParams,
    ) -> Result<f64> {
        let exe = self.rt.load(&self.spec.files["train_epoch"])?;
        let (nb, bsz, idim, odim) = (b.n_batches, b.batch, b.in_dim, b.out_dim);
        let mut inputs = Vec::with_capacity(3 * state.params.tensors.len() + 6);
        state.params.push_literals(&mut inputs)?;
        state.m.push_literals(&mut inputs)?;
        state.v.push_literals(&mut inputs)?;
        inputs.push(scalar_f32(state.t));
        inputs.push(literal_f32(&b.x, &[nb as i64, bsz as i64, idim as i64])?);
        inputs.push(literal_f32(&b.y, &[nb as i64, bsz as i64, odim as i64])?);
        inputs.push(literal_f32(&b.mask, &[nb as i64, bsz as i64, odim as i64])?);
        inputs.push(scalar_f32(hp.lr as f32));
        inputs.push(scalar_f32(hp.weight_decay as f32));
        let out = self.rt.execute(&exe, &inputs)?;
        state.absorb(&self.spec, &out)
    }

    /// Masked-MSE loss of `params` on batches (via the predict artifact).
    pub fn eval_loss(&self, params: &ParamStore, b: &Batches) -> Result<f64> {
        let preds = self.predict_normalised(params, b)?;
        let mut se = 0.0;
        let mut n = 0.0;
        for i in 0..preds.len() {
            if b.mask[i] > 0.0 {
                let d = preds[i] as f64 - b.y[i] as f64;
                se += d * d;
                n += 1.0;
            }
        }
        Ok(if n > 0.0 { se / n } else { 0.0 })
    }

    /// Raw (normalised-space) predictions for all rows in `b`.
    ///
    /// The per-chunk state is staged once and reused: the parameter
    /// literals are built a single time (not re-converted per chunk),
    /// and each distinct predict batch size gets one padded input
    /// buffer that rows are written into in place — no per-chunk
    /// allocation on the batched-predict hot path.
    pub fn predict_normalised(&self, params: &ParamStore, b: &Batches) -> Result<Vec<f32>> {
        let (b_small, b_large) = self.rt.manifest.predict_batches;
        let total = b.n_batches * b.batch;
        let mut out = vec![0.0f32; total * b.out_dim];

        // params are chunk-invariant: convert to literals exactly once
        // and truncate the tail back between executions
        let mut inputs = Vec::new();
        params.push_literals(&mut inputs)?;
        let n_param_inputs = inputs.len();
        // (batch size, executable, reusable padded input buffer) — at
        // most two entries (the small and large predict artifacts)
        let mut staged: Vec<(usize, std::rc::Rc<xla::PjRtLoadedExecutable>, Vec<f32>)> =
            Vec::with_capacity(2);

        let mut row = 0usize;
        while row < total {
            let remaining = total - row;
            let bsz = if remaining >= b_large { b_large } else { b_small };
            let si = match staged.iter().position(|(s, _, _)| *s == bsz) {
                Some(i) => i,
                None => {
                    let exe = self.rt.load(&self.spec.files[&format!("predict_b{bsz}")])?;
                    staged.push((bsz, exe, vec![0.0f32; bsz * b.in_dim]));
                    staged.len() - 1
                }
            };
            let n_rows = bsz.min(remaining);
            let x = &mut staged[si].2;
            x[..n_rows * b.in_dim]
                .copy_from_slice(&b.x[row * b.in_dim..(row + n_rows) * b.in_dim]);
            if n_rows < bsz {
                // only the final short chunk pads; keep the padding
                // deterministic rather than leaking earlier rows
                x[n_rows * b.in_dim..].fill(0.0);
            }
            inputs.truncate(n_param_inputs);
            inputs.push(literal_f32(&staged[si].2, &[bsz as i64, b.in_dim as i64])?);
            let res = self.rt.execute(&staged[si].1, &inputs)?;
            let y = to_f32_vec(&res[0])?;
            out[row * b.out_dim..(row + n_rows) * b.out_dim]
                .copy_from_slice(&y[..n_rows * b.out_dim]);
            row += n_rows;
        }
        Ok(out)
    }
}

/// Mutable Adam state across steps.
struct TrainState {
    params: ParamStore,
    m: ParamStore,
    v: ParamStore,
    t: f32,
}

impl TrainState {
    fn fresh(spec: &ModelSpec, params: ParamStore) -> Self {
        Self {
            params,
            m: ParamStore::zeros_like(spec),
            v: ParamStore::zeros_like(spec),
            t: 0.0,
        }
    }

    /// Consume a train_step/train_epoch output tuple; returns the loss.
    fn absorb(&mut self, spec: &ModelSpec, out: &[xla::Literal]) -> Result<f64> {
        let np = spec.param_shapes.len();
        ensure!(out.len() == 3 * np + 2, "unexpected output arity {}", out.len());
        self.params = ParamStore::from_literals(spec, &out[..np])?;
        self.m = ParamStore::from_literals(spec, &out[np..2 * np])?;
        self.v = ParamStore::from_literals(spec, &out[2 * np..3 * np])?;
        self.t = to_f32_vec(&out[3 * np])?[0];
        Ok(to_f32_vec(&out[3 * np + 1])?[0] as f64)
    }
}

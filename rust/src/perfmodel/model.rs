//! The runtime-agnostic model layer: one trait, [`CostModel`], between
//! "something that can predict primitive/DLT costs" and everything that
//! consumes predictions (dense [`TableSource`] baking, the lazy
//! [`ModeledSource`](crate::selection::ModeledSource) serving source, the
//! [`Coordinator`](crate::coordinator)'s platform onboarding, and the
//! experiment suite).
//!
//! Three implementations ship in-tree:
//! * [`LinCostModel`] — the paper's Lin baseline bundled as a full cost
//!   model (primitive rows + 3x3 DLT matrices). Pure Rust, trains offline
//!   in closed form, no PJRT — the model the serving layer can always
//!   fall back to.
//! * [`XlaCostModel`] — the NN1/NN2 [`Predictor`]/[`DltPredictor`] pair
//!   driving the AOT artifacts over PJRT, when the runtime is available.
//! * [`FactorCorrected`] — §4.4 transfer: any base model wrapped with
//!   per-column multiplicative factors estimated from a small target
//!   calibration set.
//!
//! Raw model output is *dense* (a number for every primitive / every DLT
//! cell, physical or not); [`masked_row`] / [`clamp_dlt`] apply the
//! catalog applicability mask and the positive floor exactly once, at the
//! boundary where predictions become [`CostSource`](crate::selection::CostSource)
//! answers.

use crate::dataset::{DltDataset, PrimDataset, Standardizer};
use crate::layers::ConvConfig;
use crate::networks::Network;
use crate::primitives::{catalog, Layout};
use crate::runtime::Runtime;
use crate::selection::TableSource;
use anyhow::Result;
use std::sync::Arc;

use super::lin::LinModel;
use super::params::ParamStore;
use super::predictor::{DltPredictor, Predictor};
use super::transfer::{robust_factors, MIN_CALIB_RATIOS};

/// Positive floor applied to served predictions (ms). Log-space inverses
/// are positive by construction, but factor correction and future model
/// kinds are not; PBQP edge/node costs must never go non-positive.
pub const COST_FLOOR_MS: f64 = 1e-9;

/// Where a model's knowledge came from — reported through
/// [`SelectionReport`](crate::coordinator::SelectionReport) provenance so
/// a tenant can tell a natively-trained platform from a few-sample
/// transfer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ModelProvenance {
    /// Trained on `samples` profiled rows of `platform` itself.
    Native { platform: String, samples: usize },
    /// Adapted from a `source` platform's model using `calib_samples`
    /// target calibration rows (paper §4.4).
    Transferred { source: String, calib_samples: usize },
}

impl ModelProvenance {
    /// The platform the underlying knowledge was measured on.
    pub fn origin(&self) -> &str {
        match self {
            ModelProvenance::Native { platform, .. } => platform,
            ModelProvenance::Transferred { source, .. } => source,
        }
    }

    /// Human-readable one-liner for reports.
    pub fn describe(&self) -> String {
        match self {
            ModelProvenance::Native { platform, samples } => {
                format!("native({platform}, {samples} samples)")
            }
            ModelProvenance::Transferred { source, calib_samples } => {
                format!("transfer({source}, {calib_samples} calib samples)")
            }
        }
    }
}

/// A trained performance model serving both cost surfaces of the paper's
/// pipeline: per-primitive layer cost rows and 3x3 DLT matrices.
///
/// Predictions are **raw and dense** — one number per catalog primitive
/// (resp. per DLT cell) regardless of applicability, possibly
/// non-physical. Consumers apply [`masked_row`] / [`clamp_dlt`] (or go
/// through [`model_table`] / [`ModeledSource`](crate::selection::ModeledSource),
/// which do it for them).
///
/// ```
/// use primsel::layers::ConvConfig;
/// use primsel::perfmodel::model::{masked_row, CostModel, ModelProvenance, COST_FLOOR_MS};
/// use primsel::primitives::catalog;
///
/// /// A toy model: every primitive costs `macs / 1e6` ms.
/// struct MacsModel(ModelProvenance);
///
/// impl CostModel for MacsModel {
///     fn kind(&self) -> &str { "macs" }
///     fn provenance(&self) -> &ModelProvenance { &self.0 }
///     fn predict_prim(&self, cfgs: &[ConvConfig]) -> primsel::Result<Vec<Vec<f64>>> {
///         Ok(cfgs.iter().map(|c| vec![c.macs() / 1e6; catalog().len()]).collect())
///     }
///     fn predict_dlt(&self, pairs: &[(u32, u32)]) -> primsel::Result<Vec<[[f64; 3]; 3]>> {
///         Ok(pairs.iter().map(|&(c, im)| [[(c * im) as f64 * 1e-6; 3]; 3]).collect())
///     }
/// }
///
/// let m = MacsModel(ModelProvenance::Native { platform: "toy".into(), samples: 0 });
/// let cfg = ConvConfig::new(64, 64, 56, 2, 3); // stride 2: winograd/kn2 inapplicable
/// let raw = m.predict_prim(std::slice::from_ref(&cfg)).unwrap();
/// let row = masked_row(&cfg, &raw[0], COST_FLOOR_MS);
/// // dense raw output, masked served row
/// assert_eq!(raw[0].len(), catalog().len());
/// assert!(row.iter().zip(catalog()).all(|(t, p)| t.is_some() == p.applicable(&cfg)));
/// assert_eq!(m.provenance().origin(), "toy");
/// ```
pub trait CostModel {
    /// Short model-kind tag ("lin", "nn2", "lin+factor", ...).
    fn kind(&self) -> &str;

    /// Where the model's knowledge came from.
    fn provenance(&self) -> &ModelProvenance;

    /// Raw per-primitive cost predictions (ms) for layer configs: one
    /// dense row of `catalog().len()` values per config.
    fn predict_prim(&self, cfgs: &[ConvConfig]) -> Result<Vec<Vec<f64>>>;

    /// Raw 3x3 DLT matrices (ms) for `(c, im)` tensors. Diagonal entries
    /// are meaningless (identity transforms are free) and ignored by
    /// consumers.
    fn predict_dlt(&self, pairs: &[(u32, u32)]) -> Result<Vec<[[f64; 3]; 3]>>;
}

/// Turn one dense raw prediction row into a served cost row: inapplicable
/// primitives masked to `None` via the catalog, the rest clamped to
/// `floor_ms`.
pub fn masked_row(cfg: &ConvConfig, raw: &[f64], floor_ms: f64) -> Vec<Option<f64>> {
    catalog()
        .iter()
        .zip(raw)
        .map(|(p, &v)| if p.applicable(cfg) { Some(v.max(floor_ms)) } else { None })
        .collect()
}

/// Clamp a raw DLT matrix into served form: zero diagonal, off-diagonal
/// entries floored at `floor_ms`.
pub fn clamp_dlt(raw: [[f64; 3]; 3], floor_ms: f64) -> [[f64; 3]; 3] {
    let mut m = [[0.0; 3]; 3];
    for src in Layout::ALL {
        for dst in Layout::ALL {
            if src != dst {
                m[src.index()][dst.index()] = raw[src.index()][dst.index()].max(floor_ms);
            }
        }
    }
    m
}

/// Bake a dense [`TableSource`] for one network from a model: one batched
/// primitive prediction for all layers, one batched DLT prediction for
/// all distinct edge tensors (step ii of the paper's Figure 2). The
/// table is masked and clamped, ready to serve or persist.
pub fn model_table(net: &Network, model: &dyn CostModel) -> Result<TableSource> {
    let raw = model.predict_prim(&net.layers)?;
    let rows = net
        .layers
        .iter()
        .zip(&raw)
        .map(|(cfg, r)| masked_row(cfg, r, COST_FLOOR_MS))
        .collect();
    let mut keys: Vec<(u32, u32)> = net
        .edges
        .iter()
        .map(|&(u, v)| (net.layers[u].k, net.layers[v].im))
        .collect();
    keys.sort_unstable();
    keys.dedup();
    let mats = model
        .predict_dlt(&keys)?
        .into_iter()
        .map(|m| clamp_dlt(m, COST_FLOOR_MS))
        .collect();
    Ok(TableSource::new(net.layers.clone(), rows, keys, mats))
}

/// Extended Lin feature map for a layer config: the raw `(k, c, im, s, f)`
/// plus the output spatial size `o = (im - f) / s + 1`. In log space every
/// product of powers of existing features is linearly dependent, so `o`
/// is the one derived feature that adds expressiveness — and it carries
/// the gemm shapes (`o²` columns) every lowering family is built on.
pub fn lin_prim_features(cfg: &ConvConfig) -> Vec<f64> {
    let o = cfg.out_size().unwrap_or(1).max(1) as f64;
    vec![cfg.k as f64, cfg.c as f64, cfg.im as f64, cfg.s as f64, cfg.f as f64, o]
}

/// The paper's Lin baseline bundled as a full [`CostModel`]: one
/// per-column log-space OLS for primitive rows (over
/// [`lin_prim_features`]) and one for the 9 DLT cells (over `(c, im)`).
/// Fits in closed form on the host — no PJRT, no artifacts — which makes
/// it the model the serving layer can always train from a calibration
/// sample, offline.
#[derive(Debug, Clone)]
pub struct LinCostModel {
    prim: LinModel,
    dlt: LinModel,
    provenance: ModelProvenance,
}

impl LinCostModel {
    /// Fit both Lin models on profiled datasets from `platform`.
    pub fn fit(prim: &PrimDataset, dlt: &DltDataset, platform: &str) -> Result<LinCostModel> {
        let xs: Vec<Vec<f64>> = prim.configs.iter().map(lin_prim_features).collect();
        let sx = Standardizer::fit(&xs, true);
        let sy = Standardizer::fit_masked(&prim.targets, true);
        let prim_lin = LinModel::fit(&xs, &prim.targets, sx, sy)?;

        let dxs: Vec<Vec<f64>> = dlt.features().iter().map(|f| f.to_vec()).collect();
        let dys = dlt.flat_targets();
        let dsx = Standardizer::fit(&dxs, true);
        let dsy = Standardizer::fit_masked(&dys, true);
        let dlt_lin = LinModel::fit(&dxs, &dys, dsx, dsy)?;

        Ok(LinCostModel {
            prim: prim_lin,
            dlt: dlt_lin,
            provenance: ModelProvenance::Native {
                platform: platform.to_string(),
                samples: prim.len(),
            },
        })
    }

    /// The underlying primitive-row Lin model.
    pub fn prim_lin(&self) -> &LinModel {
        &self.prim
    }
}

impl CostModel for LinCostModel {
    fn kind(&self) -> &str {
        "lin"
    }

    fn provenance(&self) -> &ModelProvenance {
        &self.provenance
    }

    fn predict_prim(&self, cfgs: &[ConvConfig]) -> Result<Vec<Vec<f64>>> {
        let xs: Vec<Vec<f64>> = cfgs.iter().map(lin_prim_features).collect();
        Ok(self.prim.predict_raw(&xs))
    }

    fn predict_dlt(&self, pairs: &[(u32, u32)]) -> Result<Vec<[[f64; 3]; 3]>> {
        let xs: Vec<Vec<f64>> =
            pairs.iter().map(|&(c, im)| vec![c as f64, im as f64]).collect();
        Ok(self.dlt.predict_raw(&xs).into_iter().map(matrix_from_flat9).collect())
    }
}

/// Everything needed to assemble an [`XlaCostModel`] except the runtime
/// borrow — the shape the [`Workbench`](crate::experiments::Workbench)
/// hands out so its `&mut self` training phase and the model's `&Runtime`
/// inference phase don't fight over borrows.
pub struct XlaModelInputs {
    pub prim_kind: String,
    pub prim_params: ParamStore,
    pub std_x: Standardizer,
    pub std_y: Standardizer,
    pub dlt_kind: String,
    pub dlt_params: ParamStore,
    pub dlt_std_x: Standardizer,
    pub dlt_std_y: Standardizer,
    pub provenance: ModelProvenance,
}

impl XlaModelInputs {
    /// Compile the predictors against a runtime and return the model.
    pub fn build(self, rt: &Runtime) -> Result<XlaCostModel<'_>> {
        let prim =
            Predictor::new(rt, &self.prim_kind, self.prim_params, self.std_x, self.std_y)?;
        let dlt = DltPredictor::new(
            rt,
            &self.dlt_kind,
            self.dlt_params,
            self.dlt_std_x,
            self.dlt_std_y,
        )?;
        Ok(XlaCostModel { kind: self.prim_kind, prim, dlt, provenance: self.provenance })
    }
}

/// The NN1/NN2 predictors (AOT artifacts over PJRT) as a [`CostModel`].
/// Only constructible when a runtime is open; the rest of the serving
/// stack neither knows nor cares which implementation answers.
pub struct XlaCostModel<'rt> {
    kind: String,
    prim: Predictor<'rt>,
    dlt: DltPredictor<'rt>,
    provenance: ModelProvenance,
}

impl XlaCostModel<'_> {
    /// Apply §4.4 per-primitive correction factors (builder style),
    /// marking the provenance as transferred from its current origin.
    pub fn with_prim_factors(mut self, factors: Vec<f64>, calib_samples: usize) -> Self {
        self.prim.factors = factors;
        self.provenance = ModelProvenance::Transferred {
            source: self.provenance.origin().to_string(),
            calib_samples,
        };
        self
    }
}

impl CostModel for XlaCostModel<'_> {
    fn kind(&self) -> &str {
        &self.kind
    }

    fn provenance(&self) -> &ModelProvenance {
        &self.provenance
    }

    fn predict_prim(&self, cfgs: &[ConvConfig]) -> Result<Vec<Vec<f64>>> {
        let xs: Vec<Vec<f64>> = cfgs.iter().map(|c| c.features().to_vec()).collect();
        self.prim.predict_raw(&xs)
    }

    fn predict_dlt(&self, pairs: &[(u32, u32)]) -> Result<Vec<[[f64; 3]; 3]>> {
        self.dlt.predict_pairs(pairs)
    }
}

/// §4.4 factor correction as a model combinator: a base model (any
/// [`CostModel`] that is `Send + Sync`) scaled per primitive column and
/// per DLT cell by median measured/predicted ratios from a target
/// calibration set.
pub struct FactorCorrected {
    kind: String,
    base: Arc<dyn CostModel + Send + Sync>,
    prim_factors: Vec<f64>,
    /// Row-major src x dst; diagonal 1.0 (unused).
    dlt_factors: [[f64; 3]; 3],
    provenance: ModelProvenance,
}

impl FactorCorrected {
    /// Estimate factors from a calibration sample measured on the target
    /// platform (see [`robust_factors`] for the estimator's guards).
    pub fn fit(
        base: Arc<dyn CostModel + Send + Sync>,
        prim: &PrimDataset,
        dlt: &DltDataset,
    ) -> Result<FactorCorrected> {
        let prim_factors =
            robust_factors(&base.predict_prim(&prim.configs)?, &prim.targets, MIN_CALIB_RATIOS);

        let dlt_preds: Vec<Vec<f64>> = base
            .predict_dlt(&dlt.pairs)?
            .into_iter()
            .map(|m| m.iter().flatten().copied().collect())
            .collect();
        let flat = robust_factors(&dlt_preds, &dlt.flat_targets(), MIN_CALIB_RATIOS);
        // an empty DLT calibration set yields an empty factor vector
        // (robust_factors sizes off the measured rows): keep 1.0 rather
        // than indexing out of bounds
        let mut dlt_factors = [[1.0; 3]; 3];
        if flat.len() == 9 {
            for src in Layout::ALL {
                for dst in Layout::ALL {
                    if src != dst {
                        dlt_factors[src.index()][dst.index()] =
                            flat[src.index() * 3 + dst.index()];
                    }
                }
            }
        }

        let provenance = ModelProvenance::Transferred {
            source: base.provenance().origin().to_string(),
            calib_samples: prim.len(),
        };
        let kind = format!("{}+factor", base.kind());
        Ok(FactorCorrected { kind, base, prim_factors, dlt_factors, provenance })
    }

    /// The per-primitive correction factors.
    pub fn prim_factors(&self) -> &[f64] {
        &self.prim_factors
    }

    /// The per-DLT-cell correction factors (row-major src x dst;
    /// diagonal fixed at 1.0, unused).
    pub fn dlt_factors(&self) -> &[[f64; 3]; 3] {
        &self.dlt_factors
    }
}

impl CostModel for FactorCorrected {
    fn kind(&self) -> &str {
        &self.kind
    }

    fn provenance(&self) -> &ModelProvenance {
        &self.provenance
    }

    fn predict_prim(&self, cfgs: &[ConvConfig]) -> Result<Vec<Vec<f64>>> {
        let mut rows = self.base.predict_prim(cfgs)?;
        for row in &mut rows {
            for (v, f) in row.iter_mut().zip(&self.prim_factors) {
                *v *= f;
            }
        }
        Ok(rows)
    }

    fn predict_dlt(&self, pairs: &[(u32, u32)]) -> Result<Vec<[[f64; 3]; 3]>> {
        let mut mats = self.base.predict_dlt(pairs)?;
        for m in &mut mats {
            for src in Layout::ALL {
                for dst in Layout::ALL {
                    m[src.index()][dst.index()] *= self.dlt_factors[src.index()][dst.index()];
                }
            }
        }
        Ok(mats)
    }
}

/// Assemble a 3x3 matrix from 9 row-major values (diagonal zeroed — the
/// layout of `DltDataset::flat_targets` and the DLT Lin outputs).
fn matrix_from_flat9(row: Vec<f64>) -> [[f64; 3]; 3] {
    let mut m = [[0.0; 3]; 3];
    for src in Layout::ALL {
        for dst in Layout::ALL {
            if src != dst {
                m[src.index()][dst.index()] = row[src.index() * 3 + dst.index()];
            }
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset;
    use crate::networks;
    use crate::perfmodel::metrics::mdrae_all;
    use crate::selection::CostSource;
    use crate::simulator::{machine, Simulator};

    fn lin_for(platform: &str, n_configs: usize, seed: u64) -> (LinCostModel, Simulator) {
        let sim = Simulator::new(machine::by_name(platform).unwrap());
        let configs = dataset::enumerate_configs(n_configs, seed);
        let prim = dataset::profile_prim_dataset(&sim, &configs);
        let pairs = dataset::dlt_pairs(&configs);
        let dlt = dataset::profile_dlt_dataset(&sim, &pairs);
        (LinCostModel::fit(&prim, &dlt, platform).unwrap(), sim)
    }

    #[test]
    fn lin_cost_model_fits_simulator_reasonably() {
        let (model, sim) = lin_for("intel", 600, 11);
        let test = dataset::enumerate_configs(800, 12);
        let test = &test[600..];
        let actual: Vec<Vec<Option<f64>>> =
            test.iter().map(|c| sim.profile_layer(c)).collect();
        let preds = model.predict_prim(test).unwrap();
        let md = mdrae_all(&preds, &actual);
        assert!(md < 0.60, "Lin MdRAE unreasonably high: {md}");
        assert_eq!(model.kind(), "lin");
        assert_eq!(model.provenance().origin(), "intel");
    }

    #[test]
    fn lin_dlt_predictions_track_the_simulator() {
        // DLT cost is a power law in (c, im) *per bandwidth tier*; the
        // tier steps are exactly what a log-space OLS cannot represent,
        // so require order-of-magnitude tracking (factor 4), not
        // precision — selection only needs the relative ranking of
        // layout chains to be roughly right.
        let (model, sim) = lin_for("arm", 400, 3);
        let mats = model.predict_dlt(&[(64, 56), (128, 28)]).unwrap();
        for (m, &(c, im)) in mats.iter().zip(&[(64u32, 56u32), (128, 28)]) {
            let truth = sim.dlt_matrix(c, im);
            for src in Layout::ALL {
                for dst in Layout::ALL {
                    if src == dst {
                        assert_eq!(m[src.index()][dst.index()], 0.0);
                    } else {
                        let (p, a) =
                            (m[src.index()][dst.index()], truth[src.index()][dst.index()]);
                        let ratio = p / a;
                        assert!(
                            p.is_finite() && (0.25..4.0).contains(&ratio),
                            "dlt ({c},{im}) {src:?}->{dst:?}: {p} vs {a}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn masked_row_masks_and_clamps() {
        let cfg = ConvConfig::new(8, 8, 32, 2, 3); // strided: kn2/wino inapplicable
        let raw = vec![-5.0; catalog().len()];
        let row = masked_row(&cfg, &raw, COST_FLOOR_MS);
        for (t, p) in row.iter().zip(catalog()) {
            match t {
                Some(v) => {
                    assert!(p.applicable(&cfg));
                    assert_eq!(*v, COST_FLOOR_MS);
                }
                None => assert!(!p.applicable(&cfg)),
            }
        }
    }

    #[test]
    fn clamp_dlt_zeroes_diagonal_and_floors() {
        let m = clamp_dlt([[-1.0; 3]; 3], COST_FLOOR_MS);
        for src in Layout::ALL {
            for dst in Layout::ALL {
                let v = m[src.index()][dst.index()];
                if src == dst {
                    assert_eq!(v, 0.0);
                } else {
                    assert_eq!(v, COST_FLOOR_MS);
                }
            }
        }
    }

    #[test]
    fn model_table_serves_a_network() {
        let (model, _) = lin_for("intel", 400, 5);
        let net = networks::vgg(11);
        let table = model_table(&net, &model).unwrap();
        for cfg in &net.layers {
            let row = table.layer_costs(cfg);
            for (t, p) in row.iter().zip(catalog()) {
                assert_eq!(t.is_some(), p.applicable(cfg));
                if let Some(v) = t {
                    assert!(*v >= COST_FLOOR_MS && v.is_finite());
                }
            }
        }
        for &(u, v) in &net.edges {
            let (c, im) = (net.layers[u].k, net.layers[v].im);
            let m = table.dlt_matrix3(c, im);
            assert_eq!(m[0][0], 0.0);
            assert!(m[0][2] >= COST_FLOOR_MS);
        }
    }

    #[test]
    fn factor_corrected_recovers_cross_platform_scale() {
        // intel-trained Lin, factor-corrected with arm calibration data,
        // must predict arm costs much better than the uncorrected model
        let (intel_model, _) = lin_for("intel", 800, 21);
        let arm = Simulator::new(machine::arm_cortex_a73());
        let cal_cfgs = dataset::enumerate_configs(900, 22);
        let prim = dataset::profile_prim_dataset(&arm, &cal_cfgs[800..]);
        let pairs = dataset::dlt_pairs(&cal_cfgs[800..]);
        let dlt = dataset::profile_dlt_dataset(&arm, &pairs);
        let base: Arc<dyn CostModel + Send + Sync> = Arc::new(intel_model);
        let corrected = FactorCorrected::fit(Arc::clone(&base), &prim, &dlt).unwrap();
        assert_eq!(corrected.kind(), "lin+factor");
        assert!(matches!(
            corrected.provenance(),
            ModelProvenance::Transferred { calib_samples: 100, .. }
        ));

        let test_cfgs = dataset::enumerate_configs(1000, 23);
        let test_cfgs = &test_cfgs[900..];
        let actual: Vec<Vec<Option<f64>>> =
            test_cfgs.iter().map(|c| arm.profile_layer(c)).collect();
        let md_base = mdrae_all(&base.predict_prim(test_cfgs).unwrap(), &actual);
        let md_corr = mdrae_all(&corrected.predict_prim(test_cfgs).unwrap(), &actual);
        assert!(
            md_corr < md_base * 0.7,
            "correction didn't help: {md_base} -> {md_corr}"
        );
    }
}

//! Transfer learning (paper §4.4): adapting a source-platform performance
//! model to a new target platform.
//!
//! Two mechanisms:
//! 1. **Factor correction** — per-primitive multiplicative scale estimated
//!    from ~1% of target samples (median ratio of measured to predicted).
//!    Works on any [`CostModel`] (Lin or the PJRT predictors); the
//!    model-level entry points are [`prim_factors`] and
//!    [`FactorCorrected::fit`](super::model::FactorCorrected::fit).
//! 2. **Fine-tuning** — continue training the source parameters on a small
//!    fraction of target data at lr/10 (same AOT artifacts; lr is a
//!    runtime scalar).

use super::metrics::median;
use super::model::CostModel;
use crate::dataset::PrimDataset;
use anyhow::Result;

/// Minimum number of calibration ratios a column needs before its median
/// is trusted as a correction factor. Below this the factor stays 1.0 —
/// a 1- or 2-sample "median" is just noise wearing a robe.
pub const MIN_CALIB_RATIOS: usize = 3;

/// Estimate per-column correction factors from predictions and measured
/// targets: `factor_j = median over samples of (measured_j / predicted_j)`.
///
/// Robustness guards (the places a raw ratio estimator goes wrong):
/// * predictions that are non-positive or non-finite are skipped — Lin's
///   log-space inverse can go non-physical on extrapolated inputs, and a
///   ratio against such a prediction is meaningless;
/// * columns with fewer than `min_ratios` usable ratios keep factor 1.0
///   instead of trusting a 1-sample "median".
pub fn robust_factors(
    preds: &[Vec<f64>],
    measured: &[Vec<Option<f64>>],
    min_ratios: usize,
) -> Vec<f64> {
    let out_dim = measured.first().map_or(0, |r| r.len());
    let mut factors = vec![1.0; out_dim];
    for (j, factor) in factors.iter_mut().enumerate() {
        let ratios: Vec<f64> = preds
            .iter()
            .zip(measured)
            .filter_map(|(p, m)| {
                let pv = p[j];
                if pv.is_finite() && pv > 0.0 {
                    m[j].map(|mv| mv / pv)
                } else {
                    None
                }
            })
            .collect();
        if ratios.len() >= min_ratios {
            *factor = median(&ratios);
        }
    }
    factors
}

/// Per-primitive factors for a [`CostModel`] from a calibration subset of
/// a target platform's primitive dataset — the entry point every factor
/// flow (experiments, onboarding, examples) goes through.
pub fn prim_factors(model: &dyn CostModel, calib: &PrimDataset) -> Result<Vec<f64>> {
    Ok(robust_factors(&model.predict_prim(&calib.configs)?, &calib.targets, MIN_CALIB_RATIOS))
}

/// Drift statistic over a window of (predicted, measured) rows: the worst
/// per-column absolute log of the robust factor — `max_j |ln f_j|` with
/// `f_j` from [`robust_factors`].
///
/// This is the same §4.4 machinery that *fits* corrections, re-read as a
/// detector: if the serving model still matched the platform, every
/// factor would sit near 1.0 and the score near 0.0; a column whose
/// median measured/predicted ratio has moved to `r` scores `|ln r|`
/// regardless of direction. Columns without enough usable ratios keep
/// factor 1.0 and so cannot raise the score. Returns 0.0 for an empty
/// window.
pub fn drift_score(preds: &[Vec<f64>], measured: &[Vec<Option<f64>>], min_ratios: usize) -> f64 {
    robust_factors(preds, measured, min_ratios)
        .into_iter()
        .filter(|f| f.is_finite() && *f > 0.0)
        .map(|f| f.ln().abs())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_ratio_recovers_scale() {
        let preds = vec![vec![1.0], vec![2.0], vec![4.0], vec![8.0]];
        let measured: Vec<Vec<Option<f64>>> =
            vec![vec![Some(2.1)], vec![Some(4.0)], vec![Some(7.9)], vec![Some(16.0)]];
        let f = robust_factors(&preds, &measured, MIN_CALIB_RATIOS);
        assert!((f[0] - 2.0).abs() < 0.05, "{}", f[0]);
    }

    #[test]
    fn non_physical_predictions_are_skipped() {
        // a zero/negative prediction must not poison the median with a
        // huge or negative ratio
        let preds = vec![vec![-1.0], vec![0.0], vec![2.0], vec![2.0], vec![2.0]];
        let measured: Vec<Vec<Option<f64>>> =
            vec![vec![Some(5.0)]; 5];
        let f = robust_factors(&preds, &measured, MIN_CALIB_RATIOS);
        assert!((f[0] - 2.5).abs() < 1e-12, "{}", f[0]);
    }

    #[test]
    fn sparse_columns_keep_identity_factor() {
        // two usable ratios are below MIN_CALIB_RATIOS: stay at 1.0
        let preds = vec![vec![1.0, 1.0]; 4];
        let measured: Vec<Vec<Option<f64>>> = vec![
            vec![Some(3.0), Some(7.0)],
            vec![Some(3.0), Some(7.0)],
            vec![Some(3.0), None],
            vec![None, None],
        ];
        let f = robust_factors(&preds, &measured, 3);
        assert_eq!(f, vec![3.0, 1.0]);
    }

    #[test]
    fn drift_score_is_zero_on_agreement_and_symmetric_in_direction() {
        let preds = vec![vec![2.0, 4.0]; 4];
        let agree: Vec<Vec<Option<f64>>> = vec![vec![Some(2.0), Some(4.0)]; 4];
        assert!(drift_score(&preds, &agree, MIN_CALIB_RATIOS).abs() < 1e-12);

        // 3x slowdown in column 0, 3x speedup in column 1: both score ln 3
        let slow: Vec<Vec<Option<f64>>> = vec![vec![Some(6.0), Some(4.0)]; 4];
        let fast: Vec<Vec<Option<f64>>> = vec![vec![Some(2.0), Some(4.0 / 3.0)]; 4];
        let s = drift_score(&preds, &slow, MIN_CALIB_RATIOS);
        let f = drift_score(&preds, &fast, MIN_CALIB_RATIOS);
        assert!((s - 3f64.ln()).abs() < 1e-9, "{s}");
        assert!((f - 3f64.ln()).abs() < 1e-9, "{f}");
    }

    #[test]
    fn drift_score_empty_window_is_zero() {
        assert_eq!(drift_score(&[], &[], MIN_CALIB_RATIOS), 0.0);
    }
}

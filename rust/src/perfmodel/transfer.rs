//! Transfer learning (paper §4.4): adapting a source-platform performance
//! model to a new target platform.
//!
//! Two mechanisms:
//! 1. **Factor correction** — per-primitive multiplicative scale estimated
//!    from ~1% of target samples (median ratio of measured to predicted).
//! 2. **Fine-tuning** — continue training the source parameters on a small
//!    fraction of target data at lr/10 (same AOT artifacts; lr is a
//!    runtime scalar).

use super::metrics::median;
use super::predictor::Predictor;
use anyhow::Result;

/// Estimate per-output correction factors from a small calibration set:
/// factor_j = median over samples of (measured_j / predicted_j).
///
/// `xs` raw features, `measured` masked targets (ms).
pub fn factor_correction(
    pred: &Predictor,
    xs: &[Vec<f64>],
    measured: &[Vec<Option<f64>>],
) -> Result<Vec<f64>> {
    let raw = pred.predict_raw(xs)?;
    let out_dim = pred.out_dim();
    let mut factors = vec![1.0; out_dim];
    for j in 0..out_dim {
        let ratios: Vec<f64> = raw
            .iter()
            .zip(measured)
            .filter_map(|(p, m)| m[j].map(|mv| mv / p[j].max(1e-12)))
            .collect();
        if !ratios.is_empty() {
            factors[j] = median(&ratios);
        }
    }
    Ok(factors)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_ratio_recovers_scale() {
        // direct unit test of the estimator logic on synthetic ratios
        let ratios = [1.9, 2.0, 2.1, 2.05, 1.95];
        assert!((median(&ratios) - 2.0).abs() < 1e-9);
    }
}

//! Batched predictors: turn layer configurations into denormalised
//! per-primitive execution-time estimates via the AOT `predict` artifacts
//! (step ii of the paper's Figure 2 pipeline — the whole network's layers
//! go through the model in one batch).

use super::params::ParamStore;
use super::trainer::Trainer;
use crate::dataset::{Batches, Standardizer};
use crate::layers::ConvConfig;
use crate::primitives::{catalog, Layout};
use crate::runtime::Runtime;
use anyhow::Result;

/// A trained primitive-cost model ready for inference.
pub struct Predictor<'rt> {
    trainer: Trainer<'rt>,
    pub params: ParamStore,
    pub std_x: Standardizer,
    pub std_y: Standardizer,
    /// Per-output multiplicative correction (transfer §4.4); 1.0 = none.
    pub factors: Vec<f64>,
}

impl<'rt> Predictor<'rt> {
    pub fn new(
        rt: &'rt Runtime,
        kind: &str,
        params: ParamStore,
        std_x: Standardizer,
        std_y: Standardizer,
    ) -> Result<Self> {
        let trainer = Trainer::new(rt, kind)?;
        let out_dim = trainer.spec().out_dim;
        Ok(Self { trainer, params, std_x, std_y, factors: vec![1.0; out_dim] })
    }

    pub fn out_dim(&self) -> usize {
        self.trainer.spec().out_dim
    }

    /// Predict the full primitive-cost matrix for `configs` (ms).
    /// Inapplicable primitives are None, mirroring the profiler.
    pub fn predict_configs(&self, configs: &[ConvConfig]) -> Result<Vec<Vec<Option<f64>>>> {
        let xs: Vec<Vec<f64>> = configs.iter().map(|c| c.features().to_vec()).collect();
        let raw = self.predict_raw(&xs)?;
        Ok(configs
            .iter()
            .zip(raw)
            .map(|(cfg, row)| {
                catalog()
                    .iter()
                    .zip(row)
                    .map(|(p, v)| if p.applicable(cfg) { Some(v) } else { None })
                    .collect()
            })
            .collect())
    }

    /// Predict denormalised outputs (ms) for raw feature rows.
    pub fn predict_raw(&self, xs: &[Vec<f64>]) -> Result<Vec<Vec<f64>>> {
        let spec = self.trainer.spec();
        let b = crate::dataset::make_inference_batches(
            xs,
            &self.std_x,
            spec.out_dim,
            spec.train_batch.min(1024),
        );
        let preds = self.trainer.predict_normalised(&self.params, &b)?;
        let mut out = Vec::with_capacity(xs.len());
        for i in 0..xs.len() {
            let row: Vec<f64> = (0..spec.out_dim)
                .map(|j| {
                    self.std_y.inverse_one(j, preds[i * spec.out_dim + j] as f64)
                        * self.factors[j]
                })
                .collect();
            out.push(row);
        }
        Ok(out)
    }

    /// Batches-level loss passthrough (for validation during experiments).
    pub fn eval_loss(&self, b: &Batches) -> Result<f64> {
        self.trainer.eval_loss(&self.params, b)
    }
}

/// A trained DLT-cost model: predicts the 3x3 layout-transform matrix.
pub struct DltPredictor<'rt> {
    inner: Predictor<'rt>,
}

impl<'rt> DltPredictor<'rt> {
    pub fn new(
        rt: &'rt Runtime,
        kind: &str,
        params: ParamStore,
        std_x: Standardizer,
        std_y: Standardizer,
    ) -> Result<Self> {
        Ok(Self { inner: Predictor::new(rt, kind, params, std_x, std_y)? })
    }

    /// Predict DLT matrices for (c, im) pairs; identity entries are 0.
    pub fn predict_pairs(&self, pairs: &[(u32, u32)]) -> Result<Vec<[[f64; 3]; 3]>> {
        let xs: Vec<Vec<f64>> =
            pairs.iter().map(|&(c, im)| vec![c as f64, im as f64]).collect();
        let raw = self.inner.predict_raw(&xs)?;
        Ok(raw
            .into_iter()
            .map(|row| {
                let mut m = [[0.0; 3]; 3];
                for src in Layout::ALL {
                    for dst in Layout::ALL {
                        if src != dst {
                            m[src.index()][dst.index()] =
                                row[src.index() * 3 + dst.index()];
                        }
                    }
                }
                m
            })
            .collect())
    }
}

//! primsel — CNN primitive selection via learned performance models.
//!
//! Subcommands:
//!   exp --id <table1|...|fig10|all> [--repeats N] [--max-epochs N]
//!       regenerate a paper table/figure (results/ gets the CSVs)
//!   select --network <name> --platform <intel|amd|arm> [--source model|profile]
//!       run the full Figure-2 pipeline on one network
//!   serve [--capacity N] [--workers N] [--heavy N] [--light N]
//!       drive the admission-controlled service with a mixed-tenant workload
//!   metrics [--series] [--timeline <path>]
//!       run a small serving workload, then print the Prometheus
//!       exposition, the JSON snapshot, and the flight recorder;
//!       --series adds the ops-plane time-series + SLO report and
//!       --timeline exports a Chrome trace for Perfetto
//!   profile [--runs N]
//!       time the real Pallas kernel artifacts on this host via PJRT
//!   train --platform <p> --kind <nn1|nn2|dlt_nn1|dlt_nn2>
//!       (re)train a performance model and cache it
//!   networks | catalog
//!       list the zoo / the primitive catalog

use anyhow::{bail, Result};
use primsel::experiments::{self, Workbench};
use primsel::perfmodel::model::model_table;
use primsel::primitives::catalog;
use primsel::report::Table;
use primsel::runtime::Runtime;
use primsel::{networks, profiler, selection};
use std::collections::HashMap;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let flags = parse_flags(&args[1..]);
    match cmd.as_str() {
        "exp" => cmd_exp(&flags),
        "select" => cmd_select(&flags),
        "serve" => cmd_serve(&flags),
        "metrics" => cmd_metrics(&flags),
        "profile" => cmd_profile(&flags),
        "train" => cmd_train(&flags),
        "networks" => cmd_networks(),
        "catalog" => cmd_catalog(),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command {other} (try `primsel help`)"),
    }
}

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut map = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                map.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                map.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    map
}

fn print_usage() {
    println!(
        "primsel — CNN primitive selection via performance modeling\n\
         \n\
         usage: primsel <command> [flags]\n\
         \n\
         commands:\n\
         \x20 exp --id <id|all> [--repeats N] [--max-epochs N]   regenerate paper artefacts\n\
         \x20 select --network <name> --platform <p> [--source model|profile]\n\
         \x20 serve [--capacity N] [--workers N] [--heavy N] [--light N]\n\
         \x20                                                    mixed-tenant serving demo\n\
         \x20 metrics [--requests N] [--series] [--timeline F]   serve a workload, dump telemetry\n\
         \x20                                                    (--series: sampler + SLO report;\n\
         \x20                                                     --timeline F: Chrome trace JSON)\n\
         \x20 profile [--runs N]                                  time real kernels on this host\n\
         \x20 train --platform <p> --kind <kind>                  (re)train a model\n\
         \x20 networks                                            list the network zoo\n\
         \x20 catalog                                             list the 31 primitives\n\
         \n\
         experiment ids: {}",
        experiments::ALL_IDS.join(", ")
    );
}

fn cmd_exp(flags: &HashMap<String, String>) -> Result<()> {
    let id = flags.get("id").map(String::as_str).unwrap_or("all");
    let rt = Runtime::open_default()?;
    let mut wb = Workbench::new(rt);
    if let Some(r) = flags.get("repeats") {
        wb.repeats = r.parse()?;
    }
    if let Some(m) = flags.get("max-epochs") {
        wb.max_epochs = m.parse()?;
    }
    let ids: Vec<&str> = if id == "all" {
        experiments::ALL_IDS.to_vec()
    } else {
        vec![id]
    };
    for id in ids {
        eprintln!("=== running {id} ===");
        for table in experiments::run(id, &mut wb)? {
            println!("{}", table.render());
        }
    }
    Ok(())
}

fn cmd_select(flags: &HashMap<String, String>) -> Result<()> {
    let name = flags
        .get("network")
        .map(String::as_str)
        .unwrap_or("googlenet");
    let platform = flags.get("platform").map(String::as_str).unwrap_or("intel");
    let source = flags.get("source").map(String::as_str).unwrap_or("model");
    let net = networks::by_name(name)
        .ok_or_else(|| anyhow::anyhow!("unknown network {name} (see `primsel networks`)"))?;

    let rt = Runtime::open_default()?;
    let mut wb = Workbench::new(rt);
    let sim = wb.platform(platform)?.sim.clone();
    // cost-query engine: selection + evaluation share one memoized cache
    let measured_costs = selection::CostCache::new(&sim);

    let sel = if source == "model" {
        let inputs = wb.xla_model_inputs(platform)?;
        let model = inputs.build(&wb.rt)?;
        let src = model_table(&net, &model)?;
        selection::select(&net, &src)?
    } else {
        selection::select(&net, &measured_costs)?
    };

    let measured = selection::evaluate(&net, &sel, &measured_costs)?;
    let mut t = Table::new(
        &format!("selection for {name} on {platform} (source: {source})"),
        &["layer", "config (k,c,im,s,f)", "primitive"],
    );
    for (i, cfg) in net.layers.iter().enumerate() {
        t.row(vec![
            format!("{i}"),
            format!("({},{},{},{},{})", cfg.k, cfg.c, cfg.im, cfg.s, cfg.f),
            catalog()[sel.primitive[i]].name.into(),
        ]);
    }
    println!("{}", t.render());
    println!(
        "estimated: {:.3} ms | measured-on-{platform}: {measured:.3} ms",
        sel.estimated_ms
    );
    Ok(())
}

/// Drive the admission-controlled service with a mixed-tenant workload:
/// a weight-1 "heavy" tenant floods zoo requests through non-blocking
/// admission (rejections are the backpressure signal), while a weight-4
/// "light" tenant submits a small interactive batch through blocking
/// admission. Prints the light tenant's reports, then the full
/// [`ServiceStats`] — rejected counts and p50/p95 wait included — so a
/// fairness regression is visible straight from the terminal.
fn cmd_serve(flags: &HashMap<String, String>) -> Result<()> {
    use primsel::coordinator::{Coordinator, SelectionRequest};
    use primsel::service::{Service, ServiceConfig, SubmitError};

    let get = |key: &str, default: usize| -> Result<usize> {
        let parsed: Option<usize> = flags.get(key).map(|v| v.parse()).transpose()?;
        Ok(parsed.unwrap_or(default))
    };
    let capacity = get("capacity", 32)?;
    let workers = get("workers", primsel::par::workers().clamp(2, 8))?;
    let heavy_n = get("heavy", 48)?;
    let light_n = get("light", 8)?;
    if capacity < 1 || workers < 1 {
        bail!("--capacity and --workers must be at least 1 (got {capacity}, {workers})");
    }

    let service = Service::new(
        Coordinator::shared(),
        ServiceConfig::default().with_capacity(capacity).with_workers(workers),
    );
    // unequal weights: the light tenant gets 4 dispatches for each heavy
    // one while both are backlogged
    service.register_tenant("heavy", 1.0, workers)?;
    service.register_tenant("light", 4.0, workers)?;

    let nets = networks::selection_networks();
    let platforms = ["intel", "amd", "arm"];

    let mut heavy_tickets = Vec::new();
    for i in 0..heavy_n {
        let req = SelectionRequest::new(
            nets[i % nets.len()].clone(),
            platforms[i % platforms.len()],
        );
        match service.try_submit("heavy", req) {
            Ok(t) => heavy_tickets.push(t),
            Err(SubmitError::QueueFull) => {} // shed load; counted as rejected
            Err(e) => bail!("heavy admission failed: {e}"),
        }
    }
    let light_tickets: Vec<_> = (0..light_n)
        .map(|i| {
            let req = SelectionRequest::new(
                nets[i % nets.len()].clone(),
                platforms[(i + 1) % platforms.len()],
            );
            service.submit("light", req)
        })
        .collect::<std::result::Result<_, _>>()
        .map_err(|e| anyhow::anyhow!("light admission failed: {e}"))?;

    let mut t = Table::new(
        "light tenant reports (weighted 4x over the heavy flood)",
        &["network", "platform", "est time (ms)", "request wall (ms)"],
    );
    for ticket in light_tickets {
        let r = ticket.wait()?;
        t.row(vec![
            r.network,
            r.platform,
            format!("{:.3}", r.evaluated_ms),
            format!("{:.3}", r.wall_ms),
        ]);
    }
    println!("{}", t.render());
    println!(
        "light tenant fully served; heavy backlog still queued: {}",
        service.stats().tenants.iter().find(|t| t.tenant == "heavy").map_or(0, |t| t.queued)
    );

    for ticket in heavy_tickets {
        ticket.wait()?;
    }
    println!("{}", service.stats().render());
    service.shutdown();
    Ok(())
}

/// Serve a small mixed-tenant workload, then dump the unified
/// telemetry: the Prometheus exposition and JSON snapshot of the
/// process metrics registry (marker-delimited so tools can split the
/// stream), followed by the flight recorder's slowest-request and
/// health-event tables. With `--series` the ops plane comes up too
/// (background sampler + burn-rate SLOs) and the rolling time-series
/// report is printed; `--timeline <path>` writes the flight recorder
/// as Chrome trace-event JSON loadable in Perfetto.
fn cmd_metrics(flags: &HashMap<String, String>) -> Result<()> {
    use primsel::coordinator::{Coordinator, Objective, SelectionRequest};
    use primsel::health::HealthPolicy;
    use primsel::obs::SloSpec;
    use primsel::selection::CostSource;
    use primsel::service::{Service, ServiceConfig};
    use primsel::simulator::{machine, Simulator};
    use std::sync::Arc;
    use std::time::Duration;

    let requests: usize = flags
        .get("requests")
        .map(|v| v.parse())
        .transpose()?
        .unwrap_or(12);
    let series = flags.contains_key("series");
    let coord = Coordinator::shared();
    // monitor one platform so the health gauges have a row to publish
    let target: Arc<dyn CostSource> = Arc::new(Simulator::new(machine::intel_i9_9900k()));
    coord.monitor_platform("intel", target, HealthPolicy::default().with_sampling(0.25, 11))?;
    let mut config = ServiceConfig::default().with_capacity(16).with_workers(2);
    if series {
        config = config
            .with_sampling(Duration::from_millis(25))
            .with_slo(SloSpec::latency_p95("e2e-latency", "e2e", 50.0))
            .with_slo(SloSpec::error_rate("admission-errors", 0.05))
            .with_slo(SloSpec::queue_depth("queue-pressure", 0.8))
            .with_slo(SloSpec::drift("intel-drift", "intel", 0.25).with_nudge(16));
    }
    let service = Service::new(Arc::clone(&coord), config);
    service.register_tenant("interactive", 4.0, 2)?;
    service.register_tenant("batch", 1.0, 2)?;

    let nets = networks::selection_networks();
    let platforms = ["intel", "arm"];
    let mut tickets = Vec::new();
    for i in 0..requests {
        let tenant = if i % 2 == 0 { "interactive" } else { "batch" };
        let req =
            SelectionRequest::new(nets[i % nets.len()].clone(), platforms[i % platforms.len()]);
        tickets.push(
            service
                .submit(tenant, req)
                .map_err(|e| anyhow::anyhow!("admission failed: {e}"))?,
        );
    }
    for t in tickets {
        t.wait()?;
    }
    // one budget query so the Pareto-front cache has traffic too
    let req = SelectionRequest::new(networks::vgg(16), "intel").with_objective(
        Objective::FastestUnderBytes { budget_bytes: 8.0 * 1024.0 * 1024.0 },
    );
    coord.submit(&req)?;

    let reg = service.metrics();
    println!("=== metrics: prometheus ===");
    print!("{}", reg.render_prometheus());
    println!("=== metrics: json ===");
    println!("{}", reg.snapshot_json().dump());
    println!("=== metrics: end ===");
    if series {
        // force one final tick so the series include the drained workload
        service.ops_tick();
        if let Some(report) = service.ops_report() {
            println!("=== ops: series ===");
            println!("{}", report.to_json().dump());
            println!("=== ops: end ===");
            println!("\n{}", report.render());
        }
    }
    println!("\n{}", primsel::obs::flight_recorder().render());
    if let Some(path) = flags.get("timeline") {
        primsel::obs::write_chrome_trace(
            primsel::obs::flight_recorder(),
            std::path::Path::new(path),
        )?;
        println!("chrome trace written to {path} (load in Perfetto / chrome://tracing)");
    }
    service.shutdown();
    Ok(())
}

fn cmd_profile(flags: &HashMap<String, String>) -> Result<()> {
    let runs: usize = flags
        .get("runs")
        .map(|r| r.parse())
        .transpose()?
        .unwrap_or(25);
    let rt = Runtime::open_default()?;
    println!(
        "profiling {} kernel artifacts, {} runs each...",
        rt.manifest.prim_grid.len(),
        runs
    );
    let measurements = profiler::profile_grid(&rt, runs)?;
    let mut t = Table::new(
        "host measurements (real Pallas kernels via PJRT)",
        &["kernel", "c", "im", "k", "f", "s", "median ms", "GFLOP/s"],
    );
    for m in &measurements {
        t.row(vec![
            m.kernel.clone(),
            m.c.to_string(),
            m.im.to_string(),
            m.k.to_string(),
            m.f.to_string(),
            m.s.to_string(),
            format!("{:.3}", m.median_ms),
            format!("{:.2}", m.gflops()),
        ]);
    }
    println!("{}", t.render());
    std::fs::create_dir_all("results").ok();
    std::fs::write("results/host_profile.csv", t.to_csv())?;
    Ok(())
}

fn cmd_train(flags: &HashMap<String, String>) -> Result<()> {
    let platform = flags.get("platform").map(String::as_str).unwrap_or("intel");
    let kind = flags.get("kind").map(String::as_str).unwrap_or("nn2");
    let rt = Runtime::open_default()?;
    let mut wb = Workbench::new(rt);
    if let Some(m) = flags.get("max-epochs") {
        wb.max_epochs = m.parse()?;
    }
    match kind {
        "nn2" => {
            wb.nn2_params(platform)?;
        }
        "dlt_nn2" => {
            wb.dlt_nn2_params(platform)?;
        }
        "nn1" => {
            wb.nn1_params_all(platform)?;
        }
        "dlt_nn1" => {
            wb.dlt_nn1_params_all(platform)?;
        }
        other => bail!("unknown kind {other}"),
    }
    println!("trained + cached {kind} for {platform} (artifacts/trained/)");
    Ok(())
}

fn cmd_networks() -> Result<()> {
    let mut t = Table::new("network zoo", &["name", "conv layers", "edges", "GMACs"]);
    for n in networks::zoo() {
        t.row(vec![
            n.name.clone(),
            n.n_layers().to_string(),
            n.edges.len().to_string(),
            format!("{:.2}", n.total_macs() / 1e9),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_catalog() -> Result<()> {
    let mut t = Table::new(
        "primitive catalog (31 primitives, 7 families)",
        &["#", "name", "family", "in", "out", "kernel (L1 Pallas)"],
    );
    for (i, p) in catalog().iter().enumerate() {
        t.row(vec![
            i.to_string(),
            p.name.into(),
            p.family.name().into(),
            p.in_layout.name().into(),
            p.out_layout.name().into(),
            p.kernel_id.into(),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

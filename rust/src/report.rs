//! ASCII table / CSV rendering for the experiment suite (this image has
//! no plotting stack; every paper figure is regenerated as a table whose
//! series/rows mirror the figure's, plus a CSV dump for external plotting).

use std::fmt::Write as _;

/// A simple aligned text table.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "column count");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(out, "{:<w$}  ", c, w = widths[i]);
            }
            let _ = writeln!(out);
        };
        line(&mut out, &self.headers);
        let total: usize = widths.iter().sum::<usize>() + 2 * widths.len();
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let _ = writeln!(
            out,
            "{}",
            self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }
}

/// Format a time in ms the way the paper's Table 4 does (ms / s / h).
pub fn fmt_time_ms(ms: f64) -> String {
    if ms < 1e3 {
        format!("{ms:.1}ms")
    } else if ms < 1000e3 {
        format!("{:.0}s", ms / 1e3)
    } else {
        format!("{:.2}h", ms / 3.6e6)
    }
}

/// Format a ratio as a percentage.
pub fn fmt_pct(x: f64) -> String {
    format!("{:.2}%", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns_columns() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "22".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("long-name"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("x", &["a"]);
        t.row(vec!["v,1".into()]);
        assert!(t.to_csv().contains("\"v,1\""));
    }

    #[test]
    fn time_formats() {
        assert_eq!(fmt_time_ms(43.6), "43.6ms");
        assert_eq!(fmt_time_ms(66_000.0), "66s");
        assert_eq!(fmt_time_ms(2_052_000.0), "0.57h");
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}

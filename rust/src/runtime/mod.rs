//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! This is the only module touching the `xla` crate. Interchange is HLO
//! *text* (xla_extension 0.5.1 rejects jax>=0.5 serialized protos — see
//! /opt/xla-example/README.md); all artifacts are lowered with
//! `return_tuple=True`, so every execution returns a tuple literal that we
//! decompose.

mod manifest;

pub use manifest::{DltGridEntry, Manifest, ModelSpec, PrimGridEntry};

use anyhow::{anyhow, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// A loaded-and-compiled artifact cache over one PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    pub manifest: Manifest,
}

impl Runtime {
    /// Open the artifacts directory (expects manifest.json inside).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {dir:?} — run `make artifacts`"))?;
        let client = xla::PjRtClient::cpu().map_err(wrap)?;
        Ok(Self { client, dir, cache: RefCell::new(HashMap::new()), manifest })
    }

    /// Default artifacts location relative to the repo root.
    pub fn open_default() -> Result<Self> {
        let candidates = ["artifacts", "../artifacts", "../../artifacts"];
        for c in candidates {
            if Path::new(c).join("manifest.json").exists() {
                return Self::open(c);
            }
        }
        Self::open("artifacts")
    }

    /// Load + compile an HLO text artifact (cached by file name).
    pub fn load(&self, file: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(file) {
            return Ok(exe.clone());
        }
        let path = self.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(wrap)
        .with_context(|| format!("parsing {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp).map_err(wrap)?);
        self.cache.borrow_mut().insert(file.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact on f32 literals and decompose the result tuple.
    pub fn execute(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let result = exe.execute::<xla::Literal>(inputs).map_err(wrap)?;
        let lit = result[0][0].to_literal_sync().map_err(wrap)?;
        lit.to_tuple().map_err(wrap)
    }

    /// Number of artifacts compiled so far (cache size).
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }
}

/// The xla crate has its own error type; flatten to anyhow.
fn wrap(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e}")
}

/// Build an f32 literal of the given shape from a flat slice.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    anyhow::ensure!(n as usize == data.len(), "shape/data mismatch");
    if dims.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    xla::Literal::vec1(data).reshape(dims).map_err(wrap)
}

/// Scalar f32 literal.
pub fn scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Scalar i32 literal.
pub fn scalar_i32(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Flatten a literal back to f32s.
pub fn to_f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(wrap)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        Runtime::open_default().ok()
    }

    #[test]
    fn literal_round_trip() {
        let lit = literal_f32(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).unwrap();
        assert_eq!(to_f32_vec(&lit).unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
    }

    #[test]
    fn manifest_loads() {
        let Some(rt) = runtime() else { return };
        assert_eq!(rt.manifest.n_primitives, crate::primitives::CATALOG_LEN);
        assert!(rt.manifest.models.contains_key("nn2"));
        assert!(!rt.manifest.prim_grid.is_empty());
    }

    #[test]
    fn load_compile_execute_predict() {
        let Some(rt) = runtime() else { return };
        let spec = rt.manifest.models["nn1"].clone();
        // init params from seed, then predict on zeros
        let init = rt.load(&spec.files["init"]).unwrap();
        let params = rt.execute(&init, &[scalar_i32(42)]).unwrap();
        assert_eq!(params.len(), spec.param_shapes.len());
        let b = rt.manifest.predict_batches.0;
        let predict = rt.load(&spec.files[&format!("predict_b{b}")]).unwrap();
        let x = literal_f32(&vec![0.0; b * spec.in_dim], &[b as i64, spec.in_dim as i64])
            .unwrap();
        let mut inputs = params;
        inputs.push(x);
        let out = rt.execute(&predict, &inputs).unwrap();
        assert_eq!(out.len(), 1);
        let y = to_f32_vec(&out[0]).unwrap();
        assert_eq!(y.len(), b * spec.out_dim);
        assert!(y.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn executable_cache_hits() {
        let Some(rt) = runtime() else { return };
        let f = &rt.manifest.models["nn1"].files["init"].clone();
        let a = rt.load(f).unwrap();
        let n = rt.compiled_count();
        let b = rt.load(f).unwrap();
        assert_eq!(n, rt.compiled_count());
        assert!(Rc::ptr_eq(&a, &b));
    }
}

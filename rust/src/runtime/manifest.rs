//! artifacts/manifest.json — the shape/order contract between the python
//! compile path and this runtime.

use crate::config::Json;
use anyhow::Result;
use std::collections::HashMap;
use std::path::Path;

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub n_primitives: usize,
    pub n_layouts: usize,
    pub prim_features: usize,
    pub dlt_features: usize,
    /// (small, large) predict batch sizes baked into the artifacts.
    pub predict_batches: (usize, usize),
    pub models: HashMap<String, ModelSpec>,
    pub prim_grid: Vec<PrimGridEntry>,
    pub dlt_grid: Vec<DltGridEntry>,
}

/// One performance-model kind (nn1 / nn2 / dlt_nn1 / dlt_nn2).
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub in_dim: usize,
    pub out_dim: usize,
    pub hidden: Vec<usize>,
    /// Flat tensor order: W0, b0, W1, b1, ...
    pub param_shapes: Vec<Vec<usize>>,
    pub train_batch: usize,
    pub epoch_batches: usize,
    /// artifact file names: init, train_step, train_epoch, predict_b{B}.
    pub files: HashMap<String, String>,
}

impl ModelSpec {
    pub fn n_params(&self) -> usize {
        self.param_shapes.iter().map(|s| s.iter().product::<usize>()).sum()
    }
}

/// One measured-profile-grid kernel artifact.
#[derive(Debug, Clone)]
pub struct PrimGridEntry {
    pub kernel: String,
    pub c: u32,
    pub im: u32,
    pub k: u32,
    pub f: u32,
    pub s: u32,
    pub out_layout: String,
    pub flops: f64,
    pub file: String,
}

/// One DLT kernel artifact.
#[derive(Debug, Clone)]
pub struct DltGridEntry {
    pub src: String,
    pub dst: String,
    pub c: u32,
    pub im: u32,
    pub bytes: u64,
    pub file: String,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text)?;

        let mut models = HashMap::new();
        for (name, spec) in j.get("models")?.as_obj()? {
            let mut files = HashMap::new();
            for (k, v) in spec.get("files")?.as_obj()? {
                files.insert(k.clone(), v.as_str()?.to_string());
            }
            let param_shapes = spec
                .get("param_shapes")?
                .as_arr()?
                .iter()
                .map(|s| {
                    s.as_arr().map(|dims| {
                        dims.iter().map(|d| d.as_usize().unwrap()).collect()
                    })
                })
                .collect::<Result<Vec<Vec<usize>>>>()?;
            models.insert(
                name.clone(),
                ModelSpec {
                    in_dim: spec.get("in_dim")?.as_usize()?,
                    out_dim: spec.get("out_dim")?.as_usize()?,
                    hidden: spec
                        .get("hidden")?
                        .as_arr()?
                        .iter()
                        .map(|h| h.as_usize().unwrap())
                        .collect(),
                    param_shapes,
                    train_batch: spec.get("train_batch")?.as_usize()?,
                    epoch_batches: spec.get("epoch_batches")?.as_usize()?,
                    files,
                },
            );
        }

        let prim_grid = match j.get("prim_grid") {
            Ok(arr) => arr
                .as_arr()?
                .iter()
                .map(|e| {
                    Ok(PrimGridEntry {
                        kernel: e.get("kernel")?.as_str()?.to_string(),
                        c: e.get("c")?.as_usize()? as u32,
                        im: e.get("im")?.as_usize()? as u32,
                        k: e.get("k")?.as_usize()? as u32,
                        f: e.get("f")?.as_usize()? as u32,
                        s: e.get("s")?.as_usize()? as u32,
                        out_layout: e.get("out_layout")?.as_str()?.to_string(),
                        flops: e.get("flops")?.as_f64()?,
                        file: e.get("file")?.as_str()?.to_string(),
                    })
                })
                .collect::<Result<Vec<_>>>()?,
            Err(_) => Vec::new(),
        };

        let dlt_grid = match j.get("dlt_grid") {
            Ok(arr) => arr
                .as_arr()?
                .iter()
                .map(|e| {
                    Ok(DltGridEntry {
                        src: e.get("src")?.as_str()?.to_string(),
                        dst: e.get("dst")?.as_str()?.to_string(),
                        c: e.get("c")?.as_usize()? as u32,
                        im: e.get("im")?.as_usize()? as u32,
                        bytes: e.get("bytes")?.as_usize()? as u64,
                        file: e.get("file")?.as_str()?.to_string(),
                    })
                })
                .collect::<Result<Vec<_>>>()?,
            Err(_) => Vec::new(),
        };

        let pb = j.get("predict_batches")?.as_arr()?;
        Ok(Manifest {
            n_primitives: j.get("n_primitives")?.as_usize()?,
            n_layouts: j.get("n_layouts")?.as_usize()?,
            prim_features: j.get("prim_features")?.as_usize()?,
            dlt_features: j.get("dlt_features")?.as_usize()?,
            predict_batches: (pb[0].as_usize()?, pb[1].as_usize()?),
            models,
            prim_grid,
            dlt_grid,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_real_manifest_when_present() {
        for dir in ["artifacts", "../artifacts"] {
            let p = Path::new(dir).join("manifest.json");
            if p.exists() {
                let m = Manifest::load(&p).unwrap();
                assert_eq!(m.models.len(), 4);
                let nn2 = &m.models["nn2"];
                assert_eq!(nn2.in_dim, 5);
                assert_eq!(nn2.out_dim, m.n_primitives);
                assert_eq!(nn2.param_shapes.len(), 10);
                assert!(nn2.files.contains_key("train_step"));
                return;
            }
        }
    }
}

//! The convolutional primitive catalog (paper Table 6, 31 modeled
//! primitives across the seven families of §3.1) with layout contracts and
//! applicability predicates.

mod catalog;

pub use catalog::{catalog, Primitive, CATALOG_LEN};

use crate::layers::ConvConfig;

/// The paper's three data layouts (§3.2.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Layout {
    /// c × im × im
    Chw,
    /// im × c × im
    Hcw,
    /// im × im × c
    Hwc,
}

impl Layout {
    pub const ALL: [Layout; 3] = [Layout::Chw, Layout::Hcw, Layout::Hwc];

    pub fn index(self) -> usize {
        match self {
            Layout::Chw => 0,
            Layout::Hcw => 1,
            Layout::Hwc => 2,
        }
    }

    pub fn from_index(i: usize) -> Layout {
        Self::ALL[i]
    }

    pub fn name(self) -> &'static str {
        match self {
            Layout::Chw => "chw",
            Layout::Hcw => "hcw",
            Layout::Hwc => "hwc",
        }
    }
}

/// Primitive families (paper §3.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Family {
    Direct,
    Im2,
    Kn2,
    Wino3,
    Wino5,
    Conv1x1,
    Mec,
}

impl Family {
    pub const ALL: [Family; 7] = [
        Family::Direct,
        Family::Im2,
        Family::Kn2,
        Family::Wino3,
        Family::Wino5,
        Family::Conv1x1,
        Family::Mec,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Family::Direct => "direct",
            Family::Im2 => "im2",
            Family::Kn2 => "kn2",
            Family::Wino3 => "wino3",
            Family::Wino5 => "wino5",
            Family::Conv1x1 => "c1x1",
            Family::Mec => "mec",
        }
    }
}

/// GEMM operand transpose variants (`ab`, `atb`, `abt`, `atbt` in the
/// triNNity names). Functionally equivalent; they differ in cost.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GemmVariant {
    Ab,
    Atb,
    Abt,
    Atbt,
}

impl Primitive {
    /// Whether the primitive can implement the given layer configuration
    /// (paper §3.2.1: some `R_i` are undefined).
    pub fn applicable(&self, cfg: &ConvConfig) -> bool {
        if !cfg.is_valid() {
            return false;
        }
        match self.family {
            Family::Direct | Family::Im2 | Family::Mec => true,
            // kn2's shifted-gemm trick needs unit stride (paper §3.1).
            Family::Kn2 => cfg.s == 1,
            Family::Wino3 => cfg.s == 1 && cfg.f == 3 && cfg.im >= 3,
            Family::Wino5 => cfg.s == 1 && cfg.f == 5 && cfg.im >= 5,
            Family::Conv1x1 => cfg.f == 1,
        }
    }
}

/// Number of primitives applicable to a config.
pub fn applicable_count(cfg: &ConvConfig) -> usize {
    catalog().iter().filter(|p| p.applicable(cfg)).count()
}

/// Find a primitive index by name.
pub fn index_of(name: &str) -> Option<usize> {
    catalog().iter().position(|p| p.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_has_31_primitives() {
        assert_eq!(catalog().len(), 31);
        assert_eq!(catalog().len(), CATALOG_LEN);
    }

    #[test]
    fn names_unique() {
        let mut names: Vec<_> = catalog().iter().map(|p| p.name).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), CATALOG_LEN);
    }

    #[test]
    fn all_families_present() {
        for fam in Family::ALL {
            assert!(
                catalog().iter().any(|p| p.family == fam),
                "missing family {fam:?}"
            );
        }
    }

    #[test]
    fn applicability_rules() {
        let any = ConvConfig::new(64, 64, 56, 1, 3);
        let strided = ConvConfig::new(64, 64, 56, 2, 3);
        let one = ConvConfig::new(64, 64, 56, 1, 1);
        let five = ConvConfig::new(64, 64, 56, 1, 5);
        for p in catalog() {
            match p.family {
                Family::Direct | Family::Im2 | Family::Mec => {
                    assert!(p.applicable(&any) && p.applicable(&strided));
                }
                Family::Kn2 => {
                    assert!(p.applicable(&any) && !p.applicable(&strided));
                }
                Family::Wino3 => {
                    assert!(p.applicable(&any) && !p.applicable(&five));
                    assert!(!p.applicable(&strided));
                }
                Family::Wino5 => {
                    assert!(p.applicable(&five) && !p.applicable(&any));
                }
                Family::Conv1x1 => {
                    assert!(p.applicable(&one) && !p.applicable(&any));
                }
            }
        }
    }

    #[test]
    fn every_config_has_a_primitive() {
        // the "always applicable" families guarantee a non-empty choice set
        for (s, f) in [(1u32, 3u32), (2, 5), (4, 7), (1, 1), (2, 11)] {
            let cfg = ConvConfig::new(8, 8, 32, s, f);
            assert!(applicable_count(&cfg) >= 3, "{cfg:?}");
        }
    }

    #[test]
    fn layout_round_trip() {
        for l in Layout::ALL {
            assert_eq!(Layout::from_index(l.index()), l);
        }
    }

    #[test]
    fn kernel_ids_are_known() {
        // kernel ids must match python/compile/kernels REGISTRY keys
        let known = [
            "direct_sum2d", "im2col_copy", "im2col_scan", "im2row_copy",
            "im2row_scan", "kn2row", "kn2col", "winograd_2x2_3x3",
            "winograd_3x3_3x3", "winograd_4x4_3x3", "winograd_2x2_5x5",
            "winograd_4x4_5x5", "conv1x1_ki", "conv1x1_ik", "mec_col",
        ];
        for p in catalog() {
            assert!(known.contains(&p.kernel_id), "{}", p.kernel_id);
        }
    }
}

//! The static catalog of the 31 modeled primitives.
//!
//! Names follow triNNity (paper Table 6). Each entry records which Pallas
//! kernel implements it (`kernel_id`, a key of python REGISTRY), its input
//! and output layout contracts, and the variant knobs the simulator's cost
//! model keys on (gemm transposes, copy-vs-scan, Winograd tile size and
//! vector width).

use super::{Family, GemmVariant, Layout};

/// A catalog entry for one convolutional primitive.
#[derive(Debug, Clone)]
pub struct Primitive {
    /// triNNity-style primitive name.
    pub name: &'static str,
    pub family: Family,
    /// Pallas kernel id in python/compile/kernels REGISTRY.
    pub kernel_id: &'static str,
    pub in_layout: Layout,
    pub out_layout: Layout,
    /// GEMM operand transpose variant.
    pub gemm: GemmVariant,
    /// im2 family: copy (materialise patch matrix) vs scan (streamed).
    pub copy: bool,
    /// Winograd output tile size m (0 for non-winograd).
    pub tile_m: u32,
    /// Vectorisation width of the `-vec-N` variants (1 = scalar).
    pub vec_width: u32,
}

const fn prim(
    name: &'static str,
    family: Family,
    kernel_id: &'static str,
    in_layout: Layout,
    out_layout: Layout,
    gemm: GemmVariant,
    copy: bool,
    tile_m: u32,
    vec_width: u32,
) -> Primitive {
    Primitive { name, family, kernel_id, in_layout, out_layout, gemm, copy, tile_m, vec_width }
}

use Family as F;
use GemmVariant as G;
use Layout as L;

/// Number of primitives — must match python/compile/constants.N_PRIMITIVES.
pub const CATALOG_LEN: usize = 31;

static CATALOG: [Primitive; CATALOG_LEN] = [
    // --- direct (1)
    prim("direct-sum2d", F::Direct, "direct_sum2d", L::Chw, L::Chw, G::Ab, false, 0, 1),
    // --- im2 (10)
    prim("im2col-copy-ab-ki", F::Im2, "im2col_copy", L::Chw, L::Chw, G::Ab, true, 0, 1),
    prim("im2col-copy-atb-ik", F::Im2, "im2col_copy", L::Chw, L::Hwc, G::Atb, true, 0, 1),
    prim("im2col-copy-atb-ki", F::Im2, "im2col_copy", L::Chw, L::Chw, G::Atb, true, 0, 1),
    prim("im2col-copy-atbt-ik", F::Im2, "im2col_copy", L::Chw, L::Hwc, G::Atbt, true, 0, 1),
    prim("im2col-scan-ab-ki", F::Im2, "im2col_scan", L::Chw, L::Chw, G::Ab, false, 0, 1),
    prim("im2col-scan-atb-ik", F::Im2, "im2col_scan", L::Chw, L::Hwc, G::Atb, false, 0, 1),
    prim("im2row-copy-ab-ik", F::Im2, "im2row_copy", L::Hwc, L::Hwc, G::Ab, true, 0, 1),
    prim("im2row-copy-abt-ik", F::Im2, "im2row_copy", L::Hwc, L::Hwc, G::Abt, true, 0, 1),
    prim("im2row-scan-ab-ik", F::Im2, "im2row_scan", L::Hwc, L::Hwc, G::Ab, false, 0, 1),
    prim("im2row-scan-abt-ki", F::Im2, "im2row_scan", L::Hwc, L::Chw, G::Abt, false, 0, 1),
    // --- kn2 (6)
    prim("kn2col", F::Kn2, "kn2col", L::Hwc, L::Hwc, G::Ab, false, 0, 1),
    prim("kn2col-as", F::Kn2, "kn2col", L::Hwc, L::Hwc, G::Ab, true, 0, 1),
    prim("kn2row", F::Kn2, "kn2row", L::Chw, L::Chw, G::Ab, false, 0, 1),
    prim("kn2row-aa-ab", F::Kn2, "kn2row", L::Chw, L::Chw, G::Ab, true, 0, 1),
    prim("kn2row-aa-atb", F::Kn2, "kn2row", L::Chw, L::Chw, G::Atb, true, 0, 1),
    prim("kn2row-as", F::Kn2, "kn2row", L::Chw, L::Chw, G::Atb, false, 0, 1),
    // --- wino3 (5)
    prim("winograd-2x2-3x3", F::Wino3, "winograd_2x2_3x3", L::Chw, L::Chw, G::Ab, false, 2, 1),
    prim("winograd-2x2-3x3-vec-4", F::Wino3, "winograd_2x2_3x3", L::Chw, L::Chw, G::Ab, false, 2, 4),
    prim("winograd-3x3-3x3", F::Wino3, "winograd_3x3_3x3", L::Chw, L::Chw, G::Ab, false, 3, 1),
    prim("winograd-4x4-3x3", F::Wino3, "winograd_4x4_3x3", L::Chw, L::Chw, G::Ab, false, 4, 1),
    prim("winograd-4x4-3x3-vec-8", F::Wino3, "winograd_4x4_3x3", L::Chw, L::Chw, G::Ab, false, 4, 8),
    // --- wino5 (3)
    prim("winograd-2x2-5x5", F::Wino5, "winograd_2x2_5x5", L::Chw, L::Chw, G::Ab, false, 2, 1),
    prim("winograd-3x3-5x5-vec4", F::Wino5, "winograd_2x2_5x5", L::Chw, L::Chw, G::Ab, false, 3, 4),
    prim("winograd-4x4-5x5-vec8", F::Wino5, "winograd_4x4_5x5", L::Chw, L::Chw, G::Ab, false, 4, 8),
    // --- conv-1x1 (4)
    prim("conv-1x1-gemm-ab-ik", F::Conv1x1, "conv1x1_ik", L::Hwc, L::Hwc, G::Ab, false, 0, 1),
    prim("conv-1x1-gemm-ab-ki", F::Conv1x1, "conv1x1_ki", L::Chw, L::Chw, G::Ab, false, 0, 1),
    prim("conv-1x1-gemm-atb-ik", F::Conv1x1, "conv1x1_ik", L::Hwc, L::Hwc, G::Atb, false, 0, 1),
    prim("conv-1x1-gemm-atbt-ki", F::Conv1x1, "conv1x1_ki", L::Chw, L::Chw, G::Atbt, false, 0, 1),
    // --- mec (2)
    prim("mec-col", F::Mec, "mec_col", L::Hwc, L::Hwc, G::Ab, false, 0, 1),
    prim("mec-row-partition", F::Mec, "mec_col", L::Hwc, L::Hwc, G::Abt, true, 0, 1),
];

/// The full primitive catalog, index-stable (NN2 output ordering).
pub fn catalog() -> &'static [Primitive] {
    &CATALOG
}

//! # The multi-tenant selection service
//!
//! The paper's economics ("hours to just seconds", Table 4) only pay off
//! at scale if one warm cost table serves *many* selection requests. This
//! module is that serving layer: a [`Coordinator`] owns one long-lived,
//! shared [`CostCache`] per platform and answers batches of selection
//! requests — network × platform × [`Objective`] — concurrently over
//! them.
//!
//! ```text
//!              submit_batch(&[SelectionRequest])
//!                             |
//!                        Coordinator ── par::par_map_heavy ──► workers
//!                        /         \                        (1 request
//!              CostCache(intel)  CostCache(arm) …            per job)
//!                        |             |
//!                   Simulator / predictor tables (per platform)
//! ```
//!
//! Every request for a platform routes through that platform's shared
//! cache ([`CostCache`] is `Send + Sync`, sharded internally), so the
//! first request to touch a layer config profiles it and every later
//! request — same batch or a later one — gets a hash lookup. Results are
//! bit-identical to solving each request alone with a fresh cache
//! (pinned by `rust/tests/concurrency.rs`): sources are deterministic,
//! and the cache stores exactly what the source returned.
//!
//! Platforms resolve on demand: a request naming `"intel"`, `"amd"` or
//! `"arm"` gets a simulator-backed cache built from
//! [`machine::by_name`](crate::simulator::machine::by_name); other cost
//! sources — e.g. a predictor-built
//! [`TableSource`](crate::selection::TableSource) for a trained platform
//! model — can be attached under any name with [`Coordinator::register`].
//!
//! Each [`BatchReport`] carries per-platform [`CacheStats`] deltas, so a
//! serving process can watch its hit rate climb as tenants repeat layer
//! shapes — the `serve_zoo` example prints exactly that trajectory.

use crate::networks::Network;
use crate::par;
use crate::selection::{self, memory, CacheStats, CostCache, CostSource, Selection};
use crate::simulator::{machine, Simulator};
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::{Arc, RwLock};
use std::time::Instant;

/// What a tenant wants minimised.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Objective {
    /// Plain fastest network: the paper's PBQP objective.
    MinTime,
    /// Time plus a soft per-layer workspace penalty (TASO-style): layers
    /// whose primitive overshoots `budget_bytes` are charged
    /// `lambda_ms_per_mb` per overshot MiB in the PBQP objective.
    MinTimeWithMemoryBudget {
        budget_bytes: f64,
        lambda_ms_per_mb: f64,
    },
}

impl Objective {
    /// Short human-readable tag for report tables.
    pub fn tag(&self) -> String {
        match self {
            Objective::MinTime => "time".to_string(),
            Objective::MinTimeWithMemoryBudget { budget_bytes, .. } => {
                format!("time|{:.0}MiB", budget_bytes / (1024.0 * 1024.0))
            }
        }
    }
}

/// One tenant request: optimise `network` for `platform` under
/// `objective`.
#[derive(Debug, Clone)]
pub struct SelectionRequest {
    pub network: Network,
    pub platform: String,
    pub objective: Objective,
}

impl SelectionRequest {
    /// A plain min-time request.
    pub fn new(network: Network, platform: &str) -> Self {
        Self { network, platform: platform.to_string(), objective: Objective::MinTime }
    }

    /// Override the objective (builder style).
    pub fn with_objective(mut self, objective: Objective) -> Self {
        self.objective = objective;
        self
    }
}

/// The answer to one [`SelectionRequest`].
#[derive(Debug, Clone)]
pub struct SelectionReport {
    pub network: String,
    pub platform: String,
    pub objective: Objective,
    /// The chosen primitive per layer plus the objective value.
    pub selection: Selection,
    /// Plain network time of the chosen assignment under the platform's
    /// cost source (no penalty terms), for like-for-like comparison
    /// across objectives.
    pub evaluated_ms: f64,
    /// Peak per-layer workspace of the chosen assignment.
    pub peak_workspace_bytes: f64,
    /// Wall-clock this request spent inside its worker.
    pub wall_ms: f64,
}

/// The answer to one [`Coordinator::submit_batch`] call.
#[derive(Debug, Clone)]
pub struct BatchReport {
    /// One report per request, in request order.
    pub reports: Vec<SelectionReport>,
    /// Per-platform cache hit/miss deltas over this batch's time window,
    /// in order of first appearance in the request slice. Deltas are
    /// computed from the caches' lifetime counters, so they are exact
    /// when batches on this coordinator don't overlap; if another
    /// `submit`/`submit_batch` runs concurrently on the same platform,
    /// its traffic lands in the same window and is counted here too.
    pub stats: Vec<(String, CacheStats)>,
    /// Wall-clock of the whole batch (fan-out included).
    pub wall_ms: f64,
}

/// The serving layer: per-platform shared caches plus batch fan-out.
///
/// ```
/// use primsel::coordinator::{Coordinator, Objective, SelectionRequest};
/// use primsel::networks;
///
/// let coord = Coordinator::new();
/// let batch = vec![
///     SelectionRequest::new(networks::alexnet(), "intel"),
///     SelectionRequest::new(networks::vgg(11), "arm"),
///     SelectionRequest::new(networks::alexnet(), "intel").with_objective(
///         Objective::MinTimeWithMemoryBudget {
///             budget_bytes: 4.0 * 1024.0 * 1024.0,
///             lambda_ms_per_mb: 10.0,
///         },
///     ),
/// ];
/// let report = coord.submit_batch(&batch).unwrap();
/// assert_eq!(report.reports.len(), 3);
/// for (req, rep) in batch.iter().zip(&report.reports) {
///     assert_eq!(rep.network, req.network.name);
///     assert_eq!(rep.selection.primitive.len(), req.network.n_layers());
///     assert!(rep.evaluated_ms > 0.0);
/// }
/// // both intel requests shared one warm cache
/// assert_eq!(report.stats[0].0, "intel");
/// ```
pub struct Coordinator {
    platforms: RwLock<HashMap<String, Arc<CostCache<'static>>>>,
}

impl Default for Coordinator {
    fn default() -> Self {
        Self::new()
    }
}

impl Coordinator {
    /// An empty coordinator; platform caches attach on first use.
    pub fn new() -> Self {
        Self { platforms: RwLock::new(HashMap::new()) }
    }

    /// Attach a custom cost source (predictor tables, a measured
    /// profiler…) under `platform`. Replaces any existing cache for that
    /// name, resetting its memoized rows and stats.
    pub fn register(&self, platform: &str, source: Arc<dyn CostSource>) {
        let cache = Arc::new(CostCache::new_shared(source));
        self.platforms
            .write()
            .expect("platform map poisoned")
            .insert(platform.to_string(), cache);
    }

    /// The shared cache serving `platform`, creating a simulator-backed
    /// one on first use for the built-in platform names.
    pub fn cache(&self, platform: &str) -> Result<Arc<CostCache<'static>>> {
        if let Some(c) = self.platforms.read().expect("platform map poisoned").get(platform) {
            return Ok(Arc::clone(c));
        }
        let m = machine::by_name(platform).ok_or_else(|| {
            anyhow!("unknown platform {platform:?}: register() a source or use intel/amd/arm")
        })?;
        let cache = Arc::new(CostCache::new_shared(Arc::new(Simulator::new(m))));
        let mut map = self.platforms.write().expect("platform map poisoned");
        // a racing resolver may have inserted meanwhile; keep the winner
        Ok(Arc::clone(map.entry(platform.to_string()).or_insert(cache)))
    }

    /// Solve a single request synchronously on the caller's thread
    /// (through the platform's shared cache, so it still warms the cache
    /// for everyone else).
    pub fn submit(&self, req: &SelectionRequest) -> Result<SelectionReport> {
        let cache = self.cache(&req.platform)?;
        solve_one(&cache, req)
    }

    /// Solve a batch of requests concurrently: platforms are resolved up
    /// front (so an unknown platform fails before any work is spawned),
    /// then requests fan out one-per-job over [`par::par_map_heavy`],
    /// every job routing through its platform's shared cache. Reports
    /// come back in request order and are bit-identical to solving each
    /// request alone. The returned [`BatchReport::stats`] deltas span
    /// this batch's time window — see their field docs for what that
    /// means when batches overlap.
    pub fn submit_batch(&self, reqs: &[SelectionRequest]) -> Result<BatchReport> {
        let t0 = Instant::now();
        let caches: Vec<Arc<CostCache<'static>>> =
            reqs.iter().map(|r| self.cache(&r.platform)).collect::<Result<_>>()?;

        // distinct platforms in first-appearance order, with pre-batch
        // counter snapshots for the per-batch stats delta
        let mut seen: Vec<(String, Arc<CostCache<'static>>, CacheStats)> = Vec::new();
        for (r, c) in reqs.iter().zip(&caches) {
            if !seen.iter().any(|(name, _, _)| *name == r.platform) {
                seen.push((r.platform.clone(), Arc::clone(c), c.stats()));
            }
        }

        let idx: Vec<usize> = (0..reqs.len()).collect();
        let results = par::par_map_heavy(&idx, |&i| solve_one(&caches[i], &reqs[i]));
        let reports = results.into_iter().collect::<Result<Vec<_>>>()?;

        let stats = seen
            .into_iter()
            .map(|(name, cache, before)| (name, cache.stats().since(&before)))
            .collect();
        Ok(BatchReport { reports, stats, wall_ms: t0.elapsed().as_secs_f64() * 1e3 })
    }

    /// Lifetime hit/miss totals per attached platform, sorted by name.
    pub fn cache_stats(&self) -> Vec<(String, CacheStats)> {
        let map = self.platforms.read().expect("platform map poisoned");
        let mut out: Vec<(String, CacheStats)> =
            map.iter().map(|(name, c)| (name.clone(), c.stats())).collect();
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }
}

fn solve_one(cache: &CostCache<'static>, req: &SelectionRequest) -> Result<SelectionReport> {
    let t0 = Instant::now();
    let selection = match req.objective {
        Objective::MinTime => selection::select(&req.network, cache)?,
        Objective::MinTimeWithMemoryBudget { budget_bytes, lambda_ms_per_mb } => {
            memory::select_with_budget(&req.network, cache, budget_bytes, lambda_ms_per_mb)?
        }
    };
    let evaluated_ms = selection::evaluate(&req.network, &selection, cache)?;
    let peak_workspace_bytes = memory::peak_workspace(&req.network, &selection);
    Ok(SelectionReport {
        network: req.network.name.clone(),
        platform: req.platform.clone(),
        objective: req.objective,
        selection,
        evaluated_ms,
        peak_workspace_bytes,
        wall_ms: t0.elapsed().as_secs_f64() * 1e3,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::networks;
    use crate::simulator::{machine, Simulator};

    #[test]
    fn unknown_platform_is_an_error() {
        let coord = Coordinator::new();
        let req = SelectionRequest::new(networks::alexnet(), "riscv");
        assert!(coord.submit(&req).is_err());
        assert!(coord.submit_batch(&[req]).is_err());
    }

    #[test]
    fn submit_matches_direct_selection() {
        let coord = Coordinator::new();
        let net = networks::vgg(11);
        let rep = coord.submit(&SelectionRequest::new(net.clone(), "amd")).unwrap();
        let sim = Simulator::new(machine::amd_a10_7850k());
        let direct = selection::select(&net, &sim).unwrap();
        assert_eq!(rep.selection.primitive, direct.primitive);
        assert_eq!(rep.selection.estimated_ms, direct.estimated_ms);
        assert_eq!(rep.evaluated_ms, selection::evaluate(&net, &direct, &sim).unwrap());
        assert_eq!(rep.platform, "amd");
    }

    #[test]
    fn register_overrides_builtin_resolution() {
        let coord = Coordinator::new();
        // "edge-tpu" is not a built-in name; registering any source
        // makes it servable
        let sim = Arc::new(Simulator::new(machine::arm_cortex_a73()));
        coord.register("edge-tpu", sim);
        let rep = coord.submit(&SelectionRequest::new(networks::alexnet(), "edge-tpu")).unwrap();
        assert!(rep.evaluated_ms > 0.0);
        let stats = coord.cache_stats();
        assert_eq!(stats.len(), 1);
        assert_eq!(stats[0].0, "edge-tpu");
        assert!(stats[0].1.lookups() > 0);
    }

    #[test]
    fn batch_shares_one_cache_per_platform() {
        let coord = Coordinator::new();
        let net = networks::alexnet();
        let reqs: Vec<SelectionRequest> =
            (0..6).map(|_| SelectionRequest::new(net.clone(), "intel")).collect();
        let batch = coord.submit_batch(&reqs).unwrap();
        assert_eq!(batch.reports.len(), 6);
        assert_eq!(batch.stats.len(), 1);
        let (_, s) = &batch.stats[0];
        // six identical networks share rows: every request's evaluate
        // pass re-reads keys its build pass inserted, so hits can never
        // fall below misses even under the worst cold-key races
        assert!(s.row_hits >= s.row_misses, "{s:?}");
        assert!(s.row_hits > 0, "{s:?}");
        for w in batch.reports.windows(2) {
            assert_eq!(w[0].selection.primitive, w[1].selection.primitive);
            assert_eq!(w[0].evaluated_ms, w[1].evaluated_ms);
        }
    }

    #[test]
    fn memory_budget_objective_is_respected() {
        let coord = Coordinator::new();
        let net = networks::vgg(11);
        let free = coord.submit(&SelectionRequest::new(net.clone(), "arm")).unwrap();
        let tight = coord
            .submit(&SelectionRequest::new(net, "arm").with_objective(
                Objective::MinTimeWithMemoryBudget {
                    budget_bytes: free.peak_workspace_bytes * 0.1,
                    lambda_ms_per_mb: 50.0,
                },
            ))
            .unwrap();
        assert!(tight.peak_workspace_bytes < free.peak_workspace_bytes);
        assert!(tight.evaluated_ms >= free.evaluated_ms);
    }
}

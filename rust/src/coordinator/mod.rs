//! # The multi-tenant selection service
//!
//! The paper's economics ("hours to just seconds", Table 4) only pay off
//! at scale if one warm cost table serves *many* selection requests. This
//! module is that serving layer: a [`Coordinator`] owns one long-lived,
//! shared [`CostCache`] per platform and answers batches of selection
//! requests — network × platform × [`Objective`] — concurrently over
//! them.
//!
//! ```text
//!              submit_batch(&[SelectionRequest])
//!                             |
//!                        Coordinator ── par::par_map_heavy ──► workers
//!                     /       |       \                    (1 request
//!           CostCache(intel)  |   CostCache(arm-lin) …       per job)
//!                     |       |             |
//!             Simulator   TableSource   ModeledSource ── CostModel
//!            (measured)   (persisted)   (predicted — onboarded via
//!                                        onboard_platform)
//! ```
//!
//! Every request for a platform routes through that platform's shared
//! cache ([`CostCache`] is `Send + Sync`, sharded internally), so the
//! first request to touch a layer config profiles — or *predicts* — it
//! and every later request gets a hash lookup. Results are bit-identical
//! to solving each request alone with a fresh cache (pinned by
//! `rust/tests/concurrency.rs`): sources are deterministic, and the
//! cache stores exactly what the source returned.
//!
//! ## Where platforms come from
//!
//! * **Built-in simulator platforms** (`"intel"`, `"amd"`, `"arm"`)
//!   resolve on demand via [`machine::by_name`](crate::simulator::machine::by_name).
//! * **Arbitrary sources** attach under any name with
//!   [`Coordinator::register`] — e.g. a persisted
//!   [`TableSource`](crate::selection::TableSource) reloaded from
//!   `artifacts/tables/`.
//! * **Model-served platforms** are created by
//!   [`Coordinator::onboard_platform`]: draw a small calibration sample
//!   from a target source, train a fresh
//!   [`LinCostModel`](crate::perfmodel::LinCostModel) (or §4.4
//!   factor-correct an existing source-platform model), and serve its
//!   predictions through a [`ModeledSource`](crate::selection::ModeledSource)
//!   — the paper's profiling→model swap as a service operation.
//!
//! Each [`SelectionReport`] says which kind answered via
//! [`CostProvenance`]; each [`BatchReport`] carries per-platform
//! [`CacheStats`] deltas, so a serving process can watch its hit rate
//! climb as tenants repeat layer shapes — the `serve_zoo` example prints
//! exactly that trajectory.
//!
//! ## Pareto-front serving
//!
//! Budget-shaped questions are answered from the full time×space
//! trade-off curve instead of fresh solves: the coordinator computes
//! the [`ParetoFront`] for a (platform, network) pair lazily — one
//! budget sweep over a reused PBQP arena (see
//! [`selection::pareto`](crate::selection::pareto)) — and caches it
//! keyed by platform and network fingerprint. The front-served
//! objectives [`Objective::FastestUnderBytes`] and
//! [`Objective::SmallestWithinPct`] are pure lookups on that curve
//! (zero PBQP solves when warm), and the [`SelectionReport`] carries a
//! [`FrontLookup`] saying which point answered and whether the front
//! was cached. Every platform update — [`Coordinator::register`],
//! [`Coordinator::onboard_platform`],
//! [`Coordinator::recalibrate_platform`], and the health loop's
//! auto-recalibration — swaps the platform's serving cache, which
//! expires its cached fronts in the same stroke: front slots remember
//! the exact cache `Arc` they were computed over and only serve while
//! it is still the platform's current one.
//!
//! ## Compiled selection plans
//!
//! The solve-served objectives ([`Objective::MinTime`] and
//! [`Objective::MinTimeWithMemoryBudget`]) are answered through a
//! second per-(platform, network fingerprint) cache: a compiled
//! [`SelectionPlan`](crate::selection::SelectionPlan) freezing the
//! layer/choice topology, the DLT edge matrices and the unpenalised
//! times in flat arenas plus a
//! [`ReusableSolver`](crate::pbqp::ReusableSolver) elimination
//! template. A warm request does **zero graph construction, zero
//! per-layer cache lookups and zero steady-state heap allocation**:
//! the solve runs out of a per-worker thread-local
//! [`PlanScratch`](crate::selection::PlanScratch), and freezing the
//! times is sound because a plan slot — like a front slot — remembers
//! the exact cache `Arc` it was compiled over, and cache rows are
//! immutable within a generation. Plans invalidate through the same
//! single [`Coordinator::register`]/onboard/recalibrate funnel as
//! fronts, and warm results are bit-identical to the cold
//! [`selection::select`] path by construction (pinned differentially
//! in `rust/tests/plan.rs`). Callers that don't need the report's
//! name strings can ask for [`ReportDetail::Minimal`] and render them
//! lazily with [`SelectionReport::render`] — the service workers do.

use crate::dataset::{self, calibration_sample};
use crate::health::{self, HealthMonitor, HealthPolicy, PlatformHealth, PlatformMonitor};
use crate::networks::Network;
use crate::obs;
use crate::par;
use crate::perfmodel::model::{CostModel, FactorCorrected, LinCostModel};
use crate::perfmodel::transfer::{robust_factors, MIN_CALIB_RATIOS};
use crate::selection::pareto::DEFAULT_LAMBDA_MS_PER_MB;
use crate::selection::{
    self, CacheStats, CostCache, CostSource, ModeledSource, ParetoFront, PlanScratch,
    Selection, SelectionPlan, TableSource,
};
use crate::simulator::{machine, Simulator};
use crate::sync;
use anyhow::{anyhow, ensure, Result};
use std::cell::RefCell;
use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::fmt;
use std::hash::{Hash, Hasher};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Instant;

/// What a tenant wants minimised.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Objective {
    /// Plain fastest network: the paper's PBQP objective.
    MinTime,
    /// Time plus a soft per-layer workspace penalty (TASO-style): layers
    /// whose primitive overshoots `budget_bytes` are charged
    /// `lambda_ms_per_mb` per overshot MiB in the PBQP objective.
    MinTimeWithMemoryBudget {
        budget_bytes: f64,
        lambda_ms_per_mb: f64,
    },
    /// Fastest assignment whose peak workspace fits under `budget_bytes`
    /// — answered by lookup on the platform's cached time×space
    /// [`ParetoFront`], not a fresh solve. Errors if even the leanest
    /// front point exceeds the budget (a hard constraint, unlike the
    /// soft [`Objective::MinTimeWithMemoryBudget`] penalty).
    FastestUnderBytes { budget_bytes: f64 },
    /// Smallest-footprint assignment within `pct_of_optimal_time`
    /// percent of the unconstrained optimum time — answered by front
    /// lookup. `0.0` returns the fastest point; larger slack admits
    /// leaner points.
    SmallestWithinPct { pct_of_optimal_time: f64 },
}

impl Objective {
    /// Short human-readable tag for report tables.
    pub fn tag(&self) -> String {
        match self {
            Objective::MinTime => "time".to_string(),
            Objective::MinTimeWithMemoryBudget { budget_bytes, .. } => {
                format!("time|{:.0}MiB", budget_bytes / (1024.0 * 1024.0))
            }
            Objective::FastestUnderBytes { budget_bytes } => {
                if budget_bytes.is_finite() {
                    format!("fastest|{:.0}MiB", budget_bytes / (1024.0 * 1024.0))
                } else {
                    "fastest|unbounded".to_string()
                }
            }
            Objective::SmallestWithinPct { pct_of_optimal_time } => {
                format!("smallest|+{pct_of_optimal_time:.0}%")
            }
        }
    }

    /// Whether this objective is answered by Pareto-front lookup instead
    /// of a fresh PBQP solve.
    pub fn is_front_served(&self) -> bool {
        matches!(
            self,
            Objective::FastestUnderBytes { .. } | Objective::SmallestWithinPct { .. }
        )
    }
}

/// What kind of cost source answered a request — measured (profiler /
/// simulator / precomputed measured tables) or a trained model's
/// predictions.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CostProvenance {
    /// Costs come from measurement (the paper's baseline flow).
    Measured,
    /// Costs come from a performance model (the paper's contribution).
    Predicted {
        /// The model-kind tag ("lin", "lin+factor", "nn2", ...).
        model_kind: String,
        /// Calibration rows the model saw from this platform.
        calib_samples: usize,
    },
}

/// How much of a [`SelectionReport`] to assemble eagerly. The numeric
/// fields are always exact; only the name strings are optional, because
/// they are the one part of a warm report that costs heap allocations.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ReportDetail {
    /// Fill every field, names included (the default).
    #[default]
    Full,
    /// Leave [`SelectionReport::network`] and
    /// [`SelectionReport::platform`] empty; callers that end up needing
    /// them render lazily with [`SelectionReport::render`]. The service
    /// workers request this so the warm fast path allocates nothing for
    /// requests whose tenants only read the numbers.
    Minimal,
}

/// One tenant request: optimise `network` for `platform` under
/// `objective`.
#[derive(Debug, Clone)]
pub struct SelectionRequest {
    pub network: Network,
    pub platform: String,
    pub objective: Objective,
    /// How much of the report to assemble eagerly (default
    /// [`ReportDetail::Full`]).
    pub detail: ReportDetail,
    /// Optional per-request trace: when set, the serving stack marks
    /// per-stage timestamps into it (heap-free atomic stores, so the
    /// instrumented warm path stays zero-alloc). `Service::admit`
    /// attaches one automatically; direct callers opt in with
    /// [`SelectionRequest::with_trace`].
    pub trace: Option<obs::Trace>,
}

impl SelectionRequest {
    /// A plain min-time request.
    pub fn new(network: Network, platform: &str) -> Self {
        Self {
            network,
            platform: platform.to_string(),
            objective: Objective::MinTime,
            detail: ReportDetail::Full,
            trace: None,
        }
    }

    /// Override the objective (builder style).
    pub fn with_objective(mut self, objective: Objective) -> Self {
        self.objective = objective;
        self
    }

    /// Override the report detail (builder style).
    pub fn with_detail(mut self, detail: ReportDetail) -> Self {
        self.detail = detail;
        self
    }

    /// Attach a fresh [`obs::Trace`] (builder style).
    pub fn with_trace(mut self) -> Self {
        self.trace = Some(obs::Trace::begin());
        self
    }
}

/// How a front-served request was answered: which [`ParetoFront`] point
/// was chosen and whether the front came from the coordinator's cache.
#[derive(Debug, Clone, PartialEq)]
pub struct FrontLookup {
    /// Budget level (bytes) the chosen point was swept at.
    pub budget_bytes: f64,
    /// Peak workspace (bytes) of the chosen point.
    pub peak_workspace_bytes: f64,
    /// True time (ms) of the chosen point.
    pub true_time_ms: f64,
    /// `true` when the front was already cached (zero PBQP solves for
    /// this request); `false` when this request computed it.
    pub cache_hit: bool,
    /// Number of non-dominated points on the front consulted.
    pub front_points: usize,
}

/// The answer to one [`SelectionRequest`].
#[derive(Debug, Clone)]
pub struct SelectionReport {
    pub network: String,
    pub platform: String,
    pub objective: Objective,
    /// Whether this platform's costs are measured or model-predicted.
    pub provenance: CostProvenance,
    /// The chosen primitive per layer plus the objective value.
    pub selection: Selection,
    /// Plain network time of the chosen assignment under the platform's
    /// cost source (no penalty terms), for like-for-like comparison
    /// across objectives.
    pub evaluated_ms: f64,
    /// Peak per-layer workspace of the chosen assignment.
    pub peak_workspace_bytes: f64,
    /// For front-served objectives ([`Objective::is_front_served`]): the
    /// [`ParetoFront`] point chosen and whether the front was a cache
    /// hit. `None` for solve-served objectives.
    pub front: Option<FrontLookup>,
    /// Wall-clock this request spent inside its worker.
    pub wall_ms: f64,
    /// The request's completed trace (a detached copy of the marks), when
    /// the request carried one. Spans: [`obs::Stage::Admit`] →
    /// [`obs::Stage::Done`].
    pub trace: Option<obs::Trace>,
}

impl SelectionReport {
    /// Fill the name strings from the originating request — the lazy
    /// half of a [`ReportDetail::Minimal`] report, run only once the
    /// report is actually handed to something that reads names. Safe
    /// (and idempotent) on a [`ReportDetail::Full`] report too.
    pub fn render(mut self, req: &SelectionRequest) -> SelectionReport {
        self.network.clear();
        self.network.push_str(&req.network.name);
        self.platform.clear();
        self.platform.push_str(&req.platform);
        self
    }
}

/// The answer to one [`Coordinator::submit_batch`] call.
#[derive(Debug, Clone)]
pub struct BatchReport {
    /// One report per request, in request order.
    pub reports: Vec<SelectionReport>,
    /// Per-platform cache hit/miss deltas over this batch's time window,
    /// in order of first appearance in the request slice. Deltas are
    /// computed from the caches' lifetime counters, so they are exact
    /// when batches on this coordinator don't overlap; if another
    /// `submit`/`submit_batch` runs concurrently on the same platform,
    /// its traffic lands in the same window and is counted here too.
    pub stats: Vec<(String, CacheStats)>,
    /// Wall-clock of the whole batch (fan-out included).
    pub wall_ms: f64,
}

/// How [`Coordinator::onboard_platform`] turns a calibration sample into
/// a served model.
pub enum OnboardMode {
    /// Fit a fresh [`LinCostModel`] on the calibration sample alone —
    /// closed form, offline, no source platform needed.
    FreshLin,
    /// §4.4 transfer: keep a source-platform model's shape, correct its
    /// per-column scale from the calibration sample
    /// ([`FactorCorrected`]).
    Transfer(Arc<dyn CostModel + Send + Sync>),
}

/// Everything [`Coordinator::onboard_platform`] needs to know about the
/// new platform.
pub struct OnboardSpec {
    /// The device being onboarded, behind the same [`CostSource`]
    /// interface everything else uses (simulator stand-in, real
    /// profiler, ...). Queried only for the calibration sample — and for
    /// ground truth when `validate` is non-empty.
    pub target: Arc<dyn CostSource>,
    /// Fraction of the canonical config universe to calibrate on (the
    /// paper operates at ~0.01–0.02).
    pub calib_fraction: f64,
    /// Seed for the calibration draw.
    pub seed: u64,
    pub mode: OnboardMode,
    /// Networks to validate on: each gets a predicted-vs-simulated
    /// wallclock comparison in the [`OnboardReport`] (costs extra target
    /// queries; pass an empty vec to skip).
    pub validate: Vec<Network>,
}

impl OnboardSpec {
    /// A fresh-Lin spec with no validation.
    pub fn fresh_lin(target: Arc<dyn CostSource>, calib_fraction: f64, seed: u64) -> Self {
        Self { target, calib_fraction, seed, mode: OnboardMode::FreshLin, validate: Vec::new() }
    }

    /// A §4.4 transfer spec with no validation.
    pub fn transfer(
        target: Arc<dyn CostSource>,
        source_model: Arc<dyn CostModel + Send + Sync>,
        calib_fraction: f64,
        seed: u64,
    ) -> Self {
        Self {
            target,
            calib_fraction,
            seed,
            mode: OnboardMode::Transfer(source_model),
            validate: Vec::new(),
        }
    }

    /// Request validation networks (builder style).
    pub fn with_validation(mut self, nets: Vec<Network>) -> Self {
        self.validate = nets;
        self
    }
}

/// Predicted-vs-simulated quality of one validation network.
#[derive(Debug, Clone)]
pub struct OnboardValidation {
    pub network: String,
    /// The model's own estimate of its chosen assignment (ms).
    pub predicted_ms: f64,
    /// The model-chosen assignment evaluated under the target source (ms).
    pub simulated_ms: f64,
    /// The target-profiled optimal assignment's time (ms).
    pub profiled_ms: f64,
    /// `simulated_ms / profiled_ms - 1` — the paper's Fig. 7/8 metric.
    pub increase: f64,
    /// Fraction of layers where model and profiled selection agree on
    /// the primitive.
    pub agreement: f64,
}

/// What [`Coordinator::onboard_platform`] did.
#[derive(Debug, Clone)]
pub struct OnboardReport {
    pub platform: String,
    pub model_kind: String,
    /// Calibration rows drawn from the target.
    pub calib_samples: usize,
    pub provenance: CostProvenance,
    /// One entry per requested validation network.
    pub validation: Vec<OnboardValidation>,
    /// Wall-clock of the whole onboarding (sampling + fit + validation).
    pub wall_ms: f64,
}

/// Which refresh [`Coordinator::recalibrate_platform`] ran for the
/// platform's onboarding kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecalPath {
    /// §4.4 factor refresh: the retained source model is untouched, only
    /// the per-column scale factors are re-estimated.
    TransferFactors,
    /// Full closed-form refit of the fresh-Lin model from the new draw.
    FreshLinRefit,
}

impl fmt::Display for RecalPath {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            RecalPath::TransferFactors => "transfer-factors",
            RecalPath::FreshLinRefit => "fresh-lin-refit",
        })
    }
}

/// What [`Coordinator::recalibrate_platform`] did.
#[derive(Debug, Clone)]
pub struct RecalibrationReport {
    pub platform: String,
    /// Fresh calibration rows drawn from the target.
    pub calib_samples: usize,
    /// The platform's provenance after the refresh.
    pub provenance: CostProvenance,
    /// Largest relative change the refresh caused: for transfer
    /// platforms, across all refreshed scale factors (per-primitive
    /// columns and DLT cells); for fresh-Lin platforms, across old-vs-new
    /// model predictions on the fresh draw. In both cases
    /// `max_j |new_j / old_j - 1|` — how far the platform had drifted
    /// since the previous calibration.
    pub max_factor_shift: f64,
    /// Which refresh ran (factor rescale vs full Lin refit).
    pub path: RecalPath,
    /// Wall-clock of the refresh (sampling + refit + cache rebuild).
    pub wall_ms: f64,
}

/// The model state a recalibration refreshes, per onboarding kind.
enum RecalMode {
    /// §4.4 transfer: the (untouched) source model plus the factor set
    /// currently serving.
    Transfer {
        base: Arc<dyn CostModel + Send + Sync>,
        current: Arc<FactorCorrected>,
    },
    /// Fresh-Lin: the Lin model currently serving (refit wholesale on
    /// recalibration — closed form, so a full refit costs the same as a
    /// factor pass).
    FreshLin { current: Arc<LinCostModel> },
}

/// What a model-onboarded platform keeps around so it can be
/// recalibrated in place later: the target device to draw fresh
/// measurements from, plus the per-kind model state.
struct RecalContext {
    target: Arc<dyn CostSource>,
    mode: RecalMode,
}

/// One served platform: its shared cache plus where its costs come from.
struct PlatformEntry {
    cache: Arc<CostCache<'static>>,
    provenance: CostProvenance,
    /// Present for every model-onboarded platform (enables
    /// [`Coordinator::recalibrate_platform`]); absent for measured /
    /// directly-registered sources, which have no model to refresh.
    recal: Option<RecalContext>,
}

/// A cached Pareto front plus the serving cache it was computed over.
/// The cache `Arc` doubles as a validity token: every platform update
/// (register / onboard / recalibrate / health auto-recal) swaps the
/// platform's cache pointer, so a slot whose `cache` is no longer the
/// platform's current one is stale by construction — even if an
/// invalidation raced an in-flight compute (see
/// [`Coordinator::front_for`]).
struct FrontSlot {
    cache: Arc<CostCache<'static>>,
    front: Arc<ParetoFront>,
}

/// A compiled [`SelectionPlan`] plus the serving cache it was compiled
/// over — the same generation-token pattern as [`FrontSlot`]: the slot
/// only serves while its `cache` is still the platform's current one,
/// which is also what makes the plan's *frozen times* sound (rows are
/// immutable within a cache generation).
struct PlanSlot {
    cache: Arc<CostCache<'static>>,
    plan: Arc<SelectionPlan>,
}

/// The serving layer: per-platform shared caches plus batch fan-out and
/// model-served platform onboarding.
///
/// ```
/// use primsel::coordinator::{Coordinator, Objective, SelectionRequest};
/// use primsel::networks;
///
/// let coord = Coordinator::new();
/// let batch = vec![
///     SelectionRequest::new(networks::alexnet(), "intel"),
///     SelectionRequest::new(networks::vgg(11), "arm"),
///     SelectionRequest::new(networks::alexnet(), "intel").with_objective(
///         Objective::MinTimeWithMemoryBudget {
///             budget_bytes: 4.0 * 1024.0 * 1024.0,
///             lambda_ms_per_mb: 10.0,
///         },
///     ),
/// ];
/// let report = coord.submit_batch(&batch).unwrap();
/// assert_eq!(report.reports.len(), 3);
/// for (req, rep) in batch.iter().zip(&report.reports) {
///     assert_eq!(rep.network, req.network.name);
///     assert_eq!(rep.selection.primitive.len(), req.network.n_layers());
///     assert!(rep.evaluated_ms > 0.0);
/// }
/// // both intel requests shared one warm cache
/// assert_eq!(report.stats[0].0, "intel");
/// ```
pub struct Coordinator {
    platforms: RwLock<HashMap<String, Arc<PlatformEntry>>>,
    /// Per-platform drift monitors (see [`crate::health`]); empty until
    /// [`Self::monitor_platform`] attaches one.
    health: HealthMonitor,
    /// Lazily computed time×space Pareto fronts, keyed by
    /// (platform, network fingerprint). Entries expire when the
    /// platform's serving cache is replaced (see [`FrontSlot`]).
    fronts: RwLock<HashMap<(String, u64), FrontSlot>>,
    /// Lifetime front-cache hits (warm lookups, zero PBQP solves).
    front_hits: AtomicU64,
    /// Lifetime front-cache misses (each one computed a front).
    front_misses: AtomicU64,
    /// Compiled selection plans, keyed like [`Self::fronts`] and expired
    /// by the same cache swap (see [`PlanSlot`]).
    plans: RwLock<HashMap<(String, u64), PlanSlot>>,
    /// Lifetime plan-cache hits (warm solves: zero graph builds, zero
    /// cache lookups).
    plan_hits: AtomicU64,
    /// Lifetime plan-cache misses (each one compiled a plan).
    plan_misses: AtomicU64,
    /// Cached handles into the process-wide metrics registry (resolved
    /// once here so the warm select path records lock-free).
    obs: CoordObs,
}

/// Registry handles the coordinator records into on the hot path.
struct CoordObs {
    /// `primsel.trace.stage_ms{stage="solve"}`: SolveStart → SolveEnd.
    solve_ms: obs::Histogram,
}

impl CoordObs {
    fn resolve() -> Self {
        Self {
            solve_ms: obs::registry().histogram(obs::names::STAGE_MS, &[("stage", "solve")]),
        }
    }
}

impl Default for Coordinator {
    fn default() -> Self {
        Self::new()
    }
}

impl Coordinator {
    /// An empty coordinator; platform caches attach on first use.
    pub fn new() -> Self {
        Self {
            platforms: RwLock::new(HashMap::new()),
            health: HealthMonitor::default(),
            fronts: RwLock::new(HashMap::new()),
            front_hits: AtomicU64::new(0),
            front_misses: AtomicU64::new(0),
            plans: RwLock::new(HashMap::new()),
            plan_hits: AtomicU64::new(0),
            plan_misses: AtomicU64::new(0),
            obs: CoordObs::resolve(),
        }
    }

    /// An empty coordinator behind an [`Arc`] — the shutdown-safe shared
    /// handle the serving layer ([`crate::service::Service`]) builds on:
    /// worker threads hold clones, so the platform caches outlive any
    /// one service (or batch) and survive service shutdown intact.
    pub fn shared() -> Arc<Self> {
        Arc::new(Self::new())
    }

    /// Attach a custom cost source (a persisted table, a measured
    /// profiler…) under `platform`. Replaces any existing cache for that
    /// name, resetting its memoized rows and stats. The platform is
    /// reported as [`CostProvenance::Measured`]; model-served platforms
    /// go through [`Self::onboard_platform`] instead.
    pub fn register(&self, platform: &str, source: Arc<dyn CostSource>) {
        self.register_with_provenance(platform, source, CostProvenance::Measured);
    }

    /// [`Self::register`] with an explicit [`CostProvenance`] — how a
    /// *predicted* table reloaded from disk keeps reporting
    /// `Predicted{..}` after a restart instead of silently becoming
    /// `Measured` (see [`Self::persist_table`]).
    pub fn register_with_provenance(
        &self,
        platform: &str,
        source: Arc<dyn CostSource>,
        provenance: CostProvenance,
    ) {
        self.insert(platform, Arc::new(CostCache::new_shared(source)), provenance, None);
    }

    fn insert(
        &self,
        platform: &str,
        cache: Arc<CostCache<'static>>,
        provenance: CostProvenance,
        recal: Option<RecalContext>,
    ) {
        let entry = Arc::new(PlatformEntry { cache, provenance, recal });
        sync::write(&self.platforms).insert(platform.to_string(), entry);
        // every platform update funnels through here — register, onboard,
        // recalibrate (explicit or health-loop), quarantine probe — so
        // this is the single place cached fronts and compiled plans go
        // stale, and the single place they are dropped
        self.invalidate_fronts(platform);
        self.invalidate_plans(platform);
    }

    /// Drop every cached Pareto front for `platform` (they were computed
    /// over a cache that is no longer serving).
    fn invalidate_fronts(&self, platform: &str) {
        sync::write(&self.fronts).retain(|(p, _), _| p != platform);
    }

    /// Drop every compiled plan for `platform` (they froze times out of
    /// a cache that is no longer serving).
    fn invalidate_plans(&self, platform: &str) {
        sync::write(&self.plans).retain(|(p, _), _| p != platform);
    }

    /// Onboard a new platform from a handful of calibration samples
    /// (paper §4.4 as a service operation): draw `spec.calib_fraction`
    /// of the canonical config universe from `spec.target`, fit or
    /// transfer-adapt a model, validate if requested, and serve the
    /// model's predictions under `platform` (provenance
    /// [`CostProvenance::Predicted`]).
    pub fn onboard_platform(&self, platform: &str, spec: OnboardSpec) -> Result<OnboardReport> {
        let t0 = Instant::now();
        ensure!(
            spec.calib_fraction > 0.0 && spec.calib_fraction <= 1.0,
            "calib_fraction must be in (0, 1], got {}",
            spec.calib_fraction
        );
        let (prim, dlt) = calibration_sample(spec.target.as_ref(), spec.calib_fraction, spec.seed);
        let calib_samples = prim.len();

        let (model, recal): (Arc<dyn CostModel + Send + Sync>, RecalContext) = match spec.mode {
            OnboardMode::FreshLin => {
                let lin = Arc::new(LinCostModel::fit(&prim, &dlt, platform)?);
                let ctx = RecalContext {
                    target: Arc::clone(&spec.target),
                    mode: RecalMode::FreshLin { current: Arc::clone(&lin) },
                };
                (lin, ctx)
            }
            OnboardMode::Transfer(source) => {
                let fc = Arc::new(FactorCorrected::fit(Arc::clone(&source), &prim, &dlt)?);
                let ctx = RecalContext {
                    target: Arc::clone(&spec.target),
                    mode: RecalMode::Transfer { base: source, current: Arc::clone(&fc) },
                };
                (fc, ctx)
            }
        };
        let model_kind = model.kind().to_string();
        // the long-lived serving cache is built up front so the
        // validation pass below warms it — the first tenant requests for
        // a validated platform are hash lookups, not re-predictions
        let cache: Arc<CostCache<'static>> =
            Arc::new(CostCache::new_shared(Arc::new(ModeledSource::new(model))));

        let mut validation = Vec::new();
        if !spec.validate.is_empty() {
            let modeled = cache.as_ref();
            let target = CostCache::new(spec.target.as_ref());
            for net in &spec.validate {
                let sel_model = selection::select(net, modeled)?;
                let sel_prof = selection::select(net, &target)?;
                let simulated_ms = selection::evaluate(net, &sel_model, &target)?;
                let profiled_ms = selection::evaluate(net, &sel_prof, &target)?;
                let agree = sel_model
                    .primitive
                    .iter()
                    .zip(&sel_prof.primitive)
                    .filter(|(a, b)| a == b)
                    .count();
                validation.push(OnboardValidation {
                    network: net.name.clone(),
                    predicted_ms: sel_model.estimated_ms,
                    simulated_ms,
                    profiled_ms,
                    increase: simulated_ms / profiled_ms - 1.0,
                    agreement: agree as f64 / net.n_layers() as f64,
                });
            }
        }

        let provenance =
            CostProvenance::Predicted { model_kind: model_kind.clone(), calib_samples };
        self.insert(platform, cache, provenance.clone(), Some(recal));
        Ok(OnboardReport {
            platform: platform.to_string(),
            model_kind,
            calib_samples,
            provenance,
            validation,
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
        })
    }

    /// Refresh a model-onboarded platform's serving model in place from
    /// a *fresh* measurement draw — the online-recalibration half of the
    /// onboarding lifecycle, for **both** onboarding kinds (the
    /// [`RecalibrationReport::path`] says which ran):
    ///
    /// * **transfer platforms** get new §4.4 per-column scale factors
    ///   without retraining (or even touching) the source model, because
    ///   [`FactorCorrected`] isolates all platform-specific state in the
    ///   factors;
    /// * **fresh-Lin platforms** get a wholesale Lin refit from the new
    ///   draw — the fit is closed form, so a full refit costs the same
    ///   as a factor pass and the drift loop covers every onboarded
    ///   platform kind.
    ///
    /// The platform's serving cache is re-registered (a rebuilt
    /// [`ModeledSource`] cache), dropping every memoized prediction made
    /// under the stale model; provenance keeps reporting
    /// `Predicted { .., calib_samples }` with the *new* sample count.
    /// Errors for platforms that are unknown, or measured / directly
    /// registered (no model state to refresh).
    pub fn recalibrate_platform(
        &self,
        platform: &str,
        calib_fraction: f64,
        seed: u64,
    ) -> Result<RecalibrationReport> {
        let t0 = Instant::now();
        ensure!(
            calib_fraction > 0.0 && calib_fraction <= 1.0,
            "calib_fraction must be in (0, 1], got {calib_fraction}"
        );
        let entry = sync::read(&self.platforms)
            .get(platform)
            .cloned()
            .ok_or_else(|| anyhow!("unknown platform {platform:?}: nothing to recalibrate"))?;
        let ctx = entry.recal.as_ref().ok_or_else(|| {
            anyhow!(
                "platform {platform:?} was not model-onboarded; measured and \
                 directly-registered platforms carry no recalibratable model state"
            )
        })?;

        let (prim, dlt) = calibration_sample(ctx.target.as_ref(), calib_fraction, seed);
        let calib_samples = prim.len();

        let (model, next_mode, max_factor_shift, path): (
            Arc<dyn CostModel + Send + Sync>,
            RecalMode,
            f64,
            RecalPath,
        ) = match &ctx.mode {
            RecalMode::Transfer { base, current } => {
                let fresh = Arc::new(FactorCorrected::fit(Arc::clone(base), &prim, &dlt)?);
                // drift over BOTH scale surfaces the refresh replaces:
                // primitive columns and DLT cells (a device can drift in
                // its layout transforms while per-primitive costs hold
                // steady)
                let old_dlt = current.dlt_factors().iter().flatten();
                let new_dlt = fresh.dlt_factors().iter().flatten();
                let shift = current
                    .prim_factors()
                    .iter()
                    .zip(fresh.prim_factors())
                    .chain(old_dlt.zip(new_dlt))
                    .filter(|(&old, _)| old > 0.0)
                    .map(|(&old, &new)| (new / old - 1.0).abs())
                    .fold(0.0f64, f64::max);
                (
                    Arc::clone(&fresh) as Arc<dyn CostModel + Send + Sync>,
                    RecalMode::Transfer { base: Arc::clone(base), current: fresh },
                    shift,
                    RecalPath::TransferFactors,
                )
            }
            RecalMode::FreshLin { current } => {
                let fresh = Arc::new(LinCostModel::fit(&prim, &dlt, platform)?);
                // a refit has no factor set to diff, so the shift is
                // measured where it matters: old-vs-new predictions on
                // the fresh draw's configs (and its DLT pairs), through
                // the same robust median the factor path uses
                let prim_shift = prediction_shift(
                    &current.predict_prim(&prim.configs)?,
                    &fresh.predict_prim(&prim.configs)?,
                );
                let dlt_shift = prediction_shift(
                    &flatten_off_diagonal(&current.predict_dlt(&dlt.pairs)?),
                    &flatten_off_diagonal(&fresh.predict_dlt(&dlt.pairs)?),
                );
                (
                    Arc::clone(&fresh) as Arc<dyn CostModel + Send + Sync>,
                    RecalMode::FreshLin { current: fresh },
                    prim_shift.max(dlt_shift),
                    RecalPath::FreshLinRefit,
                )
            }
        };

        let provenance =
            CostProvenance::Predicted { model_kind: model.kind().to_string(), calib_samples };
        let cache: Arc<CostCache<'static>> =
            Arc::new(CostCache::new_shared(Arc::new(ModeledSource::new(model))));
        let next_ctx = RecalContext { target: Arc::clone(&ctx.target), mode: next_mode };
        self.insert(platform, cache, provenance.clone(), Some(next_ctx));
        Ok(RecalibrationReport {
            platform: platform.to_string(),
            calib_samples,
            provenance,
            max_factor_shift,
            path,
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
        })
    }

    /// Bake the dense serving table for `platform` over `nets` and
    /// persist it as JSON under `artifacts/tables/<platform>.json`, so
    /// an onboarded platform survives a process restart: reload with
    /// [`TableSource::load_json`] and re-attach with
    /// [`Self::register_with_provenance`], passing the original
    /// platform's [`Self::provenance`] (persisted tables carry values,
    /// not provenance — a reloaded predicted table must not come back
    /// claiming `Measured`). Returns the path written.
    pub fn persist_table(&self, platform: &str, nets: &[Network]) -> Result<PathBuf> {
        let path = dataset::table_artifact_path(platform);
        self.persist_table_to(platform, nets, &path)?;
        Ok(path)
    }

    /// [`Self::persist_table`] with an explicit destination path.
    pub fn persist_table_to(
        &self,
        platform: &str,
        nets: &[Network],
        path: &std::path::Path,
    ) -> Result<()> {
        let entry = self.entry(platform)?;
        let mut configs: Vec<crate::layers::ConvConfig> = Vec::new();
        let mut rows = Vec::new();
        let mut keys: Vec<(u32, u32)> = Vec::new();
        for net in nets {
            for cfg in &net.layers {
                // networks repeat layer shapes; one row per distinct
                // config is all the table keeps anyway
                if !configs.contains(cfg) {
                    configs.push(*cfg);
                    rows.push(entry.cache.row(cfg).to_vec());
                }
            }
            keys.extend(net.edges.iter().map(|&(u, v)| (net.layers[u].k, net.layers[v].im)));
        }
        keys.sort_unstable();
        keys.dedup();
        let mats = keys.iter().map(|&(c, im)| entry.cache.matrix(c, im)).collect();
        TableSource::new(configs, rows, keys, mats).save_json(path)
    }

    /// The platform entry, creating a simulator-backed one on first use
    /// for the built-in platform names.
    fn entry(&self, platform: &str) -> Result<Arc<PlatformEntry>> {
        if let Some(e) = sync::read(&self.platforms).get(platform) {
            return Ok(Arc::clone(e));
        }
        let m = machine::by_name(platform).ok_or_else(|| {
            anyhow!(
                "unknown platform {platform:?}: register()/onboard_platform() a source \
                 or use intel/amd/arm"
            )
        })?;
        let entry = Arc::new(PlatformEntry {
            cache: Arc::new(CostCache::new_shared(Arc::new(Simulator::new(m)))),
            provenance: CostProvenance::Measured,
            recal: None,
        });
        let mut map = sync::write(&self.platforms);
        // a racing resolver may have inserted meanwhile; keep the winner
        Ok(Arc::clone(map.entry(platform.to_string()).or_insert(entry)))
    }

    /// The shared cache serving `platform`, creating a simulator-backed
    /// one on first use for the built-in platform names.
    pub fn cache(&self, platform: &str) -> Result<Arc<CostCache<'static>>> {
        Ok(Arc::clone(&self.entry(platform)?.cache))
    }

    /// Where `platform`'s costs come from, if it is attached (or a
    /// built-in name).
    pub fn provenance(&self, platform: &str) -> Result<CostProvenance> {
        Ok(self.entry(platform)?.provenance.clone())
    }

    /// Attach a drift monitor to `platform` (which must already resolve:
    /// built-in, registered, or onboarded): a configurable fraction of
    /// served selections is shadow-replayed against `target` — the live
    /// device, behind the usual [`CostSource`] interface — feeding the
    /// health state machine described in [`crate::health`]. Replaces any
    /// existing monitor for the name, resetting its state.
    ///
    /// ```
    /// use primsel::coordinator::{Coordinator, SelectionRequest};
    /// use primsel::health::{HealthPolicy, HealthState};
    /// use primsel::networks;
    /// use primsel::simulator::{machine, Simulator};
    /// use std::sync::Arc;
    ///
    /// let coord = Coordinator::new();
    /// let live = Arc::new(Simulator::new(machine::intel_i9_9900k()));
    /// coord
    ///     .monitor_platform("intel", live, HealthPolicy::default().with_sampling(1.0, 1))
    ///     .unwrap();
    /// coord.submit(&SelectionRequest::new(networks::alexnet(), "intel")).unwrap();
    /// let health = coord.platform_health();
    /// assert_eq!(health[0].platform, "intel");
    /// // the live source agrees with the served cache: no drift
    /// assert_eq!(health[0].state, HealthState::Healthy);
    /// assert_eq!(health[0].sampled, 1);
    /// ```
    pub fn monitor_platform(
        &self,
        platform: &str,
        target: Arc<dyn CostSource>,
        policy: HealthPolicy,
    ) -> Result<()> {
        policy.validate()?;
        // the platform must be servable before it is monitorable
        let _ = self.entry(platform)?;
        self.health.register(platform, target, policy);
        Ok(())
    }

    /// Health snapshots for every monitored platform, sorted by name
    /// (empty when nothing is monitored).
    pub fn platform_health(&self) -> Vec<PlatformHealth> {
        self.health.snapshot()
    }

    /// The health snapshot for one platform, if it is monitored.
    pub fn platform_health_of(&self, platform: &str) -> Option<PlatformHealth> {
        self.health.get(platform).map(|m| m.snapshot())
    }

    /// Ask `platform`'s health monitor to shadow-sample its next `n`
    /// observations unconditionally, ahead of its deterministic
    /// sampling coin — the ops plane calls this when a Critical drift
    /// alert fires, pulling drift evidence forward instead of waiting
    /// for the coin. Returns whether the platform is monitored.
    pub fn boost_shadow_sampling(&self, platform: &str, n: u64) -> bool {
        self.health.boost(platform, n)
    }

    /// [`Self::boost_shadow_sampling`] for every monitored platform
    /// (Critical latency alerts, where no single platform is implied);
    /// returns how many monitors were nudged.
    pub fn boost_all_shadow_sampling(&self, n: u64) -> usize {
        self.health.boost_all(n)
    }

    /// Run one recalibration attempt for the health loop: any panic from
    /// a faulty target source (the [`CostSource`] trait has no error
    /// channel) is caught and reported as a failure message, never
    /// propagated.
    fn recalibrate_guarded(&self, platform: &str, fraction: f64, seed: u64) -> Result<(), String> {
        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            self.recalibrate_platform(platform, fraction, seed)
        })) {
            Ok(Ok(_report)) => Ok(()),
            Ok(Err(e)) => Err(e.to_string()),
            Err(payload) => {
                Err(format!("recalibration panicked: {}", health::panic_message(payload)))
            }
        }
    }

    /// The monitor's recalibration hook for `platform` (see
    /// [`PlatformMonitor`]): draws with the policy's fraction and a
    /// per-attempt seed so retries see fresh samples.
    fn health_recal<'a>(
        &'a self,
        platform: &'a str,
        mon: &'a PlatformMonitor,
    ) -> impl Fn(u64) -> Result<(), String> + 'a {
        move |attempt| {
            self.recalibrate_guarded(
                platform,
                mon.policy().recalib_fraction,
                mon.attempt_seed(attempt),
            )
        }
    }

    /// The unit of work everything request-shaped funnels through: solve
    /// one request synchronously on the caller's thread. This is what
    /// [`Self::submit_batch`]'s fan-out jobs and the serving layer's
    /// persistent workers ([`service::worker`](crate::service)) each
    /// call per request.
    ///
    /// Solve-served objectives go through the compiled-plan cache: the
    /// first request for a (platform, network) pair compiles a
    /// [`SelectionPlan`] (one graph build through the platform's shared
    /// cache), and every warm repeat solves out of the frozen arenas
    /// with zero graph construction, zero per-layer cache lookups, and
    /// — with [`ReportDetail::Minimal`] — zero steady-state heap
    /// allocation on the solve core. Warm answers are bit-identical to
    /// the cold path.
    ///
    /// ```
    /// use primsel::coordinator::{Coordinator, ReportDetail, SelectionRequest};
    /// use primsel::networks;
    ///
    /// let coord = Coordinator::new();
    /// let req = SelectionRequest::new(networks::alexnet(), "intel");
    /// let cold = coord.select_one(&req).unwrap(); // compiles + caches the plan
    /// let warm = coord.select_one(&req).unwrap(); // plan hit: no graph build
    /// assert_eq!(warm.selection.primitive, cold.selection.primitive);
    /// assert_eq!(warm.evaluated_ms, cold.evaluated_ms);
    /// assert_eq!(coord.plan_cache_stats(), (1, 1));
    ///
    /// // minimal reports skip the name strings; render fills them lazily
    /// let min = coord
    ///     .select_one(&req.clone().with_detail(ReportDetail::Minimal))
    ///     .unwrap();
    /// assert!(min.network.is_empty());
    /// assert_eq!(min.render(&req).network, "alexnet");
    /// ```
    ///
    /// When the platform is monitored ([`Self::monitor_platform`]), the
    /// request passes the health admission gate first — a `Quarantined`
    /// platform refuses immediately with a typed
    /// [`QuarantinedError`](crate::health::QuarantinedError) (recover it
    /// with `err.downcast_ref`), or probes a recalibration if the
    /// cool-down has elapsed — and feeds the monitor's shadow sampler
    /// after solving.
    pub fn select_one(&self, req: &SelectionRequest) -> Result<SelectionReport> {
        if let Some(t) = &req.trace {
            t.mark(obs::Stage::SolveStart);
        }
        let monitor = self.health.get(&req.platform);
        if let Some(mon) = &monitor {
            let recal = self.health_recal(&req.platform, mon);
            mon.admit(&recal).map_err(anyhow::Error::from)?;
        }
        // resolve the entry *after* admission: a successful quarantine
        // probe re-registers the serving cache
        let entry = self.entry(&req.platform)?;
        let mut report = if req.objective.is_front_served() {
            self.solve_via_front(&entry, req)?
        } else {
            self.solve_via_plan(&entry, req)?
        };
        if let Some(mon) = &monitor {
            let recal = self.health_recal(&req.platform, mon);
            mon.observe(&req.network, entry.cache.as_ref(), &recal);
        }
        if let Some(t) = &req.trace {
            t.mark(obs::Stage::SolveEnd);
            if let Some(ns) = t.span_ns(obs::Stage::SolveStart, obs::Stage::SolveEnd) {
                self.obs.solve_ms.record_ns(ns);
            }
            // Service workers own the Done mark and the flight-recorder
            // entry for queued requests; a trace with no Admit mark means
            // a direct caller, so this request completes here.
            if !t.has(obs::Stage::Admit) {
                t.mark(obs::Stage::Done);
                obs::flight_recorder().record_request(
                    t,
                    &req.platform,
                    &req.network.name,
                    "direct",
                );
            }
            report.trace = Some(t.clone());
        }
        Ok(report)
    }

    /// Solve a single request synchronously (alias of
    /// [`Self::select_one`], kept as the one-off entry point's name).
    pub fn submit(&self, req: &SelectionRequest) -> Result<SelectionReport> {
        self.select_one(req)
    }

    /// Solve a batch of requests concurrently: platforms are resolved up
    /// front (so an unknown platform fails before any work is spawned),
    /// then requests fan out one-per-job over [`par::par_map_heavy`],
    /// every job routing through its platform's shared cache. Reports
    /// come back in request order and are bit-identical to solving each
    /// request alone. The returned [`BatchReport::stats`] deltas span
    /// this batch's time window — see their field docs for what that
    /// means when batches overlap.
    pub fn submit_batch(&self, reqs: &[SelectionRequest]) -> Result<BatchReport> {
        let t0 = Instant::now();
        let entries: Vec<Arc<PlatformEntry>> =
            reqs.iter().map(|r| self.entry(&r.platform)).collect::<Result<_>>()?;

        // distinct platforms in first-appearance order, with pre-batch
        // counter snapshots for the per-batch stats delta
        let mut seen: Vec<(String, Arc<PlatformEntry>, CacheStats)> = Vec::new();
        for (r, e) in reqs.iter().zip(&entries) {
            if !seen.iter().any(|(name, _, _)| *name == r.platform) {
                seen.push((r.platform.clone(), Arc::clone(e), e.cache.stats()));
            }
        }

        // each job goes through select_one, not solve_one directly, so
        // batch traffic passes the same health gate and feeds the same
        // drift monitors as the serving layer's per-request path
        let idx: Vec<usize> = (0..reqs.len()).collect();
        let results = par::par_map_heavy(&idx, |&i| self.select_one(&reqs[i]));
        let reports = results.into_iter().collect::<Result<Vec<_>>>()?;

        let stats = seen
            .into_iter()
            .map(|(name, entry, before)| (name, entry.cache.stats().since(&before)))
            .collect();
        Ok(BatchReport { reports, stats, wall_ms: t0.elapsed().as_secs_f64() * 1e3 })
    }

    /// Lifetime hit/miss totals per attached platform, sorted by name.
    pub fn cache_stats(&self) -> Vec<(String, CacheStats)> {
        let map = sync::read(&self.platforms);
        let mut out: Vec<(String, CacheStats)> =
            map.iter().map(|(name, e)| (name.clone(), e.cache.stats())).collect();
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    /// The time×space Pareto front for (`platform`, `network`), computed
    /// lazily on first request and cached until the platform's serving
    /// cache is replaced — re-registration, [`Self::onboard_platform`],
    /// [`Self::recalibrate_platform`], and the health loop's
    /// auto-recalibration all funnel through the same cache swap, so a
    /// stale front can never serve.
    ///
    /// ```
    /// use primsel::coordinator::Coordinator;
    /// use primsel::networks;
    /// use std::sync::Arc;
    ///
    /// let coord = Coordinator::new();
    /// let net = networks::alexnet();
    /// let cold = coord.pareto_front("intel", &net).unwrap();
    /// assert!(!cold.is_empty());
    /// // the second request answers from the cache: same front, no solve
    /// let warm = coord.pareto_front("intel", &net).unwrap();
    /// assert!(Arc::ptr_eq(&cold, &warm));
    /// assert_eq!(coord.front_cache_stats(), (1, 1));
    /// ```
    pub fn pareto_front(&self, platform: &str, network: &Network) -> Result<Arc<ParetoFront>> {
        let entry = self.entry(platform)?;
        Ok(self.front_for(platform, &entry, network)?.0)
    }

    /// Lifetime `(hits, misses)` of the Pareto-front cache: every miss
    /// computed a front (one budget sweep), every hit answered with zero
    /// PBQP solves.
    pub fn front_cache_stats(&self) -> (u64, u64) {
        (self.front_hits.load(Ordering::Relaxed), self.front_misses.load(Ordering::Relaxed))
    }

    /// The compiled [`SelectionPlan`] for (`platform`, `network`),
    /// compiled lazily on first request and cached until the platform's
    /// serving cache is replaced — the same lifecycle as
    /// [`Self::pareto_front`]. Handy for embedding the warm fast path
    /// directly (benchmarks, pinned-latency callers): solve it with a
    /// caller-retained [`PlanScratch`].
    pub fn selection_plan(&self, platform: &str, network: &Network) -> Result<Arc<SelectionPlan>> {
        let entry = self.entry(platform)?;
        Ok(self.plan_for(platform, &entry, network)?.0)
    }

    /// Lifetime `(hits, misses)` of the compiled-plan cache: every miss
    /// compiled a plan (one graph build + solver template), every hit
    /// solved warm out of frozen arenas.
    pub fn plan_cache_stats(&self) -> (u64, u64) {
        (self.plan_hits.load(Ordering::Relaxed), self.plan_misses.load(Ordering::Relaxed))
    }

    /// The plan for (`platform`, `net`) over `entry`'s cache plus
    /// whether it was cached — the same generation-checked lookup as
    /// [`Self::front_for`]: a slot only serves while it was compiled
    /// over the cache *currently* serving the platform (`Arc::ptr_eq`),
    /// so a plan compiled concurrently with a recalibration expires the
    /// moment the new cache lands.
    fn plan_for(
        &self,
        platform: &str,
        entry: &Arc<PlatformEntry>,
        net: &Network,
    ) -> Result<(Arc<SelectionPlan>, bool)> {
        let key = (platform.to_string(), network_fingerprint(net));
        if let Some(slot) = sync::read(&self.plans).get(&key) {
            if Arc::ptr_eq(&slot.cache, &entry.cache) {
                self.plan_hits.fetch_add(1, Ordering::Relaxed);
                return Ok((Arc::clone(&slot.plan), true));
            }
        }
        self.plan_misses.fetch_add(1, Ordering::Relaxed);
        // compile outside the lock: the graph build is the expensive
        // part and the map must stay available to other platforms
        let plan = Arc::new(SelectionPlan::compile(net, entry.cache.as_ref())?);
        let mut map = sync::write(&self.plans);
        let slot = map.entry(key).or_insert_with(|| PlanSlot {
            cache: Arc::clone(&entry.cache),
            plan: Arc::clone(&plan),
        });
        if !Arc::ptr_eq(&slot.cache, &entry.cache) {
            // the surviving slot belongs to a different cache generation
            // than the one we compiled over; replace it with ours — if
            // ours is the stale one, the next request through the new
            // cache fails the pointer check above and recompiles
            *slot = PlanSlot { cache: Arc::clone(&entry.cache), plan: Arc::clone(&plan) };
        }
        Ok((Arc::clone(&slot.plan), false))
    }

    /// Answer a solve-served objective through the compiled-plan cache.
    /// Warm requests run the whole solve out of the thread-local
    /// [`PlanScratch`]: the only heap allocations left are the report's
    /// `Selection` vec and (under [`ReportDetail::Full`]) its name
    /// strings — the solve core itself is allocation-free, pinned by
    /// `rust/tests/alloc_counter.rs`.
    fn solve_via_plan(
        &self,
        entry: &Arc<PlatformEntry>,
        req: &SelectionRequest,
    ) -> Result<SelectionReport> {
        thread_local! {
            static PLAN_SCRATCH: RefCell<PlanScratch> = RefCell::new(PlanScratch::default());
        }
        let t0 = Instant::now();
        let (plan, _cached) = self.plan_for(&req.platform, entry, &req.network)?;
        if let Some(t) = &req.trace {
            t.mark(obs::Stage::PlanReady);
        }
        let mut report = PLAN_SCRATCH.with(|cell| {
            let scratch = &mut *cell.borrow_mut();
            let view = match req.objective {
                Objective::MinTime => plan.min_time_into(scratch),
                Objective::MinTimeWithMemoryBudget { budget_bytes, lambda_ms_per_mb } => {
                    plan.with_budget_into(budget_bytes, lambda_ms_per_mb, scratch)
                }
                other => unreachable!("front objective routed to solve_via_plan: {other:?}"),
            };
            if let Some(t) = &req.trace {
                t.mark(obs::Stage::Solved);
            }
            let (network, platform) = report_names(req);
            SelectionReport {
                network,
                platform,
                objective: req.objective,
                provenance: entry.provenance.clone(),
                selection: view.to_selection(),
                // the plan's frozen times are exactly the cold path's
                // cache rows (same generation), and the solver's
                // objective sums them in evaluate()'s order — so this
                // *is* the evaluated time, bit for bit, with no lookups
                evaluated_ms: view.estimated_ms,
                peak_workspace_bytes: view.peak_workspace_bytes,
                front: None,
                wall_ms: 0.0,
                trace: None,
            }
        });
        report.wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        Ok(report)
    }

    /// The front for (`platform`, `net`) over `entry`'s cache plus
    /// whether it was cached. A slot only counts as a hit when it was
    /// computed over the cache *currently* serving the platform
    /// (`Arc::ptr_eq`), so a front computed concurrently with a
    /// recalibration expires the moment the new cache lands.
    fn front_for(
        &self,
        platform: &str,
        entry: &Arc<PlatformEntry>,
        net: &Network,
    ) -> Result<(Arc<ParetoFront>, bool)> {
        let key = (platform.to_string(), network_fingerprint(net));
        if let Some(slot) = sync::read(&self.fronts).get(&key) {
            if Arc::ptr_eq(&slot.cache, &entry.cache) {
                self.front_hits.fetch_add(1, Ordering::Relaxed);
                return Ok((Arc::clone(&slot.front), true));
            }
        }
        self.front_misses.fetch_add(1, Ordering::Relaxed);
        // compute outside the lock: the sweep is the expensive part and
        // the map must stay available to other platforms meanwhile
        let front =
            Arc::new(ParetoFront::compute(net, entry.cache.as_ref(), DEFAULT_LAMBDA_MS_PER_MB)?);
        let mut map = sync::write(&self.fronts);
        let slot = map.entry(key).or_insert_with(|| FrontSlot {
            cache: Arc::clone(&entry.cache),
            front: Arc::clone(&front),
        });
        if !Arc::ptr_eq(&slot.cache, &entry.cache) {
            // the surviving slot belongs to a different cache generation
            // than the one we solved over; replace it with ours — if ours
            // is the stale one, the next request through the new cache
            // fails the pointer check above and recomputes
            *slot = FrontSlot { cache: Arc::clone(&entry.cache), front: Arc::clone(&front) };
        }
        Ok((Arc::clone(&slot.front), false))
    }

    /// Answer a front-served objective ([`Objective::is_front_served`])
    /// by lookup on the platform's cached Pareto front.
    fn solve_via_front(
        &self,
        entry: &Arc<PlatformEntry>,
        req: &SelectionRequest,
    ) -> Result<SelectionReport> {
        let t0 = Instant::now();
        let (front, cache_hit) = self.front_for(&req.platform, entry, &req.network)?;
        if let Some(t) = &req.trace {
            t.mark(obs::Stage::PlanReady);
        }
        let point = match req.objective {
            Objective::FastestUnderBytes { budget_bytes } => {
                front.fastest_under(budget_bytes).ok_or_else(|| {
                    anyhow!(
                        "no selection for {:?} on {:?} fits under {budget_bytes} bytes: \
                         the leanest front point peaks at {} bytes",
                        req.network.name,
                        req.platform,
                        front.min_peak_bytes()
                    )
                })?
            }
            Objective::SmallestWithinPct { pct_of_optimal_time } => {
                ensure!(
                    pct_of_optimal_time.is_finite() && pct_of_optimal_time >= 0.0,
                    "pct_of_optimal_time must be finite and non-negative, \
                     got {pct_of_optimal_time}"
                );
                front
                    .smallest_within_pct(pct_of_optimal_time)
                    .ok_or_else(|| anyhow!("empty Pareto front"))?
            }
            other => unreachable!("solve_via_front called with {other:?}"),
        };
        if let Some(t) = &req.trace {
            t.mark(obs::Stage::Solved);
        }
        let (network, platform) = report_names(req);
        Ok(SelectionReport {
            network,
            platform,
            objective: req.objective,
            provenance: entry.provenance.clone(),
            selection: point.selection.clone(),
            evaluated_ms: point.true_time_ms,
            peak_workspace_bytes: point.peak_workspace_bytes,
            front: Some(FrontLookup {
                budget_bytes: point.budget_bytes,
                peak_workspace_bytes: point.peak_workspace_bytes,
                true_time_ms: point.true_time_ms,
                cache_hit,
                front_points: front.len(),
            }),
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
            trace: None,
        })
    }
}

/// Structural fingerprint of a network for the front-cache key: name,
/// layer configs, and edges (everything the PBQP instance depends on).
fn network_fingerprint(net: &Network) -> u64 {
    let mut h = DefaultHasher::new();
    net.name.hash(&mut h);
    net.layers.hash(&mut h);
    net.edges.hash(&mut h);
    h.finish()
}

/// Worst relative old→new prediction change across columns, via the same
/// robust per-column median the factor machinery uses:
/// `max_j |median_i(new_ij / old_ij) - 1|`.
fn prediction_shift(old: &[Vec<f64>], new: &[Vec<f64>]) -> f64 {
    let as_measured: Vec<Vec<Option<f64>>> =
        new.iter().map(|r| r.iter().map(|&v| Some(v)).collect()).collect();
    robust_factors(old, &as_measured, MIN_CALIB_RATIOS)
        .into_iter()
        .filter(|f| f.is_finite())
        .map(|f| (f - 1.0).abs())
        .fold(0.0f64, f64::max)
}

/// Flatten predicted 3x3 DLT matrices into rows of their six
/// off-diagonal cells (the diagonal is meaningless — identity transforms
/// are free — and must not contribute ratios).
fn flatten_off_diagonal(mats: &[[[f64; 3]; 3]]) -> Vec<Vec<f64>> {
    mats.iter()
        .map(|m| {
            let mut row = Vec::with_capacity(6);
            for (i, r) in m.iter().enumerate() {
                for (j, &v) in r.iter().enumerate() {
                    if i != j {
                        row.push(v);
                    }
                }
            }
            row
        })
        .collect()
}

/// The report's name strings per the request's [`ReportDetail`]:
/// `Minimal` defers them (two empty, capacity-free `String`s) for
/// [`SelectionReport::render`] to fill if anyone asks.
fn report_names(req: &SelectionRequest) -> (String, String) {
    match req.detail {
        ReportDetail::Full => (req.network.name.clone(), req.platform.clone()),
        ReportDetail::Minimal => (String::new(), String::new()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::networks;
    use crate::simulator::{machine, Simulator};

    #[test]
    fn unknown_platform_is_an_error() {
        let coord = Coordinator::new();
        let req = SelectionRequest::new(networks::alexnet(), "riscv");
        assert!(coord.submit(&req).is_err());
        assert!(coord.submit_batch(&[req]).is_err());
    }

    #[test]
    fn submit_matches_direct_selection() {
        let coord = Coordinator::new();
        let net = networks::vgg(11);
        let rep = coord.submit(&SelectionRequest::new(net.clone(), "amd")).unwrap();
        let sim = Simulator::new(machine::amd_a10_7850k());
        let direct = selection::select(&net, &sim).unwrap();
        assert_eq!(rep.selection.primitive, direct.primitive);
        assert_eq!(rep.selection.estimated_ms, direct.estimated_ms);
        assert_eq!(rep.evaluated_ms, selection::evaluate(&net, &direct, &sim).unwrap());
        assert_eq!(rep.platform, "amd");
        assert_eq!(rep.provenance, CostProvenance::Measured);
    }

    #[test]
    fn register_overrides_builtin_resolution() {
        let coord = Coordinator::new();
        // "edge-tpu" is not a built-in name; registering any source
        // makes it servable
        let sim = Arc::new(Simulator::new(machine::arm_cortex_a73()));
        coord.register("edge-tpu", sim);
        let rep = coord.submit(&SelectionRequest::new(networks::alexnet(), "edge-tpu")).unwrap();
        assert!(rep.evaluated_ms > 0.0);
        let stats = coord.cache_stats();
        assert_eq!(stats.len(), 1);
        assert_eq!(stats[0].0, "edge-tpu");
        assert!(stats[0].1.lookups() > 0);
    }

    #[test]
    fn batch_shares_one_cache_per_platform() {
        let coord = Coordinator::new();
        let net = networks::alexnet();
        let reqs: Vec<SelectionRequest> =
            (0..6).map(|_| SelectionRequest::new(net.clone(), "intel")).collect();
        let batch = coord.submit_batch(&reqs).unwrap();
        assert_eq!(batch.reports.len(), 6);
        assert_eq!(batch.stats.len(), 1);
        let (_, s) = &batch.stats[0];
        // the six identical requests share one cache and one compiled
        // plan: plan compiles (racing jobs may each compile once) are
        // the only cache traffic, and every request answers identically
        assert!(s.lookups() > 0, "first batch compiles through the cache: {s:?}");
        let (hits, misses) = coord.plan_cache_stats();
        assert_eq!(hits + misses, 6);
        assert!(misses >= 1);
        for w in batch.reports.windows(2) {
            assert_eq!(w[0].selection.primitive, w[1].selection.primitive);
            assert_eq!(w[0].evaluated_ms, w[1].evaluated_ms);
        }
        // a second identical batch is all plan hits: zero cache traffic
        let warm = coord.submit_batch(&reqs).unwrap();
        let (_, s) = &warm.stats[0];
        assert_eq!(s.lookups(), 0, "warm batch is plan-served: {s:?}");
        assert_eq!(coord.plan_cache_stats().0, hits + 6);
        for (a, b) in batch.reports.iter().zip(&warm.reports) {
            assert_eq!(a.selection.primitive, b.selection.primitive);
            assert_eq!(a.evaluated_ms, b.evaluated_ms);
        }
    }

    #[test]
    fn minimal_detail_defers_names_and_render_fills_them() {
        let coord = Coordinator::new();
        let req = SelectionRequest::new(networks::alexnet(), "intel");
        let full = coord.submit(&req).unwrap();
        let min = coord
            .submit(&req.clone().with_detail(ReportDetail::Minimal))
            .unwrap();
        assert!(min.network.is_empty() && min.platform.is_empty());
        // everything numeric is identical regardless of detail
        assert_eq!(min.selection.primitive, full.selection.primitive);
        assert_eq!(min.selection.estimated_ms, full.selection.estimated_ms);
        assert_eq!(min.evaluated_ms, full.evaluated_ms);
        assert_eq!(min.peak_workspace_bytes, full.peak_workspace_bytes);
        let rendered = min.render(&req);
        assert_eq!(rendered.network, "alexnet");
        assert_eq!(rendered.platform, "intel");
        // render is idempotent on a Full report
        assert_eq!(full.clone().render(&req).network, full.network);
        // front-served reports honour detail too
        let fr = coord
            .submit(
                &req.clone()
                    .with_objective(Objective::FastestUnderBytes { budget_bytes: f64::INFINITY })
                    .with_detail(ReportDetail::Minimal),
            )
            .unwrap();
        assert!(fr.network.is_empty());
        assert_eq!(fr.render(&req).platform, "intel");
    }

    #[test]
    fn warm_requests_answer_from_the_cached_plan() {
        let coord = Coordinator::new();
        let net = networks::vgg(11);
        let req = SelectionRequest::new(net.clone(), "intel");
        let cold = coord.submit(&req).unwrap();
        assert_eq!(coord.plan_cache_stats(), (0, 1));
        let plan = coord.selection_plan("intel", &net).unwrap();
        let warm = coord.submit(&req).unwrap();
        assert_eq!(coord.plan_cache_stats(), (2, 1));
        assert_eq!(warm.selection.primitive, cold.selection.primitive);
        assert_eq!(warm.selection.estimated_ms, cold.selection.estimated_ms);
        assert_eq!(warm.evaluated_ms, cold.evaluated_ms);
        // budgeted objectives share the same plan (same fingerprint)
        let tight = coord
            .submit(&req.clone().with_objective(Objective::MinTimeWithMemoryBudget {
                budget_bytes: cold.peak_workspace_bytes * 0.1,
                lambda_ms_per_mb: 50.0,
            }))
            .unwrap();
        assert_eq!(coord.plan_cache_stats(), (3, 1));
        assert!(tight.peak_workspace_bytes < cold.peak_workspace_bytes);
        assert!(Arc::ptr_eq(&plan, &coord.selection_plan("intel", &net).unwrap()));
    }

    #[test]
    fn register_drops_cached_plans() {
        let coord = Coordinator::new();
        let net = networks::alexnet();
        let sim: Arc<dyn CostSource> = Arc::new(Simulator::new(machine::arm_cortex_a73()));
        coord.register("dev", Arc::clone(&sim));
        let req = SelectionRequest::new(net.clone(), "dev");
        let first = coord.submit(&req).unwrap();
        let plan = coord.selection_plan("dev", &net).unwrap();
        // re-registering (even the same source) swaps the serving cache,
        // so the compiled plan must be recompiled — and the recompiled
        // answer is bit-identical because the source is the same
        coord.register("dev", sim);
        let fresh_plan = coord.selection_plan("dev", &net).unwrap();
        assert!(!Arc::ptr_eq(&plan, &fresh_plan));
        let again = coord.submit(&req).unwrap();
        assert_eq!(again.selection.primitive, first.selection.primitive);
        assert_eq!(again.evaluated_ms, first.evaluated_ms);
    }

    #[test]
    fn memory_budget_objective_is_respected() {
        let coord = Coordinator::new();
        let net = networks::vgg(11);
        let free = coord.submit(&SelectionRequest::new(net.clone(), "arm")).unwrap();
        let tight = coord
            .submit(&SelectionRequest::new(net, "arm").with_objective(
                Objective::MinTimeWithMemoryBudget {
                    budget_bytes: free.peak_workspace_bytes * 0.1,
                    lambda_ms_per_mb: 50.0,
                },
            ))
            .unwrap();
        assert!(tight.peak_workspace_bytes < free.peak_workspace_bytes);
        assert!(tight.evaluated_ms >= free.evaluated_ms);
    }

    #[test]
    fn front_objectives_answer_from_the_cached_front() {
        let coord = Coordinator::new();
        let net = networks::vgg(11);

        // unbounded budget == plain min-time selection, bit for bit
        let free = coord.submit(&SelectionRequest::new(net.clone(), "intel")).unwrap();
        let fastest = coord
            .submit(&SelectionRequest::new(net.clone(), "intel").with_objective(
                Objective::FastestUnderBytes { budget_bytes: f64::INFINITY },
            ))
            .unwrap();
        assert_eq!(fastest.selection.primitive, free.selection.primitive);
        assert_eq!(fastest.evaluated_ms, free.evaluated_ms);
        let look = fastest.front.as_ref().expect("front-served report carries a lookup");
        assert!(!look.cache_hit, "first front request computes");
        assert!(look.front_points >= 1);

        // a second front query on the same pair is a cache hit
        let again = coord
            .submit(&SelectionRequest::new(net.clone(), "intel").with_objective(
                Objective::SmallestWithinPct { pct_of_optimal_time: 0.0 },
            ))
            .unwrap();
        assert!(again.front.unwrap().cache_hit);
        // zero slack pins the fastest point
        assert_eq!(again.evaluated_ms, fastest.evaluated_ms);
        assert_eq!(coord.front_cache_stats(), (1, 1));

        // solve-served objectives never carry a lookup
        assert!(free.front.is_none());
    }

    #[test]
    fn front_objectives_reject_bad_inputs() {
        let coord = Coordinator::new();
        let net = networks::alexnet();
        // no assignment has negative workspace: unsatisfiable hard budget
        let err = coord
            .submit(&SelectionRequest::new(net.clone(), "intel").with_objective(
                Objective::FastestUnderBytes { budget_bytes: -1.0 },
            ))
            .unwrap_err();
        assert!(err.to_string().contains("leanest front point"), "{err}");
        for pct in [f64::NAN, -5.0] {
            assert!(coord
                .submit(&SelectionRequest::new(net.clone(), "intel").with_objective(
                    Objective::SmallestWithinPct { pct_of_optimal_time: pct },
                ))
                .is_err());
        }
    }

    #[test]
    fn register_drops_cached_fronts() {
        let coord = Coordinator::new();
        let net = networks::alexnet();
        let sim: Arc<dyn CostSource> = Arc::new(Simulator::new(machine::arm_cortex_a73()));
        coord.register("dev", Arc::clone(&sim));
        let first = coord.pareto_front("dev", &net).unwrap();
        let warm = coord.pareto_front("dev", &net).unwrap();
        assert!(Arc::ptr_eq(&first, &warm));
        // re-registering (even the same source) swaps the serving cache,
        // so the cached front must be recomputed
        coord.register("dev", sim);
        let fresh = coord.pareto_front("dev", &net).unwrap();
        assert!(!Arc::ptr_eq(&first, &fresh));
        // same source, so the recomputed front is bit-identical
        assert_eq!(fresh.points.len(), first.points.len());
        for (a, b) in fresh.points.iter().zip(&first.points) {
            assert_eq!(a.selection.primitive, b.selection.primitive);
            assert_eq!(a.true_time_ms, b.true_time_ms);
        }
        assert_eq!(coord.front_cache_stats(), (1, 2));
    }

    #[test]
    fn onboarding_rejects_bad_fraction() {
        let coord = Coordinator::new();
        let target: Arc<dyn CostSource> =
            Arc::new(Simulator::new(machine::arm_cortex_a73()));
        let spec = OnboardSpec::fresh_lin(Arc::clone(&target), 0.0, 1);
        assert!(coord.onboard_platform("arm-lin", spec).is_err());
        let spec = OnboardSpec::fresh_lin(target, 1.5, 1);
        assert!(coord.onboard_platform("arm-lin", spec).is_err());
    }

    #[test]
    fn onboarded_platform_serves_with_predicted_provenance() {
        let coord = Coordinator::new();
        let target: Arc<dyn CostSource> =
            Arc::new(Simulator::new(machine::arm_cortex_a73()));
        let report = coord
            .onboard_platform("arm-lin", OnboardSpec::fresh_lin(target, 0.02, 7))
            .unwrap();
        assert_eq!(report.platform, "arm-lin");
        assert_eq!(report.model_kind, "lin");
        assert!(report.calib_samples > 0);
        assert!(report.validation.is_empty());

        let rep = coord.submit(&SelectionRequest::new(networks::alexnet(), "arm-lin")).unwrap();
        assert!(rep.evaluated_ms > 0.0);
        match &rep.provenance {
            CostProvenance::Predicted { model_kind, calib_samples } => {
                assert_eq!(model_kind, "lin");
                assert_eq!(*calib_samples, report.calib_samples);
            }
            other => panic!("expected predicted provenance, got {other:?}"),
        }
        // the built-in measured platform is untouched
        let rep = coord.submit(&SelectionRequest::new(networks::alexnet(), "arm")).unwrap();
        assert_eq!(rep.provenance, CostProvenance::Measured);
    }

    #[test]
    fn recalibrate_refreshes_transfer_factors_in_place() {
        // onboard arm via §4.4 transfer from an intel-trained Lin, then
        // recalibrate from a fresh (larger, differently-seeded) draw:
        // provenance tracks the new sample count and serving continues
        // over the rebuilt cache
        let coord = Coordinator::new();
        let intel = Simulator::new(machine::intel_i9_9900k());
        let (prim, dlt) = calibration_sample(&intel, 0.05, 3);
        let source: Arc<dyn CostModel + Send + Sync> =
            Arc::new(LinCostModel::fit(&prim, &dlt, "intel").unwrap());
        let target: Arc<dyn CostSource> =
            Arc::new(Simulator::new(machine::arm_cortex_a73()));
        let onboard = coord
            .onboard_platform("arm-x", OnboardSpec::transfer(target, source, 0.02, 5))
            .unwrap();
        assert_eq!(onboard.model_kind, "lin+factor");

        let recal = coord.recalibrate_platform("arm-x", 0.04, 99).unwrap();
        assert_eq!(recal.platform, "arm-x");
        assert_eq!(recal.path, RecalPath::TransferFactors);
        assert!(recal.calib_samples > onboard.calib_samples);
        assert!(recal.max_factor_shift.is_finite());
        match &recal.provenance {
            CostProvenance::Predicted { model_kind, calib_samples } => {
                assert_eq!(model_kind, "lin+factor");
                assert_eq!(*calib_samples, recal.calib_samples);
            }
            other => panic!("expected predicted provenance, got {other:?}"),
        }
        assert_eq!(coord.provenance("arm-x").unwrap(), recal.provenance);
        let rep =
            coord.submit(&SelectionRequest::new(networks::alexnet(), "arm-x")).unwrap();
        assert!(rep.evaluated_ms > 0.0);

        // fresh-Lin platforms recalibrate too, via a full refit: the
        // serving model is replaced and the report says so
        let t2: Arc<dyn CostSource> = Arc::new(Simulator::new(machine::arm_cortex_a73()));
        coord.onboard_platform("arm-lin2", OnboardSpec::fresh_lin(t2, 0.02, 7)).unwrap();
        let refit = coord.recalibrate_platform("arm-lin2", 0.03, 11).unwrap();
        assert_eq!(refit.path, RecalPath::FreshLinRefit);
        assert!(refit.max_factor_shift.is_finite() && refit.max_factor_shift >= 0.0);
        let rep =
            coord.submit(&SelectionRequest::new(networks::alexnet(), "arm-lin2")).unwrap();
        assert!(rep.evaluated_ms > 0.0);

        // only model-onboarded platforms carry recalibratable state
        assert!(coord.recalibrate_platform("riscv", 0.02, 1).is_err()); // unknown
        let direct: Arc<dyn CostSource> = Arc::new(Simulator::new(machine::arm_cortex_a73()));
        coord.register("arm-direct", direct);
        assert!(coord.recalibrate_platform("arm-direct", 0.02, 1).is_err()); // registered
        assert!(coord.recalibrate_platform("arm-x", 0.0, 1).is_err()); // bad fraction
    }
}

//! Data parallelism for the embarrassingly-parallel sweeps (dataset
//! profiling, per-platform experiment columns, bench warmups) and the
//! per-request batch fan-out.
//!
//! The API is deliberately rayon-shaped (`par_map` ≈
//! `par_iter().map().collect()`), but the implementation is
//! dependency-free: the build environment is offline, so the rayon
//! dependency is gated out (see the commented dependency block in
//! Cargo.toml — swapping these bodies for
//! `items.par_iter().map(f).collect()` is a two-line change once a
//! registry is reachable). [`par_map`]/[`par_map_coarse`] are
//! `std::thread::scope` fan-out over chunks — for the sweep shapes we
//! have, static chunking is within noise of a work-stealing pool.
//! [`par_map_heavy`] instead routes through one process-wide persistent
//! worker pool (lazily spawned, [`Pool`]-backed): batch-serving callers
//! like [`Coordinator::submit_batch`](crate::coordinator::Coordinator::submit_batch)
//! hit it per batch, and per-batch thread spawn/join cost is exactly
//! the kind of warm-path overhead the compiled-plan work removes
//! elsewhere.

use std::num::NonZeroUsize;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Below this many items the spawn cost outweighs the win; run inline.
const MIN_PAR_ITEMS: usize = 64;

/// Number of worker threads to fan out across.
pub fn workers() -> usize {
    std::thread::available_parallelism().map(NonZeroUsize::get).unwrap_or(1)
}

/// Parallel map preserving input order: `out[i] = f(&items[i])`.
///
/// `f` runs concurrently from multiple threads; results are stitched back
/// in order, so callers observe exactly the sequential result. Falls back
/// to a plain sequential map for small inputs or single-core hosts.
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    let threads = workers().min(n.div_ceil(MIN_PAR_ITEMS.max(1)));
    if threads <= 1 || n < MIN_PAR_ITEMS {
        return items.iter().map(f).collect();
    }
    let chunk = n.div_ceil(threads);
    let f = &f;
    let mut out: Vec<R> = Vec::with_capacity(n);
    std::thread::scope(|s| {
        let handles: Vec<_> = items
            .chunks(chunk)
            .map(|c| s.spawn(move || c.iter().map(f).collect::<Vec<R>>()))
            .collect();
        for h in handles {
            out.extend(h.join().expect("par_map worker panicked"));
        }
    });
    out
}

/// Parallel map that always fans out to exactly one thread per item —
/// for small item counts where true all-at-once concurrency is the
/// point (e.g. the contended-cache bench needs every tenant live at
/// once, even on hosts with fewer cores than tenants). For bounded
/// fan-out over a batch of heavy items, prefer [`par_map_heavy`].
pub fn par_map_coarse<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n <= 1 {
        return items.iter().map(f).collect();
    }
    let f = &f;
    let mut out: Vec<R> = Vec::with_capacity(n);
    std::thread::scope(|s| {
        let handles: Vec<_> =
            items.iter().map(|it| s.spawn(move || f(it))).collect();
        for h in handles {
            out.push(h.join().expect("par_map_coarse worker panicked"));
        }
    });
    out
}

/// One submitted [`par_map_heavy`] batch, type-erased so differently
/// typed batches share one queue. A batch is `lanes` independent units
/// of work; any thread (pool worker or the submitter itself) claims
/// lanes with a `fetch_add` ticket and runs them via the monomorphized
/// `run_lane` shim.
///
/// Safety of the `Send + Sync` impls: `ctx` points into the submitting
/// frame of `par_map_heavy`, which blocks until `done == lanes` before
/// returning — so every dereference of `ctx` (only ever through
/// `run_lane`, only for a claimed lane) happens while the frame is
/// alive. Queue stragglers may hold the `Arc` (and thus the raw
/// pointer) longer, but they can never claim a lane on an exhausted
/// batch, so they never dereference it.
struct HeavyBatch {
    run_lane: unsafe fn(*const (), usize),
    ctx: *const (),
    lanes: usize,
    /// Lane ticket dispenser: claims are `fetch_add` so a lane runs
    /// exactly once no matter how many threads drain the batch.
    next: AtomicUsize,
    /// Lanes fully finished (ran or panicked) — the submitter's wait
    /// condition, guarded so the condvar wake-up can't be missed.
    done: Mutex<usize>,
    all_done: Condvar,
    panicked: AtomicBool,
}

unsafe impl Send for HeavyBatch {}
unsafe impl Sync for HeavyBatch {}

impl HeavyBatch {
    /// Claim and run lanes until the ticket dispenser runs dry. Lane
    /// panics are caught and recorded (the submitter re-raises), so a
    /// panicking item never takes a persistent pool worker down.
    fn run_claimed(&self) {
        loop {
            let lane = self.next.fetch_add(1, Ordering::Relaxed);
            if lane >= self.lanes {
                return;
            }
            let ok = std::panic::catch_unwind(AssertUnwindSafe(|| unsafe {
                (self.run_lane)(self.ctx, lane)
            }))
            .is_ok();
            if !ok {
                self.panicked.store(true, Ordering::Relaxed);
            }
            let mut done = self.done.lock().expect("heavy batch poisoned");
            *done += 1;
            if *done == self.lanes {
                self.all_done.notify_all();
            }
        }
    }

    /// Block until every lane has finished (not merely been claimed).
    fn wait_done(&self) {
        let mut done = self.done.lock().expect("heavy batch poisoned");
        while *done < self.lanes {
            done = self.all_done.wait(done).expect("heavy batch poisoned");
        }
    }

    /// Whether every lane has already been claimed (the batch can be
    /// dropped from the queue; in-flight lanes finish on whoever claimed
    /// them).
    fn exhausted(&self) -> bool {
        self.next.load(Ordering::Relaxed) >= self.lanes
    }
}

/// The monomorphized lane runner behind [`HeavyBatch::run_lane`].
///
/// Safety: `ctx` must be the `&C` the batch was built over, still alive
/// — guaranteed by the submitter blocking in [`HeavyBatch::wait_done`]
/// until every claimed lane finishes.
unsafe fn call_lane<C: Fn(usize) + Sync>(ctx: *const (), lane: usize) {
    (*(ctx as *const C))(lane)
}

/// Recover the monomorphized [`call_lane`] for an unnameable closure
/// type by inference.
fn lane_fn_of<C: Fn(usize) + Sync>(_c: &C) -> unsafe fn(*const (), usize) {
    call_lane::<C>
}

/// The process-wide persistent pool behind [`par_map_heavy`]: a queue
/// of in-flight batches drained by `workers() - 1` long-lived threads
/// (the submitting thread is always the +1 — see below).
struct HeavyPool {
    queue: Mutex<Vec<Arc<HeavyBatch>>>,
    work: Condvar,
}

impl HeavyPool {
    fn submit(&self, batch: &Arc<HeavyBatch>) {
        self.queue.lock().expect("heavy pool poisoned").push(Arc::clone(batch));
        self.work.notify_all();
    }

    /// A persistent worker's life: sleep until a batch shows up, drain
    /// lanes from the oldest live batch, drop exhausted batches, repeat
    /// forever (the pool is process-lived; threads park on the condvar
    /// when idle and cost nothing).
    fn worker_loop(&self) {
        loop {
            let batch = {
                let mut q = self.queue.lock().expect("heavy pool poisoned");
                loop {
                    q.retain(|b| !b.exhausted());
                    match q.first() {
                        Some(b) => break Arc::clone(b),
                        None => q = self.work.wait(q).expect("heavy pool poisoned"),
                    }
                }
            };
            batch.run_claimed();
        }
    }
}

/// The lazily-spawned singleton pool. Threads are spawned once, named
/// `primsel-heavy-*`, and intentionally leaked — they idle on a condvar
/// between batches and die with the process.
fn heavy_pool() -> &'static HeavyPool {
    static POOL: OnceLock<&'static HeavyPool> = OnceLock::new();
    POOL.get_or_init(|| {
        let pool: &'static HeavyPool = Box::leak(Box::new(HeavyPool {
            queue: Mutex::new(Vec::new()),
            work: Condvar::new(),
        }));
        let n = workers().saturating_sub(1).max(1);
        std::mem::forget(Pool::spawn(n, "primsel-heavy", move |_| pool.worker_loop()));
        pool
    })
}

/// Parallel map for batches of heavy, possibly uneven items (selection
/// requests, per-network sweeps): always fans out — no `MIN_PAR_ITEMS`
/// threshold — over the process-wide **persistent** worker pool, so a
/// serving loop calling this per batch pays zero thread spawn/join per
/// call. Concurrency is bounded at [`workers()`]: `workers() - 1` pool
/// threads plus the submitting thread, which always claims lanes
/// itself. That self-service is also what makes the call re-entrant —
/// a lane that itself calls `par_map_heavy` still makes progress even
/// if every pool thread is busy.
///
/// Items are dealt round-robin across `min(workers(), n)` lanes (lane
/// `w` takes items `w, w + L, w + 2L, …`), so a run of expensive
/// requests spreads across workers instead of landing in one contiguous
/// chunk; results are stitched back in input order. Panics in `f` are
/// re-raised on the submitting thread; persistent workers survive them.
pub fn par_map_heavy<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    let lanes = workers().min(n);
    if lanes <= 1 {
        return items.iter().map(f).collect();
    }
    // one output bin per lane: a lane is claimed by exactly one thread,
    // so the mutexes are uncontended — they exist to hand the results
    // (and a happens-before edge) back to the submitter
    let outputs: Vec<Mutex<Vec<(usize, R)>>> =
        (0..lanes).map(|_| Mutex::new(Vec::new())).collect();
    let runner = |lane: usize| {
        let mut out: Vec<(usize, R)> = Vec::new();
        for (i, it) in items.iter().enumerate().skip(lane).step_by(lanes) {
            out.push((i, f(it)));
        }
        *outputs[lane].lock().expect("heavy lane poisoned") = out;
    };
    let batch = Arc::new(HeavyBatch {
        run_lane: lane_fn_of(&runner),
        ctx: &runner as *const _ as *const (),
        lanes,
        next: AtomicUsize::new(0),
        done: Mutex::new(0),
        all_done: Condvar::new(),
        panicked: AtomicBool::new(false),
    });
    heavy_pool().submit(&batch);
    // claim lanes on this thread too, then wait for stragglers claimed
    // by pool workers — only after that is it safe for `runner` (and
    // `outputs`, and `items`) to leave scope
    batch.run_claimed();
    batch.wait_done();
    if batch.panicked.load(Ordering::Relaxed) {
        panic!("par_map_heavy worker panicked");
    }
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    for bin in &outputs {
        for (i, r) in bin.lock().expect("heavy lane poisoned").drain(..) {
            slots[i] = Some(r);
        }
    }
    slots.into_iter().map(|r| r.expect("every index visited")).collect()
}

/// A long-lived, named worker pool — the persistent counterpart of the
/// scoped fan-outs above, for services that outlive any one batch (the
/// admission-controlled serving layer in [`crate::service`]). `n` OS
/// threads each run `body(worker_index)` until it returns; unlike the
/// scoped helpers, the body must be `'static` (share state via `Arc`)
/// and the threads are joined explicitly with [`Pool::join`].
///
/// The pool itself has no queue or shutdown channel: the body is
/// expected to loop on some shared work source (e.g.
/// [`AdmissionQueue::pop`](crate::service::queue::AdmissionQueue::pop))
/// and return when that source reports closed-and-drained. That keeps
/// this primitive rayon-swappable too — under a real rayon dependency
/// these become `ThreadPoolBuilder` threads.
pub struct Pool {
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Pool {
    /// Spawn `n` named threads (`<name>-0` … `<name>-{n-1}`), each
    /// running `body(worker_index)` to completion.
    pub fn spawn<F>(n: usize, name: &str, body: F) -> Pool
    where
        F: Fn(usize) + Send + Sync + 'static,
    {
        let body = std::sync::Arc::new(body);
        let handles = (0..n)
            .map(|i| {
                let body = std::sync::Arc::clone(&body);
                std::thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || body(i))
                    .expect("failed to spawn pool worker")
            })
            .collect();
        Pool { handles }
    }

    /// Number of worker threads in the pool.
    pub fn len(&self) -> usize {
        self.handles.len()
    }

    /// Whether the pool has no workers.
    pub fn is_empty(&self) -> bool {
        self.handles.is_empty()
    }

    /// Wait for every worker's body to return. Panics if a worker
    /// panicked (the service layer treats a dead worker as a bug, not a
    /// recoverable condition).
    pub fn join(self) {
        for h in self.handles {
            h.join().expect("pool worker panicked");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_and_values() {
        let items: Vec<u64> = (0..1000).collect();
        let seq: Vec<u64> = items.iter().map(|x| x * x + 1).collect();
        assert_eq!(par_map(&items, |x| x * x + 1), seq);
    }

    #[test]
    fn small_inputs_run_inline() {
        let items = [1, 2, 3];
        assert_eq!(par_map(&items, |x| x + 1), vec![2, 3, 4]);
        let empty: [i32; 0] = [];
        assert!(par_map(&empty, |x| *x).is_empty());
    }

    #[test]
    fn coarse_fan_out() {
        let items = ["a", "bb", "ccc"];
        assert_eq!(par_map_coarse(&items, |s| s.len()), vec![1, 2, 3]);
    }

    #[test]
    fn heavy_fan_out_preserves_order() {
        // below MIN_PAR_ITEMS, where par_map would run inline — the heavy
        // variant must still fan out and still stitch results in order
        let items: Vec<u64> = (0..13).collect();
        let seq: Vec<u64> = items.iter().map(|x| x * 3 + 1).collect();
        assert_eq!(par_map_heavy(&items, |x| x * 3 + 1), seq);
        let empty: [u64; 0] = [];
        assert!(par_map_heavy(&empty, |x| *x).is_empty());
        assert_eq!(par_map_heavy(&[7u64], |x| x + 1), vec![8]);
    }

    #[test]
    fn heavy_is_reentrant() {
        // a lane that itself fans out must complete even when every
        // persistent pool thread is occupied — the submitting thread
        // always claims its own lanes
        let outer: Vec<u64> = (0..8).collect();
        let got = par_map_heavy(&outer, |&x| {
            let inner: Vec<u64> = (0..5).collect();
            par_map_heavy(&inner, |&y| x * 10 + y).iter().sum::<u64>()
        });
        let want: Vec<u64> = outer.iter().map(|&x| 5 * x * 10 + 10).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn heavy_propagates_panics_and_pool_survives() {
        let items: Vec<u64> = (0..9).collect();
        let r = std::panic::catch_unwind(|| {
            par_map_heavy(&items, |&x| {
                if x == 4 {
                    panic!("boom");
                }
                x
            })
        });
        assert!(r.is_err(), "a panicking item must fail the whole map");
        // the persistent workers caught the lane panic and live on:
        // the next batch is served normally
        assert_eq!(par_map_heavy(&items, |&x| x + 1), (1..10).collect::<Vec<u64>>());
    }

    #[test]
    fn pool_runs_every_worker_and_joins() {
        use std::sync::atomic::{AtomicU64, Ordering};
        use std::sync::Arc;
        let hits = Arc::new(AtomicU64::new(0));
        let h = Arc::clone(&hits);
        let pool = Pool::spawn(4, "test-pool", move |i| {
            h.fetch_add(1 << (8 * i), Ordering::SeqCst);
        });
        assert_eq!(pool.len(), 4);
        assert!(!pool.is_empty());
        pool.join();
        // each worker index ran exactly once
        assert_eq!(hits.load(Ordering::SeqCst), 0x01010101);
    }

    #[test]
    fn shares_borrowed_state() {
        // the closure may borrow outer state (the sweep pattern: one
        // shared &Simulator, many configs)
        let offset = 10u64;
        let items: Vec<u64> = (0..500).collect();
        let out = par_map(&items, |x| x + offset);
        assert_eq!(out[499], 509);
    }
}

//! Scoped-thread data parallelism for the embarrassingly-parallel sweeps
//! (dataset profiling, per-platform experiment columns, bench warmups).
//!
//! The API is deliberately rayon-shaped (`par_map` ≈
//! `par_iter().map().collect()`), but the implementation is
//! `std::thread::scope` fan-out over contiguous chunks: the build
//! environment is offline, so the rayon dependency is gated out (see the
//! commented dependency block in Cargo.toml — swapping these bodies for
//! `items.par_iter().map(f).collect()` is a two-line change once a
//! registry is reachable). For the sweep shapes we have — thousands of
//! independent, similarly-sized items — static chunking is within noise
//! of a work-stealing pool.

use std::num::NonZeroUsize;

/// Below this many items the spawn cost outweighs the win; run inline.
const MIN_PAR_ITEMS: usize = 64;

/// Number of worker threads to fan out across.
pub fn workers() -> usize {
    std::thread::available_parallelism().map(NonZeroUsize::get).unwrap_or(1)
}

/// Parallel map preserving input order: `out[i] = f(&items[i])`.
///
/// `f` runs concurrently from multiple threads; results are stitched back
/// in order, so callers observe exactly the sequential result. Falls back
/// to a plain sequential map for small inputs or single-core hosts.
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    let threads = workers().min(n.div_ceil(MIN_PAR_ITEMS.max(1)));
    if threads <= 1 || n < MIN_PAR_ITEMS {
        return items.iter().map(f).collect();
    }
    let chunk = n.div_ceil(threads);
    let f = &f;
    let mut out: Vec<R> = Vec::with_capacity(n);
    std::thread::scope(|s| {
        let handles: Vec<_> = items
            .chunks(chunk)
            .map(|c| s.spawn(move || c.iter().map(f).collect::<Vec<R>>()))
            .collect();
        for h in handles {
            out.extend(h.join().expect("par_map worker panicked"));
        }
    });
    out
}

/// Parallel map that always fans out to exactly one thread per item —
/// for small item counts where true all-at-once concurrency is the
/// point (e.g. the contended-cache bench needs every tenant live at
/// once, even on hosts with fewer cores than tenants). For bounded
/// fan-out over a batch of heavy items, prefer [`par_map_heavy`].
pub fn par_map_coarse<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n <= 1 {
        return items.iter().map(f).collect();
    }
    let f = &f;
    let mut out: Vec<R> = Vec::with_capacity(n);
    std::thread::scope(|s| {
        let handles: Vec<_> =
            items.iter().map(|it| s.spawn(move || f(it))).collect();
        for h in handles {
            out.push(h.join().expect("par_map_coarse worker panicked"));
        }
    });
    out
}

/// Parallel map for batches of heavy, possibly uneven items (selection
/// requests, per-network sweeps): always fans out — no `MIN_PAR_ITEMS`
/// threshold — but bounds the fleet at [`workers()`] threads. Items are
/// dealt round-robin (worker `w` takes `w, w + T, w + 2T, …`), so a run
/// of expensive requests spreads across workers instead of landing in
/// one contiguous chunk; results are stitched back in input order.
pub fn par_map_heavy<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    let threads = workers().min(n);
    if threads <= 1 {
        return items.iter().map(f).collect();
    }
    let f = &f;
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..threads)
            .map(|w| {
                s.spawn(move || {
                    items
                        .iter()
                        .enumerate()
                        .skip(w)
                        .step_by(threads)
                        .map(|(i, it)| (i, f(it)))
                        .collect::<Vec<(usize, R)>>()
                })
            })
            .collect();
        for h in handles {
            for (i, r) in h.join().expect("par_map_heavy worker panicked") {
                slots[i] = Some(r);
            }
        }
    });
    slots.into_iter().map(|r| r.expect("every index visited")).collect()
}

/// A long-lived, named worker pool — the persistent counterpart of the
/// scoped fan-outs above, for services that outlive any one batch (the
/// admission-controlled serving layer in [`crate::service`]). `n` OS
/// threads each run `body(worker_index)` until it returns; unlike the
/// scoped helpers, the body must be `'static` (share state via `Arc`)
/// and the threads are joined explicitly with [`Pool::join`].
///
/// The pool itself has no queue or shutdown channel: the body is
/// expected to loop on some shared work source (e.g.
/// [`AdmissionQueue::pop`](crate::service::queue::AdmissionQueue::pop))
/// and return when that source reports closed-and-drained. That keeps
/// this primitive rayon-swappable too — under a real rayon dependency
/// these become `ThreadPoolBuilder` threads.
pub struct Pool {
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Pool {
    /// Spawn `n` named threads (`<name>-0` … `<name>-{n-1}`), each
    /// running `body(worker_index)` to completion.
    pub fn spawn<F>(n: usize, name: &str, body: F) -> Pool
    where
        F: Fn(usize) + Send + Sync + 'static,
    {
        let body = std::sync::Arc::new(body);
        let handles = (0..n)
            .map(|i| {
                let body = std::sync::Arc::clone(&body);
                std::thread::Builder::new()
                    .name(format!("{name}-{i}"))
                    .spawn(move || body(i))
                    .expect("failed to spawn pool worker")
            })
            .collect();
        Pool { handles }
    }

    /// Number of worker threads in the pool.
    pub fn len(&self) -> usize {
        self.handles.len()
    }

    /// Whether the pool has no workers.
    pub fn is_empty(&self) -> bool {
        self.handles.is_empty()
    }

    /// Wait for every worker's body to return. Panics if a worker
    /// panicked (the service layer treats a dead worker as a bug, not a
    /// recoverable condition).
    pub fn join(self) {
        for h in self.handles {
            h.join().expect("pool worker panicked");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_and_values() {
        let items: Vec<u64> = (0..1000).collect();
        let seq: Vec<u64> = items.iter().map(|x| x * x + 1).collect();
        assert_eq!(par_map(&items, |x| x * x + 1), seq);
    }

    #[test]
    fn small_inputs_run_inline() {
        let items = [1, 2, 3];
        assert_eq!(par_map(&items, |x| x + 1), vec![2, 3, 4]);
        let empty: [i32; 0] = [];
        assert!(par_map(&empty, |x| *x).is_empty());
    }

    #[test]
    fn coarse_fan_out() {
        let items = ["a", "bb", "ccc"];
        assert_eq!(par_map_coarse(&items, |s| s.len()), vec![1, 2, 3]);
    }

    #[test]
    fn heavy_fan_out_preserves_order() {
        // below MIN_PAR_ITEMS, where par_map would run inline — the heavy
        // variant must still fan out and still stitch results in order
        let items: Vec<u64> = (0..13).collect();
        let seq: Vec<u64> = items.iter().map(|x| x * 3 + 1).collect();
        assert_eq!(par_map_heavy(&items, |x| x * 3 + 1), seq);
        let empty: [u64; 0] = [];
        assert!(par_map_heavy(&empty, |x| *x).is_empty());
        assert_eq!(par_map_heavy(&[7u64], |x| x + 1), vec![8]);
    }

    #[test]
    fn pool_runs_every_worker_and_joins() {
        use std::sync::atomic::{AtomicU64, Ordering};
        use std::sync::Arc;
        let hits = Arc::new(AtomicU64::new(0));
        let h = Arc::clone(&hits);
        let pool = Pool::spawn(4, "test-pool", move |i| {
            h.fetch_add(1 << (8 * i), Ordering::SeqCst);
        });
        assert_eq!(pool.len(), 4);
        assert!(!pool.is_empty());
        pool.join();
        // each worker index ran exactly once
        assert_eq!(hits.load(Ordering::SeqCst), 0x01010101);
    }

    #[test]
    fn shares_borrowed_state() {
        // the closure may borrow outer state (the sweep pattern: one
        // shared &Simulator, many configs)
        let offset = 10u64;
        let items: Vec<u64> = (0..500).collect();
        let out = par_map(&items, |x| x + offset);
        assert_eq!(out[499], 509);
    }
}

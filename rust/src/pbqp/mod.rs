//! Partitioned Boolean Quadratic Programming solver (Hames & Scholz [9]),
//! the optimisation engine of the primitive-selection stage.
//!
//! A PBQP instance assigns one choice per node minimising
//! `Σ node_cost[u][x_u] + Σ edge_cost[(u,v)][x_u][x_v]`.
//! Our instances: nodes = conv layers (choices = applicable primitives),
//! edges = dataflow (costs = data-layout transformation times).
//!
//! The solver applies the classic degree reductions — R0 (isolated), RI
//! (degree 1), RII (degree 2) — exactly, and falls back to the RN
//! heuristic for nodes of degree ≥ 3, then back-propagates choices.
//! Chain networks (VGG/AlexNet) solve exactly; branchy graphs
//! (GoogLeNet/ResNet) use RN at the junctions, matching [9]/[1].
//!
//! Internally the working graph is a flat edge arena driven by
//! degree-bucket worklists (see `solver.rs` for the representation notes);
//! the public [`Graph`]/[`solve`] surface is unchanged. For callers that
//! re-solve one topology under many node-cost re-pricings (the Pareto
//! budget sweep, the coordinator's compiled selection plans),
//! [`ReusableSolver`] keeps the merged-edge arena and elimination
//! machinery across solves, and [`ReusableSolver::solve_flat_into`]
//! runs a solve entirely out of a caller-retained [`SolveScratch`]
//! (zero steady-state allocation); [`solves_on_thread`] counts solves
//! per thread so warm serving paths can assert they ran none, and
//! [`template_builds_on_thread`] counts working-graph constructions so
//! plan-cache hits can assert they re-built nothing.

mod solver;

pub use solver::{
    solve, solves_on_thread, template_builds_on_thread, ReusableSolver, Solution, SolveScratch,
};

/// Infinite cost marker for forbidden (node, choice) combinations.
pub const INF: f64 = 1e30;

/// A PBQP problem instance.
#[derive(Debug, Clone)]
pub struct Graph {
    /// node_costs[u][i] — cost of choice i at node u.
    pub node_costs: Vec<Vec<f64>>,
    /// Edges with dense cost matrices: cost[i][j] for (choice_u, choice_v).
    pub edges: Vec<Edge>,
}

#[derive(Debug, Clone)]
pub struct Edge {
    pub u: usize,
    pub v: usize,
    /// Row-major |choices_u| x |choices_v|.
    pub cost: Vec<f64>,
}

impl Edge {
    pub fn new(u: usize, v: usize, cost: Vec<f64>) -> Self {
        Self { u, v, cost }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize, cols: usize) -> f64 {
        self.cost[i * cols + j]
    }
}

impl Graph {
    pub fn new(node_costs: Vec<Vec<f64>>) -> Self {
        Self { node_costs, edges: Vec::new() }
    }

    pub fn n_nodes(&self) -> usize {
        self.node_costs.len()
    }

    pub fn add_edge(&mut self, u: usize, v: usize, cost: Vec<f64>) {
        assert_ne!(u, v, "self loops are node costs");
        assert_eq!(
            cost.len(),
            self.node_costs[u].len() * self.node_costs[v].len(),
            "edge cost matrix shape"
        );
        self.edges.push(Edge::new(u, v, cost));
    }

    /// Total cost of an assignment.
    pub fn cost_of(&self, choice: &[usize]) -> f64 {
        let mut total = 0.0;
        for (u, &i) in choice.iter().enumerate() {
            total += self.node_costs[u][i];
        }
        for e in &self.edges {
            let cols = self.node_costs[e.v].len();
            total += e.at(choice[e.u], choice[e.v], cols);
        }
        total
    }

    /// Exhaustive minimum — exponential; for verification on small graphs.
    pub fn brute_force(&self) -> Solution {
        let n = self.n_nodes();
        let mut best = vec![0usize; n];
        let mut best_cost = f64::INFINITY;
        let mut cur = vec![0usize; n];
        loop {
            let c = self.cost_of(&cur);
            if c < best_cost {
                best_cost = c;
                best = cur.clone();
            }
            // odometer increment
            let mut pos = 0;
            loop {
                if pos == n {
                    return Solution { choice: best, cost: best_cost };
                }
                cur[pos] += 1;
                if cur[pos] < self.node_costs[pos].len() {
                    break;
                }
                cur[pos] = 0;
                pos += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain3() -> Graph {
        // 3 nodes, 2 choices each; edge penalises mismatched choices
        let mut g = Graph::new(vec![
            vec![1.0, 2.0],
            vec![5.0, 1.0],
            vec![1.0, 4.0],
        ]);
        let mismatch = vec![0.0, 3.0, 3.0, 0.0];
        g.add_edge(0, 1, mismatch.clone());
        g.add_edge(1, 2, mismatch);
        g
    }

    #[test]
    fn cost_of_known_assignment() {
        let g = chain3();
        // choices (0, 1, 0): 1 + 1 + 1 + edge(0,1)=3 + edge(1,0)=3 = 9
        assert_eq!(g.cost_of(&[0, 1, 0]), 9.0);
        // choices (1, 1, 1): 2 + 1 + 4 + 0 + 0 = 7
        assert_eq!(g.cost_of(&[1, 1, 1]), 7.0);
    }

    #[test]
    fn brute_force_finds_optimum() {
        let g = chain3();
        let sol = g.brute_force();
        // both (0,0,0) and (1,1,1) cost 7 — the optimum is 7 either way
        assert_eq!(sol.cost, 7.0);
        assert_eq!(g.cost_of(&sol.choice), 7.0);
    }

    #[test]
    #[should_panic]
    fn add_edge_checks_shape() {
        let mut g = Graph::new(vec![vec![0.0; 2], vec![0.0; 3]]);
        g.add_edge(0, 1, vec![0.0; 5]);
    }
}

//! The reduction-based PBQP solver.
//!
//! Working representation: **flat arenas**. Node costs live in one flat
//! `Vec<f64>` with per-node offsets (row `u` spans `off[u]..off[u+1]`);
//! each merged edge is stored once, in one orientation, with its dense
//! cost matrix carved out of a flat matrix arena and dead edges
//! tombstoned — no per-node `HashMap` adjacency, no transposed duplicate
//! matrices (the opposite orientation is an index swap at the access
//! site), and no per-node heap rows. Node elimination is driven by
//! **degree buckets**: candidate nodes of degree 0/1/2 sit in three
//! lazily-validated worklists, so picking the next reducible node is
//! O(1) instead of an O(n) rescan per elimination (O(n²) overall on the
//! old representation — visible on the 1024-node bench chains).
//! Degree-≥3 nodes (the RN heuristic) keep the original
//! min-degree/min-index scan, preserving the old solver's choice rule
//! where reduction order can matter.
//!
//! R0/RI/RII are exact reductions, so any order of applying them to
//! degree ≤2 nodes reaches the same optimum — bucket order differing from
//! the old lowest-index scan cannot change the objective on reducible
//! graphs (pinned against `brute_force` in rust/tests/proptests.rs).
//! Reductions eliminate nodes onto a stack; back-propagation resolves
//! choices in reverse elimination order.
//!
//! For warm serving paths, [`ReusableSolver::solve_flat_into`] runs the
//! whole solve out of a caller-owned [`SolveScratch`]: the working
//! arena is `clone_from`-restored into retained buffers, elimination
//! tables and RII deltas append to flat scratch arenas, and the choice
//! vector is reused — after a warm-up solve the steady-state path
//! performs **zero heap allocations** (pinned by the counting-allocator
//! test in `rust/tests/alloc_counter.rs`).

use super::{Edge, Graph, INF};
use std::cell::Cell;

/// A solved assignment.
#[derive(Debug, Clone, PartialEq)]
pub struct Solution {
    pub choice: Vec<usize>,
    pub cost: f64,
}

thread_local! {
    /// Per-thread count of PBQP solves ([`solve`] + [`ReusableSolver::solve_with`]
    /// + [`ReusableSolver::solve_flat_into`]).
    static SOLVES: Cell<u64> = const { Cell::new(0) };
    /// Per-thread count of working-graph constructions ([`Work::from_graph`]):
    /// one per fresh [`solve`] and one per [`ReusableSolver::new`].
    static GRAPH_BUILDS: Cell<u64> = const { Cell::new(0) };
}

/// Number of PBQP solves run so far **on the calling thread**. The
/// counter is thread-local on purpose: tests asserting "this warm path
/// ran zero solves" stay exact even while other test threads solve
/// concurrently in the same process.
pub fn solves_on_thread() -> u64 {
    SOLVES.with(|c| c.get())
}

/// Number of PBQP working-graph/template constructions so far **on the
/// calling thread** — one per fresh [`solve`] and one per
/// [`ReusableSolver::new`]. Same thread-local convention as
/// [`solves_on_thread`]: warm plan-cache paths can assert they re-built
/// zero templates while still counting their (cheap, arena-reusing)
/// solves.
pub fn template_builds_on_thread() -> u64 {
    GRAPH_BUILDS.with(|c| c.get())
}

fn note_solve() {
    SOLVES.with(|c| c.set(c.get() + 1));
}

fn note_graph_build() {
    GRAPH_BUILDS.with(|c| c.set(c.get() + 1));
}

/// Records how an eliminated node's choice is recovered. Lookup tables
/// are ranges into the flat `ReduceScratch::tables` arena (offset only;
/// lengths are implied by the dependents' arities), keeping eliminations
/// allocation-free.
#[derive(Clone, Copy)]
enum Elim {
    /// R0: choice independent of any neighbour.
    Free { node: usize },
    /// RI: choice depends on one neighbour's choice.
    OneDep { node: usize, dep: usize, table: usize },
    /// RII: choice depends on two neighbours.
    TwoDep { node: usize, dep_a: usize, dep_b: usize, table: usize, cols_b: usize },
    /// RN: choice fixed heuristically during reduction.
    Fixed { node: usize, choice: usize },
}

/// One arena slot: a merged u–v edge whose dense |choices_u| x
/// |choices_v| cost matrix is stored row-major at `mat..` in the flat
/// `Work::mats` arena. The v-major view is the index swap
/// `mat[j * cols + i]`; see [`entry`].
#[derive(Clone, Copy)]
struct EdgeSlot {
    u: usize,
    v: usize,
    /// Start of this edge's matrix in `Work::mats`.
    mat: usize,
    alive: bool,
}

impl EdgeSlot {
    #[inline]
    fn other(&self, node: usize) -> usize {
        if self.u == node {
            self.v
        } else {
            self.u
        }
    }
}

/// Edge matrix entry for (choice `i` at `node`, choice `j` at the other
/// endpoint), regardless of stored orientation. `cols` is the stored
/// column count (= |choices of slot.v|); `mat` is the matrix's tail of
/// the flat arena.
#[inline]
fn entry(mat: &[f64], node_is_u: bool, cols: usize, i: usize, j: usize) -> f64 {
    if node_is_u {
        mat[i * cols + j]
    } else {
        mat[j * cols + i]
    }
}

#[derive(Clone, Default)]
struct Work {
    /// Flat node-cost arena; node u's row is costs[off[u]..off[u+1]].
    costs: Vec<f64>,
    /// n+1 row offsets into `costs`.
    off: Vec<usize>,
    /// Flat edge arena; slots are tombstoned, never removed.
    edges: Vec<EdgeSlot>,
    /// Flat backing store for every edge matrix (RII deltas append here).
    mats: Vec<f64>,
    /// incident[u] -> arena ids (pruned lazily of dead slots).
    incident: Vec<Vec<usize>>,
    /// Live-edge count per node.
    deg: Vec<usize>,
    alive: Vec<bool>,
    /// Candidate worklists for degrees 0/1/2 (entries validated on pop).
    buckets: [Vec<usize>; 3],
}

impl Work {
    fn from_graph(g: &Graph) -> Self {
        note_graph_build();
        let n = g.n_nodes();
        let mut off = Vec::with_capacity(n + 1);
        off.push(0);
        let mut costs = Vec::new();
        for row in &g.node_costs {
            costs.extend_from_slice(row);
            off.push(costs.len());
        }
        let mut w = Self {
            costs,
            off,
            edges: Vec::with_capacity(g.edges.len()),
            mats: Vec::new(),
            incident: vec![Vec::new(); n],
            deg: vec![0; n],
            alive: vec![true; n],
            buckets: [Vec::new(), Vec::new(), Vec::new()],
        };
        for e in &g.edges {
            // merge parallel edges by summing
            if let Some(eid) = w.find_edge(e.u, e.v) {
                let cols = w.arity(e.v);
                w.accumulate(eid, e.u, &e.cost, cols);
            } else {
                w.add_edge(e.u, e.v, &e.cost);
            }
        }
        // seed the worklists (reverse so pops start at low indices)
        for u in (0..n).rev() {
            if w.deg[u] <= 2 {
                w.buckets[w.deg[u]].push(u);
            }
        }
        w
    }

    /// Restore `self` to a pristine copy of `src`, reusing every retained
    /// buffer (field-wise `clone_from`; `Vec::clone_from` keeps capacity).
    fn reset_from(&mut self, src: &Work) {
        self.costs.clone_from(&src.costs);
        self.off.clone_from(&src.off);
        self.edges.clone_from(&src.edges);
        self.mats.clone_from(&src.mats);
        // `incident` is the one nested buffer: a plain `clone_from` would
        // drop the tail's inner vectors whenever a smaller template follows
        // a larger one and re-allocate them when the larger one returns, so
        // a scratch hopping between plans would never reach the zero-alloc
        // steady state. Overwrite the prefix element-wise and keep any
        // surplus inner vectors alive as a capacity pool — every `incident`
        // access is bounded by `off`'s node count, so entries past
        // `src.n_nodes()` are never read.
        for (dst, s) in self.incident.iter_mut().zip(&src.incident) {
            dst.clone_from(s);
        }
        if self.incident.len() < src.incident.len() {
            self.incident.extend(src.incident[self.incident.len()..].iter().cloned());
        }
        self.deg.clone_from(&src.deg);
        self.alive.clone_from(&src.alive);
        for (dst, s) in self.buckets.iter_mut().zip(&src.buckets) {
            dst.clone_from(s);
        }
    }

    #[inline]
    fn n_nodes(&self) -> usize {
        self.off.len() - 1
    }

    /// Choice count of node u.
    #[inline]
    fn arity(&self, u: usize) -> usize {
        self.off[u + 1] - self.off[u]
    }

    /// Node u's cost row.
    #[inline]
    fn row(&self, u: usize) -> &[f64] {
        &self.costs[self.off[u]..self.off[u + 1]]
    }

    /// Live edge between a and b, if any (edges are merged, so unique).
    fn find_edge(&self, a: usize, b: usize) -> Option<usize> {
        self.incident[a]
            .iter()
            .copied()
            .find(|&e| self.edges[e].alive && (self.edges[e].u == b || self.edges[e].v == b))
    }

    /// Collect the live arena ids incident to `u` into `out`. Only
    /// called on the node being eliminated this iteration, so its
    /// incident list is surrendered (cleared, capacity kept) rather than
    /// restored — a dead node's list is never read again.
    fn collect_live(&mut self, u: usize, out: &mut Vec<usize>) {
        out.clear();
        out.extend(self.incident[u].iter().copied().filter(|&e| self.edges[e].alive));
        self.incident[u].clear();
    }

    fn add_edge(&mut self, a: usize, b: usize, mat: &[f64]) {
        let id = self.edges.len();
        let base = self.mats.len();
        self.mats.extend_from_slice(mat);
        self.edges.push(EdgeSlot { u: a, v: b, mat: base, alive: true });
        self.incident[a].push(id);
        self.incident[b].push(id);
        self.deg[a] += 1;
        self.deg[b] += 1;
    }

    /// Sum `mat` (oriented a-rows x other-cols, `cols` columns) into an
    /// existing slot, transposing if the slot is stored the other way.
    fn accumulate(&mut self, eid: usize, a: usize, mat: &[f64], cols: usize) {
        let slot = self.edges[eid];
        let dst = &mut self.mats[slot.mat..slot.mat + mat.len()];
        if slot.u == a {
            for (x, y) in dst.iter_mut().zip(mat) {
                *x += *y;
            }
        } else {
            let rows = mat.len() / cols;
            for i in 0..rows {
                for j in 0..cols {
                    dst[j * rows + i] += mat[i * cols + j];
                }
            }
        }
    }

    fn kill_edge(&mut self, eid: usize) {
        let (a, b) = (self.edges[eid].u, self.edges[eid].v);
        self.edges[eid].alive = false;
        self.deg[a] -= 1;
        self.deg[b] -= 1;
    }

    /// Re-enqueue a node whose degree changed (no-op for degree >= 3;
    /// such nodes are found by the RN scan).
    fn touch(&mut self, u: usize) {
        if self.alive[u] && self.deg[u] <= 2 {
            self.buckets[self.deg[u]].push(u);
        }
    }

    /// Pop the next reducible node from the worklists: lowest degree
    /// class first, entries revalidated against the current degree.
    fn next_bucket(&mut self) -> Option<(usize, usize)> {
        let mut d = 0;
        while d < 3 {
            let Some(u) = self.buckets[d].pop() else {
                d += 1;
                continue;
            };
            if !self.alive[u] {
                continue;
            }
            let du = self.deg[u];
            if du == d {
                return Some((u, d));
            }
            if du < 3 {
                // stale entry: reroute, and restart from the lower class
                self.buckets[du].push(u);
                if du < d {
                    d = du;
                }
            }
        }
        None
    }

    /// Min-degree, min-index alive node (the RN fallback — identical to
    /// the old solver's global scan rule).
    fn scan_min(&self) -> Option<(usize, usize)> {
        let mut best: Option<(usize, usize)> = None; // (node, degree)
        for u in 0..self.n_nodes() {
            if !self.alive[u] {
                continue;
            }
            let d = self.deg[u];
            if best.map_or(true, |(_, bd)| d < bd) {
                best = Some((u, d));
            }
        }
        best
    }
}

/// Retained buffers for one reduction pass: the live-edge list and cost
/// row of the node being eliminated, the RII delta matrix, the
/// elimination stack, and the flat arena backing every [`Elim`] lookup
/// table. All reused across solves.
#[derive(Default)]
struct ReduceScratch {
    live: Vec<usize>,
    row: Vec<f64>,
    delta: Vec<f64>,
    stack: Vec<Elim>,
    tables: Vec<usize>,
}

/// Per-caller (typically per-worker) scratch arenas for
/// [`ReusableSolver::solve_flat_into`]: the `Work` clone target, the
/// reduction buffers, and the output choice vector. The first solve
/// primes the arenas (allocating); every later solve with the same
/// solver reuses them — the steady state is allocation-free.
#[derive(Default)]
pub struct SolveScratch {
    work: Work,
    primed: bool,
    reduce: ReduceScratch,
    choice: Vec<usize>,
}

/// Solve a PBQP instance. Exact on graphs that reduce fully with R0–RII
/// (trees, chains, series-parallel); heuristic (RN) otherwise.
///
/// ```
/// use primsel::pbqp::{solve, Graph};
///
/// // two nodes, two choices each; the edge penalises mismatched choices
/// let mut g = Graph::new(vec![vec![1.0, 3.0], vec![4.0, 1.0]]);
/// g.add_edge(0, 1, vec![0.0, 2.0, 2.0, 0.0]);
///
/// let sol = solve(&g);
/// assert_eq!(g.cost_of(&sol.choice), sol.cost);
/// // a single edge reduces exactly with RI: optimal by construction
/// assert_eq!(sol.cost, g.brute_force().cost);
/// ```
pub fn solve(g: &Graph) -> Solution {
    let n = g.n_nodes();
    if n == 0 {
        return Solution { choice: vec![], cost: 0.0 };
    }
    note_solve();
    let mut w = Work::from_graph(g);
    let mut sc = ReduceScratch::default();
    let mut choice = Vec::new();
    reduce_and_backprop(&mut w, &mut sc, &mut choice);
    let cost = g.cost_of(&choice);
    Solution { choice, cost }
}

/// The reduction loop plus back-propagation, shared between [`solve`]
/// and the [`ReusableSolver`] paths: eliminate nodes onto a stack
/// (R0/RI/RII exactly, RN heuristically), then resolve choices in
/// reverse elimination order. Consumes `w`'s worklists and mutates its
/// node costs; the caller must compute the objective against pristine
/// costs. `choice` is cleared and refilled (capacity reused).
fn reduce_and_backprop(w: &mut Work, sc: &mut ReduceScratch, choice: &mut Vec<usize>) {
    let n = w.n_nodes();
    sc.stack.clear();
    sc.tables.clear();

    loop {
        let next = w.next_bucket().or_else(|| w.scan_min());
        let Some((u, deg)) = next else { break };
        match deg {
            0 => sc.stack.push(Elim::Free { node: u }),
            1 => reduce_ri(w, u, sc),
            2 => reduce_rii(w, u, sc),
            _ => reduce_rn(w, u, sc),
        }
        w.alive[u] = false;
    }

    // back-propagate
    choice.clear();
    choice.resize(n, usize::MAX);
    for elim in sc.stack.iter().rev() {
        match *elim {
            Elim::Free { node } => {
                choice[node] = argmin(w.row(node)).0;
            }
            Elim::OneDep { node, dep, table } => {
                choice[node] = sc.tables[table + choice[dep]];
            }
            Elim::TwoDep { node, dep_a, dep_b, table, cols_b } => {
                choice[node] = sc.tables[table + choice[dep_a] * cols_b + choice[dep_b]];
            }
            Elim::Fixed { node, choice: c } => {
                choice[node] = c;
            }
        }
    }
}

/// A PBQP solver specialised to one graph *topology*, reusable across
/// node-cost re-pricings.
///
/// Construction pays the [`Graph`] → arena conversion once (parallel
/// edges merged into dense matrices, degree buckets seeded);
/// [`Self::solve_with`] then clones the pristine arena, swaps in new
/// node costs and runs the shared reduction loop. Because the merged
/// edge matrices, the bucket seeding and the reduction rules depend
/// only on the topology and the cost *values* (never on how the arena
/// was built), a `solve_with` call is bit-identical to [`solve`] on a
/// graph carrying the same node costs — the property the Pareto sweep
/// (`selection::pareto`) and the coordinator's compiled selection plans
/// (`selection::plan`) rely on when they re-price node costs without
/// rebuilding the graph.
///
/// ```
/// use primsel::pbqp::{solve, Graph, ReusableSolver};
///
/// let mut g = Graph::new(vec![vec![1.0, 3.0], vec![4.0, 1.0]]);
/// g.add_edge(0, 1, vec![0.0, 2.0, 2.0, 0.0]);
/// let solver = ReusableSolver::new(&g);
///
/// // same costs: bit-identical to a fresh solve
/// let fresh = solve(&g);
/// let reused = solver.solve_with(&g.node_costs);
/// assert_eq!(reused.choice, fresh.choice);
/// assert_eq!(reused.cost, fresh.cost);
///
/// // re-priced costs reuse the merged-edge arena
/// let repriced = solver.solve_with(&[vec![9.0, 9.0], vec![0.0, 9.0]]);
/// assert_eq!(repriced.choice[1], 0);
/// ```
pub struct ReusableSolver {
    /// Pristine post-merge arena (worklists seeded, nothing eliminated).
    template: Work,
    /// The original edges in insertion order, for the objective sum —
    /// mirrors [`Graph::cost_of`] exactly.
    edges: Vec<Edge>,
}

impl ReusableSolver {
    /// Build the reusable arena for `g`'s topology (and cost shapes).
    pub fn new(g: &Graph) -> Self {
        Self { template: Work::from_graph(g), edges: g.edges.clone() }
    }

    /// Flat node-cost row offsets of this solver's template: node `u`'s
    /// costs span `offsets()[u]..offsets()[u+1]` of a flat cost arena
    /// (see [`Self::solve_flat_into`]). Length is `n_nodes + 1`.
    pub fn offsets(&self) -> &[usize] {
        &self.template.off
    }

    /// Total flat cost-arena length (= `offsets().last()`).
    pub fn flat_len(&self) -> usize {
        self.template.costs.len()
    }

    /// Solve with `node_costs` in place of the graph's own. Each row
    /// must have the same length as the corresponding row the solver
    /// was built with.
    pub fn solve_with(&self, node_costs: &[Vec<f64>]) -> Solution {
        assert_eq!(node_costs.len(), self.template.n_nodes(), "node count mismatch");
        for (u, fresh) in node_costs.iter().enumerate() {
            assert_eq!(fresh.len(), self.template.arity(u), "choice count mismatch at node {u}");
        }
        if node_costs.is_empty() {
            return Solution { choice: vec![], cost: 0.0 };
        }
        let mut flat = Vec::with_capacity(self.template.costs.len());
        for row in node_costs {
            flat.extend_from_slice(row);
        }
        let mut scratch = SolveScratch::default();
        let (cost, choice) = self.solve_flat_into(&flat, &mut scratch);
        Solution { choice: choice.to_vec(), cost }
    }

    /// Solve with a **flat** node-cost arena (row `u` at
    /// `offsets()[u]..offsets()[u+1]`), running entirely out of
    /// `scratch`'s retained buffers. Bit-identical to [`Self::solve_with`]
    /// on the same costs; after the first (priming) call, the steady
    /// state allocates nothing.
    ///
    /// Returns the objective and a borrow of the choice vector (one
    /// choice index per node, valid until the next solve on `scratch`).
    ///
    /// ```
    /// use primsel::pbqp::{solve, Graph, ReusableSolver, SolveScratch};
    ///
    /// let mut g = Graph::new(vec![vec![1.0, 3.0], vec![4.0, 1.0]]);
    /// g.add_edge(0, 1, vec![0.0, 2.0, 2.0, 0.0]);
    /// let solver = ReusableSolver::new(&g);
    /// assert_eq!(solver.offsets(), &[0, 2, 4]);
    ///
    /// let mut scratch = SolveScratch::default();
    /// let (cost, choice) = solver.solve_flat_into(&[1.0, 3.0, 4.0, 1.0], &mut scratch);
    /// let fresh = solve(&g);
    /// assert_eq!(choice, &fresh.choice[..]);
    /// assert_eq!(cost, fresh.cost);
    /// ```
    pub fn solve_flat_into<'s>(
        &self,
        flat_costs: &[f64],
        scratch: &'s mut SolveScratch,
    ) -> (f64, &'s [usize]) {
        assert_eq!(flat_costs.len(), self.template.costs.len(), "flat cost arena length mismatch");
        if self.template.n_nodes() == 0 {
            scratch.choice.clear();
            return (0.0, &scratch.choice);
        }
        note_solve();
        if scratch.primed {
            scratch.work.reset_from(&self.template);
        } else {
            scratch.work = self.template.clone();
            scratch.primed = true;
        }
        scratch.work.costs.copy_from_slice(flat_costs);
        reduce_and_backprop(&mut scratch.work, &mut scratch.reduce, &mut scratch.choice);
        let cost = cost_of_flat(flat_costs, &self.template.off, &self.edges, &scratch.choice);
        (cost, &scratch.choice)
    }

    /// Total cost of `choice` under an explicit flat node-cost arena
    /// (laid out per [`Self::offsets`]), in [`Graph::cost_of`]'s exact
    /// summation order — so pricing a solve with one arena and costing
    /// its choice under another (e.g. penalised vs true times) stays
    /// bit-identical to the nested-`Vec` path.
    pub fn cost_of_flat(&self, flat_costs: &[f64], choice: &[usize]) -> f64 {
        cost_of_flat(flat_costs, &self.template.off, &self.edges, choice)
    }
}

/// Total assignment cost under an explicit flat node-cost arena — the
/// same summation order as [`Graph::cost_of`] (nodes in index order,
/// then edges in insertion order), so the two are bit-identical on
/// equal inputs.
fn cost_of_flat(flat: &[f64], off: &[usize], edges: &[Edge], choice: &[usize]) -> f64 {
    let mut total = 0.0;
    for (u, &i) in choice.iter().enumerate() {
        total += flat[off[u] + i];
    }
    for e in edges {
        let cols = off[e.v + 1] - off[e.v];
        total += e.at(choice[e.u], choice[e.v], cols);
    }
    total
}

fn argmin(v: &[f64]) -> (usize, f64) {
    let mut best = 0;
    for i in 1..v.len() {
        if v[i] < v[best] {
            best = i;
        }
    }
    (best, v[best])
}

/// RI: fold node u (degree 1) into its neighbour v:
/// v_cost[j] += min_i (u_cost[i] + edge[i][j]).
fn reduce_ri(w: &mut Work, u: usize, sc: &mut ReduceScratch) {
    w.collect_live(u, &mut sc.live);
    let eid = sc.live[0];
    let slot = w.edges[eid];
    let v = slot.other(u);
    let u_first = slot.u == u;
    let ru = w.arity(u);
    let rv = w.arity(v);
    let cols = if u_first { rv } else { ru };
    let t0 = sc.tables.len();
    sc.tables.resize(t0 + rv, 0);
    sc.row.clear();
    sc.row.extend_from_slice(w.row(u));
    let ov = w.off[v];
    for j in 0..rv {
        let mat = &w.mats[slot.mat..];
        let mut best_i = 0;
        let mut best = f64::INFINITY;
        for (i, &cui) in sc.row.iter().enumerate() {
            let c = cui + entry(mat, u_first, cols, i, j);
            if c < best {
                best = c;
                best_i = i;
            }
        }
        w.costs[ov + j] += best;
        sc.tables[t0 + j] = best_i;
    }
    w.kill_edge(eid);
    w.touch(v);
    sc.stack.push(Elim::OneDep { node: u, dep: v, table: t0 });
}

/// RII: fold node u (degree 2, neighbours a and b) into a new a–b edge:
/// delta[j][k] = min_i (u_cost[i] + e_a[i][j] + e_b[i][k]).
fn reduce_rii(w: &mut Work, u: usize, sc: &mut ReduceScratch) {
    w.collect_live(u, &mut sc.live);
    let (ea, eb) = (sc.live[0], sc.live[1]);
    let sa = w.edges[ea];
    let sb = w.edges[eb];
    let a = sa.other(u);
    let b = sb.other(u);
    let a_u_first = sa.u == u;
    let b_u_first = sb.u == u;
    let ru = w.arity(u);
    let ra = w.arity(a);
    let rb = w.arity(b);
    let cols_a = if a_u_first { ra } else { ru };
    let cols_b = if b_u_first { rb } else { ru };
    sc.row.clear();
    sc.row.extend_from_slice(w.row(u));
    sc.delta.clear();
    sc.delta.resize(ra * rb, 0.0);
    let t0 = sc.tables.len();
    sc.tables.resize(t0 + ra * rb, 0);
    {
        let mat_a = &w.mats[sa.mat..];
        let mat_b = &w.mats[sb.mat..];
        for j in 0..ra {
            for k in 0..rb {
                let mut best_i = 0;
                let mut best = f64::INFINITY;
                for (i, &cui) in sc.row.iter().enumerate() {
                    let c = cui
                        + entry(mat_a, a_u_first, cols_a, i, j)
                        + entry(mat_b, b_u_first, cols_b, i, k);
                    if c < best {
                        best = c;
                        best_i = i;
                    }
                }
                sc.delta[j * rb + k] = best;
                sc.tables[t0 + j * rb + k] = best_i;
            }
        }
    }
    w.kill_edge(ea);
    w.kill_edge(eb);
    if let Some(eid) = w.find_edge(a, b) {
        w.accumulate(eid, a, &sc.delta, rb);
    } else {
        w.add_edge(a, b, &sc.delta);
    }
    w.touch(a);
    w.touch(b);
    sc.stack.push(Elim::TwoDep { node: u, dep_a: a, dep_b: b, table: t0, cols_b: rb });
}

/// RN heuristic for degree >= 3: pick the locally best choice
/// (node cost + sum over neighbours of the best-case edge+neighbour cost),
/// commit it, and push the chosen row of each edge into the neighbour.
fn reduce_rn(w: &mut Work, u: usize, sc: &mut ReduceScratch) {
    w.collect_live(u, &mut sc.live);
    sc.row.clear();
    sc.row.extend_from_slice(w.row(u));
    let mut best_i = 0;
    let mut best = f64::INFINITY;
    for (i, &cui) in sc.row.iter().enumerate() {
        if cui >= INF {
            continue;
        }
        let mut c = cui;
        for &eid in &sc.live {
            let slot = w.edges[eid];
            let v = slot.other(u);
            let u_first = slot.u == u;
            let rv = w.arity(v);
            let cols = if u_first { rv } else { sc.row.len() };
            let mat = &w.mats[slot.mat..];
            let mut m = f64::INFINITY;
            for (j, &cvj) in w.row(v).iter().enumerate() {
                let e = entry(mat, u_first, cols, i, j) + cvj;
                if e < m {
                    m = e;
                }
            }
            c += m;
        }
        if c < best {
            best = c;
            best_i = i;
        }
    }
    for &eid in &sc.live {
        let slot = w.edges[eid];
        let v = slot.other(u);
        let u_first = slot.u == u;
        let rv = w.arity(v);
        let cols = if u_first { rv } else { sc.row.len() };
        let ov = w.off[v];
        for j in 0..rv {
            let add = entry(&w.mats[slot.mat..], u_first, cols, best_i, j);
            w.costs[ov + j] += add;
        }
        w.kill_edge(eid);
        w.touch(v);
    }
    sc.stack.push(Elim::Fixed { node: u, choice: best_i });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::noise::SplitMix64;

    fn random_graph(rng: &mut SplitMix64, n: usize, max_choices: usize, edge_p: f64) -> Graph {
        let node_costs: Vec<Vec<f64>> = (0..n)
            .map(|_| {
                let c = 1 + (rng.next_u64() as usize) % max_choices;
                (0..c).map(|_| rng.next_f64() * 10.0).collect()
            })
            .collect();
        let mut g = Graph::new(node_costs);
        for u in 0..n {
            for v in (u + 1)..n {
                if rng.next_f64() < edge_p {
                    let len = g.node_costs[u].len() * g.node_costs[v].len();
                    let cost: Vec<f64> = (0..len).map(|_| rng.next_f64() * 5.0).collect();
                    g.add_edge(u, v, cost);
                }
            }
        }
        g
    }

    #[test]
    fn exact_on_chains() {
        let mut rng = SplitMix64::new(11);
        for _ in 0..30 {
            let n = 2 + (rng.next_u64() as usize) % 6;
            let node_costs: Vec<Vec<f64>> = (0..n)
                .map(|_| (0..3).map(|_| rng.next_f64() * 10.0).collect())
                .collect();
            let mut g = Graph::new(node_costs);
            for u in 0..n - 1 {
                let cost: Vec<f64> = (0..9).map(|_| rng.next_f64() * 5.0).collect();
                g.add_edge(u, u + 1, cost);
            }
            let sol = solve(&g);
            let exact = g.brute_force();
            assert!(
                (sol.cost - exact.cost).abs() < 1e-9,
                "chain not exact: {} vs {}",
                sol.cost,
                exact.cost
            );
        }
    }

    #[test]
    fn exact_on_trees() {
        let mut rng = SplitMix64::new(23);
        for _ in 0..20 {
            let n = 3 + (rng.next_u64() as usize) % 6;
            let node_costs: Vec<Vec<f64>> = (0..n)
                .map(|_| (0..2).map(|_| rng.next_f64() * 10.0).collect())
                .collect();
            let mut g = Graph::new(node_costs);
            for v in 1..n {
                let u = (rng.next_u64() as usize) % v;
                let cost: Vec<f64> = (0..4).map(|_| rng.next_f64() * 5.0).collect();
                g.add_edge(u, v, cost);
            }
            let sol = solve(&g);
            let exact = g.brute_force();
            assert!((sol.cost - exact.cost).abs() < 1e-9);
        }
    }

    #[test]
    fn near_optimal_on_random_graphs() {
        // RN is a heuristic; require the known-good bound on small graphs
        let mut rng = SplitMix64::new(5);
        let mut total_gap = 0.0;
        for _ in 0..25 {
            let g = random_graph(&mut rng, 6, 3, 0.5);
            let sol = solve(&g);
            let exact = g.brute_force();
            assert!(sol.cost >= exact.cost - 1e-9);
            total_gap += (sol.cost - exact.cost) / exact.cost.max(1e-9);
        }
        assert!(total_gap / 25.0 < 0.05, "mean RN gap {}", total_gap / 25.0);
    }

    #[test]
    fn solution_choice_is_valid() {
        let mut rng = SplitMix64::new(9);
        let g = random_graph(&mut rng, 10, 4, 0.3);
        let sol = solve(&g);
        assert_eq!(sol.choice.len(), 10);
        for (u, &c) in sol.choice.iter().enumerate() {
            assert!(c < g.node_costs[u].len());
        }
        assert!((g.cost_of(&sol.choice) - sol.cost).abs() < 1e-9);
    }

    #[test]
    fn single_node() {
        let g = Graph::new(vec![vec![3.0, 1.0, 2.0]]);
        let sol = solve(&g);
        assert_eq!(sol.choice, vec![1]);
        assert_eq!(sol.cost, 1.0);
    }

    #[test]
    fn empty_graph() {
        let g = Graph::new(vec![]);
        assert_eq!(solve(&g).cost, 0.0);
    }

    #[test]
    fn parallel_edges_merge() {
        let mut g = Graph::new(vec![vec![0.0, 0.0], vec![0.0, 0.0]]);
        g.add_edge(0, 1, vec![1.0, 0.0, 0.0, 1.0]);
        g.add_edge(0, 1, vec![1.0, 0.0, 0.0, 1.0]);
        let sol = solve(&g);
        assert_eq!(sol.cost, 0.0); // mismatched choices are free
        assert_ne!(sol.choice[0], sol.choice[1]);
    }

    #[test]
    fn respects_infinite_costs() {
        let mut g = Graph::new(vec![vec![INF, 1.0], vec![1.0, INF]]);
        g.add_edge(0, 1, vec![0.0; 4]);
        let sol = solve(&g);
        assert_eq!(sol.choice, vec![1, 0]);
    }

    #[test]
    fn rii_merges_into_existing_edge() {
        // triangle: eliminating any corner folds an RII delta into the
        // opposite edge; the result must still be exact (triangles reduce
        // fully via RII then RI then R0)
        let mut rng = SplitMix64::new(77);
        for _ in 0..20 {
            let node_costs: Vec<Vec<f64>> =
                (0..3).map(|_| (0..3).map(|_| rng.next_f64() * 9.0).collect()).collect();
            let mut g = Graph::new(node_costs);
            for (u, v) in [(0, 1), (0, 2), (1, 2)] {
                g.add_edge(u, v, (0..9).map(|_| rng.next_f64() * 4.0).collect());
            }
            let sol = solve(&g);
            let exact = g.brute_force();
            assert!((sol.cost - exact.cost).abs() < 1e-9, "{} vs {}", sol.cost, exact.cost);
        }
    }

    #[test]
    fn asymmetric_choice_counts_both_orientations() {
        // ragged choice counts exercise the orientation-swapping entry
        // accessor on 1x4, 4x2 and 2x1 matrices
        let mut g = Graph::new(vec![vec![1.0], vec![0.5, 9.0, 0.1, 3.0], vec![2.0, 0.3]]);
        g.add_edge(0, 1, vec![0.0, 1.0, 5.0, 1.0]);
        g.add_edge(1, 2, vec![1.0, 0.0, 2.0, 2.0, 0.0, 4.0, 1.0, 1.0]);
        let sol = solve(&g);
        let exact = g.brute_force();
        assert!((sol.cost - exact.cost).abs() < 1e-9);
    }

    #[test]
    fn reusable_solver_matches_fresh_solve_bit_for_bit() {
        // across chains, trees and dense (RN-heuristic) graphs: swapping
        // re-priced costs into the cloned arena must equal building a
        // fresh graph with those costs — same choice, same cost bits
        let mut rng = SplitMix64::new(0x5EED);
        for case in 0..40 {
            let g = match case % 3 {
                0 => {
                    let n = 2 + (rng.next_u64() as usize) % 6;
                    let node_costs: Vec<Vec<f64>> = (0..n)
                        .map(|_| (0..3).map(|_| rng.next_f64() * 10.0).collect())
                        .collect();
                    let mut g = Graph::new(node_costs);
                    for u in 0..n - 1 {
                        g.add_edge(u, u + 1, (0..9).map(|_| rng.next_f64() * 5.0).collect());
                    }
                    g
                }
                _ => random_graph(&mut rng, 7, 3, 0.5),
            };
            let solver = ReusableSolver::new(&g);
            for _ in 0..4 {
                // re-price: same shapes, new values
                let costs: Vec<Vec<f64>> = g
                    .node_costs
                    .iter()
                    .map(|row| row.iter().map(|_| rng.next_f64() * 12.0).collect())
                    .collect();
                let mut fresh_graph = Graph::new(costs.clone());
                for e in &g.edges {
                    fresh_graph.add_edge(e.u, e.v, e.cost.clone());
                }
                let fresh = solve(&fresh_graph);
                let reused = solver.solve_with(&costs);
                assert_eq!(reused.choice, fresh.choice, "case {case}");
                assert_eq!(reused.cost, fresh.cost, "case {case}");
            }
        }
    }

    #[test]
    fn flat_scratch_path_matches_solve_with_bit_for_bit() {
        // one scratch reused across many graphs' worth of re-pricings:
        // the clone_from-restored arena must keep matching the allocating
        // path exactly (same choice, same cost bits)
        let mut rng = SplitMix64::new(0xA7E4A);
        for case in 0..25 {
            let g = random_graph(&mut rng, 8, 3, 0.4);
            let solver = ReusableSolver::new(&g);
            let mut scratch = SolveScratch::default();
            for round in 0..5 {
                let costs: Vec<Vec<f64>> = g
                    .node_costs
                    .iter()
                    .map(|row| row.iter().map(|_| rng.next_f64() * 12.0).collect())
                    .collect();
                let flat: Vec<f64> = costs.iter().flatten().copied().collect();
                let boxed = solver.solve_with(&costs);
                let (cost, choice) = solver.solve_flat_into(&flat, &mut scratch);
                assert_eq!(choice, &boxed.choice[..], "case {case} round {round}");
                assert_eq!(cost, boxed.cost, "case {case} round {round}");
            }
        }
    }

    #[test]
    fn offsets_describe_the_flat_layout() {
        let g = Graph::new(vec![vec![1.0], vec![0.5, 9.0, 0.1], vec![2.0, 0.3]]);
        let solver = ReusableSolver::new(&g);
        assert_eq!(solver.offsets(), &[0, 1, 4, 6]);
        assert_eq!(solver.flat_len(), 6);
    }

    #[test]
    #[should_panic(expected = "choice count mismatch")]
    fn reusable_solver_rejects_misshapen_costs() {
        let g = Graph::new(vec![vec![1.0, 2.0], vec![3.0]]);
        ReusableSolver::new(&g).solve_with(&[vec![1.0], vec![3.0]]);
    }

    #[test]
    #[should_panic(expected = "flat cost arena length mismatch")]
    fn flat_path_rejects_wrong_arena_length() {
        let g = Graph::new(vec![vec![1.0, 2.0], vec![3.0]]);
        let solver = ReusableSolver::new(&g);
        let mut scratch = SolveScratch::default();
        solver.solve_flat_into(&[1.0, 2.0], &mut scratch);
    }

    #[test]
    fn thread_local_solve_counter_counts_both_paths() {
        let g = Graph::new(vec![vec![3.0, 1.0]]);
        let solver = ReusableSolver::new(&g);
        let before = solves_on_thread();
        let _ = solve(&g);
        let _ = solver.solve_with(&g.node_costs);
        assert_eq!(solves_on_thread(), before + 2);
        // other threads start from their own counter
        std::thread::spawn(|| assert_eq!(solves_on_thread(), 0)).join().unwrap();
    }

    #[test]
    fn template_build_counter_counts_builds_not_reuse() {
        let g = Graph::new(vec![vec![3.0, 1.0], vec![1.0, 2.0]]);
        let before = template_builds_on_thread();
        let solver = ReusableSolver::new(&g); // one build
        let _ = solve(&g); // a fresh solve builds its own working graph
        assert_eq!(template_builds_on_thread(), before + 2);
        // re-pricing through the reusable arena builds nothing
        let mut scratch = SolveScratch::default();
        let _ = solver.solve_with(&g.node_costs);
        let _ = solver.solve_flat_into(&[3.0, 1.0, 1.0, 2.0], &mut scratch);
        assert_eq!(template_builds_on_thread(), before + 2);
        std::thread::spawn(|| assert_eq!(template_builds_on_thread(), 0)).join().unwrap();
    }

    #[test]
    fn long_chain_solves_exactly_and_fast() {
        // the degree-bucket worklist must walk a long chain end to end
        let n = 512;
        let mut rng = SplitMix64::new(31);
        let node_costs: Vec<Vec<f64>> =
            (0..n).map(|_| (0..4).map(|_| rng.next_f64() * 10.0).collect()).collect();
        let mut g = Graph::new(node_costs);
        for u in 0..n - 1 {
            g.add_edge(u, u + 1, (0..16).map(|_| rng.next_f64() * 5.0).collect());
        }
        let sol = solve(&g);
        // exact chain reduction: verify via independent DP
        let mut dp = g.node_costs[0].clone();
        for u in 1..n {
            let e = &g.edges[u - 1];
            let cols = g.node_costs[u].len();
            dp = (0..cols)
                .map(|j| {
                    (0..dp.len())
                        .map(|i| dp[i] + e.cost[i * cols + j])
                        .fold(f64::INFINITY, f64::min)
                        + g.node_costs[u][j]
                })
                .collect();
        }
        let opt = dp.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!((sol.cost - opt).abs() < 1e-6, "{} vs {opt}", sol.cost);
    }
}

//! The reduction-based PBQP solver.
//!
//! Working representation: a **flat edge arena**. Each merged edge is
//! stored once, in one orientation, with dead edges tombstoned — no
//! per-node `HashMap` adjacency, no transposed duplicate matrices (the
//! opposite orientation is an index swap at the access site). Node
//! elimination is driven by **degree buckets**: candidate nodes of degree
//! 0/1/2 sit in three lazily-validated worklists, so picking the next
//! reducible node is O(1) instead of an O(n) rescan per elimination
//! (O(n²) overall on the old representation — visible on the 1024-node
//! bench chains). Degree-≥3 nodes (the RN heuristic) keep the original
//! min-degree/min-index scan, preserving the old solver's choice rule
//! where reduction order can matter.
//!
//! R0/RI/RII are exact reductions, so any order of applying them to
//! degree ≤2 nodes reaches the same optimum — bucket order differing from
//! the old lowest-index scan cannot change the objective on reducible
//! graphs (pinned against `brute_force` in rust/tests/proptests.rs).
//! Reductions eliminate nodes onto a stack; back-propagation resolves
//! choices in reverse elimination order.

use super::{Edge, Graph, INF};
use std::cell::Cell;

/// A solved assignment.
#[derive(Debug, Clone, PartialEq)]
pub struct Solution {
    pub choice: Vec<usize>,
    pub cost: f64,
}

thread_local! {
    /// Per-thread count of PBQP solves ([`solve`] + [`ReusableSolver::solve_with`]).
    static SOLVES: Cell<u64> = const { Cell::new(0) };
}

/// Number of PBQP solves run so far **on the calling thread**. The
/// counter is thread-local on purpose: tests asserting "this warm path
/// ran zero solves" stay exact even while other test threads solve
/// concurrently in the same process.
pub fn solves_on_thread() -> u64 {
    SOLVES.with(|c| c.get())
}

fn note_solve() {
    SOLVES.with(|c| c.set(c.get() + 1));
}

/// Records how an eliminated node's choice is recovered.
enum Elim {
    /// R0: choice independent of any neighbour.
    Free { node: usize },
    /// RI: choice depends on one neighbour's choice.
    OneDep { node: usize, dep: usize, table: Vec<usize> },
    /// RII: choice depends on two neighbours.
    TwoDep { node: usize, dep_a: usize, dep_b: usize, table: Vec<usize>, cols_b: usize },
    /// RN: choice fixed heuristically during reduction.
    Fixed { node: usize, choice: usize },
}

/// One arena slot: a merged u–v edge with its dense cost matrix stored
/// row-major as |choices_u| x |choices_v|. The v-major view is the index
/// swap `mat[j * cols + i]`; see [`entry`].
#[derive(Clone)]
struct EdgeSlot {
    u: usize,
    v: usize,
    mat: Vec<f64>,
    alive: bool,
}

impl EdgeSlot {
    #[inline]
    fn other(&self, node: usize) -> usize {
        if self.u == node {
            self.v
        } else {
            self.u
        }
    }
}

/// Edge matrix entry for (choice `i` at `node`, choice `j` at the other
/// endpoint), regardless of stored orientation. `cols` is the stored
/// column count (= |choices of slot.v|).
#[inline]
fn entry(mat: &[f64], node_is_u: bool, cols: usize, i: usize, j: usize) -> f64 {
    if node_is_u {
        mat[i * cols + j]
    } else {
        mat[j * cols + i]
    }
}

#[derive(Clone)]
struct Work {
    costs: Vec<Vec<f64>>,
    /// Flat edge arena; slots are tombstoned, never removed.
    edges: Vec<EdgeSlot>,
    /// incident[u] -> arena ids (pruned lazily of dead slots).
    incident: Vec<Vec<usize>>,
    /// Live-edge count per node.
    deg: Vec<usize>,
    alive: Vec<bool>,
    /// Candidate worklists for degrees 0/1/2 (entries validated on pop).
    buckets: [Vec<usize>; 3],
}

impl Work {
    fn from_graph(g: &Graph) -> Self {
        let n = g.n_nodes();
        let mut w = Self {
            costs: g.node_costs.clone(),
            edges: Vec::with_capacity(g.edges.len()),
            incident: vec![Vec::new(); n],
            deg: vec![0; n],
            alive: vec![true; n],
            buckets: [Vec::new(), Vec::new(), Vec::new()],
        };
        for e in &g.edges {
            // merge parallel edges by summing
            if let Some(eid) = w.find_edge(e.u, e.v) {
                let cols = w.costs[e.v].len();
                w.accumulate(eid, e.u, &e.cost, cols);
            } else {
                w.add_edge(e.u, e.v, e.cost.clone());
            }
        }
        // seed the worklists (reverse so pops start at low indices)
        for u in (0..n).rev() {
            if w.deg[u] <= 2 {
                w.buckets[w.deg[u]].push(u);
            }
        }
        w
    }

    /// Live edge between a and b, if any (edges are merged, so unique).
    fn find_edge(&self, a: usize, b: usize) -> Option<usize> {
        self.incident[a]
            .iter()
            .copied()
            .find(|&e| self.edges[e].alive && (self.edges[e].u == b || self.edges[e].v == b))
    }

    /// Live arena ids incident to `u`. Only called on the node being
    /// eliminated this iteration, so its incident list is surrendered
    /// rather than restored (a dead node's list is never read again).
    fn live_edges(&mut self, u: usize) -> Vec<usize> {
        let mut inc = std::mem::take(&mut self.incident[u]);
        inc.retain(|&e| self.edges[e].alive);
        inc
    }

    fn add_edge(&mut self, a: usize, b: usize, mat: Vec<f64>) {
        let id = self.edges.len();
        self.edges.push(EdgeSlot { u: a, v: b, mat, alive: true });
        self.incident[a].push(id);
        self.incident[b].push(id);
        self.deg[a] += 1;
        self.deg[b] += 1;
    }

    /// Sum `mat` (oriented a-rows x other-cols, `cols` columns) into an
    /// existing slot, transposing if the slot is stored the other way.
    fn accumulate(&mut self, eid: usize, a: usize, mat: &[f64], cols: usize) {
        let slot = &mut self.edges[eid];
        if slot.u == a {
            for (x, y) in slot.mat.iter_mut().zip(mat) {
                *x += *y;
            }
        } else {
            let rows = mat.len() / cols;
            for i in 0..rows {
                for j in 0..cols {
                    slot.mat[j * rows + i] += mat[i * cols + j];
                }
            }
        }
    }

    fn kill_edge(&mut self, eid: usize) {
        let (a, b) = (self.edges[eid].u, self.edges[eid].v);
        self.edges[eid].alive = false;
        self.deg[a] -= 1;
        self.deg[b] -= 1;
    }

    /// Re-enqueue a node whose degree changed (no-op for degree >= 3;
    /// such nodes are found by the RN scan).
    fn touch(&mut self, u: usize) {
        if self.alive[u] && self.deg[u] <= 2 {
            self.buckets[self.deg[u]].push(u);
        }
    }

    /// Pop the next reducible node from the worklists: lowest degree
    /// class first, entries revalidated against the current degree.
    fn next_bucket(&mut self) -> Option<(usize, usize)> {
        let mut d = 0;
        while d < 3 {
            let Some(u) = self.buckets[d].pop() else {
                d += 1;
                continue;
            };
            if !self.alive[u] {
                continue;
            }
            let du = self.deg[u];
            if du == d {
                return Some((u, d));
            }
            if du < 3 {
                // stale entry: reroute, and restart from the lower class
                self.buckets[du].push(u);
                if du < d {
                    d = du;
                }
            }
        }
        None
    }

    /// Min-degree, min-index alive node (the RN fallback — identical to
    /// the old solver's global scan rule).
    fn scan_min(&self) -> Option<(usize, usize)> {
        let mut best: Option<(usize, usize)> = None; // (node, degree)
        for u in 0..self.costs.len() {
            if !self.alive[u] {
                continue;
            }
            let d = self.deg[u];
            if best.map_or(true, |(_, bd)| d < bd) {
                best = Some((u, d));
            }
        }
        best
    }
}

/// Solve a PBQP instance. Exact on graphs that reduce fully with R0–RII
/// (trees, chains, series-parallel); heuristic (RN) otherwise.
///
/// ```
/// use primsel::pbqp::{solve, Graph};
///
/// // two nodes, two choices each; the edge penalises mismatched choices
/// let mut g = Graph::new(vec![vec![1.0, 3.0], vec![4.0, 1.0]]);
/// g.add_edge(0, 1, vec![0.0, 2.0, 2.0, 0.0]);
///
/// let sol = solve(&g);
/// assert_eq!(g.cost_of(&sol.choice), sol.cost);
/// // a single edge reduces exactly with RI: optimal by construction
/// assert_eq!(sol.cost, g.brute_force().cost);
/// ```
pub fn solve(g: &Graph) -> Solution {
    let n = g.n_nodes();
    if n == 0 {
        return Solution { choice: vec![], cost: 0.0 };
    }
    note_solve();
    let mut w = Work::from_graph(g);
    let choice = reduce_and_backprop(&mut w);
    let cost = g.cost_of(&choice);
    Solution { choice, cost }
}

/// The reduction loop plus back-propagation, shared between [`solve`]
/// and [`ReusableSolver::solve_with`]: eliminate nodes onto a stack
/// (R0/RI/RII exactly, RN heuristically), then resolve choices in
/// reverse elimination order. Consumes `w`'s worklists and mutates its
/// node costs; the caller must compute the objective against pristine
/// costs.
fn reduce_and_backprop(w: &mut Work) -> Vec<usize> {
    let n = w.costs.len();
    let mut stack: Vec<Elim> = Vec::with_capacity(n);

    loop {
        let next = w.next_bucket().or_else(|| w.scan_min());
        let Some((u, deg)) = next else { break };
        match deg {
            0 => stack.push(Elim::Free { node: u }),
            1 => reduce_ri(w, u, &mut stack),
            2 => reduce_rii(w, u, &mut stack),
            _ => reduce_rn(w, u, &mut stack),
        }
        w.alive[u] = false;
    }

    // back-propagate
    let mut choice = vec![usize::MAX; n];
    for elim in stack.iter().rev() {
        match elim {
            Elim::Free { node } => {
                choice[*node] = argmin(&w.costs[*node]).0;
            }
            Elim::OneDep { node, dep, table } => {
                choice[*node] = table[choice[*dep]];
            }
            Elim::TwoDep { node, dep_a, dep_b, table, cols_b } => {
                choice[*node] = table[choice[*dep_a] * cols_b + choice[*dep_b]];
            }
            Elim::Fixed { node, choice: c } => {
                choice[*node] = *c;
            }
        }
    }
    choice
}

/// A PBQP solver specialised to one graph *topology*, reusable across
/// node-cost re-pricings.
///
/// Construction pays the [`Graph`] → arena conversion once (parallel
/// edges merged into dense matrices, degree buckets seeded);
/// [`Self::solve_with`] then clones the pristine arena, swaps in new
/// node costs and runs the shared reduction loop. Because the merged
/// edge matrices, the bucket seeding and the reduction rules depend
/// only on the topology and the cost *values* (never on how the arena
/// was built), a `solve_with` call is bit-identical to [`solve`] on a
/// graph carrying the same node costs — the property the Pareto sweep
/// (`selection::pareto`) relies on when it re-prices workspace
/// penalties across budget levels without rebuilding the graph.
///
/// ```
/// use primsel::pbqp::{solve, Graph, ReusableSolver};
///
/// let mut g = Graph::new(vec![vec![1.0, 3.0], vec![4.0, 1.0]]);
/// g.add_edge(0, 1, vec![0.0, 2.0, 2.0, 0.0]);
/// let solver = ReusableSolver::new(&g);
///
/// // same costs: bit-identical to a fresh solve
/// let fresh = solve(&g);
/// let reused = solver.solve_with(&g.node_costs);
/// assert_eq!(reused.choice, fresh.choice);
/// assert_eq!(reused.cost, fresh.cost);
///
/// // re-priced costs reuse the merged-edge arena
/// let repriced = solver.solve_with(&[vec![9.0, 9.0], vec![0.0, 9.0]]);
/// assert_eq!(repriced.choice[1], 0);
/// ```
pub struct ReusableSolver {
    /// Pristine post-merge arena (worklists seeded, nothing eliminated).
    template: Work,
    /// The original edges in insertion order, for the objective sum —
    /// mirrors [`Graph::cost_of`] exactly.
    edges: Vec<Edge>,
}

impl ReusableSolver {
    /// Build the reusable arena for `g`'s topology (and cost shapes).
    pub fn new(g: &Graph) -> Self {
        Self { template: Work::from_graph(g), edges: g.edges.clone() }
    }

    /// Solve with `node_costs` in place of the graph's own. Each row
    /// must have the same length as the corresponding row the solver
    /// was built with.
    pub fn solve_with(&self, node_costs: &[Vec<f64>]) -> Solution {
        assert_eq!(node_costs.len(), self.template.costs.len(), "node count mismatch");
        for (u, (fresh, built)) in node_costs.iter().zip(&self.template.costs).enumerate() {
            assert_eq!(fresh.len(), built.len(), "choice count mismatch at node {u}");
        }
        if node_costs.is_empty() {
            return Solution { choice: vec![], cost: 0.0 };
        }
        note_solve();
        let mut w = self.template.clone();
        w.costs = node_costs.to_vec();
        let choice = reduce_and_backprop(&mut w);
        let cost = cost_of_with(node_costs, &self.edges, &choice);
        Solution { choice, cost }
    }
}

/// Total assignment cost under explicit node costs — the same summation
/// order as [`Graph::cost_of`] (nodes in index order, then edges in
/// insertion order), so the two are bit-identical on equal inputs.
fn cost_of_with(node_costs: &[Vec<f64>], edges: &[Edge], choice: &[usize]) -> f64 {
    let mut total = 0.0;
    for (u, &i) in choice.iter().enumerate() {
        total += node_costs[u][i];
    }
    for e in edges {
        let cols = node_costs[e.v].len();
        total += e.at(choice[e.u], choice[e.v], cols);
    }
    total
}

fn argmin(v: &[f64]) -> (usize, f64) {
    let mut best = 0;
    for i in 1..v.len() {
        if v[i] < v[best] {
            best = i;
        }
    }
    (best, v[best])
}

/// RI: fold node u (degree 1) into its neighbour v:
/// v_cost[j] += min_i (u_cost[i] + edge[i][j]).
fn reduce_ri(w: &mut Work, u: usize, stack: &mut Vec<Elim>) {
    let eid = w.live_edges(u)[0];
    let v = w.edges[eid].other(u);
    let u_first = w.edges[eid].u == u;
    let ru = w.costs[u].len();
    let rv = w.costs[v].len();
    let cols = if u_first { rv } else { ru };
    let mut table = vec![0usize; rv];
    let cu = w.costs[u].clone();
    for j in 0..rv {
        let mat = &w.edges[eid].mat;
        let mut best_i = 0;
        let mut best = f64::INFINITY;
        for (i, &cui) in cu.iter().enumerate() {
            let c = cui + entry(mat, u_first, cols, i, j);
            if c < best {
                best = c;
                best_i = i;
            }
        }
        w.costs[v][j] += best;
        table[j] = best_i;
    }
    w.kill_edge(eid);
    w.touch(v);
    stack.push(Elim::OneDep { node: u, dep: v, table });
}

/// RII: fold node u (degree 2, neighbours a and b) into a new a–b edge:
/// delta[j][k] = min_i (u_cost[i] + e_a[i][j] + e_b[i][k]).
fn reduce_rii(w: &mut Work, u: usize, stack: &mut Vec<Elim>) {
    let live = w.live_edges(u);
    let (ea, eb) = (live[0], live[1]);
    let a = w.edges[ea].other(u);
    let b = w.edges[eb].other(u);
    let a_u_first = w.edges[ea].u == u;
    let b_u_first = w.edges[eb].u == u;
    let ru = w.costs[u].len();
    let ra = w.costs[a].len();
    let rb = w.costs[b].len();
    let cols_a = if a_u_first { ra } else { ru };
    let cols_b = if b_u_first { rb } else { ru };
    let cu = w.costs[u].clone();
    let mut delta = vec![0.0; ra * rb];
    let mut table = vec![0usize; ra * rb];
    {
        let mat_a = &w.edges[ea].mat;
        let mat_b = &w.edges[eb].mat;
        for j in 0..ra {
            for k in 0..rb {
                let mut best_i = 0;
                let mut best = f64::INFINITY;
                for (i, &cui) in cu.iter().enumerate() {
                    let c = cui
                        + entry(mat_a, a_u_first, cols_a, i, j)
                        + entry(mat_b, b_u_first, cols_b, i, k);
                    if c < best {
                        best = c;
                        best_i = i;
                    }
                }
                delta[j * rb + k] = best;
                table[j * rb + k] = best_i;
            }
        }
    }
    w.kill_edge(ea);
    w.kill_edge(eb);
    if let Some(eid) = w.find_edge(a, b) {
        w.accumulate(eid, a, &delta, rb);
    } else {
        w.add_edge(a, b, delta);
    }
    w.touch(a);
    w.touch(b);
    stack.push(Elim::TwoDep { node: u, dep_a: a, dep_b: b, table, cols_b: rb });
}

/// RN heuristic for degree >= 3: pick the locally best choice
/// (node cost + sum over neighbours of the best-case edge+neighbour cost),
/// commit it, and push the chosen row of each edge into the neighbour.
fn reduce_rn(w: &mut Work, u: usize, stack: &mut Vec<Elim>) {
    let live = w.live_edges(u);
    let cu = w.costs[u].clone();
    let mut best_i = 0;
    let mut best = f64::INFINITY;
    for (i, &cui) in cu.iter().enumerate() {
        if cui >= INF {
            continue;
        }
        let mut c = cui;
        for &eid in &live {
            let slot = &w.edges[eid];
            let v = slot.other(u);
            let u_first = slot.u == u;
            let rv = w.costs[v].len();
            let cols = if u_first { rv } else { cu.len() };
            let mut m = f64::INFINITY;
            for (j, &cvj) in w.costs[v].iter().enumerate() {
                let e = entry(&slot.mat, u_first, cols, i, j) + cvj;
                if e < m {
                    m = e;
                }
            }
            c += m;
        }
        if c < best {
            best = c;
            best_i = i;
        }
    }
    for &eid in &live {
        let v = w.edges[eid].other(u);
        let u_first = w.edges[eid].u == u;
        let rv = w.costs[v].len();
        let cols = if u_first { rv } else { cu.len() };
        for j in 0..rv {
            let add = entry(&w.edges[eid].mat, u_first, cols, best_i, j);
            w.costs[v][j] += add;
        }
        w.kill_edge(eid);
        w.touch(v);
    }
    stack.push(Elim::Fixed { node: u, choice: best_i });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::noise::SplitMix64;

    fn random_graph(rng: &mut SplitMix64, n: usize, max_choices: usize, edge_p: f64) -> Graph {
        let node_costs: Vec<Vec<f64>> = (0..n)
            .map(|_| {
                let c = 1 + (rng.next_u64() as usize) % max_choices;
                (0..c).map(|_| rng.next_f64() * 10.0).collect()
            })
            .collect();
        let mut g = Graph::new(node_costs);
        for u in 0..n {
            for v in (u + 1)..n {
                if rng.next_f64() < edge_p {
                    let len = g.node_costs[u].len() * g.node_costs[v].len();
                    let cost: Vec<f64> = (0..len).map(|_| rng.next_f64() * 5.0).collect();
                    g.add_edge(u, v, cost);
                }
            }
        }
        g
    }

    #[test]
    fn exact_on_chains() {
        let mut rng = SplitMix64::new(11);
        for _ in 0..30 {
            let n = 2 + (rng.next_u64() as usize) % 6;
            let node_costs: Vec<Vec<f64>> = (0..n)
                .map(|_| (0..3).map(|_| rng.next_f64() * 10.0).collect())
                .collect();
            let mut g = Graph::new(node_costs);
            for u in 0..n - 1 {
                let cost: Vec<f64> = (0..9).map(|_| rng.next_f64() * 5.0).collect();
                g.add_edge(u, u + 1, cost);
            }
            let sol = solve(&g);
            let exact = g.brute_force();
            assert!(
                (sol.cost - exact.cost).abs() < 1e-9,
                "chain not exact: {} vs {}",
                sol.cost,
                exact.cost
            );
        }
    }

    #[test]
    fn exact_on_trees() {
        let mut rng = SplitMix64::new(23);
        for _ in 0..20 {
            let n = 3 + (rng.next_u64() as usize) % 6;
            let node_costs: Vec<Vec<f64>> = (0..n)
                .map(|_| (0..2).map(|_| rng.next_f64() * 10.0).collect())
                .collect();
            let mut g = Graph::new(node_costs);
            for v in 1..n {
                let u = (rng.next_u64() as usize) % v;
                let cost: Vec<f64> = (0..4).map(|_| rng.next_f64() * 5.0).collect();
                g.add_edge(u, v, cost);
            }
            let sol = solve(&g);
            let exact = g.brute_force();
            assert!((sol.cost - exact.cost).abs() < 1e-9);
        }
    }

    #[test]
    fn near_optimal_on_random_graphs() {
        // RN is a heuristic; require the known-good bound on small graphs
        let mut rng = SplitMix64::new(5);
        let mut total_gap = 0.0;
        for _ in 0..25 {
            let g = random_graph(&mut rng, 6, 3, 0.5);
            let sol = solve(&g);
            let exact = g.brute_force();
            assert!(sol.cost >= exact.cost - 1e-9);
            total_gap += (sol.cost - exact.cost) / exact.cost.max(1e-9);
        }
        assert!(total_gap / 25.0 < 0.05, "mean RN gap {}", total_gap / 25.0);
    }

    #[test]
    fn solution_choice_is_valid() {
        let mut rng = SplitMix64::new(9);
        let g = random_graph(&mut rng, 10, 4, 0.3);
        let sol = solve(&g);
        assert_eq!(sol.choice.len(), 10);
        for (u, &c) in sol.choice.iter().enumerate() {
            assert!(c < g.node_costs[u].len());
        }
        assert!((g.cost_of(&sol.choice) - sol.cost).abs() < 1e-9);
    }

    #[test]
    fn single_node() {
        let g = Graph::new(vec![vec![3.0, 1.0, 2.0]]);
        let sol = solve(&g);
        assert_eq!(sol.choice, vec![1]);
        assert_eq!(sol.cost, 1.0);
    }

    #[test]
    fn empty_graph() {
        let g = Graph::new(vec![]);
        assert_eq!(solve(&g).cost, 0.0);
    }

    #[test]
    fn parallel_edges_merge() {
        let mut g = Graph::new(vec![vec![0.0, 0.0], vec![0.0, 0.0]]);
        g.add_edge(0, 1, vec![1.0, 0.0, 0.0, 1.0]);
        g.add_edge(0, 1, vec![1.0, 0.0, 0.0, 1.0]);
        let sol = solve(&g);
        assert_eq!(sol.cost, 0.0); // mismatched choices are free
        assert_ne!(sol.choice[0], sol.choice[1]);
    }

    #[test]
    fn respects_infinite_costs() {
        let mut g = Graph::new(vec![vec![INF, 1.0], vec![1.0, INF]]);
        g.add_edge(0, 1, vec![0.0; 4]);
        let sol = solve(&g);
        assert_eq!(sol.choice, vec![1, 0]);
    }

    #[test]
    fn rii_merges_into_existing_edge() {
        // triangle: eliminating any corner folds an RII delta into the
        // opposite edge; the result must still be exact (triangles reduce
        // fully via RII then RI then R0)
        let mut rng = SplitMix64::new(77);
        for _ in 0..20 {
            let node_costs: Vec<Vec<f64>> =
                (0..3).map(|_| (0..3).map(|_| rng.next_f64() * 9.0).collect()).collect();
            let mut g = Graph::new(node_costs);
            for (u, v) in [(0, 1), (0, 2), (1, 2)] {
                g.add_edge(u, v, (0..9).map(|_| rng.next_f64() * 4.0).collect());
            }
            let sol = solve(&g);
            let exact = g.brute_force();
            assert!((sol.cost - exact.cost).abs() < 1e-9, "{} vs {}", sol.cost, exact.cost);
        }
    }

    #[test]
    fn asymmetric_choice_counts_both_orientations() {
        // ragged choice counts exercise the orientation-swapping entry
        // accessor on 1x4, 4x2 and 2x1 matrices
        let mut g = Graph::new(vec![vec![1.0], vec![0.5, 9.0, 0.1, 3.0], vec![2.0, 0.3]]);
        g.add_edge(0, 1, vec![0.0, 1.0, 5.0, 1.0]);
        g.add_edge(1, 2, vec![1.0, 0.0, 2.0, 2.0, 0.0, 4.0, 1.0, 1.0]);
        let sol = solve(&g);
        let exact = g.brute_force();
        assert!((sol.cost - exact.cost).abs() < 1e-9);
    }

    #[test]
    fn reusable_solver_matches_fresh_solve_bit_for_bit() {
        // across chains, trees and dense (RN-heuristic) graphs: swapping
        // re-priced costs into the cloned arena must equal building a
        // fresh graph with those costs — same choice, same cost bits
        let mut rng = SplitMix64::new(0x5EED);
        for case in 0..40 {
            let g = match case % 3 {
                0 => {
                    let n = 2 + (rng.next_u64() as usize) % 6;
                    let node_costs: Vec<Vec<f64>> = (0..n)
                        .map(|_| (0..3).map(|_| rng.next_f64() * 10.0).collect())
                        .collect();
                    let mut g = Graph::new(node_costs);
                    for u in 0..n - 1 {
                        g.add_edge(u, u + 1, (0..9).map(|_| rng.next_f64() * 5.0).collect());
                    }
                    g
                }
                _ => random_graph(&mut rng, 7, 3, 0.5),
            };
            let solver = ReusableSolver::new(&g);
            for _ in 0..4 {
                // re-price: same shapes, new values
                let costs: Vec<Vec<f64>> = g
                    .node_costs
                    .iter()
                    .map(|row| row.iter().map(|_| rng.next_f64() * 12.0).collect())
                    .collect();
                let mut fresh_graph = Graph::new(costs.clone());
                for e in &g.edges {
                    fresh_graph.add_edge(e.u, e.v, e.cost.clone());
                }
                let fresh = solve(&fresh_graph);
                let reused = solver.solve_with(&costs);
                assert_eq!(reused.choice, fresh.choice, "case {case}");
                assert_eq!(reused.cost, fresh.cost, "case {case}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "choice count mismatch")]
    fn reusable_solver_rejects_misshapen_costs() {
        let g = Graph::new(vec![vec![1.0, 2.0], vec![3.0]]);
        ReusableSolver::new(&g).solve_with(&[vec![1.0], vec![3.0]]);
    }

    #[test]
    fn thread_local_solve_counter_counts_both_paths() {
        let g = Graph::new(vec![vec![3.0, 1.0]]);
        let solver = ReusableSolver::new(&g);
        let before = solves_on_thread();
        let _ = solve(&g);
        let _ = solver.solve_with(&g.node_costs);
        assert_eq!(solves_on_thread(), before + 2);
        // other threads start from their own counter
        std::thread::spawn(|| assert_eq!(solves_on_thread(), 0)).join().unwrap();
    }

    #[test]
    fn long_chain_solves_exactly_and_fast() {
        // the degree-bucket worklist must walk a long chain end to end
        let n = 512;
        let mut rng = SplitMix64::new(31);
        let node_costs: Vec<Vec<f64>> =
            (0..n).map(|_| (0..4).map(|_| rng.next_f64() * 10.0).collect()).collect();
        let mut g = Graph::new(node_costs);
        for u in 0..n - 1 {
            g.add_edge(u, u + 1, (0..16).map(|_| rng.next_f64() * 5.0).collect());
        }
        let sol = solve(&g);
        // exact chain reduction: verify via independent DP
        let mut dp = g.node_costs[0].clone();
        for u in 1..n {
            let e = &g.edges[u - 1];
            let cols = g.node_costs[u].len();
            dp = (0..cols)
                .map(|j| {
                    (0..dp.len())
                        .map(|i| dp[i] + e.cost[i * cols + j])
                        .fold(f64::INFINITY, f64::min)
                        + g.node_costs[u][j]
                })
                .collect();
        }
        let opt = dp.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!((sol.cost - opt).abs() < 1e-6, "{} vs {opt}", sol.cost);
    }
}

//! The reduction-based PBQP solver.
//!
//! Working representation: a mutable adjacency list of dense edge
//! matrices. Reductions eliminate nodes onto a stack; back-propagation
//! resolves choices in reverse elimination order.

use super::{Graph, INF};
use std::collections::HashMap;

/// A solved assignment.
#[derive(Debug, Clone, PartialEq)]
pub struct Solution {
    pub choice: Vec<usize>,
    pub cost: f64,
}

/// Records how an eliminated node's choice is recovered.
enum Elim {
    /// R0: choice independent of any neighbour.
    Free { node: usize },
    /// RI: choice depends on one neighbour's choice.
    OneDep { node: usize, dep: usize, table: Vec<usize> },
    /// RII: choice depends on two neighbours.
    TwoDep { node: usize, dep_a: usize, dep_b: usize, table: Vec<usize>, cols_b: usize },
    /// RN: choice fixed heuristically during reduction.
    Fixed { node: usize, choice: usize },
}

struct Work {
    costs: Vec<Vec<f64>>,
    /// adj[u] -> map of neighbour v to edge matrix oriented (u rows, v cols).
    adj: Vec<HashMap<usize, Vec<f64>>>,
    alive: Vec<bool>,
}

impl Work {
    fn from_graph(g: &Graph) -> Self {
        let n = g.n_nodes();
        let mut adj: Vec<HashMap<usize, Vec<f64>>> = vec![HashMap::new(); n];
        for e in &g.edges {
            let ru = g.node_costs[e.u].len();
            let rv = g.node_costs[e.v].len();
            // merge parallel edges by summing
            let fwd = adj[e.u].entry(e.v).or_insert_with(|| vec![0.0; ru * rv]);
            for i in 0..ru * rv {
                fwd[i] += e.cost[i];
            }
            let mut transposed = vec![0.0; ru * rv];
            for i in 0..ru {
                for j in 0..rv {
                    transposed[j * ru + i] = e.cost[i * rv + j];
                }
            }
            let bwd = adj[e.v].entry(e.u).or_insert_with(|| vec![0.0; ru * rv]);
            for i in 0..ru * rv {
                bwd[i] += transposed[i];
            }
        }
        Self { costs: g.node_costs.clone(), adj, alive: vec![true; n] }
    }

    fn degree(&self, u: usize) -> usize {
        self.adj[u].len()
    }

    fn remove_edge(&mut self, u: usize, v: usize) -> Vec<f64> {
        self.adj[v].remove(&u);
        self.adj[u].remove(&v).expect("edge exists")
    }

    fn add_or_merge_edge(&mut self, u: usize, v: usize, mat: Vec<f64>) {
        let ru = self.costs[u].len();
        let rv = self.costs[v].len();
        let fwd = self.adj[u].entry(v).or_insert_with(|| vec![0.0; ru * rv]);
        for i in 0..ru * rv {
            fwd[i] += mat[i];
        }
        let mut transposed = vec![0.0; ru * rv];
        for i in 0..ru {
            for j in 0..rv {
                transposed[j * ru + i] = mat[i * rv + j];
            }
        }
        let bwd = self.adj[v].entry(u).or_insert_with(|| vec![0.0; rv * ru]);
        for i in 0..ru * rv {
            bwd[i] += transposed[i];
        }
    }
}

/// Solve a PBQP instance. Exact on graphs that reduce fully with R0–RII
/// (trees, chains, series-parallel); heuristic (RN) otherwise.
pub fn solve(g: &Graph) -> Solution {
    let n = g.n_nodes();
    if n == 0 {
        return Solution { choice: vec![], cost: 0.0 };
    }
    let mut w = Work::from_graph(g);
    let mut stack: Vec<Elim> = Vec::with_capacity(n);

    loop {
        // lowest-degree-first elimination
        let mut next: Option<(usize, usize)> = None; // (degree, node)
        for u in 0..n {
            if !w.alive[u] {
                continue;
            }
            let d = w.degree(u);
            if next.map_or(true, |(bd, _)| d < bd) {
                next = Some((d, u));
            }
            if d == 0 {
                break;
            }
        }
        let Some((deg, u)) = next else { break };
        match deg {
            0 => reduce_r0(&mut w, u, &mut stack),
            1 => reduce_ri(&mut w, u, &mut stack),
            2 => reduce_rii(&mut w, u, &mut stack),
            _ => reduce_rn(&mut w, u, &mut stack),
        }
        w.alive[u] = false;
    }

    // back-propagate
    let mut choice = vec![usize::MAX; n];
    let mut cost_accum = 0.0;
    for elim in stack.iter().rev() {
        match elim {
            Elim::Free { node } => {
                let (i, c) = argmin(&w.costs[*node]);
                choice[*node] = i;
                cost_accum += c;
            }
            Elim::OneDep { node, dep, table } => {
                choice[*node] = table[choice[*dep]];
            }
            Elim::TwoDep { node, dep_a, dep_b, table, cols_b } => {
                choice[*node] = table[choice[*dep_a] * cols_b + choice[*dep_b]];
            }
            Elim::Fixed { node, choice: c } => {
                choice[*node] = *c;
            }
        }
    }
    let _ = cost_accum;
    let cost = g.cost_of(&choice);
    Solution { choice, cost }
}

fn argmin(v: &[f64]) -> (usize, f64) {
    let mut best = 0;
    for i in 1..v.len() {
        if v[i] < v[best] {
            best = i;
        }
    }
    (best, v[best])
}

fn reduce_r0(_w: &mut Work, u: usize, stack: &mut Vec<Elim>) {
    stack.push(Elim::Free { node: u });
}

/// RI: fold node u (degree 1) into its neighbour v:
/// v_cost[j] += min_i (u_cost[i] + edge[i][j]).
fn reduce_ri(w: &mut Work, u: usize, stack: &mut Vec<Elim>) {
    let (&v, _) = w.adj[u].iter().next().unwrap();
    let mat = w.remove_edge(u, v); // u rows, v cols
    let ru = w.costs[u].len();
    let rv = w.costs[v].len();
    let mut table = vec![0usize; rv];
    for j in 0..rv {
        let mut best_i = 0;
        let mut best = f64::INFINITY;
        for i in 0..ru {
            let c = w.costs[u][i] + mat[i * rv + j];
            if c < best {
                best = c;
                best_i = i;
            }
        }
        w.costs[v][j] += best;
        table[j] = best_i;
    }
    stack.push(Elim::OneDep { node: u, dep: v, table });
}

/// RII: fold node u (degree 2, neighbours a and b) into a new a–b edge:
/// delta[j][k] = min_i (u_cost[i] + e_a[i][j] + e_b[i][k]).
fn reduce_rii(w: &mut Work, u: usize, stack: &mut Vec<Elim>) {
    let neighbours: Vec<usize> = w.adj[u].keys().copied().collect();
    let (a, b) = (neighbours[0], neighbours[1]);
    let mat_a = w.remove_edge(u, a); // u rows, a cols
    let mat_b = w.remove_edge(u, b); // u rows, b cols
    let ru = w.costs[u].len();
    let ra = w.costs[a].len();
    let rb = w.costs[b].len();
    let mut delta = vec![0.0; ra * rb];
    let mut table = vec![0usize; ra * rb];
    for j in 0..ra {
        for k in 0..rb {
            let mut best_i = 0;
            let mut best = f64::INFINITY;
            for i in 0..ru {
                let c = w.costs[u][i] + mat_a[i * ra + j] + mat_b[i * rb + k];
                if c < best {
                    best = c;
                    best_i = i;
                }
            }
            delta[j * rb + k] = best;
            table[j * rb + k] = best_i;
        }
    }
    w.add_or_merge_edge(a, b, delta);
    stack.push(Elim::TwoDep { node: u, dep_a: a, dep_b: b, table, cols_b: rb });
}

/// RN heuristic for degree >= 3: pick the locally best choice
/// (node cost + sum over neighbours of the best-case edge+neighbour cost),
/// commit it, and push the chosen row of each edge into the neighbour.
fn reduce_rn(w: &mut Work, u: usize, stack: &mut Vec<Elim>) {
    let neighbours: Vec<usize> = w.adj[u].keys().copied().collect();
    let ru = w.costs[u].len();
    let mut best_i = 0;
    let mut best = f64::INFINITY;
    for i in 0..ru {
        if w.costs[u][i] >= INF {
            continue;
        }
        let mut c = w.costs[u][i];
        for &v in &neighbours {
            let rv = w.costs[v].len();
            let mat = &w.adj[u][&v];
            let mut m = f64::INFINITY;
            for j in 0..rv {
                let e = mat[i * rv + j] + w.costs[v][j];
                if e < m {
                    m = e;
                }
            }
            c += m;
        }
        if c < best {
            best = c;
            best_i = i;
        }
    }
    for &v in &neighbours {
        let mat = w.remove_edge(u, v);
        let rv = w.costs[v].len();
        for j in 0..rv {
            w.costs[v][j] += mat[best_i * rv + j];
        }
    }
    stack.push(Elim::Fixed { node: u, choice: best_i });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::noise::SplitMix64;

    fn random_graph(rng: &mut SplitMix64, n: usize, max_choices: usize, edge_p: f64) -> Graph {
        let node_costs: Vec<Vec<f64>> = (0..n)
            .map(|_| {
                let c = 1 + (rng.next_u64() as usize) % max_choices;
                (0..c).map(|_| rng.next_f64() * 10.0).collect()
            })
            .collect();
        let mut g = Graph::new(node_costs);
        for u in 0..n {
            for v in (u + 1)..n {
                if rng.next_f64() < edge_p {
                    let len = g.node_costs[u].len() * g.node_costs[v].len();
                    let cost: Vec<f64> = (0..len).map(|_| rng.next_f64() * 5.0).collect();
                    g.add_edge(u, v, cost);
                }
            }
        }
        g
    }

    #[test]
    fn exact_on_chains() {
        let mut rng = SplitMix64::new(11);
        for _ in 0..30 {
            let n = 2 + (rng.next_u64() as usize) % 6;
            let node_costs: Vec<Vec<f64>> = (0..n)
                .map(|_| (0..3).map(|_| rng.next_f64() * 10.0).collect())
                .collect();
            let mut g = Graph::new(node_costs);
            for u in 0..n - 1 {
                let cost: Vec<f64> = (0..9).map(|_| rng.next_f64() * 5.0).collect();
                g.add_edge(u, u + 1, cost);
            }
            let sol = solve(&g);
            let exact = g.brute_force();
            assert!(
                (sol.cost - exact.cost).abs() < 1e-9,
                "chain not exact: {} vs {}",
                sol.cost,
                exact.cost
            );
        }
    }

    #[test]
    fn exact_on_trees() {
        let mut rng = SplitMix64::new(23);
        for _ in 0..20 {
            let n = 3 + (rng.next_u64() as usize) % 6;
            let node_costs: Vec<Vec<f64>> = (0..n)
                .map(|_| (0..2).map(|_| rng.next_f64() * 10.0).collect())
                .collect();
            let mut g = Graph::new(node_costs);
            for v in 1..n {
                let u = (rng.next_u64() as usize) % v;
                let cost: Vec<f64> = (0..4).map(|_| rng.next_f64() * 5.0).collect();
                g.add_edge(u, v, cost);
            }
            let sol = solve(&g);
            let exact = g.brute_force();
            assert!((sol.cost - exact.cost).abs() < 1e-9);
        }
    }

    #[test]
    fn near_optimal_on_random_graphs() {
        // RN is a heuristic; require the known-good bound on small graphs
        let mut rng = SplitMix64::new(5);
        let mut total_gap = 0.0;
        for _ in 0..25 {
            let g = random_graph(&mut rng, 6, 3, 0.5);
            let sol = solve(&g);
            let exact = g.brute_force();
            assert!(sol.cost >= exact.cost - 1e-9);
            total_gap += (sol.cost - exact.cost) / exact.cost.max(1e-9);
        }
        assert!(total_gap / 25.0 < 0.05, "mean RN gap {}", total_gap / 25.0);
    }

    #[test]
    fn solution_choice_is_valid() {
        let mut rng = SplitMix64::new(9);
        let g = random_graph(&mut rng, 10, 4, 0.3);
        let sol = solve(&g);
        assert_eq!(sol.choice.len(), 10);
        for (u, &c) in sol.choice.iter().enumerate() {
            assert!(c < g.node_costs[u].len());
        }
        assert!((g.cost_of(&sol.choice) - sol.cost).abs() < 1e-9);
    }

    #[test]
    fn single_node() {
        let g = Graph::new(vec![vec![3.0, 1.0, 2.0]]);
        let sol = solve(&g);
        assert_eq!(sol.choice, vec![1]);
        assert_eq!(sol.cost, 1.0);
    }

    #[test]
    fn empty_graph() {
        let g = Graph::new(vec![]);
        assert_eq!(solve(&g).cost, 0.0);
    }

    #[test]
    fn parallel_edges_merge() {
        let mut g = Graph::new(vec![vec![0.0, 0.0], vec![0.0, 0.0]]);
        g.add_edge(0, 1, vec![1.0, 0.0, 0.0, 1.0]);
        g.add_edge(0, 1, vec![1.0, 0.0, 0.0, 1.0]);
        let sol = solve(&g);
        assert_eq!(sol.cost, 0.0); // mismatched choices are free
        assert_ne!(sol.choice[0], sol.choice[1]);
    }

    #[test]
    fn respects_infinite_costs() {
        let mut g = Graph::new(vec![vec![INF, 1.0], vec![1.0, INF]]);
        g.add_edge(0, 1, vec![0.0; 4]);
        let sol = solve(&g);
        assert_eq!(sol.choice, vec![1, 0]);
    }
}

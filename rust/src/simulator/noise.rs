//! Deterministic measurement noise.
//!
//! The paper profiles each primitive 25 times and takes the median, so the
//! residual noise in its datasets is small but non-zero. We reproduce that
//! with a multiplicative log-normal jitter seeded from a hash of
//! (platform, primitive, configuration) — the same query always returns
//! the same "measurement", as a median-of-25 would.

/// SplitMix64 — tiny, high-quality 64-bit mixer (public domain).
#[derive(Debug, Clone)]
pub struct SplitMix64(pub u64);

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self(seed)
    }

    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Standard normal via Box–Muller.
    pub fn next_normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = (self.next_u64() as usize) % (i + 1);
            v.swap(i, j);
        }
    }
}

/// FNV-1a hash of a byte string (stable across runs and platforms).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// FNV-1a over 64-bit words: the integer-keyed fast path of the cost-query
/// engine. Hashing a handful of words replaces the old per-query
/// `format!`-a-string-then-hash-its-bytes flow on the simulator hot path;
/// word granularity (vs. byte) keeps the avalanche behaviour of the
/// follow-on SplitMix64 finaliser while touching 8x less state.
pub fn fnv1a_words(words: &[u64]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &w in words {
        h ^= w;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Multiplicative log-normal jitter factor with standard deviation `sigma`
/// deterministically derived from `key`.
pub fn jitter(key: &str, sigma: f64) -> f64 {
    jitter_seed(fnv1a(key.as_bytes()), sigma)
}

/// Jitter from a precomputed integer seed (see [`fnv1a_words`]).
pub fn jitter_seed(seed: u64, sigma: f64) -> f64 {
    let mut rng = SplitMix64::new(seed);
    // burn one draw to decorrelate from the raw hash
    rng.next_u64();
    (sigma * rng.next_normal()).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(jitter("intel/x/1", 0.03), jitter("intel/x/1", 0.03));
        assert_ne!(jitter("intel/x/1", 0.03), jitter("intel/x/2", 0.03));
    }

    #[test]
    fn word_hash_deterministic_and_sensitive() {
        assert_eq!(fnv1a_words(&[1, 2, 3]), fnv1a_words(&[1, 2, 3]));
        assert_ne!(fnv1a_words(&[1, 2, 3]), fnv1a_words(&[1, 2, 4]));
        assert_ne!(fnv1a_words(&[1, 2, 3]), fnv1a_words(&[3, 2, 1]));
    }

    #[test]
    fn jitter_seed_near_one() {
        for i in 0..200u64 {
            let j = jitter_seed(fnv1a_words(&[0xC0, i]), 0.03);
            assert!(j > 0.8 && j < 1.25, "{j}");
        }
    }

    #[test]
    fn jitter_near_one() {
        for i in 0..200 {
            let j = jitter(&format!("k{i}"), 0.03);
            assert!(j > 0.8 && j < 1.25, "{j}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = SplitMix64::new(42);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn shuffle_permutes() {
        let mut rng = SplitMix64::new(7);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, sorted);
    }
}

//! Analytical machine models for the paper's three testbeds.
//!
//! Each preset captures the microarchitectural parameters the primitive
//! cost model keys on. Values are drawn from public spec sheets for the
//! paper's exact parts: Intel Core i9-9900K @ 5.0 GHz (Coffee Lake, AVX2,
//! 2 FMA ports), AMD A10-7850K @ 3.7 GHz (Steamroller, AVX, 1 FMA pipe,
//! no L3) and ARM Cortex-A73 @ 2.36 GHz (NEON 128-bit, in a big.LITTLE
//! SoC with a 2 MB shared L2, no L3).


/// One simulated platform.
#[derive(Debug, Clone)]
pub struct Machine {
    pub name: &'static str,
    /// Core clock in GHz.
    pub ghz: f64,
    /// f32 SIMD lanes per vector unit (AVX2 = 8, AVX = 8, NEON = 4).
    pub simd_lanes: f64,
    /// FMA issue ports.
    pub fma_ports: f64,
    /// Scalar FLOP/cycle (non-vectorised code paths, e.g. direct-sum2d).
    pub scalar_ipc: f64,
    /// Cache capacities in KiB (l3 = 0 when absent).
    pub l1_kb: f64,
    pub l2_kb: f64,
    pub l3_kb: f64,
    /// Sustained bandwidths in GB/s per cache level and main memory.
    pub bw_l1: f64,
    pub bw_l2: f64,
    pub bw_l3: f64,
    pub bw_mem: f64,
    /// Peak fraction a well-tuned large gemm achieves on this platform.
    pub gemm_eff: f64,
    /// Fixed per-primitive-call overhead in microseconds (loop setup,
    /// packing bookkeeping; larger on the in-order-ish cores).
    pub call_overhead_us: f64,
    /// Relative penalty for transposed-operand gemm variants (atb/abt).
    pub transpose_penalty: f64,
    /// Efficiency of scalar (non `-vec`) winograd transforms.
    pub wino_scalar_eff: f64,
}

impl Machine {
    /// Peak f32 FLOP/s of vectorised FMA code.
    pub fn peak_flops(&self) -> f64 {
        self.ghz * 1e9 * self.simd_lanes * self.fma_ports * 2.0
    }

    /// Peak f32 FLOP/s of scalar code.
    pub fn scalar_flops(&self) -> f64 {
        self.ghz * 1e9 * self.scalar_ipc
    }

    /// Sustained bandwidth (GB/s) for a working set of `bytes`.
    pub fn bandwidth_for(&self, bytes: f64) -> f64 {
        let kb = bytes / 1024.0;
        if kb <= self.l1_kb {
            self.bw_l1
        } else if kb <= self.l2_kb {
            self.bw_l2
        } else if self.l3_kb > 0.0 && kb <= self.l3_kb {
            self.bw_l3
        } else {
            self.bw_mem
        }
    }

    /// Time in ms to stream `bytes` through the level it fits in.
    pub fn stream_ms(&self, bytes: f64) -> f64 {
        bytes / (self.bandwidth_for(bytes) * 1e9) * 1e3
    }
}

/// Intel Core i9-9900K @ 5.0 GHz — the paper's pre-training platform.
pub fn intel_i9_9900k() -> Machine {
    Machine {
        name: "intel",
        ghz: 5.0,
        simd_lanes: 8.0,
        fma_ports: 2.0,
        scalar_ipc: 2.0,
        l1_kb: 32.0,
        l2_kb: 256.0,
        l3_kb: 16384.0,
        bw_l1: 400.0,
        bw_l2: 150.0,
        bw_l3: 60.0,
        bw_mem: 25.0,
        gemm_eff: 0.85,
        call_overhead_us: 2.0,
        transpose_penalty: 0.93,
        wino_scalar_eff: 0.35,
    }
}

/// AMD A10-7850K @ 3.7 GHz — Steamroller, no L3, one FMA pipe.
pub fn amd_a10_7850k() -> Machine {
    Machine {
        name: "amd",
        ghz: 3.7,
        simd_lanes: 8.0,
        fma_ports: 1.0,
        scalar_ipc: 1.4,
        l1_kb: 16.0,
        l2_kb: 2048.0,
        l3_kb: 0.0,
        bw_l1: 160.0,
        bw_l2: 60.0,
        bw_l3: 0.0,
        bw_mem: 13.0,
        gemm_eff: 0.70,
        call_overhead_us: 3.5,
        transpose_penalty: 0.88,
        wino_scalar_eff: 0.30,
    }
}

/// ARM Cortex-A73 @ 2.36 GHz — NEON (4 f32 lanes), 2 MB shared L2.
pub fn arm_cortex_a73() -> Machine {
    Machine {
        name: "arm",
        ghz: 2.36,
        simd_lanes: 4.0,
        fma_ports: 1.0,
        scalar_ipc: 1.0,
        l1_kb: 64.0,
        l2_kb: 2048.0,
        l3_kb: 0.0,
        bw_l1: 60.0,
        bw_l2: 25.0,
        bw_l3: 0.0,
        bw_mem: 6.0,
        gemm_eff: 0.60,
        call_overhead_us: 6.0,
        transpose_penalty: 0.80,
        wino_scalar_eff: 0.22,
    }
}

/// Look up a platform preset by name.
pub fn by_name(name: &str) -> Option<Machine> {
    match name.to_ascii_lowercase().as_str() {
        "intel" => Some(intel_i9_9900k()),
        "amd" => Some(amd_a10_7850k()),
        "arm" => Some(arm_cortex_a73()),
        _ => None,
    }
}

/// All three paper platforms.
pub fn all() -> Vec<Machine> {
    vec![intel_i9_9900k(), amd_a10_7850k(), arm_cortex_a73()]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_ordering_matches_parts() {
        let (i, a, r) = (intel_i9_9900k(), amd_a10_7850k(), arm_cortex_a73());
        assert!(i.peak_flops() > a.peak_flops());
        assert!(a.peak_flops() > r.peak_flops());
    }

    #[test]
    fn bandwidth_tiers_monotone() {
        for m in all() {
            assert!(m.bandwidth_for(1024.0) >= m.bandwidth_for(1024.0 * 1024.0));
            assert!(
                m.bandwidth_for(1024.0 * 1024.0)
                    >= m.bandwidth_for(512.0 * 1024.0 * 1024.0)
            );
        }
    }

    #[test]
    fn lookup() {
        assert!(by_name("Intel").is_some());
        assert!(by_name("riscv").is_none());
    }

    #[test]
    fn stream_time_positive_and_monotone() {
        let m = intel_i9_9900k();
        assert!(m.stream_ms(1e6) > 0.0);
        assert!(m.stream_ms(2e6) > m.stream_ms(1e6));
    }
}

//! The platform substrate: analytical machine models standing in for the
//! paper's Intel / AMD / ARM testbeds (DESIGN.md §3 documents the
//! substitution). A [`Simulator`] answers the same queries the paper's
//! profiler answers — primitive execution time and DLT cost for a layer
//! configuration — with platform-dependent non-linear behaviour plus
//! median-of-25-style measurement noise.

pub mod cost;
pub mod machine;
pub mod noise;

pub use machine::Machine;

use crate::layers::ConvConfig;
use crate::primitives::{catalog, Layout};

/// Noise level of the simulated median-of-25 measurements.
pub const NOISE_SIGMA: f64 = 0.02;

/// A simulated profiling target.
#[derive(Debug, Clone)]
pub struct Simulator {
    pub machine: Machine,
    /// Noise sigma (0.0 disables noise — useful for tests).
    pub sigma: f64,
}

impl Simulator {
    pub fn new(machine: Machine) -> Self {
        Self { machine, sigma: NOISE_SIGMA }
    }

    pub fn noiseless(machine: Machine) -> Self {
        Self { machine, sigma: 0.0 }
    }

    pub fn name(&self) -> &'static str {
        self.machine.name
    }

    /// "Profile" primitive `idx` on `cfg`: ms, or None if inapplicable.
    pub fn profile_primitive(&self, idx: usize, cfg: &ConvConfig) -> Option<f64> {
        let prim = &catalog()[idx];
        let base = cost::primitive_ms(&self.machine, prim, cfg)?;
        Some(base * self.noise(&format!("{}/{}/{:?}", self.machine.name, prim.name, cfg)))
    }

    /// Profile all primitives for a layer (the dataset row).
    pub fn profile_layer(&self, cfg: &ConvConfig) -> Vec<Option<f64>> {
        (0..catalog().len()).map(|i| self.profile_primitive(i, cfg)).collect()
    }

    /// DLT cost in ms (zero on the identity).
    pub fn profile_dlt(&self, c: u32, im: u32, src: Layout, dst: Layout) -> f64 {
        let base = cost::dlt_ms(&self.machine, c, im, src, dst);
        if base == 0.0 {
            return 0.0;
        }
        base * self.noise(&format!(
            "{}/dlt/{}/{}/{c}x{im}",
            self.machine.name,
            src.name(),
            dst.name()
        ))
    }

    /// The full 3x3 DLT matrix for a tensor (row = src, col = dst).
    pub fn dlt_matrix(&self, c: u32, im: u32) -> [[f64; 3]; 3] {
        let mut m = [[0.0; 3]; 3];
        for src in Layout::ALL {
            for dst in Layout::ALL {
                m[src.index()][dst.index()] = self.profile_dlt(c, im, src, dst);
            }
        }
        m
    }

    /// Simulated wall-clock cost of *profiling* this layer exhaustively
    /// (the paper's Table 4 "Profiling" column): 25 runs per applicable
    /// primitive.
    pub fn profiling_wallclock_ms(&self, cfg: &ConvConfig) -> f64 {
        let runs = 25.0;
        self.profile_layer(cfg)
            .into_iter()
            .flatten()
            .map(|t| t * runs)
            .sum()
    }

    fn noise(&self, key: &str) -> f64 {
        if self.sigma == 0.0 {
            1.0
        } else {
            noise::jitter(key, self.sigma)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim() -> Simulator {
        Simulator::new(machine::intel_i9_9900k())
    }

    #[test]
    fn profile_layer_length_matches_catalog() {
        let row = sim().profile_layer(&ConvConfig::new(64, 64, 56, 1, 3));
        assert_eq!(row.len(), catalog().len());
        assert!(row.iter().filter(|r| r.is_some()).count() >= 15);
    }

    #[test]
    fn deterministic_measurements() {
        let s = sim();
        let cfg = ConvConfig::new(64, 64, 56, 1, 3);
        assert_eq!(s.profile_primitive(1, &cfg), s.profile_primitive(1, &cfg));
    }

    #[test]
    fn dlt_matrix_diag_zero() {
        let m = sim().dlt_matrix(64, 56);
        for i in 0..3 {
            assert_eq!(m[i][i], 0.0);
            for j in 0..3 {
                if i != j {
                    assert!(m[i][j] > 0.0);
                }
            }
        }
    }

    #[test]
    fn profiling_wallclock_dwarfs_single_run() {
        let s = sim();
        let cfg = ConvConfig::new(128, 128, 28, 1, 3);
        let single: f64 = s.profile_layer(&cfg).into_iter().flatten().sum();
        assert!(s.profiling_wallclock_ms(&cfg) >= single * 20.0);
    }

    #[test]
    fn noiseless_matches_cost_model() {
        let s = Simulator::noiseless(machine::intel_i9_9900k());
        let cfg = ConvConfig::new(64, 64, 56, 1, 3);
        let direct = s.profile_primitive(0, &cfg).unwrap();
        let expected =
            cost::primitive_ms(&s.machine, &catalog()[0], &cfg).unwrap();
        assert_eq!(direct, expected);
    }
}

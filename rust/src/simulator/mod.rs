//! The platform substrate: analytical machine models standing in for the
//! paper's Intel / AMD / ARM testbeds (`ARCHITECTURE.md` documents the
//! substitution). A [`Simulator`] answers the same queries the paper's
//! profiler answers — primitive execution time and DLT cost for a layer
//! configuration — with platform-dependent non-linear behaviour plus
//! median-of-25-style measurement noise.
//!
//! Noise keys are integer-hashed: every query folds
//! `(machine salt, kind tag, primitive index / layout pair, packed config)`
//! through [`noise::fnv1a_words`] instead of formatting a string per query
//! (the old hot-path behaviour) — the cost-query engine in
//! `selection::cache` leans on this being cheap.

pub mod cost;
pub mod machine;
pub mod noise;

pub use machine::Machine;

use crate::layers::ConvConfig;
use crate::primitives::{catalog, Layout};

/// Noise level of the simulated median-of-25 measurements.
pub const NOISE_SIGMA: f64 = 0.02;

/// Domain tags keeping primitive and DLT noise streams disjoint.
const TAG_PRIM: u64 = 0x505249;
const TAG_DLT: u64 = 0x444c54;

/// A simulated profiling target.
#[derive(Debug, Clone)]
pub struct Simulator {
    pub machine: Machine,
    /// Noise sigma (0.0 disables noise — useful for tests).
    pub sigma: f64,
    /// Per-machine noise salt (hash of the machine name, computed once at
    /// construction so per-query keys are pure integer folds).
    salt: u64,
}

impl Simulator {
    pub fn new(machine: Machine) -> Self {
        let salt = noise::fnv1a(machine.name.as_bytes());
        Self { machine, sigma: NOISE_SIGMA, salt }
    }

    pub fn noiseless(machine: Machine) -> Self {
        Self { sigma: 0.0, ..Self::new(machine) }
    }

    pub fn name(&self) -> &'static str {
        self.machine.name
    }

    /// "Profile" primitive `idx` on `cfg`: ms, or None if inapplicable.
    pub fn profile_primitive(&self, idx: usize, cfg: &ConvConfig) -> Option<f64> {
        let prim = &catalog()[idx];
        let base = cost::primitive_ms(&self.machine, prim, cfg)?;
        Some(base * self.noise(&[TAG_PRIM, idx as u64, pack_cfg(cfg)]))
    }

    /// Profile all primitives for a layer (the dataset row).
    pub fn profile_layer(&self, cfg: &ConvConfig) -> Vec<Option<f64>> {
        (0..catalog().len()).map(|i| self.profile_primitive(i, cfg)).collect()
    }

    /// DLT cost in ms (zero on the identity).
    pub fn profile_dlt(&self, c: u32, im: u32, src: Layout, dst: Layout) -> f64 {
        let base = cost::dlt_ms(&self.machine, c, im, src, dst);
        if base == 0.0 {
            return 0.0;
        }
        let pair = (src.index() * 3 + dst.index()) as u64;
        base * self.noise(&[TAG_DLT, pair, (c as u64) << 32 | im as u64])
    }

    /// The full 3x3 DLT matrix for a tensor (row = src, col = dst).
    pub fn dlt_matrix(&self, c: u32, im: u32) -> [[f64; 3]; 3] {
        let mut m = [[0.0; 3]; 3];
        for src in Layout::ALL {
            for dst in Layout::ALL {
                m[src.index()][dst.index()] = self.profile_dlt(c, im, src, dst);
            }
        }
        m
    }

    /// Simulated wall-clock cost of *profiling* this layer exhaustively
    /// (the paper's Table 4 "Profiling" column): 25 runs per applicable
    /// primitive. Profiles the layer once; callers that already hold the
    /// row (a dataset, a [`crate::selection::CostCache`]) should use
    /// [`wallclock_from_row`] instead of paying a second profile.
    pub fn profiling_wallclock_ms(&self, cfg: &ConvConfig) -> f64 {
        wallclock_from_row(&self.profile_layer(cfg))
    }

    fn noise(&self, key: &[u64; 3]) -> f64 {
        if self.sigma == 0.0 {
            1.0
        } else {
            let seed = noise::fnv1a_words(&[self.salt, key[0], key[1], key[2]]);
            noise::jitter_seed(seed, self.sigma)
        }
    }
}

/// Pack a [`ConvConfig`] into one word for noise keying. Field widths
/// cover the paper's Table 1 ranges (k, c ≤ 2048 → 12 bits; im ≤ 299 →
/// 10; s ≤ 4 → 3; f ≤ 11 → 4) with headroom; packing is injective for
/// any in-range config, so distinct configs get distinct noise streams.
fn pack_cfg(cfg: &ConvConfig) -> u64 {
    (cfg.k as u64) << 40 | (cfg.c as u64) << 20 | (cfg.im as u64) << 8 | (cfg.s as u64) << 4
        | cfg.f as u64
}

/// The Table-4 profiling wall-clock implied by an already-profiled row:
/// 25 runs per applicable primitive.
pub fn wallclock_from_row(row: &[Option<f64>]) -> f64 {
    const RUNS: f64 = 25.0;
    row.iter().flatten().map(|t| t * RUNS).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim() -> Simulator {
        Simulator::new(machine::intel_i9_9900k())
    }

    #[test]
    fn profile_layer_length_matches_catalog() {
        let row = sim().profile_layer(&ConvConfig::new(64, 64, 56, 1, 3));
        assert_eq!(row.len(), catalog().len());
        assert!(row.iter().filter(|r| r.is_some()).count() >= 15);
    }

    #[test]
    fn deterministic_measurements() {
        let s = sim();
        let cfg = ConvConfig::new(64, 64, 56, 1, 3);
        assert_eq!(s.profile_primitive(1, &cfg), s.profile_primitive(1, &cfg));
    }

    #[test]
    fn noise_streams_are_distinct() {
        // different primitives, configs and machines must decorrelate
        let s = sim();
        let a = ConvConfig::new(64, 64, 56, 1, 3);
        let b = ConvConfig::new(64, 64, 56, 2, 3);
        let base =
            |idx: usize, cfg: &ConvConfig| cost::primitive_ms(&s.machine, &catalog()[idx], cfg);
        let j = |idx: usize, cfg: &ConvConfig| {
            s.profile_primitive(idx, cfg).unwrap() / base(idx, cfg).unwrap()
        };
        assert_ne!(j(0, &a), j(1, &a));
        assert_ne!(j(0, &a), j(0, &b));
        let arm = Simulator::new(machine::arm_cortex_a73());
        let j_arm = arm.profile_primitive(0, &a).unwrap()
            / cost::primitive_ms(&arm.machine, &catalog()[0], &a).unwrap();
        assert_ne!(j(0, &a), j_arm);
    }

    #[test]
    fn pack_cfg_injective_on_table1_ranges() {
        let cfgs = [
            ConvConfig::new(1, 1, 7, 1, 1),
            ConvConfig::new(2048, 2048, 299, 4, 11),
            ConvConfig::new(64, 64, 56, 1, 3),
            ConvConfig::new(64, 64, 56, 1, 5),
            ConvConfig::new(64, 64, 57, 1, 3),
            ConvConfig::new(64, 65, 56, 1, 3),
            ConvConfig::new(65, 64, 56, 1, 3),
            ConvConfig::new(64, 64, 56, 2, 3),
        ];
        let mut packed: Vec<u64> = cfgs.iter().map(pack_cfg).collect();
        packed.sort();
        packed.dedup();
        assert_eq!(packed.len(), cfgs.len());
    }

    #[test]
    fn dlt_matrix_diag_zero() {
        let m = sim().dlt_matrix(64, 56);
        for i in 0..3 {
            assert_eq!(m[i][i], 0.0);
            for j in 0..3 {
                if i != j {
                    assert!(m[i][j] > 0.0);
                }
            }
        }
    }

    #[test]
    fn profiling_wallclock_dwarfs_single_run() {
        let s = sim();
        let cfg = ConvConfig::new(128, 128, 28, 1, 3);
        let row = s.profile_layer(&cfg);
        let single: f64 = row.iter().flatten().sum();
        assert!(s.profiling_wallclock_ms(&cfg) >= single * 20.0);
        // the row-based variant is exactly the cfg-based one
        assert_eq!(wallclock_from_row(&row), s.profiling_wallclock_ms(&cfg));
    }

    #[test]
    fn noiseless_matches_cost_model() {
        let s = Simulator::noiseless(machine::intel_i9_9900k());
        let cfg = ConvConfig::new(64, 64, 56, 1, 3);
        let direct = s.profile_primitive(0, &cfg).unwrap();
        let expected =
            cost::primitive_ms(&s.machine, &catalog()[0], &cfg).unwrap();
        assert_eq!(direct, expected);
    }
}

//! Per-family analytical cost models.
//!
//! Every primitive's execution time is `max(compute, memory) + overhead`
//! with family-specific traffic/efficiency terms. The models are
//! deliberately *non-linear* in (k, c, im, s, f): cache-capacity knees,
//! small-matrix gemm inefficiency, vectorisation remainders and transform
//! overheads — exactly the structure that makes the paper's NN models beat
//! linear regression, and that differs across the three machines.
//!
//! Times are in **milliseconds**.

use super::machine::Machine;
use crate::layers::ConvConfig;
use crate::primitives::{Family, GemmVariant, Layout, Primitive};

const BYTES: f64 = 4.0; // f32

/// GEMM efficiency for an (m, n, k) product on `mach` with operand
/// transposes `variant`.
fn gemm_eff(mach: &Machine, m: f64, n: f64, variant: GemmVariant) -> f64 {
    // small-matrix penalty: efficiency ramps up with the smallest dim
    // relative to the SIMD width (vector-lane utilisation).
    let lanes = mach.simd_lanes;
    let min_dim = m.min(n);
    let vec_util = (min_dim / lanes).min(1.0) * 0.5 + 0.5 * (n / lanes).min(1.0);
    let transpose = match variant {
        GemmVariant::Ab => 1.0,
        GemmVariant::Atb | GemmVariant::Abt => mach.transpose_penalty,
        GemmVariant::Atbt => mach.transpose_penalty * mach.transpose_penalty,
    };
    (mach.gemm_eff * vec_util * transpose).max(0.02)
}

/// Time of one (m, n, k) gemm, including the bandwidth bound for its
/// working set (blocked: A + B + C plus one extra pass over B per m-block
/// that spills the cache level). Small-gemm inefficiency appears as an
/// additive pipeline-startup cost so the model stays monotone in work.
fn gemm_ms(mach: &Machine, m: f64, n: f64, kk: f64, variant: GemmVariant) -> f64 {
    let flops = 2.0 * m * n * kk;
    let eff = gemm_eff(mach, m, n, variant);
    let lanes = mach.simd_lanes;
    // fixed pipeline-fill latency (independent of the achieved efficiency,
    // so time stays monotone in the problem dimensions)
    let startup = 2.0 * (64.0 * lanes * lanes * 32.0) / mach.peak_flops() * 1e3;
    let compute = flops / (mach.peak_flops() * eff) * 1e3 + startup;
    let ws = (m * kk + kk * n + m * n) * BYTES;
    // if the working set spills a level, B is re-streamed per 128-row block
    let spill_factor = if ws / 1024.0 > mach.l2_kb { 1.0 + (m / 128.0).min(4.0) } else { 1.0 };
    let memory = mach.stream_ms(ws) * spill_factor;
    compute.max(memory)
}

/// Execution time of `prim` on layer `cfg` for machine `mach`, in ms.
/// Returns `None` when the primitive is inapplicable (undefined R_i).
pub fn primitive_ms(mach: &Machine, prim: &Primitive, cfg: &ConvConfig) -> Option<f64> {
    if !prim.applicable(cfg) {
        return None;
    }
    let o = cfg.out_size()? as f64;
    let (k, c, im, s, f) =
        (cfg.k as f64, cfg.c as f64, cfg.im as f64, cfg.s as f64, cfg.f as f64);
    let overhead = mach.call_overhead_us / 1e3;

    let t = match prim.family {
        Family::Direct => {
            // scalar six-loop code: compute-bound at scalar ipc, with a
            // locality knee when one image row-set exceeds L1.
            let flops = 2.0 * cfg.macs();
            let row_set = c * im * BYTES;
            let locality = if row_set / 1024.0 <= mach.l1_kb { 1.0 } else { 2.2 };
            flops * locality / mach.scalar_flops() * 1e3
        }
        Family::Im2 => {
            let patch = c * f * f * o * o * BYTES;
            let gemm = gemm_ms(mach, k, o * o, c * f * f, prim.gemm);
            if prim.copy {
                // materialise patch matrix: write + read back for the gemm
                let copy = mach.stream_ms(patch * 2.0) + mach.stream_ms(c * im * im * BYTES);
                copy + gemm
            } else {
                // scan: no patch matrix; f*f strided passes over the input,
                // each a smaller gemm with strided-read inefficiency.
                let strided = 1.0 + 0.15 * (s - 1.0);
                let small = gemm_ms(mach, k, o * o, c, prim.gemm) * f * f * strided;
                let reread = mach.stream_ms(c * im * im * BYTES) * f.min(3.0);
                small + reread
            }
        }
        Family::Kn2 => {
            // f*f full-image gemms + shifted accumulation traffic
            let g = gemm_ms(mach, k, im * im, c, prim.gemm) * f * f;
            let acc = mach.stream_ms(k * o * o * BYTES * 2.0) * (f * f - 1.0);
            // the -aa (accumulating add) variants trade gemm locality for
            // extra accumulation passes
            let aa = if prim.copy { 1.12 } else { 1.0 };
            (g + acc) * aa
        }
        Family::Wino3 | Family::Wino5 => {
            let m_t = prim.tile_m as f64;
            let a = m_t + f - 1.0;
            let tiles = (o / m_t).ceil().powi(2);
            // input transform: 2 passes of (a x a)·(a x a) per tile-channel
            let t_in = tiles * c * 2.0 * a * a * a * 2.0;
            let t_out = tiles * k * (a * a * m_t + a * m_t * m_t) * 2.0;
            // vectorised variants batch `vec_width` tiles through the VPU
            let vec_eff = if prim.vec_width > 1 {
                (prim.vec_width as f64).min(mach.simd_lanes) / mach.simd_lanes
                    * mach.gemm_eff
            } else {
                mach.wino_scalar_eff
            };
            let transform = (t_in + t_out) / (mach.peak_flops() * vec_eff) * 1e3;
            // a^2 batched gemms of (k x c) x (c x tiles)
            let g = gemm_ms(mach, k, tiles, c, prim.gemm) * a * a;
            // U + V working set pressure: spills add a memory term
            let ws = (a * a * k * c + a * a * tiles * c) * BYTES;
            let spill = mach.stream_ms(ws);
            transform + g + spill
        }
        Family::Conv1x1 => {
            let mut t = gemm_ms(mach, k, o * o, c, prim.gemm);
            if cfg.s > 1 {
                // strided subsample: sparse reads of the input
                t += mach.stream_ms(c * im * im * BYTES) * 0.6;
            }
            t
        }
        Family::Mec => {
            // width-lowered L: (o, im, c*f) copy + o row-gemms
            let lower = mach.stream_ms(o * im * c * f * BYTES * 2.0);
            let row = gemm_ms(mach, o, k, f * c * f, prim.gemm);
            // per-row launches poorly amortised: overhead scales with o
            let row_overhead = o * mach.call_overhead_us / 1e3 * 0.08;
            let part = if prim.copy { 1.06 } else { 1.0 }; // row-partition variant
            (lower + row * o + row_overhead) * part
        }
    };
    Some(t + overhead)
}

/// Data-layout transformation cost `(c, im, src -> dst)` in ms.
/// Zero for the identity; otherwise two passes over the tensor with a
/// platform- and pair-dependent strided-access penalty.
pub fn dlt_ms(mach: &Machine, c: u32, im: u32, src: Layout, dst: Layout) -> f64 {
    if src == dst {
        return 0.0;
    }
    let bytes = c as f64 * (im as f64).powi(2) * BYTES;
    // penalty depends on how hostile the permutation is to the cache line:
    // chw<->hwc moves the channel stride across the whole tensor, the
    // hcw middle layout is cheaper to reach from either side.
    let pair_penalty = match (src, dst) {
        (Layout::Chw, Layout::Hwc) | (Layout::Hwc, Layout::Chw) => 2.0,
        (Layout::Hcw, _) | (_, Layout::Hcw) => 1.4,
        _ => 1.0,
    };
    // scalar gather/scatter: worse on narrow-SIMD machines
    let machine_penalty = 1.0 + 4.0 / mach.simd_lanes;
    mach.stream_ms(bytes * 2.0) * pair_penalty * machine_penalty
        + mach.call_overhead_us / 1e3 * 0.5
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::primitives::catalog;
    use crate::simulator::machine;

    fn cfg(k: u32, c: u32, im: u32, s: u32, f: u32) -> ConvConfig {
        ConvConfig::new(k, c, im, s, f)
    }

    #[test]
    fn applicable_costs_are_positive_finite() {
        let m = machine::intel_i9_9900k();
        for p in catalog() {
            for cc in [cfg(64, 64, 56, 1, 3), cfg(32, 16, 112, 2, 5), cfg(256, 256, 14, 1, 1)] {
                if let Some(t) = primitive_ms(&m, p, &cc) {
                    assert!(t.is_finite() && t > 0.0, "{} {cc:?} -> {t}", p.name);
                }
            }
        }
    }

    #[test]
    fn inapplicable_is_none() {
        let m = machine::intel_i9_9900k();
        let wino = catalog().iter().find(|p| p.name == "winograd-2x2-3x3").unwrap();
        assert!(primitive_ms(&m, wino, &cfg(8, 8, 32, 2, 3)).is_none());
        assert!(primitive_ms(&m, wino, &cfg(8, 8, 32, 1, 5)).is_none());
    }

    #[test]
    fn direct_slower_than_im2col_on_big_layers() {
        let m = machine::intel_i9_9900k();
        let direct = catalog().iter().find(|p| p.family == Family::Direct).unwrap();
        let im2 = catalog().iter().find(|p| p.name == "im2col-copy-ab-ki").unwrap();
        let cc = cfg(256, 256, 56, 1, 3);
        let td = primitive_ms(&m, direct, &cc).unwrap();
        let ti = primitive_ms(&m, im2, &cc).unwrap();
        assert!(td > ti, "direct {td} should exceed im2col {ti}");
    }

    #[test]
    fn winograd_wins_for_3x3_on_intel() {
        // the vectorised winograd should beat im2col for a mid-size 3x3
        let m = machine::intel_i9_9900k();
        let wino =
            catalog().iter().find(|p| p.name == "winograd-4x4-3x3-vec-8").unwrap();
        let im2 = catalog().iter().find(|p| p.name == "im2col-copy-ab-ki").unwrap();
        let cc = cfg(256, 256, 28, 1, 3);
        let tw = primitive_ms(&m, wino, &cc).unwrap();
        let ti = primitive_ms(&m, im2, &cc).unwrap();
        assert!(tw < ti, "wino {tw} vs im2col {ti}");
    }

    #[test]
    fn times_scale_with_work() {
        let m = machine::arm_cortex_a73();
        for p in catalog() {
            let small = cfg(32, 32, 28, 1, 3);
            let big = cfg(128, 128, 56, 1, 3);
            if let (Some(a), Some(b)) =
                (primitive_ms(&m, p, &small), primitive_ms(&m, p, &big))
            {
                assert!(b > a, "{}: {b} !> {a}", p.name);
            }
        }
    }

    #[test]
    fn platforms_rank_differently() {
        // the relative ranking of primitives must differ across machines —
        // the property that makes transfer learning non-trivial.
        let cfgs = [cfg(64, 64, 56, 1, 3), cfg(128, 128, 28, 1, 3), cfg(512, 256, 14, 1, 3)];
        let mut differs = false;
        for cc in cfgs {
            let rank = |m: &Machine| {
                let mut v: Vec<(usize, f64)> = catalog()
                    .iter()
                    .enumerate()
                    .filter_map(|(i, p)| primitive_ms(m, p, &cc).map(|t| (i, t)))
                    .collect();
                v.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
                v.into_iter().map(|(i, _)| i).collect::<Vec<_>>()
            };
            let ri = rank(&machine::intel_i9_9900k());
            let ra = rank(&machine::arm_cortex_a73());
            if ri != ra {
                differs = true;
            }
        }
        assert!(differs);
    }

    #[test]
    fn dlt_identity_is_free() {
        let m = machine::intel_i9_9900k();
        for l in Layout::ALL {
            assert_eq!(dlt_ms(&m, 64, 56, l, l), 0.0);
        }
    }

    #[test]
    fn dlt_cost_scales_with_tensor() {
        let m = machine::amd_a10_7850k();
        let small = dlt_ms(&m, 16, 28, Layout::Chw, Layout::Hwc);
        let big = dlt_ms(&m, 256, 56, Layout::Chw, Layout::Hwc);
        assert!(big > small * 10.0);
    }

    #[test]
    fn arm_slower_than_intel() {
        let im2 = catalog().iter().find(|p| p.name == "im2col-copy-ab-ki").unwrap();
        let cc = cfg(128, 128, 28, 1, 3);
        let ti = primitive_ms(&machine::intel_i9_9900k(), im2, &cc).unwrap();
        let ta = primitive_ms(&machine::arm_cortex_a73(), im2, &cc).unwrap();
        assert!(ta > ti * 2.0, "arm {ta} vs intel {ti}");
    }
}

//! # primsel — CNN primitive selection via learned performance models
//!
//! Rust reimplementation of *"Optimising the Performance of Convolutional
//! Neural Networks across Computing Systems using Transfer Learning"*
//! (Mulder, Radu, Dubach, 2020) as a three-layer rust + JAX + Pallas stack:
//!
//! * **L1** — the convolutional primitives themselves are Pallas kernels
//!   (`python/compile/kernels/`), AOT-lowered to HLO text.
//! * **L2** — the performance models (NN1/NN2 MLPs) are JAX functions
//!   (`python/compile/model.py`), likewise AOT-lowered: `init`,
//!   `train_step`, `train_epoch` and `predict` each ship as one HLO module.
//! * **L3** — this crate: the coordinator that owns datasets, training
//!   loops (driving the AOT artifacts over PJRT), the PBQP selection
//!   solver, the platform simulators, profiling, transfer learning and the
//!   paper's full experiment suite. Python never runs at request time.
//!
//! See `README.md` for the module map and `ARCHITECTURE.md` for the
//! end-to-end dataflow and the shared-cache concurrency model.

pub mod config;
pub mod coordinator;
pub mod dataset;
pub mod experiments;
pub mod health;
pub mod layers;
pub mod linalg;
pub mod networks;
pub mod obs;
pub mod par;
pub mod pbqp;
pub mod perfmodel;
pub mod primitives;
pub mod profiler;
pub mod report;
pub mod runtime;
pub mod selection;
pub mod service;
pub mod simulator;
pub mod sync;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;

//! Minimal JSON support (this image has no serde): a recursive-descent
//! parser for `artifacts/manifest.json` and experiment configuration
//! files, plus a writer for experiment result dumps.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing characters at byte {}", p.pos);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    /// Serialise (stable key order; enough for result dumps).
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for ch in s.chars() {
                    match ch {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!("expected {:?} at byte {}", b as char, self.pos);
        }
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected , or }} got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected , or ] got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                &self.bytes[self.pos..self.pos + 4],
                            )?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        c => bail!("bad escape \\{}", c as char),
                    }
                }
                b => {
                    // collect the full utf-8 sequence
                    let len = match b {
                        0x00..=0x7f => 1,
                        0xc0..=0xdf => 2,
                        0xe0..=0xef => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    self.pos = start + len;
                    s.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": {}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        let b = j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap();
        assert_eq!(b.as_str().unwrap(), "x");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn round_trip() {
        let src = r#"{"m":{"x":[1,2.5,"s"],"y":null,"z":true}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.dump()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""café ü""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "café ü");
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{"n_primitives": 31, "models": {"nn2": {"in_dim": 5,
            "param_shapes": [[5,128],[128]], "files": {"init": "nn2_init.hlo.txt"}}}}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("n_primitives").unwrap().as_usize().unwrap(), 31);
        let shapes = j
            .get("models").unwrap()
            .get("nn2").unwrap()
            .get("param_shapes").unwrap()
            .as_arr().unwrap();
        assert_eq!(shapes[0].as_arr().unwrap()[1].as_usize().unwrap(), 128);
    }
}

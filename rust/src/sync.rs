//! Poison-recovering lock helpers.
//!
//! The serving stack guards shared state (admission lanes, tenant
//! tables, ticket slots, cache shards, the platform map) with standard
//! library locks. A panic while holding one of those locks poisons it,
//! and the previous `expect("... poisoned")` discipline turned that
//! single client panic into a crash for *every* subsequent tenant — one
//! bad request could wedge admission fleet-wide.
//!
//! Every guarded structure in this crate keeps its invariants by
//! construction (counters, bounded deques, fulfil-once slots): a panic
//! mid-critical-section cannot leave them half-updated in a way a later
//! reader would misread. So the right recovery is the one the standard
//! library exposes for exactly this case: take the guard out of the
//! [`PoisonError`] and carry on. These helpers centralise that
//! `unwrap_or_else(PoisonError::into_inner)` so call sites stay as
//! terse as the old `expect` and the policy lives in one place.

use std::sync::{
    Condvar, Mutex, MutexGuard, PoisonError, RwLock, RwLockReadGuard, RwLockWriteGuard,
    WaitTimeoutResult,
};
use std::time::Duration;

/// Lock a mutex, recovering the guard if a previous holder panicked.
pub fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Read-lock an `RwLock`, recovering from poison.
pub fn read<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(PoisonError::into_inner)
}

/// Write-lock an `RwLock`, recovering from poison.
pub fn write<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(PoisonError::into_inner)
}

/// Block on a condvar, recovering the reacquired guard from poison.
pub fn wait<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(PoisonError::into_inner)
}

/// Block on a condvar with a timeout, recovering the guard from poison.
pub fn wait_timeout<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    dur: Duration,
) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
    cv.wait_timeout(guard, dur).unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lock_recovers_after_a_holder_panics() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        // poison the mutex: a thread panics while holding the guard
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("holder dies");
        })
        .join();
        assert!(m.lock().is_err(), "mutex must actually be poisoned");
        // the helper still hands out a usable guard
        *lock(&m) += 1;
        assert_eq!(*lock(&m), 8);
    }

    #[test]
    fn rwlock_recovers_after_a_writer_panics() {
        let l = Arc::new(RwLock::new(vec![1, 2, 3]));
        let l2 = Arc::clone(&l);
        let _ = std::thread::spawn(move || {
            let _g = l2.write().unwrap();
            panic!("writer dies");
        })
        .join();
        assert!(l.read().is_err());
        assert_eq!(read(&l).len(), 3);
        write(&l).push(4);
        assert_eq!(read(&l).len(), 4);
    }

    #[test]
    fn wait_timeout_returns_on_deadline() {
        let m = Mutex::new(());
        let cv = Condvar::new();
        let (_g, res) = wait_timeout(&cv, lock(&m), Duration::from_millis(5));
        assert!(res.timed_out());
    }
}

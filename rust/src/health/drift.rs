//! The rolling drift window: the evidence buffer behind the health
//! state machine's drift statistic.
//!
//! Each entry is one replayed layer config — the cost row the serving
//! cache answered with (`preds`) next to what the live target measured
//! for the same config (`measured`). The window is bounded: old evidence
//! ages out, so a platform that drifts and then recovers (or gets
//! recalibrated) sees its score fall back without any manual reset.

use crate::perfmodel::transfer::{self, MIN_CALIB_RATIOS};
use std::collections::VecDeque;

/// A bounded window of (served prediction, live measurement) rows with
/// the §4.4 drift statistic over its contents.
///
/// The score is [`transfer::drift_score`]: per primitive column, the
/// median measured/served ratio across the window, reduced to
/// `max_j |ln factor_j|`. A platform whose serving model still matches
/// its device scores ≈ 0; a column drifted to `r×` scores `|ln r|`.
///
/// ```
/// use primsel::health::DriftWindow;
///
/// let mut w = DriftWindow::new(16);
/// assert_eq!(w.score(), 0.0); // empty window: no evidence, no drift
///
/// // the device now runs every primitive at twice the served cost
/// for _ in 0..4 {
///     w.push(vec![1.0, 5.0], vec![Some(2.0), Some(10.0)]);
/// }
/// assert!((w.score() - 2f64.ln()).abs() < 1e-9);
///
/// // capacity bounds the evidence: pushing 16 agreeing rows evicts the
/// // drifted ones and the score decays back to zero
/// for _ in 0..16 {
///     w.push(vec![1.0, 5.0], vec![Some(1.0), Some(5.0)]);
/// }
/// assert_eq!(w.len(), 16);
/// assert_eq!(w.score(), 0.0);
/// ```
#[derive(Debug, Clone)]
pub struct DriftWindow {
    cap: usize,
    rows: VecDeque<(Vec<f64>, Vec<Option<f64>>)>,
}

impl DriftWindow {
    /// An empty window holding at most `cap` rows (floored at 1).
    pub fn new(cap: usize) -> Self {
        let cap = cap.max(1);
        Self { cap, rows: VecDeque::with_capacity(cap) }
    }

    /// Append one replayed config's (served, measured) rows, evicting the
    /// oldest entry when full. Served values of `NaN` mark positions the
    /// cache had no cost for; they are skipped by the statistic.
    pub fn push(&mut self, preds: Vec<f64>, measured: Vec<Option<f64>>) {
        if self.rows.len() == self.cap {
            self.rows.pop_front();
        }
        self.rows.push_back((preds, measured));
    }

    /// Rows currently held.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// Whether the window holds no evidence.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Maximum rows the window holds.
    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Drop all evidence (after a recalibration: the old rows compare
    /// against a model that no longer serves).
    pub fn clear(&mut self) {
        self.rows.clear();
    }

    /// The drift statistic over the current window (0.0 when empty; see
    /// the type docs).
    pub fn score(&self) -> f64 {
        let (preds, measured): (Vec<_>, Vec<_>) = self.rows.iter().cloned().unzip();
        transfer::drift_score(&preds, &measured, MIN_CALIB_RATIOS)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eviction_keeps_len_at_capacity() {
        let mut w = DriftWindow::new(3);
        for i in 0..10 {
            w.push(vec![1.0], vec![Some(i as f64 + 1.0)]);
        }
        assert_eq!(w.len(), 3);
        assert_eq!(w.capacity(), 3);
        // the survivors are the last three pushes: medians over {8,9,10}
        assert!((w.score() - 9f64.ln()).abs() < 1e-9);
    }

    #[test]
    fn clear_resets_evidence() {
        let mut w = DriftWindow::new(4);
        for _ in 0..4 {
            w.push(vec![1.0], vec![Some(3.0)]);
        }
        assert!(w.score() > 1.0);
        w.clear();
        assert!(w.is_empty());
        assert_eq!(w.score(), 0.0);
    }

    #[test]
    fn nan_preds_are_ignored_not_poisonous() {
        let mut w = DriftWindow::new(8);
        for _ in 0..4 {
            w.push(vec![f64::NAN, 2.0], vec![Some(1.0), Some(4.0)]);
        }
        assert!((w.score() - 2f64.ln()).abs() < 1e-9);
    }

    #[test]
    fn zero_capacity_floors_to_one() {
        let mut w = DriftWindow::new(0);
        w.push(vec![1.0], vec![Some(1.0)]);
        w.push(vec![1.0], vec![Some(1.0)]);
        assert_eq!(w.len(), 1);
    }
}

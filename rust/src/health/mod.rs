//! # Platform health: drift detection, self-healing, quarantine
//!
//! The paper's §4.4 transfer flow calibrates a platform *once*; real
//! fleets drift (thermal throttling, firmware updates, co-tenancy). This
//! module closes the loop: every monitored platform gets a shadow
//! sampler that replays a fraction of served selections against the live
//! target device, a rolling drift statistic built from the same factor
//! machinery §4.4 uses to *fit* corrections ([`DriftWindow`]), and a
//! state machine that recalibrates automatically and degrades gracefully
//! when recalibration itself keeps failing:
//!
//! ```text
//!                 drift ≤ band                 drift > band
//!               ┌─────────────┐             ┌──────────────┐
//!               ▼             │             ▼              │
//!          ┌─────────┐   ┌──────────┐   ┌───────────────┐  │
//!          │ Healthy │──►│ Drifting │──►│ Recalibrating │──┘ (failure,
//!          └─────────┘   └──────────┘   └───────────────┘    < N consec.,
//!               ▲    drift > band   next     │    │          backoff 2^k)
//!               │                sampled     │    │
//!               │                 observe    │    │ N consecutive
//!               │         success            │    │ failures
//!               └────────────────────────────┘    ▼
//!                                          ┌─────────────┐
//!                  probe success           │ Quarantined │──┐
//!               ◄──────────────────────────│ (refused)   │  │ cool-down
//!                                          └─────────────┘  │ elapsed:
//!                                                 ▲         │ probe
//!                                                 └─────────┘
//! ```
//!
//! * `Healthy`, `Drifting` and `Recalibrating` all **serve**: drift makes
//!   selections stale, not wrong, so traffic keeps flowing while the
//!   factors refresh in the background of a request.
//! * `Quarantined` **refuses**: every admission resolves immediately
//!   with a typed [`QuarantinedError`] (downcastable from the crate's
//!   `anyhow`-style error — a ticket never hangs on a dead platform).
//!   After `cool_down`, the next admission *probes*: it runs one
//!   synchronous recalibration, readmitting on success and re-arming the
//!   cool-down on failure.
//!
//! The [`Coordinator`](crate::coordinator::Coordinator) drives this per
//! request: `monitor_platform` attaches a monitor, `select_one` consults
//! it at admission and feeds it after each solve, and `platform_health`
//! snapshots every monitor for operators (the service layer renders the
//! same snapshots in `ServiceStats`). Fault injection for all of it
//! lives in [`FaultySource`](crate::selection::FaultySource).
//!
//! Recalibration — whether triggered here by drift or called explicitly
//! — re-registers the platform's serving cache through the
//! coordinator's single insertion funnel, which also drops every cached
//! time×space Pareto front for the platform: a health-loop refresh can
//! never leave a stale front serving budget queries.

pub mod drift;

pub use drift::DriftWindow;

use crate::layers::ConvConfig;
use crate::networks::Network;
use crate::selection::{CostCache, CostSource};
use crate::simulator::noise::{fnv1a_words, SplitMix64};
use crate::sync;
use std::collections::HashMap;
use std::fmt;
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

/// Hash salt for the shadow-sampling coin (vs. recalibration seeds).
const SALT_SAMPLE: u64 = 0x4845_414C_5448_5341; // "HEALTHSA"
/// Hash salt mixing recalibration-attempt seeds.
const SALT_RECAL: u64 = 0x4845_414C_5448_5243; // "HEALTHRC"

/// Where a monitored platform sits in the health state machine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HealthState {
    /// Drift statistic inside the band; serving normally.
    Healthy,
    /// Drift statistic beyond the band; still serving, recalibration
    /// pending (or backing off after a failed attempt).
    Drifting,
    /// A recalibration is in flight; still serving from the old cache.
    Recalibrating,
    /// Too many consecutive recalibration failures; admissions are
    /// refused with [`QuarantinedError`] until a cool-down probe
    /// succeeds.
    Quarantined,
}

impl HealthState {
    /// Whether requests for the platform are admitted in this state.
    pub fn is_serving(self) -> bool {
        self != HealthState::Quarantined
    }

    /// Stable lowercase name (rendered in stats tables and recorded in
    /// flight-recorder transition events).
    pub fn name(self) -> &'static str {
        match self {
            HealthState::Healthy => "healthy",
            HealthState::Drifting => "drifting",
            HealthState::Recalibrating => "recalibrating",
            HealthState::Quarantined => "quarantined",
        }
    }

    /// Numeric code for gauges (`primsel.health.state`): 0 healthy,
    /// 1 drifting, 2 recalibrating, 3 quarantined.
    pub fn code(self) -> u64 {
        match self {
            HealthState::Healthy => 0,
            HealthState::Drifting => 1,
            HealthState::Recalibrating => 2,
            HealthState::Quarantined => 3,
        }
    }
}

impl fmt::Display for HealthState {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Tuning for one platform's monitor. The defaults suit a long-running
/// service (light shadow sampling, a band well above model noise,
/// patient quarantine); tests tighten everything to make transitions
/// happen in a handful of requests.
#[derive(Debug, Clone)]
pub struct HealthPolicy {
    /// Fraction of served selections whose layer configs are replayed
    /// against the live target (0 disables shadow traffic entirely,
    /// 1 replays every request). The per-request coin is a pure function
    /// of `(seed, observation index)` — deterministic, order-free at the
    /// endpoints.
    pub sample_fraction: f64,
    /// Seed for the sampling coin and recalibration draws.
    pub seed: u64,
    /// Rolling window capacity (replayed configs retained).
    pub window: usize,
    /// Minimum window fill before the drift statistic is trusted; below
    /// this no transition happens.
    pub min_window: usize,
    /// Drift band: state goes `Drifting` when the windowed statistic
    /// (max per-column `|ln(measured/served factor)|`) exceeds this.
    /// The default 0.35 tolerates factor drift up to ~1.42x / 0.70x.
    pub drift_band: f64,
    /// Whether `Drifting` triggers automatic recalibration (on the next
    /// sampled observation past any backoff).
    pub auto_recalibrate: bool,
    /// Calibration fraction for automatic recalibration draws.
    pub recalib_fraction: f64,
    /// Consecutive recalibration failures before `Quarantined`.
    pub max_failures: u32,
    /// Base delay between failed recalibration attempts; attempt `k`
    /// (1-based) waits `backoff * 2^(k-1)`.
    pub backoff: Duration,
    /// How long a quarantined platform waits before an admission is
    /// allowed to probe-recalibrate it.
    pub cool_down: Duration,
}

impl Default for HealthPolicy {
    fn default() -> Self {
        Self {
            sample_fraction: 0.05,
            seed: 0,
            window: 64,
            min_window: 12,
            drift_band: 0.35,
            auto_recalibrate: true,
            recalib_fraction: 0.02,
            max_failures: 3,
            backoff: Duration::from_millis(250),
            cool_down: Duration::from_secs(5),
        }
    }
}

impl HealthPolicy {
    /// Set the shadow-sampling fraction and seed (builder style).
    pub fn with_sampling(mut self, fraction: f64, seed: u64) -> Self {
        self.sample_fraction = fraction;
        self.seed = seed;
        self
    }

    /// Set window capacity and minimum fill (builder style).
    pub fn with_window(mut self, window: usize, min_window: usize) -> Self {
        self.window = window;
        self.min_window = min_window;
        self
    }

    /// Set the drift band (builder style).
    pub fn with_drift_band(mut self, band: f64) -> Self {
        self.drift_band = band;
        self
    }

    /// Enable/disable automatic recalibration and set its calibration
    /// fraction (builder style).
    pub fn with_auto_recalibrate(mut self, on: bool, fraction: f64) -> Self {
        self.auto_recalibrate = on;
        self.recalib_fraction = fraction;
        self
    }

    /// Set the quarantine knobs (builder style).
    pub fn with_quarantine(
        mut self,
        max_failures: u32,
        backoff: Duration,
        cool_down: Duration,
    ) -> Self {
        self.max_failures = max_failures;
        self.backoff = backoff;
        self.cool_down = cool_down;
        self
    }

    /// Reject nonsensical policies before a monitor is built from one.
    pub fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.sample_fraction),
            "sample_fraction must be in [0, 1], got {}",
            self.sample_fraction
        );
        anyhow::ensure!(
            self.drift_band.is_finite() && self.drift_band > 0.0,
            "drift_band must be positive, got {}",
            self.drift_band
        );
        anyhow::ensure!(
            self.recalib_fraction > 0.0 && self.recalib_fraction <= 1.0,
            "recalib_fraction must be in (0, 1], got {}",
            self.recalib_fraction
        );
        anyhow::ensure!(self.max_failures >= 1, "max_failures must be at least 1");
        anyhow::ensure!(self.min_window >= 1, "min_window must be at least 1");
        anyhow::ensure!(
            self.window >= self.min_window,
            "window ({}) must hold at least min_window ({}) rows",
            self.window,
            self.min_window
        );
        Ok(())
    }
}

/// The typed refusal a quarantined platform answers admissions with.
/// Travels through the crate's error type and stays downcastable:
/// `err.downcast_ref::<QuarantinedError>()` recovers it behind any
/// added context, so callers (and the service's tickets) can tell
/// "platform is quarantined" from ordinary request errors.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct QuarantinedError {
    pub platform: String,
    /// Consecutive recalibration failures at refusal time.
    pub consecutive_failures: u32,
    /// Time until the next admission is allowed to probe.
    pub retry_in: Duration,
}

impl fmt::Display for QuarantinedError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "platform {:?} is quarantined after {} consecutive recalibration failures; \
             next probe allowed in {:.0?}",
            self.platform, self.consecutive_failures, self.retry_in
        )
    }
}

impl std::error::Error for QuarantinedError {}

/// Operator-facing snapshot of one monitored platform.
#[derive(Debug, Clone)]
pub struct PlatformHealth {
    pub platform: String,
    pub state: HealthState,
    /// Latest windowed drift statistic (0.0 until `min_window` fills).
    pub drift: f64,
    /// Rows currently in the drift window.
    pub window: usize,
    /// Requests observed for this platform since monitoring began.
    pub observed: u64,
    /// Observed requests the shadow sampler replayed.
    pub sampled: u64,
    /// Shadow replays that panicked (target fault during a probe row).
    pub probe_failures: u64,
    /// Successful recalibrations (automatic + quarantine probes).
    pub recalibrations: u64,
    /// Failed recalibration attempts, lifetime.
    pub recal_failures: u64,
    /// Failures since the last success (what quarantine triggers on).
    pub consecutive_failures: u32,
    /// Times the platform entered quarantine.
    pub quarantines: u64,
}

/// Render a panic payload as text (the shape `std::panic::catch_unwind`
/// hands back) — shared by the recalibration guard and the service
/// worker's fault boundary.
pub(crate) fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    payload
        .downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "non-string panic payload".to_string())
}

/// Internal mutable state of one platform's monitor.
struct MonitorState {
    health: HealthState,
    window: DriftWindow,
    drift: f64,
    observed: u64,
    sampled: u64,
    probe_failures: u64,
    recalibrations: u64,
    recal_failures: u64,
    consecutive_failures: u32,
    quarantines: u64,
    /// Earliest instant the next recalibration attempt (automatic retry
    /// or quarantine probe) may run.
    not_before: Instant,
    /// Monotone counter mixing per-attempt recalibration seeds.
    attempt: u64,
    /// A recalibration is in flight; transitions and further attempts
    /// hold off until its outcome lands.
    busy: bool,
    /// Observations left to shadow-sample unconditionally, ahead of the
    /// deterministic coin — set by an ops-plane alert nudge
    /// ([`PlatformMonitor::boost`]).
    boosted: u64,
}

/// One monitored platform: the live target to replay against, the
/// policy, and the state machine. Driven entirely by the coordinator
/// ([`admit`](Self::admit) before a solve, [`observe`](Self::observe)
/// after); recalibration is injected as a closure so this type never
/// depends on the coordinator.
pub(crate) struct PlatformMonitor {
    platform: String,
    target: Arc<dyn CostSource>,
    policy: HealthPolicy,
    state: Mutex<MonitorState>,
}

/// The recalibration hook [`PlatformMonitor`] calls: given an attempt
/// counter (for seed mixing), run one recalibration and report success
/// or a failure message. Implementations must not panic — wrap fallible
/// sources in `catch_unwind`.
pub(crate) type RecalFn<'a> = &'a dyn Fn(u64) -> Result<(), String>;

impl PlatformMonitor {
    fn new(platform: &str, target: Arc<dyn CostSource>, policy: HealthPolicy) -> Self {
        let window = DriftWindow::new(policy.window);
        Self {
            platform: platform.to_string(),
            target,
            policy,
            state: Mutex::new(MonitorState {
                health: HealthState::Healthy,
                window,
                drift: 0.0,
                observed: 0,
                sampled: 0,
                probe_failures: 0,
                recalibrations: 0,
                recal_failures: 0,
                consecutive_failures: 0,
                quarantines: 0,
                not_before: Instant::now(),
                attempt: 0,
                busy: false,
                boosted: 0,
            }),
        }
    }

    pub(crate) fn policy(&self) -> &HealthPolicy {
        &self.policy
    }

    /// Mix the policy seed with an attempt counter into a fresh
    /// calibration-draw seed, so retries draw different samples.
    pub(crate) fn attempt_seed(&self, attempt: u64) -> u64 {
        fnv1a_words(&[self.policy.seed, SALT_RECAL, attempt])
    }

    /// Shadow-sample the next `n` observations unconditionally (the
    /// ops-plane alert nudge). Boosts don't stack beyond the largest
    /// outstanding request, so repeated alerts can't pin sampling on.
    pub(crate) fn boost(&self, n: u64) {
        let mut s = sync::lock(&self.state);
        s.boosted = s.boosted.max(n);
    }

    /// Deterministic sampling coin for the `n`-th observation.
    fn sample_coin(&self, n: u64) -> bool {
        let f = self.policy.sample_fraction;
        if f <= 0.0 {
            return false;
        }
        if f >= 1.0 {
            return true;
        }
        SplitMix64::new(fnv1a_words(&[self.policy.seed, SALT_SAMPLE, n])).next_f64() < f
    }

    /// Admission gate, called before a request for this platform is
    /// solved. Serving states pass through; `Quarantined` refuses with
    /// the typed error — unless the cool-down has elapsed, in which case
    /// this admission *probes*: it runs one synchronous recalibration
    /// and serves on success.
    pub(crate) fn admit(&self, recal: RecalFn<'_>) -> Result<(), QuarantinedError> {
        let attempt = {
            let mut s = sync::lock(&self.state);
            if s.health != HealthState::Quarantined {
                return Ok(());
            }
            let now = Instant::now();
            if s.busy || now < s.not_before {
                return Err(QuarantinedError {
                    platform: self.platform.clone(),
                    consecutive_failures: s.consecutive_failures,
                    retry_in: s.not_before.saturating_duration_since(now),
                });
            }
            // cool-down elapsed: this admission probes. State stays
            // Quarantined (concurrent admissions keep being refused);
            // `busy` claims the probe for this thread.
            s.busy = true;
            let a = s.attempt;
            s.attempt += 1;
            a
        };
        self.apply_recal_outcome(recal(attempt))
    }

    /// Post-solve hook: count the observation, maybe shadow-replay the
    /// network's layer configs against the live target, rescore drift,
    /// and fire automatic recalibration when due. `cache` is the
    /// platform's serving cache (the "predicted" side of the replay).
    ///
    /// Automatic recalibration fires on the first *sampled* observation
    /// after the platform entered `Drifting` (and past any backoff) —
    /// detection and repair are separate observations, so state is
    /// externally visible between them.
    pub(crate) fn observe(&self, net: &Network, cache: &CostCache<'static>, recal: RecalFn<'_>) {
        let now = Instant::now();
        let (attempt, due) = {
            let mut s = sync::lock(&self.state);
            s.observed += 1;
            // an alert boost spends before the coin so early sampling is
            // guaranteed; the coin sequence itself stays untouched (it
            // keys on `observed`), so post-boost behaviour is identical
            let take = if s.boosted > 0 {
                s.boosted -= 1;
                true
            } else {
                self.sample_coin(s.observed)
            };
            if !take {
                return;
            }
            s.sampled += 1;
            let due = self.policy.auto_recalibrate
                && s.health == HealthState::Drifting
                && !s.busy
                && now >= s.not_before;
            if due {
                s.busy = true;
                let prev = s.health;
                s.health = HealthState::Recalibrating;
                self.note_transition(prev, s.health, s.drift);
                s.attempt += 1;
            }
            (s.attempt - u64::from(due), due)
        };
        if due {
            // repair beats more evidence: skip the replay and spend this
            // observation on the recalibration itself
            let _ = self.apply_recal_outcome(recal(attempt));
            return;
        }

        // shadow replay outside the lock: the target may be slow (or
        // faulty — a panic here is a probe failure, not a crash)
        let mut configs: Vec<ConvConfig> = Vec::new();
        for cfg in &net.layers {
            if !configs.contains(cfg) {
                configs.push(*cfg);
            }
        }
        let replay = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            configs
                .iter()
                .map(|cfg| {
                    let preds: Vec<f64> =
                        cache.row(cfg).iter().map(|t| t.unwrap_or(f64::NAN)).collect();
                    let measured: Vec<Option<f64>> = self.target.layer_costs(cfg).into_owned();
                    (preds, measured)
                })
                .collect::<Vec<_>>()
        }));

        let mut s = sync::lock(&self.state);
        match replay {
            Ok(rows) => {
                for (preds, measured) in rows {
                    s.window.push(preds, measured);
                }
                if s.window.len() >= self.policy.min_window {
                    s.drift = s.window.score();
                    // band transitions only apply to the serving states a
                    // score can move; an in-flight recalibration's outcome
                    // owns the next transition
                    if !s.busy
                        && matches!(s.health, HealthState::Healthy | HealthState::Drifting)
                    {
                        let prev = s.health;
                        s.health = if s.drift > self.policy.drift_band {
                            HealthState::Drifting
                        } else {
                            HealthState::Healthy
                        };
                        self.note_transition(prev, s.health, s.drift);
                    }
                }
            }
            Err(_) => s.probe_failures += 1,
        }
    }

    /// Land a recalibration outcome: success heals (fresh factors serve,
    /// stale evidence drops), failure escalates (backoff, then
    /// quarantine at `max_failures` consecutive).
    fn apply_recal_outcome(&self, outcome: Result<(), String>) -> Result<(), QuarantinedError> {
        let now = Instant::now();
        let mut s = sync::lock(&self.state);
        s.busy = false;
        let prev = s.health;
        match outcome {
            Ok(()) => {
                s.recalibrations += 1;
                s.consecutive_failures = 0;
                crate::obs::flight_recorder().record_recalibration(&self.platform, true, s.drift);
                // the window compared against a model that no longer
                // serves; its evidence is void
                s.window.clear();
                s.drift = 0.0;
                s.health = HealthState::Healthy;
                s.not_before = now;
                self.note_transition(prev, s.health, s.drift);
                Ok(())
            }
            Err(_msg) => {
                s.recal_failures += 1;
                s.consecutive_failures += 1;
                crate::obs::flight_recorder().record_recalibration(&self.platform, false, s.drift);
                if s.consecutive_failures >= self.policy.max_failures {
                    if s.consecutive_failures == self.policy.max_failures {
                        s.quarantines += 1;
                    }
                    s.health = HealthState::Quarantined;
                    s.not_before = now + self.policy.cool_down;
                    self.note_transition(prev, s.health, s.drift);
                    Err(QuarantinedError {
                        platform: self.platform.clone(),
                        consecutive_failures: s.consecutive_failures,
                        retry_in: self.policy.cool_down,
                    })
                } else {
                    s.health = HealthState::Drifting;
                    let shift = (s.consecutive_failures - 1).min(16);
                    s.not_before = now + self.policy.backoff * (1u32 << shift);
                    self.note_transition(prev, s.health, s.drift);
                    Ok(())
                }
            }
        }
    }

    /// Record a health-state change as a structured flight-recorder
    /// event (no-op when the state did not actually change).
    fn note_transition(&self, from: HealthState, to: HealthState, drift: f64) {
        if from != to {
            crate::obs::flight_recorder().record_transition(
                &self.platform,
                from.name(),
                to.name(),
                drift,
            );
        }
    }

    /// Operator snapshot of the current state.
    pub(crate) fn snapshot(&self) -> PlatformHealth {
        let s = sync::lock(&self.state);
        PlatformHealth {
            platform: self.platform.clone(),
            state: s.health,
            drift: s.drift,
            window: s.window.len(),
            observed: s.observed,
            sampled: s.sampled,
            probe_failures: s.probe_failures,
            recalibrations: s.recalibrations,
            recal_failures: s.recal_failures,
            consecutive_failures: s.consecutive_failures,
            quarantines: s.quarantines,
        }
    }
}

/// The coordinator's registry of platform monitors.
#[derive(Default)]
pub(crate) struct HealthMonitor {
    monitors: RwLock<HashMap<String, Arc<PlatformMonitor>>>,
}

impl HealthMonitor {
    /// Attach (or replace) the monitor for `platform`.
    pub(crate) fn register(
        &self,
        platform: &str,
        target: Arc<dyn CostSource>,
        policy: HealthPolicy,
    ) {
        let mon = Arc::new(PlatformMonitor::new(platform, target, policy));
        sync::write(&self.monitors).insert(platform.to_string(), mon);
    }

    /// The monitor for `platform`, if one is attached.
    pub(crate) fn get(&self, platform: &str) -> Option<Arc<PlatformMonitor>> {
        sync::read(&self.monitors).get(platform).cloned()
    }

    /// Ask `platform`'s monitor to shadow-sample its next `n`
    /// observations unconditionally. Returns whether a monitor exists.
    pub(crate) fn boost(&self, platform: &str, n: u64) -> bool {
        match self.get(platform) {
            Some(m) => {
                m.boost(n);
                true
            }
            None => false,
        }
    }

    /// [`Self::boost`] for every monitored platform; returns how many
    /// monitors were nudged.
    pub(crate) fn boost_all(&self, n: u64) -> usize {
        let monitors: Vec<Arc<PlatformMonitor>> =
            sync::read(&self.monitors).values().cloned().collect();
        for m in &monitors {
            m.boost(n);
        }
        monitors.len()
    }

    /// Snapshot every monitor, sorted by platform name.
    pub(crate) fn snapshot(&self) -> Vec<PlatformHealth> {
        let mut out: Vec<PlatformHealth> =
            sync::read(&self.monitors).values().map(|m| m.snapshot()).collect();
        out.sort_by(|a, b| a.platform.cmp(&b.platform));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::networks;
    use std::borrow::Cow;
    use std::sync::atomic::{AtomicU64, Ordering};

    /// A constant-cost source: every primitive costs `ms`, DLTs are
    /// free. Counts queries so tests can assert shadow-traffic volume.
    struct Flat {
        ms: AtomicU64,
        queries: AtomicU64,
    }

    impl Flat {
        fn new(ms: f64) -> Self {
            Self { ms: AtomicU64::new(ms.to_bits()), queries: AtomicU64::new(0) }
        }

        fn set(&self, ms: f64) {
            self.ms.store(ms.to_bits(), Ordering::Relaxed);
        }

        fn queries(&self) -> u64 {
            self.queries.load(Ordering::Relaxed)
        }
    }

    impl CostSource for Flat {
        fn layer_costs(&self, _cfg: &ConvConfig) -> Cow<'_, [Option<f64>]> {
            self.queries.fetch_add(1, Ordering::Relaxed);
            Cow::Owned(vec![Some(f64::from_bits(self.ms.load(Ordering::Relaxed))); 4])
        }

        fn dlt_cost(
            &self,
            _c: u32,
            _im: u32,
            _src: crate::primitives::Layout,
            _dst: crate::primitives::Layout,
        ) -> f64 {
            0.0
        }
    }

    fn tight_policy() -> HealthPolicy {
        HealthPolicy::default()
            .with_sampling(1.0, 7)
            .with_window(16, 4)
            .with_drift_band(0.5)
            .with_quarantine(2, Duration::ZERO, Duration::from_millis(40))
    }

    fn monitor_over(
        target: Arc<Flat>,
        policy: HealthPolicy,
    ) -> (PlatformMonitor, CostCache<'static>) {
        // the serving cache predicts a constant 1.0 ms per primitive
        let cache = CostCache::new_shared(Arc::new(Flat::new(1.0)) as Arc<dyn CostSource>);
        (PlatformMonitor::new("p", target, policy), cache)
    }

    fn no_recal(_a: u64) -> Result<(), String> {
        panic!("recalibration must not fire in this test")
    }

    #[test]
    fn healthy_to_drifting_and_back_tracks_the_band() {
        let target = Arc::new(Flat::new(1.0));
        let policy = tight_policy().with_auto_recalibrate(false, 0.02);
        let (mon, cache) = monitor_over(Arc::clone(&target), policy);
        let net = networks::alexnet();

        mon.observe(&net, &cache, &no_recal);
        assert_eq!(mon.snapshot().state, HealthState::Healthy);
        assert!(mon.snapshot().drift < 0.1);

        // the device slows 3x: next replays push the score past the band
        target.set(3.0);
        for _ in 0..6 {
            mon.observe(&net, &cache, &no_recal);
        }
        let snap = mon.snapshot();
        assert_eq!(snap.state, HealthState::Drifting);
        assert!((snap.drift - 3f64.ln()).abs() < 0.2, "{}", snap.drift);

        // recovery: enough agreeing rows age the drifted evidence out
        target.set(1.0);
        for _ in 0..20 {
            mon.observe(&net, &cache, &no_recal);
        }
        assert_eq!(mon.snapshot().state, HealthState::Healthy);
    }

    #[test]
    fn auto_recalibration_fires_on_the_next_sampled_observe() {
        let target = Arc::new(Flat::new(4.0));
        let (mon, cache) = monitor_over(target, tight_policy());
        let net = networks::alexnet();
        let fired = AtomicU64::new(0);
        let recal = |_a: u64| {
            fired.fetch_add(1, Ordering::Relaxed);
            Ok(())
        };

        // drive until Drifting — recal must NOT fire on the detecting
        // observation itself
        while mon.snapshot().state != HealthState::Drifting {
            mon.observe(&net, &cache, &recal);
            assert!(mon.snapshot().observed < 50, "never entered Drifting");
        }
        assert_eq!(fired.load(Ordering::Relaxed), 0);

        // the next observation repairs: success → Healthy, evidence gone
        mon.observe(&net, &cache, &recal);
        assert_eq!(fired.load(Ordering::Relaxed), 1);
        let snap = mon.snapshot();
        assert_eq!(snap.state, HealthState::Healthy);
        assert_eq!(snap.recalibrations, 1);
        assert_eq!(snap.window, 0);
        assert_eq!(snap.drift, 0.0);
    }

    #[test]
    fn repeated_failures_quarantine_then_probe_readmits() {
        let target = Arc::new(Flat::new(4.0));
        let (mon, cache) = monitor_over(target, tight_policy());
        let net = networks::alexnet();
        let failing = |_a: u64| Err("injected".to_string());

        while mon.snapshot().state != HealthState::Drifting {
            mon.observe(&net, &cache, &failing);
        }
        // max_failures = 2 with zero backoff: two more sampled
        // observations exhaust the attempts
        mon.observe(&net, &cache, &failing);
        let mid = mon.snapshot();
        assert_eq!(mid.state, HealthState::Drifting, "one failure backs off, still serving");
        assert_eq!(mid.consecutive_failures, 1);
        mon.observe(&net, &cache, &failing);
        let snap = mon.snapshot();
        assert_eq!(snap.state, HealthState::Quarantined);
        assert_eq!(snap.quarantines, 1);
        assert_eq!(snap.recal_failures, 2);

        // inside the cool-down every admission refuses with the typed
        // error and never invokes the recal hook
        let err = mon.admit(&no_recal).unwrap_err();
        assert_eq!(err.platform, "p");
        assert_eq!(err.consecutive_failures, 2);

        // after the cool-down the next admission probes; success heals
        std::thread::sleep(Duration::from_millis(45));
        let probe_ok = |_a: u64| Ok(());
        mon.admit(&probe_ok).unwrap();
        let healed = mon.snapshot();
        assert_eq!(healed.state, HealthState::Healthy);
        assert_eq!(healed.recalibrations, 1);
        assert_eq!(healed.consecutive_failures, 0);
        // and admissions are plain pass-throughs again
        mon.admit(&no_recal).unwrap();
    }

    #[test]
    fn failed_probe_rearms_the_cool_down() {
        let target = Arc::new(Flat::new(4.0));
        let (mon, cache) = monitor_over(target, tight_policy());
        let net = networks::alexnet();
        let failing = |_a: u64| Err("injected".to_string());
        while mon.snapshot().state != HealthState::Quarantined {
            mon.observe(&net, &cache, &failing);
        }
        std::thread::sleep(Duration::from_millis(45));
        let err = mon.admit(&failing).unwrap_err();
        assert_eq!(err.consecutive_failures, 3);
        // the probe failure re-armed the cool-down: an immediate retry
        // is refused without invoking the hook
        assert!(mon.admit(&no_recal).is_err());
        assert_eq!(mon.snapshot().state, HealthState::Quarantined);
        // a single quarantine entry despite multiple failures inside it
        assert_eq!(mon.snapshot().quarantines, 1);
    }

    #[test]
    fn sampling_fraction_zero_generates_no_shadow_traffic() {
        let target = Arc::new(Flat::new(9.0)); // wildly drifted…
        let policy = tight_policy().with_sampling(0.0, 7);
        let (mon, cache) = monitor_over(Arc::clone(&target), policy);
        let net = networks::alexnet();
        for _ in 0..50 {
            mon.observe(&net, &cache, &no_recal);
        }
        // …but with sampling off nothing is replayed, so nothing is seen
        let snap = mon.snapshot();
        assert_eq!(target.queries(), 0);
        assert_eq!(snap.sampled, 0);
        assert_eq!(snap.observed, 50);
        assert_eq!(snap.state, HealthState::Healthy);
    }

    #[test]
    fn replay_panic_counts_as_probe_failure_not_crash() {
        struct Bomb;
        impl CostSource for Bomb {
            fn layer_costs(&self, _cfg: &ConvConfig) -> Cow<'_, [Option<f64>]> {
                panic!("injected fault: boom");
            }
            fn dlt_cost(
                &self,
                _c: u32,
                _im: u32,
                _src: crate::primitives::Layout,
                _dst: crate::primitives::Layout,
            ) -> f64 {
                0.0
            }
        }
        let cache = CostCache::new_shared(Arc::new(Flat::new(1.0)) as Arc<dyn CostSource>);
        let mon = PlatformMonitor::new("p", Arc::new(Bomb), tight_policy());
        let net = networks::alexnet();
        mon.observe(&net, &cache, &no_recal);
        let snap = mon.snapshot();
        assert_eq!(snap.probe_failures, 1);
        assert_eq!(snap.window, 0);
        assert_eq!(snap.state, HealthState::Healthy);
    }

    #[test]
    fn policy_validation_rejects_nonsense() {
        assert!(HealthPolicy::default().validate().is_ok());
        assert!(HealthPolicy::default().with_sampling(1.5, 0).validate().is_err());
        assert!(HealthPolicy::default().with_drift_band(0.0).validate().is_err());
        assert!(HealthPolicy::default().with_auto_recalibrate(true, 0.0).validate().is_err());
        let p = HealthPolicy::default().with_quarantine(0, Duration::ZERO, Duration::ZERO);
        assert!(p.validate().is_err());
        assert!(HealthPolicy::default().with_window(4, 12).validate().is_err());
    }

    #[test]
    fn quarantined_error_is_downcastable_through_anyhow() {
        let typed = QuarantinedError {
            platform: "p".to_string(),
            consecutive_failures: 3,
            retry_in: Duration::from_secs(5),
        };
        let e: anyhow::Error = typed.clone().into();
        assert_eq!(e.downcast_ref::<QuarantinedError>(), Some(&typed));
        assert!(e.to_string().contains("quarantined"));
    }
}

//! Rolling time-series over the metrics registry.
//!
//! A [`Sampler`] snapshots a [`Registry`] on a fixed cadence into
//! fixed-capacity per-series ring buffers: counters become **rates**
//! (delta per second between consecutive samples), gauges become
//! **levels**, and histograms contribute two series each (**p50** and
//! **p95** milliseconds, digested allocation-free via
//! [`LatencyHistogram::snapshot_inline`](crate::service::LatencyHistogram::snapshot_inline)).
//! Timestamps come through an injected [`Clock`], so tests drive a
//! [`ManualClock`](super::clock::ManualClock) and replay bit-identical
//! series; production uses the monotonic
//! [`SystemClock`](super::clock::SystemClock) owned by the service's
//! `primsel-sampler` thread.
//!
//! The steady-state sample path does not allocate: per-series state is
//! keyed on stable registry entry indices and rings are pre-sized, so
//! the heap is touched only when a *new* series appears. This is pinned
//! (with the sampler thread live) by `rust/tests/alloc_counter.rs`.
//!
//! [`OpsReport`] bundles the drained series with SLO alert states and
//! flight-recorder counts into a `ServiceStats`-style rendering with
//! ASCII sparklines — what `serve_zoo --dashboard` prints.

use super::clock::Clock;
use super::registry::{CellValue, Registry};
use super::slo::Alert;
use crate::config::Json;
use crate::report::Table;
use crate::sync;
use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Duration;

/// How the sampler runs: ring capacity per series and the cadence the
/// owning thread ticks at (the sampler itself is cadence-agnostic —
/// every [`Sampler::sample`] call is one tick).
#[derive(Debug, Clone)]
pub struct SamplerConfig {
    /// Points retained per series; older points are overwritten.
    pub capacity: usize,
    /// Intended wall cadence between ticks (used by the service's
    /// sampler thread; tests tick by hand).
    pub cadence: Duration,
}

impl Default for SamplerConfig {
    fn default() -> Self {
        Self { capacity: 240, cadence: Duration::from_secs(1) }
    }
}

impl SamplerConfig {
    /// Default capacity at the given cadence.
    pub fn every(cadence: Duration) -> Self {
        Self { cadence, ..Self::default() }
    }

    /// Override the per-series ring capacity.
    pub fn with_capacity(mut self, capacity: usize) -> Self {
        self.capacity = capacity.max(1);
        self
    }
}

/// One sampled point: nanoseconds on the sampler's clock, value in the
/// series' unit (rate per second, gauge level, or milliseconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SeriesPoint {
    pub t_ns: u64,
    pub value: f64,
}

/// Fixed-capacity overwrite ring of [`SeriesPoint`]s.
#[derive(Debug)]
struct Ring {
    points: Box<[SeriesPoint]>,
    /// Points ever pushed; the ring holds the last `capacity` of them.
    pushed: u64,
}

impl Ring {
    fn new(capacity: usize) -> Self {
        Self {
            points: vec![SeriesPoint { t_ns: 0, value: 0.0 }; capacity].into_boxed_slice(),
            pushed: 0,
        }
    }

    fn push(&mut self, p: SeriesPoint) {
        let i = (self.pushed % self.points.len() as u64) as usize;
        self.points[i] = p;
        self.pushed += 1;
    }

    fn len(&self) -> usize {
        (self.pushed as usize).min(self.points.len())
    }

    /// Oldest→newest copy (allocates; reporting path only).
    fn drain_ordered(&self) -> Vec<SeriesPoint> {
        let n = self.len();
        let cap = self.points.len() as u64;
        let start = self.pushed.saturating_sub(n as u64);
        (0..n)
            .map(|k| self.points[((start + k as u64) % cap) as usize])
            .collect()
    }
}

/// How raw registry values map onto series points.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SeriesKind {
    /// Counter delta per second between consecutive ticks.
    Rate,
    /// Gauge level as-is.
    Level,
    /// Histogram p50 in milliseconds.
    P50,
    /// Histogram p95 in milliseconds.
    P95,
}

impl SeriesKind {
    fn name(self) -> &'static str {
        match self {
            SeriesKind::Rate => "rate",
            SeriesKind::Level => "level",
            SeriesKind::P50 => "p50_ms",
            SeriesKind::P95 => "p95_ms",
        }
    }
}

#[derive(Debug)]
struct SeriesState {
    name: String,
    labels: Vec<(String, String)>,
    kind: SeriesKind,
    /// Last raw counter value (rate series only).
    prev_raw: f64,
    prev_t_ns: u64,
    /// Whether `prev_*` holds a real prior sample.
    primed: bool,
    ring: Ring,
}

impl SeriesState {
    fn observe(&mut self, t_ns: u64, raw: f64) {
        match self.kind {
            SeriesKind::Rate => {
                if self.primed && t_ns > self.prev_t_ns && raw >= self.prev_raw {
                    let dt_sec = (t_ns - self.prev_t_ns) as f64 / 1e9;
                    self.ring.push(SeriesPoint { t_ns, value: (raw - self.prev_raw) / dt_sec });
                }
                // A counter that went backwards was reset (registry
                // `Counter::store` republishing): re-prime silently.
                self.prev_raw = raw;
                self.prev_t_ns = t_ns;
                self.primed = true;
            }
            _ => self.ring.push(SeriesPoint { t_ns, value: raw }),
        }
    }
}

#[derive(Debug, Default)]
struct SamplerState {
    /// Registry entry index → series indices (`[idx, usize::MAX]` for
    /// counters/gauges, `[p50_idx, p95_idx]` for histograms). Registry
    /// entries are append-only so this vector only ever grows.
    by_entry: Vec<[usize; 2]>,
    series: Vec<SeriesState>,
    ticks: u64,
}

const NONE: usize = usize::MAX;

/// The sampler proper: call [`Sampler::sample`] once per tick.
#[derive(Debug)]
pub struct Sampler {
    cfg: SamplerConfig,
    state: Mutex<SamplerState>,
}

impl Default for Sampler {
    fn default() -> Self {
        Self::new(SamplerConfig::default())
    }
}

impl Sampler {
    /// A sampler with the given ring capacity / cadence.
    pub fn new(cfg: SamplerConfig) -> Self {
        Self { cfg, state: Mutex::new(SamplerState::default()) }
    }

    /// The configured cadence (the owning thread's tick interval).
    pub fn cadence(&self) -> Duration {
        self.cfg.cadence
    }

    /// Take one sample of every series in `reg` at `clock`'s current
    /// time. Allocation-free once every live series has been seen;
    /// allocates only to grow state for newly registered series.
    pub fn sample(&self, reg: &Registry, clock: &dyn Clock) {
        let mut guard = sync::lock(&self.state);
        let st = &mut *guard;
        let t_ns = clock.now_ns();
        let capacity = self.cfg.capacity;
        reg.visit(|i, name, labels, value| {
            while st.by_entry.len() <= i {
                st.by_entry.push([NONE, NONE]);
            }
            if st.by_entry[i][0] == NONE {
                let kinds: &[SeriesKind] = match value {
                    CellValue::Counter(_) => &[SeriesKind::Rate],
                    CellValue::Gauge(_) => &[SeriesKind::Level],
                    CellValue::Summary(_) => &[SeriesKind::P50, SeriesKind::P95],
                };
                for (slot, &kind) in kinds.iter().enumerate() {
                    st.by_entry[i][slot] = st.series.len();
                    st.series.push(SeriesState {
                        name: name.to_string(),
                        labels: labels.to_vec(),
                        kind,
                        prev_raw: 0.0,
                        prev_t_ns: 0,
                        primed: false,
                        ring: Ring::new(capacity),
                    });
                }
            }
            match value {
                CellValue::Counter(c) => {
                    st.series[st.by_entry[i][0]].observe(t_ns, c as f64);
                }
                CellValue::Gauge(g) => {
                    st.series[st.by_entry[i][0]].observe(t_ns, g);
                }
                CellValue::Summary(s) => {
                    st.series[st.by_entry[i][0]].observe(t_ns, s.p50_ms);
                    st.series[st.by_entry[i][1]].observe(t_ns, s.p95_ms);
                }
            }
        });
        st.ticks += 1;
    }

    /// Ticks taken so far.
    pub fn ticks(&self) -> u64 {
        sync::lock(&self.state).ticks
    }

    /// Copy out every series, oldest point first, sorted by
    /// (name, labels, kind). Reporting path — allocates.
    pub fn snapshot(&self) -> Vec<SeriesSnapshot> {
        let st = sync::lock(&self.state);
        let mut out: Vec<SeriesSnapshot> = st
            .series
            .iter()
            .map(|s| SeriesSnapshot {
                name: s.name.clone(),
                labels: s.labels.clone(),
                kind: s.kind.name(),
                points: s.ring.drain_ordered(),
            })
            .collect();
        out.sort_by(|a, b| (&a.name, &a.labels, a.kind).cmp(&(&b.name, &b.labels, b.kind)));
        out
    }

    /// JSON form of [`Sampler::snapshot`]:
    /// `{"ticks": n, "series": [{name, labels, kind, points: [[t_ns, value], ...]}]}`.
    pub fn snapshot_json(&self) -> Json {
        let series = self.snapshot();
        let ticks = self.ticks();
        let mut arr = Vec::with_capacity(series.len());
        for s in series {
            let mut obj = BTreeMap::new();
            obj.insert("name".to_string(), Json::Str(s.name));
            let labels: BTreeMap<String, Json> = s
                .labels
                .into_iter()
                .map(|(k, v)| (k, Json::Str(v)))
                .collect();
            obj.insert("labels".to_string(), Json::Obj(labels));
            obj.insert("kind".to_string(), Json::Str(s.kind.to_string()));
            obj.insert(
                "points".to_string(),
                Json::Arr(
                    s.points
                        .iter()
                        .map(|p| Json::Arr(vec![Json::Num(p.t_ns as f64), Json::Num(p.value)]))
                        .collect(),
                ),
            );
            arr.push(Json::Obj(obj));
        }
        let mut root = BTreeMap::new();
        root.insert("ticks".to_string(), Json::Num(ticks as f64));
        root.insert("series".to_string(), Json::Arr(arr));
        Json::Obj(root)
    }
}

/// One drained series: dotted metric name, its labels, how raw values
/// were mapped ([`kind`](SeriesSnapshot::kind) is `"rate"`, `"level"`,
/// `"p50_ms"` or `"p95_ms"`), and the retained points oldest-first.
#[derive(Debug, Clone)]
pub struct SeriesSnapshot {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub kind: &'static str,
    pub points: Vec<SeriesPoint>,
}

impl SeriesSnapshot {
    /// Latest value, if any point was retained.
    pub fn last(&self) -> Option<f64> {
        self.points.last().map(|p| p.value)
    }

    /// ASCII sparkline over the last `width` points (min→max scaled to
    /// eight block glyphs; flat series render as a mid-level bar).
    pub fn sparkline(&self, width: usize) -> String {
        sparkline(
            self.points.iter().map(|p| p.value),
            self.points.len().saturating_sub(width),
        )
    }
}

const SPARK: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];

/// Render `values[skip..]` as a block-glyph sparkline.
fn sparkline(values: impl Iterator<Item = f64> + Clone, skip: usize) -> String {
    let vals: Vec<f64> = values.skip(skip).filter(|v| v.is_finite()).collect();
    if vals.is_empty() {
        return String::new();
    }
    let min = vals.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = vals.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let span = max - min;
    vals.iter()
        .map(|&v| {
            let level = if span <= f64::EPSILON {
                3
            } else {
                (((v - min) / span) * 7.0).round() as usize
            };
            SPARK[level.min(7)]
        })
        .collect()
}

/// Flight-recorder lifetime counts carried into an [`OpsReport`].
#[derive(Debug, Clone, Copy, Default)]
pub struct RecorderCounts {
    pub requests: u64,
    pub events: u64,
    pub slow: u64,
    pub requests_dropped: u64,
    pub events_dropped: u64,
}

/// Point-in-time ops-plane digest: drained series with sparklines, SLO
/// alert states, and flight-recorder coverage. Built by
/// [`Service::ops_report`](crate::service::Service::ops_report);
/// rendered by `serve_zoo --dashboard` and the `metrics --series`
/// subcommand.
#[derive(Debug, Clone)]
pub struct OpsReport {
    /// Sampler-clock time the report was assembled at.
    pub at_ns: u64,
    /// Sampler ticks taken so far.
    pub ticks: u64,
    pub series: Vec<SeriesSnapshot>,
    pub alerts: Vec<Alert>,
    pub recorder: RecorderCounts,
}

impl OpsReport {
    /// ASCII tables in the `ServiceStats::render` style: one row per
    /// series (last value + sparkline trend), one per SLO alert, and a
    /// recorder coverage line.
    pub fn render(&self) -> String {
        let mut t = Table::new(
            &format!(
                "ops report — tick {} at {:.1}s",
                self.ticks,
                self.at_ns as f64 / 1e9
            ),
            &["series", "labels", "kind", "points", "last", "trend"],
        );
        for s in &self.series {
            if s.points.is_empty() {
                continue;
            }
            let labels = s
                .labels
                .iter()
                .map(|(k, v)| format!("{k}={v}"))
                .collect::<Vec<_>>()
                .join(",");
            t.row(vec![
                s.name.clone(),
                labels,
                s.kind.to_string(),
                s.points.len().to_string(),
                format!("{:.3}", s.last().unwrap_or(0.0)),
                s.sparkline(24),
            ]);
        }
        let mut out = t.render();
        if !self.alerts.is_empty() {
            let mut at = Table::new(
                "slo alerts",
                &["slo", "state", "burn fast", "burn slow", "value", "target"],
            );
            for a in &self.alerts {
                at.row(vec![
                    a.slo.clone(),
                    a.state.name().to_string(),
                    format!("{:.2}", a.burn_fast),
                    format!("{:.2}", a.burn_slow),
                    format!("{:.3}", a.value),
                    format!("{:.3}", a.target),
                ]);
            }
            out.push('\n');
            out.push_str(&at.render());
        }
        out.push_str(&format!(
            "\nrecorder: {} requests ({} dropped), {} slow, {} events ({} dropped)\n",
            self.recorder.requests,
            self.recorder.requests_dropped,
            self.recorder.slow,
            self.recorder.events,
            self.recorder.events_dropped,
        ));
        out
    }

    /// JSON form (series as in [`Sampler::snapshot_json`], plus alert
    /// states and recorder counts).
    pub fn to_json(&self) -> Json {
        let mut series = Vec::with_capacity(self.series.len());
        for s in &self.series {
            let mut obj = BTreeMap::new();
            obj.insert("name".to_string(), Json::Str(s.name.clone()));
            let labels: BTreeMap<String, Json> = s
                .labels
                .iter()
                .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                .collect();
            obj.insert("labels".to_string(), Json::Obj(labels));
            obj.insert("kind".to_string(), Json::Str(s.kind.to_string()));
            obj.insert(
                "points".to_string(),
                Json::Arr(
                    s.points
                        .iter()
                        .map(|p| Json::Arr(vec![Json::Num(p.t_ns as f64), Json::Num(p.value)]))
                        .collect(),
                ),
            );
            series.push(Json::Obj(obj));
        }
        let alerts = self
            .alerts
            .iter()
            .map(|a| {
                let mut obj = BTreeMap::new();
                obj.insert("slo".to_string(), Json::Str(a.slo.clone()));
                obj.insert("state".to_string(), Json::Str(a.state.name().to_string()));
                obj.insert("burn_fast".to_string(), Json::Num(a.burn_fast));
                obj.insert("burn_slow".to_string(), Json::Num(a.burn_slow));
                obj.insert("value".to_string(), Json::Num(a.value));
                obj.insert("target".to_string(), Json::Num(a.target));
                Json::Obj(obj)
            })
            .collect();
        let mut rec = BTreeMap::new();
        rec.insert("requests".to_string(), Json::Num(self.recorder.requests as f64));
        rec.insert("events".to_string(), Json::Num(self.recorder.events as f64));
        rec.insert("slow".to_string(), Json::Num(self.recorder.slow as f64));
        rec.insert(
            "requests_dropped".to_string(),
            Json::Num(self.recorder.requests_dropped as f64),
        );
        rec.insert(
            "events_dropped".to_string(),
            Json::Num(self.recorder.events_dropped as f64),
        );
        let mut root = BTreeMap::new();
        root.insert("at_ns".to_string(), Json::Num(self.at_ns as f64));
        root.insert("ticks".to_string(), Json::Num(self.ticks as f64));
        root.insert("series".to_string(), Json::Arr(series));
        root.insert("alerts".to_string(), Json::Arr(alerts));
        root.insert("recorder".to_string(), Json::Obj(rec));
        Json::Obj(root)
    }
}

#[cfg(test)]
mod tests {
    use super::super::clock::ManualClock;
    use super::*;

    fn find<'a>(snaps: &'a [SeriesSnapshot], name: &str, kind: &str) -> &'a SeriesSnapshot {
        snaps
            .iter()
            .find(|s| s.name == name && s.kind == kind)
            .unwrap_or_else(|| panic!("missing series {name} kind {kind}"))
    }

    #[test]
    fn counters_sample_as_rates_gauges_as_levels() {
        let reg = Registry::new();
        let c = reg.counter("primsel.s.count", &[]);
        let g = reg.gauge("primsel.s.gauge", &[]);
        let clock = ManualClock::new(0);
        let sampler = Sampler::new(SamplerConfig::default());

        c.add(10);
        g.set(3.0);
        sampler.sample(&reg, &clock); // primes the counter; gauge point lands
        clock.advance(2_000_000_000); // 2 s
        c.add(40);
        g.set(5.0);
        sampler.sample(&reg, &clock);

        let snaps = sampler.snapshot();
        let rate = find(&snaps, "primsel.s.count", "rate");
        assert_eq!(rate.points.len(), 1, "first counter sample only primes");
        assert!((rate.points[0].value - 20.0).abs() < 1e-9, "40 over 2s = 20/s");
        let level = find(&snaps, "primsel.s.gauge", "level");
        assert_eq!(level.points.len(), 2);
        assert_eq!(level.points[1].value, 5.0);
        assert_eq!(sampler.ticks(), 2);
    }

    #[test]
    fn histograms_sample_as_p50_and_p95_series() {
        let reg = Registry::new();
        let h = reg.histogram("primsel.s.lat", &[("stage", "solve")]);
        for _ in 0..20 {
            h.record(Duration::from_millis(2));
        }
        let clock = ManualClock::new(0);
        let sampler = Sampler::default();
        sampler.sample(&reg, &clock);

        let snaps = sampler.snapshot();
        let p50 = find(&snaps, "primsel.s.lat", "p50_ms");
        let p95 = find(&snaps, "primsel.s.lat", "p95_ms");
        assert_eq!(p50.labels, vec![("stage".to_string(), "solve".to_string())]);
        assert!(p50.points[0].value > 1.0 && p50.points[0].value < 4.0);
        assert!(p95.points[0].value >= p50.points[0].value);
    }

    #[test]
    fn rings_overwrite_oldest_points() {
        let reg = Registry::new();
        let g = reg.gauge("primsel.s.g", &[]);
        let clock = ManualClock::new(0);
        let sampler = Sampler::new(SamplerConfig::default().with_capacity(4));
        for i in 0..10 {
            g.set(i as f64);
            sampler.sample(&reg, &clock);
            clock.advance(1_000_000_000);
        }
        let snaps = sampler.snapshot();
        let s = find(&snaps, "primsel.s.g", "level");
        assert_eq!(s.points.len(), 4);
        let vals: Vec<f64> = s.points.iter().map(|p| p.value).collect();
        assert_eq!(vals, vec![6.0, 7.0, 8.0, 9.0], "oldest-first, last 4 kept");
    }

    #[test]
    fn counter_resets_reprime_without_negative_rates() {
        let reg = Registry::new();
        let c = reg.counter("primsel.s.reset", &[]);
        let clock = ManualClock::new(0);
        let sampler = Sampler::default();
        c.add(100);
        sampler.sample(&reg, &clock);
        clock.advance(1_000_000_000);
        c.store(10); // went backwards: treated as a reset
        sampler.sample(&reg, &clock);
        clock.advance(1_000_000_000);
        c.store(30);
        sampler.sample(&reg, &clock);

        let snaps = sampler.snapshot();
        let s = find(&snaps, "primsel.s.reset", "rate");
        assert_eq!(s.points.len(), 1, "reset tick emits no point");
        assert!((s.points[0].value - 20.0).abs() < 1e-9);
    }

    #[test]
    fn manual_clock_sampling_is_deterministic() {
        let run = || {
            let reg = Registry::new();
            let c = reg.counter("primsel.s.det", &[]);
            let clock = ManualClock::new(0);
            let sampler = Sampler::default();
            for i in 0..16u64 {
                c.add(i * 3 + 1);
                sampler.sample(&reg, &clock);
                clock.advance(500_000_000);
            }
            sampler.snapshot_json().dump()
        };
        assert_eq!(run(), run(), "same tick sequence must replay bit-identically");
    }

    #[test]
    fn sparklines_scale_min_to_max() {
        let s = SeriesSnapshot {
            name: "x".into(),
            labels: vec![],
            kind: "level",
            points: (0..8)
                .map(|i| SeriesPoint { t_ns: i, value: i as f64 })
                .collect(),
        };
        let line = s.sparkline(8);
        assert_eq!(line.chars().count(), 8);
        assert_eq!(line.chars().next().unwrap(), '▁');
        assert_eq!(line.chars().last().unwrap(), '█');
        // flat series: mid-level bar, not a panic
        let flat = SeriesSnapshot {
            name: "y".into(),
            labels: vec![],
            kind: "level",
            points: vec![SeriesPoint { t_ns: 0, value: 2.0 }; 3],
        };
        assert_eq!(flat.sparkline(8), "▄▄▄");
    }

    #[test]
    fn ops_report_renders_series_alerts_and_recorder_counts() {
        let report = OpsReport {
            at_ns: 2_500_000_000,
            ticks: 5,
            series: vec![SeriesSnapshot {
                name: "primsel.queue.depth".into(),
                labels: vec![],
                kind: "level",
                points: vec![
                    SeriesPoint { t_ns: 0, value: 1.0 },
                    SeriesPoint { t_ns: 1, value: 3.0 },
                ],
            }],
            alerts: vec![],
            recorder: RecorderCounts {
                requests: 12,
                events: 3,
                slow: 1,
                requests_dropped: 0,
                events_dropped: 0,
            },
        };
        let text = report.render();
        assert!(text.contains("ops report — tick 5"));
        assert!(text.contains("primsel.queue.depth"));
        assert!(text.contains("12 requests (0 dropped)"));
        let json = report.to_json().dump();
        let parsed = Json::parse(&json).expect("ops report JSON must parse");
        assert_eq!(
            parsed.get("recorder").unwrap().get("requests").unwrap().as_f64().unwrap(),
            12.0
        );
    }
}

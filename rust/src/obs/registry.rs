//! Process-wide metrics registry: counters, gauges, and latency
//! histograms under stable dotted names with static label sets.
//!
//! Handles ([`Counter`], [`Gauge`], [`Histogram`]) are cheap `Arc`
//! clones; registration (`counter`/`gauge`/`histogram`) is get-or-create
//! and may allocate, so hot paths cache their handles once and then
//! update lock-free. Two exporters: Prometheus text exposition
//! ([`Registry::render_prometheus`]) and a JSON snapshot
//! ([`Registry::snapshot_json`] on the hand-rolled [`config::Json`]).

use crate::config::Json;
use crate::service::{HistogramSnapshot, LatencyHistogram};
use crate::sync;
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, RwLock};
use std::time::Duration;

/// Monotonic counter handle. Cloning shares the underlying cell.
#[derive(Debug, Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    /// Add 1.
    pub fn inc(&self) {
        self.0.fetch_add(1, Relaxed);
    }

    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Relaxed);
    }

    /// Overwrite with an absolute value — scrape-time publishing of a
    /// monotonic count maintained elsewhere (tenant counters, cache
    /// hit/miss totals).
    pub fn store(&self, v: u64) {
        self.0.store(v, Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> u64 {
        self.0.load(Relaxed)
    }
}

/// Point-in-time gauge handle storing an `f64` as bits.
#[derive(Debug, Clone)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    /// Set the gauge.
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Relaxed);
    }

    /// Current value.
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Relaxed))
    }
}

/// Latency histogram handle (shared [`LatencyHistogram`]). Recording
/// and snapshotting are both lock-free and allocation-free.
#[derive(Debug, Clone)]
pub struct Histogram(Arc<LatencyHistogram>);

impl Histogram {
    /// Record one latency sample.
    pub fn record(&self, d: Duration) {
        self.0.record(d);
    }

    /// Record a sample given in nanoseconds.
    pub fn record_ns(&self, ns: u64) {
        self.0.record(Duration::from_nanos(ns));
    }

    /// Quantile snapshot (count, mean, p50, p95, max).
    pub fn snapshot(&self) -> HistogramSnapshot {
        self.0.snapshot()
    }
}

#[derive(Clone)]
enum Cell {
    Counter(Arc<AtomicU64>),
    Gauge(Arc<AtomicU64>),
    Histogram(Arc<LatencyHistogram>),
}

impl Cell {
    /// Prometheus type keyword for this cell.
    fn kind(&self) -> &'static str {
        match self {
            Cell::Counter(_) => "counter",
            Cell::Gauge(_) => "gauge",
            Cell::Histogram(_) => "summary",
        }
    }
}

/// One series' current value as seen by [`Registry::visit`]: counters
/// and gauges as plain numbers, histograms pre-digested into a
/// [`HistogramSnapshot`] (taken allocation-free via
/// [`LatencyHistogram::snapshot_inline`]).
#[derive(Debug, Clone, Copy)]
pub enum CellValue {
    /// Monotonic counter value.
    Counter(u64),
    /// Gauge level.
    Gauge(f64),
    /// Histogram quantile digest.
    Summary(HistogramSnapshot),
}

struct Entry {
    name: String,
    labels: Vec<(String, String)>,
    cell: Cell,
}

#[derive(Default)]
struct Inner {
    entries: Vec<Entry>,
    /// `name + labels` composite key → index into `entries`.
    index: HashMap<String, usize>,
    /// Per-name type pin: one dotted name is one metric type.
    kinds: HashMap<String, &'static str>,
}

/// The registry proper. One process-wide instance lives behind
/// [`crate::obs::registry`]; standalone instances serve unit tests.
#[derive(Default)]
pub struct Registry {
    inner: RwLock<Inner>,
}

impl Registry {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    fn key(name: &str, labels: &[(&str, &str)]) -> String {
        let mut k = String::from(name);
        for (lk, lv) in labels {
            k.push('\u{1}');
            k.push_str(lk);
            k.push('\u{2}');
            k.push_str(lv);
        }
        k
    }

    fn get_or_insert(
        &self,
        name: &str,
        labels: &[(&str, &str)],
        make: impl FnOnce() -> Cell,
    ) -> Cell {
        let key = Self::key(name, labels);
        {
            let inner = sync::read(&self.inner);
            if let Some(&i) = inner.index.get(&key) {
                return inner.entries[i].cell.clone();
            }
        }
        let mut inner = sync::write(&self.inner);
        if let Some(&i) = inner.index.get(&key) {
            return inner.entries[i].cell.clone();
        }
        let cell = make();
        let prior = inner.kinds.entry(name.to_string()).or_insert_with(|| cell.kind());
        assert_eq!(
            *prior,
            cell.kind(),
            "metric {name:?} already registered as a {prior}"
        );
        let idx = inner.entries.len();
        inner.entries.push(Entry {
            name: name.to_string(),
            labels: labels
                .iter()
                .map(|(k, v)| (k.to_string(), v.to_string()))
                .collect(),
            cell: cell.clone(),
        });
        inner.index.insert(key, idx);
        cell
    }

    /// Get or register a counter under `name` + `labels`.
    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> Counter {
        match self.get_or_insert(name, labels, || Cell::Counter(Arc::new(AtomicU64::new(0)))) {
            Cell::Counter(c) => Counter(c),
            other => panic!("metric {name:?} already registered as a {}", other.kind()),
        }
    }

    /// Get or register a gauge under `name` + `labels`.
    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Gauge {
        match self.get_or_insert(name, labels, || Cell::Gauge(Arc::new(AtomicU64::new(0)))) {
            Cell::Gauge(g) => Gauge(g),
            other => panic!("metric {name:?} already registered as a {}", other.kind()),
        }
    }

    /// Get or register a latency histogram under `name` + `labels`.
    pub fn histogram(&self, name: &str, labels: &[(&str, &str)]) -> Histogram {
        match self.get_or_insert(name, labels, || {
            Cell::Histogram(Arc::new(LatencyHistogram::new()))
        }) {
            Cell::Histogram(h) => Histogram(h),
            other => panic!("metric {name:?} already registered as a {}", other.kind()),
        }
    }

    /// Visit every registered series in registration order without
    /// allocating: `f(entry_index, name, labels, value)`. Entry indices
    /// are stable (the entry table is append-only), so callers — the
    /// series [`Sampler`](crate::obs::series::Sampler) — can key
    /// per-series state on them and stay allocation-free once every
    /// live series has been seen. `f` runs under the registry read
    /// lock: it must not register metrics.
    pub fn visit(&self, mut f: impl FnMut(usize, &str, &[(String, String)], CellValue)) {
        let inner = sync::read(&self.inner);
        for (i, e) in inner.entries.iter().enumerate() {
            let v = match &e.cell {
                Cell::Counter(c) => CellValue::Counter(c.load(Relaxed)),
                Cell::Gauge(g) => CellValue::Gauge(f64::from_bits(g.load(Relaxed))),
                Cell::Histogram(h) => CellValue::Summary(h.snapshot_inline()),
            };
            f(i, &e.name, &e.labels, v);
        }
    }

    /// Number of registered (name, labels) series.
    pub fn len(&self) -> usize {
        sync::read(&self.inner).entries.len()
    }

    /// Whether no series are registered yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Prometheus text exposition. Dotted names are sanitised to
    /// underscore form (`primsel.queue.depth` → `primsel_queue_depth`);
    /// histograms export as summaries (`quantile="0.5"|"0.95"|"1"` plus
    /// `_sum` / `_count`, millisecond values).
    pub fn render_prometheus(&self) -> String {
        let inner = sync::read(&self.inner);
        let mut by_name: BTreeMap<&str, Vec<&Entry>> = BTreeMap::new();
        for e in &inner.entries {
            by_name.entry(&e.name).or_default().push(e);
        }
        let mut out = String::new();
        for (name, mut entries) in by_name {
            entries.sort_by(|a, b| a.labels.cmp(&b.labels));
            let prom = sanitize_name(name);
            let kind = entries[0].cell.kind();
            out.push_str(&format!(
                "# HELP {prom} {}\n# TYPE {prom} {kind}\n",
                escape_help_text(name)
            ));
            for e in entries {
                match &e.cell {
                    Cell::Counter(c) => {
                        let lbl = label_block(&e.labels, None);
                        out.push_str(&format!("{prom}{lbl} {}\n", c.load(Relaxed)));
                    }
                    Cell::Gauge(g) => {
                        let lbl = label_block(&e.labels, None);
                        out.push_str(&format!(
                            "{prom}{lbl} {}\n",
                            fmt_f64(f64::from_bits(g.load(Relaxed)))
                        ));
                    }
                    Cell::Histogram(h) => {
                        let s = h.snapshot();
                        for (q, v) in [("0.5", s.p50_ms), ("0.95", s.p95_ms), ("1", s.max_ms)] {
                            let lbl = label_block(&e.labels, Some(("quantile", q)));
                            out.push_str(&format!("{prom}{lbl} {}\n", fmt_f64(v)));
                        }
                        let lbl = label_block(&e.labels, None);
                        out.push_str(&format!(
                            "{prom}_sum{lbl} {}\n",
                            fmt_f64(s.mean_ms * s.count as f64)
                        ));
                        out.push_str(&format!("{prom}_count{lbl} {}\n", s.count));
                    }
                }
            }
        }
        out
    }

    /// JSON snapshot: `{"counters": [...], "gauges": [...],
    /// "histograms": [...]}`, each entry carrying its dotted `name`,
    /// `labels` object, and value(s). Deterministic ordering.
    pub fn snapshot_json(&self) -> Json {
        let inner = sync::read(&self.inner);
        let mut entries: Vec<&Entry> = inner.entries.iter().collect();
        entries.sort_by(|a, b| (&a.name, &a.labels).cmp(&(&b.name, &b.labels)));

        let mut counters = Vec::new();
        let mut gauges = Vec::new();
        let mut histograms = Vec::new();
        for e in entries {
            let mut obj = BTreeMap::new();
            obj.insert("name".to_string(), Json::Str(e.name.clone()));
            let labels: BTreeMap<String, Json> = e
                .labels
                .iter()
                .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                .collect();
            obj.insert("labels".to_string(), Json::Obj(labels));
            match &e.cell {
                Cell::Counter(c) => {
                    obj.insert("value".to_string(), Json::Num(c.load(Relaxed) as f64));
                    counters.push(Json::Obj(obj));
                }
                Cell::Gauge(g) => {
                    obj.insert(
                        "value".to_string(),
                        Json::Num(f64::from_bits(g.load(Relaxed))),
                    );
                    gauges.push(Json::Obj(obj));
                }
                Cell::Histogram(h) => {
                    let s = h.snapshot();
                    obj.insert("count".to_string(), Json::Num(s.count as f64));
                    obj.insert("mean_ms".to_string(), Json::Num(s.mean_ms));
                    obj.insert("p50_ms".to_string(), Json::Num(s.p50_ms));
                    obj.insert("p95_ms".to_string(), Json::Num(s.p95_ms));
                    obj.insert("max_ms".to_string(), Json::Num(s.max_ms));
                    obj.insert(
                        "sum_ms".to_string(),
                        Json::Num(s.mean_ms * s.count as f64),
                    );
                    histograms.push(Json::Obj(obj));
                }
            }
        }
        let mut root = BTreeMap::new();
        root.insert("counters".to_string(), Json::Arr(counters));
        root.insert("gauges".to_string(), Json::Arr(gauges));
        root.insert("histograms".to_string(), Json::Arr(histograms));
        Json::Obj(root)
    }
}

/// Prometheus metric names allow `[a-zA-Z_:][a-zA-Z0-9_:]*`; map dots
/// (and anything else) to underscores.
fn sanitize_name(name: &str) -> String {
    let mut out: String = name
        .chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || c == '_' || c == ':' {
                c
            } else {
                '_'
            }
        })
        .collect();
    if out.chars().next().is_some_and(|c| c.is_ascii_digit()) {
        out.insert(0, '_');
    }
    out
}

fn sanitize_label(name: &str) -> String {
    let mut out: String = name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' { c } else { '_' })
        .collect();
    if out.chars().next().is_some_and(|c| c.is_ascii_digit()) {
        out.insert(0, '_');
    }
    out
}

fn escape_label_value(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// HELP text escaping per the exposition format: only backslash and
/// newline (double-quotes are legal in HELP text, unlike label values).
fn escape_help_text(v: &str) -> String {
    v.replace('\\', "\\\\").replace('\n', "\\n")
}

fn label_block(labels: &[(String, String)], extra: Option<(&str, &str)>) -> String {
    if labels.is_empty() && extra.is_none() {
        return String::new();
    }
    let mut parts: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{}=\"{}\"", sanitize_label(k), escape_label_value(v)))
        .collect();
    if let Some((k, v)) = extra {
        parts.push(format!("{k}=\"{v}\""));
    }
    format!("{{{}}}", parts.join(","))
}

/// Prometheus floats: plain `Display` except NaN/∞ spelled the way the
/// exposition format expects.
fn fmt_f64(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v.is_infinite() {
        (if v > 0.0 { "+Inf" } else { "-Inf" }).to_string()
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_register_once_and_share_state() {
        let reg = Registry::new();
        let a = reg.counter("primsel.test.count", &[("tenant", "t0")]);
        let b = reg.counter("primsel.test.count", &[("tenant", "t0")]);
        a.inc();
        b.add(2);
        assert_eq!(a.get(), 3);
        assert_eq!(reg.len(), 1);

        let g = reg.gauge("primsel.test.gauge", &[]);
        g.set(1.5);
        assert_eq!(reg.gauge("primsel.test.gauge", &[]).get(), 1.5);
        assert_eq!(reg.len(), 2);

        // distinct label values are distinct series
        reg.counter("primsel.test.count", &[("tenant", "t1")]).inc();
        assert_eq!(a.get(), 3);
        assert_eq!(reg.len(), 3);
    }

    #[test]
    #[should_panic(expected = "already registered")]
    fn one_name_cannot_change_type() {
        let reg = Registry::new();
        reg.counter("primsel.test.mixed", &[]);
        reg.gauge("primsel.test.mixed", &[]);
    }

    #[test]
    fn prometheus_rendering_sanitises_names_and_types_each_family_once() {
        let reg = Registry::new();
        reg.counter("primsel.req.total", &[("tenant", "a")]).add(4);
        reg.counter("primsel.req.total", &[("tenant", "b")]).add(6);
        reg.gauge("primsel.queue.depth", &[]).set(2.0);
        let h = reg.histogram("primsel.stage_ms", &[("stage", "solve")]);
        h.record(Duration::from_millis(2));
        h.record(Duration::from_millis(4));

        let text = reg.render_prometheus();
        assert!(text.contains("# TYPE primsel_req_total counter"));
        assert_eq!(text.matches("# TYPE primsel_req_total").count(), 1);
        assert!(text.contains("primsel_req_total{tenant=\"a\"} 4"));
        assert!(text.contains("primsel_req_total{tenant=\"b\"} 6"));
        assert!(text.contains("# TYPE primsel_queue_depth gauge"));
        assert!(text.contains("primsel_queue_depth 2"));
        assert!(text.contains("# TYPE primsel_stage_ms summary"));
        assert!(text.contains("primsel_stage_ms{stage=\"solve\",quantile=\"0.5\"}"));
        assert!(text.contains("primsel_stage_ms_count{stage=\"solve\"} 2"));
        assert!(!text.contains("primsel.req.total{"), "dotted names must not leak");
    }

    #[test]
    fn label_values_are_escaped() {
        let reg = Registry::new();
        reg.counter("primsel.esc", &[("p", "a\"b\\c\nd")]).inc();
        let text = reg.render_prometheus();
        assert!(text.contains("p=\"a\\\"b\\\\c\\nd\""));
    }

    #[test]
    fn help_text_escapes_backslash_and_newline() {
        assert_eq!(escape_help_text("plain.name"), "plain.name");
        assert_eq!(escape_help_text("a\\b\nc\"d"), "a\\\\b\\nc\"d");
    }

    #[test]
    fn visit_reports_every_series_with_stable_indices() {
        let reg = Registry::new();
        let c = reg.counter("primsel.visit.count", &[("tenant", "t0")]);
        c.add(5);
        reg.gauge("primsel.visit.gauge", &[]).set(2.5);
        let h = reg.histogram("primsel.visit.hist", &[]);
        h.record(Duration::from_millis(4));

        let mut seen = Vec::new();
        reg.visit(|i, name, labels, v| {
            seen.push((i, name.to_string(), labels.to_vec(), v));
        });
        assert_eq!(seen.len(), 3);
        assert_eq!(seen[0].0, 0);
        assert_eq!(seen[0].1, "primsel.visit.count");
        assert_eq!(seen[0].2, vec![("tenant".to_string(), "t0".to_string())]);
        assert!(matches!(seen[0].3, CellValue::Counter(5)));
        assert!(matches!(seen[1].3, CellValue::Gauge(g) if g == 2.5));
        match seen[2].3 {
            CellValue::Summary(s) => {
                assert_eq!(s.count, 1);
                assert!(s.p50_ms > 0.0);
            }
            _ => panic!("histogram must visit as a summary"),
        }

        // registering more series appends; earlier indices are stable
        reg.counter("primsel.visit.count", &[("tenant", "t1")]).inc();
        let mut names = Vec::new();
        reg.visit(|i, name, _, _| names.push((i, name.to_string())));
        assert_eq!(names[0], (0, "primsel.visit.count".to_string()));
        assert_eq!(names[3], (3, "primsel.visit.count".to_string()));
    }

    #[test]
    fn json_snapshot_round_trips_through_the_parser() {
        let reg = Registry::new();
        reg.counter("primsel.c", &[("tenant", "x")]).add(7);
        reg.gauge("primsel.g", &[]).set(0.25);
        reg.histogram("primsel.h", &[]).record(Duration::from_millis(3));

        let snap = reg.snapshot_json();
        let parsed = Json::parse(&snap.dump()).expect("snapshot must be valid JSON");
        let counters = parsed.get("counters").unwrap().as_arr().unwrap();
        assert_eq!(counters.len(), 1);
        assert_eq!(counters[0].get("name").unwrap().as_str().unwrap(), "primsel.c");
        assert_eq!(counters[0].get("value").unwrap().as_f64().unwrap(), 7.0);
        assert_eq!(
            counters[0].get("labels").unwrap().get("tenant").unwrap().as_str().unwrap(),
            "x"
        );
        let hists = parsed.get("histograms").unwrap().as_arr().unwrap();
        assert_eq!(hists[0].get("count").unwrap().as_f64().unwrap(), 1.0);
        assert!(hists[0].get("p50_ms").unwrap().as_f64().unwrap() > 0.0);
    }
}

//! Request tracing: heap-free per-stage timestamps carried on a
//! [`SelectionRequest`](crate::coordinator::SelectionRequest).
//!
//! A [`Trace`] is a fixed-size array of atomic nanosecond offsets from a
//! single origin instant — one slot per pipeline [`Stage`]. Marking a
//! stage is one `Instant::elapsed` plus one relaxed store, so the
//! instrumented warm select path stays zero-allocation (pinned by
//! `rust/tests/alloc_counter.rs`). The atomics give interior mutability
//! through the shared `&SelectionRequest` that `Coordinator::select_one`
//! and `submit_batch` hand across threads.

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::time::{Duration, Instant};

/// Number of pipeline stages a trace can record (one mark slot each).
pub const N_STAGES: usize = 7;

/// Pipeline stages a request passes through, in nominal order. Each
/// stage owns one slot in the fixed [`Trace`] mark array.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Accepted into the admission queue (`Service::admit`).
    Admit = 0,
    /// Popped off the DRR queue by a service worker.
    Dispatch = 1,
    /// `Coordinator::select_one` entered.
    SolveStart = 2,
    /// Compiled plan / cached front resolved for the request.
    PlanReady = 3,
    /// PBQP solve or front lookup produced a selection.
    Solved = 4,
    /// `Coordinator::select_one` returning.
    SolveEnd = 5,
    /// Report handed back to the caller.
    Done = 6,
}

impl Stage {
    /// All stages in pipeline order.
    pub const ALL: [Stage; N_STAGES] = [
        Stage::Admit,
        Stage::Dispatch,
        Stage::SolveStart,
        Stage::PlanReady,
        Stage::Solved,
        Stage::SolveEnd,
        Stage::Done,
    ];

    /// Stable lowercase name (used in recorder tables and docs).
    pub fn name(self) -> &'static str {
        match self {
            Stage::Admit => "admit",
            Stage::Dispatch => "dispatch",
            Stage::SolveStart => "solve_start",
            Stage::PlanReady => "plan_ready",
            Stage::Solved => "solved",
            Stage::SolveEnd => "solve_end",
            Stage::Done => "done",
        }
    }
}

/// Per-request span recorder: a fixed inline array of atomic marks.
///
/// Marks are stored as `nanosecond offset + 1` so that `0` doubles as
/// "unset" — the whole trace is plain words, no heap, no locks.
#[derive(Debug)]
pub struct Trace {
    origin: Instant,
    marks: [AtomicU64; N_STAGES],
}

impl Clone for Trace {
    fn clone(&self) -> Self {
        Self {
            origin: self.origin,
            marks: std::array::from_fn(|i| AtomicU64::new(self.marks[i].load(Relaxed))),
        }
    }
}

impl Trace {
    /// Start a trace with its origin at "now" and every stage unset.
    pub fn begin() -> Self {
        Self {
            origin: Instant::now(),
            marks: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Record `stage` at "now". Safe to call through a shared reference
    /// from any thread; later marks overwrite earlier ones.
    pub fn mark(&self, stage: Stage) {
        let ns = self.origin.elapsed().as_nanos().min((u64::MAX - 1) as u128) as u64;
        self.marks[stage as usize].store(ns + 1, Relaxed);
    }

    /// Deterministic mark for tests and benchmarks: record `stage` at an
    /// explicit nanosecond offset from the origin.
    pub fn mark_at_ns(&self, stage: Stage, ns: u64) {
        self.marks[stage as usize].store(ns.saturating_add(1), Relaxed);
    }

    /// The instant marks are measured from. The flight recorder uses it
    /// to place this trace on its own epoch-relative wall axis.
    pub(crate) fn origin(&self) -> Instant {
        self.origin
    }

    /// Nanosecond offset of `stage` from the origin, if marked.
    pub fn stage_ns(&self, stage: Stage) -> Option<u64> {
        match self.marks[stage as usize].load(Relaxed) {
            0 => None,
            v => Some(v - 1),
        }
    }

    /// Whether `stage` has been marked.
    pub fn has(&self, stage: Stage) -> bool {
        self.marks[stage as usize].load(Relaxed) != 0
    }

    /// Saturating span between two marked stages (`to - from`).
    pub fn span_ns(&self, from: Stage, to: Stage) -> Option<u64> {
        Some(self.stage_ns(to)?.saturating_sub(self.stage_ns(from)?))
    }

    /// [`Trace::span_ns`] as a `Duration`.
    pub fn span(&self, from: Stage, to: Stage) -> Option<Duration> {
        self.span_ns(from, to).map(Duration::from_nanos)
    }

    /// Wall span covered by the trace: earliest mark to latest mark
    /// (0 when fewer than one stage is marked).
    pub fn total_ns(&self) -> u64 {
        let mut lo = u64::MAX;
        let mut hi = 0u64;
        for m in &self.marks {
            let v = m.load(Relaxed);
            if v != 0 {
                lo = lo.min(v);
                hi = hi.max(v);
            }
        }
        if hi == 0 {
            0
        } else {
            hi - lo
        }
    }

    /// Raw mark words (`ns offset + 1`, `0` = unset) in stage order —
    /// the fixed-width encoding the flight recorder stores.
    pub fn mark_words(&self) -> [u64; N_STAGES] {
        std::array::from_fn(|i| self.marks[i].load(Relaxed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn marks_start_unset_and_record_in_order() {
        let t = Trace::begin();
        for s in Stage::ALL {
            assert_eq!(t.stage_ns(s), None);
            assert!(!t.has(s));
        }
        assert_eq!(t.total_ns(), 0);

        for s in Stage::ALL {
            t.mark(s);
        }
        let mut prev = 0u64;
        for s in Stage::ALL {
            let ns = t.stage_ns(s).expect("marked");
            assert!(ns >= prev, "{} went backwards", s.name());
            prev = ns;
        }
    }

    #[test]
    fn deterministic_marks_and_spans() {
        let t = Trace::begin();
        t.mark_at_ns(Stage::Admit, 100);
        t.mark_at_ns(Stage::Dispatch, 400);
        t.mark_at_ns(Stage::Done, 1_100);
        assert_eq!(t.span_ns(Stage::Admit, Stage::Dispatch), Some(300));
        assert_eq!(t.span_ns(Stage::Admit, Stage::Done), Some(1_000));
        assert_eq!(t.span_ns(Stage::Admit, Stage::SolveStart), None);
        assert_eq!(t.total_ns(), 1_000);
        assert_eq!(t.span(Stage::Admit, Stage::Done), Some(Duration::from_nanos(1_000)));
        // saturating: out-of-order marks clamp to zero, never panic
        assert_eq!(t.span_ns(Stage::Done, Stage::Admit), Some(0));
    }

    #[test]
    fn clone_detaches_the_mark_array() {
        let t = Trace::begin();
        t.mark_at_ns(Stage::Admit, 5);
        let c = t.clone();
        t.mark_at_ns(Stage::Done, 50);
        assert_eq!(c.stage_ns(Stage::Admit), Some(5));
        assert_eq!(c.stage_ns(Stage::Done), None);
        assert_eq!(t.stage_ns(Stage::Done), Some(50));
    }

    #[test]
    fn mark_words_round_trip_unset_encoding() {
        let t = Trace::begin();
        t.mark_at_ns(Stage::SolveStart, 7);
        let w = t.mark_words();
        assert_eq!(w[Stage::SolveStart as usize], 8);
        assert_eq!(w[Stage::Admit as usize], 0);
    }
}

//! Flight recorder: a lock-free fixed-capacity ring of completed
//! request traces plus structured health events, with always-keep-slowest
//! retention for postmortems.
//!
//! Records are encoded into a fixed `[u64; 18]` word block (kind, index,
//! total span, the seven trace marks, three 16-byte inline tags, one
//! value word, one wall-offset word) and written into per-slot
//! seqlocks: the writer CAS-claims
//! a slot (even → odd sequence), stores the words relaxed, and releases
//! (odd → even); readers retry on a torn sequence. Recording therefore
//! never allocates and never blocks, which keeps the instrumented warm
//! select path inside the zero-allocation pin. The slow ring is the one
//! exception: keep-slowest eviction needs a find-min, so it sits behind
//! a `Mutex` — but its `Vec` is pre-reserved at construction and every
//! insert is a push-within-capacity or an in-place replace, so even the
//! slow path stays allocation-free.

use crate::config::Json;
use crate::obs::trace::{Stage, Trace, N_STAGES};
use crate::report::Table;
use crate::sync;
use std::collections::BTreeMap;
use std::sync::atomic::Ordering::{Acquire, Relaxed, Release};
use std::sync::atomic::{fence, AtomicU64};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Fixed per-record word count (see the word layout constants below).
const WORDS: usize = 18;
const W_KIND: usize = 0;
const W_INDEX: usize = 1;
const W_TOTAL: usize = 2;
const W_MARKS: usize = 3; // .. W_MARKS + N_STAGES
const W_TAG_A: usize = 10; // platform / SLO name
const W_TAG_B: usize = 12; // network / previous state / outcome
const W_TAG_C: usize = 14; // tenant / new state
const W_VALUE: usize = 16; // f64 bits (drift score / burn rate)
const W_WALL: usize = 17; // ns since the recorder's epoch

/// What a [`FlightRecord`] describes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecordKind {
    /// A completed selection request (trace marks populated).
    Request = 0,
    /// A platform health-state transition.
    Transition = 1,
    /// A recalibration outcome (ok / failed).
    Recalibration = 2,
    /// An SLO alert state transition (ops plane).
    Alert = 3,
}

impl RecordKind {
    /// Stable lowercase name for tables and JSON.
    pub fn name(self) -> &'static str {
        match self {
            RecordKind::Request => "request",
            RecordKind::Transition => "transition",
            RecordKind::Recalibration => "recalibration",
            RecordKind::Alert => "alert",
        }
    }

    fn from_word(w: u64) -> RecordKind {
        match w {
            0 => RecordKind::Request,
            1 => RecordKind::Transition,
            2 => RecordKind::Recalibration,
            _ => RecordKind::Alert,
        }
    }
}

/// A decoded recorder entry. Field meaning depends on [`RecordKind`]:
/// for requests, `network`/`tenant` are the request's network name and
/// tenant lane; for transitions they hold the previous and new health
/// state names; for recalibrations `network` holds `"ok"` / `"failed"`;
/// for alerts `platform` is the SLO name and `network`/`tenant` the
/// previous/new alert states.
#[derive(Debug, Clone)]
pub struct FlightRecord {
    pub kind: RecordKind,
    /// Monotonic per-ring sequence number (drain watermarks key on it).
    pub index: u64,
    /// Wall span covered by the trace marks, nanoseconds (requests).
    pub total_ns: u64,
    /// Per-stage nanosecond offsets (requests; `None` = stage unset).
    pub marks: [Option<u64>; N_STAGES],
    pub platform: String,
    pub network: String,
    pub tenant: String,
    /// Drift score (transitions / recalibrations) or burn rate (alerts).
    pub value: f64,
    /// Nanoseconds between the recorder's construction and this record's
    /// origin (a request's trace start; an event's recording moment).
    /// Lets the timeline exporter place records on one shared axis.
    pub wall_ns: u64,
}

impl FlightRecord {
    /// Nanosecond offset of `stage`, if marked.
    pub fn stage_ns(&self, stage: Stage) -> Option<u64> {
        self.marks[stage as usize]
    }

    /// Millisecond span between two marked stages (saturating).
    pub fn span_ms(&self, from: Stage, to: Stage) -> Option<f64> {
        let (a, b) = (self.stage_ns(from)?, self.stage_ns(to)?);
        Some(b.saturating_sub(a) as f64 / 1e6)
    }

    fn decode(words: [u64; WORDS]) -> FlightRecord {
        let mut marks = [None; N_STAGES];
        for (i, m) in marks.iter_mut().enumerate() {
            let w = words[W_MARKS + i];
            *m = if w == 0 { None } else { Some(w - 1) };
        }
        FlightRecord {
            kind: RecordKind::from_word(words[W_KIND]),
            index: words[W_INDEX],
            total_ns: words[W_TOTAL],
            marks,
            platform: tag_str(words[W_TAG_A], words[W_TAG_A + 1]),
            network: tag_str(words[W_TAG_B], words[W_TAG_B + 1]),
            tenant: tag_str(words[W_TAG_C], words[W_TAG_C + 1]),
            value: f64::from_bits(words[W_VALUE]),
            wall_ns: words[W_WALL],
        }
    }

    fn json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("kind".to_string(), Json::Str(self.kind.name().to_string()));
        obj.insert("index".to_string(), Json::Num(self.index as f64));
        obj.insert("total_ms".to_string(), Json::Num(self.total_ns as f64 / 1e6));
        obj.insert("platform".to_string(), Json::Str(self.platform.clone()));
        obj.insert("network".to_string(), Json::Str(self.network.clone()));
        obj.insert("tenant".to_string(), Json::Str(self.tenant.clone()));
        obj.insert("value".to_string(), Json::Num(self.value));
        obj.insert("wall_ms".to_string(), Json::Num(self.wall_ns as f64 / 1e6));
        let mut marks = BTreeMap::new();
        for s in Stage::ALL {
            if let Some(ns) = self.stage_ns(s) {
                marks.insert(s.name().to_string(), Json::Num(ns as f64 / 1e6));
            }
        }
        obj.insert("marks_ms".to_string(), Json::Obj(marks));
        Json::Obj(obj)
    }
}

/// Inline 16-byte tag: truncate at a char boundary, little-endian pack.
fn tag_words(s: &str) -> [u64; 2] {
    let mut buf = [0u8; 16];
    let mut n = s.len().min(16);
    while !s.is_char_boundary(n) {
        n -= 1;
    }
    buf[..n].copy_from_slice(&s.as_bytes()[..n]);
    [
        u64::from_le_bytes(buf[..8].try_into().unwrap()),
        u64::from_le_bytes(buf[8..].try_into().unwrap()),
    ]
}

fn tag_str(w0: u64, w1: u64) -> String {
    let mut buf = [0u8; 16];
    buf[..8].copy_from_slice(&w0.to_le_bytes());
    buf[8..].copy_from_slice(&w1.to_le_bytes());
    let n = buf.iter().position(|&b| b == 0).unwrap_or(16);
    String::from_utf8_lossy(&buf[..n]).into_owned()
}

/// One seqlock-protected record slot.
struct Slot {
    seq: AtomicU64,
    words: [AtomicU64; WORDS],
}

impl Slot {
    fn empty() -> Slot {
        Slot {
            seq: AtomicU64::new(0),
            words: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    fn write(&self, words: &[u64; WORDS]) {
        loop {
            let s = self.seq.load(Acquire);
            if s & 1 == 0
                && self
                    .seq
                    .compare_exchange_weak(s, s + 1, Acquire, Relaxed)
                    .is_ok()
            {
                for (w, v) in self.words.iter().zip(words.iter()) {
                    w.store(*v, Relaxed);
                }
                self.seq.store(s + 2, Release);
                return;
            }
            std::hint::spin_loop();
        }
    }

    fn read(&self) -> Option<[u64; WORDS]> {
        for _ in 0..8 {
            let s1 = self.seq.load(Acquire);
            if s1 == 0 {
                return None; // never written
            }
            if s1 & 1 == 1 {
                std::hint::spin_loop();
                continue; // write in flight
            }
            let words: [u64; WORDS] = std::array::from_fn(|i| self.words[i].load(Relaxed));
            fence(Acquire);
            if self.seq.load(Relaxed) == s1 {
                return Some(words);
            }
        }
        None // persistently torn; skip this slot
    }
}

/// The recorder proper. One process-wide instance lives behind
/// [`crate::obs::flight_recorder`]; standalone instances serve tests.
pub struct FlightRecorder {
    /// Shared time origin for [`FlightRecord::wall_ns`].
    epoch: Instant,
    /// Most recent completed requests (seqlock ring, overwrites oldest).
    recent: Vec<Slot>,
    head: AtomicU64,
    /// Health transitions + recalibration outcomes (separate ring so
    /// request traffic cannot evict rare events).
    events: Vec<Slot>,
    events_head: AtomicU64,
    events_drained: AtomicU64,
    /// Keep-slowest capture of requests at or above the threshold.
    slow: Mutex<Vec<[u64; WORDS]>>,
    slow_cap: usize,
    slow_captured: AtomicU64,
    slow_threshold_ns: AtomicU64,
}

impl FlightRecorder {
    /// Recorder with explicit ring capacities (each ≥ 1).
    pub fn new(recent_cap: usize, slow_cap: usize, events_cap: usize) -> Self {
        assert!(recent_cap >= 1 && slow_cap >= 1 && events_cap >= 1);
        Self {
            epoch: Instant::now(),
            recent: (0..recent_cap).map(|_| Slot::empty()).collect(),
            head: AtomicU64::new(0),
            events: (0..events_cap).map(|_| Slot::empty()).collect(),
            events_head: AtomicU64::new(0),
            events_drained: AtomicU64::new(0),
            slow: Mutex::new(Vec::with_capacity(slow_cap)),
            slow_cap,
            slow_captured: AtomicU64::new(0),
            slow_threshold_ns: AtomicU64::new(10_000_000), // 10 ms
        }
    }

    /// Default shape for the process-wide recorder: 256 recent requests,
    /// 32 slowest, 256 health events, 10 ms slow threshold.
    pub fn with_defaults() -> Self {
        Self::new(256, 32, 256)
    }

    /// Requests recorded over the recorder's lifetime.
    pub fn requests_recorded(&self) -> u64 {
        self.head.load(Relaxed)
    }

    /// Health events recorded over the recorder's lifetime.
    pub fn events_recorded(&self) -> u64 {
        self.events_head.load(Relaxed)
    }

    /// Requests that crossed the slow threshold (including ones later
    /// evicted by slower arrivals).
    pub fn slow_captured(&self) -> u64 {
        self.slow_captured.load(Relaxed)
    }

    /// Requests overwritten out of the recent ring over the recorder's
    /// lifetime — how much the ring has forgotten, so "covered
    /// everything" is never silently false.
    pub fn requests_dropped(&self) -> u64 {
        self.head.load(Relaxed).saturating_sub(self.recent.len() as u64)
    }

    /// Events overwritten out of the event ring over the recorder's
    /// lifetime.
    pub fn events_dropped(&self) -> u64 {
        self.events_head.load(Relaxed).saturating_sub(self.events.len() as u64)
    }

    /// Set the slow-capture threshold.
    pub fn set_slow_threshold(&self, d: Duration) {
        let ns = d.as_nanos().min(u64::MAX as u128) as u64;
        self.slow_threshold_ns.store(ns, Relaxed);
    }

    /// Current slow-capture threshold.
    pub fn slow_threshold(&self) -> Duration {
        Duration::from_nanos(self.slow_threshold_ns.load(Relaxed))
    }

    fn push(ring: &[Slot], head: &AtomicU64, words: &mut [u64; WORDS]) -> u64 {
        let idx = head.fetch_add(1, Relaxed);
        words[W_INDEX] = idx;
        ring[(idx % ring.len() as u64) as usize].write(words);
        idx
    }

    /// Record a completed request trace. Lock-free and allocation-free;
    /// requests whose total span meets the slow threshold are also
    /// retained in the keep-slowest ring.
    pub fn record_request(&self, trace: &Trace, platform: &str, network: &str, tenant: &str) {
        let mut words = [0u64; WORDS];
        words[W_KIND] = RecordKind::Request as u64;
        let total = trace.total_ns();
        words[W_TOTAL] = total;
        let marks = trace.mark_words();
        words[W_MARKS..W_MARKS + N_STAGES].copy_from_slice(&marks);
        let [a0, a1] = tag_words(platform);
        words[W_TAG_A] = a0;
        words[W_TAG_A + 1] = a1;
        let [b0, b1] = tag_words(network);
        words[W_TAG_B] = b0;
        words[W_TAG_B + 1] = b1;
        let [c0, c1] = tag_words(tenant);
        words[W_TAG_C] = c0;
        words[W_TAG_C + 1] = c1;
        // traces begun before the recorder existed saturate to wall 0
        words[W_WALL] = trace
            .origin()
            .saturating_duration_since(self.epoch)
            .as_nanos()
            .min(u64::MAX as u128) as u64;
        Self::push(&self.recent, &self.head, &mut words);
        if total >= self.slow_threshold_ns.load(Relaxed) {
            self.keep_slow(words);
        }
    }

    /// Nanoseconds since the recorder's construction (the `wall_ns`
    /// written on events recorded right now).
    fn wall_now(&self) -> u64 {
        self.epoch.elapsed().as_nanos().min(u64::MAX as u128) as u64
    }

    /// Record a platform health-state transition as a structured event.
    pub fn record_transition(
        &self,
        platform: &str,
        from: &'static str,
        to: &'static str,
        drift: f64,
    ) {
        let mut words = [0u64; WORDS];
        words[W_KIND] = RecordKind::Transition as u64;
        let [a0, a1] = tag_words(platform);
        words[W_TAG_A] = a0;
        words[W_TAG_A + 1] = a1;
        let [b0, b1] = tag_words(from);
        words[W_TAG_B] = b0;
        words[W_TAG_B + 1] = b1;
        let [c0, c1] = tag_words(to);
        words[W_TAG_C] = c0;
        words[W_TAG_C + 1] = c1;
        words[W_VALUE] = drift.to_bits();
        words[W_WALL] = self.wall_now();
        Self::push(&self.events, &self.events_head, &mut words);
    }

    /// Record a recalibration outcome as a structured event.
    pub fn record_recalibration(&self, platform: &str, ok: bool, drift: f64) {
        let mut words = [0u64; WORDS];
        words[W_KIND] = RecordKind::Recalibration as u64;
        let [a0, a1] = tag_words(platform);
        words[W_TAG_A] = a0;
        words[W_TAG_A + 1] = a1;
        let [b0, b1] = tag_words(if ok { "ok" } else { "failed" });
        words[W_TAG_B] = b0;
        words[W_TAG_B + 1] = b1;
        words[W_VALUE] = drift.to_bits();
        words[W_WALL] = self.wall_now();
        Self::push(&self.events, &self.events_head, &mut words);
    }

    /// Record an SLO alert state transition as a structured event:
    /// `slo` rides the platform tag, `from`/`to` are alert state names,
    /// `burn` is the fast-window burn rate at the transition.
    pub fn record_alert(&self, slo: &str, from: &'static str, to: &'static str, burn: f64) {
        let mut words = [0u64; WORDS];
        words[W_KIND] = RecordKind::Alert as u64;
        let [a0, a1] = tag_words(slo);
        words[W_TAG_A] = a0;
        words[W_TAG_A + 1] = a1;
        let [b0, b1] = tag_words(from);
        words[W_TAG_B] = b0;
        words[W_TAG_B + 1] = b1;
        let [c0, c1] = tag_words(to);
        words[W_TAG_C] = c0;
        words[W_TAG_C + 1] = c1;
        words[W_VALUE] = burn.to_bits();
        words[W_WALL] = self.wall_now();
        Self::push(&self.events, &self.events_head, &mut words);
    }

    fn keep_slow(&self, words: [u64; WORDS]) {
        self.slow_captured.fetch_add(1, Relaxed);
        let mut slow = sync::lock(&self.slow);
        if slow.len() < self.slow_cap {
            slow.push(words); // within pre-reserved capacity: no alloc
            return;
        }
        let (mut min_i, mut min_t) = (0usize, u64::MAX);
        for (i, w) in slow.iter().enumerate() {
            if w[W_TOTAL] < min_t {
                min_t = w[W_TOTAL];
                min_i = i;
            }
        }
        if words[W_TOTAL] > min_t {
            slow[min_i] = words;
        }
    }

    /// Decode the recent-request ring, oldest first. Allocates; slots
    /// torn by concurrent writers are skipped.
    pub fn snapshot(&self) -> Vec<FlightRecord> {
        let mut out: Vec<FlightRecord> = self
            .recent
            .iter()
            .filter_map(Slot::read)
            .map(FlightRecord::decode)
            .collect();
        out.sort_by_key(|r| r.index);
        out
    }

    /// The retained slowest requests, slowest first.
    pub fn slow_snapshot(&self) -> Vec<FlightRecord> {
        let slow = sync::lock(&self.slow);
        let mut out: Vec<FlightRecord> = slow.iter().copied().map(FlightRecord::decode).collect();
        out.sort_by(|a, b| b.total_ns.cmp(&a.total_ns).then(a.index.cmp(&b.index)));
        out
    }

    /// Decode the health-event ring, oldest first.
    pub fn events_snapshot(&self) -> Vec<FlightRecord> {
        let mut out: Vec<FlightRecord> = self
            .events
            .iter()
            .filter_map(Slot::read)
            .map(FlightRecord::decode)
            .collect();
        out.sort_by_key(|r| r.index);
        out
    }

    /// Health events recorded since the previous drain (watermark moves
    /// forward; events evicted from the ring before a drain are lost).
    pub fn drain_events(&self) -> Vec<FlightRecord> {
        let mark = self
            .events_drained
            .swap(self.events_head.load(Relaxed), Relaxed);
        self.events_snapshot()
            .into_iter()
            .filter(|r| r.index >= mark)
            .collect()
    }

    /// Rendered tables: slowest retained requests + health events.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let mut t = Table::new(
            "flight recorder — slowest requests",
            &["#", "platform", "network", "tenant", "total ms", "queue ms", "solve ms"],
        );
        for r in self.slow_snapshot() {
            t.row(vec![
                r.index.to_string(),
                r.platform.clone(),
                r.network.clone(),
                r.tenant.clone(),
                format!("{:.3}", r.total_ns as f64 / 1e6),
                r.span_ms(Stage::Admit, Stage::Dispatch)
                    .map(|v| format!("{v:.3}"))
                    .unwrap_or_else(|| "-".to_string()),
                r.span_ms(Stage::SolveStart, Stage::SolveEnd)
                    .map(|v| format!("{v:.3}"))
                    .unwrap_or_else(|| "-".to_string()),
            ]);
        }
        out.push_str(&t.render());
        let events = self.events_snapshot();
        if !events.is_empty() {
            let mut t = Table::new(
                "flight recorder — health + alert events",
                &["#", "kind", "platform/slo", "from/outcome", "to", "value"],
            );
            for r in events {
                t.row(vec![
                    r.index.to_string(),
                    r.kind.name().to_string(),
                    r.platform.clone(),
                    r.network.clone(),
                    r.tenant.clone(),
                    format!("{:.3}", r.value),
                ]);
            }
            out.push('\n');
            out.push_str(&t.render());
        }
        out.push_str(&format!(
            "\nlifetime: {} requests ({} dropped from ring), {} slow captured, {} events ({} dropped)\n",
            self.requests_recorded(),
            self.requests_dropped(),
            self.slow_captured(),
            self.events_recorded(),
            self.events_dropped(),
        ));
        out
    }

    /// JSON dump of all three rings plus lifetime counters.
    pub fn snapshot_json(&self) -> Json {
        let mut root = BTreeMap::new();
        root.insert(
            "recent".to_string(),
            Json::Arr(self.snapshot().iter().map(FlightRecord::json).collect()),
        );
        root.insert(
            "slow".to_string(),
            Json::Arr(self.slow_snapshot().iter().map(FlightRecord::json).collect()),
        );
        root.insert(
            "events".to_string(),
            Json::Arr(self.events_snapshot().iter().map(FlightRecord::json).collect()),
        );
        let mut counts = BTreeMap::new();
        counts.insert(
            "requests".to_string(),
            Json::Num(self.requests_recorded() as f64),
        );
        counts.insert(
            "events".to_string(),
            Json::Num(self.events_recorded() as f64),
        );
        counts.insert(
            "slow".to_string(),
            Json::Num(self.slow_captured() as f64),
        );
        counts.insert(
            "requests_dropped".to_string(),
            Json::Num(self.requests_dropped() as f64),
        );
        counts.insert(
            "events_dropped".to_string(),
            Json::Num(self.events_dropped() as f64),
        );
        root.insert("counts".to_string(), Json::Obj(counts));
        Json::Obj(root)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tags_round_trip_and_truncate_at_char_boundaries() {
        for s in ["", "intel", "a-platform-name!", "exactly-16-bytes"] {
            let [w0, w1] = tag_words(s);
            assert_eq!(tag_str(w0, w1), s);
        }
        // 17-byte string truncates to 16
        let [w0, w1] = tag_words("seventeen-bytes-x");
        assert_eq!(tag_str(w0, w1), "seventeen-bytes-");
        // multibyte char straddling the cut is dropped whole
        let s = "αβγδεζηrole"; // 2-byte greek letters
        let [w0, w1] = tag_words(s);
        let got = tag_str(w0, w1);
        assert!(s.starts_with(&got));
        assert!(got.len() <= 16);
    }

    #[test]
    fn request_records_round_trip_through_the_ring() {
        let rec = FlightRecorder::new(4, 2, 4);
        let t = Trace::begin();
        t.mark_at_ns(Stage::Admit, 1_000);
        t.mark_at_ns(Stage::Done, 2_000_000);
        rec.record_request(&t, "intel", "vgg16", "interactive");
        let snap = rec.snapshot();
        assert_eq!(snap.len(), 1);
        let r = &snap[0];
        assert_eq!(r.kind, RecordKind::Request);
        assert_eq!(r.platform, "intel");
        assert_eq!(r.network, "vgg16");
        assert_eq!(r.tenant, "interactive");
        assert_eq!(r.stage_ns(Stage::Admit), Some(1_000));
        assert_eq!(r.stage_ns(Stage::Dispatch), None);
        assert_eq!(r.total_ns, 1_999_000);
    }

    #[test]
    fn recent_ring_overwrites_oldest_but_slow_ring_keeps_slowest() {
        let rec = FlightRecorder::new(4, 3, 4);
        rec.set_slow_threshold(Duration::ZERO);
        let totals_ms = [10u64, 50, 20, 90, 30, 70, 40, 60];
        for &ms in &totals_ms {
            let t = Trace::begin();
            t.mark_at_ns(Stage::Admit, 0);
            t.mark_at_ns(Stage::Done, ms * 1_000_000);
            rec.record_request(&t, "p", "n", "t");
        }
        // recent ring holds the last 4 records
        let recent = rec.snapshot();
        assert_eq!(recent.len(), 4);
        let kept: Vec<u64> = recent.iter().map(|r| r.total_ns / 1_000_000).collect();
        assert_eq!(kept, vec![30, 70, 40, 60]);
        // slow ring holds the 3 slowest ever seen
        let slow: Vec<u64> = rec
            .slow_snapshot()
            .iter()
            .map(|r| r.total_ns / 1_000_000)
            .collect();
        assert_eq!(slow, vec![90, 70, 60]);
        assert_eq!(rec.slow_captured(), totals_ms.len() as u64);
        assert_eq!(rec.requests_recorded(), totals_ms.len() as u64);
    }

    #[test]
    fn slow_threshold_filters_fast_requests() {
        let rec = FlightRecorder::new(4, 4, 4);
        rec.set_slow_threshold(Duration::from_millis(5));
        for ms in [1u64, 9] {
            let t = Trace::begin();
            t.mark_at_ns(Stage::Admit, 0);
            t.mark_at_ns(Stage::Done, ms * 1_000_000);
            rec.record_request(&t, "p", "n", "t");
        }
        assert_eq!(rec.slow_captured(), 1);
        assert_eq!(rec.slow_snapshot().len(), 1);
        assert_eq!(rec.requests_recorded(), 2);
    }

    #[test]
    fn events_record_and_drain_with_a_watermark() {
        let rec = FlightRecorder::new(2, 2, 8);
        rec.record_transition("arm-live", "healthy", "drifting", 1.25);
        rec.record_recalibration("arm-live", false, 2.5);
        let first = rec.drain_events();
        assert_eq!(first.len(), 2);
        assert_eq!(first[0].kind, RecordKind::Transition);
        assert_eq!(first[0].network, "healthy");
        assert_eq!(first[0].tenant, "drifting");
        assert!((first[0].value - 1.25).abs() < 1e-12);
        assert_eq!(first[1].kind, RecordKind::Recalibration);
        assert_eq!(first[1].network, "failed");
        assert!(rec.drain_events().is_empty());
        rec.record_transition("arm-live", "drifting", "quarantined", 9.0);
        let second = rec.drain_events();
        assert_eq!(second.len(), 1);
        assert_eq!(second[0].tenant, "quarantined");
        // full snapshot still shows everything
        assert_eq!(rec.events_snapshot().len(), 3);
        let rendered = rec.render();
        assert!(rendered.contains("health + alert events"));
        assert!(rendered.contains("quarantined"));
    }

    #[test]
    fn drop_accounting_counts_ring_overwrites() {
        let rec = FlightRecorder::new(4, 2, 2);
        assert_eq!(rec.requests_dropped(), 0);
        assert_eq!(rec.events_dropped(), 0);
        for _ in 0..7 {
            let t = Trace::begin();
            t.mark_at_ns(Stage::Admit, 0);
            t.mark_at_ns(Stage::Done, 1_000);
            rec.record_request(&t, "p", "n", "t");
        }
        assert_eq!(rec.requests_recorded(), 7);
        assert_eq!(rec.requests_dropped(), 3, "ring of 4 forgot 3 of 7");
        for i in 0..5 {
            rec.record_transition("p", "healthy", "drifting", i as f64);
        }
        assert_eq!(rec.events_dropped(), 3, "ring of 2 forgot 3 of 5");
        let rendered = rec.render();
        assert!(rendered.contains("7 requests (3 dropped from ring)"));
        assert!(rendered.contains("5 events (3 dropped)"));
        let parsed = Json::parse(&rec.snapshot_json().dump()).unwrap();
        let counts = parsed.get("counts").unwrap();
        assert_eq!(counts.get("requests_dropped").unwrap().as_f64().unwrap(), 3.0);
        assert_eq!(counts.get("events_dropped").unwrap().as_f64().unwrap(), 3.0);
    }

    #[test]
    fn alert_records_round_trip() {
        let rec = FlightRecorder::new(2, 2, 4);
        rec.record_alert("queue-depth", "ok", "critical", 2.75);
        let events = rec.events_snapshot();
        assert_eq!(events.len(), 1);
        let r = &events[0];
        assert_eq!(r.kind, RecordKind::Alert);
        assert_eq!(r.kind.name(), "alert");
        assert_eq!(r.platform, "queue-depth");
        assert_eq!(r.network, "ok");
        assert_eq!(r.tenant, "critical");
        assert!((r.value - 2.75).abs() < 1e-12);
        assert!(rec.render().contains("alert"));
    }

    #[test]
    fn wall_offsets_are_monotone_per_ring() {
        let rec = FlightRecorder::new(4, 2, 4);
        for i in 0..3 {
            rec.record_transition("p", "healthy", "drifting", i as f64);
        }
        let events = rec.events_snapshot();
        for pair in events.windows(2) {
            assert!(pair[1].wall_ns >= pair[0].wall_ns);
        }
        let t = Trace::begin();
        t.mark_at_ns(Stage::Admit, 0);
        t.mark_at_ns(Stage::Done, 1_000);
        rec.record_request(&t, "p", "n", "t");
        // the trace began before the recorder's epoch-relative clock
        // could go negative: offsets always decode, saturating at 0
        let r = &rec.snapshot()[0];
        assert!(r.wall_ns < u64::MAX);
    }

    #[test]
    fn recorder_json_parses() {
        let rec = FlightRecorder::new(2, 2, 2);
        rec.set_slow_threshold(Duration::ZERO);
        let t = Trace::begin();
        t.mark_at_ns(Stage::SolveStart, 0);
        t.mark_at_ns(Stage::SolveEnd, 500_000);
        rec.record_request(&t, "intel", "alexnet", "direct");
        rec.record_transition("intel", "healthy", "drifting", 0.9);
        let parsed = Json::parse(&rec.snapshot_json().dump()).expect("valid JSON");
        assert_eq!(parsed.get("recent").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(parsed.get("events").unwrap().as_arr().unwrap().len(), 1);
        assert_eq!(
            parsed.get("counts").unwrap().get("requests").unwrap().as_f64().unwrap(),
            1.0
        );
    }
}

//! Declarative SLOs with two-window burn-rate alerting.
//!
//! An [`SloSpec`] names a service-level indicator ([`Sli`]) and a
//! target; the [`SloEngine`] folds one SLI sample per tick into two
//! rolling windows (fast + slow) and compares the **burn rate** —
//! `mean(samples in window) / target` — in both against thresholds. An
//! alert fires only when *both* windows burn hot (the classic
//! multiwindow pattern: the fast window makes alerts responsive, the
//! slow window keeps one spike from paging), and clears with
//! hysteresis: a state is only left once burn drops below
//! `threshold × (1 - hysteresis)`, so boundary-riding values never
//! flap. Down-transitions step one level per evaluation — recovery
//! from [`AlertState::Critical`] always passes back through
//! [`AlertState::Warning`].
//!
//! The engine is a pure function of the `(t_ns, SloInputs)` sequence —
//! it never reads a wall clock — so under a
//! [`ManualClock`](super::clock::ManualClock) its transitions are
//! bit-deterministic (pinned by `rust/tests/slo.rs`). The service's
//! sampler thread feeds it, publishes alert states as registry gauges,
//! records every transition in the flight recorder, and on a Critical
//! drift/latency alert can nudge the health monitor into early shadow
//! sampling ([`SloSpec::with_nudge`]).

use std::collections::VecDeque;
use std::time::Duration;

/// Which signal an SLO watches. The service maps each variant onto its
/// own stats when building [`SloInputs`] every tick.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Sli {
    /// p95 latency (milliseconds) of a service stage: `"wait"`,
    /// `"service"`, or `"e2e"`.
    LatencyP95 { stage: String },
    /// Rejected / (admitted + rejected) over the service lifetime.
    ErrorRate,
    /// Queue depth as a fraction of capacity.
    QueueDepth,
    /// Drift score of one monitored platform.
    Drift { platform: String },
}

/// One declarative SLO: an indicator, a target, window lengths, and
/// burn thresholds. Build with the named constructors and chain the
/// `with_*` builders.
#[derive(Debug, Clone)]
pub struct SloSpec {
    /// Unique alert name (label value on the published gauges).
    pub name: String,
    pub sli: Sli,
    /// Target in the SLI's unit (ms, fraction, or drift score). Burn
    /// rate is `mean / target`, so burn 1.0 means "exactly at target".
    pub target: f64,
    /// Responsive window; must be shorter than `slow_window`.
    pub fast_window: Duration,
    /// Smoothing window; an alert needs this hot too.
    pub slow_window: Duration,
    /// Burn at or above this in both windows → at least Warning.
    pub warn_burn: f64,
    /// Burn at or above this in both windows → Critical.
    pub crit_burn: f64,
    /// Fractional clear margin: a threshold crossed at `b ≥ thr` only
    /// clears once `b < thr × (1 - hysteresis)`.
    pub hysteresis: f64,
    /// On entering Critical, ask the health monitor to shadow-sample
    /// the next `n` observations unconditionally (drift / latency SLOs
    /// only — closes the obs→health loop).
    pub nudge: Option<u64>,
}

impl SloSpec {
    fn new(name: &str, sli: Sli, target: f64) -> Self {
        Self {
            name: name.to_string(),
            sli,
            target,
            fast_window: Duration::from_secs(30),
            slow_window: Duration::from_secs(300),
            warn_burn: 1.0,
            crit_burn: 2.0,
            hysteresis: 0.1,
            nudge: None,
        }
    }

    /// SLO on a stage's p95 latency staying under `target_ms`.
    pub fn latency_p95(name: &str, stage: &str, target_ms: f64) -> Self {
        Self::new(name, Sli::LatencyP95 { stage: stage.to_string() }, target_ms)
    }

    /// SLO on the lifetime error (rejection) rate staying under
    /// `target` (a fraction).
    pub fn error_rate(name: &str, target: f64) -> Self {
        Self::new(name, Sli::ErrorRate, target)
    }

    /// SLO on queue occupancy staying under `target_frac` of capacity.
    pub fn queue_depth(name: &str, target_frac: f64) -> Self {
        Self::new(name, Sli::QueueDepth, target_frac)
    }

    /// SLO on one platform's drift score staying under `band`.
    pub fn drift(name: &str, platform: &str, band: f64) -> Self {
        Self::new(name, Sli::Drift { platform: platform.to_string() }, band)
    }

    /// Override the fast/slow burn windows.
    pub fn with_windows(mut self, fast: Duration, slow: Duration) -> Self {
        self.fast_window = fast;
        self.slow_window = slow;
        self
    }

    /// Override the Warning / Critical burn thresholds.
    pub fn with_burns(mut self, warn: f64, crit: f64) -> Self {
        self.warn_burn = warn;
        self.crit_burn = crit;
        self
    }

    /// Override the clear hysteresis fraction.
    pub fn with_hysteresis(mut self, h: f64) -> Self {
        self.hysteresis = h;
        self
    }

    /// Nudge the health monitor into `n` unconditional shadow samples
    /// when this SLO goes Critical.
    pub fn with_nudge(mut self, n: u64) -> Self {
        self.nudge = Some(n);
        self
    }

    /// Check the spec is internally consistent.
    pub fn validate(&self) -> Result<(), String> {
        if self.name.is_empty() {
            return Err("SLO name must be non-empty".into());
        }
        if self.target.is_nan() || self.target <= 0.0 {
            return Err(format!("SLO {:?}: target must be > 0", self.name));
        }
        if self.fast_window.is_zero() || self.slow_window < self.fast_window {
            return Err(format!(
                "SLO {:?}: need 0 < fast_window <= slow_window",
                self.name
            ));
        }
        if self.warn_burn.is_nan() || self.warn_burn <= 0.0 || self.crit_burn < self.warn_burn {
            return Err(format!(
                "SLO {:?}: need 0 < warn_burn <= crit_burn",
                self.name
            ));
        }
        if !(0.0..1.0).contains(&self.hysteresis) {
            return Err(format!(
                "SLO {:?}: hysteresis must be in [0, 1)",
                self.name
            ));
        }
        Ok(())
    }
}

/// Alert severity ladder.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum AlertState {
    Ok = 0,
    Warning = 1,
    Critical = 2,
}

impl AlertState {
    /// Lowercase name (flight-recorder tags, report rows).
    pub fn name(self) -> &'static str {
        match self {
            AlertState::Ok => "ok",
            AlertState::Warning => "warning",
            AlertState::Critical => "critical",
        }
    }

    /// Numeric code published on the state gauge (0 / 1 / 2).
    pub fn code(self) -> f64 {
        self as u8 as f64
    }
}

/// One SLO's current standing.
#[derive(Debug, Clone)]
pub struct Alert {
    pub slo: String,
    pub state: AlertState,
    pub burn_fast: f64,
    pub burn_slow: f64,
    /// Latest raw SLI sample.
    pub value: f64,
    pub target: f64,
}

/// A state change produced by one [`SloEngine::evaluate`] call.
#[derive(Debug, Clone)]
pub struct AlertTransition {
    pub slo: String,
    pub from: AlertState,
    pub to: AlertState,
    pub burn_fast: f64,
    pub burn_slow: f64,
    pub sli: Sli,
    /// Shadow-sample request carried from the spec when `to` is
    /// Critical.
    pub nudge: Option<u64>,
}

/// Snapshot of the signals the engine evaluates against, assembled by
/// the service from its own stats each tick.
#[derive(Debug, Clone, Default)]
pub struct SloInputs {
    /// (stage name, p95 ms) — typically wait / service / e2e.
    pub latency_p95_ms: Vec<(String, f64)>,
    pub error_rate: f64,
    /// Queue depth / capacity.
    pub queue_frac: f64,
    /// (platform, drift score) for each monitored platform.
    pub drift: Vec<(String, f64)>,
}

impl SloInputs {
    /// Resolve one SLI against this snapshot. `None` when the referenced
    /// stage/platform is absent this tick (the engine skips the sample).
    pub fn value(&self, sli: &Sli) -> Option<f64> {
        match sli {
            Sli::LatencyP95 { stage } => self
                .latency_p95_ms
                .iter()
                .find(|(s, _)| s == stage)
                .map(|&(_, v)| v),
            Sli::ErrorRate => Some(self.error_rate),
            Sli::QueueDepth => Some(self.queue_frac),
            Sli::Drift { platform } => {
                self.drift.iter().find(|(p, _)| p == platform).map(|&(_, v)| v)
            }
        }
    }
}

struct SloState {
    spec: SloSpec,
    /// (t_ns, value) samples inside the slow window, oldest first.
    samples: VecDeque<(u64, f64)>,
    state: AlertState,
    burn_fast: f64,
    burn_slow: f64,
    last_value: f64,
}

impl SloState {
    fn burn_over(&self, from_ns: u64) -> f64 {
        let mut sum = 0.0;
        let mut n = 0u64;
        for &(t, v) in self.samples.iter().rev() {
            if t < from_ns {
                break;
            }
            sum += v;
            n += 1;
        }
        if n == 0 {
            0.0
        } else {
            (sum / n as f64) / self.spec.target
        }
    }
}

/// The alert state machine over a set of [`SloSpec`]s. Feed it one
/// `(t_ns, SloInputs)` per tick; read back transitions (to log/nudge)
/// and [`SloEngine::alerts`] (to publish).
pub struct SloEngine {
    slos: Vec<SloState>,
}

impl SloEngine {
    /// Build an engine after validating every spec. Duplicate names are
    /// rejected — the name is the alert identity.
    pub fn new(specs: Vec<SloSpec>) -> Result<Self, String> {
        for (i, s) in specs.iter().enumerate() {
            s.validate()?;
            if specs[..i].iter().any(|p| p.name == s.name) {
                return Err(format!("duplicate SLO name {:?}", s.name));
            }
        }
        Ok(Self {
            slos: specs
                .into_iter()
                .map(|spec| SloState {
                    spec,
                    samples: VecDeque::new(),
                    state: AlertState::Ok,
                    burn_fast: 0.0,
                    burn_slow: 0.0,
                    last_value: 0.0,
                })
                .collect(),
        })
    }

    /// Whether any SLOs are configured.
    pub fn is_empty(&self) -> bool {
        self.slos.is_empty()
    }

    /// Fold one tick of inputs at time `t_ns` into every SLO and return
    /// the state transitions it caused (empty when nothing changed).
    /// Pure in `(t_ns, inputs)`: no clocks, no randomness.
    pub fn evaluate(&mut self, t_ns: u64, inputs: &SloInputs) -> Vec<AlertTransition> {
        let mut transitions = Vec::new();
        for slo in &mut self.slos {
            let Some(value) = inputs.value(&slo.spec.sli) else {
                continue;
            };
            slo.last_value = value;
            slo.samples.push_back((t_ns, value));
            let slow_ns = slo.spec.slow_window.as_nanos().min(u64::MAX as u128) as u64;
            let keep_from = t_ns.saturating_sub(slow_ns);
            while slo.samples.front().is_some_and(|&(t, _)| t < keep_from) {
                slo.samples.pop_front();
            }
            let fast_ns = slo.spec.fast_window.as_nanos().min(u64::MAX as u128) as u64;
            slo.burn_fast = slo.burn_over(t_ns.saturating_sub(fast_ns));
            slo.burn_slow = slo.burn_over(keep_from);

            let spec = &slo.spec;
            let (bf, bs) = (slo.burn_fast, slo.burn_slow);
            let both_at_least = |thr: f64| bf >= thr && bs >= thr;
            let clear = |thr: f64| thr * (1.0 - spec.hysteresis);
            let next = match slo.state {
                AlertState::Ok => {
                    if both_at_least(spec.crit_burn) {
                        AlertState::Critical
                    } else if both_at_least(spec.warn_burn) {
                        AlertState::Warning
                    } else {
                        AlertState::Ok
                    }
                }
                AlertState::Warning => {
                    if both_at_least(spec.crit_burn) {
                        AlertState::Critical
                    } else if bf < clear(spec.warn_burn) && bs < clear(spec.warn_burn) {
                        AlertState::Ok
                    } else {
                        AlertState::Warning
                    }
                }
                AlertState::Critical => {
                    if bf >= clear(spec.crit_burn) || bs >= clear(spec.crit_burn) {
                        AlertState::Critical
                    } else {
                        // one step down per evaluation: recovery goes
                        // through Warning, never Critical → Ok
                        AlertState::Warning
                    }
                }
            };
            if next != slo.state {
                transitions.push(AlertTransition {
                    slo: spec.name.clone(),
                    from: slo.state,
                    to: next,
                    burn_fast: bf,
                    burn_slow: bs,
                    sli: spec.sli.clone(),
                    nudge: if next == AlertState::Critical { spec.nudge } else { None },
                });
                slo.state = next;
            }
        }
        transitions
    }

    /// Current standing of every SLO, in spec order.
    pub fn alerts(&self) -> Vec<Alert> {
        self.slos
            .iter()
            .map(|s| Alert {
                slo: s.spec.name.clone(),
                state: s.state,
                burn_fast: s.burn_fast,
                burn_slow: s.burn_slow,
                value: s.last_value,
                target: s.spec.target,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SEC: u64 = 1_000_000_000;

    fn engine_one(spec: SloSpec) -> SloEngine {
        SloEngine::new(vec![spec]).expect("valid spec")
    }

    fn queue_inputs(frac: f64) -> SloInputs {
        SloInputs { queue_frac: frac, ..SloInputs::default() }
    }

    #[test]
    fn specs_are_validated() {
        assert!(SloSpec::error_rate("e", 0.0).validate().is_err(), "zero target");
        assert!(
            SloSpec::error_rate("e", 0.1)
                .with_windows(Duration::from_secs(60), Duration::from_secs(30))
                .validate()
                .is_err(),
            "fast window longer than slow"
        );
        assert!(
            SloSpec::error_rate("e", 0.1).with_burns(2.0, 1.0).validate().is_err(),
            "crit below warn"
        );
        assert!(
            SloSpec::error_rate("e", 0.1).with_hysteresis(1.0).validate().is_err(),
            "hysteresis must stay below 1"
        );
        assert!(SloSpec::error_rate("e", 0.1).validate().is_ok());
        assert!(
            SloEngine::new(vec![
                SloSpec::error_rate("dup", 0.1),
                SloSpec::queue_depth("dup", 0.5),
            ])
            .is_err(),
            "duplicate names rejected"
        );
    }

    #[test]
    fn alert_fires_only_when_both_windows_burn() {
        // fast 2 s, slow 10 s: one hot tick heats the fast window but
        // the slow window average stays below threshold.
        let spec = SloSpec::queue_depth("q", 0.5)
            .with_windows(Duration::from_secs(2), Duration::from_secs(10))
            .with_burns(1.0, 2.0);
        let mut eng = engine_one(spec);
        let mut t = 0u64;
        for _ in 0..9 {
            assert!(eng.evaluate(t, &queue_inputs(0.05)).is_empty());
            t += SEC;
        }
        // single spike: fast window hot, slow still cool → no alert
        let tr = eng.evaluate(t, &queue_inputs(0.9));
        assert!(tr.is_empty(), "one spike must not page: {tr:?}");
        t += SEC;
        // sustained heat: slow window catches up → Warning then Critical
        let mut states = Vec::new();
        for _ in 0..20 {
            for tr in eng.evaluate(t, &queue_inputs(1.4)) {
                states.push(tr.to);
            }
            t += SEC;
        }
        assert_eq!(states, vec![AlertState::Warning, AlertState::Critical]);
    }

    #[test]
    fn recovery_steps_down_through_warning() {
        let spec = SloSpec::queue_depth("q", 0.1)
            .with_windows(Duration::from_secs(1), Duration::from_secs(3));
        let mut eng = engine_one(spec);
        let mut t = 0u64;
        for _ in 0..5 {
            eng.evaluate(t, &queue_inputs(0.5)); // burn 5 → Critical
            t += SEC;
        }
        assert_eq!(eng.alerts()[0].state, AlertState::Critical);
        let mut seen = Vec::new();
        for _ in 0..8 {
            for tr in eng.evaluate(t, &queue_inputs(0.0)) {
                seen.push((tr.from, tr.to));
            }
            t += SEC;
        }
        assert_eq!(
            seen,
            vec![
                (AlertState::Critical, AlertState::Warning),
                (AlertState::Warning, AlertState::Ok),
            ],
            "recovery must pass through Warning"
        );
    }

    #[test]
    fn nudge_rides_only_critical_transitions() {
        let spec = SloSpec::drift("d", "arm", 1.0)
            .with_windows(Duration::from_secs(1), Duration::from_secs(2))
            .with_burns(1.0, 2.0)
            .with_nudge(16);
        let mut eng = engine_one(spec);
        let drift = |v: f64| SloInputs {
            drift: vec![("arm".to_string(), v)],
            ..SloInputs::default()
        };
        let mut t = 0u64;
        let mut nudges = Vec::new();
        for v in [0.5, 1.5, 1.5, 5.0, 5.0, 0.0, 0.0, 0.0] {
            for tr in eng.evaluate(t, &drift(v)) {
                nudges.push((tr.to, tr.nudge));
            }
            t += SEC;
        }
        assert!(nudges.contains(&(AlertState::Critical, Some(16))));
        for (state, nudge) in &nudges {
            if *state != AlertState::Critical {
                assert_eq!(*nudge, None, "nudge must only ride Critical");
            }
        }
    }

    #[test]
    fn missing_sli_values_are_skipped_not_zeroed() {
        let spec = SloSpec::drift("d", "ghost", 1.0)
            .with_windows(Duration::from_secs(1), Duration::from_secs(2));
        let mut eng = engine_one(spec);
        for i in 0..5 {
            let tr = eng.evaluate(i * SEC, &SloInputs::default());
            assert!(tr.is_empty());
        }
        let a = &eng.alerts()[0];
        assert_eq!(a.state, AlertState::Ok);
        assert_eq!(a.burn_fast, 0.0, "no samples, no burn");
    }
}

//! Unified observability for the serving stack: a process-wide metrics
//! [`Registry`], heap-free per-request [`Trace`]s, and a lock-free
//! [`FlightRecorder`] for postmortems.
//!
//! The three pieces cooperate: requests carry a [`Trace`] from admission
//! through the DRR queue, worker pickup, and `Coordinator::select_one`;
//! completed traces aggregate into per-stage histograms in the registry
//! and land in the flight recorder (always keeping the slowest);
//! platform health transitions and recalibration outcomes are recorded
//! as structured events. `Service::metrics()` publishes scrape-time
//! gauges (queue depth, cache hit ratios, health states) into the same
//! registry, which exports as Prometheus text or a JSON snapshot.
//!
//! On top of those sits the ops plane: [`series`] samples the registry
//! into rolling ring-buffer time-series on an injectable [`clock`],
//! [`slo`] evaluates declarative burn-rate SLOs into an alert state
//! machine, and [`timeline`] exports the recorder's rings as Chrome
//! trace-event JSON for Perfetto.
//!
//! Everything on the warm path — marking a trace stage, recording a
//! histogram sample, writing a flight record, taking a series sample —
//! is allocation-free and lock-free (the sampler excepted: it holds its
//! own mutex, never the hot path's), pinned by
//! `rust/tests/alloc_counter.rs`.

pub mod clock;
pub mod recorder;
pub mod registry;
pub mod series;
pub mod slo;
pub mod timeline;
pub mod trace;

pub use clock::{Clock, ManualClock, SystemClock};
pub use recorder::{FlightRecord, FlightRecorder, RecordKind};
pub use registry::{CellValue, Counter, Gauge, Histogram, Registry};
pub use series::{OpsReport, RecorderCounts, Sampler, SamplerConfig, SeriesPoint, SeriesSnapshot};
pub use slo::{Alert, AlertState, AlertTransition, Sli, SloEngine, SloInputs, SloSpec};
pub use timeline::{chrome_trace, write_chrome_trace};
pub use trace::{Stage, Trace, N_STAGES};

use std::sync::OnceLock;

/// The process-wide metrics registry. Handles registered here aggregate
/// across every `Service` / `Coordinator` in the process.
pub fn registry() -> &'static Registry {
    static REGISTRY: OnceLock<Registry> = OnceLock::new();
    REGISTRY.get_or_init(Registry::new)
}

/// The process-wide flight recorder (default ring shape, 10 ms slow
/// threshold — adjustable via [`FlightRecorder::set_slow_threshold`]).
pub fn flight_recorder() -> &'static FlightRecorder {
    static RECORDER: OnceLock<FlightRecorder> = OnceLock::new();
    RECORDER.get_or_init(FlightRecorder::with_defaults)
}

/// The stable dotted metric-name catalog. Every name the serving stack
/// registers lives here so exporters, tools (`check_metrics.py`), and
/// docs agree on one vocabulary.
pub mod names {
    /// Per-stage latency histograms, label `stage` ∈ {`queue`, `solve`, `e2e`}.
    pub const STAGE_MS: &str = "primsel.trace.stage_ms";
    /// Admission-queue depth at scrape time (gauge).
    pub const QUEUE_DEPTH: &str = "primsel.queue.depth";
    /// Admission-queue capacity (gauge).
    pub const QUEUE_CAPACITY: &str = "primsel.queue.capacity";
    /// Worker-pool size (gauge).
    pub const WORKERS: &str = "primsel.service.workers";
    /// Per-tenant admitted requests, label `tenant` (counter).
    pub const TENANT_ADMITTED: &str = "primsel.tenant.admitted";
    /// Per-tenant rejected requests, label `tenant` (counter).
    pub const TENANT_REJECTED: &str = "primsel.tenant.rejected";
    /// Per-tenant served requests, label `tenant` (counter).
    pub const TENANT_SERVED: &str = "primsel.tenant.served";
    /// Cost-cache hits since service start, label `platform` (counter).
    pub const COST_HITS: &str = "primsel.cache.cost.hits";
    /// Cost-cache misses since service start, label `platform` (counter).
    pub const COST_MISSES: &str = "primsel.cache.cost.misses";
    /// Cost-cache hit ratio, label `platform` (gauge, 0..1).
    pub const COST_HIT_RATIO: &str = "primsel.cache.cost.hit_ratio";
    /// Compiled-plan cache hits (counter).
    pub const PLAN_HITS: &str = "primsel.cache.plan.hits";
    /// Compiled-plan cache misses (counter).
    pub const PLAN_MISSES: &str = "primsel.cache.plan.misses";
    /// Compiled-plan cache hit ratio (gauge, 0..1).
    pub const PLAN_HIT_RATIO: &str = "primsel.cache.plan.hit_ratio";
    /// Pareto-front cache hits (counter).
    pub const FRONT_HITS: &str = "primsel.cache.front.hits";
    /// Pareto-front cache misses (counter).
    pub const FRONT_MISSES: &str = "primsel.cache.front.misses";
    /// Pareto-front cache hit ratio (gauge, 0..1).
    pub const FRONT_HIT_RATIO: &str = "primsel.cache.front.hit_ratio";
    /// Health state code, label `platform` (gauge: 0 healthy, 1
    /// drifting, 2 recalibrating, 3 quarantined).
    pub const HEALTH_STATE: &str = "primsel.health.state";
    /// Latest drift score, label `platform` (gauge).
    pub const HEALTH_DRIFT: &str = "primsel.health.drift";
    /// Flight-recorder lifetime request count (counter).
    pub const RECORDER_REQUESTS: &str = "primsel.recorder.requests";
    /// Flight-recorder lifetime health-event count (counter).
    pub const RECORDER_EVENTS: &str = "primsel.recorder.events";
    /// Flight-recorder lifetime slow-capture count (counter).
    pub const RECORDER_SLOW: &str = "primsel.recorder.slow";
    /// Requests overwritten out of the recorder's recent ring (counter).
    pub const RECORDER_REQUESTS_DROPPED: &str = "primsel.recorder.requests_dropped";
    /// Events overwritten out of the recorder's event ring (counter).
    pub const RECORDER_EVENTS_DROPPED: &str = "primsel.recorder.events_dropped";
    /// SLO alert state code, label `slo` (gauge: 0 ok, 1 warning, 2
    /// critical).
    pub const SLO_STATE: &str = "primsel.slo.state";
    /// Fast-window burn rate, label `slo` (gauge).
    pub const SLO_BURN_FAST: &str = "primsel.slo.burn_fast";
    /// Slow-window burn rate, label `slo` (gauge).
    pub const SLO_BURN_SLOW: &str = "primsel.slo.burn_slow";
    /// Series-sampler ticks taken (counter).
    pub const SERIES_TICKS: &str = "primsel.series.ticks";
}

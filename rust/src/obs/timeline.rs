//! Chrome trace-event export of the flight recorder's rings.
//!
//! [`chrome_trace`] turns already-captured records into the JSON the
//! Chrome tracing UI / Perfetto load: each recorded request becomes a
//! complete `"X"` umbrella span plus one span per adjacent marked
//! stage pair of its [`Trace`](super::trace::Trace) ladder, and each
//! health / recalibration / SLO event becomes a global `"i"` instant.
//! Processes (`pid`) map to platforms and threads (`tid`) to greedily
//! assigned non-overlapping request lanes, with `"M"` metadata naming
//! both. Timestamps are the records' [`wall_ns`](super::recorder::FlightRecord::wall_ns)
//! offsets from the recorder's epoch, emitted in microseconds and
//! sorted, so `ts` is monotone per `(pid, tid)` in array order (pinned
//! by `rust/tests/timeline.rs` and CI's `check_timeline.py`).
//!
//! Export reads only the rings — it costs the serving hot path nothing.

use super::recorder::{FlightRecord, FlightRecorder, RecordKind};
use super::trace::Stage;
use crate::config::Json;
use crate::Result;
use anyhow::Context as _;
use std::collections::BTreeMap;
use std::path::Path;

/// Ops-plane events (alerts, events on unmapped platforms) land on this
/// pid; request lanes start at 1.
const OPS_PID: u64 = 0;

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn span(
    name: String,
    cat: &str,
    ts_us: f64,
    dur_us: f64,
    pid: u64,
    tid: u64,
    args: Json,
) -> Json {
    obj(vec![
        ("name", Json::Str(name)),
        ("cat", Json::Str(cat.to_string())),
        ("ph", Json::Str("X".to_string())),
        ("ts", Json::Num(ts_us)),
        ("dur", Json::Num(dur_us)),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid as f64)),
        ("args", args),
    ])
}

fn instant(name: String, ts_us: f64, pid: u64, args: Json) -> Json {
    obj(vec![
        ("name", Json::Str(name)),
        ("cat", Json::Str("event".to_string())),
        ("ph", Json::Str("i".to_string())),
        ("s", Json::Str("g".to_string())),
        ("ts", Json::Num(ts_us)),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(0.0)),
        ("args", args),
    ])
}

fn metadata(name: &str, value: String, pid: u64, tid: u64) -> Json {
    obj(vec![
        ("name", Json::Str(name.to_string())),
        ("ph", Json::Str("M".to_string())),
        ("ts", Json::Num(0.0)),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid as f64)),
        ("args", obj(vec![("name", Json::Str(value))])),
    ])
}

/// A request's marked stages in ladder order: (stage, epoch-relative ns).
fn marked_stages(r: &FlightRecord) -> Vec<(Stage, u64)> {
    Stage::ALL
        .iter()
        .filter_map(|&s| r.stage_ns(s).map(|ns| (s, r.wall_ns.saturating_add(ns))))
        .collect()
}

/// Build the Chrome trace-event JSON for everything the recorder
/// currently holds: `{"traceEvents": [...], "displayTimeUnit": "ms"}`.
pub fn chrome_trace(rec: &FlightRecorder) -> Json {
    // recent and slow overlap; the per-ring index identifies a request
    let mut requests: BTreeMap<u64, FlightRecord> = BTreeMap::new();
    for r in rec.snapshot().into_iter().chain(rec.slow_snapshot()) {
        requests.entry(r.index).or_insert(r);
    }
    let events = rec.events_snapshot();

    // pids: one per platform named by a request, sorted for stability
    let mut pids: BTreeMap<String, u64> = BTreeMap::new();
    for r in requests.values() {
        let next = pids.len() as u64 + 1;
        pids.entry(r.platform.clone()).or_insert(next);
    }
    for e in &events {
        if e.kind != RecordKind::Alert {
            let next = pids.len() as u64 + 1;
            pids.entry(e.platform.clone()).or_insert(next);
        }
    }

    // greedy lane (tid) assignment per pid over requests sorted by start
    let mut ordered: Vec<&FlightRecord> = requests.values().collect();
    ordered.sort_by_key(|r| {
        let start = marked_stages(r).first().map(|&(_, ns)| ns).unwrap_or(r.wall_ns);
        (start, r.index)
    });
    let mut lanes: BTreeMap<u64, Vec<u64>> = BTreeMap::new(); // pid → per-lane last end ns
    let mut out = Vec::new();
    let mut max_lane: BTreeMap<u64, usize> = BTreeMap::new();
    for r in ordered {
        let stages = marked_stages(r);
        let start = stages.first().map(|&(_, ns)| ns).unwrap_or(r.wall_ns);
        let end = start.saturating_add(r.total_ns);
        let pid = pids[&r.platform];
        let ends = lanes.entry(pid).or_default();
        let lane = match ends.iter().position(|&e| e <= start) {
            Some(i) => {
                ends[i] = end;
                i
            }
            None => {
                ends.push(end);
                ends.len() - 1
            }
        };
        max_lane
            .entry(pid)
            .and_modify(|m| *m = (*m).max(lane))
            .or_insert(lane);
        let tid = lane as u64 + 1;
        out.push(span(
            r.network.clone(),
            "request",
            start as f64 / 1e3,
            r.total_ns as f64 / 1e3,
            pid,
            tid,
            obj(vec![
                ("tenant", Json::Str(r.tenant.clone())),
                ("index", Json::Num(r.index as f64)),
                ("total_ms", Json::Num(r.total_ns as f64 / 1e6)),
            ]),
        ));
        for pair in stages.windows(2) {
            let [(from, a), (to, b)] = [pair[0], pair[1]];
            out.push(span(
                format!("{}->{}", from.name(), to.name()),
                "stage",
                a as f64 / 1e3,
                b.saturating_sub(a) as f64 / 1e3,
                pid,
                tid,
                obj(vec![("index", Json::Num(r.index as f64))]),
            ));
        }
    }

    for e in &events {
        let (name, pid, args) = match e.kind {
            RecordKind::Transition => (
                format!("transition: {}->{}", e.network, e.tenant),
                *pids.get(&e.platform).unwrap_or(&OPS_PID),
                obj(vec![
                    ("platform", Json::Str(e.platform.clone())),
                    ("drift", Json::Num(e.value)),
                ]),
            ),
            RecordKind::Recalibration => (
                format!("recalibration: {}", e.network),
                *pids.get(&e.platform).unwrap_or(&OPS_PID),
                obj(vec![
                    ("platform", Json::Str(e.platform.clone())),
                    ("drift", Json::Num(e.value)),
                ]),
            ),
            RecordKind::Alert => (
                format!("alert: {}->{}", e.network, e.tenant),
                OPS_PID,
                obj(vec![
                    ("slo", Json::Str(e.platform.clone())),
                    ("burn", Json::Num(e.value)),
                ]),
            ),
            RecordKind::Request => continue, // never lands in the event ring
        };
        out.push(instant(name, e.wall_ns as f64 / 1e3, pid, args));
    }

    // sorted by ts ⇒ ts is monotone per (pid, tid) in array order
    out.sort_by(|a, b| {
        let ts = |j: &Json| j.get("ts").and_then(Json::as_f64).unwrap_or(0.0);
        ts(a).partial_cmp(&ts(b)).unwrap_or(std::cmp::Ordering::Equal)
    });

    let mut head = vec![metadata("process_name", "ops".to_string(), OPS_PID, 0)];
    for (platform, &pid) in &pids {
        head.push(metadata("process_name", platform.clone(), pid, 0));
        for lane in 0..=*max_lane.get(&pid).unwrap_or(&0) {
            head.push(metadata(
                "thread_name",
                format!("lane-{lane}"),
                pid,
                lane as u64 + 1,
            ));
        }
    }
    head.extend(out);

    let mut root = BTreeMap::new();
    root.insert("traceEvents".to_string(), Json::Arr(head));
    root.insert("displayTimeUnit".to_string(), Json::Str("ms".to_string()));
    Json::Obj(root)
}

/// Render [`chrome_trace`] to `path` (parent directories are created).
/// Load the file in `chrome://tracing` or <https://ui.perfetto.dev>.
pub fn write_chrome_trace(rec: &FlightRecorder, path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating {}", dir.display()))?;
        }
    }
    std::fs::write(path, chrome_trace(rec).dump())
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::super::trace::Trace;
    use super::*;

    fn recorded_request(
        rec: &FlightRecorder,
        platform: &str,
        network: &str,
        marks: &[(Stage, u64)],
    ) {
        let t = Trace::begin();
        for &(s, ns) in marks {
            t.mark_at_ns(s, ns);
        }
        rec.record_request(&t, platform, network, "tenant");
    }

    fn field<'a>(e: &'a Json, key: &str) -> &'a str {
        e.get(key).unwrap().as_str().unwrap()
    }

    fn num(e: &Json, key: &str) -> f64 {
        e.get(key).unwrap().as_f64().unwrap()
    }

    #[test]
    fn spans_cover_adjacent_marked_stage_pairs() {
        let rec = FlightRecorder::new(8, 2, 8);
        recorded_request(
            &rec,
            "intel",
            "vgg16",
            &[
                (Stage::Admit, 0),
                (Stage::Dispatch, 1_000),
                (Stage::SolveStart, 2_000),
                (Stage::SolveEnd, 7_000),
                (Stage::Done, 8_000),
            ],
        );
        let trace = chrome_trace(&rec);
        let events = trace.get("traceEvents").unwrap().as_arr().unwrap();
        let xs: Vec<&Json> = events.iter().filter(|e| field(e, "ph") == "X").collect();
        // 1 umbrella + 4 adjacent stage pairs
        assert_eq!(xs.len(), 5);
        let names: Vec<&str> = xs.iter().map(|e| field(e, "name")).collect();
        assert!(names.contains(&"vgg16"));
        assert!(names.contains(&"admit->dispatch"));
        assert!(names.contains(&"solve_start->solve_end"));
        for e in &xs {
            assert!(num(e, "dur") >= 0.0);
            assert!(e.get("pid").is_ok() && e.get("tid").is_ok());
        }
    }

    #[test]
    fn overlapping_requests_fan_out_to_lanes() {
        let rec = FlightRecorder::new(8, 2, 8);
        // both requests begin traces "now", so their wall offsets are
        // near-identical and the marked windows overlap
        for net in ["alexnet", "vgg11"] {
            recorded_request(&rec, "intel", net, &[(Stage::Admit, 0), (Stage::Done, 50_000_000)]);
        }
        let trace = chrome_trace(&rec);
        let events = trace.get("traceEvents").unwrap().as_arr().unwrap();
        let tids: Vec<f64> = events
            .iter()
            .filter(|e| field(e, "ph") == "X" && field(e, "cat") == "request")
            .map(|e| num(e, "tid"))
            .collect();
        assert_eq!(tids.len(), 2);
        assert_ne!(tids[0], tids[1], "overlapping requests need distinct lanes");
        // thread metadata names both lanes
        let lanes: Vec<&str> = events
            .iter()
            .filter(|e| field(e, "name") == "thread_name")
            .map(|e| field(e.get("args").unwrap(), "name"))
            .collect();
        assert!(lanes.contains(&"lane-0") && lanes.contains(&"lane-1"));
    }

    #[test]
    fn health_and_alert_events_become_global_instants() {
        let rec = FlightRecorder::new(4, 2, 8);
        rec.record_transition("arm-live", "healthy", "drifting", 2.5);
        rec.record_recalibration("arm-live", true, 0.3);
        rec.record_alert("drift-band", "ok", "critical", 3.0);
        let trace = chrome_trace(&rec);
        let events = trace.get("traceEvents").unwrap().as_arr().unwrap();
        let instants: Vec<&Json> = events.iter().filter(|e| field(e, "ph") == "i").collect();
        assert_eq!(instants.len(), 3);
        let names: Vec<&str> = instants.iter().map(|e| field(e, "name")).collect();
        assert!(names.contains(&"transition: healthy->drifting"));
        assert!(names.contains(&"recalibration: ok"));
        assert!(names.contains(&"alert: ok->critical"));
        for e in &instants {
            assert_eq!(field(e, "s"), "g");
        }
    }

    #[test]
    fn ts_is_monotone_per_pid_tid_in_array_order() {
        let rec = FlightRecorder::new(16, 4, 8);
        for (i, net) in ["a", "b", "c", "d"].iter().enumerate() {
            recorded_request(
                &rec,
                if i % 2 == 0 { "intel" } else { "arm" },
                net,
                &[(Stage::Admit, (i as u64) * 10_000), (Stage::Done, (i as u64) * 10_000 + 5_000)],
            );
        }
        rec.record_transition("intel", "healthy", "drifting", 1.0);
        let trace = chrome_trace(&rec);
        let events = trace.get("traceEvents").unwrap().as_arr().unwrap();
        let mut last: BTreeMap<(u64, u64), f64> = BTreeMap::new();
        for e in events {
            let key = (num(e, "pid") as u64, num(e, "tid") as u64);
            let ts = num(e, "ts");
            if let Some(&prev) = last.get(&key) {
                assert!(ts >= prev, "ts regressed on pid/tid {key:?}");
            }
            last.insert(key, ts);
        }
        // and the whole document parses back
        assert!(Json::parse(&trace.dump()).is_ok());
    }
}

//! Injectable monotonic time for the ops plane.
//!
//! The series sampler and the SLO burn-rate engine never read the wall
//! clock directly: every timestamp they consume comes through a
//! [`Clock`], so production code runs on a [`SystemClock`] (monotonic,
//! `Instant`-backed) while tests drive a [`ManualClock`] and get
//! bit-deterministic sample sequences, burn rates and alert
//! transitions. Timestamps are nanoseconds since the clock's own origin
//! — only differences are meaningful, never absolute epochs.

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::time::Instant;

/// A monotonic nanosecond clock. Implementations must be cheap and
/// thread-safe; `now_ns` must never go backwards.
pub trait Clock: Send + Sync {
    /// Nanoseconds since this clock's origin.
    fn now_ns(&self) -> u64;
}

/// The production clock: `Instant::now()` offsets from construction.
#[derive(Debug)]
pub struct SystemClock {
    origin: Instant,
}

impl Default for SystemClock {
    fn default() -> Self {
        Self::new()
    }
}

impl SystemClock {
    /// A clock whose origin is "now".
    pub fn new() -> Self {
        Self { origin: Instant::now() }
    }
}

impl Clock for SystemClock {
    fn now_ns(&self) -> u64 {
        self.origin.elapsed().as_nanos().min(u64::MAX as u128) as u64
    }
}

/// A hand-cranked clock for deterministic tests: time moves only when
/// the test calls [`ManualClock::advance`] (or [`ManualClock::set`]),
/// so a sampler tick or SLO evaluation sequence replays identically on
/// every run.
#[derive(Debug, Default)]
pub struct ManualClock {
    now_ns: AtomicU64,
}

impl ManualClock {
    /// A manual clock starting at `start_ns`.
    pub fn new(start_ns: u64) -> Self {
        Self { now_ns: AtomicU64::new(start_ns) }
    }

    /// Move time forward by `delta_ns`.
    pub fn advance(&self, delta_ns: u64) {
        self.now_ns.fetch_add(delta_ns, Relaxed);
    }

    /// Jump to an absolute offset. Panics if `ns` would move time
    /// backwards — monotonicity is part of the [`Clock`] contract.
    pub fn set(&self, ns: u64) {
        let prev = self.now_ns.swap(ns, Relaxed);
        assert!(ns >= prev, "ManualClock::set({ns}) would rewind past {prev}");
    }
}

impl Clock for ManualClock {
    fn now_ns(&self) -> u64 {
        self.now_ns.load(Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn system_clock_is_monotone() {
        let c = SystemClock::new();
        let a = c.now_ns();
        let b = c.now_ns();
        assert!(b >= a);
    }

    #[test]
    fn manual_clock_moves_only_by_hand() {
        let c = ManualClock::new(100);
        assert_eq!(c.now_ns(), 100);
        assert_eq!(c.now_ns(), 100);
        c.advance(50);
        assert_eq!(c.now_ns(), 150);
        c.set(1_000);
        assert_eq!(c.now_ns(), 1_000);
    }

    #[test]
    #[should_panic(expected = "rewind")]
    fn manual_clock_refuses_to_rewind() {
        let c = ManualClock::new(10);
        c.set(5);
    }
}

//! Figure 7: inference-time increase of networks optimised with the
//! performance model vs optimised with profiled ("measured") costs.

use super::Workbench;
use crate::networks::{self, Network};
use crate::perfmodel::predictor::DltPredictor;
use crate::perfmodel::Predictor;
use crate::report::Table;
use crate::selection::{self, TableSource};
use anyhow::Result;

/// Build a TableSource for a network from the two predictors (step ii of
/// the paper's pipeline): one batched call for all layers, one for all
/// edge tensors.
pub fn model_source(
    net: &Network,
    prim: &Predictor,
    dlt: &DltPredictor,
) -> Result<TableSource> {
    let rows = prim.predict_configs(&net.layers)?;
    let mut keys: Vec<(u32, u32)> = net
        .edges
        .iter()
        .map(|&(u, v)| (net.layers[u].k, net.layers[v].im))
        .collect();
    keys.sort();
    keys.dedup();
    let mats = dlt.predict_pairs(&keys)?;
    Ok(TableSource::new(net.layers.clone(), rows, keys, mats))
}

/// The relative inference-time increase of model-driven selection vs
/// profile-driven selection, evaluated under measured (simulated) costs.
pub fn increase_for(
    wb: &mut Workbench,
    net: &Network,
    platform: &str,
) -> Result<f64> {
    let nn2_params = wb.nn2_params(platform)?;
    let dlt_params = wb.dlt_nn2_params(platform)?;
    let (sx, sy) = wb.prim_standardizers(platform)?;
    let (dx, dy) = wb.dlt_standardizers(platform)?;
    let sim = wb.platform(platform)?.sim.clone();

    let prim = Predictor::new(&wb.rt, "nn2", nn2_params, sx, sy)?;
    let dlt = DltPredictor::new(&wb.rt, "dlt_nn2", dlt_params, dx, dy)?;
    let source = model_source(net, &prim, &dlt)?;

    // one shared cost cache: select and both evaluations profile each
    // distinct layer/edge tensor once
    let measured = selection::CostCache::new(&sim);
    let sel_model = selection::select(net, &source)?;
    let sel_profiled = selection::select(net, &measured)?;
    let t_model = selection::evaluate(net, &sel_model, &measured)?;
    let t_profiled = selection::evaluate(net, &sel_profiled, &measured)?;
    Ok(t_model / t_profiled - 1.0)
}

/// Figure 7 over the six selection networks and the three platforms.
pub fn fig7(wb: &mut Workbench) -> Result<Vec<Table>> {
    let nets = networks::selection_networks();
    let mut t = Table::new(
        "Figure 7 — relative inference-time increase (model- vs profile-optimised)",
        &["network", "Intel", "AMD", "ARM"],
    );
    let mut worst: f64 = 0.0;
    for net in &nets {
        let mut cells = vec![net.name.clone()];
        for platform in ["intel", "amd", "arm"] {
            let inc = increase_for(wb, net, platform)?;
            worst = worst.max(inc);
            cells.push(format!("{:.2}%", inc * 100.0));
        }
        t.row(cells);
    }
    t.row(vec![
        "paper bound".into(),
        "<= 1.1%".into(),
        format!("(our worst: {:.2}%)", worst * 100.0),
        "".into(),
    ]);
    Ok(vec![t])
}

//! Figure 7: inference-time increase of networks optimised with the
//! performance model vs optimised with profiled ("measured") costs.

use super::Workbench;
use crate::networks;
use crate::perfmodel::model::model_table;
use crate::report::Table;
use crate::selection;
use anyhow::Result;

/// The relative inference-time increase of model-driven selection vs
/// profile-driven selection, evaluated under measured (simulated) costs.
pub fn increase_for(
    wb: &mut Workbench,
    net: &networks::Network,
    platform: &str,
) -> Result<f64> {
    let inputs = wb.xla_model_inputs(platform)?;
    let sim = wb.platform(platform)?.sim.clone();
    let model = inputs.build(&wb.rt)?;
    let source = model_table(net, &model)?;

    // one shared cost cache: select and both evaluations profile each
    // distinct layer/edge tensor once
    let measured = selection::CostCache::new(&sim);
    let sel_model = selection::select(net, &source)?;
    let sel_profiled = selection::select(net, &measured)?;
    let t_model = selection::evaluate(net, &sel_model, &measured)?;
    let t_profiled = selection::evaluate(net, &sel_profiled, &measured)?;
    Ok(t_model / t_profiled - 1.0)
}

/// Figure 7 over the six selection networks and the three platforms.
pub fn fig7(wb: &mut Workbench) -> Result<Vec<Table>> {
    let nets = networks::selection_networks();
    let mut t = Table::new(
        "Figure 7 — relative inference-time increase (model- vs profile-optimised)",
        &["network", "Intel", "AMD", "ARM"],
    );
    let mut worst: f64 = 0.0;
    for net in &nets {
        let mut cells = vec![net.name.clone()];
        for platform in ["intel", "amd", "arm"] {
            let inc = increase_for(wb, net, platform)?;
            worst = worst.max(inc);
            cells.push(format!("{:.2}%", inc * 100.0));
        }
        t.row(cells);
    }
    t.row(vec![
        "paper bound".into(),
        "<= 1.1%".into(),
        format!("(our worst: {:.2}%)", worst * 100.0),
        "".into(),
    ]);
    Ok(vec![t])
}

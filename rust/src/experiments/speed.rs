//! Table 4: total time to optimise each CNN — performance-model inference
//! (milliseconds, wall-clock measured on this host through PJRT) vs the
//! profiling approach (simulated device wall-clock: 25 runs per applicable
//! primitive per layer, paper §4.1.1/§5.2).

use super::Workbench;
use crate::networks;
use crate::par;
use crate::perfmodel::model::model_table;
use crate::report::{fmt_time_ms, Table};
use crate::selection::{self, CostCache};
use anyhow::Result;
use std::time::Instant;

pub fn table4(wb: &mut Workbench) -> Result<Vec<Table>> {
    // model inference is timed with the Intel-trained models (as the paper
    // produces estimates on the Intel platform)
    let inputs = wb.xla_model_inputs("intel")?;
    let sims: Vec<_> = ["intel", "amd", "arm"]
        .iter()
        .map(|p| wb.platform(p).map(|pd| pd.sim.clone()))
        .collect::<Result<_>>()?;

    let model = inputs.build(&wb.rt)?;

    let nets = networks::selection_networks();

    // simulated profiling wall-clock per (platform, network): one shared
    // cost cache per platform, every (platform, network) cell its own
    // parallel job. Cells of the same platform race on one warm cache —
    // each distinct layer config is stored at most once per platform
    // (racing cells may transiently double-compute a shared config; the
    // first insert wins), and the fan-out is no longer capped at one
    // thread per platform (the pre-sharded shape).
    let caches: Vec<CostCache> = sims.iter().map(|s| CostCache::new(s)).collect();
    let cells: Vec<(usize, usize)> = (0..sims.len())
        .flat_map(|p| (0..nets.len()).map(move |n| (p, n)))
        .collect();
    let flat = par::par_map_heavy(&cells, |&(p, n)| {
        caches[p].network_profiling_wallclock_ms(&nets[n])
    });
    let prof_cols: Vec<Vec<f64>> = flat.chunks(nets.len()).map(|c| c.to_vec()).collect();

    let mut t = Table::new(
        "Table 4 — time to optimise a CNN: perf-model vs profiling",
        &["CNN", "Perf. Model Inf.", "Intel prof.", "AMD prof.", "ARM prof.", "speedup vs ARM"],
    );
    for (ni, net) in nets.iter().enumerate() {
        // warm the predict executables so we time inference, not compile
        let _ = model_table(net, &model)?;
        let t0 = Instant::now();
        let source = model_table(net, &model)?;
        let _sel = selection::select(net, &source)?;
        let model_ms = t0.elapsed().as_secs_f64() * 1e3;

        let speedup = prof_cols[2][ni] / model_ms;
        t.row(vec![
            net.name.clone(),
            fmt_time_ms(model_ms),
            fmt_time_ms(prof_cols[0][ni]),
            fmt_time_ms(prof_cols[1][ni]),
            fmt_time_ms(prof_cols[2][ni]),
            format!("{speedup:.0}x"),
        ]);
    }
    Ok(vec![t])
}

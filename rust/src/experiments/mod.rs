//! Experiment regenerators — one per table/figure of the paper's
//! evaluation (see `README.md` for the index). Each experiment prints a
//! table whose rows/series mirror the paper's artefact and dumps a CSV
//! next to it under `results/`.

mod accuracy;
mod quality;
mod speed;
mod tables;
mod transfer;
mod workbench;

pub use workbench::Workbench;

use crate::report::Table;
use anyhow::Result;

/// All experiment ids in paper order.
pub const ALL_IDS: &[&str] = &[
    "table1", "table2", "table3", "fig4", "fig5", "fig6", "table4", "fig7",
    "fig8", "fig9", "fig10", "table5",
];

/// Run one experiment by id. Returns the rendered tables.
pub fn run(id: &str, wb: &mut Workbench) -> Result<Vec<Table>> {
    let tables = match id {
        "table1" => tables::table1(),
        "table2" => tables::table2(wb)?,
        "table3" => tables::table3(),
        "fig4" => accuracy::fig4(wb)?,
        "fig5" => accuracy::fig5(wb)?,
        "fig6" => accuracy::fig6(wb)?,
        "table4" => speed::table4(wb)?,
        "fig7" => quality::fig7(wb)?,
        "fig8" => transfer::fig8(wb)?,
        "fig9" => transfer::fig9(wb, "fig9", &[0.01, 0.025, 0.05, 0.10, 0.25])?,
        "fig10" => transfer::fig9(wb, "fig10", &[0.001])?,
        "table5" => transfer::table5(wb)?,
        _ => anyhow::bail!("unknown experiment id {id} (known: {ALL_IDS:?})"),
    };
    // persist CSVs
    std::fs::create_dir_all("results").ok();
    for (i, t) in tables.iter().enumerate() {
        let path = format!("results/{id}_{i}.csv");
        std::fs::write(&path, t.to_csv()).ok();
    }
    Ok(tables)
}

//! Figures 4–6: performance-model estimation accuracy (MdRAE).

use super::workbench::column_standardizer;
use super::Workbench;
use crate::perfmodel::metrics::{mdrae_per_column, median};
use crate::perfmodel::predictor::DltPredictor;
use crate::perfmodel::Predictor;
use crate::primitives::{catalog, Layout};
use crate::report::Table;
use anyhow::Result;

/// Figure 4: MdRAE of Lin / NN1 / NN2 per primitive, Intel test set.
pub fn fig4(wb: &mut Workbench) -> Result<Vec<Table>> {
    // phase 1: everything that mutates the workbench (training / caching)
    let lin = wb.lin_model("intel")?;
    let nn1_params = wb.nn1_params_all("intel")?;
    let nn2_params = wb.nn2_params("intel")?;
    let (xs, targets, sx, sy) = wb.prim_test_data("intel")?;

    // phase 2: inference only (borrows wb.rt immutably)
    let lin_md = mdrae_per_column(&lin.predict_raw(&xs), &targets);

    let nn2 = Predictor::new(&wb.rt, "nn2", nn2_params, sx.clone(), sy.clone())?;
    let nn2_md = mdrae_per_column(&nn2.predict_raw(&xs)?, &targets);

    let mut nn1_md = Vec::with_capacity(catalog().len());
    for (p, params) in nn1_params.into_iter().enumerate() {
        let sy1 = column_standardizer(&sy, p);
        let m = Predictor::new(&wb.rt, "nn1", params, sx.clone(), sy1)?;
        let preds = m.predict_raw(&xs)?;
        let actual: Vec<Vec<Option<f64>>> =
            targets.iter().map(|row| vec![row[p]]).collect();
        nn1_md.push(mdrae_per_column(&preds, &actual)[0]);
    }

    let mut t = Table::new(
        "Figure 4 — MdRAE per primitive on the Intel test set",
        &["primitive", "Lin", "NN1", "NN2"],
    );
    for (i, prim) in catalog().iter().enumerate() {
        t.row(vec![
            prim.name.into(),
            format!("{:.1}%", lin_md[i] * 100.0),
            format!("{:.1}%", nn1_md[i] * 100.0),
            format!("{:.1}%", nn2_md[i] * 100.0),
        ]);
    }
    let summary =
        |v: &[f64]| median(&v.iter().copied().filter(|x| x.is_finite()).collect::<Vec<_>>());
    t.row(vec![
        "MEDIAN".into(),
        format!("{:.1}%", summary(&lin_md) * 100.0),
        format!("{:.1}%", summary(&nn1_md) * 100.0),
        format!("{:.1}%", summary(&nn2_md) * 100.0),
    ]);
    Ok(vec![t])
}

/// Figure 5: MdRAE of NN2 on the AMD and ARM test sets.
pub fn fig5(wb: &mut Workbench) -> Result<Vec<Table>> {
    let mut per_platform = Vec::new();
    for platform in ["amd", "arm"] {
        let params = wb.nn2_params(platform)?;
        let (xs, targets, sx, sy) = wb.prim_test_data(platform)?;
        let nn2 = Predictor::new(&wb.rt, "nn2", params, sx, sy)?;
        let md = mdrae_per_column(&nn2.predict_raw(&xs)?, &targets);
        per_platform.push(md);
    }
    let mut t = Table::new(
        "Figure 5 — NN2 MdRAE per primitive on AMD / ARM test sets",
        &["primitive", "AMD", "ARM"],
    );
    for (i, prim) in catalog().iter().enumerate() {
        t.row(vec![
            prim.name.into(),
            format!("{:.1}%", per_platform[0][i] * 100.0),
            format!("{:.1}%", per_platform[1][i] * 100.0),
        ]);
    }
    Ok(vec![t])
}

/// Figure 6: MdRAE of the DLT-cost models (Lin / NN1 / NN2) on Intel.
pub fn fig6(wb: &mut Workbench) -> Result<Vec<Table>> {
    let dlt_nn1 = wb.dlt_nn1_params_all("intel")?;
    let dlt_nn2 = wb.dlt_nn2_params("intel")?;
    let (pairs, actuals, sx, sy) = wb.dlt_test_data("intel")?;

    // Lin fit needs the training split: grab it in a scoped mutable borrow
    let lin = {
        let pd = wb.platform("intel")?;
        let train = pd.dlt.subset(&pd.dlt_split.train);
        let txs: Vec<Vec<f64>> = train.features().iter().map(|f| f.to_vec()).collect();
        crate::perfmodel::LinModel::fit(&txs, &train.flat_targets(), sx.clone(), sy.clone())?
    };
    let xs: Vec<Vec<f64>> =
        pairs.iter().map(|&(c, im)| vec![c as f64, im as f64]).collect();
    let lin_md = mdrae_per_column(&lin.predict_raw(&xs), &actuals);

    let nn2 = DltPredictor::new(&wb.rt, "dlt_nn2", dlt_nn2, sx.clone(), sy.clone())?;
    let mats = nn2.predict_pairs(&pairs)?;
    let preds: Vec<Vec<f64>> =
        mats.iter().map(|m| m.iter().flatten().copied().collect()).collect();
    let nn2_md = mdrae_per_column(&preds, &actuals);

    let mut nn1_md = Vec::with_capacity(9);
    for (p, params) in dlt_nn1.into_iter().enumerate() {
        let sy1 = column_standardizer(&sy, p);
        let m = Predictor::new(&wb.rt, "dlt_nn1", params, sx.clone(), sy1)?;
        let preds = m.predict_raw(&xs)?;
        let actual: Vec<Vec<Option<f64>>> =
            actuals.iter().map(|row| vec![row[p]]).collect();
        nn1_md.push(mdrae_per_column(&preds, &actual)[0]);
    }

    let mut labels = Vec::new();
    for src in Layout::ALL {
        for dst in Layout::ALL {
            labels.push(format!("{}->{}", src.name(), dst.name()));
        }
    }
    let mut t = Table::new(
        "Figure 6 — DLT-cost MdRAE on the Intel test set",
        &["transformation", "Lin", "NN1", "NN2"],
    );
    for i in 0..9 {
        if i % 4 == 0 {
            continue; // identity transforms are skipped (cost zero)
        }
        t.row(vec![
            labels[i].clone(),
            format!("{:.1}%", lin_md[i] * 100.0),
            format!("{:.1}%", nn1_md[i] * 100.0),
            format!("{:.1}%", nn2_md[i] * 100.0),
        ]);
    }
    Ok(vec![t])
}

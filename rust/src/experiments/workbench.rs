//! The Workbench: shared state for the experiment suite — per-platform
//! datasets, trained models (disk-cached under `artifacts/trained/`),
//! and the standardisers that travel with them.
//!
//! Model *construction* lives in `perfmodel::model`: the workbench hands
//! out [`XlaModelInputs`] bundles (params + standardisers + provenance)
//! and [`LinCostModel`]s, so experiment code routes through the
//! [`CostModel`](crate::perfmodel::CostModel) trait instead of wiring
//! Predictor/Lin plumbing by hand.

use crate::dataset::{self, Batches, DltDataset, PrimDataset, Split, Standardizer};
use crate::layers::ConvConfig;
use crate::perfmodel::model::{LinCostModel, ModelProvenance, XlaModelInputs};
use crate::perfmodel::{self, hparams_for, LinModel, ParamStore, TrainOpts, Trainer};
use crate::runtime::Runtime;
use crate::simulator::{machine, Simulator};
use anyhow::Result;
use std::collections::HashMap;
use std::path::PathBuf;

pub use crate::dataset::DATASET_SEED;

pub const SPLIT_SEED: u64 = 42;

/// One platform's profiled data, ready for training.
pub struct PlatformData {
    pub sim: Simulator,
    pub prim: PrimDataset,
    pub prim_split: Split,
    pub dlt: DltDataset,
    pub dlt_split: Split,
    pub std_x: Standardizer,
    pub std_y: Standardizer,
    pub dlt_std_x: Standardizer,
    pub dlt_std_y: Standardizer,
}

impl PlatformData {
    pub fn build(platform: &str) -> Result<Self> {
        let sim = Simulator::new(
            machine::by_name(platform)
                .ok_or_else(|| anyhow::anyhow!("unknown platform {platform}"))?,
        );
        let configs = dataset::enumerate_configs(dataset::MAX_CONFIGS, DATASET_SEED);
        let prim = dataset::profile_prim_dataset(&sim, &configs);
        let prim_split = dataset::split(prim.len(), SPLIT_SEED);
        let pairs = dataset::dlt_pairs(&configs);
        let dlt = dataset::profile_dlt_dataset(&sim, &pairs);
        let dlt_split = dataset::split(dlt.len(), SPLIT_SEED);

        // standardisers are fitted on the training split only
        let train = prim.subset(&prim_split.train);
        let xs: Vec<Vec<f64>> = train.features().iter().map(|f| f.to_vec()).collect();
        let std_x = Standardizer::fit(&xs, true);
        let std_y = Standardizer::fit_masked(&train.targets, true);

        let dtrain = dlt.subset(&dlt_split.train);
        let dxs: Vec<Vec<f64>> = dtrain.features().iter().map(|f| f.to_vec()).collect();
        let dlt_std_x = Standardizer::fit(&dxs, true);
        let dlt_std_y = Standardizer::fit_masked(&dtrain.flat_targets(), true);

        Ok(Self { sim, prim, prim_split, dlt, dlt_split, std_x, std_y, dlt_std_x, dlt_std_y })
    }

    /// Batches for a set of indices into the primitive dataset.
    pub fn prim_batches(&self, idx: &[usize], batch: usize) -> Batches {
        let sub = self.prim.subset(idx);
        let xs: Vec<Vec<f64>> = sub.features().iter().map(|f| f.to_vec()).collect();
        dataset::make_batches(&xs, &sub.targets, &self.std_x, &self.std_y, batch)
    }

    /// Batches for the DLT dataset.
    pub fn dlt_batches(&self, idx: &[usize], batch: usize) -> Batches {
        let sub = self.dlt.subset(idx);
        let xs: Vec<Vec<f64>> = sub.features().iter().map(|f| f.to_vec()).collect();
        dataset::make_batches(&xs, &sub.flat_targets(), &self.dlt_std_x, &self.dlt_std_y, batch)
    }
}

/// Shared experiment state.
pub struct Workbench {
    pub rt: Runtime,
    data: HashMap<String, PlatformData>,
    /// Repeats for the sampled-fraction experiments (paper: 25).
    pub repeats: usize,
    /// Epoch caps (lowered for quick runs via CLI flag).
    pub max_epochs: usize,
}

impl Workbench {
    pub fn new(rt: Runtime) -> Self {
        Self { rt, data: HashMap::new(), repeats: 3, max_epochs: 200 }
    }

    pub fn platform(&mut self, name: &str) -> Result<&PlatformData> {
        if !self.data.contains_key(name) {
            eprintln!("[workbench] profiling platform {name} (simulated)...");
            self.data.insert(name.to_string(), PlatformData::build(name)?);
        }
        Ok(&self.data[name])
    }

    /// Owned copy of a platform's test split (features, masked targets)
    /// plus its standardisers — avoids holding a borrow of the workbench
    /// while PJRT predictors (which borrow `self.rt`) are alive.
    pub fn prim_test_data(
        &mut self,
        platform: &str,
    ) -> Result<(Vec<Vec<f64>>, Vec<Vec<Option<f64>>>, Standardizer, Standardizer)> {
        let pd = self.platform(platform)?;
        let test = pd.prim.subset(&pd.prim_split.test);
        let xs: Vec<Vec<f64>> = test.features().iter().map(|f| f.to_vec()).collect();
        Ok((xs, test.targets, pd.std_x.clone(), pd.std_y.clone()))
    }

    /// Owned DLT test data: (pairs, flat targets, std_x, std_y).
    #[allow(clippy::type_complexity)]
    pub fn dlt_test_data(
        &mut self,
        platform: &str,
    ) -> Result<(Vec<(u32, u32)>, Vec<Vec<Option<f64>>>, Standardizer, Standardizer)> {
        let pd = self.platform(platform)?;
        let test = pd.dlt.subset(&pd.dlt_split.test);
        let flat = test.flat_targets();
        Ok((test.pairs, flat, pd.dlt_std_x.clone(), pd.dlt_std_y.clone()))
    }

    /// Owned copy of a platform's primitive test split as configs +
    /// masked targets — the shape [`CostModel`](crate::perfmodel::CostModel)
    /// evaluation consumes.
    pub fn prim_test_set(
        &mut self,
        platform: &str,
    ) -> Result<(Vec<ConvConfig>, Vec<Vec<Option<f64>>>)> {
        let pd = self.platform(platform)?;
        let test = pd.prim.subset(&pd.prim_split.test);
        Ok((test.configs, test.targets))
    }

    /// Owned standardisers for a platform's primitive dataset.
    pub fn prim_standardizers(&mut self, platform: &str) -> Result<(Standardizer, Standardizer)> {
        let pd = self.platform(platform)?;
        Ok((pd.std_x.clone(), pd.std_y.clone()))
    }

    /// Owned standardisers for a platform's DLT dataset.
    pub fn dlt_standardizers(&mut self, platform: &str) -> Result<(Standardizer, Standardizer)> {
        let pd = self.platform(platform)?;
        Ok((pd.dlt_std_x.clone(), pd.dlt_std_y.clone()))
    }

    fn cache_path(&self, tag: &str) -> PathBuf {
        let dir = PathBuf::from("artifacts/trained");
        std::fs::create_dir_all(&dir).ok();
        dir.join(format!("{tag}.bin"))
    }

    fn opts(&self, kind: &str) -> TrainOpts {
        let mut hp = hparams_for(kind);
        hp.max_epochs = self.max_epochs;
        TrainOpts { hp, verbose_every: 0 }
    }

    /// Train (or load cached) the NN2 primitive model for a platform.
    pub fn nn2_params(&mut self, platform: &str) -> Result<ParamStore> {
        let path = self.cache_path(&format!("{platform}_nn2"));
        if path.exists() {
            return ParamStore::load(&path);
        }
        eprintln!("[workbench] training nn2 on {platform}...");
        let opts = self.opts("nn2");
        let pd = self.platform(platform)?;
        let tb = pd.prim_batches(&pd.prim_split.train, 1024);
        let vb = pd.prim_batches(&pd.prim_split.val, 1024);
        let trainer = Trainer::new(&self.rt, "nn2")?;
        let res = trainer.train(trainer.init(7)?, &tb, &vb, opts)?;
        eprintln!(
            "[workbench] nn2/{platform}: {} epochs, val loss {:.5}",
            res.epochs_run, res.best_val_loss
        );
        res.params.save(&path)?;
        Ok(res.params)
    }

    /// Train (or load cached) the NN2 DLT model for a platform.
    pub fn dlt_nn2_params(&mut self, platform: &str) -> Result<ParamStore> {
        let path = self.cache_path(&format!("{platform}_dlt_nn2"));
        if path.exists() {
            return ParamStore::load(&path);
        }
        eprintln!("[workbench] training dlt_nn2 on {platform}...");
        let opts = self.opts("dlt_nn2");
        let pd = self.platform(platform)?;
        let tb = pd.dlt_batches(&pd.dlt_split.train, 1024);
        let vb = pd.dlt_batches(&pd.dlt_split.val, 1024);
        let trainer = Trainer::new(&self.rt, "dlt_nn2")?;
        let res = trainer.train(trainer.init(11)?, &tb, &vb, opts)?;
        res.params.save(&path)?;
        Ok(res.params)
    }

    /// Everything needed to build the platform's native NN2
    /// [`XlaCostModel`](crate::perfmodel::XlaCostModel): train (or load)
    /// the nn2 + dlt_nn2 params, bundle them with the platform's
    /// standardisers. Build with `inputs.build(&wb.rt)` once the
    /// workbench's mutable phase is done.
    pub fn xla_model_inputs(&mut self, platform: &str) -> Result<XlaModelInputs> {
        let prim_params = self.nn2_params(platform)?;
        self.xla_model_inputs_from(prim_params, platform, platform)
    }

    /// The transfer-evaluation shape (paper §4.4): primitive params from
    /// anywhere (trained under `std_from`'s standardisers), DLT model
    /// native to `target`.
    pub fn xla_model_inputs_from(
        &mut self,
        prim_params: ParamStore,
        std_from: &str,
        target: &str,
    ) -> Result<XlaModelInputs> {
        let dlt_params = self.dlt_nn2_params(target)?;
        let (std_x, std_y) = self.prim_standardizers(std_from)?;
        let (dlt_std_x, dlt_std_y) = self.dlt_standardizers(target)?;
        let samples = self.platform(std_from)?.prim_split.train.len();
        Ok(XlaModelInputs {
            prim_kind: "nn2".to_string(),
            prim_params,
            std_x,
            std_y,
            dlt_kind: "dlt_nn2".to_string(),
            dlt_params,
            dlt_std_x,
            dlt_std_y,
            provenance: ModelProvenance::Native { platform: std_from.to_string(), samples },
        })
    }

    /// The platform's full-data [`LinCostModel`] (closed form, offline;
    /// not cached — fitting is cheaper than loading).
    pub fn lin_cost_model(&mut self, platform: &str) -> Result<LinCostModel> {
        let pd = self.platform(platform)?;
        let prim = pd.prim.subset(&pd.prim_split.train);
        let dlt = pd.dlt.subset(&pd.dlt_split.train);
        LinCostModel::fit(&prim, &dlt, platform)
    }

    /// Train (or load) all 31 per-primitive NN1 models for a platform.
    pub fn nn1_params_all(&mut self, platform: &str) -> Result<Vec<ParamStore>> {
        let n = crate::primitives::catalog().len();
        let mut out = Vec::with_capacity(n);
        let mut missing = Vec::new();
        for p in 0..n {
            let path = self.cache_path(&format!("{platform}_nn1_{p}"));
            if path.exists() {
                out.push(Some(ParamStore::load(&path)?));
            } else {
                out.push(None);
                missing.push(p);
            }
        }
        if !missing.is_empty() {
            eprintln!(
                "[workbench] training {} nn1 models on {platform}...",
                missing.len()
            );
            let mut opts = self.opts("nn1");
            opts.hp.max_epochs = opts.hp.max_epochs.min(120);
            opts.hp.patience = 8;
            self.platform(platform)?;
            let trainer = Trainer::new(&self.rt, "nn1")?;
            for p in missing {
                let pd = &self.data[platform];
                let tb = single_column_batches(pd, &pd.prim_split.train, p);
                let vb = single_column_batches(pd, &pd.prim_split.val, p);
                let res = trainer.train(trainer.init(100 + p as i32)?, &tb, &vb, opts)?;
                let path = self.cache_path(&format!("{platform}_nn1_{p}"));
                res.params.save(&path)?;
                out[p] = Some(res.params);
            }
        }
        Ok(out.into_iter().map(|o| o.unwrap()).collect())
    }

    /// Train (or load) the 9 per-transformation NN1 DLT models.
    pub fn dlt_nn1_params_all(&mut self, platform: &str) -> Result<Vec<ParamStore>> {
        let n = 9;
        let mut out = Vec::with_capacity(n);
        let mut missing = Vec::new();
        for p in 0..n {
            let path = self.cache_path(&format!("{platform}_dlt_nn1_{p}"));
            if path.exists() {
                out.push(Some(ParamStore::load(&path)?));
            } else {
                out.push(None);
                missing.push(p);
            }
        }
        if !missing.is_empty() {
            eprintln!("[workbench] training {} dlt_nn1 models on {platform}...", missing.len());
            let mut opts = self.opts("dlt_nn1");
            opts.hp.max_epochs = opts.hp.max_epochs.min(120);
            opts.hp.patience = 8;
            self.platform(platform)?;
            let trainer = Trainer::new(&self.rt, "dlt_nn1")?;
            for p in missing {
                let pd = &self.data[platform];
                let tb = single_dlt_column_batches(pd, &pd.dlt_split.train, p);
                let vb = single_dlt_column_batches(pd, &pd.dlt_split.val, p);
                let res = trainer.train(trainer.init(300 + p as i32)?, &tb, &vb, opts)?;
                let path = self.cache_path(&format!("{platform}_dlt_nn1_{p}"));
                res.params.save(&path)?;
                out[p] = Some(res.params);
            }
        }
        Ok(out.into_iter().map(|o| o.unwrap()).collect())
    }

    /// The Lin baseline for a platform (closed form; not cached).
    pub fn lin_model(&mut self, platform: &str) -> Result<LinModel> {
        let pd = self.platform(platform)?;
        let train = pd.prim.subset(&pd.prim_split.train);
        let xs: Vec<Vec<f64>> = train.features().iter().map(|f| f.to_vec()).collect();
        LinModel::fit(&xs, &train.targets, pd.std_x.clone(), pd.std_y.clone())
    }

    /// Fine-tune params on a subset of a platform's training data
    /// (lr/10, paper §4.4). Returns the tuned parameters.
    pub fn finetune(
        &mut self,
        start: ParamStore,
        platform: &str,
        idx: &[usize],
    ) -> Result<ParamStore> {
        let mut opts = TrainOpts { hp: perfmodel::finetune_hparams("nn2"), verbose_every: 0 };
        opts.hp.max_epochs = opts.hp.max_epochs.min(self.max_epochs);
        let pd = self.platform(platform)?;
        let tb = pd.prim_batches(idx, 1024);
        let vb = pd.prim_batches(&pd.prim_split.val, 1024);
        let trainer = Trainer::new(&self.rt, "nn2")?;
        Ok(trainer.train(start, &tb, &vb, opts)?.params)
    }

    /// Fine-tune with caller-supplied batches (e.g. family-restricted
    /// masks for Table 5).
    pub fn finetune_custom(
        &mut self,
        start: ParamStore,
        tb: &Batches,
        vb: &Batches,
    ) -> Result<ParamStore> {
        let mut opts =
            TrainOpts { hp: perfmodel::finetune_hparams("nn2"), verbose_every: 0 };
        opts.hp.max_epochs = opts.hp.max_epochs.min(self.max_epochs);
        let trainer = Trainer::new(&self.rt, "nn2")?;
        Ok(trainer.train(start, tb, vb, opts)?.params)
    }

    /// Train NN2 from scratch on a subset (the paper's scratch baseline).
    pub fn train_scratch(
        &mut self,
        platform: &str,
        idx: &[usize],
        seed: i32,
    ) -> Result<ParamStore> {
        let opts = self.opts("nn2");
        let pd = self.platform(platform)?;
        let tb = pd.prim_batches(idx, 1024);
        let vb = pd.prim_batches(&pd.prim_split.val, 1024);
        let trainer = Trainer::new(&self.rt, "nn2")?;
        Ok(trainer.train(trainer.init(seed)?, &tb, &vb, opts)?.params)
    }
}

/// Batches with only column `p` as the target (for NN1 training).
fn single_column_batches(pd: &PlatformData, idx: &[usize], p: usize) -> Batches {
    let sub = pd.prim.subset(idx);
    let xs: Vec<Vec<f64>> = sub.features().iter().map(|f| f.to_vec()).collect();
    let ys: Vec<Vec<Option<f64>>> =
        sub.targets.iter().map(|row| vec![row[p]]).collect();
    // a single-column standardiser sliced from the full one
    let std_y1 = Standardizer {
        log: pd.std_y.log,
        mean: vec![pd.std_y.mean[p]],
        std: vec![pd.std_y.std[p]],
    };
    dataset::make_batches(&xs, &ys, &pd.std_x, &std_y1, 1024)
}

/// Batches with only DLT column `p` as target (for DLT NN1 training).
fn single_dlt_column_batches(pd: &PlatformData, idx: &[usize], p: usize) -> Batches {
    let sub = pd.dlt.subset(idx);
    let xs: Vec<Vec<f64>> = sub.features().iter().map(|f| f.to_vec()).collect();
    let ys: Vec<Vec<Option<f64>>> =
        sub.flat_targets().iter().map(|row| vec![row[p]]).collect();
    let std_y1 = column_standardizer(&pd.dlt_std_y, p);
    dataset::make_batches(&xs, &ys, &pd.dlt_std_x, &std_y1, 1024)
}

/// Slice a one-column standardiser out of the platform's target scaler.
pub fn column_standardizer(sy: &Standardizer, p: usize) -> Standardizer {
    Standardizer { log: sy.log, mean: vec![sy.mean[p]], std: vec![sy.std[p]] }
}

//! Tables 1–3: parameter ranges, dataset sizes, hyper-parameters.

use super::Workbench;
use crate::perfmodel::hparams_for;
use crate::primitives::{catalog, Family};
use crate::report::Table;
use anyhow::Result;

/// Table 1: common parameter values for convolutional layers.
pub fn table1() -> Vec<Table> {
    let mut t = Table::new(
        "Table 1 — common parameter values (paper ranges)",
        &["parameter", "meaning", "common range"],
    );
    t.row(vec!["k".into(), "#kernels".into(), "1 to 2048".into()]);
    t.row(vec!["c".into(), "#channels".into(), "1 to 2048".into()]);
    t.row(vec!["im".into(), "image size".into(), "7 to 299".into()]);
    t.row(vec!["s".into(), "stride".into(), "1, 2 or 4".into()]);
    t.row(vec!["f".into(), "kernel size".into(), "1 to 11 (odd)".into()]);
    vec![t]
}

/// Table 2: datapoints per primitive group (paper: 4665 / 1974 / 419 / 417).
pub fn table2(wb: &mut Workbench) -> Result<Vec<Table>> {
    let pd = wb.platform("intel")?;
    let counts = pd.prim.points_per_primitive();
    let cat = catalog();

    // the paper groups by applicability class
    let group_count = |fam: Family| -> usize {
        cat.iter()
            .enumerate()
            .filter(|(_, p)| p.family == fam)
            .map(|(i, _)| counts[i])
            .max()
            .unwrap_or(0)
    };
    let mut t = Table::new(
        "Table 2 — datapoints per primitive group (ours vs paper)",
        &["primitives", "# data points (ours)", "paper"],
    );
    t.row(vec![
        "direct, mec, im2".into(),
        format!("{}", group_count(Family::Direct)),
        "4665".into(),
    ]);
    t.row(vec![
        "kn2".into(),
        format!("{}", group_count(Family::Kn2)),
        "1974".into(),
    ]);
    t.row(vec![
        "wino3, conv-1x1".into(),
        format!(
            "{} / {}",
            group_count(Family::Wino3),
            group_count(Family::Conv1x1)
        ),
        "419".into(),
    ]);
    t.row(vec![
        "wino5".into(),
        format!("{}", group_count(Family::Wino5)),
        "417".into(),
    ]);
    t.row(vec![
        "total configs".into(),
        format!("{}", pd.prim.len()),
        "~4665".into(),
    ]);

    let mut t2 = Table::new(
        "Table 2b — per-primitive datapoint counts",
        &["primitive", "# points"],
    );
    for (i, p) in cat.iter().enumerate() {
        t2.row(vec![p.name.into(), format!("{}", counts[i])]);
    }
    Ok(vec![t, t2])
}

/// Table 3: hyper-parameters used for the neural performance models.
pub fn table3() -> Vec<Table> {
    let n1 = hparams_for("nn1");
    let n2 = hparams_for("nn2");
    let mut t = Table::new(
        "Table 3 — performance-model hyper-parameters",
        &["setting", "NN1", "NN2"],
    );
    t.row(vec!["optimizer".into(), "Adam".into(), "Adam".into()]);
    t.row(vec!["learning rate".into(), format!("{}", n1.lr), format!("{}", n2.lr)]);
    t.row(vec![
        "weight decay".into(),
        format!("{}", n1.weight_decay),
        format!("{:e}", n2.weight_decay),
    ]);
    t.row(vec!["batch size".into(), format!("{}", n1.batch), format!("{}", n2.batch)]);
    t.row(vec![
        "iterations".into(),
        "early stopping".into(),
        "early stopping".into(),
    ]);
    t.row(vec!["non-linearity".into(), "ReLU".into(), "ReLU".into()]);
    t.row(vec![
        "architecture".into(),
        "5x16x64x64x16x1".into(),
        "5x128x512x512x128xn".into(),
    ]);
    vec![t]
}

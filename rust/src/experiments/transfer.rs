//! Figures 8–10 and Table 5: transfer learning across platforms. All
//! flows route through the [`CostModel`] layer: the workbench hands out
//! [`XlaModelInputs`](crate::perfmodel::XlaModelInputs) bundles, and
//! evaluation/selection consume the built model through the trait —
//! the same abstraction the serving path uses.

use super::Workbench;
use crate::dataset::PrimDataset;
use crate::networks;
use crate::perfmodel::metrics::{mdrae_all, mdrae_per_column};
use crate::perfmodel::model::{model_table, CostModel};
use crate::perfmodel::transfer::prim_factors;
use crate::perfmodel::ParamStore;
use crate::primitives::{catalog, Family};
use crate::report::Table;
use crate::selection;
use anyhow::Result;

/// Evaluate a primitive-model parameter set on a target platform:
/// (MdRAE on the target test set, GoogLeNet inference increase).
/// `std_from` names the platform whose standardisers the params were
/// trained under ("intel" for direct transfer, the target otherwise);
/// `factors` optionally applies §4.4 correction factors estimated from
/// `calib_samples` target rows.
fn eval_on_target(
    wb: &mut Workbench,
    params: ParamStore,
    std_from: &str,
    target: &str,
    factors: Option<(Vec<f64>, usize)>,
) -> Result<(f64, f64)> {
    let (cfgs, targets) = wb.prim_test_set(target)?;
    let inputs = wb.xla_model_inputs_from(params, std_from, target)?;
    let sim = wb.platform(target)?.sim.clone();

    let mut model = inputs.build(&wb.rt)?;
    if let Some((f, n)) = factors {
        model = model.with_prim_factors(f, n);
    }
    let md = mdrae_all(&model.predict_prim(&cfgs)?, &targets);

    // GoogLeNet selection quality (the paper's §4.4 target network);
    // one cache serves the profiled selection and both evaluations
    let net = networks::googlenet();
    let source = model_table(&net, &model)?;
    let measured = selection::CostCache::new(&sim);
    let sel_model = selection::select(&net, &source)?;
    let sel_prof = selection::select(&net, &measured)?;
    let t_model = selection::evaluate(&net, &sel_model, &measured)?;
    let t_prof = selection::evaluate(&net, &sel_prof, &measured)?;
    Ok((md, t_model / t_prof - 1.0))
}

/// A seeded calibration subset of a platform's training rows.
fn calib_subset(wb: &mut Workbench, target: &str, frac: f64, seed: u64) -> Result<PrimDataset> {
    let pd = wb.platform(target)?;
    let idx = crate::dataset::fraction(&pd.prim_split.train, frac, seed);
    Ok(pd.prim.subset(&idx))
}

/// Figure 8: Intel model applied to AMD/ARM — directly, factor-corrected
/// (1% of target samples), and a natively trained model.
pub fn fig8(wb: &mut Workbench) -> Result<Vec<Table>> {
    let intel = wb.nn2_params("intel")?;
    let mut ta = Table::new(
        "Figure 8a — primitive-estimation MdRAE on target platforms",
        &["target", "Intel direct", "Factor Intel (1%)", "native NN2"],
    );
    let mut tb = Table::new(
        "Figure 8b — GoogLeNet inference increase vs profiled-optimal",
        &["target", "Intel direct", "Factor Intel (1%)", "native NN2"],
    );
    for target in ["amd", "arm"] {
        // factor correction from 1% of the target's training data,
        // estimated through the CostModel trait
        let cal = calib_subset(wb, target, 0.01, 77)?;
        let factors = {
            let inputs = wb.xla_model_inputs_from(intel.clone(), "intel", target)?;
            let model = inputs.build(&wb.rt)?;
            prim_factors(&model, &cal)?
        };

        let (md_direct, inc_direct) =
            eval_on_target(wb, intel.clone(), "intel", target, None)?;
        let (md_factor, inc_factor) =
            eval_on_target(wb, intel.clone(), "intel", target, Some((factors, cal.len())))?;
        let native = wb.nn2_params(target)?;
        let (md_native, inc_native) = eval_on_target(wb, native, target, target, None)?;

        ta.row(vec![
            target.into(),
            format!("{:.0}%", md_direct * 100.0),
            format!("{:.0}%", md_factor * 100.0),
            format!("{:.1}%", md_native * 100.0),
        ]);
        tb.row(vec![
            target.into(),
            format!("{:.1}%", inc_direct * 100.0),
            format!("{:.1}%", inc_factor * 100.0),
            format!("{:.2}%", inc_native * 100.0),
        ]);
    }
    Ok(vec![ta, tb])
}

/// Figures 9/10: scratch vs fine-tuned models at training-data fractions.
pub fn fig9(wb: &mut Workbench, _id: &str, fractions: &[f64]) -> Result<Vec<Table>> {
    let intel = wb.nn2_params("intel")?;
    let repeats = wb.repeats;
    let mut t = Table::new(
        "Figures 9/10 — predictive + selection performance vs data fraction",
        &["target", "fraction", "mode", "MdRAE (mean)", "GoogLeNet incr (mean)"],
    );
    for target in ["amd", "arm"] {
        // reference: native model on all training data (the dotted line)
        let native = wb.nn2_params(target)?;
        let (md_full, inc_full) = eval_on_target(wb, native, target, target, None)?;
        t.row(vec![
            target.into(),
            "100%".into(),
            "native-full".into(),
            format!("{:.1}%", md_full * 100.0),
            format!("{:.2}%", inc_full * 100.0),
        ]);
        for &frac in fractions {
            for mode in ["scratch", "finetune"] {
                let mut mds = Vec::new();
                let mut incs = Vec::new();
                for rep in 0..repeats {
                    let idx = {
                        let pd = wb.platform(target)?;
                        crate::dataset::fraction(
                            &pd.prim_split.train,
                            frac,
                            1000 + rep as u64,
                        )
                    };
                    let params = if mode == "scratch" {
                        wb.train_scratch(target, &idx, 500 + rep as i32)?
                    } else {
                        wb.finetune(intel.clone(), target, &idx)?
                    };
                    let (md, inc) = eval_on_target(wb, params, target, target, None)?;
                    mds.push(md);
                    incs.push(inc);
                }
                let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
                t.row(vec![
                    target.into(),
                    format!("{:.1}%", frac * 100.0),
                    mode.into(),
                    format!("{:.1}%", mean(&mds) * 100.0),
                    format!("{:.2}%", mean(&incs) * 100.0),
                ]);
            }
        }
    }
    Ok(vec![t])
}

/// Table 5: cross-family transferability. Fine-tune the Intel model on
/// AMD data from one family only; evaluate per family; normalise rows so
/// the diagonal is 1.
pub fn table5(wb: &mut Workbench) -> Result<Vec<Table>> {
    let intel = wb.nn2_params("intel")?;
    let fams = Family::ALL;
    let fam_cols: Vec<Vec<usize>> = fams
        .iter()
        .map(|f| {
            catalog()
                .iter()
                .enumerate()
                .filter(|(_, p)| p.family == *f)
                .map(|(i, _)| i)
                .collect()
        })
        .collect();

    // MdRAE matrix: rows = fine-tune family, cols = eval family
    let mut raw = vec![vec![f64::NAN; fams.len()]; fams.len()];
    for (fi, cols) in fam_cols.iter().enumerate() {
        // fine-tune on AMD data restricted to this family's columns
        let (tb, vb) = {
            let pd = wb.platform("amd")?;
            let tb = family_batches(pd, &pd.prim_split.train, cols);
            let vb = family_batches(pd, &pd.prim_split.val, cols);
            (tb, vb)
        };
        let params = wb.finetune_custom(intel.clone(), &tb, &vb)?;
        let (cfgs, targets) = wb.prim_test_set("amd")?;
        let inputs = wb.xla_model_inputs_from(params, "amd", "amd")?;
        let model = inputs.build(&wb.rt)?;
        let per_col = mdrae_per_column(&model.predict_prim(&cfgs)?, &targets);
        for (fj, cols_j) in fam_cols.iter().enumerate() {
            let vals: Vec<f64> = cols_j
                .iter()
                .map(|&c| per_col[c])
                .filter(|v| v.is_finite())
                .collect();
            raw[fi][fj] = vals.iter().sum::<f64>() / vals.len().max(1) as f64;
        }
    }

    let mut t = Table::new(
        "Table 5 — cross-family transfer (rows normalised to diagonal = 1)",
        &["tuned on \\ eval", "direct", "im2", "kn2", "wino3", "wino5", "c1x1", "mec"],
    );
    for (fi, fam) in fams.iter().enumerate() {
        let mut cells = vec![fam.name().to_string()];
        for fj in 0..fams.len() {
            let norm = raw[fi][fj] / raw[fj][fj].max(1e-12);
            cells.push(format!("{norm:.0}"));
        }
        t.row(cells);
    }
    Ok(vec![t])
}

/// Batches keeping only the given target columns unmasked.
fn family_batches(
    pd: &super::workbench::PlatformData,
    idx: &[usize],
    cols: &[usize],
) -> crate::dataset::Batches {
    let sub = pd.prim.subset(idx);
    let xs: Vec<Vec<f64>> = sub.features().iter().map(|f| f.to_vec()).collect();
    let ys: Vec<Vec<Option<f64>>> = sub
        .targets
        .iter()
        .map(|row| {
            row.iter()
                .enumerate()
                .map(|(j, v)| if cols.contains(&j) { *v } else { None })
                .collect()
        })
        .collect();
    crate::dataset::make_batches(&xs, &ys, &pd.std_x, &pd.std_y, 1024)
}

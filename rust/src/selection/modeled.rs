//! [`ModeledSource`]: the bridge from the model layer to the cost-query
//! engine. Any `Send + Sync` [`CostModel`] becomes a [`CostSource`], so
//! every existing consumer — `build_problem`, [`CostCache`](super::CostCache),
//! dense tables, the [`Coordinator`](crate::coordinator) — works
//! unchanged over *predicted* costs. This is the paper's headline swap
//! (profiling stage → trained model) expressed as a drop-in source.

use super::{CostSource, TableSource};
use crate::layers::ConvConfig;
use crate::networks::Network;
use crate::perfmodel::model::{clamp_dlt, masked_row, model_table, CostModel, COST_FLOOR_MS};
use crate::primitives::Layout;
use anyhow::Result;
use std::borrow::Cow;
use std::sync::Arc;

/// A [`CostSource`] that answers from a trained [`CostModel`].
///
/// Served rows are applicability-masked via the catalog and clamped to
/// [`COST_FLOOR_MS`]; DLT matrices keep a zero diagonal. Queries run the
/// model per key, so the source reports `is_memoized() == false` and the
/// selection entry points (and the coordinator's per-platform caches)
/// transparently memoize it — each distinct layer config is predicted
/// once per cache lifetime.
///
/// The model must be infallible at query time for the `CostSource`
/// contract (which has no error channel): the in-tree `Send + Sync`
/// models (Lin, factor-corrected Lin) are pure arithmetic and cannot
/// fail, so a prediction error here is a programming bug and panics.
pub struct ModeledSource {
    model: Arc<dyn CostModel + Send + Sync>,
}

impl ModeledSource {
    pub fn new(model: Arc<dyn CostModel + Send + Sync>) -> Self {
        Self { model }
    }

    /// The model answering this source's queries.
    pub fn model(&self) -> &(dyn CostModel + Send + Sync) {
        self.model.as_ref()
    }

    /// Bake the dense per-network table (masked + clamped) — the shape to
    /// persist for an onboarded platform.
    pub fn table_for(&self, net: &Network) -> Result<TableSource> {
        model_table(net, self.model.as_ref())
    }
}

impl CostSource for ModeledSource {
    fn layer_costs(&self, cfg: &ConvConfig) -> Cow<'_, [Option<f64>]> {
        let raw = self
            .model
            .predict_prim(std::slice::from_ref(cfg))
            .expect("cost model failed to predict a layer row");
        Cow::Owned(masked_row(cfg, &raw[0], COST_FLOOR_MS))
    }

    fn dlt_cost(&self, c: u32, im: u32, src: Layout, dst: Layout) -> f64 {
        if src == dst {
            return 0.0;
        }
        self.dlt_matrix3(c, im)[src.index()][dst.index()]
    }

    fn dlt_matrix3(&self, c: u32, im: u32) -> [[f64; 3]; 3] {
        let raw = self
            .model
            .predict_dlt(&[(c, im)])
            .expect("cost model failed to predict a DLT matrix");
        clamp_dlt(raw[0], COST_FLOOR_MS)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfmodel::model::ModelProvenance;
    use crate::primitives::catalog;
    use crate::{dataset, networks, selection};
    use crate::perfmodel::LinCostModel;
    use crate::simulator::{machine, Simulator};

    /// A model that predicts nonsense (negative everywhere) — the source
    /// must still serve strictly positive, masked rows.
    struct Hostile(ModelProvenance);

    impl CostModel for Hostile {
        fn kind(&self) -> &str {
            "hostile"
        }
        fn provenance(&self) -> &ModelProvenance {
            &self.0
        }
        fn predict_prim(&self, cfgs: &[ConvConfig]) -> Result<Vec<Vec<f64>>> {
            Ok(cfgs.iter().map(|_| vec![-1.0; catalog().len()]).collect())
        }
        fn predict_dlt(&self, pairs: &[(u32, u32)]) -> Result<Vec<[[f64; 3]; 3]>> {
            Ok(pairs.iter().map(|_| [[-1.0; 3]; 3]).collect())
        }
    }

    #[test]
    fn hostile_model_is_floored_and_masked() {
        let src = ModeledSource::new(Arc::new(Hostile(ModelProvenance::Native {
            platform: "void".into(),
            samples: 0,
        })));
        let cfg = ConvConfig::new(16, 16, 28, 2, 3);
        let row = src.layer_costs(&cfg);
        for (t, p) in row.iter().zip(catalog()) {
            assert_eq!(t.is_some(), p.applicable(&cfg));
            if let Some(v) = t {
                assert_eq!(*v, COST_FLOOR_MS);
            }
        }
        let m = src.dlt_matrix3(16, 28);
        assert_eq!(m[1][1], 0.0);
        assert_eq!(m[0][1], COST_FLOOR_MS);
        assert_eq!(src.dlt_cost(16, 28, Layout::Chw, Layout::Chw), 0.0);
        assert_eq!(src.dlt_cost(16, 28, Layout::Chw, Layout::Hwc), COST_FLOOR_MS);
        assert!(!src.is_memoized());
    }

    #[test]
    fn selection_over_modeled_source_runs_end_to_end() {
        // a Lin model trained on simulated intel data must drive the full
        // select/evaluate path with no PJRT anywhere
        let sim = Simulator::new(machine::intel_i9_9900k());
        let (prim, dlt) = dataset::calibration_sample(&sim, 0.05, 7);
        let model = LinCostModel::fit(&prim, &dlt, "intel").unwrap();
        let src = ModeledSource::new(Arc::new(model));
        let net = networks::vgg(11);
        let sel = selection::select(&net, &src).unwrap();
        assert_eq!(sel.primitive.len(), net.n_layers());
        assert!(sel.estimated_ms > 0.0);
        // the modeled selection, evaluated under measured costs, is a
        // valid assignment (all chosen primitives applicable)
        let t = selection::evaluate(&net, &sel, &sim).unwrap();
        assert!(t > 0.0);
    }

    #[test]
    fn modeled_source_matches_its_baked_table() {
        let sim = Simulator::new(machine::arm_cortex_a73());
        let (prim, dlt) = dataset::calibration_sample(&sim, 0.03, 9);
        let model = LinCostModel::fit(&prim, &dlt, "arm").unwrap();
        let src = ModeledSource::new(Arc::new(model));
        let net = networks::alexnet();
        let table = src.table_for(&net).unwrap();
        for cfg in &net.layers {
            assert_eq!(src.layer_costs(cfg).as_ref(), table.layer_costs(cfg).as_ref());
        }
        for &(u, v) in &net.edges {
            let (c, im) = (net.layers[u].k, net.layers[v].im);
            assert_eq!(src.dlt_matrix3(c, im), table.dlt_matrix3(c, im));
        }
    }
}

//! The primitive-selection engine (steps ii–iv of the paper's Figure 2):
//! assemble the PBQP cost graph for a network from any cost source
//! (profiled or predicted), solve it, and evaluate assignments.
//!
//! All cost consumers sit behind the cost-query engine (see [`cache`]):
//! non-memoized sources are wrapped in a [`CostCache`] transparently, so
//! `build_problem`, `evaluate` and `single_family_baseline` profile each
//! distinct layer config and edge tensor at most once per call, and edge
//! matrices are assembled from one 3x3 DLT matrix per edge instead of one
//! cost query per primitive pair.

pub mod cache;
pub mod faulty;
pub mod memory;
pub mod modeled;
pub mod pareto;
pub mod plan;

pub use cache::{CacheStats, CostCache};
pub use faulty::FaultySource;
pub use modeled::ModeledSource;
pub use pareto::{ParetoFront, ParetoPoint};
pub use plan::{PlanScratch, PlanSelection, SelectionPlan};

use crate::layers::ConvConfig;
use crate::networks::Network;
use crate::pbqp::{self, Graph};
use crate::primitives::{catalog, Layout};
use anyhow::{ensure, Result};
use std::borrow::Cow;
use std::collections::HashMap;

/// A source of primitive and DLT costs — either the profiler/simulator
/// ("measured", the paper's baseline flow) or a performance model
/// ("predicted", the paper's contribution).
///
/// Rows are returned as `Cow`: dense table sources hand out borrows,
/// computing sources hand out owned rows. `dlt_matrix3` exists so graph
/// assembly can fetch a whole edge-tensor matrix in one query.
///
/// `Send + Sync` is a supertrait: every cost source is shareable across
/// threads, so one warm [`CostCache`] (itself a `CostSource`) can serve
/// concurrent selection requests — the contract the
/// [`Coordinator`](crate::coordinator) and the parallel sweeps rely on.
/// All in-tree sources (simulator, dense tables, caches) are immutable
/// or internally synchronised, so the bound costs nothing.
pub trait CostSource: Send + Sync {
    /// Per-primitive cost row for one layer (ms; None = inapplicable).
    fn layer_costs(&self, cfg: &ConvConfig) -> Cow<'_, [Option<f64>]>;

    /// DLT cost for a (c, im) tensor between two layouts (ms).
    fn dlt_cost(&self, c: u32, im: u32, src: Layout, dst: Layout) -> f64;

    /// The full 3x3 DLT matrix for a (c, im) tensor (row = src layout,
    /// col = dst layout; zero diagonal).
    fn dlt_matrix3(&self, c: u32, im: u32) -> [[f64; 3]; 3] {
        let mut m = [[0.0; 3]; 3];
        for src in Layout::ALL {
            for dst in Layout::ALL {
                if src != dst {
                    m[src.index()][dst.index()] = self.dlt_cost(c, im, src, dst);
                }
            }
        }
        m
    }

    /// Whether queries are already O(1) lookups (dense tables, caches).
    /// Non-memoized sources get wrapped in a [`CostCache`] by the solver
    /// entry points.
    fn is_memoized(&self) -> bool {
        false
    }
}

impl CostSource for crate::simulator::Simulator {
    fn layer_costs(&self, cfg: &ConvConfig) -> Cow<'_, [Option<f64>]> {
        Cow::Owned(self.profile_layer(cfg))
    }

    fn dlt_cost(&self, c: u32, im: u32, src: Layout, dst: Layout) -> f64 {
        self.profile_dlt(c, im, src, dst)
    }

    fn dlt_matrix3(&self, c: u32, im: u32) -> [[f64; 3]; 3] {
        self.dlt_matrix(c, im)
    }
}

/// Precomputed dense cost tables (from a Predictor or a [`CostCache`]):
/// hash-indexed configs, borrowed rows, O(1) DLT lookups.
pub struct TableSource {
    /// Layer configs in insertion (network layer) order.
    configs: Vec<ConvConfig>,
    /// Row per config, aligned with `configs`.
    prim: Vec<Vec<Option<f64>>>,
    /// cfg -> row index (first occurrence wins for duplicate configs,
    /// matching the old linear-scan semantics).
    by_cfg: HashMap<ConvConfig, usize>,
    /// DLT entries `((c, im), matrix)` sorted by key — [`Self::dlt_entries`]
    /// hands this out as a borrow.
    dlt: Vec<((u32, u32), [[f64; 3]; 3])>,
    /// (c, im) -> index into `dlt`.
    by_dlt: HashMap<(u32, u32), usize>,
}

impl TableSource {
    pub fn new(
        configs: Vec<ConvConfig>,
        prim: Vec<Vec<Option<f64>>>,
        dlt_keys: Vec<(u32, u32)>,
        dlt_mats: Vec<[[f64; 3]; 3]>,
    ) -> Self {
        assert_eq!(configs.len(), prim.len(), "row per config");
        assert_eq!(dlt_keys.len(), dlt_mats.len(), "matrix per dlt key");
        let mut by_cfg = HashMap::with_capacity(configs.len());
        for (i, cfg) in configs.iter().enumerate() {
            by_cfg.entry(*cfg).or_insert(i);
        }
        // collect through a map first so duplicate keys keep the old
        // last-insert-wins semantics, then freeze a sorted entry list
        let map: HashMap<(u32, u32), [[f64; 3]; 3]> =
            dlt_keys.into_iter().zip(dlt_mats).collect();
        let mut dlt: Vec<((u32, u32), [[f64; 3]; 3])> = map.into_iter().collect();
        dlt.sort_unstable_by_key(|(k, _)| *k);
        let by_dlt = dlt.iter().enumerate().map(|(i, (k, _))| (*k, i)).collect();
        Self { configs, prim, by_cfg, dlt, by_dlt }
    }

    /// The configs this table covers, in insertion order.
    pub fn configs(&self) -> &[ConvConfig] {
        &self.configs
    }

    /// Borrowed row for a config, if present.
    pub fn row(&self, cfg: &ConvConfig) -> Option<&[Option<f64>]> {
        self.by_cfg.get(cfg).map(|&i| self.prim[i].as_slice())
    }

    /// All DLT entries `((c, im), matrix)`, sorted by key — a borrow of
    /// the table's own sorted storage (no per-call allocation). The
    /// persistence layer (`dataset::persist`) walks the table through
    /// this and [`Self::configs`]/[`Self::row`].
    pub fn dlt_entries(&self) -> &[((u32, u32), [[f64; 3]; 3])] {
        &self.dlt
    }

    fn dlt_lookup(&self, c: u32, im: u32) -> &[[f64; 3]; 3] {
        let &i = self.by_dlt.get(&(c, im)).expect("dlt pair not in table");
        &self.dlt[i].1
    }
}

impl CostSource for TableSource {
    fn layer_costs(&self, cfg: &ConvConfig) -> Cow<'_, [Option<f64>]> {
        Cow::Borrowed(self.row(cfg).expect("config not in table"))
    }

    fn dlt_cost(&self, c: u32, im: u32, src: Layout, dst: Layout) -> f64 {
        if src == dst {
            return 0.0;
        }
        self.dlt_lookup(c, im)[src.index()][dst.index()]
    }

    fn dlt_matrix3(&self, c: u32, im: u32) -> [[f64; 3]; 3] {
        *self.dlt_lookup(c, im)
    }

    fn is_memoized(&self) -> bool {
        true
    }
}

/// The PBQP instance for a network plus the choice -> primitive mapping.
pub struct SelectionProblem {
    pub graph: Graph,
    /// choices[u] = catalog indices applicable at layer u.
    pub choices: Vec<Vec<usize>>,
}

/// Run `f` against a memoized view of `costs`: already-memoized sources
/// pass through, everything else gets a transient [`CostCache`]. Every
/// cost-consuming entry point funnels through this, so none can forget
/// the wrap (or double-wrap).
pub(crate) fn with_cache<R>(
    costs: &dyn CostSource,
    f: impl FnOnce(&dyn CostSource) -> R,
) -> R {
    if costs.is_memoized() {
        f(costs)
    } else {
        f(&CostCache::new(costs))
    }
}

/// Build the selection PBQP graph: node costs = primitive times, edge
/// costs = DLT between the producer's output layout and the consumer's
/// input layout, on the producer's output tensor.
pub fn build_problem(net: &Network, costs: &dyn CostSource) -> Result<SelectionProblem> {
    with_cache(costs, |c: &dyn CostSource| build_problem_inner(net, c))
}

fn build_problem_inner(net: &Network, costs: &dyn CostSource) -> Result<SelectionProblem> {
    let cat = catalog();
    let mut node_costs = Vec::with_capacity(net.n_layers());
    let mut choices = Vec::with_capacity(net.n_layers());
    for cfg in &net.layers {
        let row = costs.layer_costs(cfg);
        let mut ch = Vec::new();
        let mut nc = Vec::new();
        for (p, t) in row.iter().enumerate() {
            if let Some(t) = t {
                ch.push(p);
                nc.push(*t);
            }
        }
        ensure!(!ch.is_empty(), "no applicable primitive for {cfg:?}");
        node_costs.push(nc);
        choices.push(ch);
    }
    let mut graph = Graph::new(node_costs);
    for &(u, v) in &net.edges {
        // the tensor on this edge: u's output (k_u channels at v's input
        // resolution)
        let c = net.layers[u].k;
        let im = net.layers[v].im;
        let m = costs.dlt_matrix3(c, im);
        let cu = &choices[u];
        let cv = &choices[v];
        let mut mat = Vec::with_capacity(cu.len() * cv.len());
        for &pu in cu {
            let out_l = cat[pu].out_layout;
            for &pv in cv {
                let in_l = cat[pv].in_layout;
                mat.push(m[out_l.index()][in_l.index()]);
            }
        }
        graph.add_edge(u, v, mat);
    }
    Ok(SelectionProblem { graph, choices })
}

/// A solved selection: primitive per layer plus the solver's objective
/// and the assignment's true time.
#[derive(Debug, Clone)]
pub struct Selection {
    /// Catalog index per layer.
    pub primitive: Vec<usize>,
    /// The value the solver minimised. For plain min-time selection this
    /// equals `estimated_ms`; for budgeted objectives
    /// ([`memory::select_with_budget`]) it includes the per-layer
    /// workspace penalty terms.
    pub objective_ms: f64,
    /// True network time (ms) of the assignment under the cost source
    /// used for solving — node times plus DLT edges, never
    /// penalty-inflated.
    pub estimated_ms: f64,
}

/// Solve the selection problem with PBQP.
pub fn select(net: &Network, costs: &dyn CostSource) -> Result<Selection> {
    let prob = build_problem(net, costs)?;
    let sol = pbqp::solve(&prob.graph);
    let primitive = sol
        .choice
        .iter()
        .enumerate()
        .map(|(u, &ci)| prob.choices[u][ci])
        .collect();
    Ok(Selection { primitive, objective_ms: sol.cost, estimated_ms: sol.cost })
}

/// Evaluate an assignment's true network time under a (different) cost
/// source — used for the paper's Figure 7/8: optimise with predicted
/// costs, evaluate with measured costs.
pub fn evaluate(net: &Network, sel: &Selection, costs: &dyn CostSource) -> Result<f64> {
    with_cache(costs, |c: &dyn CostSource| evaluate_inner(net, sel, c))
}

fn evaluate_inner(net: &Network, sel: &Selection, costs: &dyn CostSource) -> Result<f64> {
    let cat = catalog();
    let mut total = 0.0;
    for (u, cfg) in net.layers.iter().enumerate() {
        let row = costs.layer_costs(cfg);
        let t = row[sel.primitive[u]]
            .ok_or_else(|| anyhow::anyhow!("selected inapplicable primitive"))?;
        total += t;
    }
    for &(u, v) in &net.edges {
        let c = net.layers[u].k;
        let im = net.layers[v].im;
        let out_l = cat[sel.primitive[u]].out_layout;
        let in_l = cat[sel.primitive[v]].in_layout;
        total += costs.dlt_cost(c, im, out_l, in_l);
    }
    Ok(total)
}

/// Baseline: the network time when a single fixed primitive family is
/// used everywhere (picking each layer's best member of that family, or
/// any applicable primitive if the family doesn't apply).
pub fn single_family_baseline(
    net: &Network,
    costs: &dyn CostSource,
    family: crate::primitives::Family,
) -> Result<Selection> {
    with_cache(costs, |c: &dyn CostSource| single_family_inner(net, c, family))
}

fn single_family_inner(
    net: &Network,
    costs: &dyn CostSource,
    family: crate::primitives::Family,
) -> Result<Selection> {
    let cat = catalog();
    let mut primitive = Vec::with_capacity(net.n_layers());
    for cfg in &net.layers {
        let row = costs.layer_costs(cfg);
        let pick = row
            .iter()
            .enumerate()
            .filter(|(p, t)| t.is_some() && cat[*p].family == family)
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(p, _)| p)
            .or_else(|| {
                row.iter()
                    .enumerate()
                    .filter(|(_, t)| t.is_some())
                    .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(p, _)| p)
            })
            .ok_or_else(|| anyhow::anyhow!("no applicable primitive"))?;
        primitive.push(pick);
    }
    let sel = Selection { primitive, objective_ms: 0.0, estimated_ms: 0.0 };
    let est = evaluate_inner(net, &sel, costs)?;
    Ok(Selection { objective_ms: est, estimated_ms: est, ..sel })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::networks;
    use crate::primitives::Family;
    use crate::simulator::{machine, Simulator};

    fn sim() -> Simulator {
        Simulator::new(machine::intel_i9_9900k())
    }

    #[test]
    fn selection_runs_on_all_six_networks() {
        let s = sim();
        for net in networks::selection_networks() {
            let sel = select(&net, &s).unwrap();
            assert_eq!(sel.primitive.len(), net.n_layers());
            assert!(sel.estimated_ms > 0.0);
            // plain min-time selection has no penalty terms
            assert_eq!(sel.objective_ms, sel.estimated_ms);
            // the solution's evaluated cost equals its objective
            let ev = evaluate(&net, &sel, &s).unwrap();
            assert!((ev - sel.estimated_ms).abs() / ev < 1e-9, "{ev} vs {}", sel.estimated_ms);
        }
    }

    #[test]
    fn selection_picks_applicable_primitives() {
        let s = sim();
        let net = networks::googlenet();
        let sel = select(&net, &s).unwrap();
        for (u, cfg) in net.layers.iter().enumerate() {
            assert!(catalog()[sel.primitive[u]].applicable(cfg));
        }
    }

    #[test]
    fn pbqp_beats_single_family_baselines() {
        let s = sim();
        let net = networks::vgg(11);
        let sel = select(&net, &s).unwrap();
        for fam in [Family::Direct, Family::Im2, Family::Mec] {
            let base = single_family_baseline(&net, &s, fam).unwrap();
            assert!(
                sel.estimated_ms <= base.estimated_ms * (1.0 + 1e-9),
                "{fam:?}: pbqp {} vs baseline {}",
                sel.estimated_ms,
                base.estimated_ms
            );
        }
    }

    #[test]
    fn selection_on_chain_is_optimal() {
        // chains reduce exactly with RI — spot check vs brute force on a
        // truncated VGG
        let s = sim();
        let mut net = networks::vgg(11);
        net.layers.truncate(4);
        net.edges.retain(|&(a, b)| a < 4 && b < 4);
        let prob = build_problem(&net, &s).unwrap();
        let fast = crate::pbqp::solve(&prob.graph);
        let exact = prob.graph.brute_force();
        assert!((fast.cost - exact.cost).abs() < 1e-9);
    }

    #[test]
    fn mixed_layout_selections_pay_dlt() {
        // evaluating a deliberately layout-alternating assignment must
        // cost more than the solver's choice
        let s = sim();
        let net = networks::vgg(11);
        let sel = select(&net, &s).unwrap();
        // force alternating chw/hwc primitives (im2col-copy-ab-ki / im2row-copy-ab-ik)
        let ki = crate::primitives::index_of("im2col-copy-ab-ki").unwrap();
        let ik = crate::primitives::index_of("im2row-copy-ab-ik").unwrap();
        let alt = Selection {
            primitive: (0..net.n_layers()).map(|i| if i % 2 == 0 { ki } else { ik }).collect(),
            objective_ms: 0.0,
            estimated_ms: 0.0,
        };
        let alt_cost = evaluate(&net, &alt, &s).unwrap();
        assert!(alt_cost > sel.estimated_ms);
    }

    #[test]
    fn cached_and_uncached_selection_agree() {
        // selecting through the cost-query engine must not change the
        // result: same assignment, same objective, bit for bit
        let s = sim();
        for net in [networks::vgg(11), networks::googlenet()] {
            let direct = select(&net, &s).unwrap();
            let cache = CostCache::new(&s);
            let via_cache = select(&net, &cache).unwrap();
            let table = cache.table_for(&net);
            let via_table = select(&net, &table).unwrap();
            assert_eq!(direct.primitive, via_cache.primitive);
            assert_eq!(direct.primitive, via_table.primitive);
            assert_eq!(direct.estimated_ms, via_cache.estimated_ms);
            assert_eq!(direct.estimated_ms, via_table.estimated_ms);
            let ev = evaluate(&net, &direct, &table).unwrap();
            assert_eq!(ev, evaluate(&net, &direct, &s).unwrap());
        }
    }

    #[test]
    fn table_source_missing_config_panics() {
        let t = TableSource::new(vec![], vec![], vec![], vec![]);
        let cfg = ConvConfig::new(1, 1, 7, 1, 1);
        assert!(std::panic::catch_unwind(|| t.layer_costs(&cfg).len()).is_err());
    }
}

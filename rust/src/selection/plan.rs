//! Compiled selection plans — the request-independent half of a
//! (platform, network) selection, frozen once and reused by every warm
//! request.
//!
//! [`SelectionPlan::compile`] walks the network exactly like
//! [`build_problem`](crate::selection::build_problem) does, but keeps
//! the result in **flat arenas**: the applicable catalog indices, the
//! unpenalised times and the workspace bytes of every (layer, choice)
//! pair live in dense `Vec`s laid out by the solver's row
//! [`offsets`](crate::pbqp::ReusableSolver::offsets), and the PBQP
//! topology — with its DLT edge matrices pre-assembled — lives in a
//! [`pbqp::ReusableSolver`] elimination template. A warm solve then
//! does zero graph construction and zero per-layer cost-cache lookups:
//! [`SelectionPlan::min_time_into`] prices nothing (the frozen times
//! *are* the node-cost arena) and [`SelectionPlan::with_budget_into`]
//! re-prices only the penalty terms, into a caller-retained
//! [`PlanScratch`]. After the first (priming) call on a scratch the
//! steady state allocates nothing — pinned by the counting-allocator
//! test in `rust/tests/alloc_counter.rs`.
//!
//! Bit-identity with the cold paths is by construction: the arenas hold
//! exactly the values the cold builders produce, in the same order, the
//! penalty arithmetic is the same expression, and the flat solve is
//! pinned bit-identical to a fresh [`pbqp::solve`](crate::pbqp::solve).
//! The differential suite in `rust/tests/plan.rs` re-checks all of it
//! against [`select`](crate::selection::select) and
//! [`select_with_budget`](crate::selection::memory::select_with_budget)
//! across the network zoo.

use crate::networks::Network;
use crate::pbqp;
use crate::primitives::catalog;
use crate::selection::memory::workspace_bytes;
use crate::selection::{with_cache, CostSource, Selection};
use anyhow::{ensure, Result};

/// Everything request-independent about selecting for one (network,
/// cost source) pair, compiled once: flat choice/time/workspace arenas
/// plus the solver's merged-edge elimination template. Immutable and
/// `Send + Sync` — the coordinator shares one per (platform, network
/// fingerprint) behind an `Arc`.
///
/// ```
/// use primsel::networks;
/// use primsel::selection::{self, plan::{PlanScratch, SelectionPlan}};
/// use primsel::simulator::{machine, Simulator};
///
/// let sim = Simulator::new(machine::intel_i9_9900k());
/// let net = networks::alexnet();
/// let plan = SelectionPlan::compile(&net, &sim).unwrap();
///
/// // warm solves run out of a retained scratch, no rebuilding
/// let mut scratch = PlanScratch::default();
/// let warm = plan.min_time_into(&mut scratch).to_selection();
///
/// // ... and are bit-identical to the cold path
/// let cold = selection::select(&net, &sim).unwrap();
/// assert_eq!(warm.primitive, cold.primitive);
/// assert_eq!(warm.estimated_ms, cold.estimated_ms);
/// ```
pub struct SelectionPlan {
    /// Flat applicable catalog indices: layer `u`'s choices span
    /// `solver.offsets()[u]..solver.offsets()[u+1]`.
    choices: Vec<usize>,
    /// Flat unpenalised times, same layout — the min-time cost arena.
    times: Vec<f64>,
    /// Flat workspace bytes, same layout.
    workspace: Vec<f64>,
    /// Frozen topology: merged-edge arena, worklist seeds, original
    /// edge matrices for the objective sum.
    solver: pbqp::ReusableSolver,
}

/// Caller-retained warm-solve buffers: the PBQP scratch (working-graph
/// clone target, elimination stack, choice buffer), the priced-cost
/// arena and the mapped primitive buffer. Keep one per worker thread
/// and reuse it across requests — and across plans; the buffers
/// re-shape on the fly — that reuse is what makes the steady state
/// allocation-free.
#[derive(Default)]
pub struct PlanScratch {
    solve: pbqp::SolveScratch,
    priced: Vec<f64>,
    primitive: Vec<usize>,
}

/// A borrowed view of one warm solve's result — no owned allocations;
/// valid until the next solve on the same scratch. Callers off the
/// zero-alloc path materialise it with [`Self::to_selection`].
#[derive(Debug, Clone, Copy)]
pub struct PlanSelection<'s> {
    /// Catalog index per layer.
    pub primitive: &'s [usize],
    /// The value the solver minimised (penalised for budgeted solves).
    pub objective_ms: f64,
    /// True (unpenalised) network time of the assignment, ms.
    pub estimated_ms: f64,
    /// Peak per-layer workspace of the assignment, bytes.
    pub peak_workspace_bytes: f64,
}

impl PlanSelection<'_> {
    /// Materialise an owned [`Selection`] (allocates).
    pub fn to_selection(&self) -> Selection {
        Selection {
            primitive: self.primitive.to_vec(),
            objective_ms: self.objective_ms,
            estimated_ms: self.estimated_ms,
        }
    }
}

impl SelectionPlan {
    /// Compile the plan for `net` under `costs` (memoized transparently,
    /// like every cost-consuming entry point).
    pub fn compile(net: &Network, costs: &dyn CostSource) -> Result<Self> {
        with_cache(costs, |c: &dyn CostSource| Self::compile_inner(net, c))
    }

    /// Compile against an already-memoized source (callers inside the
    /// [`with_cache`] funnel).
    pub(crate) fn compile_inner(net: &Network, costs: &dyn CostSource) -> Result<Self> {
        let cat = catalog();
        let mut node_costs = Vec::with_capacity(net.n_layers());
        let mut choice_rows: Vec<Vec<usize>> = Vec::with_capacity(net.n_layers());
        let mut choices = Vec::new();
        let mut workspace = Vec::new();
        for cfg in &net.layers {
            let row = costs.layer_costs(cfg);
            let mut ch = Vec::new();
            let mut nc = Vec::new();
            for (p, t) in row.iter().enumerate() {
                if let Some(t) = t {
                    ch.push(p);
                    nc.push(*t);
                    workspace.push(workspace_bytes(&cat[p], cfg));
                }
            }
            ensure!(!ch.is_empty(), "no applicable primitive for {cfg:?}");
            choices.extend_from_slice(&ch);
            node_costs.push(nc);
            choice_rows.push(ch);
        }
        let mut graph = pbqp::Graph::new(node_costs);
        for &(u, v) in &net.edges {
            // the tensor on this edge: u's output (k_u channels at v's
            // input resolution) — same assembly as `build_problem`
            let c = net.layers[u].k;
            let im = net.layers[v].im;
            let m = costs.dlt_matrix3(c, im);
            let cu = &choice_rows[u];
            let cv = &choice_rows[v];
            let mut mat = Vec::with_capacity(cu.len() * cv.len());
            for &pu in cu {
                let out_l = cat[pu].out_layout;
                for &pv in cv {
                    mat.push(m[out_l.index()][cat[pv].in_layout.index()]);
                }
            }
            graph.add_edge(u, v, mat);
        }
        let solver = pbqp::ReusableSolver::new(&graph);
        let times = graph.node_costs.into_iter().flatten().collect();
        Ok(Self { choices, times, workspace, solver })
    }

    /// Number of layers the plan was compiled for.
    pub fn n_layers(&self) -> usize {
        self.solver.offsets().len() - 1
    }

    /// Workspace values over all (layer, applicable primitive) pairs —
    /// the distinct budget levels worth sweeping.
    pub(crate) fn workspace_levels(&self) -> impl Iterator<Item = f64> + '_ {
        self.workspace.iter().copied()
    }

    /// Warm min-time solve: the frozen times are the cost arena, so
    /// this is one flat solve plus the choice mapping — zero graph
    /// construction, zero cache lookups, zero steady-state allocation.
    pub fn min_time_into<'s>(&self, scratch: &'s mut PlanScratch) -> PlanSelection<'s> {
        let (cost, choice) = self.solver.solve_flat_into(&self.times, &mut scratch.solve);
        let off = self.solver.offsets();
        let mut peak = 0.0f64;
        scratch.primitive.clear();
        for (u, &ci) in choice.iter().enumerate() {
            let slot = off[u] + ci;
            scratch.primitive.push(self.choices[slot]);
            peak = peak.max(self.workspace[slot]);
        }
        PlanSelection {
            primitive: &scratch.primitive,
            objective_ms: cost,
            estimated_ms: cost,
            peak_workspace_bytes: peak,
        }
    }

    /// Warm budgeted solve: re-price the penalty terms
    /// (`time + λ · max(0, workspace − budget) / MiB`, the same
    /// expression as [`select_with_budget`]) into the scratch's priced
    /// arena and solve flat. `objective_ms` is the penalised optimum;
    /// `estimated_ms` the true time of the chosen assignment.
    ///
    /// [`select_with_budget`]: crate::selection::memory::select_with_budget
    pub fn with_budget_into<'s>(
        &self,
        budget_bytes: f64,
        lambda_ms_per_mb: f64,
        scratch: &'s mut PlanScratch,
    ) -> PlanSelection<'s> {
        scratch.priced.clear();
        scratch.priced.extend(self.times.iter().zip(&self.workspace).map(|(t, w)| {
            let over = (*w - budget_bytes).max(0.0);
            *t + over / (1024.0 * 1024.0) * lambda_ms_per_mb
        }));
        let (cost, choice) = self.solver.solve_flat_into(&scratch.priced, &mut scratch.solve);
        let estimated = self.solver.cost_of_flat(&self.times, choice);
        let off = self.solver.offsets();
        let mut peak = 0.0f64;
        scratch.primitive.clear();
        for (u, &ci) in choice.iter().enumerate() {
            let slot = off[u] + ci;
            scratch.primitive.push(self.choices[slot]);
            peak = peak.max(self.workspace[slot]);
        }
        PlanSelection {
            primitive: &scratch.primitive,
            objective_ms: cost,
            estimated_ms: estimated,
            peak_workspace_bytes: peak,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::networks;
    use crate::selection::memory::{peak_workspace, select_with_budget};
    use crate::selection;
    use crate::simulator::{machine, Simulator};

    fn sim() -> Simulator {
        Simulator::new(machine::intel_i9_9900k())
    }

    #[test]
    fn warm_min_time_matches_cold_select_bit_for_bit() {
        let s = sim();
        let mut scratch = PlanScratch::default();
        for net in networks::selection_networks() {
            let plan = SelectionPlan::compile(&net, &s).unwrap();
            assert_eq!(plan.n_layers(), net.n_layers());
            let cold = selection::select(&net, &s).unwrap();
            // several rounds on one scratch: reuse must not drift
            for _ in 0..3 {
                let warm = plan.min_time_into(&mut scratch);
                assert_eq!(warm.primitive, &cold.primitive[..]);
                assert_eq!(warm.objective_ms, cold.objective_ms);
                assert_eq!(warm.estimated_ms, cold.estimated_ms);
                assert_eq!(warm.peak_workspace_bytes, peak_workspace(&net, &cold));
            }
        }
    }

    #[test]
    fn warm_budget_matches_cold_select_with_budget_bit_for_bit() {
        let s = sim();
        let net = networks::vgg(11);
        let plan = SelectionPlan::compile(&net, &s).unwrap();
        let mut scratch = PlanScratch::default();
        let free = selection::select(&net, &s).unwrap();
        let free_peak = peak_workspace(&net, &free);
        for frac in [0.01, 0.1, 0.5, 1.0] {
            let budget = free_peak * frac;
            let cold = select_with_budget(&net, &s, budget, 50.0).unwrap();
            let warm = plan.with_budget_into(budget, 50.0, &mut scratch);
            assert_eq!(warm.primitive, &cold.primitive[..]);
            assert_eq!(warm.objective_ms, cold.objective_ms);
            assert_eq!(warm.estimated_ms, cold.estimated_ms);
            assert_eq!(warm.peak_workspace_bytes, peak_workspace(&net, &cold));
        }
    }

    #[test]
    fn one_scratch_serves_many_plans() {
        // buffers re-shape when the scratch moves between differently
        // sized plans — interleave two networks on one scratch
        let s = sim();
        let a = networks::alexnet();
        let b = networks::googlenet();
        let plan_a = SelectionPlan::compile(&a, &s).unwrap();
        let plan_b = SelectionPlan::compile(&b, &s).unwrap();
        let cold_a = selection::select(&a, &s).unwrap();
        let cold_b = selection::select(&b, &s).unwrap();
        let mut scratch = PlanScratch::default();
        for _ in 0..3 {
            assert_eq!(plan_a.min_time_into(&mut scratch).primitive, &cold_a.primitive[..]);
            assert_eq!(plan_b.min_time_into(&mut scratch).primitive, &cold_b.primitive[..]);
        }
    }

    #[test]
    fn to_selection_round_trips_the_view() {
        let s = sim();
        let net = networks::alexnet();
        let plan = SelectionPlan::compile(&net, &s).unwrap();
        let mut scratch = PlanScratch::default();
        let view = plan.min_time_into(&mut scratch);
        let (obj, est) = (view.objective_ms, view.estimated_ms);
        let owned = view.to_selection();
        assert_eq!(owned.objective_ms, obj);
        assert_eq!(owned.estimated_ms, est);
        assert_eq!(owned.primitive.len(), net.n_layers());
    }
}

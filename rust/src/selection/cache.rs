//! # The cost-query engine
//!
//! Every consumer of primitive/DLT costs — `build_problem`, `evaluate`,
//! `single_family_baseline`, the memory-aware solver, the experiment
//! sweeps and the benches — goes through [`CostSource`]. This module adds
//! the caching layer between those consumers and the underlying source:
//!
//! * [`CostCache`] memoizes whole per-layer cost rows and whole 3x3 DLT
//!   matrices keyed by `ConvConfig` / `(c, im)`. A simulator query behind
//!   the cache is computed exactly once per distinct key; repeat queries
//!   are hash lookups. Values are bit-identical to the uncached source
//!   (the cache stores what the source returned — no re-derivation), a
//!   property pinned by `rust/tests/proptests.rs`.
//! * [`CostCache::table_for`] precomputes a dense per-network
//!   [`TableSource`](super::TableSource): one row per distinct layer
//!   config and one DLT matrix per distinct edge tensor. Selection,
//!   evaluation and baselines over the table never touch the simulator
//!   again, and table queries hand out *borrowed* rows (no per-query
//!   clone) via `Cow::Borrowed`.
//!
//! Layering (paper Figure 2, steps ii–iv):
//!
//! ```text
//!   build_problem / evaluate / baselines / experiments
//!                |         (Cow<[Option<f64>]> rows, 3x3 DLT matrices)
//!          CostCache  ── table_for ──► TableSource (dense, borrowed rows)
//!                |
//!      Simulator (integer-keyed noise)  ·  Predictor tables  ·  datasets
//! ```
//!
//! The cache is single-threaded by design (interior `RefCell`s); the
//! parallel sweeps in `dataset`/`experiments` shard work per thread and
//! give each shard its own cache.

use super::{CostSource, TableSource};
use crate::layers::ConvConfig;
use crate::networks::Network;
use crate::primitives::Layout;
use std::borrow::Cow;
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

/// A memoizing layer over any [`CostSource`].
pub struct CostCache<'a> {
    inner: &'a dyn CostSource,
    rows: RefCell<HashMap<ConvConfig, Rc<[Option<f64>]>>>,
    dlt: RefCell<HashMap<(u32, u32), [[f64; 3]; 3]>>,
}

impl<'a> CostCache<'a> {
    pub fn new(inner: &'a dyn CostSource) -> Self {
        Self {
            inner,
            rows: RefCell::new(HashMap::new()),
            dlt: RefCell::new(HashMap::new()),
        }
    }

    /// The memoized cost row for a layer config. A warm query is a hash
    /// lookup plus a refcount bump — no allocation or copy; the row is
    /// computed at most once.
    pub fn row(&self, cfg: &ConvConfig) -> Rc<[Option<f64>]> {
        if let Some(r) = self.rows.borrow().get(cfg) {
            return Rc::clone(r);
        }
        let r: Rc<[Option<f64>]> = self.inner.layer_costs(cfg).into_owned().into();
        self.rows.borrow_mut().insert(*cfg, Rc::clone(&r));
        r
    }

    /// The memoized 3x3 DLT matrix for an edge tensor.
    pub fn matrix(&self, c: u32, im: u32) -> [[f64; 3]; 3] {
        if let Some(m) = self.dlt.borrow().get(&(c, im)) {
            return *m;
        }
        let m = self.inner.dlt_matrix3(c, im);
        self.dlt.borrow_mut().insert((c, im), m);
        m
    }

    /// Number of distinct layer rows materialised so far.
    pub fn rows_cached(&self) -> usize {
        self.rows.borrow().len()
    }

    /// Number of distinct DLT matrices materialised so far.
    pub fn dlt_cached(&self) -> usize {
        self.dlt.borrow().len()
    }

    /// Simulated Table-4 profiling wall-clock for a whole network (25
    /// runs per applicable primitive per layer), summed over memoized
    /// rows — the one place the "what profiling would cost" aggregation
    /// lives.
    pub fn network_profiling_wallclock_ms(&self, net: &Network) -> f64 {
        net.layers
            .iter()
            .map(|cfg| crate::simulator::wallclock_from_row(&self.row(cfg)))
            .sum()
    }

    /// Precompute the dense cost table for one network: every distinct
    /// layer config profiled once, every distinct edge tensor's DLT
    /// matrix computed once. Downstream `select`/`evaluate`/baseline
    /// calls over the returned table never re-profile.
    pub fn table_for(&self, net: &Network) -> TableSource {
        let mut configs: Vec<ConvConfig> = Vec::with_capacity(net.n_layers());
        let mut prim = Vec::with_capacity(net.n_layers());
        for cfg in &net.layers {
            configs.push(*cfg);
            prim.push(self.row(cfg).to_vec());
        }
        let mut keys: Vec<(u32, u32)> = net
            .edges
            .iter()
            .map(|&(u, v)| (net.layers[u].k, net.layers[v].im))
            .collect();
        keys.sort_unstable();
        keys.dedup();
        let mats = keys.iter().map(|&(c, im)| self.matrix(c, im)).collect();
        TableSource::new(configs, prim, keys, mats)
    }
}

impl CostSource for CostCache<'_> {
    fn layer_costs(&self, cfg: &ConvConfig) -> Cow<'_, [Option<f64>]> {
        // the Cow contract needs an owned row; the copy happens only at
        // this trait boundary, inherent-path callers stay allocation-free
        Cow::Owned(self.row(cfg).to_vec())
    }

    fn dlt_cost(&self, c: u32, im: u32, src: Layout, dst: Layout) -> f64 {
        if src == dst {
            return 0.0;
        }
        self.matrix(c, im)[src.index()][dst.index()]
    }

    fn dlt_matrix3(&self, c: u32, im: u32) -> [[f64; 3]; 3] {
        self.matrix(c, im)
    }

    fn is_memoized(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::networks;
    use crate::simulator::{machine, Simulator};

    #[test]
    fn cached_rows_bit_identical_to_source() {
        let sim = Simulator::new(machine::intel_i9_9900k());
        let cache = CostCache::new(&sim);
        let cfg = ConvConfig::new(64, 64, 56, 1, 3);
        let direct = sim.profile_layer(&cfg);
        assert_eq!(cache.row(&cfg).as_ref(), direct.as_slice());
        // second query: cache hit, same shared allocation
        let (a, b) = (cache.row(&cfg), cache.row(&cfg));
        assert!(std::rc::Rc::ptr_eq(&a, &b));
        assert_eq!(a.as_ref(), direct.as_slice());
        assert_eq!(cache.rows_cached(), 1);
        let m = cache.matrix(64, 28);
        assert_eq!(m, sim.dlt_matrix(64, 28));
        assert_eq!(cache.dlt_cached(), 1);
    }

    #[test]
    fn table_for_deduplicates_queries() {
        let sim = Simulator::new(machine::amd_a10_7850k());
        let cache = CostCache::new(&sim);
        let net = networks::vgg(16); // many repeated layer configs
        let table = cache.table_for(&net);
        assert!(cache.rows_cached() < net.n_layers());
        // the table answers the same queries as the simulator
        for cfg in &net.layers {
            assert_eq!(table.layer_costs(cfg).as_ref(), sim.profile_layer(cfg).as_slice());
        }
        for &(u, v) in &net.edges {
            let (c, im) = (net.layers[u].k, net.layers[v].im);
            for src in Layout::ALL {
                for dst in Layout::ALL {
                    assert_eq!(table.dlt_cost(c, im, src, dst), sim.profile_dlt(c, im, src, dst));
                }
            }
        }
    }

    #[test]
    fn cache_as_source_matches_inner() {
        let sim = Simulator::new(machine::arm_cortex_a73());
        let cache = CostCache::new(&sim);
        let cfg = ConvConfig::new(32, 16, 112, 2, 5);
        assert_eq!(cache.layer_costs(&cfg).as_ref(), sim.layer_costs(&cfg).as_ref());
        assert_eq!(
            cache.dlt_cost(16, 56, Layout::Chw, Layout::Hwc),
            sim.dlt_cost(16, 56, Layout::Chw, Layout::Hwc)
        );
        assert_eq!(cache.dlt_cost(16, 56, Layout::Hwc, Layout::Hwc), 0.0);
        assert!(cache.is_memoized());
    }
}

//! # The cost-query engine
//!
//! Every consumer of primitive/DLT costs — `build_problem`, `evaluate`,
//! `single_family_baseline`, the memory-aware solver, the experiment
//! sweeps, the [`Coordinator`](crate::coordinator) and the benches — goes
//! through [`CostSource`]. This module adds the caching layer between
//! those consumers and the underlying source:
//!
//! * [`CostCache`] memoizes whole per-layer cost rows and whole 3x3 DLT
//!   matrices keyed by `ConvConfig` / `(c, im)`. A simulator query behind
//!   the cache is computed exactly once per distinct key; repeat queries
//!   are hash lookups. Values are bit-identical to the uncached source
//!   (the cache stores what the source returned — no re-derivation), a
//!   property pinned by `rust/tests/proptests.rs` and, for concurrent
//!   access, `rust/tests/concurrency.rs`.
//! * [`CostCache::table_for`] precomputes a dense per-network
//!   [`TableSource`](super::TableSource): one row per distinct layer
//!   config and one DLT matrix per distinct edge tensor. Selection,
//!   evaluation and baselines over the table never touch the simulator
//!   again, and table queries hand out *borrowed* rows (no per-query
//!   clone) via `Cow::Borrowed`.
//!
//! Layering (paper Figure 2, steps ii–iv; see `ARCHITECTURE.md` for the
//! end-to-end version):
//!
//! ```text
//!   Coordinator / build_problem / evaluate / baselines / experiments
//!                |         (Cow<[Option<f64>]> rows, 3x3 DLT matrices)
//!          CostCache  ── table_for ──► TableSource (dense, borrowed rows)
//!                |
//!      Simulator (integer-keyed noise)  ·  Predictor tables  ·  datasets
//! ```
//!
//! ## Concurrency model
//!
//! The cache is `Send + Sync`: the row and matrix maps are split across
//! [`N_SHARDS`] independent `RwLock`ed shards (keyed by a hash of the
//! `ConvConfig` / `(c, im)` key), and rows are shared as
//! `Arc<[Option<f64>]>`, so one warm cache can serve many concurrent
//! selection requests — the multi-tenant serving shape the
//! [`Coordinator`](crate::coordinator) builds on. Warm queries take a
//! shard read lock (shared, uncontended between distinct shards); a miss
//! computes the value *outside* the write lock, so a slow profile on one
//! key never blocks hits on other keys of the same shard. Because the
//! underlying sources are deterministic, a racing double-compute of the
//! same key produces bit-identical values; the first insert wins and
//! later readers share its allocation.
//!
//! Use one shared cache (behind `&` or `Arc`) when several threads query
//! the *same platform* — per-thread caches only make sense when each
//! thread owns a distinct source. Single-threaded callers pay one
//! uncontended lock per query, which profiling shows is noise next to a
//! simulator profile or a PJRT predict.

use super::{CostSource, TableSource};
use crate::layers::ConvConfig;
use crate::networks::Network;
use crate::primitives::Layout;
use crate::sync;
use std::borrow::Cow;
use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// Number of independent lock shards per map. A power of two (the shard
/// pick is a mask) comfortably above the core counts we serve from, so
/// concurrent misses on *different* keys rarely queue on one lock.
pub const N_SHARDS: usize = 16;

/// Hit/miss counters of a [`CostCache`], split by map. Counters are
/// monotonic over the cache's lifetime; use [`CacheStats::since`] to get
/// the delta across a batch (how the [`Coordinator`](crate::coordinator)
/// reports per-batch hit rates).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Layer-row lookups answered from the cache.
    pub row_hits: u64,
    /// Layer-row lookups that had to query the inner source.
    pub row_misses: u64,
    /// DLT-matrix lookups answered from the cache.
    pub dlt_hits: u64,
    /// DLT-matrix lookups that had to query the inner source.
    pub dlt_misses: u64,
}

impl CacheStats {
    /// Total lookups answered from the cache.
    pub fn hits(&self) -> u64 {
        self.row_hits + self.dlt_hits
    }

    /// Total lookups that reached the inner source.
    pub fn misses(&self) -> u64 {
        self.row_misses + self.dlt_misses
    }

    /// Total lookups.
    pub fn lookups(&self) -> u64 {
        self.hits() + self.misses()
    }

    /// Fraction of lookups served from the cache (0.0 when idle). This
    /// is the name the observability layer and `ServiceStats::render`
    /// use; [`CacheStats::hit_rate`] is the original spelling.
    pub fn hit_ratio(&self) -> f64 {
        self.hit_rate()
    }

    /// Fraction of lookups served from the cache (0.0 when idle).
    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            0.0
        } else {
            self.hits() as f64 / self.lookups() as f64
        }
    }

    /// Counter delta since an `earlier` snapshot of the same cache.
    pub fn since(&self, earlier: &CacheStats) -> CacheStats {
        CacheStats {
            row_hits: self.row_hits.saturating_sub(earlier.row_hits),
            row_misses: self.row_misses.saturating_sub(earlier.row_misses),
            dlt_hits: self.dlt_hits.saturating_sub(earlier.dlt_hits),
            dlt_misses: self.dlt_misses.saturating_sub(earlier.dlt_misses),
        }
    }
}

/// The wrapped source: borrowed for the transient per-call caches the
/// selection entry points create, owned (`Arc`) for the long-lived
/// per-platform caches the coordinator serves from.
enum Inner<'a> {
    Borrowed(&'a dyn CostSource),
    Shared(Arc<dyn CostSource>),
}

/// A memoizing, thread-safe layer over any [`CostSource`].
///
/// One warm `CostCache` can be shared across threads (it is
/// `Send + Sync`); results are bit-identical to querying the inner
/// source directly, no matter how many threads race on it.
///
/// ```
/// use primsel::selection::{self, CostCache};
/// use primsel::simulator::{machine, Simulator};
///
/// let sim = Simulator::new(machine::intel_i9_9900k());
/// let cache = CostCache::new(&sim); // Send + Sync: share by reference
/// let net = primsel::networks::vgg(11);
/// let sequential = selection::select(&net, &cache).unwrap();
///
/// // four concurrent tenants select over the same warm cache
/// let concurrent: Vec<_> = std::thread::scope(|s| {
///     let handles: Vec<_> = (0..4)
///         .map(|_| s.spawn(|| selection::select(&net, &cache).unwrap()))
///         .collect();
///     handles.into_iter().map(|h| h.join().unwrap()).collect()
/// });
/// for sel in &concurrent {
///     assert_eq!(sel.primitive, sequential.primitive);
///     assert_eq!(sel.estimated_ms, sequential.estimated_ms);
/// }
/// assert!(cache.stats().row_hits > 0); // the repeats were cache hits
/// ```
pub struct CostCache<'a> {
    inner: Inner<'a>,
    rows: [RwLock<HashMap<ConvConfig, Arc<[Option<f64>]>>>; N_SHARDS],
    dlt: [RwLock<HashMap<(u32, u32), [[f64; 3]; 3]>>; N_SHARDS],
    row_hits: AtomicU64,
    row_misses: AtomicU64,
    dlt_hits: AtomicU64,
    dlt_misses: AtomicU64,
}

fn shard_of<K: Hash>(key: &K) -> usize {
    let mut h = DefaultHasher::new();
    key.hash(&mut h);
    (h.finish() as usize) & (N_SHARDS - 1)
}

impl<'a> CostCache<'a> {
    /// A cache borrowing its source — the transient, per-call shape the
    /// selection entry points use.
    pub fn new(inner: &'a dyn CostSource) -> Self {
        Self::build(Inner::Borrowed(inner))
    }

    fn build(inner: Inner<'a>) -> Self {
        Self {
            inner,
            rows: std::array::from_fn(|_| RwLock::new(HashMap::new())),
            dlt: std::array::from_fn(|_| RwLock::new(HashMap::new())),
            row_hits: AtomicU64::new(0),
            row_misses: AtomicU64::new(0),
            dlt_hits: AtomicU64::new(0),
            dlt_misses: AtomicU64::new(0),
        }
    }

    fn source(&self) -> &dyn CostSource {
        match &self.inner {
            Inner::Borrowed(s) => *s,
            Inner::Shared(s) => s.as_ref(),
        }
    }

    /// The memoized cost row for a layer config. A warm query is a shard
    /// read lock, a hash lookup and a refcount bump — no allocation or
    /// copy; the row is computed at most once per distinct key (a racing
    /// double-compute stores the first result; the values are
    /// bit-identical either way because sources are deterministic).
    pub fn row(&self, cfg: &ConvConfig) -> Arc<[Option<f64>]> {
        let shard = &self.rows[shard_of(cfg)];
        if let Some(r) = sync::read(shard).get(cfg) {
            self.row_hits.fetch_add(1, Ordering::Relaxed);
            return Arc::clone(r);
        }
        self.row_misses.fetch_add(1, Ordering::Relaxed);
        // compute outside the write lock: a slow profile on this key must
        // not block hits (or other misses) on the rest of the shard
        let r: Arc<[Option<f64>]> = self.source().layer_costs(cfg).into_owned().into();
        let mut map = sync::write(shard);
        Arc::clone(map.entry(*cfg).or_insert(r))
    }

    /// The memoized 3x3 DLT matrix for an edge tensor.
    pub fn matrix(&self, c: u32, im: u32) -> [[f64; 3]; 3] {
        let key = (c, im);
        let shard = &self.dlt[shard_of(&key)];
        if let Some(m) = sync::read(shard).get(&key) {
            self.dlt_hits.fetch_add(1, Ordering::Relaxed);
            return *m;
        }
        self.dlt_misses.fetch_add(1, Ordering::Relaxed);
        let m = self.source().dlt_matrix3(c, im);
        *sync::write(shard).entry(key).or_insert(m)
    }

    /// Number of distinct layer rows materialised so far.
    pub fn rows_cached(&self) -> usize {
        self.rows.iter().map(|s| sync::read(s).len()).sum()
    }

    /// Number of distinct DLT matrices materialised so far.
    pub fn dlt_cached(&self) -> usize {
        self.dlt.iter().map(|s| sync::read(s).len()).sum()
    }

    /// Snapshot of the hit/miss counters. Monotonic; pair with
    /// [`CacheStats::since`] for per-batch deltas. Under concurrency the
    /// snapshot is *approximate* (counters are independent relaxed
    /// atomics), which is fine for the reporting it feeds.
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            row_hits: self.row_hits.load(Ordering::Relaxed),
            row_misses: self.row_misses.load(Ordering::Relaxed),
            dlt_hits: self.dlt_hits.load(Ordering::Relaxed),
            dlt_misses: self.dlt_misses.load(Ordering::Relaxed),
        }
    }

    /// Simulated Table-4 profiling wall-clock for a whole network (25
    /// runs per applicable primitive per layer), summed over memoized
    /// rows — the one place the "what profiling would cost" aggregation
    /// lives.
    pub fn network_profiling_wallclock_ms(&self, net: &Network) -> f64 {
        net.layers
            .iter()
            .map(|cfg| crate::simulator::wallclock_from_row(&self.row(cfg)))
            .sum()
    }

    /// Precompute the dense cost table for one network: every distinct
    /// layer config profiled once, every distinct edge tensor's DLT
    /// matrix computed once. Downstream `select`/`evaluate`/baseline
    /// calls over the returned table never re-profile.
    pub fn table_for(&self, net: &Network) -> TableSource {
        let mut configs: Vec<ConvConfig> = Vec::with_capacity(net.n_layers());
        let mut prim = Vec::with_capacity(net.n_layers());
        for cfg in &net.layers {
            configs.push(*cfg);
            prim.push(self.row(cfg).to_vec());
        }
        let mut keys: Vec<(u32, u32)> = net
            .edges
            .iter()
            .map(|&(u, v)| (net.layers[u].k, net.layers[v].im))
            .collect();
        keys.sort_unstable();
        keys.dedup();
        let mats = keys.iter().map(|&(c, im)| self.matrix(c, im)).collect();
        TableSource::new(configs, prim, keys, mats)
    }
}

impl CostCache<'static> {
    /// A cache owning its source — the long-lived, per-platform shape the
    /// [`Coordinator`](crate::coordinator) keeps warm across batches.
    pub fn new_shared(inner: Arc<dyn CostSource>) -> Self {
        Self::build(Inner::Shared(inner))
    }
}

impl CostSource for CostCache<'_> {
    fn layer_costs(&self, cfg: &ConvConfig) -> Cow<'_, [Option<f64>]> {
        // the Cow contract needs an owned row; the copy happens only at
        // this trait boundary, inherent-path callers stay allocation-free
        Cow::Owned(self.row(cfg).to_vec())
    }

    fn dlt_cost(&self, c: u32, im: u32, src: Layout, dst: Layout) -> f64 {
        if src == dst {
            return 0.0;
        }
        self.matrix(c, im)[src.index()][dst.index()]
    }

    fn dlt_matrix3(&self, c: u32, im: u32) -> [[f64; 3]; 3] {
        self.matrix(c, im)
    }

    fn is_memoized(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::networks;
    use crate::simulator::{machine, Simulator};

    #[test]
    fn cached_rows_bit_identical_to_source() {
        let sim = Simulator::new(machine::intel_i9_9900k());
        let cache = CostCache::new(&sim);
        let cfg = ConvConfig::new(64, 64, 56, 1, 3);
        let direct = sim.profile_layer(&cfg);
        assert_eq!(cache.row(&cfg).as_ref(), direct.as_slice());
        // second query: cache hit, same shared allocation
        let (a, b) = (cache.row(&cfg), cache.row(&cfg));
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(a.as_ref(), direct.as_slice());
        assert_eq!(cache.rows_cached(), 1);
        let m = cache.matrix(64, 28);
        assert_eq!(m, sim.dlt_matrix(64, 28));
        assert_eq!(cache.dlt_cached(), 1);
    }

    #[test]
    fn table_for_deduplicates_queries() {
        let sim = Simulator::new(machine::amd_a10_7850k());
        let cache = CostCache::new(&sim);
        let net = networks::vgg(16); // many repeated layer configs
        let table = cache.table_for(&net);
        assert!(cache.rows_cached() < net.n_layers());
        // the table answers the same queries as the simulator
        for cfg in &net.layers {
            assert_eq!(table.layer_costs(cfg).as_ref(), sim.profile_layer(cfg).as_slice());
        }
        for &(u, v) in &net.edges {
            let (c, im) = (net.layers[u].k, net.layers[v].im);
            for src in Layout::ALL {
                for dst in Layout::ALL {
                    assert_eq!(table.dlt_cost(c, im, src, dst), sim.profile_dlt(c, im, src, dst));
                }
            }
        }
    }

    #[test]
    fn cache_as_source_matches_inner() {
        let sim = Simulator::new(machine::arm_cortex_a73());
        let cache = CostCache::new(&sim);
        let cfg = ConvConfig::new(32, 16, 112, 2, 5);
        assert_eq!(cache.layer_costs(&cfg).as_ref(), sim.layer_costs(&cfg).as_ref());
        assert_eq!(
            cache.dlt_cost(16, 56, Layout::Chw, Layout::Hwc),
            sim.dlt_cost(16, 56, Layout::Chw, Layout::Hwc)
        );
        assert_eq!(cache.dlt_cost(16, 56, Layout::Hwc, Layout::Hwc), 0.0);
        assert!(cache.is_memoized());
    }

    #[test]
    fn cache_is_send_sync_and_shared_variant_owns() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<CostCache<'_>>();

        let cache = CostCache::new_shared(Arc::new(Simulator::new(
            machine::intel_i9_9900k(),
        )));
        let sim = Simulator::new(machine::intel_i9_9900k());
        let cfg = ConvConfig::new(64, 64, 56, 1, 3);
        assert_eq!(cache.row(&cfg).as_ref(), sim.profile_layer(&cfg).as_slice());
    }

    #[test]
    fn stats_count_hits_and_misses() {
        let sim = Simulator::new(machine::intel_i9_9900k());
        let cache = CostCache::new(&sim);
        assert_eq!(cache.stats(), CacheStats::default());
        let cfg = ConvConfig::new(64, 64, 56, 1, 3);
        cache.row(&cfg);
        cache.row(&cfg);
        cache.matrix(64, 28);
        cache.matrix(64, 28);
        cache.matrix(64, 28);
        let s = cache.stats();
        assert_eq!((s.row_hits, s.row_misses), (1, 1));
        assert_eq!((s.dlt_hits, s.dlt_misses), (2, 1));
        assert_eq!(s.lookups(), 5);
        assert!((s.hit_rate() - 0.6).abs() < 1e-12);
        let later = CacheStats { row_hits: 5, ..s };
        assert_eq!(later.since(&s), CacheStats { row_hits: 4, ..CacheStats::default() });
    }
}
